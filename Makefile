# FlowMoE reproduction — top-level targets.
#
# `make artifacts` exports the AOT HLO artifacts the PJRT runtime and the
# end-to-end trainer consume. It needs the python toolchain (JAX) and is
# the only step that touches python; the rust binary is self-contained
# afterwards. Everything tier-1 runs (build, tests, benches, sweeps)
# works without artifacts — artifact-dependent tests skip themselves.

ARTIFACTS_DIR := rust/artifacts

.PHONY: artifacts build test bench clean-artifacts

artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

bench: build
	cd rust && for b in table1 table3 table4 table5_ablation table6_energy_mem \
		fig4_bo fig6_custom_layers perf_hotpath tableA3_tuners tableA4_fixed_sp \
		tableA5_bo_hparams tableA7_stress tableA8_util tableA11_imbalance \
		tableA12_hetero; do cargo bench --bench $$b; done

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)
