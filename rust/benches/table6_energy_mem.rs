//! Paper Table 6: per-worker energy (J) and memory (GB) per iteration,
//! 5 frameworks x 4 models, Cluster 1 / 16 GPUs.
//!
//! Energy absolute joules use our power profile (the paper's nvidia-smi
//! integrals are testbed-specific); the comparison target is the
//! *relative savings* of FlowMoE vs each baseline (paper: 10-16 % vs
//! ScheMoE, 33-41 % vs vanilla).
//!
//! All (model, policy) cells — 4 baselines + a 5-point FlowMoE S_p grid
//! per model — run concurrently on the sweep engine.

use flowmoe::config::{preset, ClusterProfile, ModelCfg};
use flowmoe::cost::TaskCosts;
use flowmoe::metrics::{energy_joules, peak_memory};
use flowmoe::report::Table;
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;
use flowmoe::sweep::par_map;

const SP_GRID: [f64; 5] = [1e6, 2.5e6, 8e6, 32e6, 128e6];
/// Cells per model row: vanilla, FasterMoE, Tutel, ScheMoE, then the grid.
const CELLS: usize = 4 + SP_GRID.len();

fn main() {
    let cl = ClusterProfile::cluster1(16);
    let paper_mem = [
        ("GPT2-Tiny-MoE", 2.45, 2.42),
        ("BERT-Large-MoE", 4.19, 3.89),
        ("LLaMA2-MoE", 12.43, 11.01),
        ("DeepSeek-V2-S", 19.42, 17.57),
    ];
    let mut cases: Vec<(ModelCfg, Policy)> = Vec::new();
    for (name, _, _) in paper_mem {
        let cfg = preset(name).unwrap();
        for pol in [
            Policy::vanilla_ep(),
            Policy::faster_moe(2),
            Policy::tutel(2),
            Policy::sche_moe(2),
        ] {
            cases.push((cfg.clone(), pol));
        }
        // FlowMoE at the BO-tuned S_p (fixed 2.5 MB is far off-optimum for
        // the huge-AR DeepSeek configs)
        for &sp in &SP_GRID {
            cases.push((cfg.clone(), Policy::flow_moe(2, sp)));
        }
    }
    let results = par_map(&cases, |_, (cfg, pol)| {
        let costs = TaskCosts::build(cfg, &cl);
        let dag = build_dag(cfg, &costs, pol);
        let tl = simulate(&dag);
        (
            energy_joules(&tl, &cl.power),
            peak_memory(cfg, &cl, pol, &dag, &tl) / 1e9,
        )
    });

    let mut t = Table::new(
        "Table 6 — per-worker energy (J) / memory (GB) per iteration (Cluster 1, 16 GPUs)",
        &["model", "vanillaEP", "FasterMoE", "Tutel", "ScheMoE", "FlowMoE", "E saved vs vanilla", "M saved vs vanilla", "paper E/M saved"],
    );
    for (mi, (name, p_mem_van, p_mem_flow)) in paper_mem.iter().enumerate() {
        let row = &results[mi * CELLS..(mi + 1) * CELLS];
        let (ev, mv) = row[0];
        let (efm, mfm) = row[1];
        let (et, mt) = row[2];
        let (es, msc) = row[3];
        let (ef, mf) = row[4..]
            .iter()
            .cloned()
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap();
        let fmt = |e: f64, m: f64| format!("{e:.1}J/{m:.2}GB");
        t.row(vec![
            (*name).into(),
            fmt(ev, mv),
            fmt(efm, mfm),
            fmt(et, mt),
            fmt(es, msc),
            fmt(ef, mf),
            format!("{:.0}%", (1.0 - ef / ev) * 100.0),
            format!("{:.0}%", (1.0 - mf / mv) * 100.0),
            format!("~41%/{:.0}%", (1.0 - p_mem_flow / p_mem_van) * 100.0),
        ]);
    }
    t.print();
    println!("\npaper shape: FlowMoE lowest energy and memory; FasterMoE highest memory (expert replication).");
}
