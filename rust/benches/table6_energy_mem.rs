//! Paper Table 6: per-worker energy (J) and memory (GB) per iteration,
//! 5 frameworks x 4 models, Cluster 1 / 16 GPUs.
//!
//! Energy absolute joules use our power profile (the paper's nvidia-smi
//! integrals are testbed-specific); the comparison target is the
//! *relative savings* of FlowMoE vs each baseline (paper: 10-16 % vs
//! ScheMoE, 33-41 % vs vanilla).

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::cost::TaskCosts;
use flowmoe::metrics::{energy_joules, peak_memory};
use flowmoe::report::Table;
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;

fn main() {
    let cl = ClusterProfile::cluster1(16);
    let paper_mem = [
        ("GPT2-Tiny-MoE", 2.45, 2.42),
        ("BERT-Large-MoE", 4.19, 3.89),
        ("LLaMA2-MoE", 12.43, 11.01),
        ("DeepSeek-V2-S", 19.42, 17.57),
    ];
    let mut t = Table::new(
        "Table 6 — per-worker energy (J) / memory (GB) per iteration (Cluster 1, 16 GPUs)",
        &["model", "vanillaEP", "FasterMoE", "Tutel", "ScheMoE", "FlowMoE", "E saved vs vanilla", "M saved vs vanilla", "paper E/M saved"],
    );
    for (name, p_mem_van, p_mem_flow) in paper_mem {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &cl);
        let run = |pol: &Policy| {
            let dag = build_dag(&cfg, &costs, pol);
            let tl = simulate(&dag);
            (
                energy_joules(&tl, &cl.power),
                peak_memory(&cfg, &cl, pol, &dag, &tl) / 1e9,
            )
        };
        let (ev, mv) = run(&Policy::vanilla_ep());
        let (efm, mfm) = run(&Policy::faster_moe(2));
        let (et, mt) = run(&Policy::tutel(2));
        let (es, msc) = run(&Policy::sche_moe(2));
        // FlowMoE at the BO-tuned S_p (fixed 2.5 MB is far off-optimum for
        // the huge-AR DeepSeek configs)
        let (ef, mf) = [1e6, 2.5e6, 8e6, 32e6, 128e6]
            .iter()
            .map(|&sp| run(&Policy::flow_moe(2, sp)))
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap();
        let fmt = |e: f64, m: f64| format!("{e:.1}J/{m:.2}GB");
        t.row(vec![
            name.into(),
            fmt(ev, mv),
            fmt(efm, mfm),
            fmt(et, mt),
            fmt(es, msc),
            fmt(ef, mf),
            format!("{:.0}%", (1.0 - ef / ev) * 100.0),
            format!("{:.0}%", (1.0 - mf / mv) * 100.0),
            format!("~41%/{:.0}%", (1.0 - p_mem_flow / p_mem_van) * 100.0),
        ]);
    }
    t.print();
    println!("\npaper shape: FlowMoE lowest energy and memory; FasterMoE highest memory (expert replication).");
}
