//! Paper Table A.5: BO hyperparameter sensitivity (acquisition function x
//! GP kernel) on BERT-Large-MoE, Cluster 1 / 16 GPUs.
//!
//! Each (acquisition, kernel) cell is an independent BO tuning run, so
//! the grid fans out through `sweep::par_map` (input-ordered: the printed
//! table is identical to the old serial loop's).

use flowmoe::bo::{Acquisition, BoTuner, Kernel};
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::par_map;
use flowmoe::util::fmt_ms;

fn main() {
    let cfg = preset("BERT-Large-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let max = cfg.ar_bytes_per_block();

    let rows: Vec<(&str, &str, Acquisition, Kernel, f64)> = vec![
        ("EI (xi=0.1)", "GPR + Matern", Acquisition::Ei { xi: 0.1 }, Kernel::Matern52 { len: 0.25 }, 351.9),
        ("EI (xi=0.05)", "GPR + Matern", Acquisition::Ei { xi: 0.05 }, Kernel::Matern52 { len: 0.25 }, 358.9),
        ("EI (xi=0.2)", "GPR + Matern", Acquisition::Ei { xi: 0.2 }, Kernel::Matern52 { len: 0.25 }, 354.2),
        ("PI", "GPR + Matern", Acquisition::Pi { xi: 0.1 }, Kernel::Matern52 { len: 0.25 }, 355.1),
        ("LCB", "GPR + Matern", Acquisition::Lcb { kappa: 2.0 }, Kernel::Matern52 { len: 0.25 }, 355.4),
        ("EI (xi=0.1)", "GPR + RBF", Acquisition::Ei { xi: 0.1 }, Kernel::Rbf { len: 0.25 }, 357.2),
        ("EI (xi=0.1)", "GPR + RationalQuadratic", Acquisition::Ei { xi: 0.1 }, Kernel::RationalQuadratic { len: 0.25, alpha: 1.0 }, 360.2),
    ];
    let best_ms: Vec<f64> = par_map(&rows, |_, &(_, _, acq, kern, _)| {
        let obj = |sp: f64| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0;
        let mut bo = BoTuner::new(max, 5).with_acquisition(acq).with_kernel(kern);
        obj(bo.tune(10, obj)) * 1e3
    });

    let mut t = Table::new(
        "Table A.5 — BO hyperparameter sensitivity on BERT-Large-MoE [measured | paper]",
        &["acquisition", "surrogate", "time (ms)"],
    );
    for ((acq_name, kern_name, _, _, paper_ms), best) in rows.iter().zip(&best_ms) {
        t.row(vec![
            (*acq_name).into(),
            (*kern_name).into(),
            format!("{} | {}", fmt_ms(*best), fmt_ms(*paper_ms)),
        ]);
    }
    t.print();
    println!("\npaper shape: all hyperparameter choices converge within a few percent.");
}
