//! Paper Table 1: per-task time breakdown under vanilla expert
//! parallelism, Cluster 1 / 16 GPUs. Prints measured vs paper values.

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::cost::TaskCosts;
use flowmoe::report::{band_check, Table};
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;
use flowmoe::tasks::TaskKind;
use flowmoe::util::fmt_ms;

fn main() {
    // paper values: (mha+gating ms, all-reduce ms, iteration ms)
    let paper = [
        ("GPT2-Tiny-MoE", 23.5, 32.6, 169.5),
        ("BERT-Large-MoE", 61.9, 98.3, 537.8),
        ("LLaMA2-MoE", 308.4, 368.8, 1987.7),
        ("DeepSeek-V2-S", 870.2, 1247.8, 5843.3),
    ];
    let cl = ClusterProfile::cluster1(16);
    let mut t = Table::new(
        "Table 1 — vanillaEP task breakdown (Cluster 1, 16 GPUs) [measured | paper]",
        &["model", "MHA+gating (ms)", "all-reduce (ms)", "iteration (ms)", "ratio", "paper ratio", "verdict"],
    );
    for (name, p_mha, p_ar, p_iter) in paper {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &cl);
        let dag = build_dag(&cfg, &costs, &Policy::vanilla_ep());
        let tl = simulate(&dag);
        let mut mha = 0.0;
        let mut ar = 0.0;
        for task in &dag.tasks {
            let span = tl.span_of(task.id).unwrap();
            match task.kind {
                TaskKind::At { .. } => mha += span.end - span.start,
                TaskKind::Ar { .. } => ar += span.end - span.start,
                _ => {}
            }
        }
        let ratio = (mha + ar) / tl.makespan;
        let p_ratio = (p_mha + p_ar) / p_iter;
        t.row(vec![
            name.into(),
            format!("{} | {}", fmt_ms(mha * 1e3), fmt_ms(p_mha)),
            format!("{} | {}", fmt_ms(ar * 1e3), fmt_ms(p_ar)),
            format!("{} | {}", fmt_ms(tl.makespan * 1e3), fmt_ms(p_iter)),
            format!("{:.1}%", ratio * 100.0),
            format!("{:.1}%", p_ratio * 100.0),
            band_check(ratio, 0.18, 0.55).into(),
        ]);
    }
    t.print();
    println!("\npaper claim: MHA+gating + all-reduce constitute 30-40% of iteration time;");
    println!("reproduction target is the ratio band, not absolute milliseconds (calibrated cost models).");
}
