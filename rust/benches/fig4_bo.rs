//! Paper Fig. 4: BO tuning of S_p on BERT-Large-MoE (Cluster 1, 16 GPUs):
//! sampled points, GP posterior mean + 95% CI over the range, optimum.

use flowmoe::bo::BoTuner;
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::sched::{iteration_time, Policy};

fn main() {
    let cfg = preset("BERT-Large-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let obj = |sp: f64| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0;

    let max = 10.0e6; // paper Fig. 4 plots (0, 10] MB
    let mut bo = BoTuner::new(max, 42);
    bo.tune(8, obj);

    println!("\n## Fig. 4 — BO tuning S_p on BERT-Large-MoE (8 samples)\n");
    println!("samples:");
    for (sp, t) in &bo.observations {
        println!("  S_p = {:6.2} MB -> {:7.2} ms", sp / 1e6, t * 1e3);
    }
    let (best_sp, best_t) = bo.best().unwrap();
    println!("\nBO optimum: S_p = {:.2} MB ({:.2} ms)   [paper: ~2.5 MB]", best_sp / 1e6, best_t * 1e3);

    println!("\nGP posterior (mean ± 2sigma) and true objective:");
    println!("{:>8} {:>10} {:>10} {:>10}", "S_p(MB)", "mean(ms)", "±95%(ms)", "true(ms)");
    for i in 1..=20 {
        let sp = max * i as f64 / 20.0;
        let (mu, sigma) = bo.posterior(sp);
        println!(
            "{:8.2} {:10.2} {:10.2} {:10.2}",
            sp / 1e6,
            mu * 1e3,
            2.0 * sigma * 1e3,
            obj(sp) * 1e3
        );
    }
    // ASCII profile of the true objective (the Fig. 4 curve shape)
    let samples: Vec<f64> = (1..=40).map(|i| obj(max * i as f64 / 40.0) * 1e3).collect();
    let lo = samples.iter().copied().fold(f64::INFINITY, f64::min);
    println!("\ntrue objective profile (each # = 1ms above minimum {lo:.1}ms):");
    for (i, s) in samples.iter().enumerate() {
        let bars = ((s - lo) / 1.0).round() as usize;
        println!("  {:5.2}MB {}", max * (i + 1) as f64 / 40.0 / 1e6, "#".repeat(bars.min(60)));
    }
}
