//! Paper Table 5: component ablation on a customized MoE layer
//! (B=4, f=1.2, N=512, M=8192, H=8192), Cluster 1 / 16 GPUs.
//! The layer is stacked x4 — with a single isolated block, the strict
//! model leaves AR chunks nothing to overlap with (EXPERIMENTS.md
//! §Findings); the paper's single-layer 24.6 % Pipe-AR gain requires the
//! concurrent-comm behaviour, which FlowMoE-AR(CC) rows show.
//!
//! All 22 policy evaluations (fixed rows + the three BO-style S_p grids)
//! fan out over the sweep engine; rows then fold the grid minima.

use flowmoe::config::{ClusterProfile, ModelCfg};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::par_map;
use flowmoe::util::fmt_ms;

const SP_GRID: [f64; 6] = [0.5e6, 1e6, 2.5e6, 8e6, 32e6, 128e6];

fn main() {
    let mut cfg = ModelCfg::custom_layer(4, 1.2, 512, 8192, 8192, 16);
    cfg.l = 4;
    let cl = ClusterProfile::cluster1(16);

    let ar_cc = |sp: f64| {
        let mut p = Policy::flow_moe_cc(2, sp);
        p.pipe_at = false;
        p.name = "FlowMoE-AR-CC";
        p
    };
    // cases 0..4: fixed rows; 4..10 AR-CC grid; 10..16 strict grid; 16..22 CC grid
    let mut cases: Vec<Policy> = vec![
        Policy::vanilla_ep(),
        Policy::tutel(2),
        Policy::flow_moe_at(2),
        ar_cc(1e6),
    ];
    cases.extend(SP_GRID.iter().map(|&sp| ar_cc(sp)));
    cases.extend(SP_GRID.iter().map(|&sp| Policy::flow_moe(2, sp)));
    cases.extend(SP_GRID.iter().map(|&sp| Policy::flow_moe_cc(2, sp)));

    let times = par_map(&cases, |_, p| iteration_time(&cfg, &cl, p).0 * 1e3);
    let min_of = |r: std::ops::Range<usize>| times[r].iter().cloned().fold(f64::INFINITY, f64::min);

    let van = times[0];
    let rows: Vec<(&str, &str, &str, &str, f64, f64)> = vec![
        // name, pipe-moe, pipe-at, pipe-ar, time, paper speedup
        ("vanillaEP", "x", "x", "x", van, 1.0),
        ("Tutel", "y", "x", "x", times[1], 1.46),
        ("FlowMoE-AT", "y", "y", "x", times[2], 1.61),
        ("FlowMoE-AR (Sp=1MB)", "y", "x", "y", times[3], 1.68),
        ("FlowMoE-AR (BO)", "y", "x", "y", min_of(4..10), 1.82),
        ("FlowMoE (strict, BO)", "y", "y", "y", min_of(10..16), 2.05),
        ("FlowMoE (BO)", "y", "y", "y", min_of(16..22), 2.05),
    ];

    let mut t = Table::new(
        "Table 5 — ablation on customized layer (B4 f1.2 N512 M8192 H8192 x4 blocks)",
        &["config", "Pipe-MoE", "Pipe-AT", "Pipe-AR", "time (ms)", "speedup", "paper speedup"],
    );
    for (name, pm, pa, par, time, paper) in rows {
        t.row(vec![
            name.into(),
            pm.into(),
            pa.into(),
            par.into(),
            fmt_ms(time),
            format!("{:.2}x", van / time),
            format!("{paper:.2}x"),
        ]);
    }
    t.print();
    println!("\npaper shape: each component adds speedup; BO beats fixed S_p=1MB; full FlowMoE fastest.");
}
