//! Paper Table 5: component ablation on a customized MoE layer
//! (B=4, f=1.2, N=512, M=8192, H=8192), Cluster 1 / 16 GPUs.
//! The layer is stacked x4 — with a single isolated block, the strict
//! model leaves AR chunks nothing to overlap with (EXPERIMENTS.md
//! §Findings); the paper's single-layer 24.6 % Pipe-AR gain requires the
//! concurrent-comm behaviour, which FlowMoE-AR(CC) rows show.

use flowmoe::config::{ClusterProfile, ModelCfg};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::util::fmt_ms;

fn main() {
    let mut cfg = ModelCfg::custom_layer(4, 1.2, 512, 8192, 8192, 16);
    cfg.l = 4;
    let cl = ClusterProfile::cluster1(16);
    let ms = |p: &Policy| iteration_time(&cfg, &cl, p).0 * 1e3;
    let tuned = |mk: &dyn Fn(f64) -> Policy| {
        [0.5e6, 1e6, 2.5e6, 8e6, 32e6, 128e6]
            .iter()
            .map(|&sp| ms(&mk(sp)))
            .fold(f64::INFINITY, f64::min)
    };

    let van = ms(&Policy::vanilla_ep());
    // AR rows use the concurrent-channel mode (what the paper's NCCL
    // testbed actually measured — EXPERIMENTS.md §Findings); the strict
    // single-comm-stream variants are printed for comparison.
    let cc_1mb = {
        let mut p = Policy::flow_moe_cc(2, 1e6);
        p.pipe_at = false;
        p.name = "FlowMoE-AR-CC";
        ms(&p)
    };
    let cc_ar_bo = tuned(&|sp| {
        let mut p = Policy::flow_moe_cc(2, sp);
        p.pipe_at = false;
        p
    });
    let rows: Vec<(&str, &str, &str, &str, f64, f64)> = vec![
        // name, pipe-moe, pipe-at, pipe-ar, time, paper speedup
        ("vanillaEP", "x", "x", "x", van, 1.0),
        ("Tutel", "y", "x", "x", ms(&Policy::tutel(2)), 1.46),
        ("FlowMoE-AT", "y", "y", "x", ms(&Policy::flow_moe_at(2)), 1.61),
        ("FlowMoE-AR (Sp=1MB)", "y", "x", "y", cc_1mb, 1.68),
        ("FlowMoE-AR (BO)", "y", "x", "y", cc_ar_bo, 1.82),
        ("FlowMoE (strict, BO)", "y", "y", "y", tuned(&|sp| Policy::flow_moe(2, sp)), 2.05),
        ("FlowMoE (BO)", "y", "y", "y", tuned(&|sp| Policy::flow_moe_cc(2, sp)), 2.05),
    ];

    let mut t = Table::new(
        "Table 5 — ablation on customized layer (B4 f1.2 N512 M8192 H8192 x4 blocks)",
        &["config", "Pipe-MoE", "Pipe-AT", "Pipe-AR", "time (ms)", "speedup", "paper speedup"],
    );
    for (name, pm, pa, par, time, paper) in rows {
        t.row(vec![
            name.into(),
            pm.into(),
            pa.into(),
            par.into(),
            fmt_ms(time),
            format!("{:.2}x", van / time),
            format!("{paper:.2}x"),
        ]);
    }
    t.print();
    println!("\npaper shape: each component adds speedup; BO beats fixed S_p=1MB; full FlowMoE fastest.");
}
