//! Paper Table A.3: S_p tuning — BO vs grid search vs random number
//! generation, 4 models on Cluster 1 / 16 GPUs. Also prints the BO
//! overhead estimate of Table A.6.
//!
//! The four model rows are independent tuning runs, so they fan out
//! across cores on the sweep engine (input-ordered results keep the
//! printed table identical to the serial walk).

use flowmoe::bo::{grid_search, random_tuner, BoTuner};
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::par_map;
use flowmoe::util::fmt_ms;

fn main() {
    let paper = [
        ("GPT2-Tiny-MoE", 95.6, 101.3, 109.3, 3.22),
        ("BERT-Large-MoE", 351.9, 373.8, 388.96, 1.38),
        ("LLaMA2-MoE", 1124.0, 1208.23, 1250.09, 0.43),
        ("DeepSeek-V2-S", 3205.3, 3498.8, 3902.75, 0.16),
    ];
    let cl = ClusterProfile::cluster1(16);
    let rows = par_map(&paper, |_, &(name, _, _, _, _)| {
        let cfg = preset(name).unwrap();
        let obj = |sp: f64| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0;
        let max = cfg.ar_bytes_per_block();

        let mut bo = BoTuner::new(max, 7);
        let bo_best = obj(bo.tune(8, obj)) * 1e3;
        let grid_best = obj(grid_search(max, 8, obj)) * 1e3;
        let (_, rand_avg) = random_tuner(max, 8, 7, obj);
        let rand_avg = rand_avg * 1e3;

        // BO overhead (Table A.6): the 8x10 profiling iterations run at
        // sub-optimal S_p; extra time relative to 1000 tuned iterations.
        let profiled: f64 = bo.observations.iter().map(|(_, y)| y * 10.0).sum();
        let tuned_1000 = (bo_best / 1e3) * 1000.0;
        let overhead = (profiled - 80.0 * bo_best / 1e3).max(0.0) / tuned_1000 * 100.0;
        (bo_best, grid_best, rand_avg, overhead)
    });

    let mut t = Table::new(
        "Table A.3 — tuner comparison, per-iteration ms [measured | paper]",
        &["model", "BO", "grid search", "random", "BO overhead % (A.6 paper)"],
    );
    for ((name, p_bo, p_grid, p_rand, p_ovh), (bo_best, grid_best, rand_avg, overhead)) in
        paper.iter().zip(&rows)
    {
        t.row(vec![
            (*name).into(),
            format!("{} | {}", fmt_ms(*bo_best), fmt_ms(*p_bo)),
            format!("{} | {}", fmt_ms(*grid_best), fmt_ms(*p_grid)),
            format!("{} | {}", fmt_ms(*rand_avg), fmt_ms(*p_rand)),
            format!("{overhead:.2}% | {p_ovh:.2}%"),
        ]);
    }
    t.print();
    println!("\npaper shape: BO <= grid < random on every model; BO overhead is negligible.");
}
