//! Paper Table A.4: BO auto-tuning vs fixed partition sizes
//! S_p in {0.5, 1, 2, 4, 8} MB, 4 models on Cluster 1 / 16 GPUs.
//!
//! Each model's (BO run + 5 fixed-S_p evaluations) is one independent
//! case on the `flowmoe::sweep` engine — model rows evaluate in
//! parallel, printed in input order.

use flowmoe::bo::BoTuner;
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::Sweeper;
use flowmoe::util::fmt_ms;

const MODELS: [&str; 4] = ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE", "DeepSeek-V2-S"];
const FIXED_MB: [f64; 5] = [0.5, 1.0, 2.0, 4.0, 8.0];

fn main() {
    let cl = ClusterProfile::cluster1(16);
    let rows: Vec<(f64, Vec<f64>)> = Sweeper::new().run(&MODELS, |_, name| {
        let cfg = preset(name).unwrap();
        let obj = |sp: f64| iteration_time(&cfg, &cl, &Policy::flow_moe(2, sp)).0;
        let mut bo = BoTuner::new(cfg.ar_bytes_per_block(), 11);
        let tuned = obj(bo.tune(8, obj)) * 1e3;
        let fixed: Vec<f64> = FIXED_MB.iter().map(|&mb| obj(mb * 1e6) * 1e3).collect();
        (tuned, fixed)
    });

    let mut t = Table::new(
        "Table A.4 — BO vs fixed S_p, per-iteration ms (Cluster 1, 16 GPUs)",
        &["model", "BO", "0.5MB", "1MB", "2MB", "4MB", "8MB"],
    );
    for (name, (tuned, fixed)) in MODELS.iter().zip(&rows) {
        let mut row = vec![name.to_string(), fmt_ms(*tuned)];
        row.extend(fixed.iter().map(|&ms| fmt_ms(ms)));
        t.row(row);
    }
    t.print();
    println!("\npaper shape: no single fixed S_p is best everywhere; BO matches or beats all of them.");
}
