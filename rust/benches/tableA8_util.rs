//! Paper Tables A.8/A.9: GPU SM utilization (compute-stream occupancy
//! analogue) vs pipelining degree R and vs batch size. The per-model
//! rows of both tables run in parallel on the sweep engine.

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::cost::TaskCosts;
use flowmoe::metrics::sm_utilization;
use flowmoe::report::Table;
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;
use flowmoe::sweep::par_map;

fn main() {
    let cl = ClusterProfile::cluster1(16);
    let paper_a8 = [
        ("GPT2-Tiny-MoE", 72.63, 48.43, 87.09),
        ("BERT-Large-MoE", 87.84, 78.16, 88.90),
        ("LLaMA2-MoE", 89.16, 88.19, 89.49),
        ("DeepSeek-V2-S", 89.27, 88.85, 90.77),
    ];
    let rows = par_map(&paper_a8, |_, &(name, _, _, _)| {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &cl);
        let u = |pol: &Policy| sm_utilization(&simulate(&build_dag(&cfg, &costs, pol))) * 100.0;
        (u(&Policy::flow_moe(2, 2.5e6)), u(&Policy::flow_moe(4, 2.5e6)), u(&Policy::vanilla_ep()))
    });
    let mut t = Table::new(
        "Table A.8 — compute-stream occupancy vs R [measured | paper SM util]",
        &["model", "FlowMoE R=2", "FlowMoE R=4", "vanillaEP"],
    );
    for ((name, p2, p4, pv), (u2, u4, uv)) in paper_a8.iter().zip(&rows) {
        t.row(vec![
            (*name).into(),
            format!("{u2:.1}% | {p2:.1}%"),
            format!("{u4:.1}% | {p4:.1}%"),
            format!("{uv:.1}% | {pv:.1}%"),
        ]);
    }
    t.print();

    // Table A.9: occupancy vs batch size (B=4 vs B=2)
    let paper_a9 = [
        ("GPT2-Tiny-MoE", 72.63, 36.62),
        ("BERT-Large-MoE", 87.84, 61.48),
        ("LLaMA2-MoE", 89.16, 88.45),
        ("DeepSeek-V2-S", 89.27, 89.06),
    ];
    let rows9 = par_map(&paper_a9, |_, &(name, _, _)| {
        let cfg4 = preset(name).unwrap();
        let mut cfg2 = cfg4.clone();
        cfg2.b = 2;
        let u = |cfg: &flowmoe::config::ModelCfg| {
            let costs = TaskCosts::build(cfg, &cl);
            sm_utilization(&simulate(&build_dag(cfg, &costs, &Policy::flow_moe(2, 2.5e6)))) * 100.0
        };
        (u(&cfg4), u(&cfg2))
    });
    let mut t9 = Table::new(
        "Table A.9 — occupancy vs batch size (FlowMoE R=2) [measured | paper]",
        &["model", "B=4", "B=2"],
    );
    for ((name, p4, p2), (u4, u2)) in paper_a9.iter().zip(&rows9) {
        t9.row(vec![
            (*name).into(),
            format!("{u4:.1}% | {p4:.1}%"),
            format!("{u2:.1}% | {p2:.1}%"),
        ]);
    }
    t9.print();
    println!("\npaper shape: smaller microbatches / batches lower utilization, least for large models.");
}
