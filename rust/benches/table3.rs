//! Paper Table 3: per-iteration time, 6 frameworks x 4 models x {4,8,16}
//! GPUs on Cluster 1, with FlowMoE speedups vs each baseline.
//! Prints both the strict single-comm-stream FlowMoE (the paper's theory
//! model) and the concurrent-channel FlowMoE-CC (the measured-behaviour
//! model) — see EXPERIMENTS.md §Findings.
//!
//! Rows are computed on the `flowmoe::sweep` engine: each (model, GPUs)
//! cell is an independent batch of simulations, fanned out across cores
//! with input-ordered results so the printed table is deterministic.

use flowmoe::config::{preset, ClusterProfile, ModelCfg};
use flowmoe::report::{band_check, Table};
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::{tuned_min, Sweeper};
use flowmoe::util::fmt_ms;

/// Per-(model, cluster) timings of one Table 3 row, in ms.
struct Row {
    van: f64,
    fast: f64,
    tut: f64,
    fsm: f64,
    sche: f64,
    flow: f64,
    cc: f64,
}

fn row(cfg: &ModelCfg, cl: &ClusterProfile) -> Row {
    let sp = 2.5e6;
    let ms = |p: &Policy| iteration_time(cfg, cl, p).0 * 1e3;
    Row {
        van: ms(&Policy::vanilla_ep()),
        fast: ms(&Policy::faster_moe(2)),
        tut: ms(&Policy::tutel(2)),
        fsm: ms(&Policy::fs_moe(2)),
        sche: ms(&Policy::sche_moe(2)),
        flow: ms(&Policy::flow_moe(2, sp)),
        cc: tuned_cc(cfg, cl),
    }
}

fn main() {
    // paper speedup bands S5..S1 @16 GPUs per model: (vanilla, ScheMoE)
    let paper_s = [
        ("GPT2-Tiny-MoE", 1.77, 1.22),
        ("BERT-Large-MoE", 1.53, 1.15),
        ("LLaMA2-MoE", 1.76, 1.22),
        ("DeepSeek-V2-S", 1.82, 1.28),
    ];
    let sweeper = Sweeper::new();
    // all 12 (gpus x model) cells as one parallel batch, row-major
    let cells: Vec<(usize, &str)> = [4usize, 8, 16]
        .iter()
        .flat_map(|&g| paper_s.iter().map(move |&(name, _, _)| (g, name)))
        .collect();
    let rows = sweeper.run(&cells, |_, &(gpus, name)| {
        let base = preset(name).unwrap();
        let cfg = base.with_experts_for_workers((base.e / 16).max(1), gpus);
        row(&cfg, &ClusterProfile::cluster1(gpus))
    });

    for (gi, gpus) in [4usize, 8, 16].iter().enumerate() {
        let mut t = Table::new(
            &format!("Table 3 — per-iteration time (ms), Cluster 1, {gpus} GPUs, R=2"),
            &["model", "vanillaEP", "FasterMoE", "Tutel", "FSMoE", "ScheMoE", "FlowMoE", "FlowMoE-CC", "S5(vanilla)", "S1(ScheMoE)"],
        );
        for (mi, (name, _, _)) in paper_s.iter().enumerate() {
            let r = &rows[gi * paper_s.len() + mi];
            t.row(vec![
                (*name).into(),
                fmt_ms(r.van),
                fmt_ms(r.fast),
                fmt_ms(r.tut),
                fmt_ms(r.fsm),
                fmt_ms(r.sche),
                fmt_ms(r.flow),
                fmt_ms(r.cc),
                format!("{:.2}x", r.van / r.cc),
                format!("{:.2}x", r.sche / r.cc),
            ]);
        }
        t.print();
    }

    // paper-vs-measured verdicts at the headline 16-GPU setting (reuse
    // the 16-GPU batch rows: last group of the cells vector)
    let mut v = Table::new(
        "Table 3 verdicts @16 GPUs (FlowMoE-CC, BO-tuned S_p)",
        &["model", "S5 measured", "S5 paper", "S1 measured", "S1 paper", "verdict(S5 in 1.2-2.0)"],
    );
    for (mi, (name, p_s5, p_s1)) in paper_s.iter().enumerate() {
        let r = &rows[2 * paper_s.len() + mi];
        let s5 = r.van / r.cc;
        let s1 = r.sche / r.cc;
        v.row(vec![
            (*name).into(),
            format!("{s5:.2}x"),
            format!("{p_s5:.2}x"),
            format!("{s1:.2}x"),
            format!("{p_s1:.2}x"),
            band_check(s5, 1.2, 2.0).into(),
        ]);
    }
    v.print();
}

/// FlowMoE-CC at the best S_p over a BO-like coarse grid, in ms.
fn tuned_cc(cfg: &ModelCfg, cl: &ClusterProfile) -> f64 {
    tuned_min(cfg, cl, &[1e6, 2.5e6, 8e6, 32e6, 128e6], |sp| {
        Policy::flow_moe_cc(2, sp)
    }) * 1e3
}
