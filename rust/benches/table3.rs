//! Paper Table 3: per-iteration time, 6 frameworks x 4 models x {4,8,16}
//! GPUs on Cluster 1, with FlowMoE speedups vs each baseline.
//! Prints both the strict single-comm-stream FlowMoE (the paper's theory
//! model) and the concurrent-channel FlowMoE-CC (the measured-behaviour
//! model) — see EXPERIMENTS.md §Findings.

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::{band_check, Table};
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::util::fmt_ms;

fn main() {
    // paper speedup bands S5..S1 @16 GPUs per model: (vanilla, ScheMoE)
    let paper_s = [
        ("GPT2-Tiny-MoE", 1.77, 1.22),
        ("BERT-Large-MoE", 1.53, 1.15),
        ("LLaMA2-MoE", 1.76, 1.22),
        ("DeepSeek-V2-S", 1.82, 1.28),
    ];
    for gpus in [4usize, 8, 16] {
        let cl = ClusterProfile::cluster1(gpus);
        let mut t = Table::new(
            &format!("Table 3 — per-iteration time (ms), Cluster 1, {gpus} GPUs, R=2"),
            &["model", "vanillaEP", "FasterMoE", "Tutel", "FSMoE", "ScheMoE", "FlowMoE", "FlowMoE-CC", "S5(vanilla)", "S1(ScheMoE)"],
        );
        for (name, _, _) in paper_s {
            let base = preset(name).unwrap();
            let cfg = base.with_experts_for_workers((base.e / 16).max(1), gpus);
            let sp = 2.5e6;
            let ms = |p: &Policy| iteration_time(&cfg, &cl, p).0 * 1e3;
            let van = ms(&Policy::vanilla_ep());
            let fast = ms(&Policy::faster_moe(2));
            let tut = ms(&Policy::tutel(2));
            let fsm = ms(&Policy::fs_moe(2));
            let sche = ms(&Policy::sche_moe(2));
            let flow = ms(&Policy::flow_moe(2, sp));
            let cc = tuned_cc(&cfg, &cl);
            t.row(vec![
                name.into(),
                fmt_ms(van),
                fmt_ms(fast),
                fmt_ms(tut),
                fmt_ms(fsm),
                fmt_ms(sche),
                fmt_ms(flow),
                fmt_ms(cc),
                format!("{:.2}x", van / cc),
                format!("{:.2}x", sche / cc),
            ]);
        }
        t.print();
    }
    // paper-vs-measured verdicts at the headline 16-GPU setting
    let cl = ClusterProfile::cluster1(16);
    let mut v = Table::new(
        "Table 3 verdicts @16 GPUs (FlowMoE-CC, BO-tuned S_p)",
        &["model", "S5 measured", "S5 paper", "S1 measured", "S1 paper", "verdict(S5 in 1.2-2.0)"],
    );
    for (name, p_s5, p_s1) in paper_s {
        let cfg = preset(name).unwrap();
        let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0 * 1e3;
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0 * 1e3;
        let cc = tuned_cc(&cfg, &cl);
        let s5 = van / cc;
        let s1 = sche / cc;
        v.row(vec![
            name.into(),
            format!("{s5:.2}x"),
            format!("{p_s5:.2}x"),
            format!("{s1:.2}x"),
            format!("{p_s1:.2}x"),
            band_check(s5, 1.2, 2.0).into(),
        ]);
    }
    v.print();
}

/// FlowMoE-CC at the best S_p over a BO-like coarse grid, in ms.
fn tuned_cc(cfg: &flowmoe::config::ModelCfg, cl: &ClusterProfile) -> f64 {
    [1e6, 2.5e6, 8e6, 32e6, 128e6]
        .iter()
        .map(|&sp| iteration_time(cfg, cl, &Policy::flow_moe_cc(2, sp)).0 * 1e3)
        .fold(f64::INFINITY, f64::min)
}
