//! Paper Table 4: pipelining degree R in {2,4,8} on DeepSeek-V2-S,
//! Cluster 1 / 16 GPUs — Tutel vs ScheMoE vs FlowMoE. The three R rows
//! run in parallel on the sweep engine (each row is ~6 simulations).

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::par_map;
use flowmoe::util::fmt_ms;

fn main() {
    let paper = [(2usize, 4481.4, 4093.7, 3205.3), (4, 4628.2, 4164.0, 3113.8), (8, 4588.9, 4308.7, 3295.9)];
    let cfg = preset("DeepSeek-V2-S").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let rows = par_map(&paper, |_, &(r, _, _, _)| {
        let tut = iteration_time(&cfg, &cl, &Policy::tutel(r)).0 * 1e3;
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(r)).0 * 1e3;
        let flow = [2.5e6, 8e6, 32e6, 128e6]
            .iter()
            .map(|&sp| iteration_time(&cfg, &cl, &Policy::flow_moe_cc(r, sp)).0 * 1e3)
            .fold(f64::INFINITY, f64::min);
        (tut, sche, flow)
    });
    let mut t = Table::new(
        "Table 4 — R-degree on DeepSeek-V2-S (Cluster 1, 16 GPUs) [measured | paper]",
        &["R", "Tutel (ms)", "ScheMoE (ms)", "FlowMoE-CC (ms)", "S1 (Tutel)", "S2 (ScheMoE)"],
    );
    for ((r, p_tut, p_sche, p_flow), (tut, sche, flow)) in paper.iter().zip(&rows) {
        t.row(vec![
            r.to_string(),
            format!("{} | {}", fmt_ms(*tut), fmt_ms(*p_tut)),
            format!("{} | {}", fmt_ms(*sche), fmt_ms(*p_sche)),
            format!("{} | {}", fmt_ms(*flow), fmt_ms(*p_flow)),
            format!("{:.2}x", tut / flow),
            format!("{:.2}x", sche / flow),
        ]);
    }
    t.print();
    println!("\npaper shape: FlowMoE wins at every R; gains flatten beyond R=4 (startup overhead).");

    // Extension: automatic R selection (the paper defers to PipeMoE [21]
    // for picking R; sched::autor implements that selection).
    let (r, t_auto, evals) =
        flowmoe::sched::autor::select_r(&cfg, &cl, |r| Policy::flow_moe_cc(r, 2.5e6));
    println!("\nauto-R (sched::autor): picked R={r} ({:.1} ms); candidates:", t_auto * 1e3);
    for (rc, tc) in evals {
        println!("  R={rc}: {:.1} ms", tc * 1e3);
    }
}
