//! Paper Tables A.10/A.11: expert-load imbalance — BERT-Large-MoE-w
//! (8 experts per GPU) with increasingly skewed routing (capacity factor
//! f up); max/min per-worker utilization spread.

use flowmoe::data::skewed_expert_tokens;
use flowmoe::metrics::load_imbalance_utilization;
use flowmoe::report::Table;

fn main() {
    // skew exponent grows with f (the paper: larger f => more tokens
    // concentrated on popular experts => fewer activated experts)
    let rows = [
        (1.0, 0.0, 89.20, 87.81),
        (4.0, 0.9, 89.72, 50.65),
        (8.0, 1.4, 90.30, 31.60),
        (16.0, 2.0, 90.68, 19.41),
    ];
    let n_experts = 8 * 16; // BERT-Large-MoE-w: 8 experts/GPU x 16 GPUs
    let mut t = Table::new(
        "Table A.11 — load imbalance on BERT-Large-MoE-w (16 GPUs, 8 experts/GPU) [measured | paper]",
        &["f", "max util", "min util", "spread"],
    );
    for (f, skew, p_max, p_min) in rows {
        let tokens = skewed_expert_tokens(n_experts, 32768.0, skew);
        let (maxu, minu) = load_imbalance_utilization(&tokens, 8, 0.888);
        t.row(vec![
            format!("{f:.1}"),
            format!("{:.1}% | {p_max:.1}%", maxu * 100.0),
            format!("{:.1}% | {p_min:.1}%", minu * 100.0),
            format!("{:.1}pp", (maxu - minu) * 100.0),
        ]);
    }
    t.print();
    println!("\npaper shape: higher f (more skew) widens the max-min utilization gap.");
}
