//! Paper Table A.7: stress tests on scaled-up models (LLaMA2-MoE-L,
//! DeepSeek-V2-M) at 4/8/16 GPUs, including the OOM detection at 16.
//! The six (GPUs, model) rows run in parallel on the sweep engine.

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::cost::peak_memory_bytes;
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::par_map;
use flowmoe::util::fmt_ms;

fn main() {
    let mut t = Table::new(
        "Table A.7 — stress tests (Cluster 1) [measured | paper]",
        &["GPUs", "model", "vanillaEP", "Tutel", "ScheMoE", "FlowMoE", "S3 (vanilla)"],
    );
    let paper: &[(usize, &str, Option<(f64, f64, f64, f64)>)] = &[
        (4, "LLaMA2-MoE-L", Some((2405.1, 1927.0, 1806.1, 1493.8))),
        (4, "DeepSeek-V2-M", Some((535.3, 468.4, 432.2, 352.2))),
        (8, "LLaMA2-MoE-L", Some((2989.1, 2493.9, 2297.9, 1833.8))),
        (8, "DeepSeek-V2-M", Some((944.6, 773.4, 723.6, 552.4))),
        (16, "LLaMA2-MoE-L", None), // paper: OOM
        (16, "DeepSeek-V2-M", Some((1254.6, 956.9, 893.4, 708.8))),
    ];
    let rows: Vec<Vec<String>> = par_map(paper, |_, &(gpus, name, paper_row)| {
        let base = preset(name).unwrap();
        let cfg = base.with_experts_for_workers((base.e / 16).max(1), gpus);
        let cl = ClusterProfile::cluster1(gpus);
        let mem = peak_memory_bytes(&cfg, gpus, cfg.l as f64, 1.0);
        if mem > cl.mem_bytes {
            return vec![
                gpus.to_string(),
                name.into(),
                format!("OOM ({:.1}GB > {:.1}GB) | {}", mem / 1e9, cl.mem_bytes / 1e9,
                        if paper_row.is_none() { "OOM" } else { "ran" }),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ];
        }
        let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0 * 1e3;
        let tut = iteration_time(&cfg, &cl, &Policy::tutel(2)).0 * 1e3;
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0 * 1e3;
        let flow = [2.5e6, 8e6, 32e6, 128e6]
            .iter()
            .map(|&sp| iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, sp)).0 * 1e3)
            .fold(f64::INFINITY, f64::min);
        let p = paper_row.unwrap_or((0.0, 0.0, 0.0, 0.0));
        vec![
            gpus.to_string(),
            name.into(),
            format!("{} | {}", fmt_ms(van), fmt_ms(p.0)),
            format!("{} | {}", fmt_ms(tut), fmt_ms(p.1)),
            format!("{} | {}", fmt_ms(sche), fmt_ms(p.2)),
            format!("{} | {}", fmt_ms(flow), fmt_ms(p.3)),
            format!("{:.2}x", van / flow),
        ]
    });
    for row in rows {
        t.row(row);
    }
    t.print();
    println!("\npaper shape: FlowMoE best on every non-OOM row; LLaMA2-MoE-L OOMs at 16 GPUs.");
    println!("note: paper DeepSeek-V2-M rows are internally inconsistent with its Table 1 AR");
    println!("bandwidth (2.9GB replicated grads cannot all-reduce inside 1254ms at 1.35GB/s);");
    println!("we reproduce the Table-1-consistent behaviour (EXPERIMENTS.md §Findings).");
}
