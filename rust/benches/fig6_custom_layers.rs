//! Paper Fig. 6: speedup statistic of FlowMoE over ScheMoE across the
//! customized-MoE-layer grid (B x f x N x M x H), OOM cases excluded.
//! Cluster 1 / 16 GPUs (490 valid in the paper) and Cluster 2 / 8 GPUs
//! (393 valid). Pass --limit N to subsample for speed, --threads T to
//! cap the sweep engine's worker count.
//!
//! The grid runs on `flowmoe::sweep` — every (layer x policy x S_p) case
//! is an independent simulation, evaluated across all cores with
//! deterministic, grid-ordered results.

use flowmoe::cli::Args;
use flowmoe::config::ClusterProfile;
use flowmoe::report::histogram;
use flowmoe::sweep::{fig6_sweep, Sweeper};

fn main() {
    let args = Args::from_env();
    let limit = args.usize_or("limit", usize::MAX);
    let mut sweeper = Sweeper::new();
    if let Some(t) = args.get("threads").and_then(|t| t.parse().ok()) {
        sweeper = sweeper.with_threads(t);
    }
    eprintln!("sweep engine: {} worker threads", sweeper.threads());

    for (cl, gpus, paper_valid) in [
        (ClusterProfile::cluster1(16), 16usize, 490usize),
        (ClusterProfile::cluster2(8), 8, 393),
    ] {
        let stats = fig6_sweep(&sweeper, &cl, gpus, limit);
        println!(
            "{}",
            histogram(
                &format!(
                    "Fig. 6 — FlowMoE speedup over ScheMoE, {} x{} GPUs: {} valid ({} OOM; paper: {} valid), win rate {:.0}%",
                    cl.name,
                    gpus,
                    stats.speedups.len(),
                    stats.oom,
                    paper_valid,
                    100.0 * stats.wins as f64 / stats.speedups.len().max(1) as f64
                ),
                &stats.speedups,
                12,
                40
            )
        );
        println!(
            "mean speedup {:.3} (paper: 1.26 on average; paper claims all-win — see EXPERIMENTS.md §Findings)",
            flowmoe::util::mean(&stats.speedups)
        );
    }
}
