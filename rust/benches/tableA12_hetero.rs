//! Paper Table A.12: heterogeneous 16-GPU cluster (half the workers at
//! half compute speed) — FlowMoE still wins; the slowest GPU dictates
//! collective timing (Appendix K.1). Model rows run in parallel on the
//! sweep engine.

use flowmoe::config::{preset, ClusterProfile};
use flowmoe::report::Table;
use flowmoe::sched::{iteration_time, Policy};
use flowmoe::sweep::par_map;
use flowmoe::util::fmt_ms;

fn main() {
    let paper = [
        ("GPT2-Tiny-MoE", 235.8, 178.2, 153.3),
        ("BERT-Large-MoE", 657.7, 500.6, 449.2),
        ("LLaMA2-MoE", 2439.1, 1707.4, 1468.3),
        ("DeepSeek-V2-S", 7233.7, 4958.3, 4142.4),
    ];
    let cl = ClusterProfile::cluster1_heterogeneous(16);
    let uni = ClusterProfile::cluster1(16);
    let rows = par_map(&paper, |_, &(name, _, _, _)| {
        let cfg = preset(name).unwrap();
        let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0 * 1e3;
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0 * 1e3;
        let flow = [2.5e6, 8e6, 32e6]
            .iter()
            .map(|&sp| iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, sp)).0 * 1e3)
            .fold(f64::INFINITY, f64::min);
        let flow_uni = iteration_time(&cfg, &uni, &Policy::flow_moe_cc(2, 2.5e6)).0 * 1e3;
        (van, sche, flow, flow_uni)
    });
    let mut t = Table::new(
        "Table A.12 — heterogeneous cluster (8 of 16 GPUs at half speed) [measured | paper]",
        &["model", "vanillaEP", "ScheMoE", "FlowMoE", "S1 (vanilla)", "hetero/homog slowdown"],
    );
    for ((name, p_van, p_sche, p_flow), (van, sche, flow, flow_uni)) in paper.iter().zip(&rows) {
        t.row(vec![
            (*name).into(),
            format!("{} | {}", fmt_ms(*van), fmt_ms(*p_van)),
            format!("{} | {}", fmt_ms(*sche), fmt_ms(*p_sche)),
            format!("{} | {}", fmt_ms(*flow), fmt_ms(*p_flow)),
            format!("{:.2}x", van / flow),
            format!("{:.2}x", flow / flow_uni),
        ]);
    }
    t.print();
    println!("\npaper shape: the slowest GPU sets the timeline; FlowMoE's relative win persists.");
}
