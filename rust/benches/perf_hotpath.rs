//! Hot-path microbenchmarks (§Perf): DAG build + simulation throughput
//! (the coordinator's scheduling cost), the multi-core sweep engine vs
//! the old serial loop, the native backend's kernel dispatch tiers
//! (naive vs blocked vs simd, serial vs M-banded parallel; within a
//! tier results must be byte-identical), and the comm-pool / collective
//! primitives. Paper bound: scheduling overhead < 1 % of iteration time.
//!
//! Kernel rows are also written to `BENCH_native_kernels.json`
//! (op, shape, naive_ms, serial_ms, parallel_ms, speedup, simd_ms) so
//! future PRs have a machine-readable perf trajectory to compare
//! against: `naive_ms/serial_ms` is the blocking win, `speedup` the
//! threading win, `serial_ms/simd_ms` the f32x8 win on this host. When
//! AVX2+FMA is detected the matmul simd-vs-blocked ratio is asserted
//! >= 1.5x (skipped, not failed, on hosts without AVX2).

use std::sync::Arc;

use flowmoe::backend::kernels as kn;
use flowmoe::commpool::{partition_ranges, Collective, CommPool};
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::cost::TaskCosts;
use flowmoe::report::{bench_median, Table};
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;
use flowmoe::sweep::{flow_vs_sche, scope, valid_custom_layers, Sweeper};
use flowmoe::util::Rng;

/// Byte-equality of two f32 buffers.
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Time one native kernel per dispatch tier: blocked serial (budget 1)
/// vs blocked parallel (default budget) vs simd serial, asserting that
/// within each tier repeated and parallel runs are byte-identical to the
/// serial run. Appends table rows and one JSON results row; returns
/// `(parallel speedup, simd-vs-blocked serial speedup)`.
fn bench_kernel(
    op: &str,
    shape: &str,
    f: &dyn Fn() -> Vec<f32>,
    naive: Option<&dyn Fn() -> Vec<f32>>,
    t: &mut Table,
    json_rows: &mut Vec<String>,
) -> (f64, f64) {
    use flowmoe::backend::kernels::Dispatch;
    let run = |d: Dispatch| kn::with_dispatch(d, f);
    // correctness: parallel == serial bitwise, within each tier
    let blocked_serial = scope::with_budget(1, || run(Dispatch::Blocked));
    let blocked_par = run(Dispatch::Blocked);
    let blocked_par2 = run(Dispatch::Blocked);
    assert!(bits_eq(&blocked_par, &blocked_par2), "{op} {shape}: repeated parallel runs differ");
    assert!(bits_eq(&blocked_serial, &blocked_par), "{op} {shape}: blocked parallel differs from serial");
    let simd_serial = scope::with_budget(1, || run(Dispatch::Simd));
    let simd_par = run(Dispatch::Simd);
    assert!(bits_eq(&simd_serial, &simd_par), "{op} {shape}: simd parallel differs from serial");
    // timing per tier
    let time = |d: Dispatch| {
        bench_median(1, 3, || {
            std::hint::black_box(kn::with_dispatch(d, f).len());
        })
    };
    let s_serial = scope::with_budget(1, || time(Dispatch::Blocked));
    let s_par = time(Dispatch::Blocked);
    let s_simd = scope::with_budget(1, || time(Dispatch::Simd));
    let speedup = s_serial / s_par;
    let simd_ratio = s_serial / s_simd;
    let mut json = format!("{{\"op\":\"{op}\",\"shape\":\"{shape}\"");
    if let Some(nf) = naive {
        let s_naive = bench_median(1, 3, || {
            std::hint::black_box(nf().len());
        });
        t.row(vec![
            format!("kernel {op} {shape}, blocked serial"),
            format!("{:.1} ms", s_serial * 1e3),
            format!("{:.2}x vs naive ({:.1} ms)", s_naive / s_serial, s_naive * 1e3),
        ]);
        json.push_str(&format!(",\"naive_ms\":{:.3}", s_naive * 1e3));
    } else {
        t.row(vec![
            format!("kernel {op} {shape}, blocked serial"),
            format!("{:.1} ms", s_serial * 1e3),
            "-".into(),
        ]);
    }
    t.row(vec![
        format!("kernel {op} {shape}, parallel ({} threads)", scope::current_budget()),
        format!("{:.1} ms", s_par * 1e3),
        format!("{speedup:.2}x vs serial, byte-identical"),
    ]);
    let simd_kind = if kn::avx2_available() { "avx2+fma" } else { "portable lanes" };
    t.row(vec![
        format!("kernel {op} {shape}, simd serial ({simd_kind})"),
        format!("{:.1} ms", s_simd * 1e3),
        format!("{simd_ratio:.2}x vs blocked serial"),
    ]);
    json.push_str(&format!(
        ",\"serial_ms\":{:.3},\"parallel_ms\":{:.3},\"speedup\":{:.3},\"simd_ms\":{:.3}}}",
        s_serial * 1e3,
        s_par * 1e3,
        speedup,
        s_simd * 1e3
    ));
    json_rows.push(json);
    (speedup, simd_ratio)
}

fn main() {
    let cl = ClusterProfile::cluster1(16);
    let mut t = Table::new(
        "Perf — coordinator hot paths",
        &["case", "median", "derived"],
    );

    // 1) DAG build + simulate for the biggest model at R=8, tiny chunks
    let cfg = preset("LLaMA2-MoE-L").unwrap();
    let costs = TaskCosts::build(&cfg, &cl);
    let pol = Policy::flow_moe(8, 0.25e6);
    let dag = build_dag(&cfg, &costs, &pol);
    let n_tasks = dag.len();
    let s = bench_median(3, 10, || {
        let d = build_dag(&cfg, &costs, &pol);
        std::hint::black_box(simulate(&d).makespan);
    });
    t.row(vec![
        format!("build+simulate LLaMA2-MoE-L R=8 ({n_tasks} tasks)"),
        format!("{:.3} ms", s * 1e3),
        format!("{:.1}k tasks/s", n_tasks as f64 / s / 1e3),
    ]);

    // simulated iteration is ~1.5s; scheduling cost must be <1% of that
    let iter_s = simulate(&dag).makespan;
    t.row(vec![
        "scheduling overhead vs simulated iteration".into(),
        format!("{:.3}%", s / iter_s * 100.0),
        "paper bound: <1%".into(),
    ]);

    // 2) 675-layer sweep (drives fig6): serial loop vs the multi-core
    // sweep engine, on a fixed slice of the valid grid. Results must be
    // byte-identical; throughput target: >= 3x on >= 4 cores.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (cases, _) = valid_custom_layers(&cl, 16, 128);
    let serial_sweeper = Sweeper::new().with_threads(1);
    let par_sweeper = Sweeper::new();
    let run_sweep = |sw: &Sweeper| sw.run(&cases, |_, c| flow_vs_sche(c, &cl));
    let serial_out = run_sweep(&serial_sweeper);
    let par_out = run_sweep(&par_sweeper);
    let identical = serial_out.len() == par_out.len()
        && serial_out.iter().zip(&par_out).all(|(a, b)| {
            a.0.to_bits() == b.0.to_bits() && a.1.to_bits() == b.1.to_bits()
        });
    assert!(identical, "parallel sweep results diverge from serial");
    let s_serial = bench_median(1, 3, || {
        std::hint::black_box(run_sweep(&serial_sweeper).len());
    });
    let s_par = bench_median(1, 3, || {
        std::hint::black_box(run_sweep(&par_sweeper).len());
    });
    let speedup = s_serial / s_par;
    t.row(vec![
        format!("sweep {} layer cases x 5 sims, serial", cases.len()),
        format!("{:.1} ms", s_serial * 1e3),
        format!("{:.1} cases/s", cases.len() as f64 / s_serial),
    ]);
    t.row(vec![
        format!("sweep {} layer cases x 5 sims, {} threads", cases.len(), par_sweeper.threads()),
        format!("{:.1} ms", s_par * 1e3),
        format!(
            "{speedup:.2}x vs serial on {cores} cores (target >= 3x on >= 4), byte-identical: {identical}"
        ),
    ]);
    if cores >= 4 {
        assert!(
            speedup >= 3.0,
            "sweep engine speedup {speedup:.2}x < 3x on {cores} cores"
        );
    }

    // 3) native backend kernels: blocked serial vs M-banded parallel,
    // plus the expert-parallel FFN. e2e-flavoured shapes, scaled so the
    // whole section stays in bench time; emits BENCH_native_kernels.json.
    let mut rng = Rng::new(77);
    let mut randv = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.normal() as f32 * 0.5).collect() };
    let mut json_rows: Vec<String> = Vec::new();
    let (m, k, n) = (256usize, 256usize, 384usize);
    let a = randv(m * k);
    let b = randv(k * n);
    let bt = randv(n * k);
    let at = randv(k * m);
    let (mm_speedup, mm_simd) = bench_kernel(
        "matmul",
        &format!("{m}x{k}x{n}"),
        &|| kn::matmul(&a, &b, m, k, n),
        Some(&|| kn::matmul_ref(&a, &b, m, k, n)),
        &mut t,
        &mut json_rows,
    );
    bench_kernel(
        "matmul_nt",
        &format!("{m}x{k}x{n}"),
        &|| kn::matmul_nt(&a, &bt, m, k, n),
        Some(&|| kn::matmul_nt_ref(&a, &bt, m, k, n)),
        &mut t,
        &mut json_rows,
    );
    bench_kernel(
        "matmul_tn",
        &format!("{k}x{m}x{n}"),
        &|| kn::matmul_tn(&at, &b, k, m, n),
        Some(&|| kn::matmul_tn_ref(&at, &b, k, m, n)),
        &mut t,
        &mut json_rows,
    );
    let (fe, fc, fm, fh) = (4usize, 64usize, 256usize, 512usize);
    let fx = randv(fe * fc * fm);
    let fw1 = randv(fe * fm * fh);
    let fw2 = randv(fe * fh * fm);
    bench_kernel(
        "expert_ffn",
        &format!("e{fe}xc{fc}xm{fm}xh{fh}"),
        &|| kn::expert_ffn(&fx, &fw1, &fw2, fe, fc, fm, fh),
        None,
        &mut t,
        &mut json_rows,
    );
    if cores >= 4 {
        assert!(
            mm_speedup >= 3.0,
            "parallel blocked matmul speedup {mm_speedup:.2}x < 3x on {cores} cores"
        );
    }
    // the simd acceptance gate: only asserted where the AVX2+FMA path
    // actually runs; the portable fallback makes no speed promise
    if kn::avx2_available() {
        assert!(
            mm_simd >= 1.5,
            "simd matmul speedup {mm_simd:.2}x < 1.5x vs blocked with AVX2+FMA detected"
        );
    } else {
        t.row(vec![
            "simd >= 1.5x matmul assert".into(),
            "skipped".into(),
            "AVX2+FMA not detected (portable lanes fallback)".into(),
        ]);
    }
    // 3b) observability overhead: with tracing disabled (the default
    // here), an instrumented call site pays one relaxed atomic load.
    // Measure it directly over 1M calls and assert the implied overhead
    // on the cheapest timed kernel stays under the 2% acceptance bound —
    // a direct measurement is deterministic where a traced-vs-untraced
    // wall-clock diff of the same kernels would be noise.
    assert!(!flowmoe::obs::enabled(), "bench must run with tracing disabled");
    const SPAN_PROBES: usize = 1_000_000;
    let span_s = bench_median(1, 3, || {
        for _ in 0..SPAN_PROBES {
            let _sp = flowmoe::obs::span("bench_probe");
        }
        std::hint::black_box(());
    });
    let span_ns = span_s / SPAN_PROBES as f64 * 1e9;
    // worst case: the span cost lands on the fastest kernel we time
    let fastest_kernel_s = json_rows
        .iter()
        .filter_map(|r| {
            r.split("\"simd_ms\":")
                .nth(1)
                .and_then(|s| s.trim_end_matches('}').parse::<f64>().ok())
        })
        .fold(f64::INFINITY, f64::min)
        * 1e-3;
    let overhead_pct = span_s / SPAN_PROBES as f64 / fastest_kernel_s * 100.0;
    t.row(vec![
        "obs::span disabled-path cost".into(),
        format!("{span_ns:.1} ns/call"),
        format!("{overhead_pct:.4}% of fastest timed kernel (bound: < 2%)"),
    ]);
    assert!(
        overhead_pct < 2.0,
        "disabled span overhead {overhead_pct:.3}% >= 2% of the fastest timed kernel ({span_ns:.1} ns/call)"
    );

    // 3c) metrics registry: feed the per-rep matmul times into a global
    // histogram so the JSON stats block carries p50/p95/p99 of a real
    // kernel distribution (and the quantile path gets exercised).
    let reg = flowmoe::obs::global();
    let mm_hist = reg.histogram("bench_matmul_s");
    for _ in 0..9 {
        let t0 = std::time::Instant::now();
        std::hint::black_box(kn::matmul(&a, &b, m, k, n).len());
        mm_hist.observe(t0.elapsed().as_secs_f64());
    }
    let snap = reg.snapshot();
    let hs = &snap.hists[0];
    let stats_json = format!(
        "\"stats\":{{\"span_disabled_ns\":{span_ns:.2},\"span_overhead_pct\":{overhead_pct:.4},\
         \"matmul_reps\":{},\"matmul_p50_ms\":{:.3},\"matmul_p95_ms\":{:.3},\"matmul_p99_ms\":{:.3}}}",
        hs.count,
        hs.p50_s * 1e3,
        hs.p95_s * 1e3,
        hs.p99_s * 1e3
    );

    let json = format!(
        "{{\"bench\":\"native_kernels\",\"host_cores\":{cores},\"thread_budget\":{},\"avx2\":{},\"dispatch\":\"{}\",{stats_json},\"results\":[{}]}}\n",
        scope::current_budget(),
        kn::avx2_available(),
        kn::default_dispatch().name(),
        json_rows.join(",")
    );
    // the bench writes hand-rolled JSON: scan it like the traces
    flowmoe::testutil::scan_json(&json).expect("BENCH_native_kernels.json is malformed");
    let json_path = "BENCH_native_kernels.json";
    std::fs::write(json_path, &json).expect("write BENCH_native_kernels.json");
    t.row(vec![
        "kernel rows written to".into(),
        json_path.into(),
        "machine-readable perf trajectory".into(),
    ]);

    // 4) partitioner
    let s3 = bench_median(3, 50, || {
        std::hint::black_box(partition_ranges(100_000_000 / 4, 1 << 18).len());
    });
    t.row(vec![
        "partition 100MB grads into 1MB chunks".into(),
        format!("{:.1} us", s3 * 1e6),
        "-".into(),
    ]);

    // 5) comm pool submit+drain
    let pool = CommPool::new();
    let s4 = bench_median(2, 10, || {
        for _ in 0..1000 {
            pool.submit_ar(Box::new(|| std::hint::black_box(())));
        }
        pool.drain();
    });
    t.row(vec![
        "comm pool: 1000 jobs submit+drain".into(),
        format!("{:.1} us/job", s4 * 1e6 / 1000.0),
        "-".into(),
    ]);

    // 6) flat all-reduce of 4MB across 4 threads
    let s5 = bench_median(2, 8, || {
        let coll = Collective::new(4);
        let mut hs = Vec::new();
        for w in 0..4 {
            let c = Arc::clone(&coll);
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; 1 << 20];
                for tag in 0..4u64 {
                    c.all_reduce_sum(w, tag, &mut v).unwrap();
                }
                std::hint::black_box(v[0]);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    });
    t.row(vec![
        "collective: 4x all-reduce 4MB, 4 workers".into(),
        format!("{:.2} ms", s5 * 1e3),
        format!("{:.2} GB/s effective", 4.0 * 4.0 * 4e6 / s5 / 1e9),
    ]);

    t.print();
}
