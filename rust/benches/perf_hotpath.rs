//! Hot-path microbenchmarks (§Perf): DAG build + simulation throughput
//! (the coordinator's scheduling cost), the multi-core sweep engine vs
//! the old serial loop, and the comm-pool / collective primitives.
//! Paper bound: scheduling overhead < 1 % of iteration time.

use std::sync::Arc;

use flowmoe::commpool::{partition_ranges, Collective, CommPool};
use flowmoe::config::{preset, ClusterProfile};
use flowmoe::cost::TaskCosts;
use flowmoe::report::{bench_median, Table};
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::simulate;
use flowmoe::sweep::{flow_vs_sche, valid_custom_layers, Sweeper};

fn main() {
    let cl = ClusterProfile::cluster1(16);
    let mut t = Table::new(
        "Perf — coordinator hot paths",
        &["case", "median", "derived"],
    );

    // 1) DAG build + simulate for the biggest model at R=8, tiny chunks
    let cfg = preset("LLaMA2-MoE-L").unwrap();
    let costs = TaskCosts::build(&cfg, &cl);
    let pol = Policy::flow_moe(8, 0.25e6);
    let dag = build_dag(&cfg, &costs, &pol);
    let n_tasks = dag.len();
    let s = bench_median(3, 10, || {
        let d = build_dag(&cfg, &costs, &pol);
        std::hint::black_box(simulate(&d).makespan);
    });
    t.row(vec![
        format!("build+simulate LLaMA2-MoE-L R=8 ({n_tasks} tasks)"),
        format!("{:.3} ms", s * 1e3),
        format!("{:.1}k tasks/s", n_tasks as f64 / s / 1e3),
    ]);

    // simulated iteration is ~1.5s; scheduling cost must be <1% of that
    let iter_s = simulate(&dag).makespan;
    t.row(vec![
        "scheduling overhead vs simulated iteration".into(),
        format!("{:.3}%", s / iter_s * 100.0),
        "paper bound: <1%".into(),
    ]);

    // 2) 675-layer sweep (drives fig6): serial loop vs the multi-core
    // sweep engine, on a fixed slice of the valid grid. Results must be
    // byte-identical; throughput target: >= 3x on >= 4 cores.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (cases, _) = valid_custom_layers(&cl, 16, 128);
    let serial_sweeper = Sweeper::new().with_threads(1);
    let par_sweeper = Sweeper::new();
    let run_sweep = |sw: &Sweeper| sw.run(&cases, |_, c| flow_vs_sche(c, &cl));
    let serial_out = run_sweep(&serial_sweeper);
    let par_out = run_sweep(&par_sweeper);
    let identical = serial_out.len() == par_out.len()
        && serial_out.iter().zip(&par_out).all(|(a, b)| {
            a.0.to_bits() == b.0.to_bits() && a.1.to_bits() == b.1.to_bits()
        });
    assert!(identical, "parallel sweep results diverge from serial");
    let s_serial = bench_median(1, 3, || {
        std::hint::black_box(run_sweep(&serial_sweeper).len());
    });
    let s_par = bench_median(1, 3, || {
        std::hint::black_box(run_sweep(&par_sweeper).len());
    });
    let speedup = s_serial / s_par;
    t.row(vec![
        format!("sweep {} layer cases x 5 sims, serial", cases.len()),
        format!("{:.1} ms", s_serial * 1e3),
        format!("{:.1} cases/s", cases.len() as f64 / s_serial),
    ]);
    t.row(vec![
        format!("sweep {} layer cases x 5 sims, {} threads", cases.len(), par_sweeper.threads()),
        format!("{:.1} ms", s_par * 1e3),
        format!(
            "{speedup:.2}x vs serial on {cores} cores (target >= 3x on >= 4), byte-identical: {identical}"
        ),
    ]);
    if cores >= 4 {
        assert!(
            speedup >= 3.0,
            "sweep engine speedup {speedup:.2}x < 3x on {cores} cores"
        );
    }

    // 3) partitioner
    let s3 = bench_median(3, 50, || {
        std::hint::black_box(partition_ranges(100_000_000 / 4, 1 << 18).len());
    });
    t.row(vec![
        "partition 100MB grads into 1MB chunks".into(),
        format!("{:.1} us", s3 * 1e6),
        "-".into(),
    ]);

    // 4) comm pool submit+drain
    let pool = CommPool::new();
    let s4 = bench_median(2, 10, || {
        for _ in 0..1000 {
            pool.submit_ar(Box::new(|| std::hint::black_box(())));
        }
        pool.drain();
    });
    t.row(vec![
        "comm pool: 1000 jobs submit+drain".into(),
        format!("{:.1} us/job", s4 * 1e6 / 1000.0),
        "-".into(),
    ]);

    // 5) flat all-reduce of 4MB across 4 threads
    let s5 = bench_median(2, 8, || {
        let coll = Collective::new(4);
        let mut hs = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&coll);
            hs.push(std::thread::spawn(move || {
                let mut v = vec![1.0f32; 1 << 20];
                for tag in 0..4u64 {
                    c.all_reduce_sum(tag, &mut v);
                }
                std::hint::black_box(v[0]);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    });
    t.row(vec![
        "collective: 4x all-reduce 4MB, 4 workers".into(),
        format!("{:.2} ms", s5 * 1e3),
        format!("{:.2} GB/s effective", 4.0 * 4.0 * 4e6 / s5 / 1e9),
    ]);

    t.print();
}
