//! Deterministic synthetic corpus for the end-to-end trainer.
//!
//! Substitutes OpenWebText / wikitext-103 (DESIGN.md §1): a Zipfian
//! unigram mixture with per-"domain" structure — each sample draws a
//! latent domain that biases both its token distribution and (indirectly)
//! which experts its tokens route to, giving the gating network skewed,
//! learnable routing like real text does. A first-order Markov blend adds
//! enough sequential structure that next-token loss meaningfully drops
//! during training.

use crate::util::{rng::zipf_cdf, Pcg32, Rng};

/// Synthetic corpus generator.
pub struct Corpus {
    vocab: usize,
    n_domains: usize,
    /// Per-domain Zipf CDFs over a domain-shuffled vocab mapping.
    domain_cdfs: Vec<Vec<f64>>,
    domain_maps: Vec<Vec<u32>>,
    /// Per-domain bigram successor permutations: `succ[d][prev]` is the
    /// deterministic chain continuation for token `prev` in domain `d`.
    /// Built from one split [`Pcg32`] stream per domain (previously an
    /// ad-hoc `prev*31+7` LCG baked into `sample`).
    succ: Vec<Vec<u32>>,
    rng: Rng,
    /// Probability of continuing the local bigram chain vs resampling.
    chain_p: f64,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        let n_domains = 8;
        let mut rng = Rng::new(seed);
        let mut streams = Pcg32::new(seed);
        let cdf = zipf_cdf(vocab, 1.1);
        let mut domain_cdfs = Vec::new();
        let mut domain_maps = Vec::new();
        let mut succ = Vec::new();
        for _ in 0..n_domains {
            // each domain ranks the vocab differently (disjoint "topics")
            let mut map: Vec<u32> = (0..vocab as u32).collect();
            rng.shuffle(&mut map);
            domain_cdfs.push(cdf.clone());
            domain_maps.push(map);
            // ... and chains tokens through its own random permutation,
            // from an independent per-domain PRNG stream
            let mut s: Vec<u32> = (0..vocab as u32).collect();
            streams.split().shuffle(&mut s);
            succ.push(s);
        }
        Corpus {
            vocab,
            n_domains,
            domain_cdfs,
            domain_maps,
            succ,
            rng,
            chain_p: 0.55,
        }
    }

    /// One sample of `n` tokens.
    pub fn sample(&mut self, n: usize) -> Vec<i32> {
        let d = self.rng.below(self.n_domains);
        let mut out = Vec::with_capacity(n);
        let mut prev: i32 = -1;
        for _ in 0..n {
            let tok = if prev >= 0 && self.rng.f64() < self.chain_p {
                // deterministic bigram successor within the domain:
                // tok = succ[d][prev] — a fixed seeded permutation chain
                // the model can learn.
                self.succ[d][prev as usize] as i32
            } else {
                let r = self.rng.zipf(&self.domain_cdfs[d]);
                self.domain_maps[d][r] as i32
            };
            out.push(tok);
            prev = tok;
        }
        out
    }

    /// A batch of shape (b, n), flattened row-major.
    pub fn batch(&mut self, b: usize, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * n);
        for _ in 0..b {
            out.extend(self.sample(n));
        }
        out
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Snapshot the data cursor. The corpus tables (`domain_*`, `succ`)
    /// are a pure function of `(vocab, seed)` fixed at construction; the
    /// only mutable state is the sampling PRNG, so `(vocab, seed, rng
    /// state)` fully determines every future sample — that is what the
    /// trainer checkpoints.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the data cursor from a [`Corpus::rng_state`] snapshot
    /// taken on a corpus built with the same `(vocab, seed)`.
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }
}

/// Routing-skew generator for the load-imbalance studies (Table A.11):
/// token counts per expert when routing follows a Zipf law whose exponent
/// grows with the capacity factor (the paper's "larger f ⇒ more tokens to
/// popular experts").
pub fn skewed_expert_tokens(n_experts: usize, total_tokens: f64, skew: f64) -> Vec<f64> {
    let weights: Vec<f64> = (1..=n_experts).map(|i| 1.0 / (i as f64).powf(skew)).collect();
    let sum: f64 = weights.iter().sum();
    weights.iter().map(|w| total_tokens * w / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_range() {
        let mut c = Corpus::new(512, 1);
        let b = c.batch(4, 64);
        assert_eq!(b.len(), 256);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 512));
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Corpus::new(256, 9);
        let mut b = Corpus::new(256, 9);
        assert_eq!(a.batch(2, 32), b.batch(2, 32));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Corpus::new(256, 1);
        let mut b = Corpus::new(256, 2);
        assert_ne!(a.batch(2, 32), b.batch(2, 32));
    }

    #[test]
    fn rng_state_roundtrip_resumes_mid_stream() {
        let mut a = Corpus::new(256, 17);
        a.batch(3, 16); // advance the cursor past construction
        let snap = a.rng_state();
        let expect = a.batch(4, 32);
        let mut b = Corpus::new(256, 17);
        b.set_rng_state(snap);
        assert_eq!(expect, b.batch(4, 32), "restored cursor continues bitwise");
    }

    #[test]
    fn has_sequential_structure() {
        // bigram chaining => repeated (prev, next) pairs far above chance
        let mut c = Corpus::new(4096, 3);
        let s = c.sample(4096);
        let mut pair_counts = std::collections::HashMap::new();
        for w in s.windows(2) {
            *pair_counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let repeated = pair_counts.values().filter(|&&c| c > 1).count();
        assert!(repeated > 20, "repeated pairs: {repeated}");
    }

    #[test]
    fn skewed_tokens_sum_and_order() {
        let t = skewed_expert_tokens(8, 800.0, 1.5);
        let sum: f64 = t.iter().sum();
        assert!((sum - 800.0).abs() < 1e-9);
        assert!(t[0] > t[7]);
    }

    #[test]
    fn zero_skew_is_uniform() {
        let t = skewed_expert_tokens(4, 400.0, 0.0);
        for x in &t {
            assert!((x - 100.0).abs() < 1e-9);
        }
    }
}
