//! Expert-parallel execution over real A2A: the paper's Fig. 1b data
//! path with actual buffers moving between in-process workers.
//!
//! Each worker owns `E/P` experts; per microbatch it runs the AT piece
//! (MHA + gating HLO), routes its tokens in rust ([`dispatch`]), performs
//! a **real dispatch A2A** through the [`Collective`], runs the expert
//! FFN HLO on whatever tokens arrived, A2As the outputs back and combines
//! them ([`combine`]). The backward chain mirrors it exactly
//! (combine-bwd → A2A → expert-bwd → A2A → dispatch-bwd → AT-bwd),
//! validated against the monolithic block oracle in
//! `rust/tests/integration_cluster.rs` and mirrored in python by
//! `python/tests/test_ep_pieces.py`.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::backend::kernels as kn;
use crate::commpool::Collective;
use crate::runtime::{Engine, HostTensor};

/// Routing decision for one worker's microbatch.
#[derive(Clone, Debug)]
pub struct Routing {
    /// (E, C, M) dispatch tensor, row-major flattened.
    pub disp: Vec<f32>,
    /// (T, k) [expert, slot] pairs; slot == C marks a dropped token.
    pub comb: Vec<(u32, u32)>,
    /// Hoisted dispatch mask: one `(token, k-slot, slab offset)` entry
    /// per **kept** (non-overflowed) assignment, in `comb` order, with
    /// the slab offset pre-resolved to `(expert*C + slot) * M`. Built
    /// once in [`dispatch`] so [`combine`], [`combine_bwd`] and
    /// [`dispatch_bwd`] iterate kept rows directly instead of re-walking
    /// all T*k pairs and re-deriving the capacity test + slab index.
    pub kept: Vec<(u32, u32, usize)>,
    pub e: usize,
    pub c: usize,
    pub m: usize,
    pub k: usize,
}

/// Build the dispatch tensor from gating outputs (GShard semantics with
/// capacity dropping) — rust mirror of `ref.dispatch_ref`.
///
/// An empty token input (`t == 0`, e.g. a worker whose shard drained) is
/// a valid edge case: it returns an empty [`Routing`] — zeroed (E, C, M)
/// dispatch tensor, no per-token assignments, `k == 0` — instead of
/// dividing by zero. [`combine`]/[`combine_bwd`]/[`dispatch_bwd`] treat
/// such a routing as a no-op.
pub fn dispatch(u: &[f32], idx: &[i32], gate_len: usize, e: usize, c: usize, m: usize) -> Routing {
    let _sp = crate::obs::span("dispatch");
    let t = if m == 0 { 0 } else { u.len() / m };
    if t == 0 {
        return Routing {
            disp: vec![0.0; e * c * m],
            comb: Vec::new(),
            kept: Vec::new(),
            e,
            c,
            m,
            k: 0,
        };
    }
    let k = gate_len / t;
    let mut counters = vec![0u32; e];
    let mut disp = vec![0.0f32; e * c * m];
    let mut comb = Vec::with_capacity(t * k);
    let mut kept = Vec::with_capacity(t * k);
    for ti in 0..t {
        for ki in 0..k {
            let ex = idx[ti * k + ki] as usize;
            let slot = counters[ex];
            counters[ex] += 1;
            if (slot as usize) < c {
                let dst = (ex * c + slot as usize) * m;
                let src = ti * m;
                // a = 1.0 keeps this an exact add under every dispatch tier
                kn::axpy(&mut disp[dst..dst + m], &u[src..src + m], 1.0);
                comb.push((ex as u32, slot));
                kept.push((ti as u32, ki as u32, dst));
            } else {
                comb.push((ex as u32, c as u32)); // dropped
            }
        }
    }
    Routing {
        disp,
        comb,
        kept,
        e,
        c,
        m,
        k,
    }
}

/// Weighted gather of expert outputs back to tokens — rust mirror of
/// `ref.combine_ref`. `out` is (E, C, M) flattened. Walks the hoisted
/// `kept` list (same order as the full T*k loop, so identical float
/// summation), skipping dropped tokens without re-deriving the mask.
pub fn combine(out: &[f32], routing: &Routing, gate: &[f32]) -> Vec<f32> {
    let _sp = crate::obs::span("combine");
    let (e, c, m, k) = (routing.e, routing.c, routing.m, routing.k);
    debug_assert_eq!(out.len(), e * c * m);
    if k == 0 {
        return Vec::new(); // empty routing: no tokens to gather into
    }
    let t = routing.comb.len() / k;
    let mut y = vec![0.0f32; t * m];
    for &(ti, ki, src) in &routing.kept {
        let (ti, ki) = (ti as usize, ki as usize);
        let g = gate[ti * k + ki];
        kn::axpy(&mut y[ti * m..(ti + 1) * m], &out[src..src + m], g);
    }
    y
}

/// Backward of [`combine`]: returns (d_out (E,C,M), d_gate (T,k)).
/// Shares the forward's hoisted `kept` mask (dropped tokens keep zero
/// gate gradient and contribute nothing to d_out).
pub fn combine_bwd(dy: &[f32], out: &[f32], routing: &Routing, gate: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let _sp = crate::obs::span("combine_bwd");
    let (e, c, m, k) = (routing.e, routing.c, routing.m, routing.k);
    if k == 0 {
        return (vec![0.0; e * c * m], Vec::new()); // empty routing
    }
    let t = routing.comb.len() / k;
    let mut dout = vec![0.0f32; e * c * m];
    let mut dgate = vec![0.0f32; t * k];
    for &(ti, ki, o) in &routing.kept {
        let (ti, ki) = (ti as usize, ki as usize);
        let g = gate[ti * k + ki];
        let dyrow = &dy[ti * m..(ti + 1) * m];
        kn::axpy(&mut dout[o..o + m], dyrow, g);
        dgate[ti * k + ki] = kn::reduce_dot(dyrow, &out[o..o + m]);
    }
    (dout, dgate)
}

/// Backward of [`dispatch`]: scatter d_disp back onto token gradients,
/// via the forward's hoisted `kept` mask.
pub fn dispatch_bwd(d_disp: &[f32], routing: &Routing) -> Vec<f32> {
    let _sp = crate::obs::span("dispatch_bwd");
    let (m, k) = (routing.m, routing.k);
    if k == 0 {
        return Vec::new(); // empty routing: no token gradients
    }
    let t = routing.comb.len() / k;
    let mut du = vec![0.0f32; t * m];
    for &(ti, _ki, src) in &routing.kept {
        let ti = ti as usize;
        kn::axpy(&mut du[ti * m..(ti + 1) * m], &d_disp[src..src + m], 1.0);
    }
    du
}

/// Geometry of the EP pieces, read from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct EpGeo {
    pub p: usize,
    pub e: usize,
    pub e_local: usize,
    pub c: usize,
    pub cw: usize,
    pub m: usize,
    pub t: usize,
    pub k: usize,
}

pub fn ep_geometry(engine: &Engine, cfg: &str, p: usize) -> Result<EpGeo> {
    let ef = engine.manifest().get(&format!("exp_fwd_{cfg}"))?;
    let xd = &ef.inputs[2]; // (el, cw, m)
    let (e_local, cw, m) = (xd.shape[0], xd.shape[1], xd.shape[2]);
    let ab = engine.manifest().get(&format!("at_bwd_{cfg}"))?;
    let dg = ab.inputs.last().ok_or_else(|| anyhow!("at_bwd_{cfg} has no inputs"))?; // dgate (T, k)
    let (t, k) = (dg.shape[0], dg.shape[1]);
    if cw % p != 0 {
        return Err(anyhow!("cw {cw} not divisible by P {p}"));
    }
    Ok(EpGeo {
        p,
        e: e_local * p,
        e_local,
        c: cw / p,
        cw,
        m,
        t,
        k,
    })
}

/// Per-worker result of one EP forward+backward over a transformer block.
#[derive(Clone, Debug)]
pub struct EpResult {
    /// Block output y = h + combined (T*M).
    pub y: Vec<f32>,
    /// Gradients of the 7 AT tensors.
    pub datp: Vec<Vec<f32>>,
    /// dL/dx of the block input (T*M).
    pub dx: Vec<f32>,
    /// Local expert weight grads (el*M*H, el*H*M) — complete (sums over
    /// all source workers' tokens, the EP property).
    pub dw1: Vec<f32>,
    pub dw2: Vec<f32>,
}

/// Run one expert-parallel block fwd+bwd on worker `w` of `p`.
/// `atp` = 7 AT tensors, `w1/w2` = the worker's local expert shard,
/// `x` = local tokens (T*M), `dy` = upstream gradient (T*M).
#[allow(clippy::too_many_arguments)]
pub fn ep_block_fwd_bwd(
    engine: &mut Engine,
    coll: &Arc<Collective>,
    w: usize,
    cfg: &str,
    geo: &EpGeo,
    atp: &[Vec<f32>],
    w1: &[f32],
    w2: &[f32],
    x: &[f32],
    dy: &[f32],
    tag_base: u64,
) -> Result<EpResult> {
    let at_fwd = format!("at_fwd_{cfg}");
    let at_bwd = format!("at_bwd_{cfg}");
    let exp_fwd = format!("exp_fwd_{cfg}");
    let exp_bwd = format!("exp_bwd_{cfg}");
    let (p, el, c, m) = (geo.p, geo.e_local, geo.c, geo.m);

    // ---- AT piece ----
    let atp_t: Vec<HostTensor> = atp.iter().map(|v| HostTensor::F32(v.clone())).collect();
    let x_t = HostTensor::F32(x.to_vec());
    let mut inp: Vec<&HostTensor> = atp_t.iter().collect();
    inp.push(&x_t);
    let outs = engine.run(&at_fwd, &inp)?;
    let h = outs[0].f32().to_vec();
    let u = outs[1].f32().to_vec();
    let idx = outs[3].i32().to_vec();
    let gate = outs[4].f32().to_vec();

    // ---- routing + dispatch A2A ----
    let routing = dispatch(&u, &idx, gate.len(), geo.e, c, m);
    let slab = el * c * m;
    let sp = crate::obs::span("a2a_dispatch");
    for o in 0..p {
        let part = routing.disp[o * slab..(o + 1) * slab].to_vec();
        coll.send(w, o, tag_base, part);
    }
    // xd: (el, cw, m) with cw = C*P, source s occupies columns [s*C, (s+1)*C)
    let mut xd = vec![0.0f32; el * geo.cw * m];
    for s in 0..p {
        let part = coll.recv(s, w, tag_base).map_err(|e| anyhow!("a2a recv from {s}: {e}"))?;
        for e in 0..el {
            let dst = (e * geo.cw + s * c) * m;
            let src = e * c * m;
            xd[dst..dst + c * m].copy_from_slice(&part[src..src + c * m]);
        }
    }
    drop(sp);

    // ---- expert fwd ----
    let w1_t = HostTensor::F32(w1.to_vec());
    let w2_t = HostTensor::F32(w2.to_vec());
    let xd_t = HostTensor::F32(xd.clone());
    let yd = engine.run(&exp_fwd, &[&w1_t, &w2_t, &xd_t])?;
    let yd = yd.into_iter().next().ok_or_else(|| anyhow!("{exp_fwd} produced no outputs"))?;

    // ---- combine A2A (outputs back to sources) ----
    let sp = crate::obs::span("a2a_combine");
    for s in 0..p {
        let mut part = vec![0.0f32; slab];
        for e in 0..el {
            let src = (e * geo.cw + s * c) * m;
            part[e * c * m..(e + 1) * c * m].copy_from_slice(&yd.f32()[src..src + c * m]);
        }
        coll.send(w, s, tag_base + 1, part);
    }
    let mut out_full = vec![0.0f32; geo.e * c * m];
    for o in 0..p {
        let part = coll.recv(o, w, tag_base + 1).map_err(|e| anyhow!("a2a recv from {o}: {e}"))?;
        out_full[o * slab..(o + 1) * slab].copy_from_slice(&part);
    }
    drop(sp);
    let yc = combine(&out_full, &routing, &gate);
    let mut y = h.clone();
    for i in 0..y.len() {
        y[i] += yc[i];
    }

    // ================= backward =================
    // residual: dh = dy; combine-bwd
    let (dout, dgate) = combine_bwd(dy, &out_full, &routing, &gate);
    // A2A dout to owners (same layout as dispatch)
    let sp = crate::obs::span("a2a_combine_bwd");
    for o in 0..p {
        coll.send(w, o, tag_base + 2, dout[o * slab..(o + 1) * slab].to_vec());
    }
    let mut dyd = vec![0.0f32; el * geo.cw * m];
    for s in 0..p {
        let part = coll.recv(s, w, tag_base + 2).map_err(|e| anyhow!("a2a recv from {s}: {e}"))?;
        for e in 0..el {
            let dst = (e * geo.cw + s * c) * m;
            dyd[dst..dst + c * m].copy_from_slice(&part[e * c * m..(e + 1) * c * m]);
        }
    }
    drop(sp);
    // expert bwd on the owner
    let dyd_t = HostTensor::F32(dyd);
    let outs = engine.run(&exp_bwd, &[&w1_t, &w2_t, &xd_t, &dyd_t])?;
    let dw1 = outs[0].f32().to_vec();
    let dw2 = outs[1].f32().to_vec();
    let dxd = outs[2].f32().to_vec();
    // A2A dxd back to sources
    let sp = crate::obs::span("a2a_dispatch_bwd");
    for s in 0..p {
        let mut part = vec![0.0f32; slab];
        for e in 0..el {
            let src = (e * geo.cw + s * c) * m;
            part[e * c * m..(e + 1) * c * m].copy_from_slice(&dxd[src..src + c * m]);
        }
        coll.send(w, s, tag_base + 3, part);
    }
    let mut d_disp = vec![0.0f32; geo.e * c * m];
    for o in 0..p {
        let part = coll.recv(o, w, tag_base + 3).map_err(|e| anyhow!("a2a recv from {o}: {e}"))?;
        d_disp[o * slab..(o + 1) * slab].copy_from_slice(&part);
    }
    drop(sp);
    let du = dispatch_bwd(&d_disp, &routing);

    // AT bwd closes the chain
    let dh_t = HostTensor::F32(dy.to_vec());
    let du_t = HostTensor::F32(du);
    let dgate_t = HostTensor::F32(dgate);
    let mut inp: Vec<&HostTensor> = atp_t.iter().collect();
    inp.push(&x_t);
    inp.push(&dh_t);
    inp.push(&du_t);
    inp.push(&dgate_t);
    let outs = engine.run(&at_bwd, &inp)?;
    let datp: Vec<Vec<f32>> = outs[..7].iter().map(|t| t.f32().to_vec()).collect();
    let dx = outs[7].f32().to_vec();

    Ok(EpResult {
        y,
        datp,
        dx,
        dw1,
        dw2,
    })
}

/// Spawn P workers, run one EP block fwd+bwd each, return per-worker
/// results (used by integration tests and the quickstart example).
pub fn run_ep_cluster(
    artifacts: &Path,
    cfg: &str,
    p: usize,
    atp: Vec<Vec<f32>>,
    w1_full: Vec<f32>,
    w2_full: Vec<f32>,
    xs: Vec<Vec<f32>>,
    dys: Vec<Vec<f32>>,
) -> Result<Vec<EpResult>> {
    run_ep_cluster_faulty(artifacts, cfg, p, atp, w1_full, w2_full, xs, dys, None, crate::ft::DETECT_TIMEOUT_MS)
}

/// [`run_ep_cluster`] with seeded fault injection: a planned kill (or
/// drop/delay plan) turns the A2A exchange into typed `a2a recv` errors
/// on every survivor within `detect_ms` — the regression surface for the
/// hang class (a dead peer used to block the whole cluster forever).
#[allow(clippy::too_many_arguments)]
pub fn run_ep_cluster_faulty(
    artifacts: &Path,
    cfg: &str,
    p: usize,
    atp: Vec<Vec<f32>>,
    w1_full: Vec<f32>,
    w2_full: Vec<f32>,
    xs: Vec<Vec<f32>>,
    dys: Vec<Vec<f32>>,
    fault: Option<crate::ft::FaultPlan>,
    detect_ms: u64,
) -> Result<Vec<EpResult>> {
    let coll = Collective::with_opts(p, detect_ms, fault, 0);
    let dir = artifacts.to_path_buf();
    // kernel-level threads compose with worker-level parallelism: each
    // worker gets an equal share of the caller's budget (min 1), and the
    // caller's kernel-dispatch tier is re-applied inside the workers
    // (spawned threads start with an empty thread-local override)
    let worker_budget = (crate::sweep::scope::current_budget() / p).max(1);
    let disp = kn::active_dispatch();
    let mut handles = Vec::new();
    for w in 0..p {
        let coll = Arc::clone(&coll);
        let dir = dir.clone();
        let cfg = cfg.to_string();
        let atp = atp.clone();
        let (w1_full, w2_full) = (w1_full.clone(), w2_full.clone());
        let x = xs[w].clone();
        let dy = dys[w].clone();
        // EP workers model independent GPU ranks whose lifetime spans the
        // whole collective round; joined below.
        // flowmoe-lint: allow(thread_spawn) — long-lived worker, not a task
        handles.push(std::thread::spawn(move || -> Result<EpResult> {
            let out = kn::with_dispatch(disp, || {
                crate::sweep::scope::with_budget(worker_budget, || {
                    let mut engine = Engine::new(&dir)?;
                    let geo = ep_geometry(&engine, &cfg, p)?;
                    if coll.should_die(w, 0) {
                        // planned fault: this rank vanishes before the
                        // dispatch A2A; survivors must error, not hang
                        coll.mark_dead(w);
                        return Err(anyhow!("worker {w} killed (planned fault)"));
                    }
                    let shard = w1_full.len() / p;
                    let shard2 = w2_full.len() / p;
                    let w1 = &w1_full[w * shard..(w + 1) * shard];
                    let w2 = &w2_full[w * shard2..(w + 1) * shard2];
                    ep_block_fwd_bwd(&mut engine, &coll, w, &cfg, &geo, &atp, w1, w2, &x, &dy, 100)
                })
            });
            if out.is_err() {
                // a failed worker is gone for good; unblock the peers
                coll.mark_dead(w);
            }
            out
        }));
    }
    // join *all* workers before reporting: a propagated error must not
    // leave detached threads blocked on the collective
    let mut out = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(r)) => out.push(r),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some(anyhow!("ep worker panicked"));
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn routing_fixture() -> (Vec<f32>, Vec<i32>, Vec<f32>, usize, usize, usize) {
        // 4 tokens, m=2, e=2, c=2, k=1
        let u = vec![
            1.0, 2.0, //
            3.0, 4.0, //
            5.0, 6.0, //
            7.0, 8.0,
        ];
        let idx = vec![0, 1, 0, 0]; // token 3 overflows expert 0 (c=2)
        let gate = vec![1.0, 1.0, 0.5, 1.0];
        (u, idx, gate, 2, 2, 2)
    }

    #[test]
    fn dispatch_empty_tokens_returns_empty_routing() {
        // regression: `dispatch` used to divide by t (== 0) and panic
        let (e, c, m) = (2usize, 2usize, 2usize);
        let r = dispatch(&[], &[], 0, e, c, m);
        assert_eq!(r.k, 0);
        assert!(r.comb.is_empty());
        assert_eq!(r.disp, vec![0.0f32; e * c * m]);
        // downstream ops treat the empty routing as a no-op
        let out = vec![1.0f32; e * c * m];
        assert!(combine(&out, &r, &[]).is_empty());
        let (dout, dgate) = combine_bwd(&[], &out, &r, &[]);
        assert_eq!(dout, vec![0.0f32; e * c * m]);
        assert!(dgate.is_empty());
        assert!(dispatch_bwd(&out, &r).is_empty());
    }

    #[test]
    fn dispatch_places_and_drops() {
        let (u, idx, gate, e, c, m) = routing_fixture();
        let r = dispatch(&u, &idx, gate.len(), e, c, m);
        // expert0 slot0 = token0, slot1 = token2; expert1 slot0 = token1
        assert_eq!(&r.disp[0..2], &[1.0, 2.0]);
        assert_eq!(&r.disp[2..4], &[5.0, 6.0]);
        assert_eq!(&r.disp[4..6], &[3.0, 4.0]);
        assert_eq!(r.comb[3], (0, 2)); // dropped (slot == c)
    }

    #[test]
    fn kept_list_matches_comb_mask() {
        let (u, idx, gate, e, c, m) = routing_fixture();
        let r = dispatch(&u, &idx, gate.len(), e, c, m);
        // kept holds exactly the non-dropped (ti, ki) pairs in comb
        // order, with the (E,C,M) slab offset pre-resolved
        let mut want = Vec::new();
        for (i, &(ex, slot)) in r.comb.iter().enumerate() {
            if (slot as usize) < c {
                let (ti, ki) = (i / r.k, i % r.k);
                want.push((ti as u32, ki as u32, (ex as usize * c + slot as usize) * m));
            }
        }
        assert_eq!(r.kept, want);
        assert_eq!(r.kept.len(), 3, "token 3 overflowed expert 0");
    }

    #[test]
    fn combine_inverts_dispatch_with_unit_gates() {
        let (u, idx, _gate, e, c, m) = routing_fixture();
        let gate = vec![1.0f32; 4];
        let r = dispatch(&u, &idx, gate.len(), e, c, m);
        let y = combine(&r.disp, &r, &gate);
        // kept tokens reproduce themselves; dropped token 3 becomes zero
        assert_eq!(&y[0..6], &u[0..6]);
        assert_eq!(&y[6..8], &[0.0, 0.0]);
    }

    #[test]
    fn combine_bwd_transposes_combine() {
        // <combine(out), dy> == <out, combine_bwd(dy).dout> (adjoint test)
        let (u, idx, gate, e, c, m) = routing_fixture();
        let r = dispatch(&u, &idx, gate.len(), e, c, m);
        let mut rng = Rng::new(1);
        let out: Vec<f32> = (0..e * c * m).map(|_| rng.normal() as f32).collect();
        let dy: Vec<f32> = (0..u.len()).map(|_| rng.normal() as f32).collect();
        let y = combine(&out, &r, &gate);
        let (dout, _dg) = combine_bwd(&dy, &out, &r, &gate);
        let lhs: f32 = y.iter().zip(&dy).map(|(a, b)| a * b).sum();
        let rhs: f32 = out.iter().zip(&dout).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn dispatch_bwd_transposes_dispatch() {
        let (u, idx, gate, e, c, m) = routing_fixture();
        let r = dispatch(&u, &idx, gate.len(), e, c, m);
        let mut rng = Rng::new(2);
        let dd: Vec<f32> = (0..e * c * m).map(|_| rng.normal() as f32).collect();
        let du = dispatch_bwd(&dd, &r);
        let lhs: f32 = r.disp.iter().zip(&dd).map(|(a, b)| a * b).sum();
        let rhs: f32 = u.iter().zip(&du).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn dgate_is_dot_of_dy_and_expert_out() {
        let (u, idx, gate, e, c, m) = routing_fixture();
        let r = dispatch(&u, &idx, gate.len(), e, c, m);
        let out: Vec<f32> = (0..e * c * m).map(|i| i as f32).collect();
        let dy = vec![1.0f32; u.len()];
        let (_, dg) = combine_bwd(&dy, &out, &r, &gate);
        // token0 -> expert0 slot0 -> out rows [0,1] => dot = 0+1 = 1
        assert_eq!(dg[0], 1.0);
        // dropped token 3 gets zero gate grad
        assert_eq!(dg[3], 0.0);
    }
}
