//! Native execution backend: pure-Rust dense f32 kernels behind the
//! [`crate::runtime::Backend`] trait.
//!
//! The offline crate links no XLA/PJRT client, so the AOT HLO artifacts
//! cannot execute as compiled programs. This module lights the execution
//! path up anyway: every artifact the AOT pipeline exports
//! (`python/compile/aot.py`) has a native kernel here with identical
//! positional I/O, resolved by artifact name + config. The trainer, the
//! EP cluster and the integration tests run end-to-end with **no JAX, no
//! artifacts, no external crates**.
//!
//! Two entry points:
//! * [`NativeBackend`] — executes a manifest [`ArtifactSpec`] whose config
//!   is a known preset and whose name matches an exported entry point
//!   (`train_step_*`, `block_fwd_*`, `at_bwd_*`, ...). Every kernel it
//!   reaches — matmuls, reductions, embedding scatter, expert FFN —
//!   routes through the [`kernels::Dispatch`] chooser
//!   (`FLOWMOE_KERNELS={auto,simd,blocked,naive}`, §Perf in `kernels`).
//! * [`native_manifest`] — synthesizes the manifest the AOT exporter
//!   would have written for the `tiny` and `e2e` configs (same artifact
//!   names, same buffer names/shapes/dtypes), so `runtime::Engine` works
//!   from a clean checkout where `artifacts/manifest.txt` does not exist.

pub mod kernels;
pub mod model;
pub mod workspace;

use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::config::{preset, ModelCfg};
use crate::runtime::{ArtifactSpec, Backend, BufSpec, Dtype, HostTensor, Manifest};
use model::{AtParams, BlockParams, Geo};
pub use workspace::Workspace;

/// Artifact families the native backend executes (one per AOT entry point).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    TrainStep,
    GradStep,
    BlockFwd,
    BlockBwd,
    EmbedFwd,
    HeadLoss,
    EmbedBwd,
    AtFwd,
    AtBwd,
    ExpFwd,
    ExpBwd,
}

/// Resolve an artifact to (kernel family, model config): the name must be
/// `<entry>_<config>` with a known preset config, mirroring the AOT
/// exporter's naming scheme.
fn kind_of(spec: &ArtifactSpec) -> Option<(Kind, ModelCfg)> {
    let suffix = format!("_{}", spec.config);
    let base = spec.name.strip_suffix(suffix.as_str())?;
    let cfg = preset(&spec.config)?;
    let kind = match base {
        "train_step" => Kind::TrainStep,
        "grad_step" => Kind::GradStep,
        "block_fwd" => Kind::BlockFwd,
        "block_bwd" => Kind::BlockBwd,
        "embed_fwd" => Kind::EmbedFwd,
        "head_loss" => Kind::HeadLoss,
        "embed_bwd" => Kind::EmbedBwd,
        "at_fwd" => Kind::AtFwd,
        "at_bwd" => Kind::AtBwd,
        "exp_fwd" => Kind::ExpFwd,
        "exp_bwd" => Kind::ExpBwd,
        _ => return None,
    };
    Some((kind, cfg))
}

/// The in-tree reference execution backend (dense f32 CPU kernels).
///
/// Owns a persistent [`Workspace`] so the hot-path temporaries of
/// `train_step`/`grad_step`/`block_*`/`at_*`/`head_loss` recycle across
/// `execute` calls (i.e. across layers *and* steps). Each worker thread
/// owns its own `Engine` — and therefore its own backend + workspace —
/// so the mutex is uncontended; it exists because [`Backend::execute`]
/// takes `&self`.
#[derive(Debug, Default)]
pub struct NativeBackend {
    ws: Mutex<Workspace>,
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, spec: &ArtifactSpec) -> bool {
        kind_of(spec).is_some()
    }

    fn execute(&self, spec: &ArtifactSpec, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let (kind, cfg) =
            kind_of(spec).ok_or_else(|| anyhow!("{}: no native kernel for this artifact", spec.name))?;
        let g = Geo::from_cfg(&cfg);
        // a poisoned lock is harmless here: the workspace has no
        // invariants (take() always returns zeroed buffers), so recover
        // it instead of disabling the backend after one caught panic
        let mut ws_guard = crate::util::lock_recover(&self.ws);
        let ws = &mut *ws_guard;
        let f32s = |i: usize| inputs[i].f32();
        let out = match kind {
            Kind::EmbedFwd => {
                let tokens = inputs[1].i32();
                check_tokens(&spec.name, tokens, g.vocab)?;
                vec![HostTensor::F32(kernels::embed_lookup(f32s(0), tokens, g.m))]
            }
            Kind::EmbedBwd => {
                let tokens = inputs[0].i32();
                check_tokens(&spec.name, tokens, g.vocab)?;
                vec![HostTensor::F32(kernels::embed_scatter(tokens, f32s(1), g.vocab, g.m))]
            }
            Kind::BlockFwd => {
                let slices: Vec<&[f32]> = (0..9).map(f32s).collect();
                let bp = BlockParams::new(&slices);
                let x = f32s(9);
                let c = g.capacity(x.len() / g.m / g.n_seq);
                let (y, st) = model::block_forward_ws(&g, &bp, x, c, ws);
                st.recycle(ws);
                vec![HostTensor::F32(y)]
            }
            Kind::BlockBwd => {
                let slices: Vec<&[f32]> = (0..9).map(f32s).collect();
                let bp = BlockParams::new(&slices);
                let x = f32s(9);
                let dy = f32s(10);
                let c = g.capacity(x.len() / g.m / g.n_seq);
                let (grads, dx) = model::block_backward_ws(&g, &bp, x, c, dy, ws);
                let mut out: Vec<HostTensor> = grads.into_iter().map(HostTensor::F32).collect();
                out.push(HostTensor::F32(dx));
                out
            }
            Kind::HeadLoss => {
                let tokens = inputs[3].i32();
                check_tokens(&spec.name, tokens, g.vocab)?;
                let b = tokens.len() / g.n_seq;
                let (loss, dxf, de, dn) = model::head_loss_ws(&g, f32s(0), f32s(1), f32s(2), tokens, b, ws);
                vec![
                    HostTensor::F32(vec![loss]),
                    HostTensor::F32(dxf),
                    HostTensor::F32(de),
                    HostTensor::F32(dn),
                ]
            }
            Kind::GradStep => {
                let n_params = inputs.len() - 1;
                let params: Vec<&[f32]> = (0..n_params).map(f32s).collect();
                let tokens = inputs[n_params].i32();
                check_tokens(&spec.name, tokens, g.vocab)?;
                let b_full = tokens.len() / g.n_seq;
                let (loss, grads) = model::grad_step_ws(&g, &params, tokens, b_full, ws);
                let mut out = vec![HostTensor::F32(vec![loss])];
                out.extend(grads.into_iter().map(HostTensor::F32));
                out
            }
            Kind::TrainStep => {
                let n_params = (inputs.len() - 2) / 2;
                let params: Vec<&[f32]> = (0..n_params).map(f32s).collect();
                let moms: Vec<&[f32]> = (n_params..2 * n_params).map(f32s).collect();
                let tokens = inputs[2 * n_params].i32();
                check_tokens(&spec.name, tokens, g.vocab)?;
                let lr = f32s(2 * n_params + 1)[0];
                let b_full = tokens.len() / g.n_seq;
                let (new_p, new_m, loss) = model::train_step_ws(&g, &params, &moms, tokens, lr, b_full, ws);
                let mut out: Vec<HostTensor> = new_p.into_iter().map(HostTensor::F32).collect();
                out.extend(new_m.into_iter().map(HostTensor::F32));
                out.push(HostTensor::F32(vec![loss]));
                out
            }
            Kind::AtFwd => {
                let slices: Vec<&[f32]> = (0..7).map(f32s).collect();
                let atp = AtParams::new(&slices);
                let model::AtState { mha, u, gating } = model::at_forward_ws(&g, &atp, f32s(7), ws);
                let h = mha.into_h(ws);
                vec![
                    HostTensor::F32(h),
                    HostTensor::F32(u),
                    HostTensor::F32(gating.probs),
                    HostTensor::I32(gating.idx),
                    HostTensor::F32(gating.gate),
                ]
            }
            Kind::AtBwd => {
                let slices: Vec<&[f32]> = (0..7).map(f32s).collect();
                let atp = AtParams::new(&slices);
                let x = f32s(7);
                let st = model::at_forward_ws(&g, &atp, x, ws);
                let (grads, dx) = model::at_backward_ws(&g, &atp, x, &st, f32s(8), f32s(9), f32s(10), ws);
                st.recycle(ws);
                let mut out: Vec<HostTensor> = grads.into_iter().map(HostTensor::F32).collect();
                out.push(HostTensor::F32(dx));
                out
            }
            Kind::ExpFwd => {
                let (el, m, h) = expert_dims(spec);
                let cw = spec.inputs[2].shape[1];
                vec![HostTensor::F32(kernels::expert_ffn(
                    f32s(2),
                    f32s(0),
                    f32s(1),
                    el,
                    cw,
                    m,
                    h,
                ))]
            }
            Kind::ExpBwd => {
                let (el, m, h) = expert_dims(spec);
                let cw = spec.inputs[2].shape[1];
                let (dxd, dw1, dw2) = kernels::expert_ffn_bwd(f32s(2), f32s(0), f32s(1), f32s(3), el, cw, m, h);
                vec![HostTensor::F32(dw1), HostTensor::F32(dw2), HostTensor::F32(dxd)]
            }
        };
        Ok(out)
    }
}

/// Expert-shard dims of the EP pieces from the manifest: w1 is `(el, m, h)`.
fn expert_dims(spec: &ArtifactSpec) -> (usize, usize, usize) {
    let s = &spec.inputs[0].shape;
    (s[0], s[1], s[2])
}

/// Token ids index the embedding table directly; the engine validates
/// shapes/dtypes but not values, so reject out-of-range ids here with an
/// error instead of a slice-OOB panic deep inside a kernel.
fn check_tokens(name: &str, tokens: &[i32], vocab: usize) -> Result<()> {
    for &t in tokens {
        if t < 0 || t as usize >= vocab {
            bail!("{name}: token id {t} out of range [0, {vocab})");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Native manifest synthesis (mirror of python/compile/aot.py)
// ---------------------------------------------------------------------------

/// Configs the native manifest covers — the AOT exporter's defaults.
pub const NATIVE_CONFIGS: [&str; 2] = ["tiny", "e2e"];

/// Microbatch pipelining degree of the exported block pieces (aot.py
/// `micro_r` default).
pub const NATIVE_MICRO_R: usize = 2;

/// EP worker count of the tiny config's expert-parallel pieces.
pub const NATIVE_EP_WORKERS: usize = 2;

fn f32_spec(name: &str, shape: &[usize]) -> BufSpec {
    BufSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: Dtype::F32,
    }
}

fn i32_spec(name: &str, shape: &[usize]) -> BufSpec {
    BufSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: Dtype::I32,
    }
}

/// Canonical flat parameter order (mirror of model.py `param_spec`).
fn param_shapes(cfg: &ModelCfg) -> Vec<(String, Vec<usize>)> {
    let (m, e, h) = (cfg.m, cfg.e, cfg.h);
    let mut out = vec![("embed".to_string(), vec![cfg.vocab, m])];
    for l in 0..cfg.l {
        out.push((format!("block{l}.n1"), vec![m]));
        out.push((format!("block{l}.wq"), vec![m, m]));
        out.push((format!("block{l}.wk"), vec![m, m]));
        out.push((format!("block{l}.wv"), vec![m, m]));
        out.push((format!("block{l}.wo"), vec![m, m]));
        out.push((format!("block{l}.n2"), vec![m]));
        out.push((format!("block{l}.wg"), vec![m, e]));
        out.push((format!("block{l}.w1"), vec![e, m, h]));
        out.push((format!("block{l}.w2"), vec![e, h, m]));
    }
    out.push(("normf".to_string(), vec![m]));
    out
}

/// Synthesize the manifest `python -m compile.aot` would write for the
/// native configs — same artifact names and positional buffer signatures
/// — so the engine runs with no `artifacts/` directory at all. `dir` is
/// recorded as the (possibly nonexistent) artifacts directory.
pub fn native_manifest(dir: &Path) -> Manifest {
    let mut man = Manifest {
        artifacts: Vec::new(),
        dir: dir.to_path_buf(),
    };
    for name in NATIVE_CONFIGS {
        let Some(cfg) = preset(name) else { continue };
        let ep = if name == "tiny" { NATIVE_EP_WORKERS } else { 0 };
        push_config(&mut man, &cfg, NATIVE_MICRO_R, ep);
    }
    man
}

fn push_config(man: &mut Manifest, cfg: &ModelCfg, micro_r: usize, ep_workers: usize) {
    let c = cfg.name;
    let ps = param_shapes(cfg);
    let with_prefix =
        |pre: &str| -> Vec<BufSpec> { ps.iter().map(|(n, s)| f32_spec(&format!("{pre}.{n}"), s)).collect() };
    let mut art = |name: String, inputs: Vec<BufSpec>, outputs: Vec<BufSpec>| {
        man.artifacts.push(ArtifactSpec {
            file: format!("{name}.hlo.txt"),
            config: c.to_string(),
            name,
            inputs,
            outputs,
        });
    };

    // --- fused train_step / grad_step over the full batch ---
    let tok = i32_spec("tokens", &[cfg.b, cfg.n]);
    let mut ins = with_prefix("param");
    ins.extend(with_prefix("mom"));
    ins.push(tok.clone());
    ins.push(f32_spec("lr", &[]));
    let mut outs = with_prefix("new_param");
    outs.extend(with_prefix("new_mom"));
    outs.push(f32_spec("loss", &[]));
    art(format!("train_step_{c}"), ins, outs);

    let mut ins = with_prefix("param");
    ins.push(tok);
    let mut outs = vec![f32_spec("loss", &[])];
    outs.extend(with_prefix("grad"));
    art(format!("grad_step_{c}"), ins, outs);

    // --- per-block pieces at microbatch granularity ---
    let bm = cfg.b / micro_r;
    let tm = bm * cfg.n;
    let x_sp = f32_spec("x", &[tm, cfg.m]);
    let tok_m = i32_spec("tokens", &[bm, cfg.n]);
    let block_name =
        |(n, _): &(String, Vec<usize>)| n.split_once('.').map_or(n.as_str(), |(_, rest)| rest).to_string();
    let block9: Vec<BufSpec> = ps[1..10]
        .iter()
        .map(|t| f32_spec(&format!("bp.{}", block_name(t)), &t.1))
        .collect();
    let grad9: Vec<BufSpec> = ps[1..10]
        .iter()
        .map(|t| f32_spec(&format!("grad.{}", block_name(t)), &t.1))
        .collect();

    let mut ins = block9.clone();
    ins.push(x_sp.clone());
    art(format!("block_fwd_{c}"), ins, vec![f32_spec("y", &[tm, cfg.m])]);

    let mut ins = block9.clone();
    ins.push(x_sp.clone());
    ins.push(f32_spec("dy", &[tm, cfg.m]));
    let mut outs = grad9.clone();
    outs.push(f32_spec("dx", &[tm, cfg.m]));
    art(format!("block_bwd_{c}"), ins, outs);

    let emb = f32_spec("param.embed", &[cfg.vocab, cfg.m]);
    let nf = f32_spec("param.normf", &[cfg.m]);
    art(
        format!("embed_fwd_{c}"),
        vec![emb.clone(), tok_m.clone()],
        vec![f32_spec("x", &[tm, cfg.m])],
    );
    art(
        format!("head_loss_{c}"),
        vec![emb.clone(), nf, f32_spec("xf", &[tm, cfg.m]), tok_m.clone()],
        vec![
            f32_spec("loss", &[]),
            f32_spec("dxf", &[tm, cfg.m]),
            f32_spec("grad.embed_head", &[cfg.vocab, cfg.m]),
            f32_spec("grad.normf", &[cfg.m]),
        ],
    );
    art(
        format!("embed_bwd_{c}"),
        vec![tok_m, f32_spec("dx", &[tm, cfg.m])],
        vec![f32_spec("grad.embed", &[cfg.vocab, cfg.m])],
    );

    // --- expert-parallel layer pieces (fixed worker count) ---
    if ep_workers > 0 {
        let p = ep_workers;
        let el = cfg.e / p;
        let cap = Geo::from_cfg(cfg).capacity(cfg.b); // per-source-worker per-expert capacity
        let cw = cap * p;
        let atp: Vec<BufSpec> = ps[1..8]
            .iter()
            .map(|t| f32_spec(&format!("atp.{}", block_name(t)), &t.1))
            .collect();

        let mut ins = atp.clone();
        ins.push(x_sp.clone());
        art(
            format!("at_fwd_{c}"),
            ins,
            vec![
                f32_spec("h", &[tm, cfg.m]),
                f32_spec("u", &[tm, cfg.m]),
                f32_spec("probs", &[tm, cfg.e]),
                i32_spec("idx", &[tm, cfg.k]),
                f32_spec("gate", &[tm, cfg.k]),
            ],
        );

        let mut ins = atp;
        ins.push(x_sp.clone());
        ins.push(f32_spec("dh", &[tm, cfg.m]));
        ins.push(f32_spec("du", &[tm, cfg.m]));
        ins.push(f32_spec("dgate", &[tm, cfg.k]));
        let mut outs: Vec<BufSpec> = grad9[..7].to_vec();
        outs.push(f32_spec("dx", &[tm, cfg.m]));
        art(format!("at_bwd_{c}"), ins, outs);

        let w1 = f32_spec("w1", &[el, cfg.m, cfg.h]);
        let w2 = f32_spec("w2", &[el, cfg.h, cfg.m]);
        let xd = f32_spec("xd", &[el, cw, cfg.m]);
        art(
            format!("exp_fwd_{c}"),
            vec![w1.clone(), w2.clone(), xd.clone()],
            vec![f32_spec("yd", &[el, cw, cfg.m])],
        );
        art(
            format!("exp_bwd_{c}"),
            vec![w1, w2, xd, f32_spec("dyd", &[el, cw, cfg.m])],
            vec![
                f32_spec("dw1", &[el, cfg.m, cfg.h]),
                f32_spec("dw2", &[el, cfg.h, cfg.m]),
                f32_spec("dxd", &[el, cw, cfg.m]),
            ],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_manifest_mirrors_aot_exporter() {
        let man = native_manifest(Path::new("/nonexistent"));
        for name in [
            "train_step_tiny",
            "grad_step_tiny",
            "block_fwd_tiny",
            "block_bwd_tiny",
            "embed_fwd_tiny",
            "head_loss_tiny",
            "embed_bwd_tiny",
            "at_fwd_tiny",
            "at_bwd_tiny",
            "exp_fwd_tiny",
            "exp_bwd_tiny",
            "train_step_e2e",
            "grad_step_e2e",
            "block_fwd_e2e",
        ] {
            assert!(man.get(name).is_ok(), "missing {name}");
        }
        // e2e has no EP pieces (mirrors aot.py)
        assert!(man.get("at_fwd_e2e").is_err());

        // tiny train_step: 2 * (2 + 2*9) params+moms + tokens + lr
        let ts = man.get("train_step_tiny").unwrap();
        assert_eq!(ts.inputs.len(), 2 * 20 + 2);
        assert_eq!(ts.outputs.len(), 2 * 20 + 1);
        assert_eq!(ts.inputs[0].name, "param.embed");
        assert_eq!(ts.inputs[0].shape, vec![128, 32]);
        let tokspec = ts.inputs.iter().find(|b| b.name == "tokens").unwrap();
        assert_eq!(tokspec.shape, vec![2, 16]);
        assert_eq!(tokspec.dtype, Dtype::I32);

        // microbatch pieces: bm = B / micro_r = 1, Tm = 16
        let bf = man.get("block_fwd_tiny").unwrap();
        assert_eq!(bf.inputs.len(), 10);
        assert_eq!(bf.inputs[9].shape, vec![16, 32]);
        assert_eq!(bf.inputs[0].name, "bp.n1");

        // EP pieces: el = 2, cw = C*P = 64*2 = 128
        let ef = man.get("exp_fwd_tiny").unwrap();
        assert_eq!(ef.inputs[2].shape, vec![2, 128, 32]);
    }

    #[test]
    fn out_of_range_tokens_error_instead_of_panicking() {
        let man = native_manifest(Path::new("/nonexistent"));
        let be = NativeBackend::default();
        let spec = man.get("embed_fwd_tiny").unwrap();
        let embed = HostTensor::F32(vec![0.0; spec.inputs[0].elems()]);
        for bad in [128i32, -1] {
            let tokens = HostTensor::I32(vec![bad; spec.inputs[1].elems()]);
            let err = format!("{:#}", be.execute(spec, &[&embed, &tokens]).unwrap_err());
            assert!(err.contains("out of range"), "{err}");
        }
    }

    #[test]
    fn kind_resolution_requires_known_entry_and_config() {
        let man = native_manifest(Path::new("/nonexistent"));
        let be = NativeBackend::default();
        for a in &man.artifacts {
            assert!(be.supports(a), "native manifest artifact {} unsupported", a.name);
        }
        let bogus = ArtifactSpec {
            name: "foo_tiny".into(),
            file: String::new(),
            config: "tiny".into(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        };
        assert!(!be.supports(&bogus));
        let unknown_cfg = ArtifactSpec {
            name: "block_fwd_nosuch".into(),
            file: String::new(),
            config: "nosuch".into(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        };
        assert!(!be.supports(&unknown_cfg));
    }
}
