//! Native model pieces — host-side mirror of `python/compile/model.py`.
//!
//! Same architecture, same parameter order, same numerics: a pre-norm
//! decoder-only transformer whose feed-forward layers are MoE layers
//! (RMSNorm -> MHA -> residual -> RMSNorm -> top-k gate -> dispatch ->
//! expert FFN -> combine -> residual) with a tied-embedding LM head.
//! Routing reuses [`crate::cluster::dispatch`]/[`crate::cluster::combine`]
//! (the GShard mirror the EP path already ships) so the monolithic block
//! and the expert-parallel A2A path share one routing implementation.
//!
//! Backward passes rematerialize the forward (as the AOT `block_bwd`
//! artifact does) so no residual state crosses the caller boundary.
//!
//! # Hot path (§Perf)
//!
//! Every piece has a `*_ws` variant threading a [`Workspace`] scratch
//! arena: temporaries (projections, head scratch, gradient buffers) are
//! taken from and retired to the pool instead of allocated per call, so
//! buffers recycle across layers within a step and — via the persistent
//! workspace in [`super::NativeBackend`] — across steps. The allocating
//! free functions remain as thin wrappers over a throwaway workspace
//! (same numerics, used by tests and one-shot callers). Inside one step
//! the embarrassingly parallel axes fan out across the
//! [`crate::sweep::scope`] thread budget: matmul row bands (in
//! `kernels`), experts (in `kernels::expert_ffn*`), the per-(sample,
//! head) attention loops here, and the cross-entropy rows of
//! [`head_loss_ws`]. Every kernel call routes through the
//! [`kernels::Dispatch`](kn::Dispatch) chooser (`FLOWMOE_KERNELS`); the
//! fan-out closures capture the caller's tier so a thread-local
//! [`kn::with_dispatch`] override survives into scope workers. All of it
//! is deterministic *within a tier*: results are byte-identical for any
//! thread budget and for fresh vs recycled buffers.

use crate::cluster::{combine, combine_bwd, dispatch, dispatch_bwd, Routing};
use crate::sweep::scope;

use super::kernels as kn;
use super::workspace::Workspace;

/// Geometry of one model configuration (paper Table 2 notation).
#[derive(Clone, Copy, Debug)]
pub struct Geo {
    /// Embedding size M.
    pub m: usize,
    /// Experts per MoE layer E.
    pub e: usize,
    /// Expert hidden size H.
    pub h: usize,
    /// Top-k experts per token.
    pub top_k: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Tokens per sample N.
    pub n_seq: usize,
    /// Capacity factor f.
    pub f: f64,
    /// Vocabulary size.
    pub vocab: usize,
}

impl Geo {
    pub fn from_cfg(cfg: &crate::config::ModelCfg) -> Geo {
        Geo {
            m: cfg.m,
            e: cfg.e,
            h: cfg.h,
            top_k: cfg.k,
            n_heads: cfg.n_heads,
            n_seq: cfg.n,
            f: cfg.f,
            vocab: cfg.vocab,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.m / self.n_heads
    }

    /// GShard capacity for a batch of `b` samples: `int(f*k*b*N/E)`, at
    /// least 1 (python `int()` truncation, mirroring `MoEConfig.capacity`).
    pub fn capacity(&self, b: usize) -> usize {
        ((self.f * (self.top_k * b * self.n_seq) as f64 / self.e as f64) as usize).max(1)
    }
}

/// The 7 replicated (data-parallel) tensors of one block, canonical order.
#[derive(Clone, Copy)]
pub struct AtParams<'a> {
    pub n1: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub n2: &'a [f32],
    pub wg: &'a [f32],
}

impl<'a> AtParams<'a> {
    pub fn new(p: &[&'a [f32]]) -> AtParams<'a> {
        AtParams {
            n1: p[0],
            wq: p[1],
            wk: p[2],
            wv: p[3],
            wo: p[4],
            n2: p[5],
            wg: p[6],
        }
    }
}

/// All 9 tensors of one block: the AT part plus the expert weights.
#[derive(Clone, Copy)]
pub struct BlockParams<'a> {
    pub at: AtParams<'a>,
    pub w1: &'a [f32],
    pub w2: &'a [f32],
}

impl<'a> BlockParams<'a> {
    pub fn new(p: &[&'a [f32]]) -> BlockParams<'a> {
        BlockParams {
            at: AtParams::new(p),
            w1: p[7],
            w2: p[8],
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-head attention
// ---------------------------------------------------------------------------

/// Work threshold (`units * N^2 * head_dim`) below which the per-(sample,
/// head) attention loops stay serial — mirrors the kernel-level gating.
const HEAD_PAR_MIN: usize = 1 << 16;

/// Whether the (sample, head) axis is worth fanning out right now.
fn par_heads(units: usize, n_seq: usize, hd: usize) -> bool {
    units >= 2
        && scope::current_budget() > 1
        && units.saturating_mul(n_seq * n_seq).saturating_mul(hd) >= HEAD_PAR_MIN
}

/// Copy head `hh` of sample `bi` out of a flat `(T, M)` tensor into `(N, hd)`.
fn gather_head(xf: &[f32], bi: usize, hh: usize, n_seq: usize, m: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_seq * hd];
    for i in 0..n_seq {
        let src = (bi * n_seq + i) * m + hh * hd;
        out[i * hd..(i + 1) * hd].copy_from_slice(&xf[src..src + hd]);
    }
    out
}

/// Inverse of [`gather_head`]: write `(N, hd)` back into the flat tensor.
fn scatter_head(xf: &mut [f32], o: &[f32], bi: usize, hh: usize, n_seq: usize, m: usize, hd: usize) {
    for i in 0..n_seq {
        let dst = (bi * n_seq + i) * m + hh * hd;
        xf[dst..dst + hd].copy_from_slice(&o[i * hd..(i + 1) * hd]);
    }
}

/// Saved forward state of [`mha_forward`] (consumed by the backward).
pub struct MhaState {
    xn: Vec<f32>,
    qf: Vec<f32>,
    kf: Vec<f32>,
    vf: Vec<f32>,
    /// Per-(sample, head) attention weight matrices `(N, N)`.
    att_w: Vec<Vec<f32>>,
    of: Vec<f32>,
    /// Residual-stream output `h = x + attn(x) @ wo`.
    pub h: Vec<f32>,
}

impl MhaState {
    /// Retire every saved buffer into the workspace pool.
    pub fn recycle(self, ws: &mut Workspace) {
        let h = self.into_h(ws);
        ws.put(h);
    }

    /// Take the residual-stream output `h`, retiring every other saved
    /// buffer into the workspace pool.
    pub fn into_h(self, ws: &mut Workspace) -> Vec<f32> {
        let MhaState {
            xn,
            qf,
            kf,
            vf,
            att_w,
            of,
            h,
        } = self;
        ws.put_all([xn, qf, kf, vf, of]);
        ws.put_all(att_w);
        h
    }
}

/// Multi-head causal attention over flat `(T, M)` tokens (model.py `mha`),
/// workspace-pooled. Heads fan out across the thread budget.
pub fn mha_forward_ws(g: &Geo, p: &AtParams, x: &[f32], ws: &mut Workspace) -> MhaState {
    let _sp = crate::obs::span("mha_fwd");
    let t = x.len() / g.m;
    let b = t / g.n_seq;
    let hd = g.head_dim();
    let mut xn = ws.take(t * g.m);
    kn::rmsnorm_into(x, p.n1, &mut xn);
    let mut qf = ws.take(t * g.m);
    kn::par_matmul_into(&xn, p.wq, &mut qf, t, g.m, g.m);
    let mut kf = ws.take(t * g.m);
    kn::par_matmul_into(&xn, p.wk, &mut kf, t, g.m, g.m);
    let mut vf = ws.take(t * g.m);
    kn::par_matmul_into(&xn, p.wv, &mut vf, t, g.m, g.m);
    let units = b * g.n_heads;
    // capture the dispatch tier: scope workers are fresh threads, so a
    // thread-local override must be re-applied inside the fan-out
    let disp = kn::active_dispatch();
    let head = |u: usize| {
        kn::with_dispatch(disp, || {
            let (bi, hh) = (u / g.n_heads, u % g.n_heads);
            let q = gather_head(&qf, bi, hh, g.n_seq, g.m, hd);
            let k = gather_head(&kf, bi, hh, g.n_seq, g.m, hd);
            let v = gather_head(&vf, bi, hh, g.n_seq, g.m, hd);
            kn::attention_causal(&q, &k, &v, g.n_seq, hd)
        })
    };
    let heads: Vec<(Vec<f32>, Vec<f32>)> = if par_heads(units, g.n_seq, hd) {
        scope::par_map_vec(units, head)
    } else {
        (0..units).map(head).collect()
    };
    let mut of = ws.take(t * g.m);
    let mut att_w = Vec::with_capacity(units);
    for (u, (w, o)) in heads.into_iter().enumerate() {
        scatter_head(&mut of, &o, u / g.n_heads, u % g.n_heads, g.n_seq, g.m, hd);
        ws.put(o);
        att_w.push(w);
    }
    let mut proj = ws.take(t * g.m);
    kn::par_matmul_into(&of, p.wo, &mut proj, t, g.m, g.m);
    let mut h = ws.take(t * g.m);
    for ((hv, &xv), &pv) in h.iter_mut().zip(x).zip(&proj) {
        *hv = xv + pv;
    }
    ws.put(proj);
    MhaState {
        xn,
        qf,
        kf,
        vf,
        att_w,
        of,
        h,
    }
}

/// Multi-head causal attention (allocating wrapper over [`mha_forward_ws`]).
pub fn mha_forward(g: &Geo, p: &AtParams, x: &[f32]) -> MhaState {
    mha_forward_ws(g, p, x, &mut Workspace::new())
}

/// Backward of [`mha_forward`], workspace-pooled: returns
/// `([dn1, dwq, dwk, dwv, dwo], dx)` for the residual-stream cotangent `dh`.
pub fn mha_backward_ws(
    g: &Geo,
    p: &AtParams,
    x: &[f32],
    st: &MhaState,
    dh: &[f32],
    ws: &mut Workspace,
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let _sp = crate::obs::span("mha_bwd");
    let t = x.len() / g.m;
    let b = t / g.n_seq;
    let hd = g.head_dim();
    // h = x + of @ wo  (weight-NT GEMMs pool their packed-B panels)
    let mut dof = ws.take(t * g.m);
    kn::par_matmul_nt_into_ws(dh, p.wo, &mut dof, t, g.m, g.m, ws);
    let mut dwo = ws.take(g.m * g.m);
    kn::par_matmul_tn_into(&st.of, dh, &mut dwo, t, g.m, g.m);
    let units = b * g.n_heads;
    let disp = kn::active_dispatch();
    let head = |u: usize| {
        kn::with_dispatch(disp, || {
            let (bi, hh) = (u / g.n_heads, u % g.n_heads);
            let q = gather_head(&st.qf, bi, hh, g.n_seq, g.m, hd);
            let k = gather_head(&st.kf, bi, hh, g.n_seq, g.m, hd);
            let v = gather_head(&st.vf, bi, hh, g.n_seq, g.m, hd);
            let doh = gather_head(&dof, bi, hh, g.n_seq, g.m, hd);
            kn::attention_causal_bwd(&q, &k, &v, &st.att_w[u], &doh, g.n_seq, hd)
        })
    };
    let heads: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = if par_heads(units, g.n_seq, hd) {
        scope::par_map_vec(units, head)
    } else {
        (0..units).map(head).collect()
    };
    let mut dqf = ws.take(t * g.m);
    let mut dkf = ws.take(t * g.m);
    let mut dvf = ws.take(t * g.m);
    for (u, (dq, dk, dv)) in heads.into_iter().enumerate() {
        let (bi, hh) = (u / g.n_heads, u % g.n_heads);
        scatter_head(&mut dqf, &dq, bi, hh, g.n_seq, g.m, hd);
        scatter_head(&mut dkf, &dk, bi, hh, g.n_seq, g.m, hd);
        scatter_head(&mut dvf, &dv, bi, hh, g.n_seq, g.m, hd);
        ws.put_all([dq, dk, dv]);
    }
    ws.put(dof);
    let mut dwq = ws.take(g.m * g.m);
    kn::par_matmul_tn_into(&st.xn, &dqf, &mut dwq, t, g.m, g.m);
    let mut dwk = ws.take(g.m * g.m);
    kn::par_matmul_tn_into(&st.xn, &dkf, &mut dwk, t, g.m, g.m);
    let mut dwv = ws.take(g.m * g.m);
    kn::par_matmul_tn_into(&st.xn, &dvf, &mut dwv, t, g.m, g.m);
    let mut dxn = ws.take(t * g.m);
    kn::par_matmul_nt_into_ws(&dqf, p.wq, &mut dxn, t, g.m, g.m, ws);
    let mut dxn_k = ws.take(t * g.m);
    kn::par_matmul_nt_into_ws(&dkf, p.wk, &mut dxn_k, t, g.m, g.m, ws);
    let mut dxn_v = ws.take(t * g.m);
    kn::par_matmul_nt_into_ws(&dvf, p.wv, &mut dxn_v, t, g.m, g.m, ws);
    for ((a, b_), c) in dxn.iter_mut().zip(&dxn_k).zip(&dxn_v) {
        *a += b_ + c;
    }
    ws.put_all([dxn_k, dxn_v, dqf, dkf, dvf]);
    let mut dx_norm = ws.take(t * g.m);
    let mut dn1 = ws.take(g.m);
    kn::rmsnorm_bwd_into(x, p.n1, &dxn, &mut dx_norm, &mut dn1);
    ws.put(dxn);
    let mut dx = ws.take(t * g.m);
    for ((o, &a), &b_) in dx.iter_mut().zip(dh).zip(&dx_norm) {
        *o = a + b_;
    }
    ws.put(dx_norm);
    (vec![dn1, dwq, dwk, dwv, dwo], dx)
}

/// Backward of [`mha_forward`] (allocating wrapper).
pub fn mha_backward(g: &Geo, p: &AtParams, x: &[f32], st: &MhaState, dh: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
    mha_backward_ws(g, p, x, st, dh, &mut Workspace::new())
}

// ---------------------------------------------------------------------------
// AT piece (MHA + gating) and the full transformer block
// ---------------------------------------------------------------------------

/// Saved forward state of [`at_forward`].
pub struct AtState {
    pub mha: MhaState,
    /// Normed MoE input `u = rmsnorm(h, n2)`.
    pub u: Vec<f32>,
    pub gating: kn::Gating,
}

impl AtState {
    /// Retire every saved buffer into the workspace pool.
    pub fn recycle(self, ws: &mut Workspace) {
        let AtState { mha, u, gating } = self;
        mha.recycle(ws);
        ws.put(u);
        ws.put(gating.probs);
        ws.put(gating.gate);
        // gating.idx is i32 — the pool is f32-only, let it drop
    }
}

/// The gating head over residual-stream rows `h`, flat `(T, M)`: norm2 +
/// router matmul + top-k. The non-MHA half of the AT task, shared by
/// [`at_forward_ws`] (training, full prefixes) and the serving decode
/// path ([`crate::serve`], one row per in-flight sequence). Returns the
/// normed MoE input `u` and the gating decision.
pub fn gate_forward_ws(g: &Geo, p: &AtParams, h: &[f32], ws: &mut Workspace) -> (Vec<f32>, kn::Gating) {
    // the span covers only the gating head; MHA (full-prefix or cached
    // decode) records its own span in the caller
    let _sp = crate::obs::span("gating_fwd");
    let t = h.len() / g.m;
    let mut u = ws.take(t * g.m);
    kn::rmsnorm_into(h, p.n2, &mut u);
    let mut logits = ws.take(t * g.e);
    kn::par_matmul_into(&u, p.wg, &mut logits, t, g.m, g.e);
    let gating = kn::gating_topk(&logits, g.e, g.top_k);
    ws.put(logits);
    (u, gating)
}

/// The paper's AT task (model.py `at_task`): MHA + gating for one
/// (micro)batch of flat `(T, M)` tokens, workspace-pooled.
pub fn at_forward_ws(g: &Geo, p: &AtParams, x: &[f32], ws: &mut Workspace) -> AtState {
    let mha = mha_forward_ws(g, p, x, ws);
    let (u, gating) = gate_forward_ws(g, p, &mha.h, ws);
    AtState { mha, u, gating }
}

/// The paper's AT task (allocating wrapper over [`at_forward_ws`]).
pub fn at_forward(g: &Geo, p: &AtParams, x: &[f32]) -> AtState {
    at_forward_ws(g, p, x, &mut Workspace::new())
}

/// Backward of [`at_forward`] with cotangents for its `(h, u, gate)`
/// outputs (model.py `at_bwd`; the probs output is a non-differentiated
/// auxiliary), workspace-pooled.
/// Returns `([dn1, dwq, dwk, dwv, dwo, dn2, dwg], dx)`.
#[allow(clippy::too_many_arguments)]
pub fn at_backward_ws(
    g: &Geo,
    p: &AtParams,
    x: &[f32],
    st: &AtState,
    dh: &[f32],
    du: &[f32],
    dgate: &[f32],
    ws: &mut Workspace,
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let t = x.len() / g.m;
    let sp = crate::obs::span("gating_bwd");
    let dlogits = kn::gating_topk_bwd(&st.gating, g.e, g.top_k, dgate);
    let mut dwg = ws.take(g.m * g.e);
    kn::par_matmul_tn_into(&st.u, &dlogits, &mut dwg, t, g.m, g.e);
    let mut du_int = ws.take(t * g.m);
    kn::par_matmul_nt_into_ws(&dlogits, p.wg, &mut du_int, t, g.e, g.m, ws);
    for (a, b) in du_int.iter_mut().zip(du) {
        *a += b;
    }
    ws.put(dlogits);
    let mut dh_norm = ws.take(t * g.m);
    let mut dn2 = ws.take(g.m);
    kn::rmsnorm_bwd_into(&st.mha.h, p.n2, &du_int, &mut dh_norm, &mut dn2);
    ws.put(du_int);
    let mut dh_tot = ws.take(t * g.m);
    for ((o, &a), &b) in dh_tot.iter_mut().zip(dh).zip(&dh_norm) {
        *o = a + b;
    }
    ws.put(dh_norm);
    drop(sp); // close the gating span before the nested MHA backward
    let (mut grads, dx) = mha_backward_ws(g, p, x, &st.mha, &dh_tot, ws);
    ws.put(dh_tot);
    grads.push(dn2);
    grads.push(dwg);
    (grads, dx)
}

/// Backward of [`at_forward`] (allocating wrapper).
pub fn at_backward(
    g: &Geo,
    p: &AtParams,
    x: &[f32],
    st: &AtState,
    dh: &[f32],
    du: &[f32],
    dgate: &[f32],
) -> (Vec<Vec<f32>>, Vec<f32>) {
    at_backward_ws(g, p, x, st, dh, du, dgate, &mut Workspace::new())
}

/// Saved forward state of [`block_forward`].
pub struct BlockState {
    pub at: AtState,
    pub routing: Routing,
    pub expert_out: Vec<f32>,
}

impl BlockState {
    /// Retire every saved buffer into the workspace pool.
    pub fn recycle(self, ws: &mut Workspace) {
        let BlockState {
            at,
            routing,
            expert_out,
        } = self;
        at.recycle(ws);
        ws.put(expert_out);
        ws.put(routing.disp);
        // routing.comb/kept are index lists — let them drop
    }
}

/// The MoE half of one block over already-gated rows: dispatch ->
/// expert FFN -> combine -> residual. `h` is the residual stream and
/// `u` the normed MoE input (both flat `(T, M)`), `w1`/`w2` the expert
/// weights. Shared by [`block_forward_ws`] and the serving decode path
/// (whose expert-parallel variant replaces only the FFN slab with an
/// A2A round trip). Returns `(y, routing, expert_out)`.
pub fn moe_forward_ws(
    g: &Geo,
    w1: &[f32],
    w2: &[f32],
    h: &[f32],
    u: &[f32],
    gating: &kn::Gating,
    c: usize,
    ws: &mut Workspace,
) -> (Vec<f32>, Routing, Vec<f32>) {
    let routing = dispatch(u, &gating.idx, gating.gate.len(), g.e, c, g.m);
    let mut expert_out = ws.take(g.e * c * g.m);
    {
        let _sp = crate::obs::span("expert_fwd");
        kn::expert_ffn_into(&routing.disp, w1, w2, &mut expert_out, g.e, c, g.m, g.h);
    }
    let yc = combine(&expert_out, &routing, &gating.gate);
    let mut y = ws.take(h.len());
    for ((yv, &hv), &cv) in y.iter_mut().zip(h).zip(&yc) {
        *yv = hv + cv;
    }
    ws.put(yc);
    (y, routing, expert_out)
}

/// One transformer block forward over flat `(T, M)` activations with
/// per-expert capacity `c` (model.py `block_fwd`), workspace-pooled.
/// Returns `(y, state)`.
pub fn block_forward_ws(g: &Geo, p: &BlockParams, x: &[f32], c: usize, ws: &mut Workspace) -> (Vec<f32>, BlockState) {
    let at = at_forward_ws(g, &p.at, x, ws);
    let (y, routing, expert_out) = moe_forward_ws(g, p.w1, p.w2, &at.mha.h, &at.u, &at.gating, c, ws);
    (
        y,
        BlockState {
            at,
            routing,
            expert_out,
        },
    )
}

/// One transformer block forward (allocating wrapper over
/// [`block_forward_ws`]).
pub fn block_forward(g: &Geo, p: &BlockParams, x: &[f32], c: usize) -> (Vec<f32>, BlockState) {
    block_forward_ws(g, p, x, c, &mut Workspace::new())
}

/// Recompute-based VJP of one block (model.py `block_bwd`),
/// workspace-pooled: returns the 9 parameter grads in canonical order
/// plus `dx`.
pub fn block_backward_ws(
    g: &Geo,
    p: &BlockParams,
    x: &[f32],
    c: usize,
    dy: &[f32],
    ws: &mut Workspace,
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let (y, st) = block_forward_ws(g, p, x, c, ws);
    ws.put(y);
    let (dout, dgate) = combine_bwd(dy, &st.expert_out, &st.routing, &st.at.gating.gate);
    let mut ddisp = ws.take(g.e * c * g.m);
    let mut dw1 = ws.take(g.e * g.m * g.h);
    let mut dw2 = ws.take(g.e * g.h * g.m);
    {
        let _sp = crate::obs::span("expert_bwd");
        kn::expert_ffn_bwd_into(
            &st.routing.disp,
            p.w1,
            p.w2,
            &dout,
            &mut ddisp,
            &mut dw1,
            &mut dw2,
            g.e,
            c,
            g.m,
            g.h,
        );
    }
    ws.put(dout);
    let du = dispatch_bwd(&ddisp, &st.routing);
    ws.put(ddisp);
    let (mut grads, dx) = at_backward_ws(g, &p.at, x, &st.at, dy, &du, &dgate, ws);
    ws.put_all([du, dgate]);
    st.recycle(ws);
    grads.push(dw1);
    grads.push(dw2);
    (grads, dx)
}

/// Recompute-based VJP of one block (allocating wrapper).
pub fn block_backward(g: &Geo, p: &BlockParams, x: &[f32], c: usize, dy: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
    block_backward_ws(g, p, x, c, dy, &mut Workspace::new())
}

// ---------------------------------------------------------------------------
// Embedding / LM head / loss
// ---------------------------------------------------------------------------

/// Work threshold (`t * vocab` logits elements) below which the
/// cross-entropy row loop of [`head_loss_ws`] stays serial.
const CE_PAR_MIN: usize = 1 << 14;

/// Final norm + tied LM head + next-token cross-entropy, fused fwd+bwd
/// (model.py `head_loss_fwd_bwd`), workspace-pooled.
/// Returns `(loss, dxf, dembed, dnormf)`.
///
/// The LM-head `matmul_nt` runs through the workspace-pooled packed-B
/// path (§Perf) and the cross-entropy rows fan out across the thread
/// budget via [`scope::par_rows_pair`]: each row writes its `dlogits`
/// row plus a per-row loss slot, and the row losses are summed in fixed
/// ascending order afterwards, so the result is byte-identical for any
/// budget (within a dispatch tier).
#[allow(clippy::too_many_arguments)]
pub fn head_loss_ws(
    g: &Geo,
    embed: &[f32],
    normf: &[f32],
    xf: &[f32],
    tokens: &[i32],
    b: usize,
    ws: &mut Workspace,
) -> (f32, Vec<f32>, Vec<f32>, Vec<f32>) {
    let _sp = crate::obs::span("head_loss");
    let (n, m, v) = (g.n_seq, g.m, g.vocab);
    let t = b * n;
    let mut xn = ws.take(t * m);
    kn::rmsnorm_into(xf, normf, &mut xn);
    let mut logits = ws.take(t * v);
    kn::par_matmul_nt_into_ws(&xn, embed, &mut logits, t, m, v, ws);
    let count = (b * (n - 1)) as f32;
    let mut dlogits = ws.take(t * v);
    let mut row_loss = ws.take(t);
    let d = kn::active_dispatch();
    let logits_ref: &[f32] = &logits;
    // Fused CE fwd+bwd for one row; rows are independent (the last
    // position of each sample has no next-token target and keeps its
    // zeroed dlogits row / zero loss slot).
    let ce_row = move |ti: usize, drow: &mut [f32], lslot: &mut f32| {
        if ti % n == n - 1 {
            return;
        }
        let row = &logits_ref[ti * v..(ti + 1) * v];
        let target = tokens[ti + 1] as usize;
        let mx = kn::reduce_max_d(row, d);
        for (dv, &l) in drow.iter_mut().zip(row) {
            *dv = (l - mx).exp();
        }
        let sumexp = kn::reduce_sum_d(drow, d);
        let logz = mx + sumexp.ln();
        *lslot = logz - row[target];
        for (j, (dv, &l)) in drow.iter_mut().zip(row).enumerate() {
            let p = (l - logz).exp();
            *dv = (p - if j == target { 1.0 } else { 0.0 }) / count;
        }
    };
    if t >= 2 && scope::current_budget() > 1 && t.saturating_mul(v) >= CE_PAR_MIN {
        scope::par_rows_pair(&mut dlogits, v, &mut row_loss, 1, |row0, dband, lband| {
            for (r, (drow, lslot)) in dband.chunks_exact_mut(v).zip(lband.iter_mut()).enumerate() {
                ce_row(row0 + r, drow, lslot);
            }
        });
    } else {
        for (ti, (drow, lslot)) in dlogits.chunks_exact_mut(v).zip(row_loss.iter_mut()).enumerate() {
            ce_row(ti, drow, lslot);
        }
    }
    let mut loss = 0.0f64;
    for &rl in row_loss.iter() {
        loss += rl as f64;
    }
    let loss = (loss / count as f64) as f32;
    ws.put_all([logits, row_loss]);
    let mut dxn = ws.take(t * m);
    kn::par_matmul_into(&dlogits, embed, &mut dxn, t, v, m);
    let mut dembed = ws.take(v * m);
    kn::par_matmul_tn_into(&dlogits, &xn, &mut dembed, t, v, m);
    ws.put_all([dlogits, xn]);
    let mut dxf = ws.take(t * m);
    let mut dnormf = ws.take(m);
    kn::rmsnorm_bwd_into(xf, normf, &dxn, &mut dxf, &mut dnormf);
    ws.put(dxn);
    (loss, dxf, dembed, dnormf)
}

/// Final norm + tied LM head, forward only (the serving logits path):
/// flat `(T, vocab)` next-token logits for residual-stream rows `xf`.
/// Same numerics as the head of [`head_loss_ws`], without the loss or
/// backward; the LM-head `matmul_nt` reuses the workspace-pooled
/// packed-B panel.
pub fn lm_head_logits_ws(g: &Geo, embed: &[f32], normf: &[f32], xf: &[f32], ws: &mut Workspace) -> Vec<f32> {
    let _sp = crate::obs::span("decode_head");
    let t = xf.len() / g.m;
    let mut xn = ws.take(t * g.m);
    kn::rmsnorm_into(xf, normf, &mut xn);
    let mut logits = ws.take(t * g.vocab);
    kn::par_matmul_nt_into_ws(&xn, embed, &mut logits, t, g.m, g.vocab, ws);
    ws.put(xn);
    logits
}

/// Final norm + tied LM head + loss (allocating wrapper over
/// [`head_loss_ws`]).
pub fn head_loss(
    g: &Geo,
    embed: &[f32],
    normf: &[f32],
    xf: &[f32],
    tokens: &[i32],
    b: usize,
) -> (f32, Vec<f32>, Vec<f32>, Vec<f32>) {
    head_loss_ws(g, embed, normf, xf, tokens, b, &mut Workspace::new())
}

// ---------------------------------------------------------------------------
// Fused train/grad step over the whole parameter list
// ---------------------------------------------------------------------------

/// Per-worker full-model gradient (model.py `grad_step`), workspace-
/// pooled: forward through all blocks, head loss, full backward.
/// `params` is the canonical flat list (embed, L x 9 block tensors,
/// normf). Returns `(loss, grads)` with the tied embedding gradient
/// already summed (input lookup + LM head).
pub fn grad_step_ws(
    g: &Geo,
    params: &[&[f32]],
    tokens: &[i32],
    b_full: usize,
    ws: &mut Workspace,
) -> (f32, Vec<Vec<f32>>) {
    let n_params = params.len();
    let l_blocks = (n_params - 2) / 9;
    let c = g.capacity(b_full);
    let blocks: Vec<BlockParams> = (0..l_blocks)
        .map(|l| BlockParams::new(&params[1 + l * 9..1 + (l + 1) * 9]))
        .collect();

    let mut xs = Vec::with_capacity(l_blocks + 1);
    let mut x0 = ws.take(tokens.len() * g.m);
    kn::embed_lookup_into(params[0], tokens, g.m, &mut x0);
    xs.push(x0);
    for (l, bp) in blocks.iter().enumerate() {
        let (y, st) = block_forward_ws(g, bp, &xs[l], c, ws);
        st.recycle(ws);
        xs.push(y);
    }
    let (loss, dxf, de_head, dnormf) =
        head_loss_ws(g, params[0], params[n_params - 1], &xs[l_blocks], tokens, b_full, ws);
    if let Some(x) = xs.pop() {
        ws.put(x); // xs[l_blocks]: consumed by the head
    }

    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n_params];
    let mut dx = dxf;
    for l in (0..l_blocks).rev() {
        let (bg, dx_next) = block_backward_ws(g, &blocks[l], &xs[l], c, &dx, ws);
        if let Some(x) = xs.pop() {
            ws.put(x); // xs[l]: this was its last use
        }
        for (ti, gt) in bg.into_iter().enumerate() {
            grads[1 + l * 9 + ti] = gt;
        }
        ws.put(std::mem::replace(&mut dx, dx_next));
    }
    let mut de = ws.take(g.vocab * g.m);
    kn::embed_scatter_into(tokens, &dx, g.m, &mut de);
    for (a, b) in de.iter_mut().zip(&de_head) {
        *a += b;
    }
    ws.put_all([dx, de_head]);
    grads[0] = de;
    grads[n_params - 1] = dnormf;
    (loss, grads)
}

/// Per-worker full-model gradient (allocating wrapper over
/// [`grad_step_ws`]).
pub fn grad_step(g: &Geo, params: &[&[f32]], tokens: &[i32], b_full: usize) -> (f32, Vec<Vec<f32>>) {
    grad_step_ws(g, params, tokens, b_full, &mut Workspace::new())
}

/// Momentum coefficient baked into the fused `train_step` artifact
/// (aot.py lowers `model.train_step` at its default `momentum=0.9`).
pub const TRAIN_STEP_MOMENTUM: f32 = 0.9;

/// Fused single-process SGD+momentum step (model.py `train_step`),
/// workspace-pooled: returns `(new_params, new_moms, loss)`. The
/// per-tensor updates fan out across the thread budget; gradients are
/// retired to the pool afterwards.
pub fn train_step_ws(
    g: &Geo,
    params: &[&[f32]],
    moms: &[&[f32]],
    tokens: &[i32],
    lr: f32,
    b_full: usize,
    ws: &mut Workspace,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, f32) {
    let (loss, grads) = grad_step_ws(g, params, tokens, b_full, ws);
    let _sp = crate::obs::span("update");
    let n = params.len();
    let updated: Vec<(Vec<f32>, Vec<f32>)> = scope::par_map_vec(n, |i| {
        let (p, m, gr) = (params[i], moms[i], &grads[i]);
        let nm: Vec<f32> = m.iter().zip(gr).map(|(mv, gv)| TRAIN_STEP_MOMENTUM * mv + gv).collect();
        let np: Vec<f32> = p.iter().zip(&nm).map(|(pv, mv)| pv - lr * mv).collect();
        (np, nm)
    });
    ws.put_all(grads);
    let mut new_params = Vec::with_capacity(n);
    let mut new_moms = Vec::with_capacity(n);
    for (np, nm) in updated {
        new_params.push(np);
        new_moms.push(nm);
    }
    (new_params, new_moms, loss)
}

/// Fused single-process SGD+momentum step (allocating wrapper over
/// [`train_step_ws`]).
pub fn train_step(
    g: &Geo,
    params: &[&[f32]],
    moms: &[&[f32]],
    tokens: &[i32],
    lr: f32,
    b_full: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, f32) {
    train_step_ws(g, params, moms, tokens, lr, b_full, &mut Workspace::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::util::Rng;

    fn tiny_geo() -> Geo {
        Geo::from_cfg(&preset("tiny").unwrap())
    }

    fn rand_params(g: &Geo, l_blocks: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut shapes: Vec<usize> = vec![g.vocab * g.m];
        for _ in 0..l_blocks {
            shapes.extend([
                g.m,
                g.m * g.m,
                g.m * g.m,
                g.m * g.m,
                g.m * g.m,
                g.m,
                g.m * g.e,
                g.e * g.m * g.h,
                g.e * g.h * g.m,
            ]);
        }
        shapes.push(g.m);
        shapes
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal() as f32 * 0.15).collect())
            .collect()
    }

    #[test]
    fn capacity_matches_python_int_truncation() {
        let g = tiny_geo();
        // tiny: f=4, k=2, N=16, E=4 -> C(b) = 32 b
        assert_eq!(g.capacity(1), 32);
        assert_eq!(g.capacity(2), 64);
    }

    #[test]
    fn block_forward_is_deterministic_and_shaped() {
        let g = tiny_geo();
        let params = rand_params(&g, 1, 3);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let bp = BlockParams::new(&refs[1..10]);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..16 * g.m).map(|_| rng.normal() as f32 * 0.5).collect();
        let (y1, _) = block_forward(&g, &bp, &x, g.capacity(1));
        let (y2, _) = block_forward(&g, &bp, &x, g.capacity(1));
        assert_eq!(y1, y2);
        assert_eq!(y1.len(), x.len());
        assert!(y1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn workspace_reuse_is_bit_identical_to_fresh_buffers() {
        // the same block through a shared (dirty) workspace twice must
        // match the throwaway-workspace wrapper exactly
        let g = tiny_geo();
        let params = rand_params(&g, 1, 3);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let bp = BlockParams::new(&refs[1..10]);
        let mut rng = Rng::new(29);
        let x: Vec<f32> = (0..16 * g.m).map(|_| rng.normal() as f32 * 0.5).collect();
        let (want, _) = block_forward(&g, &bp, &x, g.capacity(1));
        let mut ws = Workspace::new();
        for round in 0..2 {
            let (y, st) = block_forward_ws(&g, &bp, &x, g.capacity(1), &mut ws);
            assert_eq!(y, want, "round {round}");
            st.recycle(&mut ws);
            ws.put(y);
            assert!(ws.pooled() > 0);
        }
    }

    #[test]
    fn grad_step_loss_near_uniform_at_random_init() {
        // random small params on vocab=128 => loss near ln(128) = 4.85
        let g = tiny_geo();
        let params = rand_params(&g, 2, 11);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(4);
        let tokens: Vec<i32> = (0..2 * g.n_seq).map(|_| rng.below(g.vocab) as i32).collect();
        let (loss, grads) = grad_step(&g, &refs, &tokens, 2);
        assert!(loss > 2.0 && loss < 8.0, "loss={loss}");
        assert_eq!(grads.len(), refs.len());
        for (gr, p) in grads.iter().zip(&params) {
            assert_eq!(gr.len(), p.len());
            assert!(gr.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn train_step_applies_sgd_with_momentum() {
        let g = tiny_geo();
        let params = rand_params(&g, 2, 13);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let moms: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let mrefs: Vec<&[f32]> = moms.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..2 * g.n_seq).map(|_| rng.below(g.vocab) as i32).collect();
        let lr = 0.05f32;
        let (new_p, new_m, loss) = train_step(&g, &refs, &mrefs, &tokens, lr, 2);
        let (loss_g, grads) = grad_step(&g, &refs, &tokens, 2);
        assert_eq!(loss, loss_g);
        // zero momentum: new_m == g and new_p == p - lr*g exactly
        for i in 0..refs.len() {
            assert_eq!(new_m[i], grads[i], "mom {i}");
            for ((np, p), gv) in new_p[i].iter().zip(&params[i]).zip(&grads[i]) {
                assert_eq!(*np, p - lr * gv);
            }
        }
    }

    #[test]
    fn microbatched_blocks_match_full_batch_drop_free() {
        // The Appendix-H identity the trainer relies on: with the tiny
        // config's generous capacity, running each microbatch through the
        // block equals running the concatenated batch (same per-token math).
        let g = tiny_geo();
        let params = rand_params(&g, 1, 7);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let bp = BlockParams::new(&refs[1..10]);
        let mut rng = Rng::new(21);
        let t_m = g.n_seq * g.m;
        let xa: Vec<f32> = (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect();
        let xb: Vec<f32> = (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect();
        let (ya, _) = block_forward(&g, &bp, &xa, g.capacity(1));
        let (yb, _) = block_forward(&g, &bp, &xb, g.capacity(1));
        let xfull: Vec<f32> = xa.iter().chain(&xb).cloned().collect();
        let (yfull, _) = block_forward(&g, &bp, &xfull, g.capacity(2));
        for (i, (want, got)) in ya.iter().chain(&yb).zip(&yfull).enumerate() {
            assert!((want - got).abs() < 1e-5, "elem {i}: {want} vs {got}");
        }
    }
}
