//! Native model pieces — host-side mirror of `python/compile/model.py`.
//!
//! Same architecture, same parameter order, same numerics: a pre-norm
//! decoder-only transformer whose feed-forward layers are MoE layers
//! (RMSNorm -> MHA -> residual -> RMSNorm -> top-k gate -> dispatch ->
//! expert FFN -> combine -> residual) with a tied-embedding LM head.
//! Routing reuses [`crate::cluster::dispatch`]/[`crate::cluster::combine`]
//! (the GShard mirror the EP path already ships) so the monolithic block
//! and the expert-parallel A2A path share one routing implementation.
//!
//! Backward passes rematerialize the forward (as the AOT `block_bwd`
//! artifact does) so no residual state crosses the caller boundary.

use crate::cluster::{combine, combine_bwd, dispatch, dispatch_bwd, Routing};

use super::kernels as kn;

/// Geometry of one model configuration (paper Table 2 notation).
#[derive(Clone, Copy, Debug)]
pub struct Geo {
    /// Embedding size M.
    pub m: usize,
    /// Experts per MoE layer E.
    pub e: usize,
    /// Expert hidden size H.
    pub h: usize,
    /// Top-k experts per token.
    pub top_k: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Tokens per sample N.
    pub n_seq: usize,
    /// Capacity factor f.
    pub f: f64,
    /// Vocabulary size.
    pub vocab: usize,
}

impl Geo {
    pub fn from_cfg(cfg: &crate::config::ModelCfg) -> Geo {
        Geo {
            m: cfg.m,
            e: cfg.e,
            h: cfg.h,
            top_k: cfg.k,
            n_heads: cfg.n_heads,
            n_seq: cfg.n,
            f: cfg.f,
            vocab: cfg.vocab,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.m / self.n_heads
    }

    /// GShard capacity for a batch of `b` samples: `int(f*k*b*N/E)`, at
    /// least 1 (python `int()` truncation, mirroring `MoEConfig.capacity`).
    pub fn capacity(&self, b: usize) -> usize {
        ((self.f * (self.top_k * b * self.n_seq) as f64 / self.e as f64) as usize).max(1)
    }
}

/// The 7 replicated (data-parallel) tensors of one block, canonical order.
#[derive(Clone, Copy)]
pub struct AtParams<'a> {
    pub n1: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub n2: &'a [f32],
    pub wg: &'a [f32],
}

impl<'a> AtParams<'a> {
    pub fn new(p: &[&'a [f32]]) -> AtParams<'a> {
        AtParams {
            n1: p[0],
            wq: p[1],
            wk: p[2],
            wv: p[3],
            wo: p[4],
            n2: p[5],
            wg: p[6],
        }
    }
}

/// All 9 tensors of one block: the AT part plus the expert weights.
#[derive(Clone, Copy)]
pub struct BlockParams<'a> {
    pub at: AtParams<'a>,
    pub w1: &'a [f32],
    pub w2: &'a [f32],
}

impl<'a> BlockParams<'a> {
    pub fn new(p: &[&'a [f32]]) -> BlockParams<'a> {
        BlockParams {
            at: AtParams::new(p),
            w1: p[7],
            w2: p[8],
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-head attention
// ---------------------------------------------------------------------------

/// Copy head `hh` of sample `bi` out of a flat `(T, M)` tensor into `(N, hd)`.
fn gather_head(xf: &[f32], bi: usize, hh: usize, n_seq: usize, m: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n_seq * hd];
    for i in 0..n_seq {
        let src = (bi * n_seq + i) * m + hh * hd;
        out[i * hd..(i + 1) * hd].copy_from_slice(&xf[src..src + hd]);
    }
    out
}

/// Inverse of [`gather_head`]: write `(N, hd)` back into the flat tensor.
fn scatter_head(xf: &mut [f32], o: &[f32], bi: usize, hh: usize, n_seq: usize, m: usize, hd: usize) {
    for i in 0..n_seq {
        let dst = (bi * n_seq + i) * m + hh * hd;
        xf[dst..dst + hd].copy_from_slice(&o[i * hd..(i + 1) * hd]);
    }
}

/// Saved forward state of [`mha_forward`] (consumed by the backward).
pub struct MhaState {
    xn: Vec<f32>,
    qf: Vec<f32>,
    kf: Vec<f32>,
    vf: Vec<f32>,
    /// Per-(sample, head) attention weight matrices `(N, N)`.
    att_w: Vec<Vec<f32>>,
    of: Vec<f32>,
    /// Residual-stream output `h = x + attn(x) @ wo`.
    pub h: Vec<f32>,
}

/// Multi-head causal attention over flat `(T, M)` tokens (model.py `mha`).
pub fn mha_forward(g: &Geo, p: &AtParams, x: &[f32]) -> MhaState {
    let t = x.len() / g.m;
    let b = t / g.n_seq;
    let hd = g.head_dim();
    let xn = kn::rmsnorm(x, p.n1);
    let qf = kn::matmul(&xn, p.wq, t, g.m, g.m);
    let kf = kn::matmul(&xn, p.wk, t, g.m, g.m);
    let vf = kn::matmul(&xn, p.wv, t, g.m, g.m);
    let mut of = vec![0.0f32; t * g.m];
    let mut att_w = Vec::with_capacity(b * g.n_heads);
    for bi in 0..b {
        for hh in 0..g.n_heads {
            let q = gather_head(&qf, bi, hh, g.n_seq, g.m, hd);
            let k = gather_head(&kf, bi, hh, g.n_seq, g.m, hd);
            let v = gather_head(&vf, bi, hh, g.n_seq, g.m, hd);
            let (w, o) = kn::attention_causal(&q, &k, &v, g.n_seq, hd);
            scatter_head(&mut of, &o, bi, hh, g.n_seq, g.m, hd);
            att_w.push(w);
        }
    }
    let proj = kn::matmul(&of, p.wo, t, g.m, g.m);
    let h: Vec<f32> = x.iter().zip(&proj).map(|(a, b)| a + b).collect();
    MhaState {
        xn,
        qf,
        kf,
        vf,
        att_w,
        of,
        h,
    }
}

/// Backward of [`mha_forward`]: returns `([dn1, dwq, dwk, dwv, dwo], dx)`
/// for the residual-stream cotangent `dh`.
pub fn mha_backward(g: &Geo, p: &AtParams, x: &[f32], st: &MhaState, dh: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
    let t = x.len() / g.m;
    let b = t / g.n_seq;
    let hd = g.head_dim();
    // h = x + of @ wo
    let dof = kn::matmul_nt(dh, p.wo, t, g.m, g.m);
    let dwo = kn::matmul_tn(&st.of, dh, t, g.m, g.m);
    let mut dqf = vec![0.0f32; t * g.m];
    let mut dkf = vec![0.0f32; t * g.m];
    let mut dvf = vec![0.0f32; t * g.m];
    for bi in 0..b {
        for hh in 0..g.n_heads {
            let q = gather_head(&st.qf, bi, hh, g.n_seq, g.m, hd);
            let k = gather_head(&st.kf, bi, hh, g.n_seq, g.m, hd);
            let v = gather_head(&st.vf, bi, hh, g.n_seq, g.m, hd);
            let doh = gather_head(&dof, bi, hh, g.n_seq, g.m, hd);
            let w = &st.att_w[bi * g.n_heads + hh];
            let (dq, dk, dv) = kn::attention_causal_bwd(&q, &k, &v, w, &doh, g.n_seq, hd);
            scatter_head(&mut dqf, &dq, bi, hh, g.n_seq, g.m, hd);
            scatter_head(&mut dkf, &dk, bi, hh, g.n_seq, g.m, hd);
            scatter_head(&mut dvf, &dv, bi, hh, g.n_seq, g.m, hd);
        }
    }
    let dwq = kn::matmul_tn(&st.xn, &dqf, t, g.m, g.m);
    let dwk = kn::matmul_tn(&st.xn, &dkf, t, g.m, g.m);
    let dwv = kn::matmul_tn(&st.xn, &dvf, t, g.m, g.m);
    let mut dxn = kn::matmul_nt(&dqf, p.wq, t, g.m, g.m);
    let dxn_k = kn::matmul_nt(&dkf, p.wk, t, g.m, g.m);
    let dxn_v = kn::matmul_nt(&dvf, p.wv, t, g.m, g.m);
    for ((a, b_), c) in dxn.iter_mut().zip(&dxn_k).zip(&dxn_v) {
        *a += b_ + c;
    }
    let (dx_norm, dn1) = kn::rmsnorm_bwd(x, p.n1, &dxn);
    let dx: Vec<f32> = dh.iter().zip(&dx_norm).map(|(a, b)| a + b).collect();
    (vec![dn1, dwq, dwk, dwv, dwo], dx)
}

// ---------------------------------------------------------------------------
// AT piece (MHA + gating) and the full transformer block
// ---------------------------------------------------------------------------

/// Saved forward state of [`at_forward`].
pub struct AtState {
    pub mha: MhaState,
    /// Normed MoE input `u = rmsnorm(h, n2)`.
    pub u: Vec<f32>,
    pub gating: kn::Gating,
}

/// The paper's AT task (model.py `at_task`): MHA + gating for one
/// (micro)batch of flat `(T, M)` tokens.
pub fn at_forward(g: &Geo, p: &AtParams, x: &[f32]) -> AtState {
    let t = x.len() / g.m;
    let mha = mha_forward(g, p, x);
    let u = kn::rmsnorm(&mha.h, p.n2);
    let logits = kn::matmul(&u, p.wg, t, g.m, g.e);
    let gating = kn::gating_topk(&logits, g.e, g.top_k);
    AtState { mha, u, gating }
}

/// Backward of [`at_forward`] with cotangents for its `(h, u, gate)`
/// outputs (model.py `at_bwd`; the probs output is a non-differentiated
/// auxiliary). Returns `([dn1, dwq, dwk, dwv, dwo, dn2, dwg], dx)`.
pub fn at_backward(
    g: &Geo,
    p: &AtParams,
    x: &[f32],
    st: &AtState,
    dh: &[f32],
    du: &[f32],
    dgate: &[f32],
) -> (Vec<Vec<f32>>, Vec<f32>) {
    let t = x.len() / g.m;
    let dlogits = kn::gating_topk_bwd(&st.gating, g.e, g.top_k, dgate);
    let dwg = kn::matmul_tn(&st.u, &dlogits, t, g.m, g.e);
    let mut du_int = kn::matmul_nt(&dlogits, p.wg, t, g.e, g.m);
    for (a, b) in du_int.iter_mut().zip(du) {
        *a += b;
    }
    let (dh_norm, dn2) = kn::rmsnorm_bwd(&st.mha.h, p.n2, &du_int);
    let dh_tot: Vec<f32> = dh.iter().zip(&dh_norm).map(|(a, b)| a + b).collect();
    let (mut grads, dx) = mha_backward(g, p, x, &st.mha, &dh_tot);
    grads.push(dn2);
    grads.push(dwg);
    (grads, dx)
}

/// Saved forward state of [`block_forward`].
pub struct BlockState {
    pub at: AtState,
    pub routing: Routing,
    pub expert_out: Vec<f32>,
}

/// One transformer block forward over flat `(T, M)` activations with
/// per-expert capacity `c` (model.py `block_fwd`). Returns `(y, state)`.
pub fn block_forward(g: &Geo, p: &BlockParams, x: &[f32], c: usize) -> (Vec<f32>, BlockState) {
    let at = at_forward(g, &p.at, x);
    let routing = dispatch(&at.u, &at.gating.idx, at.gating.gate.len(), g.e, c, g.m);
    let expert_out = kn::expert_ffn(&routing.disp, p.w1, p.w2, g.e, c, g.m, g.h);
    let yc = combine(&expert_out, &routing, &at.gating.gate);
    let y: Vec<f32> = at.mha.h.iter().zip(&yc).map(|(a, b)| a + b).collect();
    (
        y,
        BlockState {
            at,
            routing,
            expert_out,
        },
    )
}

/// Recompute-based VJP of one block (model.py `block_bwd`): returns the
/// 9 parameter grads in canonical order plus `dx`.
pub fn block_backward(g: &Geo, p: &BlockParams, x: &[f32], c: usize, dy: &[f32]) -> (Vec<Vec<f32>>, Vec<f32>) {
    let (_, st) = block_forward(g, p, x, c);
    let (dout, dgate) = combine_bwd(dy, &st.expert_out, &st.routing, &st.at.gating.gate);
    let (ddisp, dw1, dw2) = kn::expert_ffn_bwd(&st.routing.disp, p.w1, p.w2, &dout, g.e, c, g.m, g.h);
    let du = dispatch_bwd(&ddisp, &st.routing);
    let (mut grads, dx) = at_backward(g, &p.at, x, &st.at, dy, &du, &dgate);
    grads.push(dw1);
    grads.push(dw2);
    (grads, dx)
}

// ---------------------------------------------------------------------------
// Embedding / LM head / loss
// ---------------------------------------------------------------------------

/// Final norm + tied LM head + next-token cross-entropy, fused fwd+bwd
/// (model.py `head_loss_fwd_bwd`). Returns `(loss, dxf, dembed, dnormf)`.
pub fn head_loss(
    g: &Geo,
    embed: &[f32],
    normf: &[f32],
    xf: &[f32],
    tokens: &[i32],
    b: usize,
) -> (f32, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (n, m, v) = (g.n_seq, g.m, g.vocab);
    let t = b * n;
    let xn = kn::rmsnorm(xf, normf);
    let logits = kn::matmul_nt(&xn, embed, t, m, v);
    let count = (b * (n - 1)) as f32;
    let mut loss = 0.0f64;
    let mut dlogits = vec![0.0f32; t * v];
    for bi in 0..b {
        for pos in 0..n - 1 {
            let ti = bi * n + pos;
            let row = &logits[ti * v..(ti + 1) * v];
            let target = tokens[bi * n + pos + 1] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sumexp: f32 = row.iter().map(|&l| (l - mx).exp()).sum();
            let logz = mx + sumexp.ln();
            loss += (logz - row[target]) as f64;
            let drow = &mut dlogits[ti * v..(ti + 1) * v];
            for (j, (dv, &l)) in drow.iter_mut().zip(row).enumerate() {
                let p = (l - logz).exp();
                *dv = (p - if j == target { 1.0 } else { 0.0 }) / count;
            }
        }
    }
    let loss = (loss / count as f64) as f32;
    let dxn = kn::matmul(&dlogits, embed, t, v, m);
    let dembed = kn::matmul_tn(&dlogits, &xn, t, v, m);
    let (dxf, dnormf) = kn::rmsnorm_bwd(xf, normf, &dxn);
    (loss, dxf, dembed, dnormf)
}

// ---------------------------------------------------------------------------
// Fused train/grad step over the whole parameter list
// ---------------------------------------------------------------------------

/// Per-worker full-model gradient (model.py `grad_step`): forward through
/// all blocks, head loss, full backward. `params` is the canonical flat
/// list (embed, L x 9 block tensors, normf). Returns `(loss, grads)` with
/// the tied embedding gradient already summed (input lookup + LM head).
pub fn grad_step(g: &Geo, params: &[&[f32]], tokens: &[i32], b_full: usize) -> (f32, Vec<Vec<f32>>) {
    let n_params = params.len();
    let l_blocks = (n_params - 2) / 9;
    let c = g.capacity(b_full);
    let blocks: Vec<BlockParams> = (0..l_blocks)
        .map(|l| BlockParams::new(&params[1 + l * 9..1 + (l + 1) * 9]))
        .collect();

    let mut xs = Vec::with_capacity(l_blocks + 1);
    xs.push(kn::embed_lookup(params[0], tokens, g.m));
    for bp in &blocks {
        let (y, _) = block_forward(g, bp, xs.last().unwrap(), c);
        xs.push(y);
    }
    let (loss, dxf, de_head, dnormf) = head_loss(g, params[0], params[n_params - 1], &xs[l_blocks], tokens, b_full);

    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); n_params];
    let mut dx = dxf;
    for l in (0..l_blocks).rev() {
        let (bg, dx_next) = block_backward(g, &blocks[l], &xs[l], c, &dx);
        for (ti, gt) in bg.into_iter().enumerate() {
            grads[1 + l * 9 + ti] = gt;
        }
        dx = dx_next;
    }
    let mut de = kn::embed_scatter(tokens, &dx, g.vocab, g.m);
    for (a, b) in de.iter_mut().zip(&de_head) {
        *a += b;
    }
    grads[0] = de;
    grads[n_params - 1] = dnormf;
    (loss, grads)
}

/// Momentum coefficient baked into the fused `train_step` artifact
/// (aot.py lowers `model.train_step` at its default `momentum=0.9`).
pub const TRAIN_STEP_MOMENTUM: f32 = 0.9;

/// Fused single-process SGD+momentum step (model.py `train_step`):
/// returns `(new_params, new_moms, loss)`.
pub fn train_step(
    g: &Geo,
    params: &[&[f32]],
    moms: &[&[f32]],
    tokens: &[i32],
    lr: f32,
    b_full: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, f32) {
    let (loss, grads) = grad_step(g, params, tokens, b_full);
    let mut new_params = Vec::with_capacity(params.len());
    let mut new_moms = Vec::with_capacity(params.len());
    for ((p, m), gr) in params.iter().zip(moms).zip(&grads) {
        let nm: Vec<f32> = m.iter().zip(gr).map(|(mv, gv)| TRAIN_STEP_MOMENTUM * mv + gv).collect();
        let np: Vec<f32> = p.iter().zip(&nm).map(|(pv, mv)| pv - lr * mv).collect();
        new_params.push(np);
        new_moms.push(nm);
    }
    (new_params, new_moms, loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::util::Rng;

    fn tiny_geo() -> Geo {
        Geo::from_cfg(&preset("tiny").unwrap())
    }

    fn rand_params(g: &Geo, l_blocks: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut shapes: Vec<usize> = vec![g.vocab * g.m];
        for _ in 0..l_blocks {
            shapes.extend([
                g.m,
                g.m * g.m,
                g.m * g.m,
                g.m * g.m,
                g.m * g.m,
                g.m,
                g.m * g.e,
                g.e * g.m * g.h,
                g.e * g.h * g.m,
            ]);
        }
        shapes.push(g.m);
        shapes
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal() as f32 * 0.15).collect())
            .collect()
    }

    #[test]
    fn capacity_matches_python_int_truncation() {
        let g = tiny_geo();
        // tiny: f=4, k=2, N=16, E=4 -> C(b) = 32 b
        assert_eq!(g.capacity(1), 32);
        assert_eq!(g.capacity(2), 64);
    }

    #[test]
    fn block_forward_is_deterministic_and_shaped() {
        let g = tiny_geo();
        let params = rand_params(&g, 1, 3);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let bp = BlockParams::new(&refs[1..10]);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..16 * g.m).map(|_| rng.normal() as f32 * 0.5).collect();
        let (y1, _) = block_forward(&g, &bp, &x, g.capacity(1));
        let (y2, _) = block_forward(&g, &bp, &x, g.capacity(1));
        assert_eq!(y1, y2);
        assert_eq!(y1.len(), x.len());
        assert!(y1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn grad_step_loss_near_uniform_at_random_init() {
        // random small params on vocab=128 => loss near ln(128) = 4.85
        let g = tiny_geo();
        let params = rand_params(&g, 2, 11);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(4);
        let tokens: Vec<i32> = (0..2 * g.n_seq).map(|_| rng.below(g.vocab) as i32).collect();
        let (loss, grads) = grad_step(&g, &refs, &tokens, 2);
        assert!(loss > 2.0 && loss < 8.0, "loss={loss}");
        assert_eq!(grads.len(), refs.len());
        for (gr, p) in grads.iter().zip(&params) {
            assert_eq!(gr.len(), p.len());
            assert!(gr.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn train_step_applies_sgd_with_momentum() {
        let g = tiny_geo();
        let params = rand_params(&g, 2, 13);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let moms: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let mrefs: Vec<&[f32]> = moms.iter().map(|v| v.as_slice()).collect();
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..2 * g.n_seq).map(|_| rng.below(g.vocab) as i32).collect();
        let lr = 0.05f32;
        let (new_p, new_m, loss) = train_step(&g, &refs, &mrefs, &tokens, lr, 2);
        let (loss_g, grads) = grad_step(&g, &refs, &tokens, 2);
        assert_eq!(loss, loss_g);
        // zero momentum: new_m == g and new_p == p - lr*g exactly
        for i in 0..refs.len() {
            assert_eq!(new_m[i], grads[i], "mom {i}");
            for ((np, p), gv) in new_p[i].iter().zip(&params[i]).zip(&grads[i]) {
                assert_eq!(*np, p - lr * gv);
            }
        }
    }

    #[test]
    fn microbatched_blocks_match_full_batch_drop_free() {
        // The Appendix-H identity the trainer relies on: with the tiny
        // config's generous capacity, running each microbatch through the
        // block equals running the concatenated batch (same per-token math).
        let g = tiny_geo();
        let params = rand_params(&g, 1, 7);
        let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        let bp = BlockParams::new(&refs[1..10]);
        let mut rng = Rng::new(21);
        let t_m = g.n_seq * g.m;
        let xa: Vec<f32> = (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect();
        let xb: Vec<f32> = (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect();
        let (ya, _) = block_forward(&g, &bp, &xa, g.capacity(1));
        let (yb, _) = block_forward(&g, &bp, &xb, g.capacity(1));
        let xfull: Vec<f32> = xa.iter().chain(&xb).cloned().collect();
        let (yfull, _) = block_forward(&g, &bp, &xfull, g.capacity(2));
        for (i, (want, got)) in ya.iter().chain(&yb).zip(&yfull).enumerate() {
            assert!((want - got).abs() < 1e-5, "elem {i}: {want} vs {got}");
        }
    }
}
