//! Dense f32 CPU kernels for the native execution backend.
//!
//! Every op here is an exact host-side mirror of a `python/compile`
//! primitive (`kernels/ref.py` semantics): same masking constants, same
//! epsilons, same tie-breaking, so a native run is numerically
//! interchangeable with an artifact run up to summation order. Each
//! forward has a hand-derived backward; `tests/gradcheck_native.rs`
//! checks every pair against central finite differences.
//!
//! Shapes are row-major flat `&[f32]` slices; dimensions are passed
//! explicitly (the backend derives them from the artifact manifest).
//!
//! # Performance (§Perf)
//!
//! Every hot kernel routes through one **dispatch chooser**
//! ([`Dispatch`], selected by the `FLOWMOE_KERNELS` env var or a
//! thread-local [`with_dispatch`] override) with three tiers:
//!
//! * `naive` — the reference triple loops (the `*_ref` oracles run as
//!   the production kernel; debugging tier).
//! * `blocked` — cache-blocked micro-kernels: a 4-row (`MR`) band
//!   accumulates into register/L1-resident output rows while one
//!   `NC`-wide stripe of `b` streams through (4x reuse of every `b`
//!   load, four independent accumulation chains per column); the
//!   `matmul_nt` dot-product variant uses a 4x4 register tile.
//! * `simd` — explicit f32x8 vectorization: AVX2+FMA intrinsics
//!   (`std::arch::x86_64`, selected by runtime feature detection) with a
//!   portable 8-lane-unrolled scalar fallback on other hosts. Large
//!   `matmul_nt` additionally packs `b` into 8-wide column panels
//!   (optionally [`Workspace`]-pooled, see [`par_matmul_nt_into_ws`]) so
//!   the LM-head and expert GEMMs stream one contiguous panel instead of
//!   striding cold rows. Softmax/RMSNorm/cross-entropy reductions use
//!   8-lane accumulators with a fixed lane-combine order.
//!
//! `FLOWMOE_KERNELS=auto` (the default) resolves to `simd` when AVX2+FMA
//! is detected and `blocked` otherwise; requesting `simd` explicitly on
//! a host without AVX2 is an **error**, not a silent scalar fallback.
//! `par_*` variants split the M dimension into contiguous row bands
//! across [`crate::sweep::scope`]'s thread budget;
//! `expert_ffn`/`expert_ffn_bwd` fan the expert axis out the same way.
//!
//! Numerics contract: parity with the naive `*_ref` kernels is
//! **tolerance-based** (tests use 1e-4 rel-tol). The `simd` tier
//! exercises that freedom: FMA contraction and 8-lane reductions
//! reassociate/re-round relative to the scalar tiers. What **is**
//! guaranteed: every kernel is deterministic *within a fixed dispatch
//! tier on a fixed host*, each row's result is independent of the row
//! banding, and therefore parallel results are byte-identical to serial
//! results for any thread budget (asserted by `perf_hotpath`,
//! `tests/kernel_parity.rs` and `tests/kernel_conformance.rs`).

use std::cell::Cell;
use std::sync::OnceLock;

use crate::sweep::scope;

use super::workspace::Workspace;

/// Output rows per micro-kernel tile (register blocking).
const MR: usize = 4;
/// Column-stripe width: `MR` output-row stripes of `NC` f32 stay L1-hot
/// while `b` streams through.
const NC: usize = 512;
/// Work threshold (in `m*k*n` multiply-adds) below which the `par_*`
/// wrappers stay serial: spawning scoped threads costs tens of
/// microseconds, so only matmuls of ~ms scale fan out.
const PAR_MIN_MACS: usize = 1 << 18;
/// SIMD lane count of the f32x8 tier (AVX2 register width).
const L: usize = 8;
/// Minimum M rows for the packed-B `matmul_nt` path: packing costs one
/// pass over `b`, amortized across the row loop.
const NT_PACK_MIN_ROWS: usize = 8;
/// Minimum `k*n` (elements of `b`) for the packed-B `matmul_nt` path;
/// below this `b` is L1/L2-resident anyway and the dot-product kernel
/// wins.
const NT_PACK_MIN_BN: usize = 1 << 12;

// ---------------------------------------------------------------------------
// Kernel dispatch: naive / blocked / simd, env-selected, overridable
// ---------------------------------------------------------------------------

/// Kernel implementation tier. See the module docs (§Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// Reference triple loops (`*_ref` semantics).
    Naive,
    /// Cache-blocked scalar micro-kernels.
    Blocked,
    /// Explicit f32x8: AVX2+FMA when detected, 8-lane portable fallback
    /// otherwise (reachable via [`with_dispatch`]; the env knob refuses
    /// `simd` without AVX2 — see [`resolve_dispatch`]).
    Simd,
}

impl Dispatch {
    /// Stable lowercase name (matches the `FLOWMOE_KERNELS` values).
    pub fn name(self) -> &'static str {
        match self {
            Dispatch::Naive => "naive",
            Dispatch::Blocked => "blocked",
            Dispatch::Simd => "simd",
        }
    }
}

/// Whether the AVX2+FMA fast path is available on this host (runtime
/// feature detection; always `false` off x86_64).
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Parse a `FLOWMOE_KERNELS` value: `Ok(None)` = auto (unset/empty also
/// count), `Ok(Some(tier))` = forced tier, `Err` = unrecognized value.
pub fn parse_kernels(val: &str) -> Result<Option<Dispatch>, String> {
    match val.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => Ok(None),
        "naive" => Ok(Some(Dispatch::Naive)),
        "blocked" => Ok(Some(Dispatch::Blocked)),
        "simd" => Ok(Some(Dispatch::Simd)),
        other => Err(format!(
            "invalid FLOWMOE_KERNELS value {other:?}: expected auto, simd, blocked or naive"
        )),
    }
}

/// Resolve a parsed `FLOWMOE_KERNELS` choice against host capabilities.
/// `auto` picks `simd` iff AVX2+FMA is detected; an explicit `simd`
/// request without AVX2 errors (no silent scalar fallback — the caller
/// asked for a specific performance tier).
pub fn resolve_dispatch(choice: Option<Dispatch>, avx2: bool) -> Result<Dispatch, String> {
    match choice {
        None => Ok(if avx2 { Dispatch::Simd } else { Dispatch::Blocked }),
        Some(Dispatch::Simd) if !avx2 => Err(
            "FLOWMOE_KERNELS=simd requested but AVX2+FMA was not detected on this host; \
             use FLOWMOE_KERNELS=auto (runtime detection) or FLOWMOE_KERNELS=blocked"
            .to_string(),
        ),
        Some(d) => Ok(d),
    }
}

/// Process-wide dispatch from the `FLOWMOE_KERNELS` env var (read once).
/// Errors — an unrecognized value, or `simd` forced on a non-AVX2 host —
/// are returned so the CLI can exit cleanly; library callers go through
/// [`default_dispatch`], which panics with the same message.
pub fn configured_dispatch() -> Result<Dispatch, String> {
    static CONFIGURED: OnceLock<Result<Dispatch, String>> = OnceLock::new();
    CONFIGURED
        .get_or_init(|| {
            let raw = std::env::var("FLOWMOE_KERNELS").unwrap_or_default();
            resolve_dispatch(parse_kernels(&raw)?, avx2_available())
        })
        .clone()
}

/// Process-wide dispatch (see [`configured_dispatch`]); panics with a
/// clear message on an invalid `FLOWMOE_KERNELS` request.
pub fn default_dispatch() -> Dispatch {
    configured_dispatch().unwrap_or_else(|e| panic!("{e}"))
}

thread_local! {
    static LOCAL_DISPATCH: Cell<Option<Dispatch>> = const { Cell::new(None) };
}

/// Restores the previous thread-local dispatch on drop (panic-safe).
struct DispatchGuard {
    prev: Option<Dispatch>,
}

impl DispatchGuard {
    fn set(d: Dispatch) -> DispatchGuard {
        let prev = LOCAL_DISPATCH.with(|c| {
            let p = c.get();
            c.set(Some(d));
            p
        });
        DispatchGuard { prev }
    }
}

impl Drop for DispatchGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        LOCAL_DISPATCH.with(|c| c.set(prev));
    }
}

/// Dispatch tier of the calling thread: the innermost [`with_dispatch`]
/// override, else the env-selected [`default_dispatch`].
pub fn active_dispatch() -> Dispatch {
    LOCAL_DISPATCH.with(|c| c.get()).unwrap_or_else(default_dispatch)
}

/// Run `f` with the calling thread's kernel dispatch overridden (tests,
/// benches, and the fan-out points that must propagate the caller's tier
/// into [`scope`] worker threads). Unlike the env knob, forcing
/// [`Dispatch::Simd`] here is allowed on any host: it runs the portable
/// 8-lane fallback when AVX2 is unavailable.
pub fn with_dispatch<R>(d: Dispatch, f: impl FnOnce() -> R) -> R {
    let _guard = DispatchGuard::set(d);
    f()
}

// ---------------------------------------------------------------------------
// Reference (naive) matmuls — the parity oracle for the blocked kernels
// ---------------------------------------------------------------------------

/// Naive `a (m,k) @ b (k,n) -> (m,n)` triple loop (reference oracle).
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Naive `a (m,k) @ b^T` with `b (n,k)` -> `(m,n)` (reference oracle).
pub fn matmul_nt_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
    out
}

/// Naive `a^T @ b` with `a (k,m)`, `b (k,n)` -> `(m,n)` (reference oracle).
pub fn matmul_tn_ref(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Blocked band kernels (the f32 micro-kernels)
// ---------------------------------------------------------------------------

/// Blocked `a_band (rows,k) @ b (k,n)` into `out (rows,n)`; `a` holds
/// exactly the band's rows. Row results do not depend on the banding.
fn mm_band(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(b.len(), k * n);
    out.fill(0.0);
    let mut i = 0;
    while i + MR <= rows {
        let band = &mut out[i * n..(i + MR) * n];
        let (r0, band) = band.split_at_mut(n);
        let (r1, band) = band.split_at_mut(n);
        let (r2, r3) = band.split_at_mut(n);
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for p in 0..k {
                let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
                let brow = &b[p * n + j0..p * n + jn];
                let cols = r0[j0..jn]
                    .iter_mut()
                    .zip(r1[j0..jn].iter_mut())
                    .zip(r2[j0..jn].iter_mut())
                    .zip(r3[j0..jn].iter_mut())
                    .zip(brow);
                for ((((o0, o1), o2), o3), &bv) in cols {
                    *o0 += v0 * bv;
                    *o1 += v1 * bv;
                    *o2 += v2 * bv;
                    *o3 += v3 * bv;
                }
            }
            j0 = jn;
        }
        i += MR;
    }
    while i < rows {
        let r = &mut out[i * n..(i + 1) * n];
        let arow = &a[i * k..(i + 1) * k];
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * n + j0..p * n + jn];
                for (o, &bv) in r[j0..jn].iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            j0 = jn;
        }
        i += 1;
    }
}

/// Blocked `a_band (rows,k) @ b^T` with `b (n,k)` into `out (rows,n)`:
/// 4x4 register tiles, 16 independent accumulator chains.
fn nt_band(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(b.len(), n * k);
    let mut i = 0;
    while i < rows {
        let mr = MR.min(rows - i);
        let mut j = 0;
        while j < n {
            let nr = MR.min(n - j);
            if mr == MR && nr == MR {
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                let mut acc = [[0.0f32; MR]; MR];
                for p in 0..k {
                    let av = [a0[p], a1[p], a2[p], a3[p]];
                    let bv = [b0[p], b1[p], b2[p], b3[p]];
                    for (accr, &avv) in acc.iter_mut().zip(&av) {
                        for (s, &bvv) in accr.iter_mut().zip(&bv) {
                            *s += avv * bvv;
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    out[(i + r) * n + j..(i + r) * n + j + MR].copy_from_slice(accr);
                }
            } else {
                for r in 0..mr {
                    let arow = &a[(i + r) * k..(i + r + 1) * k];
                    for c in 0..nr {
                        let brow = &b[(j + c) * k..(j + c + 1) * k];
                        out[(i + r) * n + j + c] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// Blocked `a^T @ b` band: `out` holds rows `col0..col0+rows` of the
/// `(m,n)` product with `a (k,m)`, `b (k,n)`. Columns `col0+i..col0+i+4`
/// of `a` are contiguous per `p`-row, so the same 4-row micro-kernel as
/// [`mm_band`] applies.
fn tn_band(a: &[f32], b: &[f32], out: &mut [f32], col0: usize, k: usize, m: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    out.fill(0.0);
    let mut i = 0;
    while i + MR <= rows {
        let band = &mut out[i * n..(i + MR) * n];
        let (r0, band) = band.split_at_mut(n);
        let (r1, band) = band.split_at_mut(n);
        let (r2, r3) = band.split_at_mut(n);
        let c = col0 + i;
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for p in 0..k {
                let av = &a[p * m + c..p * m + c + MR];
                let (v0, v1, v2, v3) = (av[0], av[1], av[2], av[3]);
                let brow = &b[p * n + j0..p * n + jn];
                let cols = r0[j0..jn]
                    .iter_mut()
                    .zip(r1[j0..jn].iter_mut())
                    .zip(r2[j0..jn].iter_mut())
                    .zip(r3[j0..jn].iter_mut())
                    .zip(brow);
                for ((((o0, o1), o2), o3), &bv) in cols {
                    *o0 += v0 * bv;
                    *o1 += v1 * bv;
                    *o2 += v2 * bv;
                    *o3 += v3 * bv;
                }
            }
            j0 = jn;
        }
        i += MR;
    }
    while i < rows {
        let r = &mut out[i * n..(i + 1) * n];
        let c = col0 + i;
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for p in 0..k {
                let av = a[p * m + c];
                let brow = &b[p * n + j0..p * n + jn];
                for (o, &bv) in r[j0..jn].iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            j0 = jn;
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Naive band kernels (the `naive` dispatch tier: `*_ref` semantics per band)
// ---------------------------------------------------------------------------

/// Naive `a_band (rows,k) @ b (k,n)` into `out` — per-band mirror of
/// [`matmul_ref`] (bitwise-equal accumulation order).
fn mm_band_naive(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    out.fill(0.0);
    for (orow, arow) in out.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
        for (p, &av) in arow.iter().enumerate() {
            for (o, &bv) in orow.iter_mut().zip(&b[p * n..(p + 1) * n]) {
                *o += av * bv;
            }
        }
    }
}

/// Naive `a_band (rows,k) @ b^T`, `b (n,k)`, into `out` — per-band
/// mirror of [`matmul_nt_ref`].
fn nt_band_naive(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    for (orow, arow) in out.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
        for (o, brow) in orow.iter_mut().zip(b.chunks_exact(k)) {
            *o = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
}

/// Naive `a^T @ b` band (output rows `col0..col0+rows`) — per-element
/// mirror of [`matmul_tn_ref`] (accumulation ascending in `p`).
fn tn_band_naive(a: &[f32], b: &[f32], out: &mut [f32], col0: usize, k: usize, m: usize, n: usize) {
    if n == 0 {
        return;
    }
    out.fill(0.0);
    for p in 0..k {
        let brow = &b[p * n..(p + 1) * n];
        for (i, orow) in out.chunks_exact_mut(n).enumerate() {
            let av = a[p * m + col0 + i];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// f32x8 tier: portable 8-lane kernels + AVX2/FMA intrinsics twins
// ---------------------------------------------------------------------------

/// Fixed lane-combine order shared by the portable and AVX2 reducers, so
/// both produce the same reduction tree (only FMA rounding differs).
#[inline]
fn hsum8(l: &[f32; L]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Pack `b (n,k)` row-major into 8-wide column panels for the packed
/// `matmul_nt` micro-kernel: group `g` covers b-rows (output columns)
/// `8g..8g+8` and stores `packed[g*k*8 + p*8 + c] = b[(8g+c)*k + p]`,
/// zero-filling the padded tail columns, so the kernel streams one
/// contiguous unit-stride panel per column group.
fn pack_b_nt(b: &[f32], k: usize, n: usize, packed: &mut [f32]) {
    let groups = n.div_ceil(L);
    debug_assert!(packed.len() >= groups * k * L);
    for g in 0..groups {
        let block = &mut packed[g * k * L..(g + 1) * k * L];
        for c in 0..L {
            let col = g * L + c;
            if col < n {
                let src = &b[col * k..(col + 1) * k];
                for (&v, slot) in src.iter().zip(block[c..].iter_mut().step_by(L)) {
                    *slot = v;
                }
            } else {
                for slot in block[c..].iter_mut().step_by(L) {
                    *slot = 0.0;
                }
            }
        }
    }
}

/// Portable 8-lane-unrolled kernels — the `simd` tier on hosts without
/// AVX2 (and the behavioural model for the intrinsics twins in [`avx2`]):
/// same loop structure, same fixed lane-combine order, separate mul+add
/// where AVX2 uses FMA.
mod lanes {
    use super::{hsum8, L, MR};

    /// `acc += a * x`, 8 lanes at a time (element-exact vs the scalar
    /// loop: per-element order is unchanged).
    pub fn axpy(acc: &mut [f32], x: &[f32], a: f32) {
        let mut ac = acc.chunks_exact_mut(L);
        let mut xc = x.chunks_exact(L);
        for (av, xv) in (&mut ac).zip(&mut xc) {
            for (s, &v) in av.iter_mut().zip(xv) {
                *s += a * v;
            }
        }
        for (s, &v) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
            *s += a * v;
        }
    }

    /// `v *= s`, 8 lanes at a time.
    pub fn scale(v: &mut [f32], s: f32) {
        let mut vc = v.chunks_exact_mut(L);
        for c in &mut vc {
            for x in c.iter_mut() {
                *x *= s;
            }
        }
        for x in vc.into_remainder().iter_mut() {
            *x *= s;
        }
    }

    /// `v = max(v, 0)` (elementwise; simple enough that the
    /// autovectorizer handles the lanes).
    pub fn relu(v: &mut [f32]) {
        for x in v.iter_mut() {
            *x = x.max(0.0);
        }
    }

    pub fn sum(x: &[f32]) -> f32 {
        let mut acc = [0.0f32; L];
        let mut c = x.chunks_exact(L);
        for ch in &mut c {
            for (a, &v) in acc.iter_mut().zip(ch) {
                *a += v;
            }
        }
        let mut s = hsum8(&acc);
        for &v in c.remainder() {
            s += v;
        }
        s
    }

    pub fn max(x: &[f32]) -> f32 {
        let mut acc = [f32::NEG_INFINITY; L];
        let mut c = x.chunks_exact(L);
        for ch in &mut c {
            for (a, &v) in acc.iter_mut().zip(ch) {
                *a = a.max(v);
            }
        }
        let mut m = acc.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for &v in c.remainder() {
            m = m.max(v);
        }
        m
    }

    pub fn dot(x: &[f32], y: &[f32]) -> f32 {
        let mut acc = [0.0f32; L];
        let mut xc = x.chunks_exact(L);
        let mut yc = y.chunks_exact(L);
        for (xv, yv) in (&mut xc).zip(&mut yc) {
            for ((a, &xe), &ye) in acc.iter_mut().zip(xv).zip(yv) {
                *a += xe * ye;
            }
        }
        let mut s = hsum8(&acc);
        for (&xe, &ye) in xc.remainder().iter().zip(yc.remainder()) {
            s += xe * ye;
        }
        s
    }

    pub fn sum_sq(x: &[f32]) -> f32 {
        let mut acc = [0.0f32; L];
        let mut c = x.chunks_exact(L);
        for ch in &mut c {
            for (a, &v) in acc.iter_mut().zip(ch) {
                *a += v * v;
            }
        }
        let mut s = hsum8(&acc);
        for &v in c.remainder() {
            s += v * v;
        }
        s
    }

    /// `sum_i (a_i * b_i) * c_i` (rmsnorm backward's weighted dot).
    pub fn dot3(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
        let mut acc = [0.0f32; L];
        let mut ac = a.chunks_exact(L);
        let mut bc = b.chunks_exact(L);
        let mut cc = c.chunks_exact(L);
        for ((av, bv), cv) in (&mut ac).zip(&mut bc).zip(&mut cc) {
            for (((s, &ae), &be), &ce) in acc.iter_mut().zip(av).zip(bv).zip(cv) {
                *s += (ae * be) * ce;
            }
        }
        let mut s = hsum8(&acc);
        for ((&ae, &be), &ce) in ac
            .remainder()
            .iter()
            .zip(bc.remainder())
            .zip(cc.remainder())
        {
            s += (ae * be) * ce;
        }
        s
    }

    /// 8-lane `a_band (rows,k) @ b (k,n)`: per output row, `out_row +=
    /// a[p] * b_row(p)` via [`axpy`] — per-element accumulation ascending
    /// in `p`, rows independent of the banding.
    pub fn mm_band(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
        if n == 0 {
            return;
        }
        out.fill(0.0);
        for (orow, arow) in out.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
            for (p, &av) in arow.iter().enumerate() {
                axpy(orow, &b[p * n..(p + 1) * n], av);
            }
        }
    }

    /// 8-lane `a^T @ b` band (output rows `col0..col0+rows`).
    pub fn tn_band(a: &[f32], b: &[f32], out: &mut [f32], col0: usize, k: usize, m: usize, n: usize) {
        if n == 0 {
            return;
        }
        out.fill(0.0);
        for (i, orow) in out.chunks_exact_mut(n).enumerate() {
            let c = col0 + i;
            for p in 0..k {
                axpy(orow, &b[p * n..(p + 1) * n], a[p * m + c]);
            }
        }
    }

    /// 8-lane dot-product `a_band @ b^T` (the unpacked small-NT kernel).
    pub fn nt_band_small(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
        if n == 0 {
            return;
        }
        for (orow, arow) in out.chunks_exact_mut(n).zip(a.chunks_exact(k)) {
            for (o, brow) in orow.iter_mut().zip(b.chunks_exact(k)) {
                *o = dot(arow, brow);
            }
        }
    }

    /// Packed-panel `a_band @ b^T`: `packed` is the [`super::pack_b_nt`]
    /// layout; the micro-kernel runs MR rows x one 8-wide column group
    /// with per-element accumulation ascending in `p`.
    pub fn nt_band_packed(a: &[f32], packed: &[f32], out: &mut [f32], k: usize, n: usize) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        let groups = n.div_ceil(L);
        let mut i = 0;
        while i < rows {
            let mr = MR.min(rows - i);
            for g in 0..groups {
                let panel = &packed[g * k * L..(g + 1) * k * L];
                let j0 = g * L;
                let w = L.min(n - j0);
                if mr == MR {
                    let a0 = &a[i * k..(i + 1) * k];
                    let a1 = &a[(i + 1) * k..(i + 2) * k];
                    let a2 = &a[(i + 2) * k..(i + 3) * k];
                    let a3 = &a[(i + 3) * k..(i + 4) * k];
                    let mut s0 = [0.0f32; L];
                    let mut s1 = [0.0f32; L];
                    let mut s2 = [0.0f32; L];
                    let mut s3 = [0.0f32; L];
                    for (p, bv) in panel.chunks_exact(L).enumerate() {
                        let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
                        for (s, &bvv) in s0.iter_mut().zip(bv) {
                            *s += v0 * bvv;
                        }
                        for (s, &bvv) in s1.iter_mut().zip(bv) {
                            *s += v1 * bvv;
                        }
                        for (s, &bvv) in s2.iter_mut().zip(bv) {
                            *s += v2 * bvv;
                        }
                        for (s, &bvv) in s3.iter_mut().zip(bv) {
                            *s += v3 * bvv;
                        }
                    }
                    out[i * n + j0..i * n + j0 + w].copy_from_slice(&s0[..w]);
                    out[(i + 1) * n + j0..(i + 1) * n + j0 + w].copy_from_slice(&s1[..w]);
                    out[(i + 2) * n + j0..(i + 2) * n + j0 + w].copy_from_slice(&s2[..w]);
                    out[(i + 3) * n + j0..(i + 3) * n + j0 + w].copy_from_slice(&s3[..w]);
                } else {
                    for r in 0..mr {
                        let ar = &a[(i + r) * k..(i + r + 1) * k];
                        let mut s = [0.0f32; L];
                        for (p, bv) in panel.chunks_exact(L).enumerate() {
                            let v = ar[p];
                            for (sv, &bvv) in s.iter_mut().zip(bv) {
                                *sv += v * bvv;
                            }
                        }
                        out[(i + r) * n + j0..(i + r) * n + j0 + w].copy_from_slice(&s[..w]);
                    }
                }
            }
            i += mr;
        }
    }
}

/// AVX2+FMA twins of the [`lanes`] kernels. Every function is compiled
/// with the `avx2`/`fma` target features and must only be called after
/// [`avx2_available`] returned true (guarded in the dispatch shims
/// below); loop structure and lane-combine order mirror [`lanes`], with
/// fused multiply-add in place of mul+add.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{hsum8, L, MR, NC};

    /// # Safety
    /// Caller must guarantee AVX2+FMA support. All pointer accesses stay
    /// inside `acc`/`x`: `len = min(acc.len(), x.len())` bounds both the
    /// 8-wide loop (`w8 = len / L * L`) and the scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(acc: &mut [f32], x: &[f32], a: f32) {
        let len = acc.len().min(x.len());
        let w8 = len / L * L;
        let av = _mm256_set1_ps(a);
        let (pa, px) = (acc.as_mut_ptr(), x.as_ptr());
        let mut i = 0;
        while i < w8 {
            let r = _mm256_fmadd_ps(av, _mm256_loadu_ps(px.add(i)), _mm256_loadu_ps(pa.add(i)));
            _mm256_storeu_ps(pa.add(i), r);
            i += L;
        }
        while i < len {
            *pa.add(i) += a * *px.add(i);
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; every access is bounded by
    /// `v.len()` through the `w8` guard and the scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scale(v: &mut [f32], s: f32) {
        let len = v.len();
        let w8 = len / L * L;
        let sv = _mm256_set1_ps(s);
        let p = v.as_mut_ptr();
        let mut i = 0;
        while i < w8 {
            _mm256_storeu_ps(p.add(i), _mm256_mul_ps(sv, _mm256_loadu_ps(p.add(i))));
            i += L;
        }
        while i < len {
            *p.add(i) *= s;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; every access is bounded by
    /// `v.len()` through the `w8` guard and the scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn relu(v: &mut [f32]) {
        let len = v.len();
        let w8 = len / L * L;
        let z = _mm256_setzero_ps();
        let p = v.as_mut_ptr();
        let mut i = 0;
        while i < w8 {
            _mm256_storeu_ps(p.add(i), _mm256_max_ps(_mm256_loadu_ps(p.add(i)), z));
            i += L;
        }
        while i < len {
            let x = *p.add(i);
            *p.add(i) = x.max(0.0);
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; reads are bounded by
    /// `x.len()` through the `w8` guard and the scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let len = x.len();
        let w8 = len / L * L;
        let p = x.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < w8 {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(p.add(i)));
            i += L;
        }
        let mut tmp = [0.0f32; L];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut s = hsum8(&tmp);
        while i < len {
            s += *p.add(i);
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; reads are bounded by
    /// `x.len()` through the `w8` guard and the scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn max(x: &[f32]) -> f32 {
        let len = x.len();
        let w8 = len / L * L;
        let p = x.as_ptr();
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut i = 0;
        while i < w8 {
            acc = _mm256_max_ps(acc, _mm256_loadu_ps(p.add(i)));
            i += L;
        }
        let mut tmp = [0.0f32; L];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut m = tmp.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        while i < len {
            m = m.max(*p.add(i));
            i += 1;
        }
        m
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; reads are bounded by
    /// `len = min(x.len(), y.len())` in both the vector and scalar loops.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let len = x.len().min(y.len());
        let w8 = len / L * L;
        let (px, py) = (x.as_ptr(), y.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < w8 {
            acc = _mm256_fmadd_ps(_mm256_loadu_ps(px.add(i)), _mm256_loadu_ps(py.add(i)), acc);
            i += L;
        }
        let mut tmp = [0.0f32; L];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut s = hsum8(&tmp);
        while i < len {
            s += *px.add(i) * *py.add(i);
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; reads are bounded by
    /// `x.len()` through the `w8` guard and the scalar tail.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sum_sq(x: &[f32]) -> f32 {
        let len = x.len();
        let w8 = len / L * L;
        let p = x.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < w8 {
            let v = _mm256_loadu_ps(p.add(i));
            acc = _mm256_fmadd_ps(v, v, acc);
            i += L;
        }
        let mut tmp = [0.0f32; L];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut s = hsum8(&tmp);
        while i < len {
            let v = *p.add(i);
            s += v * v;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2+FMA support; reads are bounded by
    /// `len = min(a.len(), b.len(), c.len())` in both loops.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot3(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
        let len = a.len().min(b.len()).min(c.len());
        let w8 = len / L * L;
        let (pa, pb, pc) = (a.as_ptr(), b.as_ptr(), c.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < w8 {
            let ab = _mm256_mul_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc = _mm256_fmadd_ps(ab, _mm256_loadu_ps(pc.add(i)), acc);
            i += L;
        }
        let mut tmp = [0.0f32; L];
        _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
        let mut s = hsum8(&tmp);
        while i < len {
            s += (*pa.add(i) * *pb.add(i)) * *pc.add(i);
            i += 1;
        }
        s
    }

    /// 4-row band `a (rows,k) @ b (k,n)` with broadcast-FMA over 8-wide
    /// column chunks inside NC stripes (per-element accumulation
    /// ascending in `p`, like the blocked kernel).
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA support and the blocked-kernel shape
    /// contract: `a.len() >= rows*k`, `b.len() >= k*n`, `out.len() = rows*n`
    /// with `rows = out.len() / n`; all pointer offsets derive from those
    /// bounds via the row/stripe loop guards.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn mm_band(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        out.fill(0.0);
        let op = out.as_mut_ptr();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut i = 0;
        while i + MR <= rows {
            let o0 = op.add(i * n);
            let o1 = op.add((i + 1) * n);
            let o2 = op.add((i + 2) * n);
            let o3 = op.add((i + 3) * n);
            let a0 = ap.add(i * k);
            let a1 = ap.add((i + 1) * k);
            let a2 = ap.add((i + 2) * k);
            let a3 = ap.add((i + 3) * k);
            let mut j0 = 0;
            while j0 < n {
                let jn = (j0 + NC).min(n);
                let w8 = j0 + (jn - j0) / L * L;
                for p in 0..k {
                    let (s0, s1, s2, s3) = (*a0.add(p), *a1.add(p), *a2.add(p), *a3.add(p));
                    let v0 = _mm256_set1_ps(s0);
                    let v1 = _mm256_set1_ps(s1);
                    let v2 = _mm256_set1_ps(s2);
                    let v3 = _mm256_set1_ps(s3);
                    let br = bp.add(p * n);
                    let mut j = j0;
                    while j < w8 {
                        let bv = _mm256_loadu_ps(br.add(j));
                        _mm256_storeu_ps(o0.add(j), _mm256_fmadd_ps(v0, bv, _mm256_loadu_ps(o0.add(j))));
                        _mm256_storeu_ps(o1.add(j), _mm256_fmadd_ps(v1, bv, _mm256_loadu_ps(o1.add(j))));
                        _mm256_storeu_ps(o2.add(j), _mm256_fmadd_ps(v2, bv, _mm256_loadu_ps(o2.add(j))));
                        _mm256_storeu_ps(o3.add(j), _mm256_fmadd_ps(v3, bv, _mm256_loadu_ps(o3.add(j))));
                        j += L;
                    }
                    while j < jn {
                        let bv = *br.add(j);
                        *o0.add(j) += s0 * bv;
                        *o1.add(j) += s1 * bv;
                        *o2.add(j) += s2 * bv;
                        *o3.add(j) += s3 * bv;
                        j += 1;
                    }
                }
                j0 = jn;
            }
            i += MR;
        }
        while i < rows {
            let o = op.add(i * n);
            let ar = ap.add(i * k);
            let mut j0 = 0;
            while j0 < n {
                let jn = (j0 + NC).min(n);
                let w8 = j0 + (jn - j0) / L * L;
                for p in 0..k {
                    let s = *ar.add(p);
                    let v = _mm256_set1_ps(s);
                    let br = bp.add(p * n);
                    let mut j = j0;
                    while j < w8 {
                        let r = _mm256_fmadd_ps(v, _mm256_loadu_ps(br.add(j)), _mm256_loadu_ps(o.add(j)));
                        _mm256_storeu_ps(o.add(j), r);
                        j += L;
                    }
                    while j < jn {
                        *o.add(j) += s * *br.add(j);
                        j += 1;
                    }
                }
                j0 = jn;
            }
            i += 1;
        }
    }

    /// 4-row `a^T @ b` band (same broadcast-FMA micro-kernel as
    /// [`mm_band`]; the band's `a` columns `col0+i..col0+i+4` are
    /// contiguous per `p`-row).
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA support and the TN shape contract:
    /// `a.len() >= k*m` with band columns `col0..col0+rows` in range,
    /// `b.len() >= k*n`, `out.len() = rows*n`; all offsets stay inside
    /// those bounds via the row/stripe loop guards.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn tn_band(a: &[f32], b: &[f32], out: &mut [f32], col0: usize, k: usize, m: usize, n: usize) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        out.fill(0.0);
        let op = out.as_mut_ptr();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut i = 0;
        while i + MR <= rows {
            let o0 = op.add(i * n);
            let o1 = op.add((i + 1) * n);
            let o2 = op.add((i + 2) * n);
            let o3 = op.add((i + 3) * n);
            let c = col0 + i;
            let mut j0 = 0;
            while j0 < n {
                let jn = (j0 + NC).min(n);
                let w8 = j0 + (jn - j0) / L * L;
                for p in 0..k {
                    let av = ap.add(p * m + c);
                    let (s0, s1, s2, s3) = (*av, *av.add(1), *av.add(2), *av.add(3));
                    let v0 = _mm256_set1_ps(s0);
                    let v1 = _mm256_set1_ps(s1);
                    let v2 = _mm256_set1_ps(s2);
                    let v3 = _mm256_set1_ps(s3);
                    let br = bp.add(p * n);
                    let mut j = j0;
                    while j < w8 {
                        let bv = _mm256_loadu_ps(br.add(j));
                        _mm256_storeu_ps(o0.add(j), _mm256_fmadd_ps(v0, bv, _mm256_loadu_ps(o0.add(j))));
                        _mm256_storeu_ps(o1.add(j), _mm256_fmadd_ps(v1, bv, _mm256_loadu_ps(o1.add(j))));
                        _mm256_storeu_ps(o2.add(j), _mm256_fmadd_ps(v2, bv, _mm256_loadu_ps(o2.add(j))));
                        _mm256_storeu_ps(o3.add(j), _mm256_fmadd_ps(v3, bv, _mm256_loadu_ps(o3.add(j))));
                        j += L;
                    }
                    while j < jn {
                        let bv = *br.add(j);
                        *o0.add(j) += s0 * bv;
                        *o1.add(j) += s1 * bv;
                        *o2.add(j) += s2 * bv;
                        *o3.add(j) += s3 * bv;
                        j += 1;
                    }
                }
                j0 = jn;
            }
            i += MR;
        }
        while i < rows {
            let o = op.add(i * n);
            let c = col0 + i;
            let mut j0 = 0;
            while j0 < n {
                let jn = (j0 + NC).min(n);
                let w8 = j0 + (jn - j0) / L * L;
                for p in 0..k {
                    let s = *ap.add(p * m + c);
                    let v = _mm256_set1_ps(s);
                    let br = bp.add(p * n);
                    let mut j = j0;
                    while j < w8 {
                        let r = _mm256_fmadd_ps(v, _mm256_loadu_ps(br.add(j)), _mm256_loadu_ps(o.add(j)));
                        _mm256_storeu_ps(o.add(j), r);
                        j += L;
                    }
                    while j < jn {
                        *o.add(j) += s * *br.add(j);
                        j += 1;
                    }
                }
                j0 = jn;
            }
            i += 1;
        }
    }

    /// 8-lane dot-product `a_band @ b^T` (the unpacked small-NT kernel).
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA support; `a.len() >= rows*k` and
    /// `b.len() >= n*k` with `rows = out.len() / n` (all indexing here is
    /// safe slicing; only the [`dot`] calls are unchecked, bounded by the
    /// slice lengths passed in).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn nt_band_small(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                out[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]);
            }
        }
    }

    /// Packed-panel `a_band @ b^T` (see [`super::pack_b_nt`]): MR rows x
    /// one 8-wide column group, broadcast-FMA ascending in `p`.
    ///
    /// # Safety
    /// Caller must guarantee AVX2+FMA support; `a.len() >= rows*k` and
    /// `packed.len() >= n.div_ceil(8)*k*8` (the [`super::pack_b_nt`]
    /// layout) with `rows = out.len() / n`; panel and row offsets stay
    /// inside those bounds via the group/row loop guards.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn nt_band_packed(a: &[f32], packed: &[f32], out: &mut [f32], k: usize, n: usize) {
        if n == 0 {
            return;
        }
        let rows = out.len() / n;
        let groups = n.div_ceil(L);
        let pk = packed.as_ptr();
        let ap = a.as_ptr();
        let mut i = 0;
        while i < rows {
            let mr = MR.min(rows - i);
            for g in 0..groups {
                let panel = pk.add(g * k * L);
                let j0 = g * L;
                let w = L.min(n - j0);
                let mut tmp = [0.0f32; L];
                if mr == MR {
                    let a0 = ap.add(i * k);
                    let a1 = ap.add((i + 1) * k);
                    let a2 = ap.add((i + 2) * k);
                    let a3 = ap.add((i + 3) * k);
                    let mut s0 = _mm256_setzero_ps();
                    let mut s1 = _mm256_setzero_ps();
                    let mut s2 = _mm256_setzero_ps();
                    let mut s3 = _mm256_setzero_ps();
                    for p in 0..k {
                        let bv = _mm256_loadu_ps(panel.add(p * L));
                        s0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(p)), bv, s0);
                        s1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(p)), bv, s1);
                        s2 = _mm256_fmadd_ps(_mm256_set1_ps(*a2.add(p)), bv, s2);
                        s3 = _mm256_fmadd_ps(_mm256_set1_ps(*a3.add(p)), bv, s3);
                    }
                    _mm256_storeu_ps(tmp.as_mut_ptr(), s0);
                    out[i * n + j0..i * n + j0 + w].copy_from_slice(&tmp[..w]);
                    _mm256_storeu_ps(tmp.as_mut_ptr(), s1);
                    out[(i + 1) * n + j0..(i + 1) * n + j0 + w].copy_from_slice(&tmp[..w]);
                    _mm256_storeu_ps(tmp.as_mut_ptr(), s2);
                    out[(i + 2) * n + j0..(i + 2) * n + j0 + w].copy_from_slice(&tmp[..w]);
                    _mm256_storeu_ps(tmp.as_mut_ptr(), s3);
                    out[(i + 3) * n + j0..(i + 3) * n + j0 + w].copy_from_slice(&tmp[..w]);
                } else {
                    for r in 0..mr {
                        let ar = ap.add((i + r) * k);
                        let mut s = _mm256_setzero_ps();
                        for p in 0..k {
                            s = _mm256_fmadd_ps(_mm256_set1_ps(*ar.add(p)), _mm256_loadu_ps(panel.add(p * L)), s);
                        }
                        _mm256_storeu_ps(tmp.as_mut_ptr(), s);
                        out[(i + r) * n + j0..(i + r) * n + j0 + w].copy_from_slice(&tmp[..w]);
                    }
                }
            }
            i += mr;
        }
    }
}

// --- simd shims: runtime-dispatch between the AVX2 and portable twins.
// Each shim checks AVX2+FMA once per call (the std detection macro is a
// cached atomic load) and otherwise falls back to the portable lanes.
// SAFETY (all `unsafe` blocks below): the target-feature functions are
// only reachable after `avx2_available()` returned true, and they only
// require that plus in-bounds slices (guaranteed by their own loop
// guards over the slice lengths).

fn mm_band_simd(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; callers pass mm_band-shaped slices.
        unsafe { avx2::mm_band(a, b, out, k, n) };
        return;
    }
    lanes::mm_band(a, b, out, k, n);
}

fn tn_band_simd(a: &[f32], b: &[f32], out: &mut [f32], col0: usize, k: usize, m: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; callers pass tn_band-shaped slices.
        unsafe { avx2::tn_band(a, b, out, col0, k, m, n) };
        return;
    }
    lanes::tn_band(a, b, out, col0, k, m, n);
}

fn nt_band_simd_small(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; callers pass NT-shaped slices.
        unsafe { avx2::nt_band_small(a, b, out, k, n) };
        return;
    }
    lanes::nt_band_small(a, b, out, k, n);
}

fn nt_band_packed(a: &[f32], packed: &[f32], out: &mut [f32], k: usize, n: usize) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; `packed` uses the pack_b_nt layout.
        unsafe { avx2::nt_band_packed(a, packed, out, k, n) };
        return;
    }
    lanes::nt_band_packed(a, packed, out, k, n);
}

fn simd_axpy(acc: &mut [f32], x: &[f32], a: f32) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; axpy bounds itself by the slice lens.
        unsafe { avx2::axpy(acc, x, a) };
        return;
    }
    lanes::axpy(acc, x, a);
}

fn simd_scale(v: &mut [f32], s: f32) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; scale bounds itself by v.len().
        unsafe { avx2::scale(v, s) };
        return;
    }
    lanes::scale(v, s);
}

fn simd_relu(v: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; relu bounds itself by v.len().
        unsafe { avx2::relu(v) };
        return;
    }
    lanes::relu(v);
}

fn simd_sum(x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; sum bounds itself by x.len().
        return unsafe { avx2::sum(x) };
    }
    lanes::sum(x)
}

fn simd_max(x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; max bounds itself by x.len().
        return unsafe { avx2::max(x) };
    }
    lanes::max(x)
}

fn simd_dot(x: &[f32], y: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; dot bounds itself by both lens.
        return unsafe { avx2::dot(x, y) };
    }
    lanes::dot(x, y)
}

fn simd_sum_sq(x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; sum_sq bounds itself by x.len().
        return unsafe { avx2::sum_sq(x) };
    }
    lanes::sum_sq(x)
}

fn simd_dot3(a: &[f32], b: &[f32], c: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: avx2_available() holds; dot3 bounds itself by all three lens.
        return unsafe { avx2::dot3(a, b, c) };
    }
    lanes::dot3(a, b, c)
}

// ---------------------------------------------------------------------------
// Dispatch-aware reductions and elementwise helpers (shared by model,
// trainer and cluster, so every caller goes through the one chooser)
// ---------------------------------------------------------------------------

/// Max over `x` (`-inf` when empty) under an explicit dispatch tier.
pub fn reduce_max_d(x: &[f32], d: Dispatch) -> f32 {
    if d == Dispatch::Simd {
        simd_max(x)
    } else {
        x.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }
}

/// Sum over `x` under an explicit dispatch tier (the `simd` tier uses 8
/// accumulator lanes with a fixed combine order — reassociates).
pub fn reduce_sum_d(x: &[f32], d: Dispatch) -> f32 {
    if d == Dispatch::Simd {
        simd_sum(x)
    } else {
        x.iter().sum()
    }
}

/// Dot product under an explicit dispatch tier.
pub fn reduce_dot_d(x: &[f32], y: &[f32], d: Dispatch) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    if d == Dispatch::Simd {
        simd_dot(x, y)
    } else {
        x.iter().zip(y).map(|(a, b)| a * b).sum()
    }
}

/// Dot product under the calling thread's [`active_dispatch`].
pub fn reduce_dot(x: &[f32], y: &[f32]) -> f32 {
    reduce_dot_d(x, y, active_dispatch())
}

/// Sum of squares under an explicit dispatch tier.
fn reduce_sq_d(x: &[f32], d: Dispatch) -> f32 {
    if d == Dispatch::Simd {
        simd_sum_sq(x)
    } else {
        x.iter().map(|v| v * v).sum()
    }
}

/// `sum_i (a_i * b_i) * c_i` under an explicit dispatch tier.
fn reduce_dot3_d(a: &[f32], b: &[f32], c: &[f32], d: Dispatch) -> f32 {
    if d == Dispatch::Simd {
        simd_dot3(a, b, c)
    } else {
        a.iter().zip(b).zip(c).map(|((&av, &bv), &cv)| av * bv * cv).sum()
    }
}

/// `acc += a * x`, elementwise (dispatch-aware; per-element order is
/// identical across tiers, the `simd` tier fuses the multiply-add).
pub fn axpy(acc: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(acc.len(), x.len());
    if active_dispatch() == Dispatch::Simd {
        simd_axpy(acc, x, a);
    } else {
        for (dv, &s) in acc.iter_mut().zip(x) {
            *dv += a * s;
        }
    }
}

/// `v *= s`, elementwise (dispatch-aware).
pub fn scale(v: &mut [f32], s: f32) {
    if active_dispatch() == Dispatch::Simd {
        simd_scale(v, s);
    } else {
        for x in v.iter_mut() {
            *x *= s;
        }
    }
}

/// `v = max(v, 0)` under an explicit dispatch tier.
fn relu_inplace_d(v: &mut [f32], d: Dispatch) {
    if d == Dispatch::Simd {
        simd_relu(v);
    } else {
        for x in v.iter_mut() {
            *x = x.max(0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// Public matmuls: dispatch-routed `_into`, parallel `par_*`, wrappers
// ---------------------------------------------------------------------------

/// Band-kernel function types (chosen once per public call, then shared
/// by every row band so [`with_dispatch`] overrides survive the fan-out).
type MmBandFn = fn(&[f32], &[f32], &mut [f32], usize, usize);
type TnBandFn = fn(&[f32], &[f32], &mut [f32], usize, usize, usize, usize);

fn mm_band_for(d: Dispatch) -> MmBandFn {
    match d {
        Dispatch::Naive => mm_band_naive,
        Dispatch::Blocked => mm_band,
        Dispatch::Simd => mm_band_simd,
    }
}

fn tn_band_for(d: Dispatch) -> TnBandFn {
    match d {
        Dispatch::Naive => tn_band_naive,
        Dispatch::Blocked => tn_band,
        Dispatch::Simd => tn_band_simd,
    }
}

fn nt_band_for(d: Dispatch) -> MmBandFn {
    match d {
        Dispatch::Naive => nt_band_naive,
        Dispatch::Blocked => nt_band,
        Dispatch::Simd => nt_band_simd_small,
    }
}

/// Serial `a (m,k) @ b (k,n)` into `out (m,n)` (overwrites;
/// dispatch-routed).
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    mm_band_for(active_dispatch())(a, b, out, k, n);
}

/// Serial `a (m,k) @ b^T`, `b (n,k)`, into `out (m,n)` (dispatch-routed;
/// the `simd` tier packs B panels for large shapes).
pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    nt_driver(a, b, out, m, k, n, false, None);
}

/// Serial `a^T @ b`, `a (k,m)`, `b (k,n)`, into `out (m,n)`
/// (dispatch-routed).
pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    tn_band_for(active_dispatch())(a, b, out, 0, k, m, n);
}

/// Whether a `(m,k,n)` matmul is worth fanning out on the current budget.
fn par_worthwhile(m: usize, k: usize, n: usize) -> bool {
    m >= 2 && scope::current_budget() > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS
}

/// Whether the `simd` tier should pack B panels for a `(m,k,n)`
/// `matmul_nt` (see [`NT_PACK_MIN_ROWS`]/[`NT_PACK_MIN_BN`]).
fn nt_pack_worthwhile(m: usize, k: usize, n: usize) -> bool {
    m >= NT_PACK_MIN_ROWS && k.saturating_mul(n) >= NT_PACK_MIN_BN
}

/// One `matmul_nt` driver behind every public NT entry point: picks the
/// dispatch tier, packs B panels for large `simd`-tier shapes (buffer
/// from `ws` when given, else a fresh allocation), and row-bands across
/// the thread budget when `allow_par`. Row results never depend on the
/// banding, so parallel == serial bitwise within a tier.
#[allow(clippy::too_many_arguments)]
fn nt_driver(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    allow_par: bool,
    mut ws: Option<&mut Workspace>,
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    if n == 0 || m == 0 {
        return;
    }
    let d = active_dispatch();
    if d == Dispatch::Simd && nt_pack_worthwhile(m, k, n) {
        let plen = n.div_ceil(L) * k * L;
        let mut packed = match ws.as_mut() {
            Some(w) => w.take(plen),
            None => vec![0.0f32; plen],
        };
        pack_b_nt(b, k, n, &mut packed);
        if allow_par && par_worthwhile(m, k, n) {
            scope::par_rows(out, n, |row0, band| {
                let rows = band.len() / n;
                nt_band_packed(&a[row0 * k..(row0 + rows) * k], &packed, band, k, n);
            });
        } else {
            nt_band_packed(a, &packed, out, k, n);
        }
        if let Some(w) = ws {
            w.put(packed);
        }
        return;
    }
    let band = nt_band_for(d);
    if allow_par && par_worthwhile(m, k, n) {
        scope::par_rows(out, n, |row0, bs| {
            let rows = bs.len() / n;
            band(&a[row0 * k..(row0 + rows) * k], b, bs, k, n);
        });
    } else {
        band(a, b, out, k, n);
    }
}

/// Parallel matmul into `out`: splits the M rows into contiguous bands
/// across the thread budget; stays serial below [`PAR_MIN_MACS`].
/// Byte-identical to [`matmul_into`] for any budget (within a tier).
pub fn par_matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    let _sp = crate::obs::span("mm");
    let band = mm_band_for(active_dispatch());
    if !par_worthwhile(m, k, n) {
        band(a, b, out, k, n);
        return;
    }
    scope::par_rows(out, n, |row0, bs| {
        let rows = bs.len() / n;
        band(&a[row0 * k..(row0 + rows) * k], b, bs, k, n);
    });
}

/// Parallel `matmul_nt` into `out` (M-banded, budget-gated; the `simd`
/// tier packs B panels for large shapes).
pub fn par_matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    let _sp = crate::obs::span("mm_nt");
    nt_driver(a, b, out, m, k, n, true, None);
}

/// [`par_matmul_nt_into`] with the packed-B panel buffer taken from (and
/// retired to) the caller's [`Workspace`] — the LM-head path, where the
/// panel is vocab-sized and worth pooling across steps.
pub fn par_matmul_nt_into_ws(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    ws: &mut Workspace,
) {
    let _sp = crate::obs::span("mm_nt");
    nt_driver(a, b, out, m, k, n, true, Some(ws));
}

/// Parallel `matmul_tn` into `out` (output-row-banded over the
/// M columns of `a`, budget-gated).
pub fn par_matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    let _sp = crate::obs::span("mm_tn");
    let band = tn_band_for(active_dispatch());
    if !par_worthwhile(m, k, n) {
        band(a, b, out, 0, k, m, n);
        return;
    }
    scope::par_rows(out, n, |row0, bs| {
        band(a, b, bs, row0, k, m, n);
    });
}

/// Allocating parallel blocked matmul (see [`par_matmul_into`]).
pub fn par_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_matmul_into(a, b, &mut out, m, k, n);
    out
}

/// Allocating parallel blocked `matmul_nt` (see [`par_matmul_nt_into`]).
pub fn par_matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_matmul_nt_into(a, b, &mut out, m, k, n);
    out
}

/// Allocating parallel blocked `matmul_tn` (see [`par_matmul_tn_into`]).
pub fn par_matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_matmul_tn_into(a, b, &mut out, k, m, n);
    out
}

/// `a (m,k) @ b (k,n) -> (m,n)` — blocked, budget-gated parallel.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    par_matmul(a, b, m, k, n)
}

/// `a (m,k) @ b^T` with `b (n,k)` -> `(m,n)` (rows of b are the columns).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    par_matmul_nt(a, b, m, k, n)
}

/// `a^T @ b` with `a (k,m)`, `b (k,n)` -> `(m,n)`.
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    par_matmul_tn(a, b, k, m, n)
}

// ---------------------------------------------------------------------------
// Softmax / RMSNorm
// ---------------------------------------------------------------------------

/// Row-wise softmax over `(t, n)`, numerically stable (max subtraction).
/// The max and sum reductions are dispatch-routed (8-lane on the `simd`
/// tier; the scalar tiers keep the historical ascending order bitwise).
pub fn softmax_rows(x: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len() % n, 0);
    let d = active_dispatch();
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
        let mx = reduce_max_d(row, d);
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = (v - mx).exp();
        }
        let sum = reduce_sum_d(orow, d);
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
    out
}

/// Backward of row-wise softmax: `dx_i = p_i * (dp_i - sum_j dp_j p_j)`.
pub fn softmax_bwd_rows(p: &[f32], dp: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(p.len(), dp.len());
    let d = active_dispatch();
    let mut out = vec![0.0f32; p.len()];
    for ((prow, dprow), orow) in p
        .chunks_exact(n)
        .zip(dp.chunks_exact(n))
        .zip(out.chunks_exact_mut(n))
    {
        let dot = reduce_dot_d(prow, dprow, d);
        for ((o, &pv), &dpv) in orow.iter_mut().zip(prow).zip(dprow) {
            *o = pv * (dpv - dot);
        }
    }
    out
}

/// RMSNorm epsilon (matches `ref.rmsnorm_ref`).
pub const RMS_EPS: f32 = 1e-6;

/// RMSNorm over the last axis of `(t, m)` with gain `g (m,)` into `out`.
/// The mean-square reduction is dispatch-routed.
pub fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    let m = g.len();
    debug_assert_eq!(x.len() % m, 0);
    debug_assert_eq!(out.len(), x.len());
    let d = active_dispatch();
    for (row, orow) in x.chunks_exact(m).zip(out.chunks_exact_mut(m)) {
        let ms = reduce_sq_d(row, d) / m as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        for ((o, &xv), &gv) in orow.iter_mut().zip(row).zip(g) {
            *o = xv * r * gv;
        }
    }
}

/// RMSNorm over the last axis of `(t, m)` with learnable gain `g (m,)`.
pub fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    rmsnorm_into(x, g, &mut out);
    out
}

/// Backward of [`rmsnorm`] into caller buffers `dx (t,m)` / `dg (m,)`
/// (both overwritten).
///
/// With `r = (mean(x^2) + eps)^{-1/2}`:
/// `dx_j = r g_j dy_j - r^3 x_j / m * sum_i dy_i g_i x_i`,
/// `dg_j = sum_rows dy_j x_j r`.
pub fn rmsnorm_bwd_into(x: &[f32], g: &[f32], dy: &[f32], dx: &mut [f32], dg: &mut [f32]) {
    let m = g.len();
    debug_assert_eq!(x.len(), dy.len());
    debug_assert_eq!(dx.len(), x.len());
    debug_assert_eq!(dg.len(), m);
    let d = active_dispatch();
    dg.fill(0.0);
    for ((row, dyrow), dxrow) in x
        .chunks_exact(m)
        .zip(dy.chunks_exact(m))
        .zip(dx.chunks_exact_mut(m))
    {
        let ms = reduce_sq_d(row, d) / m as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        let s = reduce_dot3_d(dyrow, g, row, d);
        let r3s = r * r * r * s / m as f32;
        for (j, (dxv, &xv)) in dxrow.iter_mut().zip(row).enumerate() {
            *dxv = r * g[j] * dyrow[j] - r3s * xv;
            dg[j] += dyrow[j] * xv * r;
        }
    }
}

/// Backward of [`rmsnorm`]: returns `(dx, dg)`.
pub fn rmsnorm_bwd(x: &[f32], g: &[f32], dy: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let mut dg = vec![0.0f32; g.len()];
    rmsnorm_bwd_into(x, g, dy, &mut dx, &mut dg);
    (dx, dg)
}

// ---------------------------------------------------------------------------
// Embedding
// ---------------------------------------------------------------------------

/// Embedding lookup with the model's `sqrt(M)` scale into `out (t,m)`.
pub fn embed_lookup_into(embed: &[f32], tokens: &[i32], m: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), tokens.len() * m);
    let scale = (m as f64).sqrt() as f32;
    for (t, &tok) in tokens.iter().enumerate() {
        let src = tok as usize * m;
        for (o, &e) in out[t * m..(t + 1) * m].iter_mut().zip(&embed[src..src + m]) {
            *o = e * scale;
        }
    }
}

/// Embedding lookup with the model's `sqrt(M)` scale: `x_t = embed[tok_t] * sqrt(m)`.
pub fn embed_lookup(embed: &[f32], tokens: &[i32], m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; tokens.len() * m];
    embed_lookup_into(embed, tokens, m, &mut out);
    out
}

/// Backward of [`embed_lookup`]: scatter-add `dx * sqrt(m)` into the
/// zeroed `de (vocab, m)` buffer (rows via the dispatch-routed [`axpy`]).
pub fn embed_scatter_into(tokens: &[i32], dx: &[f32], m: usize, de: &mut [f32]) {
    let sc = (m as f64).sqrt() as f32;
    de.fill(0.0);
    for (t, &tok) in tokens.iter().enumerate() {
        let dst = tok as usize * m;
        axpy(&mut de[dst..dst + m], &dx[t * m..(t + 1) * m], sc);
    }
}

/// Backward of [`embed_lookup`]: scatter-add `dx * sqrt(m)` into `(vocab, m)`.
pub fn embed_scatter(tokens: &[i32], dx: &[f32], vocab: usize, m: usize) -> Vec<f32> {
    let mut de = vec![0.0f32; vocab * m];
    embed_scatter_into(tokens, dx, m, &mut de);
    de
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// Causal mask fill value (matches `ref.attention_causal_ref`).
const MASK_NEG: f32 = -1e30;

/// Causal scaled-dot-product attention for one (batch, head): `q,k,v (n,d)`.
/// Returns `(weights (n,n), out (n,d))`; the weights are kept for backward.
pub fn attention_causal(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f64).sqrt() as f32;
    let mut s = matmul_nt(q, k, n, d, n);
    for i in 0..n {
        for (j, x) in s[i * n..(i + 1) * n].iter_mut().enumerate() {
            if j > i {
                *x = MASK_NEG;
            } else {
                *x *= scale;
            }
        }
    }
    let w = softmax_rows(&s, n);
    let o = matmul(&w, v, n, n, d);
    (w, o)
}

/// Backward of [`attention_causal`] given the saved weights `w` and the
/// output cotangent `do_`: returns `(dq, dk, dv)`. Masked positions carry
/// zero weight, so the softmax backward zeroes their score gradient
/// automatically.
pub fn attention_causal_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    w: &[f32],
    do_: &[f32],
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f64).sqrt() as f32;
    let dv = matmul_tn(w, do_, n, n, d);
    let dw = matmul_nt(do_, v, n, d, n);
    let mut ds = softmax_bwd_rows(w, &dw, n);
    for x in ds.iter_mut() {
        *x *= scale;
    }
    let dq = matmul(&ds, k, n, n, d);
    let dk = matmul_tn(&ds, q, n, n, d);
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Gating
// ---------------------------------------------------------------------------

/// Renormalization floor of the top-k gate weights (matches `ref.gating_ref`).
pub const GATE_EPS: f32 = 1e-9;

/// Output of [`gating_topk`].
pub struct Gating {
    /// `(t, e)` full softmax probabilities.
    pub probs: Vec<f32>,
    /// `(t, k)` selected expert ids, by descending probability (ties to
    /// the smaller index, matching `ref.topk_ref`).
    pub idx: Vec<i32>,
    /// `(t, k)` gate weights renormalized over the selected experts.
    pub gate: Vec<f32>,
}

/// Top-k softmax gating over logits `(t, e)` — mirror of `ref.gating_ref`
/// composed with the logits it is fed (`u @ wg` happens in the caller).
pub fn gating_topk(logits: &[f32], e: usize, k: usize) -> Gating {
    let t = logits.len() / e;
    let probs = softmax_rows(logits, e);
    let mut idx = vec![0i32; t * k];
    let mut gate = vec![0.0f32; t * k];
    for ti in 0..t {
        let row = &probs[ti * e..(ti + 1) * e];
        let mut work: Vec<f32> = row.to_vec();
        let mut raw_sum = 0.0f32;
        for ki in 0..k {
            let best = work.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let first = work.iter().position(|&v| v == best).unwrap_or(0);
            idx[ti * k + ki] = first as i32;
            gate[ti * k + ki] = row[first];
            raw_sum += row[first];
            work[first] = f32::NEG_INFINITY;
        }
        let denom = raw_sum.max(GATE_EPS);
        for g in gate[ti * k..(ti + 1) * k].iter_mut() {
            *g /= denom;
        }
    }
    Gating { probs, idx, gate }
}

/// Backward of [`gating_topk`] w.r.t. the logits, given the cotangent of
/// the renormalized gate weights. The top-k selection is a fixed gather
/// (non-differentiable choice, like `lax.top_k`): gradients scatter to
/// the selected probability entries only, then flow through the softmax.
pub fn gating_topk_bwd(g: &Gating, e: usize, k: usize, dgate: &[f32]) -> Vec<f32> {
    let t = g.idx.len() / k;
    let mut dprobs = vec![0.0f32; t * e];
    for ti in 0..t {
        let raw: Vec<f32> = (0..k).map(|ki| g.probs[ti * e + g.idx[ti * k + ki] as usize]).collect();
        let raw_sum: f32 = raw.iter().sum();
        let drow = &dgate[ti * k..(ti + 1) * k];
        if raw_sum > GATE_EPS {
            // gate_i = raw_i / D, D = sum(raw): d raw_j = dg_j/D - s/D^2
            let s: f32 = drow.iter().zip(&raw).map(|(d, r)| d * r).sum();
            for ki in 0..k {
                let draw = drow[ki] / raw_sum - s / (raw_sum * raw_sum);
                dprobs[ti * e + g.idx[ti * k + ki] as usize] += draw;
            }
        } else {
            // denominator clamped to the constant GATE_EPS
            for ki in 0..k {
                dprobs[ti * e + g.idx[ti * k + ki] as usize] += drow[ki] / GATE_EPS;
            }
        }
    }
    softmax_bwd_rows(&g.probs, &dprobs, e)
}

// ---------------------------------------------------------------------------
// Expert FFN (expert-parallel)
// ---------------------------------------------------------------------------

/// One expert's `relu(x_e @ w1_e) @ w2_e` into its output slab, using
/// the caller's `hid (c,h)` scratch.
#[allow(clippy::too_many_arguments)]
fn expert_ffn_unit(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    ei: usize,
    out: &mut [f32],
    hid: &mut [f32],
    c: usize,
    m: usize,
    h: usize,
) {
    let xe = &x[ei * c * m..(ei + 1) * c * m];
    let w1e = &w1[ei * m * h..(ei + 1) * m * h];
    let w2e = &w2[ei * h * m..(ei + 1) * h * m];
    par_matmul_into(xe, w1e, hid, c, m, h);
    relu_inplace_d(hid, active_dispatch());
    par_matmul_into(hid, w2e, out, c, h, m);
}

/// Whether the expert axis is worth fanning out on the current budget.
fn expert_par_worthwhile(e: usize, c: usize, m: usize, h: usize) -> bool {
    e >= 2 && scope::current_budget() > 1 && c.saturating_mul(m).saturating_mul(h) >= PAR_MIN_MACS
}

/// Batched expert FFN into `out (e,c,m)` — mirror of `ref.expert_ffn_ref`:
/// per expert `e`: `relu(x_e @ w1_e) @ w2_e` with `x (e,c,m)`,
/// `w1 (e,m,h)`, `w2 (e,h,m)`. Experts fan out across the thread budget
/// when the per-expert work is large enough (results are identical
/// either way: each expert's slab is computed independently).
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_into(x: &[f32], w1: &[f32], w2: &[f32], out: &mut [f32], e: usize, c: usize, m: usize, h: usize) {
    debug_assert_eq!(out.len(), e * c * m);
    let _sp = crate::obs::span("expert_ffn");
    if expert_par_worthwhile(e, c, m, h) {
        // capture the caller's dispatch tier: scope workers are fresh
        // threads, so the thread-local override must be re-applied
        let d = active_dispatch();
        let slabs: Vec<&mut [f32]> = out.chunks_mut(c * m).collect();
        scope::par_items(slabs, |ei, oslab| {
            with_dispatch(d, || {
                let mut hid = vec![0.0f32; c * h];
                expert_ffn_unit(x, w1, w2, ei, oslab, &mut hid, c, m, h);
            });
        });
    } else {
        let mut hid = vec![0.0f32; c * h];
        for (ei, oslab) in out.chunks_mut(c * m).enumerate() {
            expert_ffn_unit(x, w1, w2, ei, oslab, &mut hid, c, m, h);
        }
    }
}

/// Batched expert FFN (allocating wrapper over [`expert_ffn_into`]).
pub fn expert_ffn(x: &[f32], w1: &[f32], w2: &[f32], e: usize, c: usize, m: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; e * c * m];
    expert_ffn_into(x, w1, w2, &mut out, e, c, m, h);
    out
}

/// One expert's backward into its `(dx, dw1, dw2)` slabs.
#[allow(clippy::too_many_arguments)]
fn expert_ffn_bwd_unit(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    ei: usize,
    dxe: &mut [f32],
    dw1e: &mut [f32],
    dw2e: &mut [f32],
    c: usize,
    m: usize,
    h: usize,
) {
    let xe = &x[ei * c * m..(ei + 1) * c * m];
    let w1e = &w1[ei * m * h..(ei + 1) * m * h];
    let w2e = &w2[ei * h * m..(ei + 1) * h * m];
    let dye = &dy[ei * c * m..(ei + 1) * c * m];
    let mut hid = vec![0.0f32; c * h];
    par_matmul_into(xe, w1e, &mut hid, c, m, h);
    // single fused read-map-write pass on the scalar tiers; the simd
    // tier pays a memcpy for the vectorized relu pass
    let hr: Vec<f32> = if active_dispatch() == Dispatch::Simd {
        let mut hr = hid.clone();
        simd_relu(&mut hr);
        hr
    } else {
        hid.iter().map(|&v| v.max(0.0)).collect()
    };
    let mut dhid = vec![0.0f32; c * h];
    par_matmul_nt_into(dye, w2e, &mut dhid, c, m, h);
    for (dv, &pre) in dhid.iter_mut().zip(&hid) {
        if pre <= 0.0 {
            *dv = 0.0;
        }
    }
    par_matmul_tn_into(&hr, dye, dw2e, c, h, m);
    par_matmul_tn_into(xe, &dhid, dw1e, c, m, h);
    par_matmul_nt_into(&dhid, w1e, dxe, c, h, m);
}

/// Backward of [`expert_ffn`] (recompute) into `dx (e,c,m)`,
/// `dw1 (e,m,h)`, `dw2 (e,h,m)`. ReLU gradient at exactly 0 is 0 (the
/// JAX convention). Experts fan out like the forward.
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_bwd_into(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dw1: &mut [f32],
    dw2: &mut [f32],
    e: usize,
    c: usize,
    m: usize,
    h: usize,
) {
    debug_assert_eq!(dx.len(), e * c * m);
    debug_assert_eq!(dw1.len(), e * m * h);
    debug_assert_eq!(dw2.len(), e * h * m);
    let _sp = crate::obs::span("expert_ffn_bwd");
    let units: Vec<(&mut [f32], &mut [f32], &mut [f32])> = dx
        .chunks_mut(c * m)
        .zip(dw1.chunks_mut(m * h))
        .zip(dw2.chunks_mut(h * m))
        .map(|((a, b), c_)| (a, b, c_))
        .collect();
    if expert_par_worthwhile(e, c, m, h) {
        let d = active_dispatch();
        scope::par_items(units, |ei, (dxe, dw1e, dw2e)| {
            with_dispatch(d, || {
                expert_ffn_bwd_unit(x, w1, w2, dy, ei, dxe, dw1e, dw2e, c, m, h);
            });
        });
    } else {
        for (ei, (dxe, dw1e, dw2e)) in units.into_iter().enumerate() {
            expert_ffn_bwd_unit(x, w1, w2, dy, ei, dxe, dw1e, dw2e, c, m, h);
        }
    }
}

/// Backward of [`expert_ffn`] (recompute): returns `(dx, dw1, dw2)`.
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_bwd(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    e: usize,
    c: usize,
    m: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; e * c * m];
    let mut dw1 = vec![0.0f32; e * m * h];
    let mut dw2 = vec![0.0f32; e * h * m];
    expert_ffn_bwd_into(x, w1, w2, dy, &mut dx, &mut dw1, &mut dw2, e, c, m, h);
    (dx, dw1, dw2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // (2,2)
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (3, 4, 5);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let want = matmul(&a, &b, m, k, n);
        // b^T stored as (n,k)
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        assert_eq!(matmul_nt(&a, &bt, m, k, n), want);
        // a^T stored as (k,m)
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let got = matmul_tn(&at, &b, k, m, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    /// Relative-tolerance comparison used by the dispatch-vs-naive
    /// checks (1e-5 absolute floor: the ambient tier may be `simd`,
    /// whose FMA re-rounding shows up on cancellation-heavy elements).
    fn assert_rel_close(got: &[f32], want: &[f32], rel: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: len");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = rel * (g.abs() + w.abs()) + 1e-5;
            assert!((g - w).abs() <= tol, "{what}[{i}]: {g} vs {w}");
        }
    }

    #[test]
    fn blocked_matmuls_match_naive_reference() {
        // a few irregular shapes here; the full odd/prime-shape sweep
        // lives in tests/kernel_parity.rs
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 9), (13, 3, 21), (8, 16, 8)] {
            let a = randv(&mut rng, m * k, 1.0);
            let b = randv(&mut rng, k * n, 1.0);
            assert_rel_close(&matmul(&a, &b, m, k, n), &matmul_ref(&a, &b, m, k, n), 1e-4, "mm");
            let bt = randv(&mut rng, n * k, 1.0);
            assert_rel_close(
                &matmul_nt(&a, &bt, m, k, n),
                &matmul_nt_ref(&a, &bt, m, k, n),
                1e-4,
                "nt",
            );
            let at = randv(&mut rng, k * m, 1.0);
            assert_rel_close(
                &matmul_tn(&at, &b, k, m, n),
                &matmul_tn_ref(&at, &b, k, m, n),
                1e-4,
                "tn",
            );
        }
    }

    #[test]
    fn parallel_matmul_is_byte_identical_to_serial() {
        let mut rng = Rng::new(43);
        let (m, k, n) = (37, 19, 23);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let serial = crate::sweep::scope::with_budget(1, || matmul(&a, &b, m, k, n));
        for budget in [2usize, 3, 8] {
            let mut par = vec![0.0f32; m * n];
            crate::sweep::scope::with_budget(budget, || {
                // bypass the size gate: band the rows explicitly
                crate::sweep::scope::par_rows(&mut par, n, |row0, band| {
                    let rows = band.len() / n;
                    matmul_into(&a[row0 * k..(row0 + rows) * k], b.as_slice(), band, rows, k, n);
                });
            });
            assert!(
                serial.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits()),
                "budget {budget}"
            );
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let p = softmax_rows(&x, 3);
        for row in p.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone logits
        }
    }

    #[test]
    fn rmsnorm_unit_gain_unit_rms() {
        let x = vec![3.0f32, -4.0]; // rms^2 = 12.5
        let g = vec![1.0f32, 1.0];
        let y = rmsnorm(&x, &g);
        let ms: f32 = y.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-4, "ms={ms}");
    }

    #[test]
    fn embed_roundtrip_adjoint() {
        // <lookup(E), dX> == <E, scatter(dX)>
        let mut rng = Rng::new(2);
        let (v, m) = (6, 4);
        let embed = randv(&mut rng, v * m, 1.0);
        let tokens = vec![0i32, 3, 3, 5];
        let dx = randv(&mut rng, tokens.len() * m, 1.0);
        let x = embed_lookup(&embed, &tokens, m);
        let de = embed_scatter(&tokens, &dx, v, m);
        let lhs: f32 = x.iter().zip(&dx).map(|(a, b)| a * b).sum();
        let rhs: f32 = embed.iter().zip(&de).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn attention_causal_first_token_attends_self_only() {
        let mut rng = Rng::new(3);
        let (n, d) = (4, 2);
        let q = randv(&mut rng, n * d, 1.0);
        let k = randv(&mut rng, n * d, 1.0);
        let v = randv(&mut rng, n * d, 1.0);
        let (w, o) = attention_causal(&q, &k, &v, n, d);
        // row 0 can only see position 0
        assert!((w[0] - 1.0).abs() < 1e-6);
        for j in 1..n {
            assert!(w[j].abs() < 1e-6);
        }
        assert!((o[0] - v[0]).abs() < 1e-5);
        // every row is a distribution
        for row in w.chunks_exact(n) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gating_selects_top_probs_and_renormalizes() {
        // 1 token, 4 experts, clear margins
        let logits = vec![2.0f32, -1.0, 0.5, -2.0];
        let g = gating_topk(&logits, 4, 2);
        assert_eq!(g.idx, vec![0, 2]);
        assert!((g.gate.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(g.gate[0] > g.gate[1]);
        let psum: f32 = g.probs.iter().sum();
        assert!((psum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gating_ties_go_to_smaller_index() {
        let logits = vec![1.0f32, 1.0, 0.0, 0.0];
        let g = gating_topk(&logits, 4, 2);
        assert_eq!(g.idx, vec![0, 1]);
    }

    #[test]
    fn expert_ffn_matches_scalar_reference() {
        // 1 expert, 1 token, m=2, h=2, hand-computed
        let x = vec![1.0f32, 2.0];
        let w1 = vec![1.0f32, -1.0, 0.5, 1.0]; // (m=2, h=2) row-major
        let w2 = vec![1.0f32, 0.0, 2.0, 1.0]; // (h=2, m=2)
        // hid = [1*1+2*0.5, 1*-1+2*1] = [2, 1]; relu same
        // out = [2*1+1*2, 2*0+1*1] = [4, 1]
        let out = expert_ffn(&x, &w1, &w2, 1, 1, 2, 2);
        assert_eq!(out, vec![4.0, 1.0]);
    }

    #[test]
    fn expert_ffn_relu_mask_blocks_gradient() {
        // hid = [2, -3]: the negative unit must contribute no gradient
        let x = vec![1.0f32, 2.0];
        let w1 = vec![1.0f32, -1.0, 0.5, -1.0]; // hid = [2, -3]
        let w2 = vec![1.0f32, 0.0, 2.0, 1.0];
        let dy = vec![1.0f32, 1.0];
        let (dx, dw1, dw2) = expert_ffn_bwd(&x, &w1, &w2, &dy, 1, 1, 2, 2);
        // dhid = dy @ w2^T = [1, 3] before mask -> [1, 0]
        // dx = dhid @ w1^T = [1*1 + 0*-1, 1*0.5 + 0*-1] = [1, 0.5]
        assert_eq!(dx, vec![1.0, 0.5]);
        // dw1 = x^T @ dhid = [[1,0],[2,0]]
        assert_eq!(dw1, vec![1.0, 0.0, 2.0, 0.0]);
        // dw2 = relu(hid)^T @ dy = [[2,2],[0,0]]
        assert_eq!(dw2, vec![2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn parse_kernels_env_values_including_garbage() {
        assert_eq!(parse_kernels(""), Ok(None));
        assert_eq!(parse_kernels("auto"), Ok(None));
        assert_eq!(parse_kernels(" AUTO "), Ok(None));
        assert_eq!(parse_kernels(" SIMD "), Ok(Some(Dispatch::Simd)));
        assert_eq!(parse_kernels("Blocked"), Ok(Some(Dispatch::Blocked)));
        assert_eq!(parse_kernels("naive"), Ok(Some(Dispatch::Naive)));
        for garbage in ["fast", "simd8", "1", "avx512", "block ed"] {
            let err = parse_kernels(garbage).unwrap_err();
            assert!(err.contains("FLOWMOE_KERNELS"), "{err}");
            assert!(err.contains(&garbage.trim().to_ascii_lowercase()), "{err}");
        }
    }

    #[test]
    fn resolve_simd_without_avx2_errors_instead_of_silent_fallback() {
        let err = resolve_dispatch(Some(Dispatch::Simd), false).unwrap_err();
        assert!(err.contains("AVX2"), "{err}");
        assert!(err.contains("blocked"), "{err}"); // actionable alternatives
        assert_eq!(resolve_dispatch(Some(Dispatch::Simd), true), Ok(Dispatch::Simd));
        assert_eq!(resolve_dispatch(None, true), Ok(Dispatch::Simd));
        assert_eq!(resolve_dispatch(None, false), Ok(Dispatch::Blocked));
        assert_eq!(resolve_dispatch(Some(Dispatch::Naive), false), Ok(Dispatch::Naive));
        assert_eq!(resolve_dispatch(Some(Dispatch::Blocked), false), Ok(Dispatch::Blocked));
    }

    #[test]
    fn with_dispatch_overrides_and_restores() {
        let ambient = active_dispatch();
        with_dispatch(Dispatch::Naive, || {
            assert_eq!(active_dispatch(), Dispatch::Naive);
            with_dispatch(Dispatch::Simd, || assert_eq!(active_dispatch(), Dispatch::Simd));
            assert_eq!(active_dispatch(), Dispatch::Naive);
        });
        assert_eq!(active_dispatch(), ambient);
    }

    #[test]
    fn pack_b_nt_layout_and_zero_padding() {
        // b (n=3, k=2): rows [1,2], [3,4], [5,6]; one 8-wide group with 5
        // padded tail columns; the buffer starts dirty on purpose
        let b = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let (k, n) = (2usize, 3usize);
        let mut packed = vec![7.0f32; k * 8];
        pack_b_nt(&b, k, n, &mut packed);
        assert_eq!(&packed[0..8], &[1.0, 3.0, 5.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(&packed[8..16], &[2.0, 4.0, 6.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn simd_reducers_match_scalar_within_tolerance() {
        let mut rng = Rng::new(5);
        for len in [0usize, 1, 7, 8, 9, 31, 100] {
            let x = randv(&mut rng, len, 1.0);
            let y = randv(&mut rng, len, 1.0);
            let ss: f32 = x.iter().sum();
            assert!((reduce_sum_d(&x, Dispatch::Simd) - ss).abs() <= 1e-4 * (ss.abs() + 1.0), "sum len {len}");
            let mx = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(reduce_max_d(&x, Dispatch::Simd), mx, "max len {len}"); // max is exact
            let dt: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((reduce_dot_d(&x, &y, Dispatch::Simd) - dt).abs() <= 1e-4 * (dt.abs() + 1.0), "dot len {len}");
        }
    }

    #[test]
    fn every_dispatch_tier_matches_reference_incl_packed_nt() {
        // in-module smoke only: one odd shape (small kernels, lane
        // remainders) and one packed-B shape; the exhaustive awkward-
        // shape sweep lives in tests/kernel_conformance.rs
        let mut rng = Rng::new(6);
        for &(m, k, n) in &[(5usize, 7usize, 9usize), (16, 64, 80)] {
            let a = randv(&mut rng, m * k, 1.0);
            let b = randv(&mut rng, k * n, 1.0);
            let bt = randv(&mut rng, n * k, 1.0);
            let at = randv(&mut rng, k * m, 1.0);
            for d in [Dispatch::Naive, Dispatch::Blocked, Dispatch::Simd] {
                with_dispatch(d, || {
                    let tag = d.name();
                    assert_rel_close(
                        &matmul(&a, &b, m, k, n),
                        &matmul_ref(&a, &b, m, k, n),
                        1e-4,
                        &format!("{tag} mm {m}x{k}x{n}"),
                    );
                    assert_rel_close(
                        &matmul_nt(&a, &bt, m, k, n),
                        &matmul_nt_ref(&a, &bt, m, k, n),
                        1e-4,
                        &format!("{tag} nt {m}x{k}x{n}"),
                    );
                    assert_rel_close(
                        &matmul_tn(&at, &b, k, m, n),
                        &matmul_tn_ref(&at, &b, k, m, n),
                        1e-4,
                        &format!("{tag} tn {m}x{k}x{n}"),
                    );
                });
            }
        }
    }

    #[test]
    fn workspace_pooled_nt_matches_plain_nt_bitwise() {
        let mut rng = Rng::new(8);
        let (m, k, n) = (16usize, 64usize, 80usize); // packed-B shape
        let a = randv(&mut rng, m * k, 1.0);
        let bt = randv(&mut rng, n * k, 1.0);
        for d in [Dispatch::Naive, Dispatch::Blocked, Dispatch::Simd] {
            with_dispatch(d, || {
                let mut plain = vec![0.0f32; m * n];
                par_matmul_nt_into(&a, &bt, &mut plain, m, k, n);
                let mut ws = Workspace::new();
                ws.put(vec![7.0f32; 8]); // dirty pool
                for round in 0..2 {
                    let mut pooled = vec![0.0f32; m * n];
                    par_matmul_nt_into_ws(&a, &bt, &mut pooled, m, k, n, &mut ws);
                    assert!(
                        plain.iter().zip(&pooled).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{} round {round}",
                        d.name()
                    );
                }
            });
        }
    }

    #[test]
    fn axpy_scale_relu_match_scalar_semantics_on_simd() {
        let mut rng = Rng::new(9);
        for len in [0usize, 1, 7, 8, 9, 31, 100] {
            let base = randv(&mut rng, len, 1.0);
            let x = randv(&mut rng, len, 1.0);
            let mut got = base.clone();
            with_dispatch(Dispatch::Simd, || axpy(&mut got, &x, 0.7));
            for ((g, &b), &xv) in got.iter().zip(&base).zip(&x) {
                let want = b + 0.7 * xv;
                assert!((g - want).abs() <= 1e-5 * (want.abs() + 1.0), "axpy len {len}");
            }
            let mut got = base.clone();
            with_dispatch(Dispatch::Simd, || scale(&mut got, -1.5));
            for (g, &b) in got.iter().zip(&base) {
                assert_eq!(*g, b * -1.5, "scale len {len}"); // mul is exact vs scalar
            }
            let mut got = base.clone();
            relu_inplace_d(&mut got, Dispatch::Simd);
            for (g, &b) in got.iter().zip(&base) {
                assert_eq!(*g, b.max(0.0), "relu len {len}");
            }
        }
    }

    #[test]
    fn expert_ffn_bwd_adjoint_on_x() {
        // <ffn(x+tv) - ffn(x-tv), w>/(2t) ~= <dx, v> for smooth region
        let mut rng = Rng::new(7);
        let (e, c, m, h) = (2usize, 3usize, 4usize, 5usize);
        // keep hidden units well away from the ReLU kink
        let x: Vec<f32> = (0..e * c * m).map(|_| 0.5 + rng.f32()).collect();
        let w1: Vec<f32> = (0..e * m * h).map(|_| 0.2 + rng.f32()).collect();
        let w2 = randv(&mut rng, e * h * m, 0.5);
        let wt = randv(&mut rng, e * c * m, 1.0);
        let (dx, _, _) = expert_ffn_bwd(&x, &w1, &w2, &wt, e, c, m, h);
        let v = randv(&mut rng, x.len(), 1.0);
        let eps = 1e-3f32;
        let xp: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let xm: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let fp: f32 = expert_ffn(&xp, &w1, &w2, e, c, m, h).iter().zip(&wt).map(|(a, b)| a * b).sum();
        let fm: f32 = expert_ffn(&xm, &w1, &w2, e, c, m, h).iter().zip(&wt).map(|(a, b)| a * b).sum();
        let fd = (fp - fm) / (2.0 * eps);
        let an: f32 = dx.iter().zip(&v).map(|(a, b)| a * b).sum();
        assert!((fd - an).abs() < 0.05 * (an.abs() + 1.0), "fd={fd} an={an}");
    }
}
