//! Dense f32 CPU kernels for the native execution backend.
//!
//! Every op here is an exact host-side mirror of a `python/compile`
//! primitive (`kernels/ref.py` semantics): same masking constants, same
//! epsilons, same tie-breaking, so a native run is numerically
//! interchangeable with an artifact run up to summation order. Each
//! forward has a hand-derived backward; `tests/gradcheck_native.rs`
//! checks every pair against central finite differences.
//!
//! Shapes are row-major flat `&[f32]` slices; dimensions are passed
//! explicitly (the backend derives them from the artifact manifest).
//!
//! # Performance (§Perf)
//!
//! The three matmul variants are **cache-blocked**: a 4-row (`MR`)
//! micro-kernel accumulates into register/L1-resident output rows while
//! one `NC`-wide stripe of `b` streams through, giving 4x reuse of every
//! `b` load and four independent FMA chains per column for ILP. The
//! `matmul_nt` dot-product variant uses a 4x4 register tile (16
//! independent accumulator chains) instead. `par_*` variants additionally
//! split the M dimension into contiguous row bands across
//! [`crate::sweep::scope`]'s thread budget; `expert_ffn`/`expert_ffn_bwd`
//! fan the expert axis out the same way.
//!
//! Numerics contract: parity with the naive `*_ref` kernels is
//! **tolerance-based** (blocking may reorder summation; tests use 1e-4
//! rel-tol). The current tiling happens to keep each output element's
//! accumulation order ascending in the contraction index — so today the
//! blocked, parallel and reference kernels agree bit-for-bit — but only
//! the tolerance contract is guaranteed (future SIMD/k-split kernels may
//! reassociate). What **is** guaranteed: every kernel is deterministic,
//! each row's result is independent of the row banding, and therefore
//! parallel results are byte-identical to serial results for any thread
//! budget (asserted by `perf_hotpath` and `tests/kernel_parity.rs`).

use crate::sweep::scope;

/// Output rows per micro-kernel tile (register blocking).
const MR: usize = 4;
/// Column-stripe width: `MR` output-row stripes of `NC` f32 stay L1-hot
/// while `b` streams through.
const NC: usize = 512;
/// Work threshold (in `m*k*n` multiply-adds) below which the `par_*`
/// wrappers stay serial: spawning scoped threads costs tens of
/// microseconds, so only matmuls of ~ms scale fan out.
const PAR_MIN_MACS: usize = 1 << 18;

// ---------------------------------------------------------------------------
// Reference (naive) matmuls — the parity oracle for the blocked kernels
// ---------------------------------------------------------------------------

/// Naive `a (m,k) @ b (k,n) -> (m,n)` triple loop (reference oracle).
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Naive `a (m,k) @ b^T` with `b (n,k)` -> `(m,n)` (reference oracle).
pub fn matmul_nt_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
    out
}

/// Naive `a^T @ b` with `a (k,m)`, `b (k,n)` -> `(m,n)` (reference oracle).
pub fn matmul_tn_ref(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Blocked band kernels (the f32 micro-kernels)
// ---------------------------------------------------------------------------

/// Blocked `a_band (rows,k) @ b (k,n)` into `out (rows,n)`; `a` holds
/// exactly the band's rows. Row results do not depend on the banding.
fn mm_band(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(b.len(), k * n);
    out.fill(0.0);
    let mut i = 0;
    while i + MR <= rows {
        let band = &mut out[i * n..(i + MR) * n];
        let (r0, band) = band.split_at_mut(n);
        let (r1, band) = band.split_at_mut(n);
        let (r2, r3) = band.split_at_mut(n);
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for p in 0..k {
                let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
                let brow = &b[p * n + j0..p * n + jn];
                let cols = r0[j0..jn]
                    .iter_mut()
                    .zip(r1[j0..jn].iter_mut())
                    .zip(r2[j0..jn].iter_mut())
                    .zip(r3[j0..jn].iter_mut())
                    .zip(brow);
                for ((((o0, o1), o2), o3), &bv) in cols {
                    *o0 += v0 * bv;
                    *o1 += v1 * bv;
                    *o2 += v2 * bv;
                    *o3 += v3 * bv;
                }
            }
            j0 = jn;
        }
        i += MR;
    }
    while i < rows {
        let r = &mut out[i * n..(i + 1) * n];
        let arow = &a[i * k..(i + 1) * k];
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * n + j0..p * n + jn];
                for (o, &bv) in r[j0..jn].iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            j0 = jn;
        }
        i += 1;
    }
}

/// Blocked `a_band (rows,k) @ b^T` with `b (n,k)` into `out (rows,n)`:
/// 4x4 register tiles, 16 independent accumulator chains.
fn nt_band(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(b.len(), n * k);
    let mut i = 0;
    while i < rows {
        let mr = MR.min(rows - i);
        let mut j = 0;
        while j < n {
            let nr = MR.min(n - j);
            if mr == MR && nr == MR {
                let a0 = &a[i * k..(i + 1) * k];
                let a1 = &a[(i + 1) * k..(i + 2) * k];
                let a2 = &a[(i + 2) * k..(i + 3) * k];
                let a3 = &a[(i + 3) * k..(i + 4) * k];
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                let mut acc = [[0.0f32; MR]; MR];
                for p in 0..k {
                    let av = [a0[p], a1[p], a2[p], a3[p]];
                    let bv = [b0[p], b1[p], b2[p], b3[p]];
                    for (accr, &avv) in acc.iter_mut().zip(&av) {
                        for (s, &bvv) in accr.iter_mut().zip(&bv) {
                            *s += avv * bvv;
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    out[(i + r) * n + j..(i + r) * n + j + MR].copy_from_slice(accr);
                }
            } else {
                for r in 0..mr {
                    let arow = &a[(i + r) * k..(i + r + 1) * k];
                    for c in 0..nr {
                        let brow = &b[(j + c) * k..(j + c + 1) * k];
                        out[(i + r) * n + j + c] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
                    }
                }
            }
            j += nr;
        }
        i += mr;
    }
}

/// Blocked `a^T @ b` band: `out` holds rows `col0..col0+rows` of the
/// `(m,n)` product with `a (k,m)`, `b (k,n)`. Columns `col0+i..col0+i+4`
/// of `a` are contiguous per `p`-row, so the same 4-row micro-kernel as
/// [`mm_band`] applies.
fn tn_band(a: &[f32], b: &[f32], out: &mut [f32], col0: usize, k: usize, m: usize, n: usize) {
    if n == 0 {
        return;
    }
    let rows = out.len() / n;
    debug_assert_eq!(out.len(), rows * n);
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    out.fill(0.0);
    let mut i = 0;
    while i + MR <= rows {
        let band = &mut out[i * n..(i + MR) * n];
        let (r0, band) = band.split_at_mut(n);
        let (r1, band) = band.split_at_mut(n);
        let (r2, r3) = band.split_at_mut(n);
        let c = col0 + i;
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for p in 0..k {
                let av = &a[p * m + c..p * m + c + MR];
                let (v0, v1, v2, v3) = (av[0], av[1], av[2], av[3]);
                let brow = &b[p * n + j0..p * n + jn];
                let cols = r0[j0..jn]
                    .iter_mut()
                    .zip(r1[j0..jn].iter_mut())
                    .zip(r2[j0..jn].iter_mut())
                    .zip(r3[j0..jn].iter_mut())
                    .zip(brow);
                for ((((o0, o1), o2), o3), &bv) in cols {
                    *o0 += v0 * bv;
                    *o1 += v1 * bv;
                    *o2 += v2 * bv;
                    *o3 += v3 * bv;
                }
            }
            j0 = jn;
        }
        i += MR;
    }
    while i < rows {
        let r = &mut out[i * n..(i + 1) * n];
        let c = col0 + i;
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NC).min(n);
            for p in 0..k {
                let av = a[p * m + c];
                let brow = &b[p * n + j0..p * n + jn];
                for (o, &bv) in r[j0..jn].iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
            j0 = jn;
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Public matmuls: blocked `_into`, parallel `par_*`, allocating wrappers
// ---------------------------------------------------------------------------

/// Serial blocked `a (m,k) @ b (k,n)` into `out (m,n)` (overwrites).
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    mm_band(a, b, out, k, n);
}

/// Serial blocked `a (m,k) @ b^T`, `b (n,k)`, into `out (m,n)`.
pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    nt_band(a, b, out, k, n);
}

/// Serial blocked `a^T @ b`, `a (k,m)`, `b (k,n)`, into `out (m,n)`.
pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    tn_band(a, b, out, 0, k, m, n);
}

/// Whether a `(m,k,n)` matmul is worth fanning out on the current budget.
fn par_worthwhile(m: usize, k: usize, n: usize) -> bool {
    m >= 2 && scope::current_budget() > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS
}

/// Parallel blocked matmul into `out`: splits the M rows into contiguous
/// bands across the thread budget; stays serial below [`PAR_MIN_MACS`].
/// Byte-identical to [`matmul_into`] for any budget.
pub fn par_matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    if !par_worthwhile(m, k, n) {
        mm_band(a, b, out, k, n);
        return;
    }
    scope::par_rows(out, n, |row0, band| {
        let rows = band.len() / n;
        mm_band(&a[row0 * k..(row0 + rows) * k], b, band, k, n);
    });
}

/// Parallel blocked `matmul_nt` into `out` (M-banded, budget-gated).
pub fn par_matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    if !par_worthwhile(m, k, n) {
        nt_band(a, b, out, k, n);
        return;
    }
    scope::par_rows(out, n, |row0, band| {
        let rows = band.len() / n;
        nt_band(&a[row0 * k..(row0 + rows) * k], b, band, k, n);
    });
}

/// Parallel blocked `matmul_tn` into `out` (output-row-banded over the
/// M columns of `a`, budget-gated).
pub fn par_matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    if !par_worthwhile(m, k, n) {
        tn_band(a, b, out, 0, k, m, n);
        return;
    }
    scope::par_rows(out, n, |row0, band| {
        tn_band(a, b, band, row0, k, m, n);
    });
}

/// Allocating parallel blocked matmul (see [`par_matmul_into`]).
pub fn par_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_matmul_into(a, b, &mut out, m, k, n);
    out
}

/// Allocating parallel blocked `matmul_nt` (see [`par_matmul_nt_into`]).
pub fn par_matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_matmul_nt_into(a, b, &mut out, m, k, n);
    out
}

/// Allocating parallel blocked `matmul_tn` (see [`par_matmul_tn_into`]).
pub fn par_matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_matmul_tn_into(a, b, &mut out, k, m, n);
    out
}

/// `a (m,k) @ b (k,n) -> (m,n)` — blocked, budget-gated parallel.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    par_matmul(a, b, m, k, n)
}

/// `a (m,k) @ b^T` with `b (n,k)` -> `(m,n)` (rows of b are the columns).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    par_matmul_nt(a, b, m, k, n)
}

/// `a^T @ b` with `a (k,m)`, `b (k,n)` -> `(m,n)`.
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    par_matmul_tn(a, b, k, m, n)
}

// ---------------------------------------------------------------------------
// Softmax / RMSNorm
// ---------------------------------------------------------------------------

/// Row-wise softmax over `(t, n)`, numerically stable (max subtraction).
pub fn softmax_rows(x: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len() % n, 0);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = (v - mx).exp();
            sum += *o;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
    out
}

/// Backward of row-wise softmax: `dx_i = p_i * (dp_i - sum_j dp_j p_j)`.
pub fn softmax_bwd_rows(p: &[f32], dp: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(p.len(), dp.len());
    let mut out = vec![0.0f32; p.len()];
    for ((prow, dprow), orow) in p
        .chunks_exact(n)
        .zip(dp.chunks_exact(n))
        .zip(out.chunks_exact_mut(n))
    {
        let dot: f32 = prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
        for ((o, &pv), &dpv) in orow.iter_mut().zip(prow).zip(dprow) {
            *o = pv * (dpv - dot);
        }
    }
    out
}

/// RMSNorm epsilon (matches `ref.rmsnorm_ref`).
pub const RMS_EPS: f32 = 1e-6;

/// RMSNorm over the last axis of `(t, m)` with gain `g (m,)` into `out`.
pub fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    let m = g.len();
    debug_assert_eq!(x.len() % m, 0);
    debug_assert_eq!(out.len(), x.len());
    for (row, orow) in x.chunks_exact(m).zip(out.chunks_exact_mut(m)) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / m as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        for ((o, &xv), &gv) in orow.iter_mut().zip(row).zip(g) {
            *o = xv * r * gv;
        }
    }
}

/// RMSNorm over the last axis of `(t, m)` with learnable gain `g (m,)`.
pub fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    rmsnorm_into(x, g, &mut out);
    out
}

/// Backward of [`rmsnorm`] into caller buffers `dx (t,m)` / `dg (m,)`
/// (both overwritten).
///
/// With `r = (mean(x^2) + eps)^{-1/2}`:
/// `dx_j = r g_j dy_j - r^3 x_j / m * sum_i dy_i g_i x_i`,
/// `dg_j = sum_rows dy_j x_j r`.
pub fn rmsnorm_bwd_into(x: &[f32], g: &[f32], dy: &[f32], dx: &mut [f32], dg: &mut [f32]) {
    let m = g.len();
    debug_assert_eq!(x.len(), dy.len());
    debug_assert_eq!(dx.len(), x.len());
    debug_assert_eq!(dg.len(), m);
    dg.fill(0.0);
    for ((row, dyrow), dxrow) in x
        .chunks_exact(m)
        .zip(dy.chunks_exact(m))
        .zip(dx.chunks_exact_mut(m))
    {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / m as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        let s: f32 = dyrow
            .iter()
            .zip(row)
            .zip(g)
            .map(|((&d, &xv), &gv)| d * gv * xv)
            .sum();
        let r3s = r * r * r * s / m as f32;
        for (j, (dxv, &xv)) in dxrow.iter_mut().zip(row).enumerate() {
            *dxv = r * g[j] * dyrow[j] - r3s * xv;
            dg[j] += dyrow[j] * xv * r;
        }
    }
}

/// Backward of [`rmsnorm`]: returns `(dx, dg)`.
pub fn rmsnorm_bwd(x: &[f32], g: &[f32], dy: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let mut dg = vec![0.0f32; g.len()];
    rmsnorm_bwd_into(x, g, dy, &mut dx, &mut dg);
    (dx, dg)
}

// ---------------------------------------------------------------------------
// Embedding
// ---------------------------------------------------------------------------

/// Embedding lookup with the model's `sqrt(M)` scale into `out (t,m)`.
pub fn embed_lookup_into(embed: &[f32], tokens: &[i32], m: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), tokens.len() * m);
    let scale = (m as f64).sqrt() as f32;
    for (t, &tok) in tokens.iter().enumerate() {
        let src = tok as usize * m;
        for (o, &e) in out[t * m..(t + 1) * m].iter_mut().zip(&embed[src..src + m]) {
            *o = e * scale;
        }
    }
}

/// Embedding lookup with the model's `sqrt(M)` scale: `x_t = embed[tok_t] * sqrt(m)`.
pub fn embed_lookup(embed: &[f32], tokens: &[i32], m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; tokens.len() * m];
    embed_lookup_into(embed, tokens, m, &mut out);
    out
}

/// Backward of [`embed_lookup`]: scatter-add `dx * sqrt(m)` into the
/// zeroed `de (vocab, m)` buffer.
pub fn embed_scatter_into(tokens: &[i32], dx: &[f32], m: usize, de: &mut [f32]) {
    let scale = (m as f64).sqrt() as f32;
    de.fill(0.0);
    for (t, &tok) in tokens.iter().enumerate() {
        let dst = tok as usize * m;
        for (o, &d) in de[dst..dst + m].iter_mut().zip(&dx[t * m..(t + 1) * m]) {
            *o += d * scale;
        }
    }
}

/// Backward of [`embed_lookup`]: scatter-add `dx * sqrt(m)` into `(vocab, m)`.
pub fn embed_scatter(tokens: &[i32], dx: &[f32], vocab: usize, m: usize) -> Vec<f32> {
    let mut de = vec![0.0f32; vocab * m];
    embed_scatter_into(tokens, dx, m, &mut de);
    de
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// Causal mask fill value (matches `ref.attention_causal_ref`).
const MASK_NEG: f32 = -1e30;

/// Causal scaled-dot-product attention for one (batch, head): `q,k,v (n,d)`.
/// Returns `(weights (n,n), out (n,d))`; the weights are kept for backward.
pub fn attention_causal(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f64).sqrt() as f32;
    let mut s = matmul_nt(q, k, n, d, n);
    for i in 0..n {
        for (j, x) in s[i * n..(i + 1) * n].iter_mut().enumerate() {
            if j > i {
                *x = MASK_NEG;
            } else {
                *x *= scale;
            }
        }
    }
    let w = softmax_rows(&s, n);
    let o = matmul(&w, v, n, n, d);
    (w, o)
}

/// Backward of [`attention_causal`] given the saved weights `w` and the
/// output cotangent `do_`: returns `(dq, dk, dv)`. Masked positions carry
/// zero weight, so the softmax backward zeroes their score gradient
/// automatically.
pub fn attention_causal_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    w: &[f32],
    do_: &[f32],
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f64).sqrt() as f32;
    let dv = matmul_tn(w, do_, n, n, d);
    let dw = matmul_nt(do_, v, n, d, n);
    let mut ds = softmax_bwd_rows(w, &dw, n);
    for x in ds.iter_mut() {
        *x *= scale;
    }
    let dq = matmul(&ds, k, n, n, d);
    let dk = matmul_tn(&ds, q, n, n, d);
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Gating
// ---------------------------------------------------------------------------

/// Renormalization floor of the top-k gate weights (matches `ref.gating_ref`).
pub const GATE_EPS: f32 = 1e-9;

/// Output of [`gating_topk`].
pub struct Gating {
    /// `(t, e)` full softmax probabilities.
    pub probs: Vec<f32>,
    /// `(t, k)` selected expert ids, by descending probability (ties to
    /// the smaller index, matching `ref.topk_ref`).
    pub idx: Vec<i32>,
    /// `(t, k)` gate weights renormalized over the selected experts.
    pub gate: Vec<f32>,
}

/// Top-k softmax gating over logits `(t, e)` — mirror of `ref.gating_ref`
/// composed with the logits it is fed (`u @ wg` happens in the caller).
pub fn gating_topk(logits: &[f32], e: usize, k: usize) -> Gating {
    let t = logits.len() / e;
    let probs = softmax_rows(logits, e);
    let mut idx = vec![0i32; t * k];
    let mut gate = vec![0.0f32; t * k];
    for ti in 0..t {
        let row = &probs[ti * e..(ti + 1) * e];
        let mut work: Vec<f32> = row.to_vec();
        let mut raw_sum = 0.0f32;
        for ki in 0..k {
            let best = work.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let first = work.iter().position(|&v| v == best).unwrap();
            idx[ti * k + ki] = first as i32;
            gate[ti * k + ki] = row[first];
            raw_sum += row[first];
            work[first] = f32::NEG_INFINITY;
        }
        let denom = raw_sum.max(GATE_EPS);
        for g in gate[ti * k..(ti + 1) * k].iter_mut() {
            *g /= denom;
        }
    }
    Gating { probs, idx, gate }
}

/// Backward of [`gating_topk`] w.r.t. the logits, given the cotangent of
/// the renormalized gate weights. The top-k selection is a fixed gather
/// (non-differentiable choice, like `lax.top_k`): gradients scatter to
/// the selected probability entries only, then flow through the softmax.
pub fn gating_topk_bwd(g: &Gating, e: usize, k: usize, dgate: &[f32]) -> Vec<f32> {
    let t = g.idx.len() / k;
    let mut dprobs = vec![0.0f32; t * e];
    for ti in 0..t {
        let raw: Vec<f32> = (0..k).map(|ki| g.probs[ti * e + g.idx[ti * k + ki] as usize]).collect();
        let raw_sum: f32 = raw.iter().sum();
        let drow = &dgate[ti * k..(ti + 1) * k];
        if raw_sum > GATE_EPS {
            // gate_i = raw_i / D, D = sum(raw): d raw_j = dg_j/D - s/D^2
            let s: f32 = drow.iter().zip(&raw).map(|(d, r)| d * r).sum();
            for ki in 0..k {
                let draw = drow[ki] / raw_sum - s / (raw_sum * raw_sum);
                dprobs[ti * e + g.idx[ti * k + ki] as usize] += draw;
            }
        } else {
            // denominator clamped to the constant GATE_EPS
            for ki in 0..k {
                dprobs[ti * e + g.idx[ti * k + ki] as usize] += drow[ki] / GATE_EPS;
            }
        }
    }
    softmax_bwd_rows(&g.probs, &dprobs, e)
}

// ---------------------------------------------------------------------------
// Expert FFN (expert-parallel)
// ---------------------------------------------------------------------------

/// One expert's `relu(x_e @ w1_e) @ w2_e` into its output slab, using
/// the caller's `hid (c,h)` scratch.
#[allow(clippy::too_many_arguments)]
fn expert_ffn_unit(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    ei: usize,
    out: &mut [f32],
    hid: &mut [f32],
    c: usize,
    m: usize,
    h: usize,
) {
    let xe = &x[ei * c * m..(ei + 1) * c * m];
    let w1e = &w1[ei * m * h..(ei + 1) * m * h];
    let w2e = &w2[ei * h * m..(ei + 1) * h * m];
    par_matmul_into(xe, w1e, hid, c, m, h);
    for v in hid.iter_mut() {
        *v = v.max(0.0);
    }
    par_matmul_into(hid, w2e, out, c, h, m);
}

/// Whether the expert axis is worth fanning out on the current budget.
fn expert_par_worthwhile(e: usize, c: usize, m: usize, h: usize) -> bool {
    e >= 2 && scope::current_budget() > 1 && c.saturating_mul(m).saturating_mul(h) >= PAR_MIN_MACS
}

/// Batched expert FFN into `out (e,c,m)` — mirror of `ref.expert_ffn_ref`:
/// per expert `e`: `relu(x_e @ w1_e) @ w2_e` with `x (e,c,m)`,
/// `w1 (e,m,h)`, `w2 (e,h,m)`. Experts fan out across the thread budget
/// when the per-expert work is large enough (results are identical
/// either way: each expert's slab is computed independently).
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_into(x: &[f32], w1: &[f32], w2: &[f32], out: &mut [f32], e: usize, c: usize, m: usize, h: usize) {
    debug_assert_eq!(out.len(), e * c * m);
    if expert_par_worthwhile(e, c, m, h) {
        let slabs: Vec<&mut [f32]> = out.chunks_mut(c * m).collect();
        scope::par_items(slabs, |ei, oslab| {
            let mut hid = vec![0.0f32; c * h];
            expert_ffn_unit(x, w1, w2, ei, oslab, &mut hid, c, m, h);
        });
    } else {
        let mut hid = vec![0.0f32; c * h];
        for (ei, oslab) in out.chunks_mut(c * m).enumerate() {
            expert_ffn_unit(x, w1, w2, ei, oslab, &mut hid, c, m, h);
        }
    }
}

/// Batched expert FFN (allocating wrapper over [`expert_ffn_into`]).
pub fn expert_ffn(x: &[f32], w1: &[f32], w2: &[f32], e: usize, c: usize, m: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; e * c * m];
    expert_ffn_into(x, w1, w2, &mut out, e, c, m, h);
    out
}

/// One expert's backward into its `(dx, dw1, dw2)` slabs.
#[allow(clippy::too_many_arguments)]
fn expert_ffn_bwd_unit(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    ei: usize,
    dxe: &mut [f32],
    dw1e: &mut [f32],
    dw2e: &mut [f32],
    c: usize,
    m: usize,
    h: usize,
) {
    let xe = &x[ei * c * m..(ei + 1) * c * m];
    let w1e = &w1[ei * m * h..(ei + 1) * m * h];
    let w2e = &w2[ei * h * m..(ei + 1) * h * m];
    let dye = &dy[ei * c * m..(ei + 1) * c * m];
    let mut hid = vec![0.0f32; c * h];
    par_matmul_into(xe, w1e, &mut hid, c, m, h);
    let hr: Vec<f32> = hid.iter().map(|&v| v.max(0.0)).collect();
    let mut dhid = vec![0.0f32; c * h];
    par_matmul_nt_into(dye, w2e, &mut dhid, c, m, h);
    for (dv, &pre) in dhid.iter_mut().zip(&hid) {
        if pre <= 0.0 {
            *dv = 0.0;
        }
    }
    par_matmul_tn_into(&hr, dye, dw2e, c, h, m);
    par_matmul_tn_into(xe, &dhid, dw1e, c, m, h);
    par_matmul_nt_into(&dhid, w1e, dxe, c, h, m);
}

/// Backward of [`expert_ffn`] (recompute) into `dx (e,c,m)`,
/// `dw1 (e,m,h)`, `dw2 (e,h,m)`. ReLU gradient at exactly 0 is 0 (the
/// JAX convention). Experts fan out like the forward.
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_bwd_into(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    dw1: &mut [f32],
    dw2: &mut [f32],
    e: usize,
    c: usize,
    m: usize,
    h: usize,
) {
    debug_assert_eq!(dx.len(), e * c * m);
    debug_assert_eq!(dw1.len(), e * m * h);
    debug_assert_eq!(dw2.len(), e * h * m);
    let units: Vec<(&mut [f32], &mut [f32], &mut [f32])> = dx
        .chunks_mut(c * m)
        .zip(dw1.chunks_mut(m * h))
        .zip(dw2.chunks_mut(h * m))
        .map(|((a, b), c_)| (a, b, c_))
        .collect();
    if expert_par_worthwhile(e, c, m, h) {
        scope::par_items(units, |ei, (dxe, dw1e, dw2e)| {
            expert_ffn_bwd_unit(x, w1, w2, dy, ei, dxe, dw1e, dw2e, c, m, h);
        });
    } else {
        for (ei, (dxe, dw1e, dw2e)) in units.into_iter().enumerate() {
            expert_ffn_bwd_unit(x, w1, w2, dy, ei, dxe, dw1e, dw2e, c, m, h);
        }
    }
}

/// Backward of [`expert_ffn`] (recompute): returns `(dx, dw1, dw2)`.
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_bwd(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    e: usize,
    c: usize,
    m: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; e * c * m];
    let mut dw1 = vec![0.0f32; e * m * h];
    let mut dw2 = vec![0.0f32; e * h * m];
    expert_ffn_bwd_into(x, w1, w2, dy, &mut dx, &mut dw1, &mut dw2, e, c, m, h);
    (dx, dw1, dw2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // (2,2)
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (3, 4, 5);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let want = matmul(&a, &b, m, k, n);
        // b^T stored as (n,k)
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        assert_eq!(matmul_nt(&a, &bt, m, k, n), want);
        // a^T stored as (k,m)
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let got = matmul_tn(&at, &b, k, m, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    /// Relative-tolerance comparison used by the blocked-vs-naive checks.
    fn assert_rel_close(got: &[f32], want: &[f32], rel: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: len");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = rel * (g.abs() + w.abs()) + 1e-6;
            assert!((g - w).abs() <= tol, "{what}[{i}]: {g} vs {w}");
        }
    }

    #[test]
    fn blocked_matmuls_match_naive_reference() {
        // a few irregular shapes here; the full odd/prime-shape sweep
        // lives in tests/kernel_parity.rs
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 9), (13, 3, 21), (8, 16, 8)] {
            let a = randv(&mut rng, m * k, 1.0);
            let b = randv(&mut rng, k * n, 1.0);
            assert_rel_close(&matmul(&a, &b, m, k, n), &matmul_ref(&a, &b, m, k, n), 1e-4, "mm");
            let bt = randv(&mut rng, n * k, 1.0);
            assert_rel_close(
                &matmul_nt(&a, &bt, m, k, n),
                &matmul_nt_ref(&a, &bt, m, k, n),
                1e-4,
                "nt",
            );
            let at = randv(&mut rng, k * m, 1.0);
            assert_rel_close(
                &matmul_tn(&at, &b, k, m, n),
                &matmul_tn_ref(&at, &b, k, m, n),
                1e-4,
                "tn",
            );
        }
    }

    #[test]
    fn parallel_matmul_is_byte_identical_to_serial() {
        let mut rng = Rng::new(43);
        let (m, k, n) = (37, 19, 23);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let serial = crate::sweep::scope::with_budget(1, || matmul(&a, &b, m, k, n));
        for budget in [2usize, 3, 8] {
            let mut par = vec![0.0f32; m * n];
            crate::sweep::scope::with_budget(budget, || {
                // bypass the size gate: band the rows explicitly
                crate::sweep::scope::par_rows(&mut par, n, |row0, band| {
                    let rows = band.len() / n;
                    matmul_into(&a[row0 * k..(row0 + rows) * k], b.as_slice(), band, rows, k, n);
                });
            });
            assert!(
                serial.iter().zip(&par).all(|(x, y)| x.to_bits() == y.to_bits()),
                "budget {budget}"
            );
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let p = softmax_rows(&x, 3);
        for row in p.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone logits
        }
    }

    #[test]
    fn rmsnorm_unit_gain_unit_rms() {
        let x = vec![3.0f32, -4.0]; // rms^2 = 12.5
        let g = vec![1.0f32, 1.0];
        let y = rmsnorm(&x, &g);
        let ms: f32 = y.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-4, "ms={ms}");
    }

    #[test]
    fn embed_roundtrip_adjoint() {
        // <lookup(E), dX> == <E, scatter(dX)>
        let mut rng = Rng::new(2);
        let (v, m) = (6, 4);
        let embed = randv(&mut rng, v * m, 1.0);
        let tokens = vec![0i32, 3, 3, 5];
        let dx = randv(&mut rng, tokens.len() * m, 1.0);
        let x = embed_lookup(&embed, &tokens, m);
        let de = embed_scatter(&tokens, &dx, v, m);
        let lhs: f32 = x.iter().zip(&dx).map(|(a, b)| a * b).sum();
        let rhs: f32 = embed.iter().zip(&de).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn attention_causal_first_token_attends_self_only() {
        let mut rng = Rng::new(3);
        let (n, d) = (4, 2);
        let q = randv(&mut rng, n * d, 1.0);
        let k = randv(&mut rng, n * d, 1.0);
        let v = randv(&mut rng, n * d, 1.0);
        let (w, o) = attention_causal(&q, &k, &v, n, d);
        // row 0 can only see position 0
        assert!((w[0] - 1.0).abs() < 1e-6);
        for j in 1..n {
            assert!(w[j].abs() < 1e-6);
        }
        assert!((o[0] - v[0]).abs() < 1e-5);
        // every row is a distribution
        for row in w.chunks_exact(n) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gating_selects_top_probs_and_renormalizes() {
        // 1 token, 4 experts, clear margins
        let logits = vec![2.0f32, -1.0, 0.5, -2.0];
        let g = gating_topk(&logits, 4, 2);
        assert_eq!(g.idx, vec![0, 2]);
        assert!((g.gate.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(g.gate[0] > g.gate[1]);
        let psum: f32 = g.probs.iter().sum();
        assert!((psum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gating_ties_go_to_smaller_index() {
        let logits = vec![1.0f32, 1.0, 0.0, 0.0];
        let g = gating_topk(&logits, 4, 2);
        assert_eq!(g.idx, vec![0, 1]);
    }

    #[test]
    fn expert_ffn_matches_scalar_reference() {
        // 1 expert, 1 token, m=2, h=2, hand-computed
        let x = vec![1.0f32, 2.0];
        let w1 = vec![1.0f32, -1.0, 0.5, 1.0]; // (m=2, h=2) row-major
        let w2 = vec![1.0f32, 0.0, 2.0, 1.0]; // (h=2, m=2)
        // hid = [1*1+2*0.5, 1*-1+2*1] = [2, 1]; relu same
        // out = [2*1+1*2, 2*0+1*1] = [4, 1]
        let out = expert_ffn(&x, &w1, &w2, 1, 1, 2, 2);
        assert_eq!(out, vec![4.0, 1.0]);
    }

    #[test]
    fn expert_ffn_relu_mask_blocks_gradient() {
        // hid = [2, -3]: the negative unit must contribute no gradient
        let x = vec![1.0f32, 2.0];
        let w1 = vec![1.0f32, -1.0, 0.5, -1.0]; // hid = [2, -3]
        let w2 = vec![1.0f32, 0.0, 2.0, 1.0];
        let dy = vec![1.0f32, 1.0];
        let (dx, dw1, dw2) = expert_ffn_bwd(&x, &w1, &w2, &dy, 1, 1, 2, 2);
        // dhid = dy @ w2^T = [1, 3] before mask -> [1, 0]
        // dx = dhid @ w1^T = [1*1 + 0*-1, 1*0.5 + 0*-1] = [1, 0.5]
        assert_eq!(dx, vec![1.0, 0.5]);
        // dw1 = x^T @ dhid = [[1,0],[2,0]]
        assert_eq!(dw1, vec![1.0, 0.0, 2.0, 0.0]);
        // dw2 = relu(hid)^T @ dy = [[2,2],[0,0]]
        assert_eq!(dw2, vec![2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn expert_ffn_bwd_adjoint_on_x() {
        // <ffn(x+tv) - ffn(x-tv), w>/(2t) ~= <dx, v> for smooth region
        let mut rng = Rng::new(7);
        let (e, c, m, h) = (2usize, 3usize, 4usize, 5usize);
        // keep hidden units well away from the ReLU kink
        let x: Vec<f32> = (0..e * c * m).map(|_| 0.5 + rng.f32()).collect();
        let w1: Vec<f32> = (0..e * m * h).map(|_| 0.2 + rng.f32()).collect();
        let w2 = randv(&mut rng, e * h * m, 0.5);
        let wt = randv(&mut rng, e * c * m, 1.0);
        let (dx, _, _) = expert_ffn_bwd(&x, &w1, &w2, &wt, e, c, m, h);
        let v = randv(&mut rng, x.len(), 1.0);
        let eps = 1e-3f32;
        let xp: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let xm: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let fp: f32 = expert_ffn(&xp, &w1, &w2, e, c, m, h).iter().zip(&wt).map(|(a, b)| a * b).sum();
        let fm: f32 = expert_ffn(&xm, &w1, &w2, e, c, m, h).iter().zip(&wt).map(|(a, b)| a * b).sum();
        let fd = (fp - fm) / (2.0 * eps);
        let an: f32 = dx.iter().zip(&v).map(|(a, b)| a * b).sum();
        assert!((fd - an).abs() < 0.05 * (an.abs() + 1.0), "fd={fd} an={an}");
    }
}
