//! Dense f32 CPU kernels for the native execution backend.
//!
//! Every op here is an exact host-side mirror of a `python/compile`
//! primitive (`kernels/ref.py` semantics): same masking constants, same
//! epsilons, same tie-breaking, so a native run is numerically
//! interchangeable with an artifact run up to summation order. Each
//! forward has a hand-derived backward; `tests/gradcheck_native.rs`
//! checks every pair against central finite differences.
//!
//! Shapes are row-major flat `&[f32]` slices; dimensions are passed
//! explicitly (the backend derives them from the artifact manifest).

/// `a (m,k) @ b (k,n) -> (m,n)`.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `a (m,k) @ b^T` with `b (n,k)` -> `(m,n)` (rows of b are the columns).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            out[i * n + j] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
    out
}

/// `a^T @ b` with `a (k,m)`, `b (k,n)` -> `(m,n)`.
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Row-wise softmax over `(t, n)`, numerically stable (max subtraction).
pub fn softmax_rows(x: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len() % n, 0);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = (v - mx).exp();
            sum += *o;
        }
        for o in orow.iter_mut() {
            *o /= sum;
        }
    }
    out
}

/// Backward of row-wise softmax: `dx_i = p_i * (dp_i - sum_j dp_j p_j)`.
pub fn softmax_bwd_rows(p: &[f32], dp: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(p.len(), dp.len());
    let mut out = vec![0.0f32; p.len()];
    for ((prow, dprow), orow) in p
        .chunks_exact(n)
        .zip(dp.chunks_exact(n))
        .zip(out.chunks_exact_mut(n))
    {
        let dot: f32 = prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
        for ((o, &pv), &dpv) in orow.iter_mut().zip(prow).zip(dprow) {
            *o = pv * (dpv - dot);
        }
    }
    out
}

/// RMSNorm epsilon (matches `ref.rmsnorm_ref`).
pub const RMS_EPS: f32 = 1e-6;

/// RMSNorm over the last axis of `(t, m)` with learnable gain `g (m,)`.
pub fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let m = g.len();
    debug_assert_eq!(x.len() % m, 0);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(m).zip(out.chunks_exact_mut(m)) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / m as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        for ((o, &xv), &gv) in orow.iter_mut().zip(row).zip(g) {
            *o = xv * r * gv;
        }
    }
    out
}

/// Backward of [`rmsnorm`]: returns `(dx, dg)`.
///
/// With `r = (mean(x^2) + eps)^{-1/2}`:
/// `dx_j = r g_j dy_j - r^3 x_j / m * sum_i dy_i g_i x_i`,
/// `dg_j = sum_rows dy_j x_j r`.
pub fn rmsnorm_bwd(x: &[f32], g: &[f32], dy: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let m = g.len();
    debug_assert_eq!(x.len(), dy.len());
    let mut dx = vec![0.0f32; x.len()];
    let mut dg = vec![0.0f32; m];
    for ((row, dyrow), dxrow) in x
        .chunks_exact(m)
        .zip(dy.chunks_exact(m))
        .zip(dx.chunks_exact_mut(m))
    {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / m as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        let s: f32 = dyrow
            .iter()
            .zip(row)
            .zip(g)
            .map(|((&d, &xv), &gv)| d * gv * xv)
            .sum();
        let r3s = r * r * r * s / m as f32;
        for (j, (dxv, &xv)) in dxrow.iter_mut().zip(row).enumerate() {
            *dxv = r * g[j] * dyrow[j] - r3s * xv;
            dg[j] += dyrow[j] * xv * r;
        }
    }
    (dx, dg)
}

/// Embedding lookup with the model's `sqrt(M)` scale: `x_t = embed[tok_t] * sqrt(m)`.
pub fn embed_lookup(embed: &[f32], tokens: &[i32], m: usize) -> Vec<f32> {
    let scale = (m as f64).sqrt() as f32;
    let mut out = vec![0.0f32; tokens.len() * m];
    for (t, &tok) in tokens.iter().enumerate() {
        let src = tok as usize * m;
        for (o, &e) in out[t * m..(t + 1) * m].iter_mut().zip(&embed[src..src + m]) {
            *o = e * scale;
        }
    }
    out
}

/// Backward of [`embed_lookup`]: scatter-add `dx * sqrt(m)` into `(vocab, m)`.
pub fn embed_scatter(tokens: &[i32], dx: &[f32], vocab: usize, m: usize) -> Vec<f32> {
    let scale = (m as f64).sqrt() as f32;
    let mut de = vec![0.0f32; vocab * m];
    for (t, &tok) in tokens.iter().enumerate() {
        let dst = tok as usize * m;
        for (o, &d) in de[dst..dst + m].iter_mut().zip(&dx[t * m..(t + 1) * m]) {
            *o += d * scale;
        }
    }
    de
}

/// Causal mask fill value (matches `ref.attention_causal_ref`).
const MASK_NEG: f32 = -1e30;

/// Causal scaled-dot-product attention for one (batch, head): `q,k,v (n,d)`.
/// Returns `(weights (n,n), out (n,d))`; the weights are kept for backward.
pub fn attention_causal(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f64).sqrt() as f32;
    let mut s = matmul_nt(q, k, n, d, n);
    for i in 0..n {
        for (j, x) in s[i * n..(i + 1) * n].iter_mut().enumerate() {
            if j > i {
                *x = MASK_NEG;
            } else {
                *x *= scale;
            }
        }
    }
    let w = softmax_rows(&s, n);
    let o = matmul(&w, v, n, n, d);
    (w, o)
}

/// Backward of [`attention_causal`] given the saved weights `w` and the
/// output cotangent `do_`: returns `(dq, dk, dv)`. Masked positions carry
/// zero weight, so the softmax backward zeroes their score gradient
/// automatically.
pub fn attention_causal_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    w: &[f32],
    do_: &[f32],
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f64).sqrt() as f32;
    let dv = matmul_tn(w, do_, n, n, d);
    let dw = matmul_nt(do_, v, n, d, n);
    let mut ds = softmax_bwd_rows(w, &dw, n);
    for x in ds.iter_mut() {
        *x *= scale;
    }
    let dq = matmul(&ds, k, n, n, d);
    let dk = matmul_tn(&ds, q, n, n, d);
    (dq, dk, dv)
}

/// Renormalization floor of the top-k gate weights (matches `ref.gating_ref`).
pub const GATE_EPS: f32 = 1e-9;

/// Output of [`gating_topk`].
pub struct Gating {
    /// `(t, e)` full softmax probabilities.
    pub probs: Vec<f32>,
    /// `(t, k)` selected expert ids, by descending probability (ties to
    /// the smaller index, matching `ref.topk_ref`).
    pub idx: Vec<i32>,
    /// `(t, k)` gate weights renormalized over the selected experts.
    pub gate: Vec<f32>,
}

/// Top-k softmax gating over logits `(t, e)` — mirror of `ref.gating_ref`
/// composed with the logits it is fed (`u @ wg` happens in the caller).
pub fn gating_topk(logits: &[f32], e: usize, k: usize) -> Gating {
    let t = logits.len() / e;
    let probs = softmax_rows(logits, e);
    let mut idx = vec![0i32; t * k];
    let mut gate = vec![0.0f32; t * k];
    for ti in 0..t {
        let row = &probs[ti * e..(ti + 1) * e];
        let mut work: Vec<f32> = row.to_vec();
        let mut raw_sum = 0.0f32;
        for ki in 0..k {
            let best = work.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let first = work.iter().position(|&v| v == best).unwrap();
            idx[ti * k + ki] = first as i32;
            gate[ti * k + ki] = row[first];
            raw_sum += row[first];
            work[first] = f32::NEG_INFINITY;
        }
        let denom = raw_sum.max(GATE_EPS);
        for g in gate[ti * k..(ti + 1) * k].iter_mut() {
            *g /= denom;
        }
    }
    Gating { probs, idx, gate }
}

/// Backward of [`gating_topk`] w.r.t. the logits, given the cotangent of
/// the renormalized gate weights. The top-k selection is a fixed gather
/// (non-differentiable choice, like `lax.top_k`): gradients scatter to
/// the selected probability entries only, then flow through the softmax.
pub fn gating_topk_bwd(g: &Gating, e: usize, k: usize, dgate: &[f32]) -> Vec<f32> {
    let t = g.idx.len() / k;
    let mut dprobs = vec![0.0f32; t * e];
    for ti in 0..t {
        let raw: Vec<f32> = (0..k).map(|ki| g.probs[ti * e + g.idx[ti * k + ki] as usize]).collect();
        let raw_sum: f32 = raw.iter().sum();
        let drow = &dgate[ti * k..(ti + 1) * k];
        if raw_sum > GATE_EPS {
            // gate_i = raw_i / D, D = sum(raw): d raw_j = dg_j/D - s/D^2
            let s: f32 = drow.iter().zip(&raw).map(|(d, r)| d * r).sum();
            for ki in 0..k {
                let draw = drow[ki] / raw_sum - s / (raw_sum * raw_sum);
                dprobs[ti * e + g.idx[ti * k + ki] as usize] += draw;
            }
        } else {
            // denominator clamped to the constant GATE_EPS
            for ki in 0..k {
                dprobs[ti * e + g.idx[ti * k + ki] as usize] += drow[ki] / GATE_EPS;
            }
        }
    }
    softmax_bwd_rows(&g.probs, &dprobs, e)
}

/// Batched expert FFN — mirror of `ref.expert_ffn_ref`:
/// per expert `e`: `relu(x_e @ w1_e) @ w2_e` with `x (e,c,m)`,
/// `w1 (e,m,h)`, `w2 (e,h,m)`.
pub fn expert_ffn(x: &[f32], w1: &[f32], w2: &[f32], e: usize, c: usize, m: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; e * c * m];
    for ei in 0..e {
        let xe = &x[ei * c * m..(ei + 1) * c * m];
        let w1e = &w1[ei * m * h..(ei + 1) * m * h];
        let w2e = &w2[ei * h * m..(ei + 1) * h * m];
        let mut hid = matmul(xe, w1e, c, m, h);
        for v in hid.iter_mut() {
            *v = v.max(0.0);
        }
        out[ei * c * m..(ei + 1) * c * m].copy_from_slice(&matmul(&hid, w2e, c, h, m));
    }
    out
}

/// Backward of [`expert_ffn`] (recompute): returns `(dx, dw1, dw2)`.
/// ReLU gradient at exactly 0 is 0 (the JAX convention).
#[allow(clippy::too_many_arguments)]
pub fn expert_ffn_bwd(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    dy: &[f32],
    e: usize,
    c: usize,
    m: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; e * c * m];
    let mut dw1 = vec![0.0f32; e * m * h];
    let mut dw2 = vec![0.0f32; e * h * m];
    for ei in 0..e {
        let xe = &x[ei * c * m..(ei + 1) * c * m];
        let w1e = &w1[ei * m * h..(ei + 1) * m * h];
        let w2e = &w2[ei * h * m..(ei + 1) * h * m];
        let dye = &dy[ei * c * m..(ei + 1) * c * m];
        let hid = matmul(xe, w1e, c, m, h);
        let hr: Vec<f32> = hid.iter().map(|&v| v.max(0.0)).collect();
        let mut dhid = matmul_nt(dye, w2e, c, m, h);
        for (dv, &pre) in dhid.iter_mut().zip(&hid) {
            if pre <= 0.0 {
                *dv = 0.0;
            }
        }
        dw2[ei * h * m..(ei + 1) * h * m].copy_from_slice(&matmul_tn(&hr, dye, c, h, m));
        dw1[ei * m * h..(ei + 1) * m * h].copy_from_slice(&matmul_tn(xe, &dhid, c, m, h));
        dx[ei * c * m..(ei + 1) * c * m].copy_from_slice(&matmul_nt(&dhid, w1e, c, h, m));
    }
    (dx, dw1, dw2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * s).collect()
    }

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // (2,2)
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (3, 4, 5);
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let want = matmul(&a, &b, m, k, n);
        // b^T stored as (n,k)
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        assert_eq!(matmul_nt(&a, &bt, m, k, n), want);
        // a^T stored as (k,m)
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let got = matmul_tn(&at, &b, k, m, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let p = softmax_rows(&x, 3);
        for row in p.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1])); // monotone logits
        }
    }

    #[test]
    fn rmsnorm_unit_gain_unit_rms() {
        let x = vec![3.0f32, -4.0]; // rms^2 = 12.5
        let g = vec![1.0f32, 1.0];
        let y = rmsnorm(&x, &g);
        let ms: f32 = y.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-4, "ms={ms}");
    }

    #[test]
    fn embed_roundtrip_adjoint() {
        // <lookup(E), dX> == <E, scatter(dX)>
        let mut rng = Rng::new(2);
        let (v, m) = (6, 4);
        let embed = randv(&mut rng, v * m, 1.0);
        let tokens = vec![0i32, 3, 3, 5];
        let dx = randv(&mut rng, tokens.len() * m, 1.0);
        let x = embed_lookup(&embed, &tokens, m);
        let de = embed_scatter(&tokens, &dx, v, m);
        let lhs: f32 = x.iter().zip(&dx).map(|(a, b)| a * b).sum();
        let rhs: f32 = embed.iter().zip(&de).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn attention_causal_first_token_attends_self_only() {
        let mut rng = Rng::new(3);
        let (n, d) = (4, 2);
        let q = randv(&mut rng, n * d, 1.0);
        let k = randv(&mut rng, n * d, 1.0);
        let v = randv(&mut rng, n * d, 1.0);
        let (w, o) = attention_causal(&q, &k, &v, n, d);
        // row 0 can only see position 0
        assert!((w[0] - 1.0).abs() < 1e-6);
        for j in 1..n {
            assert!(w[j].abs() < 1e-6);
        }
        assert!((o[0] - v[0]).abs() < 1e-5);
        // every row is a distribution
        for row in w.chunks_exact(n) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gating_selects_top_probs_and_renormalizes() {
        // 1 token, 4 experts, clear margins
        let logits = vec![2.0f32, -1.0, 0.5, -2.0];
        let g = gating_topk(&logits, 4, 2);
        assert_eq!(g.idx, vec![0, 2]);
        assert!((g.gate.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(g.gate[0] > g.gate[1]);
        let psum: f32 = g.probs.iter().sum();
        assert!((psum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gating_ties_go_to_smaller_index() {
        let logits = vec![1.0f32, 1.0, 0.0, 0.0];
        let g = gating_topk(&logits, 4, 2);
        assert_eq!(g.idx, vec![0, 1]);
    }

    #[test]
    fn expert_ffn_matches_scalar_reference() {
        // 1 expert, 1 token, m=2, h=2, hand-computed
        let x = vec![1.0f32, 2.0];
        let w1 = vec![1.0f32, -1.0, 0.5, 1.0]; // (m=2, h=2) row-major
        let w2 = vec![1.0f32, 0.0, 2.0, 1.0]; // (h=2, m=2)
        // hid = [1*1+2*0.5, 1*-1+2*1] = [2, 1]; relu same
        // out = [2*1+1*2, 2*0+1*1] = [4, 1]
        let out = expert_ffn(&x, &w1, &w2, 1, 1, 2, 2);
        assert_eq!(out, vec![4.0, 1.0]);
    }

    #[test]
    fn expert_ffn_relu_mask_blocks_gradient() {
        // hid = [2, -3]: the negative unit must contribute no gradient
        let x = vec![1.0f32, 2.0];
        let w1 = vec![1.0f32, -1.0, 0.5, -1.0]; // hid = [2, -3]
        let w2 = vec![1.0f32, 0.0, 2.0, 1.0];
        let dy = vec![1.0f32, 1.0];
        let (dx, dw1, dw2) = expert_ffn_bwd(&x, &w1, &w2, &dy, 1, 1, 2, 2);
        // dhid = dy @ w2^T = [1, 3] before mask -> [1, 0]
        // dx = dhid @ w1^T = [1*1 + 0*-1, 1*0.5 + 0*-1] = [1, 0.5]
        assert_eq!(dx, vec![1.0, 0.5]);
        // dw1 = x^T @ dhid = [[1,0],[2,0]]
        assert_eq!(dw1, vec![1.0, 0.0, 2.0, 0.0]);
        // dw2 = relu(hid)^T @ dy = [[2,2],[0,0]]
        assert_eq!(dw2, vec![2.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn expert_ffn_bwd_adjoint_on_x() {
        // <ffn(x+tv) - ffn(x-tv), w>/(2t) ~= <dx, v> for smooth region
        let mut rng = Rng::new(7);
        let (e, c, m, h) = (2usize, 3usize, 4usize, 5usize);
        // keep hidden units well away from the ReLU kink
        let x: Vec<f32> = (0..e * c * m).map(|_| 0.5 + rng.f32()).collect();
        let w1: Vec<f32> = (0..e * m * h).map(|_| 0.2 + rng.f32()).collect();
        let w2 = randv(&mut rng, e * h * m, 0.5);
        let wt = randv(&mut rng, e * c * m, 1.0);
        let (dx, _, _) = expert_ffn_bwd(&x, &w1, &w2, &wt, e, c, m, h);
        let v = randv(&mut rng, x.len(), 1.0);
        let eps = 1e-3f32;
        let xp: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let xm: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let fp: f32 = expert_ffn(&xp, &w1, &w2, e, c, m, h).iter().zip(&wt).map(|(a, b)| a * b).sum();
        let fm: f32 = expert_ffn(&xm, &w1, &w2, e, c, m, h).iter().zip(&wt).map(|(a, b)| a * b).sum();
        let fd = (fp - fm) / (2.0 * eps);
        let an: f32 = dx.iter().zip(&v).map(|(a, b)| a * b).sum();
        assert!((fd - an).abs() < 0.05 * (an.abs() + 1.0), "fd={fd} an={an}");
    }
}
