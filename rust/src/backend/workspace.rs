//! Scratch-buffer arena for the native backend's hot path.
//!
//! One training step allocates dozens of large f32 temporaries
//! (activations, per-tensor gradients, attention projections, expert
//! dispatch slabs). Allocating them fresh in every `block_fwd/bwd` call
//! of every layer of every step churns the allocator and defeats cache
//! reuse. [`Workspace`] is a deliberately simple pool: [`Workspace::take`]
//! hands out a zeroed `Vec<f32>` (reusing the best-fitting retired
//! buffer), [`Workspace::put`] retires one. The model functions in
//! [`super::model`] thread a `&mut Workspace` through the whole
//! forward/backward so temporaries recycle across layers, and
//! [`super::NativeBackend`] keeps one workspace alive across `execute`
//! calls so they also recycle across steps.
//!
//! Buffers are plain `Vec<f32>`s, so anything that must escape (returned
//! gradients, outputs) can be taken from the pool and moved out — it
//! simply doesn't come back.
//!
//! Determinism: `take` always returns a zero-filled buffer of exactly
//! the requested length, so results are bit-identical whether a buffer
//! is fresh or recycled (asserted by `tests/kernel_parity.rs`).

/// Pool of reusable f32 scratch buffers. See the module docs.
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Number of retired buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Total f32 capacity currently pooled.
    pub fn pooled_elems(&self) -> usize {
        self.pool.iter().map(|v| v.capacity()).sum()
    }

    /// A zero-filled buffer of exactly `len` elements: the smallest
    /// pooled buffer whose capacity fits (else the largest pooled buffer,
    /// grown; else a fresh allocation).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None; // smallest adequate
        let mut largest: Option<usize> = None;
        for (i, buf) in self.pool.iter().enumerate() {
            let cap = buf.capacity();
            if cap >= len && best.is_none_or(|b| cap < self.pool[b].capacity()) {
                best = Some(i);
            }
            if largest.is_none_or(|l| cap > self.pool[l].capacity()) {
                largest = Some(i);
            }
        }
        let mut v = match best.or(largest) {
            Some(i) => self.pool.swap_remove(i),
            None => Vec::with_capacity(len),
        };
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Retire a buffer into the pool for later [`Workspace::take`] reuse.
    /// Zero-capacity buffers are dropped (nothing to reuse).
    pub fn put(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.pool.push(v);
        }
    }

    /// Retire every buffer of an iterator (convenience for states).
    pub fn put_all<I: IntoIterator<Item = Vec<f32>>>(&mut self, bufs: I) {
        for v in bufs {
            self.put(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_exact_len() {
        let mut ws = Workspace::new();
        let mut a = ws.take(8);
        assert_eq!(a, vec![0.0f32; 8]);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.put(a);
        // recycled buffer comes back zeroed, at the new length
        let b = ws.take(5);
        assert_eq!(b, vec![0.0f32; 5]);
        assert!(b.capacity() >= 8, "recycled the retired allocation");
    }

    #[test]
    fn take_prefers_smallest_adequate_buffer() {
        let mut ws = Workspace::new();
        ws.put(Vec::with_capacity(100));
        ws.put(Vec::with_capacity(10));
        let v = ws.take(8);
        assert!(v.capacity() >= 8 && v.capacity() < 100, "cap {}", v.capacity());
        assert_eq!(ws.pooled(), 1); // the 100-cap buffer remains
    }

    #[test]
    fn take_grows_largest_when_nothing_fits() {
        let mut ws = Workspace::new();
        ws.put(Vec::with_capacity(4));
        let v = ws.take(64);
        assert_eq!(v.len(), 64);
        assert_eq!(ws.pooled(), 0);
    }

    #[test]
    fn put_all_retires_everything() {
        let mut ws = Workspace::new();
        ws.put_all(vec![vec![1.0f32; 3], vec![2.0f32; 5], Vec::new()]);
        assert_eq!(ws.pooled(), 2); // the empty vec is dropped
    }
}
