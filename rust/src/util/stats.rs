//! Basic statistics over f64 samples (no external crates offline).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile p in [0, 100]; 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
    }
}
