//! PCG32: a seeded, *splittable* PRNG for reproducible synthetic
//! workloads.
//!
//! [`super::Rng`] (xoshiro256**) is the crate's general-purpose
//! generator, but it has no principled way to derive independent
//! sub-streams: callers have been XOR-ing worker ids into seeds, which
//! couples every consumer's draw order to every other's. PCG32
//! (O'Neill 2014) carries an explicit stream-selector increment, so
//! [`Pcg32::split`] can hand out a child generator on a fresh stream —
//! seeded *and* sequenced from the parent's output — without perturbing
//! the parent's own sequence beyond the two draws that derived the
//! child. The serving traffic generator ([`crate::serve::traffic`])
//! splits one `--seed` into arrival/length/token streams this way, and
//! the synthetic corpus ([`crate::data`]) builds its per-domain bigram
//! permutations from split streams instead of an ad-hoc LCG.
//!
//! The output function is the reference `XSH RR` variant; the test
//! vector below pins it to the canonical `pcg32_srandom(42, 54)`
//! sequence from the PCG paper's minimal C implementation.

/// The PCG default multiplier (same LCG family as Knuth's MMIX).
const PCG_MULT: u64 = 6364136223846793005;

/// 32-bit PCG generator (`XSH RR 64/32`) with an explicit stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    /// Stream selector: always odd, so every stream is full-period.
    inc: u64,
}

impl Pcg32 {
    /// Seeded generator on stream 0.
    pub fn new(seed: u64) -> Pcg32 {
        Pcg32::new_stream(seed, 0)
    }

    /// Seeded generator on an explicit stream (the canonical
    /// `pcg32_srandom(seed, stream)` init sequence).
    pub fn new_stream(seed: u64, stream: u64) -> Pcg32 {
        let mut p = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        p.next_u32();
        p.state = p.state.wrapping_add(seed);
        p.next_u32();
        p
    }

    /// Next 32-bit output (`XSH RR`: xorshift-high, random rotate).
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws, high word first).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Derive an independent child generator: seed and stream selector
    /// both come from the parent's own output, so `split()` advances the
    /// parent by exactly four 32-bit draws and children taken in
    /// sequence land on distinct streams.
    pub fn split(&mut self) -> Pcg32 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg32::new_stream(seed, stream)
    }

    /// Uniform f64 in [0, 1) (53-bit mantissa, same recipe as
    /// [`super::Rng::f64`]).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Exponential variate with the given mean (inter-arrival gaps of a
    /// Poisson process).
    pub fn exp(&mut self, mean: f64) -> f64 {
        // 1 - f64() is in (0, 1], so the log is finite
        -(1.0 - self.f64()).ln() * mean
    }

    /// Sample an index from a CDF built by [`super::rng::zipf_cdf`].
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        match cdf.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Snapshot `(state, inc)` (for checkpoint/restore).
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::state`] snapshot; the
    /// restored stream continues bitwise where the snapshot was taken.
    pub fn from_state(state: (u64, u64)) -> Pcg32 {
        Pcg32 {
            state: state.0,
            inc: state.1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_reference_pcg32_vector() {
        // First outputs of the PCG paper's minimal C implementation
        // after pcg32_srandom(42u, 54u).
        let mut p = Pcg32::new_stream(42, 54);
        let got: Vec<u32> = (0..6).map(|_| p.next_u32()).collect();
        assert_eq!(got, vec![0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b, 0xcbed606e]);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        fn seq(seed: u64) -> Vec<u32> {
            let mut p = Pcg32::new(seed);
            (0..8).map(|_| p.next_u32()).collect()
        }
        assert_eq!(seq(7), seq(7));
        assert_ne!(seq(7), seq(8));
    }

    #[test]
    fn split_streams_are_independent_and_reproducible() {
        let mut root1 = Pcg32::new(11);
        let mut root2 = Pcg32::new(11);
        let mut a1 = root1.split();
        let mut b1 = root1.split();
        let mut a2 = root2.split();
        let mut b2 = root2.split();
        let sa1: Vec<u32> = (0..16).map(|_| a1.next_u32()).collect();
        let sb1: Vec<u32> = (0..16).map(|_| b1.next_u32()).collect();
        let sa2: Vec<u32> = (0..16).map(|_| a2.next_u32()).collect();
        let sb2: Vec<u32> = (0..16).map(|_| b2.next_u32()).collect();
        assert_eq!(sa1, sa2, "same root seed => same first child");
        assert_eq!(sb1, sb2, "same root seed => same second child");
        assert_ne!(sa1, sb1, "sibling streams differ");
        // children do not echo the parent's continuation either
        let sp: Vec<u32> = (0..16).map(|_| root1.next_u32()).collect();
        assert_ne!(sa1, sp);
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Pcg32::new_stream(42, 54);
        for _ in 0..9 {
            a.next_u32();
        }
        let snap = a.state();
        let expect: Vec<u32> = (0..24).map(|_| a.next_u32()).collect();
        let mut b = Pcg32::from_state(snap);
        let got: Vec<u32> = (0..24).map(|_| b.next_u32()).collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn f64_in_unit_interval_and_exp_positive() {
        let mut p = Pcg32::new(3);
        for _ in 0..1000 {
            let u = p.f64();
            assert!((0.0..1.0).contains(&u));
            let e = p.exp(2.0);
            assert!(e.is_finite() && e >= 0.0);
        }
    }

    #[test]
    fn zipf_skews_toward_small_indices() {
        let cdf = crate::util::rng::zipf_cdf(64, 1.2);
        let mut p = Pcg32::new(5);
        let mut counts = vec![0usize; 64];
        for _ in 0..4000 {
            counts[p.zipf(&cdf)] += 1;
        }
        assert!(counts[0] > counts[10], "head of the Zipf law dominates: {counts:?}");
        assert_eq!(counts.iter().sum::<usize>(), 4000);
    }
}
