//! Small utilities: deterministic PRNGs, statistics, formatting, JSON
//! string escaping.
//!
//! The offline crate set has no `rand`, so we carry our own
//! xoshiro256**-based PRNG (seeded via SplitMix64) — deterministic across
//! platforms, which the simulator, the synthetic corpus and the property
//! tests all rely on — plus a splittable PCG32 ([`Pcg32`]) for workloads
//! that need independent per-consumer streams from a single seed.

pub mod pcg;
pub mod rng;
pub mod stats;

use std::sync::{Mutex, MutexGuard, PoisonError};

pub use pcg::Pcg32;
pub use rng::Rng;
pub use stats::{mean, median, percentile, stddev};

/// Lock a mutex, recovering the guard if a holder panicked. Poisoning
/// only records that a panic happened while the lock was held — for the
/// crate's uses (workspace arenas, metric stores, collective mailboxes)
/// the protected data stays structurally valid, and fault tolerance
/// requires that one worker's panic must not cascade `PoisonError`
/// unwraps through the survivors' recovery path.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Escape a string for embedding in a JSON string literal: backslash and
/// double quote get a backslash prefix, control characters become \u
/// escapes. Shared by the simulator's chrome-trace exporter and the
/// runtime span tracer ([`crate::obs`]) so both emit identical escaping.
/// (The original sim-local exporter *deleted* `"` from task names,
/// corrupting any quoted label.)
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format a duration in milliseconds with sensible precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.1}")
    } else if ms >= 1.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.4}")
    }
}

/// Format a byte count as a human-readable string.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2}{}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512.0), "512.00B");
        assert_eq!(fmt_bytes(2048.0), "2.00KB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0), "3.50MB");
    }

    #[test]
    fn fmt_ms_precision() {
        assert_eq!(fmt_ms(123.456), "123.5");
        assert_eq!(fmt_ms(12.345), "12.35");
        assert_eq!(fmt_ms(0.1234), "0.1234");
    }
}
