//! Deterministic xoshiro256** PRNG (seeded by SplitMix64).
//!
//! No external `rand` crate is available offline; this is the standard
//! public-domain xoshiro256** generator, sufficient for simulation noise,
//! synthetic data and property-test case generation.

/// Deterministic, cheaply cloneable PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (s=0 uniform).
    /// Inverse-CDF over precomputed weights is overkill here; rejection-free
    /// cumulative scan is fine for the corpus generator's n (<= vocab).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        match cdf.binary_search_by(|p| p.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random choice from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Snapshot the generator state (for checkpoint/restore).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot; the restored
    /// stream continues bitwise where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

/// Build a Zipf CDF over n items with exponent s.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|i| 1.0 / (i as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.01, "mean={m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn zipf_cdf_monotone_ends_at_one() {
        let cdf = zipf_cdf(100, 1.1);
        assert!(cdf.windows(2).all(|w| w[0] <= w[1]));
        assert!((cdf[99] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zipf_skews_low_indices() {
        let cdf = zipf_cdf(1000, 1.2);
        let mut r = Rng::new(11);
        let n = 50_000;
        let low = (0..n).filter(|_| r.zipf(&cdf) < 10).count();
        assert!(low as f64 / n as f64 > 0.3, "low fraction {}", low as f64 / n as f64);
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let expect: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let got: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
