//! Dep-free, CRC-checked, atomic training checkpoints.
//!
//! Binary layout (all integers little-endian):
//!
//! ```text
//! magic  b"FMCK"                      4 bytes
//! version u32 (= 1)                   4 bytes
//! crc32   u32 over everything below   4 bytes
//! cfg     u32 len + UTF-8 bytes
//! step    u64
//! workers u32, then workers x [u64; 4] corpus RNG states
//! params  u32 count, then per tensor: u64 len + len x f32
//! moms    u32 count, then per tensor: u64 len + len x f32
//! ```
//!
//! The CRC (IEEE 802.3, the zlib polynomial) is verified **before** any
//! payload parsing, so a bit-flipped or truncated file is rejected with
//! a typed [`CkptError`] — never a panic, never a silent partial load.
//! Writes go through a `.tmp` file + `sync_all` + atomic rename, so a
//! crash mid-write leaves at most a `.tmp` orphan and the previous
//! checkpoint intact; [`latest_valid`] then picks the newest file that
//! passes validation.

use std::fmt;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

/// Full native training state at a step boundary: everything needed to
/// continue bitwise — parameters, momentum buffers, the step counter,
/// and each worker's data-cursor PRNG state. The corpus *tables* are a
/// pure function of `(cfg, seed)` and are reconstructed, not stored.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Config preset name; restore refuses a mismatch.
    pub cfg: String,
    /// Steps completed when the snapshot was taken.
    pub step: u64,
    /// Per-worker corpus RNG state (index = DP rank).
    pub corpus_rng: Vec<[u64; 4]>,
    pub params: Vec<Vec<f32>>,
    pub moms: Vec<Vec<f32>>,
}

/// Typed checkpoint failure. Corruption is an `Err`, never a panic.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// File shorter than the fixed header.
    TooShort { len: usize },
    BadMagic,
    BadVersion { got: u32 },
    CrcMismatch { want: u32, got: u32 },
    /// Payload ended inside `field` (only reachable past a CRC match,
    /// i.e. on a collision — kept as defense in depth).
    Truncated { field: &'static str },
    Malformed { what: String },
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint io: {e}"),
            CkptError::TooShort { len } => write!(f, "checkpoint too short ({len} bytes)"),
            CkptError::BadMagic => write!(f, "not a checkpoint (bad magic)"),
            CkptError::BadVersion { got } => write!(f, "unsupported checkpoint version {got}"),
            CkptError::CrcMismatch { want, got } => {
                write!(f, "checkpoint corrupt: crc {got:08x}, expected {want:08x}")
            }
            CkptError::Truncated { field } => write!(f, "checkpoint truncated in {field}"),
            CkptError::Malformed { what } => write!(f, "checkpoint malformed: {what}"),
        }
    }
}

impl std::error::Error for CkptError {}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> CkptError {
        CkptError::Io(e)
    }
}

const MAGIC: &[u8; 4] = b"FMCK";
const VERSION: u32 = 1;
/// magic + version + crc
const HEADER: usize = 12;

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE, reflected, init/xorout `0xFFFFFFFF` — zlib's crc32).
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Bounds-checked little-endian reader over the payload.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize, field: &'static str) -> Result<&'a [u8], CkptError> {
        let end = self.i.checked_add(n).ok_or(CkptError::Truncated { field })?;
        if end > self.b.len() {
            return Err(CkptError::Truncated { field });
        }
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn u32(&mut self, field: &'static str) -> Result<u32, CkptError> {
        let s = self.take(4, field)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self, field: &'static str) -> Result<u64, CkptError> {
        let s = self.take(8, field)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(s);
        Ok(u64::from_le_bytes(a))
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Length-prefixed f32 vector; the length is validated against the
    /// remaining bytes *before* allocating, so an absurd corrupt length
    /// errors instead of attempting a huge allocation.
    fn f32_vec(&mut self, field: &'static str) -> Result<Vec<f32>, CkptError> {
        let len = self.u64(field)?;
        let n: usize = len.try_into().map_err(|_| CkptError::Malformed {
            what: format!("{field} length {len} overflows usize"),
        })?;
        if n > self.remaining() / 4 {
            return Err(CkptError::Malformed {
                what: format!("{field} length {n} exceeds remaining payload"),
            });
        }
        let s = self.take(n * 4, field)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Serialize to the on-disk byte layout (header + CRC included).
pub fn encode(ck: &Checkpoint) -> Vec<u8> {
    let mut pay = Vec::new();
    pay.extend_from_slice(&(ck.cfg.len() as u32).to_le_bytes());
    pay.extend_from_slice(ck.cfg.as_bytes());
    pay.extend_from_slice(&ck.step.to_le_bytes());
    pay.extend_from_slice(&(ck.corpus_rng.len() as u32).to_le_bytes());
    for s in &ck.corpus_rng {
        for w in s {
            pay.extend_from_slice(&w.to_le_bytes());
        }
    }
    for group in [&ck.params, &ck.moms] {
        pay.extend_from_slice(&(group.len() as u32).to_le_bytes());
        for t in group.iter() {
            pay.extend_from_slice(&(t.len() as u64).to_le_bytes());
            for x in t {
                pay.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    let mut out = Vec::with_capacity(HEADER + pay.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&crc32(&pay).to_le_bytes());
    out.extend_from_slice(&pay);
    out
}

/// Parse and validate the on-disk byte layout.
pub fn decode(bytes: &[u8]) -> Result<Checkpoint, CkptError> {
    if bytes.len() < HEADER {
        return Err(CkptError::TooShort { len: bytes.len() });
    }
    if &bytes[0..4] != MAGIC {
        return Err(CkptError::BadMagic);
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != VERSION {
        return Err(CkptError::BadVersion { got: version });
    }
    let want = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
    let got = crc32(&bytes[HEADER..]);
    if want != got {
        return Err(CkptError::CrcMismatch { want, got });
    }
    let mut cur = Cur {
        b: &bytes[HEADER..],
        i: 0,
    };
    let cfg_len = cur.u32("cfg")? as usize;
    if cfg_len > cur.remaining() {
        return Err(CkptError::Malformed {
            what: format!("cfg length {cfg_len} exceeds payload"),
        });
    }
    let cfg = std::str::from_utf8(cur.take(cfg_len, "cfg")?)
        .map_err(|e| CkptError::Malformed {
            what: format!("cfg not utf-8: {e}"),
        })?
        .to_string();
    let step = cur.u64("step")?;
    let n_workers = cur.u32("workers")? as usize;
    if n_workers > cur.remaining() / 32 {
        return Err(CkptError::Malformed {
            what: format!("worker count {n_workers} exceeds payload"),
        });
    }
    let mut corpus_rng = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let mut s = [0u64; 4];
        for w in &mut s {
            *w = cur.u64("corpus_rng")?;
        }
        corpus_rng.push(s);
    }
    let mut groups: [Vec<Vec<f32>>; 2] = [Vec::new(), Vec::new()];
    for (gi, name) in [(0, "params"), (1, "moms")] {
        let n = cur.u32(name)? as usize;
        if n > cur.remaining() / 8 {
            return Err(CkptError::Malformed {
                what: format!("{name} count {n} exceeds payload"),
            });
        }
        groups[gi] = (0..n).map(|_| cur.f32_vec(name)).collect::<Result<_, _>>()?;
    }
    if cur.remaining() != 0 {
        return Err(CkptError::Malformed {
            what: format!("{} trailing bytes", cur.remaining()),
        });
    }
    let [params, moms] = groups;
    Ok(Checkpoint {
        cfg,
        step,
        corpus_rng,
        params,
        moms,
    })
}

fn ckpt_name(step: u64) -> String {
    format!("ckpt_{step:010}.bin")
}

/// Write `ck` into `dir` atomically (`.tmp` + fsync + rename). Returns
/// the final path.
pub fn save_atomic(dir: &Path, ck: &Checkpoint) -> Result<PathBuf, CkptError> {
    fs::create_dir_all(dir)?;
    let name = ckpt_name(ck.step);
    let path = dir.join(&name);
    let tmp = dir.join(format!("{name}.tmp"));
    let bytes = encode(ck);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, &path)?;
    Ok(path)
}

/// Read and validate one checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
    decode(&fs::read(path)?)
}

/// Newest *valid* checkpoint in `dir`: candidates are `ckpt_<step>.bin`
/// files ordered by step descending; the first that passes full
/// validation wins, corrupt or truncated files are skipped. A missing
/// directory or no valid candidate is `Ok(None)`.
pub fn latest_valid(dir: &Path) -> Result<Option<(PathBuf, Checkpoint)>, CkptError> {
    if !dir.is_dir() {
        return Ok(None);
    }
    let mut steps: Vec<(u64, PathBuf)> = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(mid) = name.strip_prefix("ckpt_").and_then(|s| s.strip_suffix(".bin")) else {
            continue;
        };
        if mid.is_empty() || !mid.bytes().all(|b| b.is_ascii_digit()) {
            continue;
        }
        let Ok(step) = mid.parse::<u64>() else { continue };
        steps.push((step, entry.path()));
    }
    steps.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in steps {
        if let Ok(ck) = load(&path) {
            return Ok(Some((path, ck)));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            cfg: "tiny".to_string(),
            step: 12,
            corpus_rng: vec![[1, 2, 3, 4], [5, 6, 7, 8]],
            params: vec![vec![0.5, -1.25, 3.0], vec![2.0; 7]],
            moms: vec![vec![0.0, 0.125, -0.5], vec![0.25; 7]],
        }
    }

    #[test]
    fn crc32_reference_vector() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn encode_decode_roundtrip_bitwise() {
        let ck = sample();
        let bytes = encode(&ck);
        let back = decode(&bytes).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn empty_tensors_roundtrip() {
        let ck = Checkpoint {
            cfg: String::new(),
            step: 0,
            corpus_rng: vec![],
            params: vec![vec![]],
            moms: vec![vec![]],
        };
        assert_eq!(decode(&encode(&ck)).unwrap(), ck);
    }

    #[test]
    fn bit_flip_is_rejected_typed() {
        let bytes = encode(&sample());
        // flip one bit in a few representative positions across the file
        for pos in [0, 5, 9, HEADER, HEADER + 7, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            let err = decode(&bad).unwrap_err();
            assert!(
                matches!(
                    err,
                    CkptError::BadMagic | CkptError::BadVersion { .. } | CkptError::CrcMismatch { .. }
                ),
                "pos {pos}: unexpected {err:?}"
            );
        }
    }

    #[test]
    fn truncation_is_rejected_typed() {
        let bytes = encode(&sample());
        for keep in [0, 3, 11, HEADER, bytes.len() / 2, bytes.len() - 1] {
            let err = decode(&bytes[..keep]).unwrap_err();
            assert!(
                matches!(err, CkptError::TooShort { .. } | CkptError::CrcMismatch { .. }),
                "keep {keep}: unexpected {err:?}"
            );
        }
    }

    #[test]
    fn save_load_latest_valid() {
        let dir = std::env::temp_dir().join(format!("flowmoe_ckpt_test_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let mut a = sample();
        a.step = 4;
        let mut b = sample();
        b.step = 8;
        b.params[0][0] = 9.0;
        save_atomic(&dir, &a).unwrap();
        let pb = save_atomic(&dir, &b).unwrap();
        assert_eq!(pb.file_name().unwrap().to_str().unwrap(), "ckpt_0000000008.bin");
        let (path, got) = latest_valid(&dir).unwrap().unwrap();
        assert_eq!(path, pb);
        assert_eq!(got, b);
        // corrupt the newest: the older valid checkpoint must win
        let mut bytes = fs::read(&pb).unwrap();
        let n = bytes.len();
        bytes[n - 2] ^= 0xFF;
        fs::write(&pb, &bytes).unwrap();
        let (_, got) = latest_valid(&dir).unwrap().unwrap();
        assert_eq!(got, a, "newest is corrupt; older valid wins");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_none() {
        let dir = std::env::temp_dir().join("flowmoe_ckpt_never_created_xyzzy");
        assert!(latest_valid(&dir).unwrap().is_none());
    }
}
