//! Fault tolerance: deterministic checkpoint/restore, seeded fault
//! injection, and elastic recovery for the native training path.
//!
//! Three pieces (paper Appendix K, made real instead of modeled):
//!
//! 1. [`ckpt`] — dep-free CRC-checked atomic snapshots of the full
//!    training state (params, momenta, step counter, per-worker data
//!    cursors), with a bitwise resume contract: train 2N steps ==
//!    train N + checkpoint + restore + train N.
//! 2. [`fault`] — a seeded [`FaultPlan`] (worker kill at a step,
//!    per-message drop/delay) injected into
//!    [`crate::commpool::Collective`], whose deadline-bounded ops turn
//!    the hang class into typed [`crate::commpool::CommError`]s.
//! 3. Elastic recovery — on a detected failure the `trainer::train_dp`
//!    driver aborts the step, re-forms the collective at P−1 (re-sharding
//!    the casualty's experts via [`reshard_survivors`]), reloads the
//!    newest valid checkpoint and continues; each phase is timed under
//!    `ft_detect` / `ft_reshard` / `ft_restore` obs spans and recorded
//!    in `BENCH_fault.json` ([`bench_json`]).

pub mod ckpt;
pub mod fault;

pub use ckpt::{latest_valid, load, save_atomic, Checkpoint, CkptError};
pub use fault::{Delivery, FaultPlan};

/// Default checkpoint cadence (steps) when `--ckpt-dir` is set without
/// an explicit `--ckpt-every`.
pub const DEFAULT_CKPT_EVERY: usize = 10;

/// Default failure-detection window: a collective op that makes no
/// progress for this long surfaces a typed error instead of hanging.
pub const DETECT_TIMEOUT_MS: u64 = 30_000;

/// One completed recovery, as recorded by the `train_dp` driver. The
/// non-`*_ms` fields are a pure function of the options + fault seed
/// (they land in the deterministic block of `BENCH_fault.json`).
#[derive(Clone, Debug)]
pub struct RecoveryEvent {
    /// Rank retired from the group (the detected casualty).
    pub failed_rank: usize,
    /// Step the failure surfaced at.
    pub detected_step: usize,
    /// Step of the checkpoint training restarted from.
    pub ckpt_step: usize,
    /// Steps of work discarded: progress past the checkpoint when the
    /// failure hit.
    pub steps_lost: usize,
    /// World size after the recovery.
    pub p_after: usize,
    /// `reshard[e]` = survivor ranks serving expert `e` after recovery.
    pub reshard: Vec<Vec<usize>>,
    /// Kill -> error-surfaced latency (wall clock).
    pub detect_ms: f64,
    pub reshard_ms: f64,
    pub restore_ms: f64,
}

/// Re-shard `e` experts across `survivors` ranks after a failure,
/// ranked by observed routing `counts`. With at least as many survivors
/// as experts this is exactly the serving planner
/// ([`crate::serve::ep::plan_replicas`]); with fewer, experts are
/// assigned hottest-first to the least-loaded survivor (ties to the
/// smaller rank), so the doubled load of Appendix K.3 lands on as few
/// ranks as possible. Returns `assignment[e]` = survivor ranks serving
/// expert `e`.
pub fn reshard_survivors(e: usize, survivors: usize, counts: &[u64]) -> Vec<Vec<usize>> {
    debug_assert_eq!(counts.len(), e);
    assert!(survivors > 0, "cannot reshard onto zero survivors");
    if survivors >= e {
        return crate::serve::ep::plan_replicas(e, survivors, counts, survivors);
    }
    let mut order: Vec<usize> = (0..e).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(counts[i]), i));
    let mut load = vec![0u64; survivors];
    let mut assignment = vec![Vec::new(); e];
    for &ex in &order {
        let mut best = 0;
        for w in 1..survivors {
            if load[w] < load[best] {
                best = w;
            }
        }
        assignment[ex].push(best);
        load[best] += counts[ex].max(1);
    }
    assignment
}

/// Render `BENCH_fault.json`: the `"deterministic"` block is a pure
/// function of the options + fault seed (steps lost, reshard plans),
/// the `"timing"` block carries wall-clock recovery latencies — the
/// same split as `BENCH_serve.json`.
#[allow(clippy::too_many_arguments)]
pub fn bench_json(
    cfg: &str,
    fault_seed: u64,
    workers: usize,
    steps: usize,
    ckpt_every: usize,
    detect_ms: u64,
    events: &[RecoveryEvent],
    train_s: f64,
) -> String {
    let det_events = events
        .iter()
        .map(|ev| {
            let reshard = ev
                .reshard
                .iter()
                .map(|ranks| {
                    let inner = ranks.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(",");
                    format!("[{inner}]")
                })
                .collect::<Vec<_>>()
                .join(",");
            format!(
                concat!(
                    "      {{\n",
                    "        \"failed_rank\": {},\n",
                    "        \"detected_step\": {},\n",
                    "        \"ckpt_step\": {},\n",
                    "        \"steps_lost\": {},\n",
                    "        \"p_after\": {},\n",
                    "        \"reshard\": [{}]\n",
                    "      }}"
                ),
                ev.failed_rank, ev.detected_step, ev.ckpt_step, ev.steps_lost, ev.p_after, reshard
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let timing_events = events
        .iter()
        .map(|ev| {
            format!(
                concat!(
                    "      {{\n",
                    "        \"detect_ms\": {:.3},\n",
                    "        \"reshard_ms\": {:.3},\n",
                    "        \"restore_ms\": {:.3}\n",
                    "      }}"
                ),
                ev.detect_ms, ev.reshard_ms, ev.restore_ms
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let wrap = |body: String| if body.is_empty() { String::new() } else { format!("\n{body}\n    ") };
    format!(
        concat!(
            "{{\n",
            "  \"bench\": \"fault_tolerance\",\n",
            "  \"config\": \"{config}\",\n",
            "  \"fault_seed\": {seed},\n",
            "  \"workers\": {workers},\n",
            "  \"steps\": {steps},\n",
            "  \"ckpt_every\": {every},\n",
            "  \"detect_timeout_ms\": {detect},\n",
            "  \"deterministic\": {{\n",
            "    \"recoveries\": {n},\n",
            "    \"events\": [{det}]\n",
            "  }},\n",
            "  \"timing\": {{\n",
            "    \"train_s\": {train:.6},\n",
            "    \"events\": [{tim}]\n",
            "  }}\n",
            "}}\n"
        ),
        config = crate::util::json_escape(cfg),
        seed = fault_seed,
        workers = workers,
        steps = steps,
        every = ckpt_every,
        detect = detect_ms,
        n = events.len(),
        det = wrap(det_events),
        tim = wrap(timing_events),
        train = train_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshard_with_enough_survivors_matches_serving_planner() {
        let counts = [10, 0, 5, 1];
        let got = reshard_survivors(4, 6, &counts);
        assert_eq!(got, crate::serve::ep::plan_replicas(4, 6, &counts, 6));
        // every expert still served
        assert!(got.iter().all(|r| !r.is_empty()));
    }

    #[test]
    fn reshard_fewer_survivors_spreads_hot_experts() {
        // 4 experts onto 2 survivors: the two hottest must land on
        // different ranks, and every expert keeps exactly one server.
        let counts = [100, 90, 5, 1];
        let got = reshard_survivors(4, 2, &counts);
        assert!(got.iter().all(|r| r.len() == 1));
        assert_ne!(got[0], got[1], "hottest two experts split across survivors");
        let mut served = vec![0usize; 2];
        for r in &got {
            served[r[0]] += 1;
        }
        assert_eq!(served, vec![2, 2], "load balanced two experts per survivor");
    }

    #[test]
    fn reshard_single_survivor_takes_everything() {
        let got = reshard_survivors(3, 1, &[1, 2, 3]);
        assert_eq!(got, vec![vec![0], vec![0], vec![0]]);
    }

    #[test]
    fn bench_json_is_scan_clean_and_split() {
        let events = vec![RecoveryEvent {
            failed_rank: 2,
            detected_step: 5,
            ckpt_step: 4,
            steps_lost: 2,
            p_after: 2,
            reshard: vec![vec![0], vec![1], vec![0, 1]],
            detect_ms: 1.25,
            reshard_ms: 0.5,
            restore_ms: 3.75,
        }];
        let s = bench_json("tiny", 7, 3, 8, 2, 30_000, &events, 1.5);
        crate::testutil::scan_json(&s).unwrap();
        assert!(s.contains("\"deterministic\""));
        assert!(s.contains("\"timing\""));
        assert!(s.contains("\"steps_lost\": 2"));
        assert!(s.contains("\"reshard\": [[0],[1],[0,1]]"));
        // timing fields stay out of the deterministic block
        let det_end = s.find("\"timing\"").unwrap();
        assert!(!s[..det_end].contains("detect_ms\":"), "timing leaked into deterministic block");
    }

    #[test]
    fn bench_json_no_events_is_scan_clean() {
        let s = bench_json("tiny", 1, 2, 4, 0, 30_000, &[], 0.25);
        crate::testutil::scan_json(&s).unwrap();
        assert!(s.contains("\"recoveries\": 0"));
    }
}
