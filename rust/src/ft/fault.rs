//! Seeded fault-injection plans.
//!
//! A [`FaultPlan`] describes one failure scenario as pure data: at most
//! one planned worker kill, plus per-message drop/delay probabilities.
//! Every per-message decision is a keyed hash of `(seed, epoch, from,
//! to, tag)` — no global RNG stream — so injection is insensitive to
//! thread interleaving and the whole scenario replays bit-for-bit from
//! the seed. The attempt `epoch` is mixed in so a recovery re-run of the
//! same tags does not deterministically re-drop the exact messages that
//! failed the previous attempt.

/// One seeded failure scenario.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Keyed-hash seed for the drop/delay decisions.
    pub seed: u64,
    /// `Some((rank, step))`: that worker simulates a crash at that step.
    /// Fires at most once per collective (see `Collective::should_die`).
    pub kill: Option<(usize, usize)>,
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// Probability a message is parked for [`FaultPlan::delay_ms`].
    pub delay_prob: f64,
    /// Injected delivery delay in milliseconds.
    pub delay_ms: u64,
}

/// Fate of one message under a plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Delivery {
    Deliver,
    Drop,
    Delay(u64),
}

/// SplitMix64 finalizer — the avalanche stage only (the caller supplies
/// the already-combined key).
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Keyed, deterministic fate of the `(from, to, tag)` message in
    /// attempt `epoch`.
    pub fn delivery(&self, epoch: u64, from: usize, to: usize, tag: u64) -> Delivery {
        if self.drop_prob <= 0.0 && self.delay_prob <= 0.0 {
            return Delivery::Deliver;
        }
        let mut h = mix(self.seed ^ 0x6F74_5F66_6175_6C74); // "ft_fault"
        for v in [epoch, from as u64, to as u64, tag] {
            h = mix(h.wrapping_add(0x9E3779B97F4A7C15).wrapping_add(v));
        }
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < self.drop_prob {
            Delivery::Drop
        } else if u < self.drop_prob + self.delay_prob {
            Delivery::Delay(self.delay_ms)
        } else {
            Delivery::Deliver
        }
    }

    /// The same plan with the kill disarmed — recovery attempts keep the
    /// message-level faults but must not re-kill the replaced worker.
    pub fn without_kill(&self) -> FaultPlan {
        FaultPlan {
            kill: None,
            ..self.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_per_key() {
        let plan = FaultPlan {
            seed: 42,
            drop_prob: 0.3,
            delay_prob: 0.2,
            delay_ms: 10,
            ..FaultPlan::default()
        };
        for tag in 0..200u64 {
            assert_eq!(plan.delivery(0, 1, 2, tag), plan.delivery(0, 1, 2, tag));
        }
    }

    #[test]
    fn epoch_decorrelates_attempts() {
        // the same tag must not share its fate across epochs in lockstep
        let plan = FaultPlan {
            seed: 7,
            drop_prob: 0.5,
            ..FaultPlan::default()
        };
        let differs = (0..400u64)
            .filter(|&tag| plan.delivery(0, 0, 1, tag) != plan.delivery(1, 0, 1, tag))
            .count();
        assert!(differs > 100, "epochs too correlated: {differs}/400 differ");
    }

    #[test]
    fn probabilities_are_respected_roughly() {
        let plan = FaultPlan {
            seed: 3,
            drop_prob: 0.25,
            delay_prob: 0.25,
            delay_ms: 5,
            ..FaultPlan::default()
        };
        let n = 4000u64;
        let mut drops = 0;
        let mut delays = 0;
        for tag in 0..n {
            match plan.delivery(0, 0, 1, tag) {
                Delivery::Drop => drops += 1,
                Delivery::Delay(ms) => {
                    assert_eq!(ms, 5);
                    delays += 1;
                }
                Delivery::Deliver => {}
            }
        }
        let (d, y) = (drops as f64 / n as f64, delays as f64 / n as f64);
        assert!((d - 0.25).abs() < 0.05, "drop rate {d}");
        assert!((y - 0.25).abs() < 0.05, "delay rate {y}");
    }

    #[test]
    fn zero_probability_always_delivers() {
        let plan = FaultPlan {
            seed: 11,
            kill: Some((0, 3)),
            ..FaultPlan::default()
        };
        for tag in 0..100u64 {
            assert_eq!(plan.delivery(0, 0, 1, tag), Delivery::Deliver);
        }
        assert_eq!(plan.without_kill().kill, None);
        assert_eq!(plan.without_kill().seed, 11);
    }
}
