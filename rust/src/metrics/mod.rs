//! Time / energy / memory / occupancy models over simulated timelines.
//!
//! Substitutes the paper's nvidia-smi and CUPTI measurements (DESIGN.md
//! §1): energy integrates per-state dynamic power over the timeline
//! (idle / compute-only / comm-only / overlapped); memory tracks the
//! gradient-cache release behaviour that the paper credits for FlowMoE's
//! memory savings; occupancy (compute-stream busy fraction) is the SM-
//! utilization analogue of Tables A.8–A.11.

use crate::config::{ClusterProfile, ModelCfg, PowerProfile};
use crate::cost::peak_memory_bytes;
use crate::sched::Policy;
use crate::sim::Timeline;
use crate::tasks::{Dag, Stream, TaskKind};

/// Per-iteration, per-worker energy in joules: integral of state power
/// over the makespan. The paper's Table 6 reports nvidia-smi whole-card
/// energy; we report the same integral with our power profile — absolute
/// joules differ from the paper's testbed, relative savings are the
/// comparison target (EXPERIMENTS.md).
pub fn energy_joules(tl: &Timeline, power: &PowerProfile) -> f64 {
    let total = tl.makespan;
    let comp = tl.busy(Stream::Compute);
    let comm = tl.busy(Stream::Comm);
    let both = tl.overlap();
    let comp_only = comp - both;
    let comm_only = comm - both;
    let idle = (total - comp_only - comm_only - both).max(0.0);
    idle * power.idle_w
        + comp_only * power.compute_w
        + comm_only * power.comm_w
        + both * power.both_w
}

/// Peak gradient-cache depth in blocks: how many blocks' replicated
/// gradients are resident at once. Centralized AR keeps all L blocks
/// cached until the end of backward; chunked-AR releases each block as
/// its chunks drain. Measured from the timeline: for each block, the
/// gradient is live from the end of its last AT-bwd to the end of its
/// last AR chunk.
pub fn peak_grad_cache_blocks(dag: &Dag, tl: &Timeline, l_blocks: usize) -> f64 {
    let mut live: Vec<(f64, f64)> = Vec::with_capacity(l_blocks);
    for l in 0..l_blocks {
        let mut grad_ready = 0.0f64;
        let mut ar_done = 0.0f64;
        for t in &dag.tasks {
            match t.kind {
                TaskKind::At { l: tl_, phase: crate::tasks::Phase::Bwd, .. } if tl_ == l => {
                    if let Some(s) = tl.span_of(t.id) {
                        grad_ready = grad_ready.max(s.end);
                    }
                }
                TaskKind::Ar { l: tl_, .. } if tl_ == l => {
                    if let Some(s) = tl.span_of(t.id) {
                        ar_done = ar_done.max(s.end);
                    }
                }
                _ => {}
            }
        }
        live.push((grad_ready, ar_done.max(grad_ready)));
    }
    // sweep max concurrent live intervals
    let mut events: Vec<(f64, i32)> = Vec::new();
    for (a, b) in &live {
        events.push((*a, 1));
        events.push((*b, -1));
    }
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak as f64
}

/// Peak memory (bytes) for a policy: static model + measured grad-cache
/// depth from its simulated timeline.
pub fn peak_memory(
    cfg: &ModelCfg,
    cluster: &ClusterProfile,
    policy: &Policy,
    dag: &Dag,
    tl: &Timeline,
) -> f64 {
    let cache = peak_grad_cache_blocks(dag, tl, cfg.l);
    peak_memory_bytes(cfg, cluster.p, cache, policy.expert_replication)
}

/// Compute-stream occupancy — the SM-utilization analogue (Appendix J).
pub fn sm_utilization(tl: &Timeline) -> f64 {
    tl.occupancy(Stream::Compute)
}

/// Per-worker expert-load imbalance under skewed routing (Appendix J,
/// Tables A.10/A.11): given a routing histogram over experts, return
/// (max, min) worker compute-utilization assuming utilization scales with
/// the worker's share of routed tokens (capped by capacity).
pub fn load_imbalance_utilization(
    expert_tokens: &[f64],
    experts_per_worker: usize,
    base_util: f64,
) -> (f64, f64) {
    assert!(!expert_tokens.is_empty() && experts_per_worker > 0);
    // Integer division used to silently drop the trailing experts of a
    // ragged histogram — a caller passing 17 experts at 2/worker got 8
    // workers and expert 16's load vanished from the imbalance numbers.
    assert!(
        expert_tokens.len() % experts_per_worker == 0,
        "expert_tokens.len() = {} is not a multiple of experts_per_worker = {}: \
         trailing experts would be silently dropped",
        expert_tokens.len(),
        experts_per_worker
    );
    let workers = expert_tokens.len() / experts_per_worker;
    let mut loads: Vec<f64> = (0..workers)
        .map(|w| {
            expert_tokens[w * experts_per_worker..(w + 1) * experts_per_worker]
                .iter()
                .sum()
        })
        .collect();
    let mean = loads.iter().sum::<f64>() / workers as f64;
    for l in loads.iter_mut() {
        *l /= mean.max(1e-12);
    }
    let maxu = loads.iter().copied().fold(0.0, f64::max).min(1.0 / base_util.max(1e-9)) * base_util;
    let minu = loads.iter().copied().fold(f64::INFINITY, f64::min) * base_util;
    (maxu.min(0.99), minu.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::cost::TaskCosts;
    use crate::sched::{build_dag, Policy};
    use crate::sim::simulate;

    fn run(policy: &Policy) -> (ModelCfg, ClusterProfile, Dag, Timeline) {
        let cfg = preset("BERT-Large-MoE").unwrap();
        let cl = ClusterProfile::cluster1(16);
        let costs = TaskCosts::build(&cfg, &cl);
        let dag = build_dag(&cfg, &costs, policy);
        let tl = simulate(&dag);
        (cfg, cl, dag, tl)
    }

    #[test]
    fn energy_positive_and_flowmoe_saves() {
        let (_, cl, _, tv) = run(&Policy::vanilla_ep());
        let (_, _, _, tf) = run(&Policy::flow_moe(2, 2.5e6));
        let ev = energy_joules(&tv, &cl.power);
        let ef = energy_joules(&tf, &cl.power);
        assert!(ev > 0.0 && ef > 0.0);
        // Table 6: FlowMoE saves energy vs vanilla (shorter makespan at
        // comparable busy time).
        assert!(ef < ev, "flow {ef} >= vanilla {ev}");
    }

    #[test]
    fn grad_cache_centralized_is_all_blocks() {
        let (cfg, _, dag, tl) = run(&Policy::tutel(2));
        let cache = peak_grad_cache_blocks(&dag, &tl, cfg.l);
        assert!(cache >= cfg.l as f64 - 0.5, "cache={cache}");
    }

    #[test]
    fn grad_cache_chunked_is_smaller() {
        let (cfg, _, dag_c, tl_c) = run(&Policy::tutel(2));
        let (_, _, dag_f, tl_f) = run(&Policy::flow_moe(2, 2.5e6));
        let central = peak_grad_cache_blocks(&dag_c, &tl_c, cfg.l);
        let chunked = peak_grad_cache_blocks(&dag_f, &tl_f, cfg.l);
        assert!(chunked < central, "chunked={chunked} central={central}");
    }

    #[test]
    fn memory_flowmoe_leq_tutel_lt_fastermoe() {
        let (cfg, cl, dag_t, tl_t) = run(&Policy::tutel(2));
        let (_, _, dag_f, tl_f) = run(&Policy::flow_moe(2, 2.5e6));
        let (_, _, dag_fm, tl_fm) = run(&Policy::faster_moe(2));
        let mt = peak_memory(&cfg, &cl, &Policy::tutel(2), &dag_t, &tl_t);
        let mf = peak_memory(&cfg, &cl, &Policy::flow_moe(2, 2.5e6), &dag_f, &tl_f);
        let mfm = peak_memory(&cfg, &cl, &Policy::faster_moe(2), &dag_fm, &tl_fm);
        assert!(mf < mt, "flow {mf} >= tutel {mt}");
        assert!(mt < mfm, "tutel {mt} >= fasterMoE {mfm}");
    }

    #[test]
    fn utilization_in_unit_interval_and_drops_with_r() {
        let cfg = preset("GPT2-Tiny-MoE").unwrap();
        let cl = ClusterProfile::cluster1(16);
        let costs = TaskCosts::build(&cfg, &cl);
        let u2 = {
            let d = build_dag(&cfg, &costs, &Policy::flow_moe(2, 2.5e6));
            sm_utilization(&simulate(&d))
        };
        assert!((0.0..=1.0).contains(&u2));
    }

    #[test]
    fn load_imbalance_uniform_is_balanced() {
        let (maxu, minu) = load_imbalance_utilization(&[1.0; 16], 2, 0.88);
        assert!((maxu - minu).abs() < 0.02);
    }

    #[test]
    #[should_panic(expected = "not a multiple of experts_per_worker")]
    fn load_imbalance_rejects_ragged_histogram() {
        // 17 experts at 2/worker used to silently truncate expert 16;
        // now it's a hard error.
        load_imbalance_utilization(&[1.0; 17], 2, 0.88);
    }

    #[test]
    fn load_imbalance_skewed_spreads() {
        let mut tokens = vec![0.2; 16];
        tokens[0] = 8.0;
        let (maxu, minu) = load_imbalance_utilization(&tokens, 2, 0.88);
        assert!(maxu > 0.85);
        assert!(minu < 0.4);
    }
}
