//! Runtime observability: lock-light span tracing + a metrics registry
//! for the **native execution path** — measured, not modeled.
//!
//! Everything else in the crate observes *simulated* timelines
//! ([`crate::sim::Timeline`], [`crate::metrics`]). This module records
//! what the real threads actually did, so FlowMoE's overlap claim can be
//! checked against wall-clock spans instead of the cost model:
//!
//! * **Span tracing** — [`span`] returns a scoped guard that records a
//!   `(label, thread, seq, start, end)` record into a per-thread buffer
//!   on drop. The whole machinery sits behind one process-wide
//!   [`AtomicBool`]: with tracing disabled (the default) a [`span`] call
//!   costs a single relaxed load, so the instrumentation can live
//!   permanently inside the kernel dispatch entry points, the model
//!   phases, the trainer step phases, the cluster A2A sections and the
//!   [`crate::sweep::scope`] workers (`perf_hotpath` asserts the
//!   disabled-path overhead stays under 2 % of a kernel call).
//!   Timestamps are monotonic ([`std::time::Instant`]) relative to one
//!   process epoch; [`take_spans`] drains every thread's buffer and
//!   returns the records in deterministic `(thread, seq)` order.
//! * **Metrics registry** — [`Registry`]: named [`Counter`]s,
//!   [`Gauge`]s and fixed-bucket exponential [`Histogram`]s with
//!   p50/p95/p99 extraction. The trainer feeds per-step phase timings
//!   into a per-run registry (surfaced as
//!   [`RegistrySnapshot`] on `TrainReport`); `perf_hotpath` feeds kernel
//!   rep times into [`global`] and emits them as the `stats` block of
//!   `BENCH_native_kernels.json`.
//! * **Exports** — [`chrome_trace`] renders drained spans in the exact
//!   chrome://tracing JSON shape the simulator already emits (shared
//!   [`crate::util::json_escape`]); [`OverlapStats`] + [`overlap_report`]
//!   compute measured compute/comm busy fractions and their overlap from
//!   real spans and print them side by side with the [`crate::sim`]
//!   prediction for the same config (`flowmoe train --trace out.json`).
//!
//! Tracing must never perturb results: spans carry no data, only
//! timestamps, and `tests/obs_trace.rs` asserts a traced `train_fused`
//! run is bit-identical to an untraced one.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::sim::Timeline;
use crate::tasks::Stream;
use crate::util::json_escape;

/// Lock a mutex, tolerating poisoning: a panicked recorder thread has
/// already surfaced its failure elsewhere; the observed data stays valid.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    crate::util::lock_recover(m)
}

// ---------------------------------------------------------------------------
// Span tracing
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether span tracing is currently on (one relaxed load — this is the
/// entire disabled-path cost of an instrumented call site).
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span tracing on or off process-wide. Spans already buffered are
/// kept; disabling only stops new records.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Process epoch all span timestamps are relative to (first use wins).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// One recorded span: a labelled `[start, end)` interval on one thread.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRec {
    /// Task-kind label (static so the hot path never allocates).
    pub label: &'static str,
    /// Small dense thread id (assigned on a thread's first record).
    pub tid: u32,
    /// Per-thread record sequence number (collection sorts by (tid, seq)).
    pub seq: u32,
    /// Start, nanoseconds since the process epoch (monotonic).
    pub start_ns: u64,
    /// End, nanoseconds since the process epoch (monotonic).
    pub end_ns: u64,
}

type Buffer = Arc<Mutex<Vec<SpanRec>>>;

/// All per-thread buffers ever registered (buffers outlive their
/// threads, so scoped workers' spans survive the scope).
fn buffers() -> &'static Mutex<Vec<Buffer>> {
    static BUFFERS: OnceLock<Mutex<Vec<Buffer>>> = OnceLock::new();
    BUFFERS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU32 = AtomicU32::new(0);

thread_local! {
    /// This thread's (id, buffer); registered globally on first record.
    /// Only the owning thread pushes, so the per-buffer mutex is
    /// uncontended except during [`take_spans`] — "lock-light".
    static RECORDER: (u32, Buffer) = {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let buf: Buffer = Arc::new(Mutex::new(Vec::new()));
        locked(buffers()).push(Arc::clone(&buf));
        (tid, buf)
    };
}

/// Record a span between two externally-measured instants (e.g. the
/// fault-tolerance phases `ft_detect`/`ft_restore`, whose start was
/// anchored on another thread). Instants before the process epoch clamp
/// to 0; `end < start` clamps to an empty span. No-op when disabled.
pub fn record_between(label: &'static str, start: Instant, end: Instant) {
    if !enabled() {
        return;
    }
    let e = epoch();
    let s_ns = start.saturating_duration_since(e).as_nanos() as u64;
    let e_ns = end.saturating_duration_since(e).as_nanos() as u64;
    record(label, s_ns, e_ns.max(s_ns));
}

fn record(label: &'static str, start_ns: u64, end_ns: u64) {
    RECORDER.with(|(tid, buf)| {
        let mut b = locked(buf);
        let seq = b.len() as u32;
        b.push(SpanRec {
            label,
            tid: *tid,
            seq,
            start_ns,
            end_ns,
        });
    });
}

/// Scoped span guard: records the span on drop (panic included, so a
/// panicking phase still leaves its trace).
#[must_use = "bind the guard (`let _sp = obs::span(..)`) — dropping it immediately records an empty span"]
pub struct SpanGuard {
    label: &'static str,
    start_ns: u64,
    armed: bool,
}

/// Open a span labelled `label` on the calling thread. With tracing
/// disabled this is ~one atomic load and a no-op guard.
#[inline]
pub fn span(label: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard {
            label,
            start_ns: 0,
            armed: false,
        };
    }
    SpanGuard {
        label,
        start_ns: now_ns(),
        armed: true,
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            record(self.label, self.start_ns, now_ns());
        }
    }
}

/// Drain every thread's span buffer, returning all records sorted by
/// `(tid, seq)` — a deterministic collection order for whatever set of
/// spans was recorded. Call after the traced work has joined its
/// threads; concurrent recorders keep working (their later spans land in
/// the next drain).
pub fn take_spans() -> Vec<SpanRec> {
    let mut out = Vec::new();
    {
        let bufs = locked(buffers());
        for b in bufs.iter() {
            out.append(&mut locked(b));
        }
    }
    out.sort_by_key(|s| (s.tid, s.seq));
    out
}

// ---------------------------------------------------------------------------
// Lanes + measured overlap
// ---------------------------------------------------------------------------

/// Which resource a span occupies, in the paper's two-stream model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    Compute,
    Comm,
}

/// Classify a span label into the two-stream model: data-movement task
/// families (dispatch/combine, A2A, AR chunks) are `Comm` — the same
/// assignment [`crate::sched`] gives their DAG tasks — compute task
/// families are `Compute`, and enclosing wrapper spans (`step`, `fwd`,
/// `bwd`, worker lifetimes) are `None` so they don't count everything
/// as busy.
pub fn lane_of(label: &str) -> Option<Lane> {
    if label.starts_with("a2a_") || label.starts_with("ar_") {
        return Some(Lane::Comm);
    }
    match label {
        "dispatch" | "dispatch_bwd" | "combine" | "combine_bwd" => Some(Lane::Comm),
        "mha_fwd" | "mha_bwd" | "gating_fwd" | "gating_bwd" | "expert_fwd" | "expert_bwd" | "head_loss"
        | "update" | "mm" | "mm_nt" | "mm_tn" | "expert_ffn" | "expert_ffn_bwd" | "decode_mha"
        | "decode_head" => Some(Lane::Compute),
        _ => None,
    }
}

/// Busy/overlap accounting over one set of spans (or one simulated
/// timeline): wall time, per-lane union-busy time, and the time both
/// lanes are simultaneously busy.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapStats {
    pub wall_s: f64,
    pub compute_busy_s: f64,
    pub comm_busy_s: f64,
    pub overlap_s: f64,
}

impl OverlapStats {
    /// Measured stats from real spans. Lane membership comes from
    /// [`lane_of`]; unclassified (wrapper) spans are ignored. Nested
    /// same-lane spans are unioned, not double-counted.
    pub fn from_spans(spans: &[SpanRec]) -> OverlapStats {
        // sweep over span boundaries, counting active spans per lane
        // (the sim::Timeline::overlap algorithm, on measured intervals)
        let mut events: Vec<(u64, i32, Lane)> = Vec::new();
        for s in spans {
            if let Some(lane) = lane_of(s.label) {
                events.push((s.start_ns, 1, lane));
                events.push((s.end_ns, -1, lane));
            }
        }
        if events.is_empty() {
            return OverlapStats::default();
        }
        events.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        let (mut nc, mut nm) = (0i64, 0i64);
        let mut last = events[0].0;
        let (mut busy_c, mut busy_m, mut both) = (0u64, 0u64, 0u64);
        let t0 = events[0].0;
        let t1 = events[events.len() - 1].0;
        for (t, d, lane) in events {
            let dt = t - last;
            if nc > 0 {
                busy_c += dt;
            }
            if nm > 0 {
                busy_m += dt;
            }
            if nc > 0 && nm > 0 {
                both += dt;
            }
            last = t;
            match lane {
                Lane::Compute => nc += d as i64,
                Lane::Comm => nm += d as i64,
            }
        }
        OverlapStats {
            wall_s: (t1 - t0) as f64 * 1e-9,
            compute_busy_s: busy_c as f64 * 1e-9,
            comm_busy_s: busy_m as f64 * 1e-9,
            overlap_s: both as f64 * 1e-9,
        }
    }

    /// Modeled stats from a simulated [`Timeline`] (same quantities the
    /// sim already defines: compute busy, unioned comm busy, overlap).
    pub fn from_timeline(tl: &Timeline) -> OverlapStats {
        OverlapStats {
            wall_s: tl.makespan,
            compute_busy_s: tl.busy(Stream::Compute),
            comm_busy_s: tl.busy_comm(),
            overlap_s: tl.overlap(),
        }
    }

    pub fn compute_frac(&self) -> f64 {
        if self.wall_s > 0.0 { self.compute_busy_s / self.wall_s } else { 0.0 }
    }

    pub fn comm_frac(&self) -> f64 {
        if self.wall_s > 0.0 { self.comm_busy_s / self.wall_s } else { 0.0 }
    }

    /// Fraction of communication time hidden under compute.
    pub fn hidden_comm_frac(&self) -> f64 {
        if self.comm_busy_s > 0.0 { self.overlap_s / self.comm_busy_s } else { 0.0 }
    }
}

/// Render measured (real spans) vs modeled (simulated timeline) overlap
/// side by side — the first measured-vs-modeled comparison in the repo.
/// Wall times differ by construction (the sim predicts one iteration at
/// calibrated GPU costs; the measurement is CPU wall time over the run),
/// so compare the *fractions*, which is what the overlap claim is about.
pub fn overlap_report(measured: &OverlapStats, modeled: &OverlapStats) -> String {
    let mut out = String::new();
    out.push_str("overlap: measured (runtime spans) vs modeled (sim timeline)\n");
    out.push_str(&format!(
        "  {:<26} {:>12} {:>12}\n",
        "quantity", "measured", "modeled"
    ));
    let row = |name: &str, a: f64, b: f64, pct: bool| {
        if pct {
            format!("  {:<26} {:>11.1}% {:>11.1}%\n", name, a * 100.0, b * 100.0)
        } else {
            format!("  {name:<26} {a:>11.4}s {b:>11.4}s\n")
        }
    };
    out.push_str(&row("wall time", measured.wall_s, modeled.wall_s, false));
    out.push_str(&row("compute busy / wall", measured.compute_frac(), modeled.compute_frac(), true));
    out.push_str(&row("comm busy / wall", measured.comm_frac(), modeled.comm_frac(), true));
    out.push_str(&row(
        "comm hidden under compute",
        measured.hidden_comm_frac(),
        modeled.hidden_comm_frac(),
        true,
    ));
    out
}

// ---------------------------------------------------------------------------
// Chrome-trace export
// ---------------------------------------------------------------------------

/// Render drained spans as a chrome://tracing / Perfetto JSON string —
/// the exact event shape [`Timeline::to_chrome_trace`] emits (complete
/// "X" events, ts/dur in microseconds, labels through
/// [`json_escape`]), with the recorder thread id as the trace `tid`.
/// Timestamps are re-based to the earliest span so traces start at 0.
pub fn chrome_trace(spans: &[SpanRec]) -> String {
    if spans.is_empty() {
        return "[]\n".to_string();
    }
    let t0 = spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
    let mut out = String::from("[\n");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ph\": \"X\", \"pid\": 0, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}",
            json_escape(s.label),
            s.tid,
            (s.start_ns - t0) as f64 * 1e-3,
            (s.end_ns - s.start_ns) as f64 * 1e-3
        ));
    }
    out.push_str("\n]\n");
    out
}

// ---------------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------------

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Default histogram bucketing: exponential upper bounds starting at
/// [`HIST_START_S`] seconds, doubling [`HIST_BUCKETS`] times — 10 µs to
/// ~5.6 min, which covers a kernel call through a full training step.
pub const HIST_START_S: f64 = 1e-5;
pub const HIST_FACTOR: f64 = 2.0;
pub const HIST_BUCKETS: usize = 25;

/// The default bucket upper bounds (seconds).
pub fn hist_bounds() -> Vec<f64> {
    let mut b = Vec::with_capacity(HIST_BUCKETS);
    let mut v = HIST_START_S;
    for _ in 0..HIST_BUCKETS {
        b.push(v);
        v *= HIST_FACTOR;
    }
    b
}

#[derive(Clone, Debug, Default)]
struct HistData {
    /// counts[i] observations in (bounds[i-1], bounds[i]]; one overflow
    /// slot past the last bound.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Fixed-bucket histogram over f64 observations (seconds by
/// convention). Percentiles interpolate linearly inside the bucket the
/// requested rank falls in, clamped to the exact observed min/max.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    data: Mutex<HistData>,
}

impl Histogram {
    /// Histogram with explicit ascending bucket upper bounds.
    pub fn with_bounds(bounds: Vec<f64>) -> Histogram {
        let n = bounds.len();
        Histogram {
            bounds,
            data: Mutex::new(HistData {
                counts: vec![0; n + 1],
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            }),
        }
    }

    /// Histogram with the default exponential bounds ([`hist_bounds`]).
    pub fn new() -> Histogram {
        Histogram::with_bounds(hist_bounds())
    }

    pub fn observe(&self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        let mut d = locked(&self.data);
        d.counts[idx] += 1;
        d.count += 1;
        d.sum += v;
        d.min = d.min.min(v);
        d.max = d.max.max(v);
    }

    pub fn count(&self) -> u64 {
        locked(&self.data).count
    }

    pub fn sum(&self) -> f64 {
        locked(&self.data).sum
    }

    /// Approximate quantile `q` in [0, 1]: walk buckets to the one
    /// holding the rank, interpolate linearly between its edges, clamp
    /// to the observed min/max (so p0/p100 are exact and the overflow
    /// bucket can't report +inf).
    pub fn quantile(&self, q: f64) -> f64 {
        let d = locked(&self.data);
        if d.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * d.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in d.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() { self.bounds[i] } else { d.max };
                let frac = (rank - (seen - c)) as f64 / c as f64;
                let v = lo + (hi - lo) * frac;
                return v.clamp(d.min, d.max);
            }
        }
        d.max
    }

    fn stat(&self, name: &str) -> HistStat {
        HistStat {
            name: name.to_string(),
            count: self.count(),
            total_s: self.sum(),
            p50_s: self.quantile(0.50),
            p95_s: self.quantile(0.95),
            p99_s: self.quantile(0.99),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Summary of one named histogram (seconds by convention) — the per-step
/// phase breakdown shape `TrainReport` carries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistStat {
    pub name: String,
    pub count: u64,
    pub total_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// Point-in-time export of a [`Registry`], sorted by metric name.
#[derive(Clone, Debug, Default)]
pub struct RegistrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<HistStat>,
}

use std::collections::BTreeMap;

/// Named metrics, created on first use. `BTreeMap` keeps snapshots in
/// deterministic name order. Instantiate per run (the trainer does) or
/// use the process-wide [`global`] registry.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(locked(&self.counters).entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(locked(&self.gauges).entry(name.to_string()).or_default())
    }

    /// Histogram with the default exponential seconds buckets.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(locked(&self.hists).entry(name.to_string()).or_default())
    }

    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: locked(&self.counters).iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            gauges: locked(&self.gauges).iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            hists: locked(&self.hists).iter().map(|(k, v)| v.stat(k)).collect(),
        }
    }
}

/// Process-wide registry (benches, CLI). Prefer a per-run [`Registry`]
/// where the lifetime is scoped, as the trainer does.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that toggle the process-wide tracing gate.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_span_records_nothing() {
        let _g = locked(&GATE);
        set_enabled(false);
        let _ = take_spans(); // drain stray spans from other tests
        {
            let _sp = span("test_disabled");
        }
        // other tests' armed guards may straggle in concurrently; only
        // assert that the disabled-path span itself recorded nothing
        let spans = take_spans();
        assert!(!spans.iter().any(|s| s.label == "test_disabled"));
    }

    #[test]
    fn enabled_spans_collect_in_thread_seq_order() {
        let _g = locked(&GATE);
        set_enabled(true);
        let _ = take_spans(); // clear
        {
            let _a = span("test_outer");
            let _b = span("test_inner");
        }
        set_enabled(false);
        let spans = take_spans();
        let mine: Vec<&SpanRec> = spans.iter().filter(|s| s.label.starts_with("test_")).collect();
        assert_eq!(mine.len(), 2);
        // drop order: inner guard drops first, so it records first
        assert_eq!(mine[0].label, "test_inner");
        assert_eq!(mine[1].label, "test_outer");
        assert!(mine[0].seq < mine[1].seq);
        assert_eq!(mine[0].tid, mine[1].tid);
        for s in mine {
            assert!(s.end_ns >= s.start_ns);
        }
        // global order is (tid, seq)
        assert!(spans.windows(2).all(|w| (w[0].tid, w[0].seq) <= (w[1].tid, w[1].seq)));
    }

    #[test]
    fn spans_survive_scoped_worker_threads() {
        let _g = locked(&GATE);
        set_enabled(true);
        let _ = take_spans();
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    let _sp = span("test_worker_span");
                });
            }
        });
        set_enabled(false);
        let spans = take_spans();
        let n = spans.iter().filter(|s| s.label == "test_worker_span").count();
        assert_eq!(n, 3);
    }

    #[test]
    fn lane_classification() {
        assert_eq!(lane_of("mha_fwd"), Some(Lane::Compute));
        assert_eq!(lane_of("expert_ffn_bwd"), Some(Lane::Compute));
        assert_eq!(lane_of("update"), Some(Lane::Compute));
        assert_eq!(lane_of("dispatch"), Some(Lane::Comm));
        assert_eq!(lane_of("combine_bwd"), Some(Lane::Comm));
        assert_eq!(lane_of("ar_chunk"), Some(Lane::Comm));
        assert_eq!(lane_of("a2a_combine"), Some(Lane::Comm));
        assert_eq!(lane_of("decode_mha"), Some(Lane::Compute));
        assert_eq!(lane_of("decode_head"), Some(Lane::Compute));
        assert_eq!(lane_of("step"), None);
        assert_eq!(lane_of("decode_step"), None);
        assert_eq!(lane_of("scope_worker"), None);
    }

    fn rec(label: &'static str, tid: u32, start: u64, end: u64) -> SpanRec {
        SpanRec {
            label,
            tid,
            seq: 0,
            start_ns: start,
            end_ns: end,
        }
    }

    #[test]
    fn overlap_stats_from_hand_built_spans() {
        // compute [0,10) and [20,30), comm [5,25): overlap = 5 + 5
        let spans = vec![
            rec("mha_fwd", 0, 0, 10_000_000_000),
            rec("expert_fwd", 0, 20_000_000_000, 30_000_000_000),
            rec("ar_chunk", 1, 5_000_000_000, 25_000_000_000),
            rec("step", 0, 0, 30_000_000_000), // wrapper: ignored
        ];
        let st = OverlapStats::from_spans(&spans);
        assert!((st.wall_s - 30.0).abs() < 1e-9);
        assert!((st.compute_busy_s - 20.0).abs() < 1e-9);
        assert!((st.comm_busy_s - 20.0).abs() < 1e-9);
        assert!((st.overlap_s - 10.0).abs() < 1e-9);
        assert!((st.hidden_comm_frac() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn overlap_stats_union_not_double_count() {
        // two nested compute spans: busy is 10, not 18
        let spans = vec![rec("mha_fwd", 0, 0, 10_000_000_000), rec("mm", 0, 1_000_000_000, 9_000_000_000)];
        let st = OverlapStats::from_spans(&spans);
        assert!((st.compute_busy_s - 10.0).abs() < 1e-9);
        assert_eq!(st.overlap_s, 0.0);
    }

    #[test]
    fn chrome_trace_shape_and_escaping() {
        let spans = vec![rec("mha_fwd", 3, 2_000, 5_000)];
        let json = chrome_trace(&spans);
        assert!(json.starts_with("[\n"));
        assert!(json.trim_end().ends_with(']'));
        // rebased to the first span, ns -> us
        assert!(json.contains("\"name\": \"mha_fwd\""));
        assert!(json.contains("\"tid\": 3"));
        assert!(json.contains("\"ts\": 0.000"));
        assert!(json.contains("\"dur\": 3.000"));
        assert_eq!(chrome_trace(&[]), "[]\n");
    }

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        let c = r.counter("x");
        c.inc();
        c.add(4);
        assert_eq!(r.counter("x").get(), 5);
        let g = r.gauge("y");
        g.set(2.5);
        assert_eq!(r.gauge("y").get(), 2.5);
        let snap = r.snapshot();
        assert_eq!(snap.counters, vec![("x".to_string(), 5)]);
        assert_eq!(snap.gauges, vec![("y".to_string(), 2.5)]);
    }

    #[test]
    fn histogram_percentiles_interpolate_and_clamp() {
        // bounds 1,2,4: 100 obs of 1.5 -> every quantile inside (1,2]
        let h = Histogram::with_bounds(vec![1.0, 2.0, 4.0]);
        for _ in 0..100 {
            h.observe(1.5);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 150.0).abs() < 1e-9);
        for q in [0.5, 0.95, 0.99] {
            let v = h.quantile(q);
            assert!((1.0..=2.0).contains(&v), "q{q} = {v}");
        }
        // clamping: a single observation reports itself exactly
        let h1 = Histogram::with_bounds(vec![1.0, 2.0]);
        h1.observe(1.25);
        assert_eq!(h1.quantile(0.5), 1.25);
        assert_eq!(h1.quantile(0.99), 1.25);
        // overflow bucket is finite (clamped to the observed max)
        let h2 = Histogram::with_bounds(vec![1.0]);
        h2.observe(50.0);
        assert_eq!(h2.quantile(0.99), 50.0);
    }

    #[test]
    fn histogram_quantile_orders_across_buckets() {
        let h = Histogram::new();
        for i in 0..90 {
            h.observe(1e-4 + i as f64 * 1e-6); // fast cluster
        }
        for _ in 0..10 {
            h.observe(1.0); // slow tail
        }
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 < 1e-3, "p50 = {p50}");
        assert!(p95 >= p50 && p99 >= p95);
        assert!(p99 > 0.5, "p99 = {p99} should land in the slow tail");
    }

    #[test]
    fn registry_snapshot_sorted_and_stats_shaped() {
        let r = Registry::new();
        r.histogram("b").observe(0.5);
        r.histogram("a").observe(0.1);
        r.histogram("a").observe(0.2);
        let snap = r.snapshot();
        let names: Vec<&str> = snap.hists.iter().map(|h| h.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b"]);
        assert_eq!(snap.hists[0].count, 2);
        assert!((snap.hists[0].total_s - 0.3).abs() < 1e-9);
        assert!(snap.hists[0].p50_s > 0.0);
    }

    #[test]
    fn overlap_report_renders_both_columns() {
        let m = OverlapStats {
            wall_s: 2.0,
            compute_busy_s: 1.5,
            comm_busy_s: 0.5,
            overlap_s: 0.25,
        };
        let s = overlap_report(&m, &m);
        assert!(s.contains("measured"));
        assert!(s.contains("modeled"));
        assert!(s.contains("75.0%"));
        assert!(s.contains("comm hidden under compute"));
    }
}
