//! Execution runtime: manifest-driven engine with pluggable backends.
//!
//! The engine is manifest-driven: `python/compile/aot.py` writes
//! `artifacts/manifest.txt` describing every artifact's positional
//! input/output buffers (name, shape, dtype); the engine parses it so no
//! shape knowledge is duplicated in rust. When no `manifest.txt` exists
//! (a clean checkout), [`Engine::new`] synthesizes the equivalent
//! manifest natively ([`crate::backend::native_manifest`]) — same
//! artifact names and signatures — so nothing downstream needs artifacts.
//!
//! Execution goes through the [`Backend`] trait:
//!
//! * [`crate::backend::NativeBackend`] (the default) runs every exported
//!   entry point on in-tree dense f32 CPU kernels — the end-to-end
//!   trainer, the EP cluster and the integration tests execute with no
//!   JAX, no artifacts and no external crates.
//! * [`PjRtStub`] models the not-yet-linked XLA/PJRT client: it supports
//!   nothing and returns the "PJRT backend unavailable" error. A future
//!   PJRT-enabled build would add a third implementation that compiles
//!   and executes the HLO files; the marshalling contract (validate
//!   once, reuse device buffers across executions) is already in place.
//!
//! Each worker thread owns its own [`Engine`] (real PJRT clients are
//! `Rc`-backed and not `Send`); host tensors ([`HostTensor`]) are plain
//! `Vec`s and move freely between threads.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Dtype of a buffer (the stack only uses f32 and i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// A named positional buffer in an artifact signature.
#[derive(Clone, Debug)]
pub struct BufSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl BufSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT artifact: file + I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub config: String,
    pub inputs: Vec<BufSpec>,
    pub outputs: Vec<BufSpec>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let mut m = Manifest {
            artifacts: Vec::new(),
            dir: dir.to_path_buf(),
        };
        for line in text.lines() {
            let trimmed = line.trim_start();
            if trimmed.starts_with("artifact ") {
                let parts: Vec<&str> = trimmed.split_whitespace().collect();
                let name = parts.get(1).ok_or_else(|| anyhow!("bad artifact line"))?;
                let mut file = String::new();
                let mut config = String::new();
                for p in &parts[2..] {
                    if let Some(v) = p.strip_prefix("file=") {
                        file = v.to_string();
                    } else if let Some(v) = p.strip_prefix("config=") {
                        config = v.to_string();
                    }
                }
                m.artifacts.push(ArtifactSpec {
                    name: name.to_string(),
                    file,
                    config,
                    inputs: Vec::new(),
                    outputs: Vec::new(),
                });
            } else if trimmed.starts_with("input ") || trimmed.starts_with("output ") {
                let parts: Vec<&str> = trimmed.split_whitespace().collect();
                if parts.len() != 4 {
                    bail!("bad io line: {line}");
                }
                let shape = if parts[2] == "scalar" {
                    vec![]
                } else {
                    parts[2]
                        .split('x')
                        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("{e}: {line}")))
                        .collect::<Result<Vec<_>>>()?
                };
                let dtype = match parts[3] {
                    "f32" => Dtype::F32,
                    "i32" => Dtype::I32,
                    other => bail!("unknown dtype {other}"),
                };
                let spec = BufSpec {
                    name: parts[1].to_string(),
                    shape,
                    dtype,
                };
                let art = m
                    .artifacts
                    .last_mut()
                    .ok_or_else(|| anyhow!("io line before artifact"))?;
                if trimmed.starts_with("input ") {
                    art.inputs.push(spec);
                } else {
                    art.outputs.push(spec);
                }
            }
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }
}

/// A host-side tensor (moves freely across threads).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
    pub fn f32_mut(&mut self) -> &mut Vec<f32> {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
    pub fn i32(&self) -> &[i32] {
        match self {
            HostTensor::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn scalar_f32(&self) -> f32 {
        self.f32()[0]
    }
}

/// Opaque device-buffer handle. In the offline stub it pins validated
/// host data; a real PJRT backend would hold the device allocation. The
/// marshalling contract (validate once, reuse across many executions) is
/// identical either way.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    data: HostTensor,
}

impl PjRtBuffer {
    /// The pinned host data.
    pub fn host(&self) -> &HostTensor {
        &self.data
    }
}

/// An execution backend: maps a manifest artifact to an implementation
/// and runs it on validated host tensors. Implementations: the in-tree
/// [`crate::backend::NativeBackend`] (dense f32 CPU kernels) and the
/// [`PjRtStub`] placeholder for a linked XLA/PJRT client.
pub trait Backend: Send {
    /// Short backend id (shown by `flowmoe info`).
    fn name(&self) -> &'static str;
    /// Whether this backend can execute `spec` (without external files).
    fn supports(&self, spec: &ArtifactSpec) -> bool;
    /// Execute one artifact. Inputs are already validated against the
    /// manifest signature; outputs must match it positionally.
    fn execute(&self, spec: &ArtifactSpec, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

/// Placeholder for the not-yet-linked XLA/PJRT client: supports no
/// artifact and reports the canonical "backend unavailable" error.
#[derive(Clone, Copy, Debug, Default)]
pub struct PjRtStub;

impl Backend for PjRtStub {
    fn name(&self) -> &'static str {
        "pjrt-stub"
    }

    fn supports(&self, _spec: &ArtifactSpec) -> bool {
        false
    }

    fn execute(&self, spec: &ArtifactSpec, _inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        Err(anyhow!("execute {}: {BACKEND_UNAVAILABLE}", spec.name))
    }
}

/// Per-thread engine: parses (or synthesizes) the artifact manifest,
/// validates and marshals buffers, and dispatches execution to its
/// [`Backend`]. See the module docs for backend selection.
pub struct Engine {
    manifest: Manifest,
    backend: Box<dyn Backend>,
    /// Artifacts resolved to an executable (native kernel or located HLO
    /// file — the analogue of a real client's compile cache).
    prepared: HashSet<String>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("backend", &self.backend.name())
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}

/// Error text for artifacts no configured backend can execute.
const BACKEND_UNAVAILABLE: &str =
    "PJRT backend unavailable: this is the offline no-external-deps build \
     (no XLA/PJRT client crate linked) and the artifact has no native \
     kernel. Manifest parsing and buffer validation work; executing \
     arbitrary HLO requires a PJRT-enabled build (see rust/README.md)";

impl Engine {
    /// Engine on the default backend (the in-tree native kernels). Loads
    /// `manifest.txt` from `artifacts_dir` when present; otherwise
    /// synthesizes the native manifest, so a clean checkout executes the
    /// `tiny`/`e2e` configs with no artifacts at all.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        Engine::with_backend(artifacts_dir, Box::new(crate::backend::NativeBackend::default()))
    }

    /// Engine on an explicit backend (pluggable dispatch).
    pub fn with_backend(artifacts_dir: &Path, backend: Box<dyn Backend>) -> Result<Engine> {
        let manifest = if artifacts_dir.join("manifest.txt").exists() {
            Manifest::load(artifacts_dir)?
        } else {
            crate::backend::native_manifest(artifacts_dir)
        };
        Ok(Engine {
            manifest,
            backend,
            prepared: HashSet::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Short id of the executing backend.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Resolve an artifact to something executable: a native kernel, or
    /// (for artifacts the backend cannot run) its HLO file on disk — the
    /// analogue of compiling it and caching the executable.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.prepared.contains(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?.clone();
        if !self.backend.supports(&spec) {
            let path = self.manifest.dir.join(&spec.file);
            if !path.exists() {
                bail!(
                    "artifact {name}: HLO file {} missing (run `make artifacts`)",
                    path.display()
                );
            }
        }
        self.prepared.insert(name.to_string());
        Ok(())
    }

    /// Upload a host tensor to a device buffer for spec `s`. Exposed so
    /// hot loops can marshal a tensor once and reuse it across many
    /// executions (§Perf: parameters are read by 4R block calls per step
    /// — marshalling them per call dominated the step time).
    pub fn buffer(&self, t: &HostTensor, s: &BufSpec) -> Result<PjRtBuffer> {
        validate_input(t, s)?;
        Ok(PjRtBuffer { data: t.clone() })
    }

    /// Upload an f32 slice directly (no HostTensor wrapper).
    pub fn buffer_f32(&self, v: &[f32], s: &BufSpec) -> Result<PjRtBuffer> {
        if v.len() != s.elems() || s.dtype != Dtype::F32 {
            bail!("input {}: size/dtype mismatch", s.name);
        }
        Ok(PjRtBuffer {
            data: HostTensor::F32(v.to_vec()),
        })
    }

    /// Execute with caller-owned device buffers (the leak-free hot path:
    /// buffers were validated once at marshalling time and are reused
    /// across many executions).
    pub fn run_buffers(&mut self, name: &str, bufs: &[&PjRtBuffer]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let spec = self.manifest.get(name)?.clone();
        if bufs.len() != spec.inputs.len() {
            bail!("{name}: {} inputs given, {} expected", bufs.len(), spec.inputs.len());
        }
        let inputs: Vec<&HostTensor> = bufs.iter().map(|b| b.host()).collect();
        self.dispatch(&spec, &inputs)
    }

    /// Execute an artifact with host tensors; validates shapes/dtypes
    /// against the manifest in place (no buffer copies) before
    /// dispatching to the backend.
    pub fn run(&mut self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            validate_input(t, s).map_err(|e| anyhow!("{name}: {e:#}"))?;
        }
        self.dispatch(&spec, inputs)
    }

    /// Shared execution tail: backend dispatch + output validation.
    fn dispatch(&mut self, spec: &ArtifactSpec, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let name = &spec.name;
        if !self.backend.supports(spec) {
            return Err(anyhow!("execute {name}: {BACKEND_UNAVAILABLE}"));
        }
        let outs = self.backend.execute(spec, inputs)?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{name}: backend {} returned {} outputs, manifest says {}",
                self.backend.name(),
                outs.len(),
                spec.outputs.len()
            );
        }
        for (t, s) in outs.iter().zip(&spec.outputs) {
            if t.len() != s.elems() {
                bail!("{name}: output {} has {} elems, expected {}", s.name, t.len(), s.elems());
            }
        }
        Ok(outs)
    }
}

/// Shape/dtype validation of one input against its manifest spec
/// (shared by the copying `buffer` path and the zero-copy `run` path).
fn validate_input(t: &HostTensor, s: &BufSpec) -> Result<()> {
    if t.len() != s.elems() {
        bail!(
            "input {} has {} elems, expected {} ({:?})",
            s.name,
            t.len(),
            s.elems(),
            s.shape
        );
    }
    match (t, s.dtype) {
        (HostTensor::F32(_), Dtype::F32) | (HostTensor::I32(_), Dtype::I32) => Ok(()),
        _ => bail!("input {} dtype mismatch", s.name),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_io_lines() {
        let dir = std::env::temp_dir().join("flowmoe_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact foo file=foo.hlo.txt config=tiny\n  input a 2x3 f32\n  input t scalar f32\n  output y 6 i32\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("foo").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elems(), 6);
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[0].dtype, Dtype::I32);
    }

    #[test]
    fn manifest_missing_artifact_errors() {
        let dir = std::env::temp_dir().join("flowmoe_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "artifact a file=f config=c\n").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.f32()[1], 2.0);
        let i = HostTensor::I32(vec![7]);
        assert_eq!(i.i32()[0], 7);
    }

    #[test]
    fn missing_manifest_load_error_says_make_artifacts() {
        let dir =
            std::env::temp_dir().join(format!("flowmoe_manifest_absent_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.txt"));
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn engine_without_artifacts_uses_native_backend() {
        let dir = std::env::temp_dir().join(format!("flowmoe_native_engine_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.txt"));
        let mut engine = Engine::new(&dir).unwrap();
        assert_eq!(engine.backend_name(), "native");
        // the synthesized manifest carries the AOT exporter's artifacts...
        assert!(engine.manifest().get("train_step_tiny").is_ok());
        assert!(engine.manifest().get("block_fwd_e2e").is_ok());
        // ...and they actually execute: a tiny embed_fwd end to end
        let spec = engine.manifest().get("embed_fwd_tiny").unwrap().clone();
        let embed = HostTensor::F32(vec![0.5; spec.inputs[0].elems()]);
        let tokens = HostTensor::I32(vec![3; spec.inputs[1].elems()]);
        let outs = engine.run("embed_fwd_tiny", &[&embed, &tokens]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].len(), spec.outputs[0].elems());
        let want = 0.5 * (32f64).sqrt() as f32;
        assert!(outs[0].f32().iter().all(|&v| (v - want).abs() < 1e-6));
    }

    #[test]
    fn pjrt_stub_backend_reports_unavailable() {
        let dir = std::env::temp_dir().join(format!("flowmoe_stub_engine_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.txt"));
        let mut engine = Engine::with_backend(&dir, Box::new(PjRtStub)).unwrap();
        assert_eq!(engine.backend_name(), "pjrt-stub");
        let spec = engine.manifest().get("embed_fwd_tiny").unwrap().clone();
        let embed = HostTensor::F32(vec![0.0; spec.inputs[0].elems()]);
        let tokens = HostTensor::I32(vec![0; spec.inputs[1].elems()]);
        // no native kernels and no HLO files on disk: prepare points at
        // `make artifacts`
        let err = format!("{:#}", engine.run("embed_fwd_tiny", &[&embed, &tokens]).unwrap_err());
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn engine_validates_buffers_and_reports_stubbed_backend() {
        let dir =
            std::env::temp_dir().join(format!("flowmoe_engine_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact foo file=foo.hlo.txt config=tiny\n  input a 2x3 f32\n  output y 6 f32\n",
        )
        .unwrap();
        std::fs::write(dir.join("foo.hlo.txt"), "HloModule foo\n").unwrap();
        let mut engine = Engine::new(&dir).unwrap();
        let spec = engine.manifest().get("foo").unwrap().clone();

        // marshalling validates shapes/dtypes
        assert!(engine.buffer_f32(&[0.0; 6], &spec.inputs[0]).is_ok());
        assert!(engine.buffer_f32(&[0.0; 5], &spec.inputs[0]).is_err());
        assert!(engine
            .buffer(&HostTensor::I32(vec![0; 6]), &spec.inputs[0])
            .is_err());

        // execution reports the stubbed backend, not a confusing panic
        let t = HostTensor::F32(vec![0.0; 6]);
        let err = format!("{:#}", engine.run("foo", &[&t]).unwrap_err());
        assert!(err.contains("PJRT backend unavailable"), "{err}");
    }
}
