//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`) behind a
//! manifest-driven engine: `python/compile/aot.py` writes
//! `artifacts/manifest.txt` describing every artifact's positional
//! input/output buffers (name, shape, dtype); the engine parses it so no
//! shape knowledge is duplicated in rust.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so each worker thread owns
//! its own [`Engine`]; host tensors ([`HostTensor`]) are plain `Vec`s and
//! move freely between threads.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Dtype of a buffer (the stack only uses f32 and i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// A named positional buffer in an artifact signature.
#[derive(Clone, Debug)]
pub struct BufSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl BufSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT artifact: file + I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub config: String,
    pub inputs: Vec<BufSpec>,
    pub outputs: Vec<BufSpec>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let mut m = Manifest {
            artifacts: Vec::new(),
            dir: dir.to_path_buf(),
        };
        for line in text.lines() {
            let trimmed = line.trim_start();
            if trimmed.starts_with("artifact ") {
                let parts: Vec<&str> = trimmed.split_whitespace().collect();
                let name = parts.get(1).ok_or_else(|| anyhow!("bad artifact line"))?;
                let mut file = String::new();
                let mut config = String::new();
                for p in &parts[2..] {
                    if let Some(v) = p.strip_prefix("file=") {
                        file = v.to_string();
                    } else if let Some(v) = p.strip_prefix("config=") {
                        config = v.to_string();
                    }
                }
                m.artifacts.push(ArtifactSpec {
                    name: name.to_string(),
                    file,
                    config,
                    inputs: Vec::new(),
                    outputs: Vec::new(),
                });
            } else if trimmed.starts_with("input ") || trimmed.starts_with("output ") {
                let parts: Vec<&str> = trimmed.split_whitespace().collect();
                if parts.len() != 4 {
                    bail!("bad io line: {line}");
                }
                let shape = if parts[2] == "scalar" {
                    vec![]
                } else {
                    parts[2]
                        .split('x')
                        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("{e}: {line}")))
                        .collect::<Result<Vec<_>>>()?
                };
                let dtype = match parts[3] {
                    "f32" => Dtype::F32,
                    "i32" => Dtype::I32,
                    other => bail!("unknown dtype {other}"),
                };
                let spec = BufSpec {
                    name: parts[1].to_string(),
                    shape,
                    dtype,
                };
                let art = m
                    .artifacts
                    .last_mut()
                    .ok_or_else(|| anyhow!("io line before artifact"))?;
                if trimmed.starts_with("input ") {
                    art.inputs.push(spec);
                } else {
                    art.outputs.push(spec);
                }
            }
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }
}

/// A host-side tensor (moves freely across threads).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
    pub fn f32_mut(&mut self) -> &mut Vec<f32> {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }
    pub fn i32(&self) -> &[i32] {
        match self {
            HostTensor::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn scalar_f32(&self) -> f32 {
        self.f32()[0]
    }
}

/// Per-thread PJRT engine: compiles artifacts lazily, caches executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            exes: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) an artifact's executable.
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Upload a host tensor to a device buffer for spec `s`. Exposed so
    /// hot loops can marshal a tensor once and reuse it across many
    /// executions (§Perf: parameters are read by 4R block calls per step
    /// — marshalling them per call dominated the step time).
    ///
    /// Device buffers (`execute_b`) are used instead of Literals
    /// (`execute`): the xla crate's `execute` leaks every input buffer it
    /// creates (`buffer.release()` with no matching delete in
    /// xla_rs.cc::execute — ~1.5 GB/step for the e2e trainer, §Perf #5);
    /// `execute_b` borrows caller-owned buffers and leaks nothing.
    pub fn buffer(&self, t: &HostTensor, s: &BufSpec) -> Result<xla::PjRtBuffer> {
        if t.len() != s.elems() {
            bail!(
                "input {} has {} elems, expected {} ({:?})",
                s.name,
                t.len(),
                s.elems(),
                s.shape
            );
        }
        match (t, s.dtype) {
            (HostTensor::F32(v), Dtype::F32) => self
                .client
                .buffer_from_host_buffer::<f32>(v, &s.shape, None)
                .map_err(|e| anyhow!("{e:?}")),
            (HostTensor::I32(v), Dtype::I32) => self
                .client
                .buffer_from_host_buffer::<i32>(v, &s.shape, None)
                .map_err(|e| anyhow!("{e:?}")),
            _ => bail!("input {} dtype mismatch", s.name),
        }
    }

    /// Upload an f32 slice directly (no HostTensor wrapper, no clone).
    pub fn buffer_f32(&self, v: &[f32], s: &BufSpec) -> Result<xla::PjRtBuffer> {
        if v.len() != s.elems() || s.dtype != Dtype::F32 {
            bail!("input {}: size/dtype mismatch", s.name);
        }
        self.client
            .buffer_from_host_buffer::<f32>(v, &s.shape, None)
            .map_err(|e| anyhow!("{e:?}"))
    }

    /// Execute with caller-owned device buffers (leak-free hot path).
    pub fn run_buffers(&mut self, name: &str, bufs: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let spec = self.manifest.get(name)?.clone();
        if bufs.len() != spec.inputs.len() {
            bail!("{name}: {} inputs given, {} expected", bufs.len(), spec.inputs.len());
        }
        let exe = self.exes.get(name).unwrap();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(bufs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        Self::unpack(name, result, &spec)
    }

    fn unpack(
        name: &str,
        result: Vec<Vec<xla::PjRtBuffer>>,
        spec: &ArtifactSpec,
    ) -> Result<Vec<HostTensor>> {
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: {} outputs, {} expected", parts.len(), spec.outputs.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, s) in parts.into_iter().zip(&spec.outputs) {
            let t = match s.dtype {
                Dtype::F32 => HostTensor::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?),
                Dtype::I32 => HostTensor::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?),
            };
            if t.len() != s.elems() {
                bail!("{name}: output {} wrong size", s.name);
            }
            out.push(t);
        }
        Ok(out)
    }

    /// Build an input Literal for buffer spec `s` from a host tensor.
    /// Prefer [`Engine::buffer`]; kept for Literal-based flows.
    pub fn literal(t: &HostTensor, s: &BufSpec) -> Result<xla::Literal> {
        if t.len() != s.elems() {
            bail!(
                "input {} has {} elems, expected {} ({:?})",
                s.name,
                t.len(),
                s.elems(),
                s.shape
            );
        }
        let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
        let lit = match (t, s.dtype) {
            (HostTensor::F32(v), Dtype::F32) => xla::Literal::vec1(v),
            (HostTensor::I32(v), Dtype::I32) => xla::Literal::vec1(v),
            _ => bail!("input {} dtype mismatch", s.name),
        };
        if s.shape.is_empty() {
            lit.reshape(&[]).map_err(|e| anyhow!("{e:?}"))
        } else {
            lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))
        }
    }

    /// Build an f32 input Literal straight from a slice (no HostTensor
    /// wrapper, no intermediate clone).
    pub fn literal_f32(v: &[f32], s: &BufSpec) -> Result<xla::Literal> {
        if v.len() != s.elems() || s.dtype != Dtype::F32 {
            bail!("input {}: size/dtype mismatch", s.name);
        }
        let dims: Vec<i64> = s.shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(v);
        if s.shape.is_empty() {
            lit.reshape(&[]).map_err(|e| anyhow!("{e:?}"))
        } else {
            lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))
        }
    }

    /// Execute an artifact with host tensors; validates shapes against the
    /// manifest and returns outputs as host tensors.
    pub fn run(&mut self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.prepare(name)?;
        let spec = self.manifest.get(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut bufs = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            bufs.push(self.buffer(t, s).map_err(|e| anyhow!("{name}: {e:#}"))?);
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(name, &refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_io_lines() {
        let dir = std::env::temp_dir().join("flowmoe_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact foo file=foo.hlo.txt config=tiny\n  input a 2x3 f32\n  input t scalar f32\n  output y 6 i32\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("foo").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elems(), 6);
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[0].dtype, Dtype::I32);
    }

    #[test]
    fn manifest_missing_artifact_errors() {
        let dir = std::env::temp_dir().join("flowmoe_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "artifact a file=f config=c\n").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::F32(vec![1.0, 2.0]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.f32()[1], 2.0);
        let i = HostTensor::I32(vec![7]);
        assert_eq!(i.i32()[0], 7);
    }
}
