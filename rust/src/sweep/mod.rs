//! Parallel sweep engine for the paper's evaluation grids.
//!
//! FlowMoE's headline experiment (Fig. 6) evaluates 675 customized MoE
//! layers (B x f x N x M x H), each under several scheduling policies and
//! all-reduce chunk sizes S_p. Every case is an independent pure
//! computation (`build_dag` + `simulate`), so the grid is embarrassingly
//! parallel — yet the seed benches walked it in serial loops on one core.
//!
//! [`Sweeper`] runs any such grid across all cores with
//! `std::thread::scope` workers that *steal* chunks of the remaining case
//! range from a shared atomic cursor (dynamic self-scheduling: an idle
//! worker always claims the next unclaimed chunk, so uneven case costs
//! cannot idle a core). Results are written back by input index, making
//! the output **deterministic and input-ordered**: for pure case
//! functions, the parallel result vector is byte-identical to the serial
//! one. A progress/ETA callback hook reports completion as cases finish.
//!
//! A panic inside one case is isolated (`catch_unwind`): the remaining
//! cases still run, and [`Sweeper::try_run`] reports the failing case's
//! index and panic message instead of tearing down the whole sweep.
//!
//! The module also carries the domain grids the benches share: the
//! 675-layer customized grid, OOM filtering, and the ScheMoE-vs-FlowMoE
//! per-case evaluation (used by `fig6_custom_layers`, `perf_hotpath`,
//! `examples/sweep_custom_layers` and the `flowmoe sweep` subcommand).

pub mod scope;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::config::{ClusterProfile, ModelCfg};
use crate::sched::{iteration_time, Policy};

/// Snapshot passed to the progress callback after each completed case.
#[derive(Clone, Copy, Debug)]
pub struct Progress {
    /// Cases completed so far (including this one).
    pub done: usize,
    /// Total cases in the sweep.
    pub total: usize,
    /// Wall seconds since the sweep started.
    pub elapsed_s: f64,
    /// Estimated seconds remaining (elapsed/done extrapolation).
    pub eta_s: f64,
}

/// A case that panicked during the sweep.
#[derive(Clone, Debug)]
pub struct CasePanic {
    /// Input index of the failing case.
    pub index: usize,
    /// Stringified panic payload.
    pub message: String,
}

impl std::fmt::Display for CasePanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "case {} panicked: {}", self.index, self.message)
    }
}

type ProgressFn = Box<dyn Fn(&Progress) + Send + Sync>;

/// Multi-core sweep runner. See the module docs for the scheduling model.
pub struct Sweeper {
    threads: usize,
    chunk: usize,
    progress: Option<ProgressFn>,
}

impl Default for Sweeper {
    fn default() -> Self {
        Self::new()
    }
}

impl Sweeper {
    /// A sweeper using the caller's thread budget ([`scope::current_budget`]:
    /// `FLOWMOE_THREADS` or every available core), claiming one case at a
    /// time (finest-grained balancing; each simulator case is ~ms, far
    /// above the cost of one atomic claim).
    pub fn new() -> Sweeper {
        Sweeper {
            threads: scope::current_budget(),
            chunk: 1,
            progress: None,
        }
    }

    /// Override the worker-thread count (1 = serial, for baselines).
    pub fn with_threads(mut self, n: usize) -> Sweeper {
        self.threads = n.max(1);
        self
    }

    /// Override how many cases a worker claims per steal.
    pub fn with_chunk(mut self, c: usize) -> Sweeper {
        self.chunk = c.max(1);
        self
    }

    /// Install a progress/ETA callback, invoked (from worker threads)
    /// after every completed case.
    pub fn on_progress(mut self, f: impl Fn(&Progress) + Send + Sync + 'static) -> Sweeper {
        self.progress = Some(Box::new(f));
        self
    }

    /// Configured worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f` over every item; results are input-ordered. Panics
    /// after the sweep completes if any case panicked (all other cases
    /// still finish first) — use [`Sweeper::try_run`] to handle failures.
    pub fn run<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let results = self.try_run(items, f);
        let total = results.len();
        let mut out = Vec::with_capacity(total);
        let mut failures: Vec<CasePanic> = Vec::new();
        for r in results {
            match r {
                Ok(v) => out.push(v),
                Err(e) => failures.push(e),
            }
        }
        if let Some(first) = failures.first() {
            panic!("sweep: {}/{} cases panicked; first: {}", failures.len(), total, first);
        }
        out
    }

    /// Evaluate `f` over every item, capturing per-case panics instead of
    /// propagating them. The result vector is input-ordered.
    pub fn try_run<T, R, F>(&self, items: &[T], f: F) -> Vec<Result<R, CasePanic>>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let t0 = Instant::now();
        let done = AtomicUsize::new(0);
        let threads = self.threads.min(n);
        let mut out: Vec<Option<Result<R, CasePanic>>> = (0..n).map(|_| None).collect();

        if threads <= 1 {
            for (i, item) in items.iter().enumerate() {
                out[i] = Some(run_case(&f, i, item));
                self.report(&done, n, t0);
            }
        } else {
            let cursor = AtomicUsize::new(0);
            let chunk = self.chunk;
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(threads);
                for _ in 0..threads {
                    let f = &f;
                    let cursor = &cursor;
                    let done = &done;
                    handles.push(s.spawn(move || {
                        // budget 1 inside: a case that itself calls the
                        // parallel kernels must not oversubscribe the host
                        scope::with_budget(1, || {
                            let mut local: Vec<(usize, Result<R, CasePanic>)> = Vec::new();
                            loop {
                                // steal the next unclaimed chunk of the range
                                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                                if start >= n {
                                    break;
                                }
                                let end = (start + chunk).min(n);
                                for i in start..end {
                                    local.push((i, run_case(f, i, &items[i])));
                                    self.report(done, n, t0);
                                }
                            }
                            local
                        })
                    }));
                }
                for h in handles {
                    // audited: worker closures catch case panics (run_case),
                    // so join only fails on an unwinding harness bug
                    // flowmoe-lint: allow(unwrap)
                    for (i, r) in h.join().expect("sweep worker thread died") {
                        out[i] = Some(r);
                    }
                }
            });
        }
        out.into_iter()
            // audited: the chunk cursor covers 0..n exactly, so every slot
            // is filled; an empty slot is a harness bug worth a loud stop
            // flowmoe-lint: allow(unwrap)
            .map(|o| o.expect("sweep case never executed"))
            .collect()
    }

    fn report(&self, done: &AtomicUsize, total: usize, t0: Instant) {
        let d = done.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(cb) = &self.progress {
            let elapsed_s = t0.elapsed().as_secs_f64();
            let eta_s = elapsed_s / d as f64 * (total - d) as f64;
            cb(&Progress {
                done: d,
                total,
                elapsed_s,
                eta_s,
            });
        }
    }
}

fn run_case<T, R, F>(f: &F, i: usize, item: &T) -> Result<R, CasePanic>
where
    F: Fn(usize, &T) -> R,
{
    catch_unwind(AssertUnwindSafe(|| f(i, item))).map_err(|p| CasePanic {
        index: i,
        message: panic_message(p.as_ref()),
    })
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Parallel map with default settings (all cores, input-ordered output).
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    Sweeper::new().run(items, f)
}

// ---------------------------------------------------------------------------
// Domain grids: the paper's customized-layer sweep (Fig. 6)
// ---------------------------------------------------------------------------

/// Mini-batch sizes of the customized-layer grid (paper Sec. 5.1).
pub const GRID_B: [usize; 3] = [2, 4, 8];
/// Capacity factors of the grid.
pub const GRID_F: [f64; 3] = [1.0, 1.1, 1.2];
/// Sequence lengths of the grid.
pub const GRID_N: [usize; 3] = [512, 1024, 2048];
/// Embedding sizes of the grid.
pub const GRID_M: [usize; 5] = [512, 1024, 2048, 4096, 8192];
/// Expert hidden sizes of the grid.
pub const GRID_H: [usize; 5] = [512, 1024, 2048, 4096, 8192];

/// Coarse BO stand-in S_p grid used by the Fig. 6 FlowMoE rows.
pub const SP_GRID_FIG6: [f64; 4] = [1e6, 4e6, 16e6, 64e6];

/// The full 675-config customized-MoE-layer grid (3 x 3 x 3 x 5 x 5) in
/// row-major (B, f, N, M, H) order — the order the seed's serial loops
/// walked, so parallel results line up case-for-case.
pub fn custom_layer_grid(gpus: usize) -> Vec<ModelCfg> {
    let cap = GRID_B.len() * GRID_F.len() * GRID_N.len() * GRID_M.len() * GRID_H.len();
    let mut out = Vec::with_capacity(cap);
    for b in GRID_B {
        for f in GRID_F {
            for n in GRID_N {
                for m in GRID_M {
                    for h in GRID_H {
                        out.push(ModelCfg::custom_layer(b, f, n, m, h, gpus));
                    }
                }
            }
        }
    }
    out
}

/// Scan the grid in order, dropping OOM configs (like the paper), until
/// `limit` valid cases are collected. Returns (valid configs, OOM count
/// among the scanned prefix).
pub fn valid_custom_layers(cl: &ClusterProfile, gpus: usize, limit: usize) -> (Vec<ModelCfg>, usize) {
    let mut valid = Vec::new();
    let mut oom = 0usize;
    for cfg in custom_layer_grid(gpus) {
        if valid.len() >= limit {
            break;
        }
        if crate::cost::peak_memory_bytes(&cfg, gpus, 1.0, 1.0) > cl.mem_bytes {
            oom += 1;
            continue;
        }
        valid.push(cfg);
    }
    (valid, oom)
}

/// Best simulated iteration time over an S_p grid (coarse BO stand-in).
pub fn tuned_min<F: Fn(f64) -> Policy>(
    cfg: &ModelCfg,
    cl: &ClusterProfile,
    sp_grid: &[f64],
    make: F,
) -> f64 {
    sp_grid
        .iter()
        .map(|&sp| iteration_time(cfg, cl, &make(sp)).0)
        .fold(f64::INFINITY, f64::min)
}

/// One Fig. 6 case: (ScheMoE seconds, tuned FlowMoE-CC seconds).
pub fn flow_vs_sche(cfg: &ModelCfg, cl: &ClusterProfile) -> (f64, f64) {
    let sche = iteration_time(cfg, cl, &Policy::sche_moe(2)).0;
    let flow = tuned_min(cfg, cl, &SP_GRID_FIG6, |sp| Policy::flow_moe_cc(2, sp));
    (sche, flow)
}

/// Aggregated Fig. 6 sweep outcome.
pub struct Fig6Stats {
    /// ScheMoE/FlowMoE speedup per valid case, grid order.
    pub speedups: Vec<f64>,
    /// OOM-excluded config count.
    pub oom: usize,
    /// Cases where FlowMoE strictly beat ScheMoE.
    pub wins: usize,
}

/// Run the customized-layer sweep (Fig. 6) on `sweeper`'s thread pool.
pub fn fig6_sweep(sweeper: &Sweeper, cl: &ClusterProfile, gpus: usize, limit: usize) -> Fig6Stats {
    let (cases, oom) = valid_custom_layers(cl, gpus, limit);
    let pairs = sweeper.run(&cases, |_, cfg| flow_vs_sche(cfg, cl));
    let wins = pairs.iter().filter(|(sche, flow)| flow < sche).count();
    let speedups = pairs.iter().map(|(sche, flow)| sche / flow).collect();
    Fig6Stats { speedups, oom, wins }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    #[test]
    fn empty_input_yields_empty_output() {
        let items: Vec<u64> = Vec::new();
        let out: Vec<u64> = Sweeper::new().run(&items, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_results_match_serial_bit_for_bit() {
        // The acceptance property: same grid, same bytes, any thread count.
        let cl = ClusterProfile::cluster1(16);
        let (cases, _) = valid_custom_layers(&cl, 16, 24);
        assert!(!cases.is_empty());
        let serial: Vec<(f64, f64)> = Sweeper::new()
            .with_threads(1)
            .run(&cases, |_, cfg| flow_vs_sche(cfg, &cl));
        for threads in [2usize, 4, 8] {
            let par: Vec<(f64, f64)> = Sweeper::new()
                .with_threads(threads)
                .run(&cases, |_, cfg| flow_vs_sche(cfg, &cl));
            assert_eq!(serial.len(), par.len());
            for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "case {i} ({threads} threads)");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "case {i} ({threads} threads)");
            }
        }
    }

    #[test]
    fn results_are_input_ordered() {
        let items: Vec<usize> = (0..997).collect();
        let out = Sweeper::new().with_threads(8).run(&items, |i, &x| {
            assert_eq!(i, x);
            x * 3 + 1
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3 + 1);
        }
    }

    #[test]
    fn one_panicking_case_is_isolated() {
        let items: Vec<usize> = (0..64).collect();
        let results = Sweeper::new()
            .with_threads(4)
            .try_run(&items, |_, &x| {
                if x == 13 {
                    panic!("unlucky case {x}");
                }
                x * 2
            });
        assert_eq!(results.len(), 64);
        for (i, r) in results.iter().enumerate() {
            if i == 13 {
                let e = r.as_ref().unwrap_err();
                assert_eq!(e.index, 13);
                assert!(e.message.contains("unlucky case 13"), "{}", e.message);
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 2);
            }
        }
    }

    #[test]
    #[should_panic(expected = "cases panicked")]
    fn run_surfaces_case_panics_after_completion() {
        let items = vec![1usize, 2, 3];
        let _ = Sweeper::new().with_threads(2).run(&items, |_, &x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn progress_callback_reports_every_case_and_eta() {
        let calls = Arc::new(AtomicUsize::new(0));
        let max_done = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&calls);
        let m2 = Arc::clone(&max_done);
        let items: Vec<usize> = (0..40).collect();
        let out = Sweeper::new()
            .with_threads(4)
            .on_progress(move |p| {
                c2.fetch_add(1, Ordering::SeqCst);
                m2.fetch_max(p.done, Ordering::SeqCst);
                assert_eq!(p.total, 40);
                assert!(p.done >= 1 && p.done <= 40);
                assert!(p.elapsed_s >= 0.0 && p.eta_s >= 0.0);
            })
            .run(&items, |_, &x| x + 1);
        assert_eq!(out.len(), 40);
        assert_eq!(calls.load(Ordering::SeqCst), 40);
        assert_eq!(max_done.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn every_case_runs_exactly_once_even_with_big_chunks() {
        let seen = Arc::new(Mutex::new(vec![0usize; 101]));
        let s2 = Arc::clone(&seen);
        let items: Vec<usize> = (0..101).collect();
        Sweeper::new()
            .with_threads(3)
            .with_chunk(16)
            .run(&items, move |i, _| {
                s2.lock().unwrap()[i] += 1;
            });
        assert!(seen.lock().unwrap().iter().all(|&c| c == 1));
    }

    #[test]
    fn custom_layer_grid_is_675_cases() {
        let grid = custom_layer_grid(16);
        assert_eq!(grid.len(), 675);
        assert!(grid.iter().all(|c| c.e == 16 && c.k == 2 && c.l == 1));
        // row-major order: H varies fastest
        assert_eq!(grid[0].h, 512);
        assert_eq!(grid[1].h, 1024);
    }

    #[test]
    fn valid_layers_respect_limit_and_filter_oom() {
        let cl = ClusterProfile::cluster1(16);
        let (all, oom_all) = valid_custom_layers(&cl, 16, usize::MAX);
        assert_eq!(all.len() + oom_all, 675);
        assert!(oom_all > 0, "expected some OOM configs on a 24GB card");
        let (few, _) = valid_custom_layers(&cl, 16, 10);
        assert_eq!(few.len(), 10);
        assert_eq!(&all[..10], &few[..]);
    }

    #[test]
    fn fig6_sweep_sample_flowmoe_wins_majority() {
        let cl = ClusterProfile::cluster1(16);
        let sweeper = Sweeper::new();
        let stats = fig6_sweep(&sweeper, &cl, 16, 32);
        assert_eq!(stats.speedups.len(), 32);
        assert!(stats.wins * 2 > stats.speedups.len(), "wins {}/{}", stats.wins, stats.speedups.len());
        assert!(crate::util::mean(&stats.speedups) > 1.0);
    }

    #[test]
    fn prop_par_map_equals_serial_map() {
        // Property: for random integer workloads, the parallel sweep is
        // exactly the serial map (order, values, length).
        check(25, |rng| {
            let n = rng.below(200);
            let items: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1_000_000).collect();
            let threads = rng.range(1, 8);
            let f = |i: usize, x: &u64| x.wrapping_mul(31).wrapping_add(i as u64);
            let serial: Vec<u64> = items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
            let par = Sweeper::new().with_threads(threads).run(&items, f);
            if serial != par {
                return Err(format!("mismatch at n={n}, threads={threads}"));
            }
            Ok(())
        });
    }
}
