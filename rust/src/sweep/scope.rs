//! Intra-step parallelism primitives with a crate-wide thread budget.
//!
//! The sweep engine parallelizes *across* independent benchmark cases;
//! the native backend parallelizes *inside* one training step (matmul
//! row bands, experts, attention heads, per-tensor optimizer updates).
//! Both kinds of parallelism can nest — a sweep case may run a model
//! step, a `train_dp` worker runs kernels — so raw
//! `available_parallelism()` everywhere would oversubscribe the host.
//!
//! This module is the single arbiter:
//!
//! * [`default_budget`] — process-wide thread budget, `FLOWMOE_THREADS`
//!   env var when set, else the detected core count.
//! * [`current_budget`] / [`with_budget`] — a thread-local override so a
//!   coordinator (e.g. `trainer::train_dp` spawning P workers) can hand
//!   each child `budget / P` threads.
//! * Worker threads spawned by the primitives below run with budget 1,
//!   so nested `par_*` calls degrade to serial instead of multiplying.
//!
//! Every primitive is **deterministic**: work is split into contiguous
//! input-ordered bands and each unit of work is computed exactly as the
//! serial path computes it, so results are byte-identical to a serial
//! run for any budget (the property `perf_hotpath` and the kernel
//! parity tests assert).

use std::cell::Cell;
use std::sync::OnceLock;

/// Process-wide default thread budget: the `FLOWMOE_THREADS` env var
/// when set to a positive integer, else the detected core count (read
/// once; changing the env var mid-process has no effect).
pub fn default_budget() -> usize {
    static BUDGET: OnceLock<usize> = OnceLock::new();
    *BUDGET.get_or_init(|| {
        std::env::var("FLOWMOE_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

thread_local! {
    static LOCAL_BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Thread budget of the calling thread: the innermost [`with_budget`]
/// override, else [`default_budget`].
pub fn current_budget() -> usize {
    LOCAL_BUDGET.with(|b| b.get()).unwrap_or_else(default_budget)
}

/// Restores the previous thread-local budget on drop (panic-safe).
struct BudgetGuard {
    prev: Option<usize>,
}

impl BudgetGuard {
    fn set(n: usize) -> BudgetGuard {
        let prev = LOCAL_BUDGET.with(|b| {
            let p = b.get();
            b.set(Some(n.max(1)));
            p
        });
        BudgetGuard { prev }
    }
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        LOCAL_BUDGET.with(|b| b.set(prev));
    }
}

/// Run `f` with the calling thread's budget overridden to `n` (min 1).
/// Nested overrides stack; the previous value is restored afterwards,
/// panic included.
pub fn with_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let _guard = BudgetGuard::set(n);
    f()
}

/// Split `n` items into at most `parts` contiguous `(start, len)` bands
/// of near-equal size (first `n % parts` bands get one extra item).
fn bands(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push((start, len));
        start += len;
    }
    out
}

/// Split the rows of a row-major `(rows, row_len)` buffer into
/// contiguous bands across the thread budget; each band is processed as
/// `f(first_row, band)` on its own scoped thread (budget 1 inside).
///
/// `f` must compute each row independently of the banding (the kernel
/// contract in `backend::kernels`), so the buffer contents are
/// byte-identical to `f(0, out)` for any budget.
pub fn par_rows<F>(out: &mut [f32], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    // hard assert: a ragged buffer would make the banding drop the tail
    // in the parallel path only, breaking the byte-identity contract
    assert_eq!(out.len() % row_len, 0, "par_rows: buffer not a whole number of rows");
    let rows = out.len() / row_len;
    let threads = current_budget().min(rows);
    if threads <= 1 {
        f(0, out);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        for (start, len) in bands(rows, threads) {
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(len * row_len);
            rest = tail;
            s.spawn(move || {
                let _g = BudgetGuard::set(1);
                let _sp = crate::obs::span("scope_worker");
                f(start, band);
            });
        }
    });
}

/// Like [`par_rows`], but bands **two** row-major buffers by one shared
/// row split: `f(first_row, band_a, band_b)` receives the same rows of
/// `a` (row length `a_len`) and `b` (row length `b_len`). Both buffers
/// must hold the same whole number of rows. Used by the native backend's
/// cross-entropy loop, where each row produces a gradient row *and* a
/// per-row loss slot; the same banding contract as [`par_rows`] applies,
/// so the contents of both buffers are byte-identical to `f(0, a, b)`
/// for any budget.
pub fn par_rows_pair<F>(a: &mut [f32], a_len: usize, b: &mut [f32], b_len: usize, f: F)
where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    if a_len == 0 || b_len == 0 || a.is_empty() {
        return;
    }
    assert_eq!(a.len() % a_len, 0, "par_rows_pair: first buffer not a whole number of rows");
    assert_eq!(b.len() % b_len, 0, "par_rows_pair: second buffer not a whole number of rows");
    let rows = a.len() / a_len;
    assert_eq!(b.len() / b_len, rows, "par_rows_pair: row counts differ");
    let threads = current_budget().min(rows);
    if threads <= 1 {
        f(0, a, b);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest_a = a;
        let mut rest_b = b;
        for (start, len) in bands(rows, threads) {
            let (band_a, tail_a) = std::mem::take(&mut rest_a).split_at_mut(len * a_len);
            rest_a = tail_a;
            let (band_b, tail_b) = std::mem::take(&mut rest_b).split_at_mut(len * b_len);
            rest_b = tail_b;
            s.spawn(move || {
                let _g = BudgetGuard::set(1);
                let _sp = crate::obs::span("scope_worker");
                f(start, band_a, band_b);
            });
        }
    });
}

/// Distribute owned work items across the thread budget; item `i` is
/// handled exactly once as `f(i, item)` (budget 1 inside the workers).
/// Items typically carry disjoint `&mut` views of one output — e.g. the
/// per-expert slabs of `expert_ffn` — which keeps the result
/// independent of the distribution.
pub fn par_items<T, F>(items: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let n = items.len();
    let threads = current_budget().min(n);
    if threads <= 1 {
        for (i, it) in items.into_iter().enumerate() {
            f(i, it);
        }
        return;
    }
    // peel contiguous index bands off the tail so each thread owns a sub-vec
    let mut rest = items;
    let mut parts: Vec<(usize, Vec<T>)> = Vec::with_capacity(threads);
    for (start, _len) in bands(n, threads).into_iter().rev() {
        parts.push((start, rest.split_off(start)));
    }
    std::thread::scope(|s| {
        let f = &f;
        for (start, chunk) in parts {
            s.spawn(move || {
                let _g = BudgetGuard::set(1);
                let _sp = crate::obs::span("scope_worker");
                for (j, it) in chunk.into_iter().enumerate() {
                    f(start + j, it);
                }
            });
        }
    });
}

/// Parallel input-ordered map over `0..n`: returns
/// `[f(0), f(1), ..., f(n-1)]`, identical to the serial map for pure
/// `f` (contiguous bands, one scoped thread each, budget 1 inside).
pub fn par_map_vec<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = current_budget().min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let banding = bands(n, threads);
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = banding
            .into_iter()
            .map(|(start, len)| {
                s.spawn(move || {
                    let _g = BudgetGuard::set(1);
                    let _sp = crate::obs::span("scope_worker");
                    (start..start + len).map(f).collect::<Vec<R>>()
                })
            })
            .collect();
        let mut out = Vec::with_capacity(n);
        for h in handles {
            // audited: re-raising a worker panic on the caller thread
            // flowmoe-lint: allow(unwrap)
            out.extend(h.join().expect("par_map_vec worker panicked"));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn bands_cover_range_exactly_once() {
        for n in [0usize, 1, 2, 7, 64, 101] {
            for parts in [1usize, 2, 3, 8, 200] {
                let b = bands(n, parts);
                let mut next = 0;
                for (start, len) in b {
                    assert_eq!(start, next);
                    assert!(len >= 1);
                    next += len;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn with_budget_overrides_and_restores() {
        let outer = current_budget();
        with_budget(3, || {
            assert_eq!(current_budget(), 3);
            with_budget(1, || assert_eq!(current_budget(), 1));
            assert_eq!(current_budget(), 3);
        });
        assert_eq!(current_budget(), outer);
    }

    #[test]
    fn with_budget_floors_at_one() {
        with_budget(0, || assert_eq!(current_budget(), 1));
    }

    #[test]
    fn par_rows_matches_serial_bitwise() {
        let row_len = 17;
        let rows = 23;
        let fill = |first_row: usize, band: &mut [f32]| {
            for (r, row) in band.chunks_exact_mut(row_len).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = ((first_row + r) * 1000 + j) as f32 * 0.25;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * row_len];
        fill(0, &mut serial);
        for budget in [1usize, 2, 3, 8, 64] {
            let mut par = vec![0.0f32; rows * row_len];
            with_budget(budget, || par_rows(&mut par, row_len, fill));
            assert!(serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()), "budget {budget}");
        }
    }

    #[test]
    fn par_rows_pair_matches_serial_bitwise() {
        let (a_len, b_len, rows) = (13usize, 2usize, 29usize);
        let fill = |first: usize, a: &mut [f32], b: &mut [f32]| {
            for (r, (arow, brow)) in a.chunks_exact_mut(a_len).zip(b.chunks_exact_mut(b_len)).enumerate() {
                let row = first + r;
                for (j, v) in arow.iter_mut().enumerate() {
                    *v = (row * 100 + j) as f32 * 0.5;
                }
                brow[0] = row as f32;
                brow[1] = arow.iter().sum();
            }
        };
        let mut sa = vec![0.0f32; rows * a_len];
        let mut sb = vec![0.0f32; rows * b_len];
        fill(0, &mut sa, &mut sb);
        for budget in [1usize, 2, 3, 7, 64] {
            let mut pa = vec![0.0f32; rows * a_len];
            let mut pb = vec![0.0f32; rows * b_len];
            with_budget(budget, || par_rows_pair(&mut pa, a_len, &mut pb, b_len, fill));
            assert!(sa.iter().zip(&pa).all(|(x, y)| x.to_bits() == y.to_bits()), "a budget {budget}");
            assert!(sb.iter().zip(&pb).all(|(x, y)| x.to_bits() == y.to_bits()), "b budget {budget}");
        }
    }

    #[test]
    #[should_panic(expected = "row counts differ")]
    fn par_rows_pair_rejects_mismatched_row_counts() {
        let mut a = vec![0.0f32; 6]; // 3 rows of 2
        let mut b = vec![0.0f32; 4]; // 4 rows of 1
        par_rows_pair(&mut a, 2, &mut b, 1, |_, _, _| {});
    }

    #[test]
    fn par_rows_workers_run_with_budget_one() {
        let mut out = vec![0.0f32; 16];
        with_budget(4, || {
            par_rows(&mut out, 4, |_, band| {
                assert_eq!(current_budget(), 1);
                band.fill(1.0);
            });
        });
        assert!(out.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn par_items_processes_each_item_once_in_place() {
        let n = 37;
        let mut data = vec![0u64; n];
        let items: Vec<(usize, &mut u64)> = data.iter_mut().enumerate().collect();
        let calls = AtomicUsize::new(0);
        with_budget(5, || {
            par_items(items, |i, (orig, slot)| {
                assert_eq!(i, orig);
                *slot = i as u64 * 7 + 1;
                calls.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(calls.load(Ordering::SeqCst), n);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 * 7 + 1);
        }
    }

    #[test]
    fn par_map_vec_is_input_ordered() {
        for budget in [1usize, 2, 4, 9] {
            let out = with_budget(budget, || par_map_vec(25, |i| i * i));
            assert_eq!(out, (0..25).map(|i| i * i).collect::<Vec<_>>(), "budget {budget}");
        }
    }

    #[test]
    fn default_budget_is_positive() {
        assert!(default_budget() >= 1);
        assert!(current_budget() >= 1);
    }
}
