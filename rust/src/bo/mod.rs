//! Bayesian optimization of the all-reduce chunk size S_p (paper Sec. 4.1
//! and Appendix D), built from scratch: Gaussian-process regression
//! (Matern-5/2 / RBF / Rational-Quadratic kernels) with Expected
//! Improvement / Probability of Improvement / Lower Confidence Bound
//! acquisitions, plus the grid-search and random baselines of Table A.3
//! and the re-tuning trigger of Appendix K.2 (Eq. A.11).
//!
//! Candidate evaluation can run serially ([`BoTuner::tune`]) or in
//! parallel batches through the multi-core sweep engine
//! ([`BoTuner::tune_batch`]): each round scores the acquisition once,
//! picks `q` spread-out maximizers and fans the objective evaluations
//! across cores — the profiling iterations dominate BO wall time
//! (Table A.6), so batching them is a near-linear speedup.

pub mod gp;

use crate::util::Rng;
pub use gp::{Gp, Kernel};

/// Acquisition function (Appendix D.1 / Table A.5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Acquisition {
    /// Expected improvement with exploration weight xi (paper: xi = 0.1).
    Ei { xi: f64 },
    /// Probability of improvement.
    Pi { xi: f64 },
    /// Lower confidence bound (minimization): mu - kappa * sigma.
    Lcb { kappa: f64 },
}

/// BO tuner for minimizing iteration time over S_p in (0, max_bytes].
pub struct BoTuner {
    pub kernel: Kernel,
    pub acq: Acquisition,
    pub max_bytes: f64,
    /// Observed (sp_bytes, seconds) pairs.
    pub observations: Vec<(f64, f64)>,
    rng: Rng,
    /// Candidate grid resolution for acquisition maximization.
    pub n_candidates: usize,
    /// GP observation noise (relative to y std).
    pub noise: f64,
}

impl BoTuner {
    pub fn new(max_bytes: f64, seed: u64) -> Self {
        BoTuner {
            kernel: Kernel::Matern52 { len: 0.25 },
            acq: Acquisition::Ei { xi: 0.1 },
            max_bytes,
            observations: Vec::new(),
            rng: Rng::new(seed),
            n_candidates: 256,
            noise: 1e-3,
        }
    }

    pub fn with_kernel(mut self, k: Kernel) -> Self {
        self.kernel = k;
        self
    }

    pub fn with_acquisition(mut self, a: Acquisition) -> Self {
        self.acq = a;
        self
    }

    fn norm_x(&self, sp: f64) -> f64 {
        sp / self.max_bytes
    }

    /// Record an observed (S_p, iteration time) sample.
    pub fn observe(&mut self, sp_bytes: f64, seconds: f64) {
        assert!(sp_bytes > 0.0 && seconds.is_finite());
        self.observations.push((sp_bytes, seconds));
    }

    /// Best observed configuration so far.
    pub fn best(&self) -> Option<(f64, f64)> {
        self.observations
            .iter()
            .copied()
            .min_by(|a, b| a.1.total_cmp(&b.1))
    }

    /// The `i`-th point of the log-spaced acquisition candidate grid
    /// (the response varies on a log scale).
    fn candidate(&self, i: usize) -> f64 {
        let frac = (i as f64 + 0.5) / self.n_candidates as f64;
        self.max_bytes * (10f64).powf(-2.5 * (1.0 - frac))
    }

    /// Acquisition value at a normalized posterior `(mu, sigma)`.
    fn acq_value(&self, mu: f64, sigma: f64, ybest: f64) -> f64 {
        match self.acq {
            Acquisition::Ei { xi } => {
                let imp = ybest - mu - xi;
                let z = imp / sigma;
                imp * phi_cdf(z) + sigma * phi_pdf(z)
            }
            Acquisition::Pi { xi } => phi_cdf((ybest - mu - xi) / sigma),
            Acquisition::Lcb { kappa } => -(mu - kappa * sigma),
        }
    }

    /// Score every grid candidate under the current posterior.
    fn scored_candidates(&self) -> Vec<(f64, f64)> {
        let (gp, ymean, ystd) = self.fit();
        let ybest = (self.best().map_or(0.0, |(_, y)| y) - ymean) / ystd;
        (0..self.n_candidates)
            .map(|i| {
                let x = self.candidate(i);
                let (mu, var) = gp.predict(self.norm_x(x));
                let sigma = var.max(1e-12).sqrt();
                (x, self.acq_value(mu, sigma, ybest))
            })
            .collect()
    }

    /// Suggest the next S_p to try. First suggestion is random (the
    /// paper's single random initial sample); afterwards the GP-posterior
    /// acquisition is maximized over a candidate grid.
    pub fn suggest(&mut self) -> f64 {
        if self.observations.is_empty() {
            return self.rng.range_f64(0.02, 1.0) * self.max_bytes;
        }
        let mut best = (self.max_bytes * 0.5, f64::NEG_INFINITY);
        for (x, a) in self.scored_candidates() {
            if a > best.1 {
                best = (x, a);
            }
        }
        best.0
    }

    /// Suggest `q` distinct candidates to evaluate *concurrently*: the
    /// acquisition is scored once over the grid, then maximized greedily
    /// with an exclusion window around every pick, so one batch covers
    /// several promising regions instead of clustering on the argmax.
    /// With no observations yet, returns `q` random initial points.
    pub fn suggest_batch(&mut self, q: usize) -> Vec<f64> {
        assert!(q >= 1);
        if self.observations.is_empty() {
            return (0..q).map(|_| self.rng.range_f64(0.02, 1.0) * self.max_bytes).collect();
        }
        let scored = self.scored_candidates();
        let mut order: Vec<usize> = (0..scored.len()).collect();
        order.sort_by(|&a, &b| scored[b].1.total_cmp(&scored[a].1));
        let window = (self.n_candidates / (4 * q)).max(1);
        let mut picked: Vec<usize> = Vec::with_capacity(q);
        for &i in &order {
            if picked.len() == q {
                break;
            }
            if picked.iter().all(|&p| p.abs_diff(i) >= window) {
                picked.push(i);
            }
        }
        // pathological window (q near the grid size): fill with next best
        for &i in &order {
            if picked.len() == q {
                break;
            }
            if !picked.contains(&i) {
                picked.push(i);
            }
        }
        picked.into_iter().map(|i| scored[i].0).collect()
    }

    /// Posterior mean/std (in seconds) at sp — for the Fig. 4 curve.
    pub fn posterior(&self, sp_bytes: f64) -> (f64, f64) {
        let (gp, ymean, ystd) = self.fit();
        let (mu, var) = gp.predict(self.norm_x(sp_bytes));
        (mu * ystd + ymean, var.max(0.0).sqrt() * ystd)
    }

    fn fit(&self) -> (Gp, f64, f64) {
        let xs: Vec<f64> = self.observations.iter().map(|(x, _)| self.norm_x(*x)).collect();
        let ys_raw: Vec<f64> = self.observations.iter().map(|(_, y)| *y).collect();
        let ymean = crate::util::mean(&ys_raw);
        let ystd = crate::util::stddev(&ys_raw).max(1e-12);
        let ys: Vec<f64> = ys_raw.iter().map(|y| (y - ymean) / ystd).collect();
        (Gp::fit(self.kernel, &xs, &ys, self.noise), ymean, ystd)
    }

    /// Run a full tuning loop against an objective (e.g. measured or
    /// simulated iteration time), `n_samples` trials, return best S_p.
    pub fn tune<F: FnMut(f64) -> f64>(&mut self, n_samples: usize, mut objective: F) -> f64 {
        for _ in 0..n_samples {
            let sp = self.suggest();
            let y = objective(sp);
            self.observe(sp, y);
        }
        self.best().map_or(self.max_bytes, |(sp, _)| sp)
    }

    /// Batched tuning loop: draws up to `batch` joint candidates per
    /// round ([`BoTuner::suggest_batch`]) and evaluates them in parallel
    /// on the multi-core sweep engine ([`crate::sweep`]), observing every
    /// result before refitting. Exactly `n_samples` objective evaluations
    /// total (the last round shrinks to the remainder); results are
    /// deterministic in the seed (the sweep is input-ordered).
    pub fn tune_batch<F>(&mut self, n_samples: usize, batch: usize, objective: F) -> f64
    where
        F: Fn(f64) -> f64 + Sync,
    {
        assert!(batch >= 1);
        let mut remaining = n_samples;
        while remaining > 0 {
            let cands = self.suggest_batch(batch.min(remaining));
            let ys = crate::sweep::par_map(&cands, |_, &sp| objective(sp));
            for (sp, y) in cands.iter().zip(&ys) {
                self.observe(*sp, *y);
            }
            remaining -= cands.len();
        }
        self.best().map_or(self.max_bytes, |(sp, _)| sp)
    }
}

/// Appendix K.2 re-tuning trigger (Eq. A.11): re-run BO when the current
/// iteration time deviates from the tuned optimum by more than `delta`.
pub fn should_retune(current_s: f64, tuned_best_s: f64, delta: f64) -> bool {
    (current_s - tuned_best_s).abs() / tuned_best_s > delta
}

/// Grid-search baseline (Table A.3): k equally spaced points.
pub fn grid_search<F: FnMut(f64) -> f64>(max_bytes: f64, k: usize, mut objective: F) -> f64 {
    let mut best = (max_bytes, f64::INFINITY);
    for i in 1..=k {
        let sp = max_bytes * i as f64 / k as f64;
        let y = objective(sp);
        if y < best.1 {
            best = (sp, y);
        }
    }
    best.0
}

/// Random-sampling baseline (Table A.3): pick one random S_p per trial and
/// keep using whatever the last draw was (the paper re-draws every
/// iteration; we model the average behaviour by returning the mean
/// objective over draws together with a representative draw).
pub fn random_tuner<F: FnMut(f64) -> f64>(
    max_bytes: f64,
    trials: usize,
    seed: u64,
    mut objective: F,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut total = 0.0;
    let mut last = max_bytes;
    for _ in 0..trials {
        last = rng.range_f64(0.01, 1.0) * max_bytes;
        total += objective(last);
    }
    (last, total / trials as f64)
}

fn phi_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn phi_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Abramowitz–Stegun 7.1.26 erf approximation (|err| < 1.5e-7).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic single-minimum objective shaped like the paper's Fig. 4:
    /// startup overhead blows up for tiny S_p, overlap loss for huge S_p.
    fn objective(sp_mb: f64) -> f64 {
        let s = sp_mb.max(1e-3);
        0.40 + 0.08 / s + 0.012 * s
    }

    #[test]
    fn erf_reference_values() {
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf(-1.0) + 0.8427007).abs() < 1e-5);
        assert!((erf(3.0) - 0.9999779).abs() < 1e-5);
    }

    #[test]
    fn cdf_monotone() {
        assert!(phi_cdf(-1.0) < phi_cdf(0.0));
        assert!(phi_cdf(0.0) < phi_cdf(1.0));
        assert!((phi_cdf(0.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bo_finds_near_optimal_sp() {
        // analytic optimum of objective: sqrt(0.08/0.012) = 2.58 MB
        let mut bo = BoTuner::new(10e6, 42);
        let best = bo.tune(8, |sp| objective(sp / 1e6));
        let best_mb = best / 1e6;
        let opt = (0.08f64 / 0.012).sqrt();
        // within 2.5x of optimum beats the worst-case by a wide margin
        assert!(
            objective(best_mb) < objective(opt) * 1.12,
            "best {best_mb:.2}MB -> {:.4} vs opt {:.4}",
            objective(best_mb),
            objective(opt)
        );
    }

    #[test]
    fn bo_beats_random_on_average() {
        let mut bo = BoTuner::new(10e6, 7);
        let bo_best = bo.tune(8, |sp| objective(sp / 1e6));
        let (_, rand_avg) = random_tuner(10e6, 8, 7, |sp| objective(sp / 1e6));
        assert!(objective(bo_best / 1e6) < rand_avg);
    }

    #[test]
    fn bo_at_least_grid_quality() {
        let mut bo = BoTuner::new(10e6, 11);
        let bo_best = bo.tune(8, |sp| objective(sp / 1e6));
        let grid_best = grid_search(10e6, 8, |sp| objective(sp / 1e6));
        assert!(objective(bo_best / 1e6) <= objective(grid_best / 1e6) * 1.05);
    }

    #[test]
    fn observations_drive_posterior_down_near_optimum() {
        let mut bo = BoTuner::new(10e6, 3);
        bo.tune(10, |sp| objective(sp / 1e6));
        let (mu_opt, _) = bo.posterior(2.58e6);
        let (mu_bad, _) = bo.posterior(0.05e6);
        assert!(mu_opt < mu_bad);
    }

    #[test]
    fn all_acquisitions_converge() {
        for acq in [
            Acquisition::Ei { xi: 0.1 },
            Acquisition::Ei { xi: 0.05 },
            Acquisition::Ei { xi: 0.2 },
            Acquisition::Pi { xi: 0.1 },
            Acquisition::Lcb { kappa: 2.0 },
        ] {
            let mut bo = BoTuner::new(10e6, 5).with_acquisition(acq);
            let best = bo.tune(10, |sp| objective(sp / 1e6));
            assert!(
                objective(best / 1e6) < 0.52,
                "{acq:?}: best {:.3}",
                objective(best / 1e6)
            );
        }
    }

    #[test]
    fn all_kernels_converge() {
        for k in [
            Kernel::Matern52 { len: 0.25 },
            Kernel::Rbf { len: 0.25 },
            Kernel::RationalQuadratic { len: 0.25, alpha: 1.0 },
        ] {
            let mut bo = BoTuner::new(10e6, 9).with_kernel(k);
            let best = bo.tune(10, |sp| objective(sp / 1e6));
            assert!(objective(best / 1e6) < 0.52, "{k:?}");
        }
    }

    #[test]
    fn retune_trigger() {
        assert!(!should_retune(1.02, 1.0, 0.1));
        assert!(should_retune(1.25, 1.0, 0.1));
        assert!(should_retune(0.7, 1.0, 0.1));
    }

    #[test]
    fn suggest_in_range() {
        let mut bo = BoTuner::new(10e6, 17);
        for _ in 0..6 {
            let sp = bo.suggest();
            assert!(sp > 0.0 && sp <= 10e6);
            bo.observe(sp, objective(sp / 1e6));
        }
    }

    #[test]
    fn suggest_batch_returns_distinct_in_range_candidates() {
        let mut bo = BoTuner::new(10e6, 23);
        // cold start: q random points
        let first = bo.suggest_batch(4);
        assert_eq!(first.len(), 4);
        for &sp in &first {
            assert!(sp > 0.0 && sp <= 10e6);
            bo.observe(sp, objective(sp / 1e6));
        }
        // posterior-driven batch: distinct, spread by the exclusion window
        let batch = bo.suggest_batch(4);
        assert_eq!(batch.len(), 4);
        for i in 0..batch.len() {
            assert!(batch[i] > 0.0 && batch[i] <= 10e6);
            for j in i + 1..batch.len() {
                assert_ne!(batch[i], batch[j], "duplicate candidate in batch");
            }
        }
    }

    #[test]
    fn tune_batch_converges_like_serial() {
        let mut bo = BoTuner::new(10e6, 42);
        // 10 samples in batches of 4: rounds of 4, 4, 2 — exactly 10 evals
        let best = bo.tune_batch(10, 4, |sp| objective(sp / 1e6));
        assert_eq!(bo.observations.len(), 10);
        let opt = (0.08f64 / 0.012).sqrt();
        assert!(
            objective(best / 1e6) < objective(opt) * 1.12,
            "batched best {:.2}MB -> {:.4} vs opt {:.4}",
            best / 1e6,
            objective(best / 1e6),
            objective(opt)
        );
    }

    #[test]
    fn tune_batch_is_deterministic_in_seed() {
        // the parallel sweep is input-ordered, so two runs with the same
        // seed observe identical (sp, y) sequences
        let mut a = BoTuner::new(10e6, 9);
        let mut b = BoTuner::new(10e6, 9);
        a.tune_batch(6, 3, |sp| objective(sp / 1e6));
        b.tune_batch(6, 3, |sp| objective(sp / 1e6));
        assert_eq!(a.observations.len(), b.observations.len());
        for ((xa, ya), (xb, yb)) in a.observations.iter().zip(&b.observations) {
            assert_eq!(xa.to_bits(), xb.to_bits());
            assert_eq!(ya.to_bits(), yb.to_bits());
        }
    }
}
