//! Minimal 1-D Gaussian-process regression (Cholesky-based) used by the
//! BO tuner. Inputs/outputs are pre-normalized by the caller.

/// Stationary covariance kernels (Appendix D.1 / Table A.5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// Matern nu=5/2 with length scale `len` (the paper's choice).
    Matern52 { len: f64 },
    /// Squared-exponential.
    Rbf { len: f64 },
    /// Rational quadratic with scale-mixture parameter `alpha`.
    RationalQuadratic { len: f64, alpha: f64 },
}

impl Kernel {
    pub fn eval(&self, a: f64, b: f64) -> f64 {
        let r = (a - b).abs();
        match *self {
            Kernel::Matern52 { len } => {
                let s = 5f64.sqrt() * r / len;
                (1.0 + s + s * s / 3.0) * (-s).exp()
            }
            Kernel::Rbf { len } => (-(r * r) / (2.0 * len * len)).exp(),
            Kernel::RationalQuadratic { len, alpha } => {
                (1.0 + r * r / (2.0 * alpha * len * len)).powf(-alpha)
            }
        }
    }
}

/// Fitted GP posterior over normalized 1-D inputs.
pub struct Gp {
    kernel: Kernel,
    xs: Vec<f64>,
    /// L from K = L L^T (lower triangular, row-major packed).
    chol: Vec<Vec<f64>>,
    /// alpha = K^{-1} y.
    alpha: Vec<f64>,
}

impl Gp {
    /// Fit on points (xs, ys) with observation-noise variance `noise`.
    pub fn fit(kernel: Kernel, xs: &[f64], ys: &[f64], noise: f64) -> Gp {
        assert_eq!(xs.len(), ys.len());
        let n = xs.len();
        let mut k = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                k[i][j] = kernel.eval(xs[i], xs[j]);
            }
            k[i][i] += noise + 1e-9;
        }
        let chol = cholesky(&k);
        let alpha = chol_solve(&chol, ys);
        Gp {
            kernel,
            xs: xs.to_vec(),
            chol,
            alpha,
        }
    }

    /// Posterior (mean, variance) at x.
    pub fn predict(&self, x: f64) -> (f64, f64) {
        let n = self.xs.len();
        let kstar: Vec<f64> = self.xs.iter().map(|&xi| self.kernel.eval(x, xi)).collect();
        let mu: f64 = kstar.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        // v = L^{-1} k*
        let mut v = kstar.clone();
        for i in 0..n {
            let mut s = v[i];
            for j in 0..i {
                s -= self.chol[i][j] * v[j];
            }
            v[i] = s / self.chol[i][i];
        }
        let var = self.kernel.eval(x, x) - v.iter().map(|a| a * a).sum::<f64>();
        (mu, var.max(0.0))
    }
}

/// Dense Cholesky decomposition (lower triangular). Panics on non-PD
/// input; callers add jitter to the diagonal.
fn cholesky(a: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let n = a.len();
    let mut l = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            if i == j {
                assert!(s > 0.0, "matrix not positive definite (s={s})");
                l[i][j] = s.sqrt();
            } else {
                l[i][j] = s / l[j][j];
            }
        }
    }
    l
}

/// Solve (L L^T) x = y.
fn chol_solve(l: &[Vec<f64>], y: &[f64]) -> Vec<f64> {
    let n = y.len();
    // forward: L z = y
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = y[i];
        for j in 0..i {
            s -= l[i][j] * z[j];
        }
        z[i] = s / l[i][i];
    }
    // backward: L^T x = z
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for j in i + 1..n {
            s -= l[j][i] * x[j];
        }
        x[i] = s / l[i][i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_reconstructs() {
        let a = vec![
            vec![4.0, 2.0, 0.6],
            vec![2.0, 5.0, 1.0],
            vec![0.6, 1.0, 3.0],
        ];
        let l = cholesky(&a);
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += l[i][k] * l[j][k];
                }
                assert!((s - a[i][j]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn chol_solve_identity() {
        let a = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let l = cholesky(&a);
        let x = chol_solve(&l, &[3.0, -2.0]);
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] + 2.0).abs() < 1e-12);
    }

    #[test]
    fn gp_interpolates_training_points() {
        let xs = [0.1, 0.4, 0.7, 0.95];
        let ys = [1.0, -0.5, 0.3, 0.8];
        let gp = Gp::fit(Kernel::Matern52 { len: 0.2 }, &xs, &ys, 1e-8);
        for (x, y) in xs.iter().zip(&ys) {
            let (mu, var) = gp.predict(*x);
            assert!((mu - y).abs() < 1e-2, "mu={mu} y={y}");
            assert!(var < 1e-3);
        }
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let gp = Gp::fit(Kernel::Rbf { len: 0.1 }, &[0.5], &[0.0], 1e-6);
        let (_, v_near) = gp.predict(0.5);
        let (_, v_far) = gp.predict(0.0);
        assert!(v_far > v_near);
    }

    #[test]
    fn kernels_are_one_at_zero_distance() {
        for k in [
            Kernel::Matern52 { len: 0.3 },
            Kernel::Rbf { len: 0.3 },
            Kernel::RationalQuadratic { len: 0.3, alpha: 2.0 },
        ] {
            assert!((k.eval(0.4, 0.4) - 1.0).abs() < 1e-12);
            assert!(k.eval(0.0, 1.0) < 1.0);
        }
    }

    #[test]
    fn kernels_decay_with_distance() {
        for k in [
            Kernel::Matern52 { len: 0.3 },
            Kernel::Rbf { len: 0.3 },
            Kernel::RationalQuadratic { len: 0.3, alpha: 2.0 },
        ] {
            assert!(k.eval(0.0, 0.1) > k.eval(0.0, 0.5));
        }
    }
}
