//! Runtime communication pool (paper Algorithm 2) + real collectives.
//!
//! [`CommPool`] is the per-worker communication thread: two queues (A2A
//! and all-reduce chunks); the pool executes A2A jobs whenever any are
//! queued and AR-chunk jobs only otherwise — exactly the paper's
//! COMMPOOLMANAGER priority rule, with no preemption (a running job
//! completes before the next pick).
//!
//! [`Collective`] provides the real data-movement primitives between the
//! in-process workers: tagged flat all-reduce, barriers and A2A
//! mailboxes. Collective ops must be entered in the same order by every
//! worker; the trainer guarantees this by enqueueing jobs in the
//! deterministic schedule order the coordinator computed (DESIGN.md §5 —
//! the same requirement NCCL imposes on the paper's implementation).
//!
//! # Fault tolerance
//!
//! Every blocking primitive is deadline-bounded and returns
//! `Result<_, `[`CommError`]`>` instead of hanging on a dead peer: a
//! worker that dies is marked via [`Collective::mark_dead`] (by its own
//! thread wrapper, or by a planned kill from the seeded
//! [`FaultPlan`]), and every survivor waiting on it wakes with a typed
//! [`CommError::PeerDead`] within the detection window. Message
//! drop/delay faults are injected in [`Collective::send`] from the same
//! seeded plan, so a whole failure scenario is a pure function of
//! `(plan seed, attempt epoch)` and replays exactly.
//!
//! After any collective op returns `Err`, the group's reduce/barrier
//! state is unspecified (partial arrivals remain); recovery re-forms a
//! fresh `Collective` at the surviving world size. Point-to-point mail
//! plus [`Collective::revive`] stay usable, which is what the serving
//! cluster's in-place worker respawn relies on.

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ft::{Delivery, FaultPlan};
use crate::util::lock_recover;

/// A communication job (runs on the pool thread).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Poison-tolerant condvar wait (same rationale as
/// [`crate::util::lock_recover`]: a panicked worker already fails the
/// run through its join handle; don't cascade the panic).
fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant bounded condvar wait.
fn wait_timeout_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>, d: Duration) -> MutexGuard<'a, T> {
    cv.wait_timeout(g, d).unwrap_or_else(PoisonError::into_inner).0
}

#[derive(Default)]
struct Queues {
    a2a: VecDeque<Job>,
    ar: VecDeque<Job>,
    closed: bool,
    /// jobs executed so far (drain tracking)
    done: u64,
    submitted: u64,
}

/// Priority communication pool: one worker thread, A2A-before-AR.
pub struct CommPool {
    inner: Arc<(Mutex<Queues>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl CommPool {
    pub fn new() -> CommPool {
        let inner = Arc::new((Mutex::new(Queues::default()), Condvar::new()));
        let inner2 = Arc::clone(&inner);
        // flowmoe-lint: allow(thread_spawn) — the pool thread outlives scopes
        let handle = std::thread::Builder::new()
            .name("commpool".into())
            .spawn(move || {
                let (lock, cv) = &*inner2;
                loop {
                    let job = {
                        let mut q = lock_recover(lock);
                        loop {
                            // Algorithm 2: A2A first, then AR chunks.
                            if let Some(j) = q.a2a.pop_front() {
                                break Some(j);
                            }
                            if let Some(j) = q.ar.pop_front() {
                                break Some(j);
                            }
                            if q.closed {
                                break None;
                            }
                            q = wait_recover(cv, q);
                        }
                    };
                    match job {
                        Some(j) => {
                            j();
                            let (lock, cv) = &*inner2;
                            let mut q = lock_recover(lock);
                            q.done += 1;
                            cv.notify_all();
                        }
                        None => return,
                    }
                }
            })
            // audited: the OS refusing a thread at pool construction is
            // unrecoverable for the trainer, so a panic here is deliberate
            // flowmoe-lint: allow(unwrap)
            .expect("spawn commpool");
        CommPool {
            inner,
            handle: Some(handle),
        }
    }

    /// Enqueue a high-priority A2A job.
    pub fn submit_a2a(&self, job: Job) {
        let (lock, cv) = &*self.inner;
        let mut q = lock_recover(lock);
        q.a2a.push_back(job);
        q.submitted += 1;
        cv.notify_all();
    }

    /// Enqueue a low-priority all-reduce chunk job.
    pub fn submit_ar(&self, job: Job) {
        let (lock, cv) = &*self.inner;
        let mut q = lock_recover(lock);
        q.ar.push_back(job);
        q.submitted += 1;
        cv.notify_all();
    }

    /// Block until every submitted job has run.
    pub fn drain(&self) {
        let (lock, cv) = &*self.inner;
        let mut q = lock_recover(lock);
        while q.done < q.submitted {
            q = wait_recover(cv, q);
        }
    }
}

impl Default for CommPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for CommPool {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.inner;
            let mut q = lock_recover(lock);
            q.closed = true;
            cv.notify_all();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Split `len` elements into chunks of at most `chunk_elems` — the paper's
/// PARTITION procedure over a flat gradient tensor. Returns (start, len)
/// ranges covering [0, len) exactly.
pub fn partition_ranges(len: usize, chunk_elems: usize) -> Vec<(usize, usize)> {
    assert!(chunk_elems > 0);
    let mut out = Vec::new();
    let mut s = 0;
    while s < len {
        let l = chunk_elems.min(len - s);
        out.push((s, l));
        s += l;
    }
    out
}

// ---------------------------------------------------------------------------
// Real in-process collectives
// ---------------------------------------------------------------------------

/// Typed failure of a collective op — the hang class turned into errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CommError {
    /// A peer was detected dead while this op waited on it.
    PeerDead { rank: usize, op: &'static str },
    /// No progress within the detection deadline (an unresponsive peer
    /// or a dropped message — indistinguishable from outside).
    Timeout { op: &'static str, waited_ms: u64 },
    /// The collective was shut down ([`Collective::poison`]) while
    /// waiting; stale workers from before a recovery exit through this.
    Closed,
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::PeerDead { rank, op } => write!(f, "peer {rank} dead during {op}"),
            CommError::Timeout { op, waited_ms } => write!(f, "{op} timed out after {waited_ms}ms"),
            CommError::Closed => write!(f, "collective closed"),
        }
    }
}

impl std::error::Error for CommError {}

/// Per-tag all-reduce rendezvous. Contributions are stored per rank and
/// reduced in **rank order** by the last arriver, so the f32 sum is
/// bitwise independent of thread arrival order at any world size.
struct AllReduceSlot {
    parts: Vec<Option<Vec<f32>>>,
    buf: Vec<f32>,
    len: usize,
    arrived: usize,
    copied: usize,
}

/// An injected-delay message parked until its due time.
struct DelayedMsg {
    due: Instant,
    key: (usize, usize, u64),
    data: Vec<f32>,
}

struct CollectiveState {
    reduce: HashMap<u64, AllReduceSlot>,
    mail: HashMap<(usize, usize, u64), Vec<f32>>,
    barrier_gen: u64,
    barrier_arrived: usize,
    /// `dead[r]` = rank r is known dead (its waiters error out).
    dead: Vec<bool>,
    /// When the first currently-live death was marked (detection-latency
    /// measurement anchor; cleared when every rank is revived).
    death_at: Option<Instant>,
    /// The planned kill fires exactly once per collective.
    kill_fired: bool,
    delayed: Vec<DelayedMsg>,
    closed: bool,
}

impl CollectiveState {
    /// Move every due injected-delay message into the mailbox.
    fn release_due(&mut self, now: Instant) {
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].due <= now {
                let m = self.delayed.swap_remove(i);
                self.mail.insert(m.key, m.data);
            } else {
                i += 1;
            }
        }
    }

    fn first_dead(&self) -> Option<usize> {
        self.dead.iter().position(|&d| d)
    }
}

/// In-process collective context shared by the P workers.
pub struct Collective {
    p: usize,
    state: Mutex<CollectiveState>,
    cv: Condvar,
    /// Detection window: any blocking op errors out after this long.
    deadline: Duration,
    /// Seeded fault injection plan (None = faultless).
    fault: Option<FaultPlan>,
    /// Attempt epoch mixed into fault decisions, so a recovery re-run of
    /// the same tags does not deterministically re-drop them.
    epoch: u64,
}

impl Collective {
    pub fn new(p: usize) -> Arc<Collective> {
        Collective::with_opts(p, crate::ft::DETECT_TIMEOUT_MS, None, 0)
    }

    /// Collective with an explicit detection deadline and an optional
    /// seeded fault plan (`epoch` distinguishes recovery attempts).
    pub fn with_opts(p: usize, detect_ms: u64, fault: Option<FaultPlan>, epoch: u64) -> Arc<Collective> {
        Arc::new(Collective {
            p,
            state: Mutex::new(CollectiveState {
                reduce: HashMap::new(),
                mail: HashMap::new(),
                barrier_gen: 0,
                barrier_arrived: 0,
                dead: vec![false; p],
                death_at: None,
                kill_fired: false,
                delayed: Vec::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            deadline: Duration::from_millis(detect_ms.max(1)),
            fault,
            epoch,
        })
    }

    pub fn world(&self) -> usize {
        self.p
    }

    /// Flat all-reduce (sum) of `data` across all P workers under `tag`.
    /// Every worker must call with its own `rank`, the same tag and
    /// equal lengths; tags must be globally ordered consistently (see
    /// module docs). The reduction is performed in rank order, so the
    /// result is bitwise deterministic at any P. Errors within the
    /// detection window if a peer dies or stalls.
    pub fn all_reduce_sum(&self, rank: usize, tag: u64, data: &mut [f32]) -> Result<(), CommError> {
        let p = self.p;
        let mut st = lock_recover(&self.state);
        {
            let slot = st.reduce.entry(tag).or_insert_with(|| AllReduceSlot {
                parts: (0..p).map(|_| None).collect(),
                buf: Vec::new(),
                len: data.len(),
                arrived: 0,
                copied: 0,
            });
            assert_eq!(slot.len, data.len(), "all_reduce length mismatch (tag {tag})");
            slot.parts[rank] = Some(data.to_vec());
            slot.arrived += 1;
            if slot.arrived == p {
                let mut buf = vec![0.0f32; slot.len];
                for part in slot.parts.iter_mut() {
                    if let Some(v) = part.take() {
                        for (b, d) in buf.iter_mut().zip(&v) {
                            *b += *d;
                        }
                    }
                }
                slot.buf = buf;
            }
        }
        if st.reduce.get(&tag).map(|s| s.arrived) == Some(p) {
            self.cv.notify_all();
        } else {
            let start = Instant::now();
            loop {
                if st.reduce.get(&tag).map(|s| s.arrived) == Some(p) {
                    break;
                }
                if st.closed {
                    return Err(CommError::Closed);
                }
                if let Some(d) = st.first_dead() {
                    return Err(CommError::PeerDead { rank: d, op: "all_reduce" });
                }
                let waited = start.elapsed();
                if waited >= self.deadline {
                    return Err(CommError::Timeout {
                        op: "all_reduce",
                        waited_ms: waited.as_millis() as u64,
                    });
                }
                st = wait_timeout_recover(&self.cv, st, self.deadline - waited);
            }
        }
        // copy out; last reader removes the slot
        let remove = {
            let Some(slot) = st.reduce.get_mut(&tag) else {
                return Ok(()); // unreachable: the slot exists until the last copy below
            };
            data.copy_from_slice(&slot.buf);
            slot.copied += 1;
            slot.copied == p
        };
        if remove {
            st.reduce.remove(&tag);
            self.cv.notify_all();
        }
        Ok(())
    }

    /// Deposit a message for `to` (non-blocking). Subject to the seeded
    /// fault plan: the message may be dropped or parked until a delay
    /// elapses.
    pub fn send(&self, from: usize, to: usize, tag: u64, data: Vec<f32>) {
        self.send_inner(from, to, tag, data, false);
    }

    /// Unconditional deposit: bypasses fault injection and overwrites
    /// any undelivered previous message under the same key. Recovery
    /// resends and shutdown sentinels use this — a retransmission *must*
    /// get through, and the original (possibly in-flight delayed) copy
    /// must not trip the duplicate-send assert.
    pub fn send_replace(&self, from: usize, to: usize, tag: u64, data: Vec<f32>) {
        self.send_inner(from, to, tag, data, true);
    }

    fn send_inner(&self, from: usize, to: usize, tag: u64, data: Vec<f32>, replace: bool) {
        let mut st = lock_recover(&self.state);
        if !replace {
            if let Some(plan) = &self.fault {
                match plan.delivery(self.epoch, from, to, tag) {
                    Delivery::Drop => return,
                    Delivery::Delay(ms) => {
                        st.delayed.push(DelayedMsg {
                            due: Instant::now() + Duration::from_millis(ms),
                            key: (from, to, tag),
                            data,
                        });
                        self.cv.notify_all();
                        return;
                    }
                    Delivery::Deliver => {}
                }
            }
            let prev = st.mail.insert((from, to, tag), data);
            assert!(prev.is_none(), "duplicate send ({from}->{to}, tag {tag})");
        } else {
            st.delayed.retain(|m| m.key != (from, to, tag));
            st.mail.insert((from, to, tag), data);
        }
        self.cv.notify_all();
    }

    /// Bounded receive from `from` (default detection deadline).
    pub fn recv(&self, from: usize, to: usize, tag: u64) -> Result<Vec<f32>, CommError> {
        self.recv_timeout(from, to, tag, self.deadline)
    }

    /// Receive with an explicit deadline. Errors with
    /// [`CommError::PeerDead`] as soon as `from` is known dead (unless a
    /// delayed message for this key is still in flight), or with
    /// [`CommError::Timeout`] once the deadline passes.
    pub fn recv_timeout(&self, from: usize, to: usize, tag: u64, deadline: Duration) -> Result<Vec<f32>, CommError> {
        let start = Instant::now();
        let mut st = lock_recover(&self.state);
        loop {
            let now = Instant::now();
            st.release_due(now);
            if let Some(v) = st.mail.remove(&(from, to, tag)) {
                return Ok(v);
            }
            if st.closed {
                return Err(CommError::Closed);
            }
            let pending = st
                .delayed
                .iter()
                .filter(|m| m.key == (from, to, tag))
                .map(|m| m.due)
                .min();
            if st.dead[from] && pending.is_none() {
                return Err(CommError::PeerDead { rank: from, op: "recv" });
            }
            let waited = now.saturating_duration_since(start);
            if waited >= deadline {
                return Err(CommError::Timeout {
                    op: "recv",
                    waited_ms: waited.as_millis() as u64,
                });
            }
            let mut wait = deadline - waited;
            if let Some(due) = pending {
                let until_due = due.saturating_duration_since(now).max(Duration::from_millis(1));
                wait = wait.min(until_due);
            }
            st = wait_timeout_recover(&self.cv, st, wait);
        }
    }

    /// Generation barrier across all workers; errors within the
    /// detection window if a peer dies or stalls.
    pub fn barrier(&self) -> Result<(), CommError> {
        let mut st = lock_recover(&self.state);
        let gen = st.barrier_gen;
        st.barrier_arrived += 1;
        if st.barrier_arrived == self.p {
            st.barrier_arrived = 0;
            st.barrier_gen += 1;
            self.cv.notify_all();
            return Ok(());
        }
        let start = Instant::now();
        while st.barrier_gen == gen {
            if st.closed {
                return Err(CommError::Closed);
            }
            if let Some(d) = st.first_dead() {
                return Err(CommError::PeerDead { rank: d, op: "barrier" });
            }
            let waited = start.elapsed();
            if waited >= self.deadline {
                return Err(CommError::Timeout {
                    op: "barrier",
                    waited_ms: waited.as_millis() as u64,
                });
            }
            st = wait_timeout_recover(&self.cv, st, self.deadline - waited);
        }
        Ok(())
    }

    /// Mark `rank` dead: every op waiting on it wakes with
    /// [`CommError::PeerDead`]. Idempotent; the first marking anchors
    /// [`Collective::death_time`].
    pub fn mark_dead(&self, rank: usize) {
        let mut st = lock_recover(&self.state);
        if !st.dead[rank] {
            st.dead[rank] = true;
            if st.death_at.is_none() {
                st.death_at = Some(Instant::now());
            }
        }
        self.cv.notify_all();
    }

    /// Clear the dead mark on `rank` (a replacement worker took over its
    /// slot, as in the serving cluster's in-place respawn).
    pub fn revive(&self, rank: usize) {
        let mut st = lock_recover(&self.state);
        st.dead[rank] = false;
        if st.first_dead().is_none() {
            st.death_at = None;
        }
        self.cv.notify_all();
    }

    /// Lowest-numbered rank currently marked dead.
    pub fn first_dead(&self) -> Option<usize> {
        lock_recover(&self.state).first_dead()
    }

    /// When the first currently-live death was marked (for detection
    /// latency: `death_time().elapsed()` at the moment the error
    /// surfaced).
    pub fn death_time(&self) -> Option<Instant> {
        lock_recover(&self.state).death_at
    }

    /// True exactly once for the `(rank, step)` named by the fault
    /// plan's kill — the worker that draws `true` simulates its crash.
    pub fn should_die(&self, rank: usize, step: usize) -> bool {
        let Some(plan) = &self.fault else {
            return false;
        };
        if plan.kill != Some((rank, step)) {
            return false;
        }
        let mut st = lock_recover(&self.state);
        if st.kill_fired {
            return false;
        }
        st.kill_fired = true;
        true
    }

    /// Permanently close the collective: every current and future
    /// blocking op returns [`CommError::Closed`]. Used at shutdown so
    /// stale pre-recovery workers exit promptly instead of idling out
    /// their timeout.
    pub fn poison(&self) {
        let mut st = lock_recover(&self.state);
        st.closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_covers_exactly() {
        let r = partition_ranges(10, 3);
        assert_eq!(r, vec![(0, 3), (3, 3), (6, 3), (9, 1)]);
        let total: usize = r.iter().map(|(_, l)| l).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn partition_single_chunk() {
        assert_eq!(partition_ranges(5, 100), vec![(0, 5)]);
    }

    #[test]
    fn partition_empty() {
        assert!(partition_ranges(0, 4).is_empty());
    }

    #[test]
    fn pool_runs_jobs_and_drains() {
        let pool = CommPool::new();
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let n2 = Arc::clone(&n);
            pool.submit_a2a(Box::new(move || {
                n2.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.drain();
        assert_eq!(n.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn pool_prioritizes_a2a_over_ar() {
        // Submit a blocker first so both queues fill before any pick.
        let pool = CommPool::new();
        let order = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));

        let g2 = Arc::clone(&gate);
        pool.submit_ar(Box::new(move || {
            let (l, c) = &*g2;
            let mut open = l.lock().unwrap();
            while !*open {
                open = c.wait(open).unwrap();
            }
        }));
        let o1 = Arc::clone(&order);
        pool.submit_ar(Box::new(move || o1.lock().unwrap().push("ar")));
        let o2 = Arc::clone(&order);
        pool.submit_a2a(Box::new(move || o2.lock().unwrap().push("a2a")));

        // open the gate: pool should then pick a2a before the queued ar
        {
            let (l, c) = &*gate;
            *l.lock().unwrap() = true;
            c.notify_all();
        }
        pool.drain();
        assert_eq!(*order.lock().unwrap(), vec!["a2a", "ar"]);
    }

    #[test]
    fn all_reduce_sums_across_workers() {
        let p = 4;
        let coll = Collective::new(p);
        let mut handles = Vec::new();
        for w in 0..p {
            let c = Arc::clone(&coll);
            handles.push(std::thread::spawn(move || {
                let mut v = vec![w as f32 + 1.0; 8];
                c.all_reduce_sum(w, 1, &mut v).unwrap();
                v
            }));
        }
        for h in handles {
            let v = h.join().unwrap();
            assert!(v.iter().all(|&x| x == 10.0)); // 1+2+3+4
        }
    }

    #[test]
    fn all_reduce_multiple_tags_in_order() {
        let p = 2;
        let coll = Collective::new(p);
        let mut handles = Vec::new();
        for w in 0..p {
            let c = Arc::clone(&coll);
            handles.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for tag in 0..20u64 {
                    let mut v = vec![(w + 1) as f32 * (tag + 1) as f32; 4];
                    c.all_reduce_sum(w, tag, &mut v).unwrap();
                    out.push(v[0]);
                }
                out
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            for (tag, v) in out.iter().enumerate() {
                assert_eq!(*v, 3.0 * (tag + 1) as f32);
            }
        }
    }

    #[test]
    fn all_reduce_is_rank_order_deterministic() {
        // f32 addition is not associative: 1e8 + 1 - 1e8 = 0.0 in rank
        // order (the 1.0 is absorbed), but -1e8 arriving second would
        // give 1.0. With per-rank parts reduced in rank order the result
        // must be exactly 0.0 no matter which thread arrives last.
        let p = 3;
        let contrib = [1e8f32, 1.0, -1e8];
        for round in 0..20u64 {
            let coll = Collective::new(p);
            let mut handles = Vec::new();
            for w in 0..p {
                let c = Arc::clone(&coll);
                let x = contrib[w];
                handles.push(std::thread::spawn(move || {
                    let mut v = vec![x; 4];
                    c.all_reduce_sum(w, round, &mut v).unwrap();
                    v
                }));
            }
            for h in handles {
                let v = h.join().unwrap();
                assert!(v.iter().all(|&x| x == 0.0), "round {round}: got {v:?}");
            }
        }
    }

    #[test]
    fn send_recv_roundtrip() {
        let coll = Collective::new(2);
        let c1 = Arc::clone(&coll);
        let t = std::thread::spawn(move || c1.recv(0, 1, 7));
        coll.send(0, 1, 7, vec![1.0, 2.0, 3.0]);
        assert_eq!(t.join().unwrap().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn barrier_synchronizes() {
        let p = 3;
        let coll = Collective::new(p);
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..p {
            let c = Arc::clone(&coll);
            let n = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                n.fetch_add(1, Ordering::SeqCst);
                c.barrier().unwrap();
                // after the barrier every increment must be visible
                assert_eq!(n.load(Ordering::SeqCst), 3);
                c.barrier().unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn dead_peer_errors_within_deadline() {
        // 3-worker group, one killed: the survivors' collective ops must
        // surface a typed error well before the 2s deadline, not hang.
        let p = 3;
        let coll = Collective::with_opts(p, 2000, None, 0);
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for w in 0..p {
            let c = Arc::clone(&coll);
            handles.push(std::thread::spawn(move || {
                if w == 2 {
                    c.mark_dead(2); // simulated crash before the barrier
                    return Ok(());
                }
                c.barrier()
            }));
        }
        let mut errs = 0;
        for h in handles {
            match h.join().unwrap() {
                Ok(()) => {}
                Err(CommError::PeerDead { rank, op }) => {
                    assert_eq!(rank, 2);
                    assert_eq!(op, "barrier");
                    errs += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(errs, 2, "both survivors must observe the death");
        assert!(t0.elapsed() < Duration::from_millis(1900), "detection must beat the deadline");
    }

    #[test]
    fn recv_timeout_on_silent_peer() {
        let coll = Collective::with_opts(2, 30_000, None, 0);
        let t0 = Instant::now();
        let err = coll.recv_timeout(0, 1, 9, Duration::from_millis(100)).unwrap_err();
        assert!(matches!(err, CommError::Timeout { op: "recv", .. }), "got {err:?}");
        assert!(t0.elapsed() >= Duration::from_millis(100));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn dropped_message_surfaces_as_timeout() {
        let plan = FaultPlan {
            drop_prob: 1.0,
            ..FaultPlan::default()
        };
        let coll = Collective::with_opts(2, 30_000, Some(plan), 0);
        coll.send(0, 1, 3, vec![1.0]);
        let err = coll.recv_timeout(0, 1, 3, Duration::from_millis(80)).unwrap_err();
        assert!(matches!(err, CommError::Timeout { .. }), "got {err:?}");
        // a replace-send must get through regardless of the plan
        coll.send_replace(0, 1, 3, vec![2.0]);
        assert_eq!(coll.recv(0, 1, 3).unwrap(), vec![2.0]);
    }

    #[test]
    fn delayed_message_is_delivered_late() {
        let plan = FaultPlan {
            delay_prob: 1.0,
            delay_ms: 50,
            ..FaultPlan::default()
        };
        let coll = Collective::with_opts(2, 30_000, Some(plan), 0);
        let t0 = Instant::now();
        coll.send(0, 1, 11, vec![7.0]);
        let got = coll.recv_timeout(0, 1, 11, Duration::from_secs(10)).unwrap();
        assert_eq!(got, vec![7.0]);
        assert!(t0.elapsed() >= Duration::from_millis(40), "delivery was not delayed");
    }

    #[test]
    fn should_die_fires_exactly_once() {
        let plan = FaultPlan {
            kill: Some((1, 5)),
            ..FaultPlan::default()
        };
        let coll = Collective::with_opts(2, 1000, Some(plan), 0);
        assert!(!coll.should_die(0, 5), "wrong rank");
        assert!(!coll.should_die(1, 4), "wrong step");
        assert!(coll.should_die(1, 5), "planned kill fires");
        assert!(!coll.should_die(1, 5), "and only once");
    }

    #[test]
    fn poison_unblocks_waiters() {
        let coll = Collective::with_opts(2, 60_000, None, 0);
        let c1 = Arc::clone(&coll);
        let t = std::thread::spawn(move || c1.recv(0, 1, 1));
        std::thread::sleep(Duration::from_millis(20));
        coll.poison();
        assert_eq!(t.join().unwrap().unwrap_err(), CommError::Closed);
        // subsequent ops fail fast too
        assert_eq!(coll.barrier().unwrap_err(), CommError::Closed);
    }
}
