//! Runtime communication pool (paper Algorithm 2) + real collectives.
//!
//! [`CommPool`] is the per-worker communication thread: two queues (A2A
//! and all-reduce chunks); the pool executes A2A jobs whenever any are
//! queued and AR-chunk jobs only otherwise — exactly the paper's
//! COMMPOOLMANAGER priority rule, with no preemption (a running job
//! completes before the next pick).
//!
//! [`Collective`] provides the real data-movement primitives between the
//! in-process workers: tagged flat all-reduce, barriers and A2A
//! mailboxes. Collective ops must be entered in the same order by every
//! worker; the trainer guarantees this by enqueueing jobs in the
//! deterministic schedule order the coordinator computed (DESIGN.md §5 —
//! the same requirement NCCL imposes on the paper's implementation).

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;

/// A communication job (runs on the pool thread).
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Poison-tolerant lock: a panicked worker already fails the run through
/// its join handle, so recover the inner state instead of cascading the
/// panic into every thread sharing the pool.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant condvar wait (same rationale as [`lock_recover`]).
fn wait_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

#[derive(Default)]
struct Queues {
    a2a: VecDeque<Job>,
    ar: VecDeque<Job>,
    closed: bool,
    /// jobs executed so far (drain tracking)
    done: u64,
    submitted: u64,
}

/// Priority communication pool: one worker thread, A2A-before-AR.
pub struct CommPool {
    inner: Arc<(Mutex<Queues>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl CommPool {
    pub fn new() -> CommPool {
        let inner = Arc::new((Mutex::new(Queues::default()), Condvar::new()));
        let inner2 = Arc::clone(&inner);
        // flowmoe-lint: allow(thread_spawn) — the pool thread outlives scopes
        let handle = std::thread::Builder::new()
            .name("commpool".into())
            .spawn(move || {
                let (lock, cv) = &*inner2;
                loop {
                    let job = {
                        let mut q = lock_recover(lock);
                        loop {
                            // Algorithm 2: A2A first, then AR chunks.
                            if let Some(j) = q.a2a.pop_front() {
                                break Some(j);
                            }
                            if let Some(j) = q.ar.pop_front() {
                                break Some(j);
                            }
                            if q.closed {
                                break None;
                            }
                            q = wait_recover(cv, q);
                        }
                    };
                    match job {
                        Some(j) => {
                            j();
                            let (lock, cv) = &*inner2;
                            let mut q = lock_recover(lock);
                            q.done += 1;
                            cv.notify_all();
                        }
                        None => return,
                    }
                }
            })
            // audited: the OS refusing a thread at pool construction is
            // unrecoverable for the trainer, so a panic here is deliberate
            // flowmoe-lint: allow(unwrap)
            .expect("spawn commpool");
        CommPool {
            inner,
            handle: Some(handle),
        }
    }

    /// Enqueue a high-priority A2A job.
    pub fn submit_a2a(&self, job: Job) {
        let (lock, cv) = &*self.inner;
        let mut q = lock_recover(lock);
        q.a2a.push_back(job);
        q.submitted += 1;
        cv.notify_all();
    }

    /// Enqueue a low-priority all-reduce chunk job.
    pub fn submit_ar(&self, job: Job) {
        let (lock, cv) = &*self.inner;
        let mut q = lock_recover(lock);
        q.ar.push_back(job);
        q.submitted += 1;
        cv.notify_all();
    }

    /// Block until every submitted job has run.
    pub fn drain(&self) {
        let (lock, cv) = &*self.inner;
        let mut q = lock_recover(lock);
        while q.done < q.submitted {
            q = wait_recover(cv, q);
        }
    }
}

impl Default for CommPool {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for CommPool {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.inner;
            let mut q = lock_recover(lock);
            q.closed = true;
            cv.notify_all();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Split `len` elements into chunks of at most `chunk_elems` — the paper's
/// PARTITION procedure over a flat gradient tensor. Returns (start, len)
/// ranges covering [0, len) exactly.
pub fn partition_ranges(len: usize, chunk_elems: usize) -> Vec<(usize, usize)> {
    assert!(chunk_elems > 0);
    let mut out = Vec::new();
    let mut s = 0;
    while s < len {
        let l = chunk_elems.min(len - s);
        out.push((s, l));
        s += l;
    }
    out
}

// ---------------------------------------------------------------------------
// Real in-process collectives
// ---------------------------------------------------------------------------

struct AllReduceSlot {
    buf: Vec<f32>,
    arrived: usize,
    copied: usize,
}

struct CollectiveState {
    reduce: HashMap<u64, AllReduceSlot>,
    mail: HashMap<(usize, usize, u64), Vec<f32>>,
    barrier_gen: u64,
    barrier_arrived: usize,
}

/// In-process collective context shared by the P workers.
pub struct Collective {
    p: usize,
    state: Mutex<CollectiveState>,
    cv: Condvar,
}

impl Collective {
    pub fn new(p: usize) -> Arc<Collective> {
        Arc::new(Collective {
            p,
            state: Mutex::new(CollectiveState {
                reduce: HashMap::new(),
                mail: HashMap::new(),
                barrier_gen: 0,
                barrier_arrived: 0,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn world(&self) -> usize {
        self.p
    }

    /// Flat all-reduce (sum) of `data` across all P workers under `tag`.
    /// Every worker must call with the same tag and equal lengths; tags
    /// must be globally ordered consistently (see module docs).
    pub fn all_reduce_sum(&self, tag: u64, data: &mut [f32]) {
        let mut st = lock_recover(&self.state);
        {
            let slot = st.reduce.entry(tag).or_insert_with(|| AllReduceSlot {
                buf: vec![0.0; data.len()],
                arrived: 0,
                copied: 0,
            });
            assert_eq!(slot.buf.len(), data.len(), "all_reduce length mismatch (tag {tag})");
            for (b, d) in slot.buf.iter_mut().zip(data.iter()) {
                *b += *d;
            }
            slot.arrived += 1;
        }
        if st.reduce[&tag].arrived == self.p {
            self.cv.notify_all();
        } else {
            while st.reduce.get(&tag).map(|s| s.arrived) != Some(self.p) {
                st = wait_recover(&self.cv, st);
            }
        }
        // copy out; last reader removes the slot
        let remove = {
            let Some(slot) = st.reduce.get_mut(&tag) else {
                return; // unreachable: the slot exists until the last copy below
            };
            data.copy_from_slice(&slot.buf);
            slot.copied += 1;
            slot.copied == self.p
        };
        if remove {
            st.reduce.remove(&tag);
            self.cv.notify_all();
        }
    }

    /// Deposit a message for `to` (non-blocking).
    pub fn send(&self, from: usize, to: usize, tag: u64, data: Vec<f32>) {
        let mut st = lock_recover(&self.state);
        let prev = st.mail.insert((from, to, tag), data);
        assert!(prev.is_none(), "duplicate send ({from}->{to}, tag {tag})");
        self.cv.notify_all();
    }

    /// Blocking receive from `from`.
    pub fn recv(&self, from: usize, to: usize, tag: u64) -> Vec<f32> {
        let mut st = lock_recover(&self.state);
        loop {
            if let Some(v) = st.mail.remove(&(from, to, tag)) {
                return v;
            }
            st = wait_recover(&self.cv, st);
        }
    }

    /// Generation barrier across all workers.
    pub fn barrier(&self) {
        let mut st = lock_recover(&self.state);
        let gen = st.barrier_gen;
        st.barrier_arrived += 1;
        if st.barrier_arrived == self.p {
            st.barrier_arrived = 0;
            st.barrier_gen += 1;
            self.cv.notify_all();
        } else {
            while st.barrier_gen == gen {
                st = wait_recover(&self.cv, st);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_covers_exactly() {
        let r = partition_ranges(10, 3);
        assert_eq!(r, vec![(0, 3), (3, 3), (6, 3), (9, 1)]);
        let total: usize = r.iter().map(|(_, l)| l).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn partition_single_chunk() {
        assert_eq!(partition_ranges(5, 100), vec![(0, 5)]);
    }

    #[test]
    fn partition_empty() {
        assert!(partition_ranges(0, 4).is_empty());
    }

    #[test]
    fn pool_runs_jobs_and_drains() {
        let pool = CommPool::new();
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let n2 = Arc::clone(&n);
            pool.submit_a2a(Box::new(move || {
                n2.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.drain();
        assert_eq!(n.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn pool_prioritizes_a2a_over_ar() {
        // Submit a blocker first so both queues fill before any pick.
        let pool = CommPool::new();
        let order = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));

        let g2 = Arc::clone(&gate);
        pool.submit_ar(Box::new(move || {
            let (l, c) = &*g2;
            let mut open = l.lock().unwrap();
            while !*open {
                open = c.wait(open).unwrap();
            }
        }));
        let o1 = Arc::clone(&order);
        pool.submit_ar(Box::new(move || o1.lock().unwrap().push("ar")));
        let o2 = Arc::clone(&order);
        pool.submit_a2a(Box::new(move || o2.lock().unwrap().push("a2a")));

        // open the gate: pool should then pick a2a before the queued ar
        {
            let (l, c) = &*gate;
            *l.lock().unwrap() = true;
            c.notify_all();
        }
        pool.drain();
        assert_eq!(*order.lock().unwrap(), vec!["a2a", "ar"]);
    }

    #[test]
    fn all_reduce_sums_across_workers() {
        let p = 4;
        let coll = Collective::new(p);
        let mut handles = Vec::new();
        for w in 0..p {
            let c = Arc::clone(&coll);
            handles.push(std::thread::spawn(move || {
                let mut v = vec![w as f32 + 1.0; 8];
                c.all_reduce_sum(1, &mut v);
                v
            }));
        }
        for h in handles {
            let v = h.join().unwrap();
            assert!(v.iter().all(|&x| x == 10.0)); // 1+2+3+4
        }
    }

    #[test]
    fn all_reduce_multiple_tags_in_order() {
        let p = 2;
        let coll = Collective::new(p);
        let mut handles = Vec::new();
        for w in 0..p {
            let c = Arc::clone(&coll);
            handles.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for tag in 0..20u64 {
                    let mut v = vec![(w + 1) as f32 * (tag + 1) as f32; 4];
                    c.all_reduce_sum(tag, &mut v);
                    out.push(v[0]);
                }
                out
            }));
        }
        for h in handles {
            let out = h.join().unwrap();
            for (tag, v) in out.iter().enumerate() {
                assert_eq!(*v, 3.0 * (tag + 1) as f32);
            }
        }
    }

    #[test]
    fn send_recv_roundtrip() {
        let coll = Collective::new(2);
        let c1 = Arc::clone(&coll);
        let t = std::thread::spawn(move || c1.recv(0, 1, 7));
        coll.send(0, 1, 7, vec![1.0, 2.0, 3.0]);
        assert_eq!(t.join().unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn barrier_synchronizes() {
        let p = 3;
        let coll = Collective::new(p);
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..p {
            let c = Arc::clone(&coll);
            let n = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                n.fetch_add(1, Ordering::SeqCst);
                c.barrier();
                // after the barrier every increment must be visible
                assert_eq!(n.load(Ordering::SeqCst), 3);
                c.barrier();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
