//! `flowmoe-lint` — dependency-free repo lint (see `flowmoe::analyze::lint`
//! for the rule catalog). Exits non-zero on any finding; CI runs it next
//! to `cargo clippy`.

use std::path::Path;
use std::process::ExitCode;

use flowmoe::analyze::lint::lint_repo;

fn main() -> ExitCode {
    // run from the crate dir (`rust/`) or the repo root
    let root = if Path::new("src/lib.rs").is_file() {
        Path::new(".")
    } else {
        Path::new("rust")
    };
    match lint_repo(root) {
        Ok(findings) if findings.is_empty() => {
            println!("flowmoe-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("flowmoe-lint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("flowmoe-lint: {e:#}");
            ExitCode::FAILURE
        }
    }
}
