//! Minimal config-file parsing (no serde offline): `key = value` lines
//! with `[section]` headers, `#` comments. Lets deployments define custom
//! models and cluster profiles without recompiling:
//!
//! ```text
//! [model]
//! name = my-moe
//! L = 8
//! B = 4
//! N = 512
//! M = 1024
//! H = 4096
//! E = 16
//! k = 2
//! f = 1.1
//! n_heads = 16
//! vocab = 32000
//!
//! [cluster]
//! base = cluster1       # cluster1 | cluster2
//! gpus = 16
//! inter_bw_gbps = 100
//! ar_bw_gbps = 9.6
//! ```

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::config::{ClusterProfile, ModelCfg};

/// Parsed sections: section name -> (key -> value).
pub type Sections = HashMap<String, HashMap<String, String>>;

/// Parse the `key = value` / `[section]` format.
pub fn parse_sections(text: &str) -> Result<Sections> {
    let mut out: Sections = HashMap::new();
    let mut cur = "".to_string();
    out.entry(cur.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: bad section header {raw}", lineno + 1);
            }
            cur = line[1..line.len() - 1].trim().to_string();
            out.entry(cur.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            out.entry(cur.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        } else {
            bail!("line {}: expected key = value, got {raw}", lineno + 1);
        }
    }
    Ok(out)
}

fn get<T: std::str::FromStr>(sec: &HashMap<String, String>, key: &str, default: T) -> Result<T> {
    match sec.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("bad value for {key}: {v}")),
    }
}

/// Build a [`ModelCfg`] from a `[model]` section (missing keys default to
/// a small transformer). Names are leaked (`&'static str`) — config files
/// are loaded once per process.
pub fn model_from_sections(secs: &Sections) -> Result<ModelCfg> {
    let sec = secs
        .get("model")
        .ok_or_else(|| anyhow!("missing [model] section"))?;
    let name: String = sec.get("name").cloned().unwrap_or_else(|| "custom".into());
    Ok(ModelCfg {
        name: Box::leak(name.into_boxed_str()),
        l: get(sec, "L", 4)?,
        b: get(sec, "B", 4)?,
        n: get(sec, "N", 512)?,
        m: get(sec, "M", 512)?,
        h: get(sec, "H", 1024)?,
        e: get(sec, "E", 16)?,
        k: get(sec, "k", 2)?,
        f: get(sec, "f", 1.0)?,
        n_heads: get(sec, "n_heads", 8)?,
        vocab: get(sec, "vocab", 0)?,
    })
}

/// Build a [`ClusterProfile`] from a `[cluster]` section layered on a
/// base profile.
pub fn cluster_from_sections(secs: &Sections) -> Result<ClusterProfile> {
    let sec = secs
        .get("cluster")
        .ok_or_else(|| anyhow!("missing [cluster] section"))?;
    let gpus: usize = get(sec, "gpus", 16)?;
    let mut cl = match sec.get("base").map(|s| s.as_str()).unwrap_or("cluster1") {
        "cluster1" => ClusterProfile::cluster1(gpus),
        "cluster2" => ClusterProfile::cluster2(gpus),
        other => bail!("unknown base cluster {other}"),
    };
    if let Some(v) = sec.get("inter_bw_gbps") {
        cl.net.inter_bw = v.parse::<f64>().map_err(|_| anyhow!("bad inter_bw_gbps"))? * 1e9 / 8.0;
    }
    if let Some(v) = sec.get("ar_bw_gbps") {
        cl.net.ar_bw = v.parse::<f64>().map_err(|_| anyhow!("bad ar_bw_gbps"))? * 1e9 / 8.0;
    }
    if let Some(v) = sec.get("mem_gb") {
        cl.mem_bytes = v.parse::<f64>().map_err(|_| anyhow!("bad mem_gb"))? * 1e9;
    }
    Ok(cl)
}

/// Load (model, cluster) from a config file path.
pub fn load_config(path: &str) -> Result<(ModelCfg, ClusterProfile)> {
    let text = std::fs::read_to_string(path)?;
    let secs = parse_sections(&text)?;
    Ok((model_from_sections(&secs)?, cluster_from_sections(&secs)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
# a comment
[model]
name = my-moe
L = 8
M = 1024
E = 32
k = 2

[cluster]
base = cluster1
gpus = 8
inter_bw_gbps = 100
";

    #[test]
    fn parses_sections_and_comments() {
        let s = parse_sections(SAMPLE).unwrap();
        assert_eq!(s["model"]["L"], "8");
        assert_eq!(s["cluster"]["gpus"], "8");
    }

    #[test]
    fn model_defaults_and_overrides() {
        let s = parse_sections(SAMPLE).unwrap();
        let m = model_from_sections(&s).unwrap();
        assert_eq!(m.name, "my-moe");
        assert_eq!(m.l, 8);
        assert_eq!(m.m, 1024);
        assert_eq!(m.b, 4); // default
    }

    #[test]
    fn cluster_base_and_bandwidth() {
        let s = parse_sections(SAMPLE).unwrap();
        let c = cluster_from_sections(&s).unwrap();
        assert_eq!(c.p, 8);
        assert!((c.net.inter_bw - 12.5e9).abs() < 1e6);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_sections("[model\nL = 2").is_err());
        assert!(parse_sections("just words").is_err());
    }

    #[test]
    fn missing_section_errors() {
        let s = parse_sections("[model]\nL = 2").unwrap();
        assert!(cluster_from_sections(&s).is_err());
    }

    #[test]
    fn parsed_config_simulates() {
        let s = parse_sections(SAMPLE).unwrap();
        let m = model_from_sections(&s).unwrap();
        let c = cluster_from_sections(&s).unwrap();
        let (t, _) = crate::sched::iteration_time(&m, &c, &crate::sched::Policy::flow_moe(2, 2.5e6));
        assert!(t > 0.0);
    }
}
