//! Hardware profiles for the two testbeds of the paper (Sec. 5.1) and the
//! heterogeneous variant of Appendix K.
//!
//! The paper measured on real GPUs; we replace the testbed with calibrated
//! analytic profiles (DESIGN.md §1). Constants are calibrated so that
//! vanilla expert parallelism reproduces the *ratios* of the paper's
//! Table 1 (MHA+gating + all-reduce ≈ 30–40 % of iteration time); all
//! schedulers are then compared on identical task costs, which is the
//! variable the paper isolates.

/// Compute-side profile of one accelerator.
#[derive(Clone, Debug)]
pub struct GpuProfile {
    pub name: &'static str,
    /// Peak fp32 throughput in FLOP/s.
    pub peak_flops: f64,
    /// Max achievable model-flops-utilization on large matmuls.
    pub mfu_max: f64,
    /// Matmul dim at which MFU reaches half of `mfu_max` (small-kernel
    /// inefficiency: tiny M ⇒ tiny effective throughput).
    pub mfu_half_dim: f64,
    /// Fixed per-task launch/framework overhead (s).
    pub comp_alpha: f64,
    /// Relative compute speed multiplier (heterogeneous clusters scale
    /// this; 1.0 = nominal).
    pub speed: f64,
}

impl GpuProfile {
    pub const RTX3090: GpuProfile = GpuProfile {
        name: "RTX3090",
        peak_flops: 35.6e12,
        mfu_max: 0.30,
        mfu_half_dim: 128.0,
        comp_alpha: 400e-6,
        speed: 1.0,
    };

    pub const RTX2080TI: GpuProfile = GpuProfile {
        name: "RTX2080Ti",
        peak_flops: 13.4e12,
        mfu_max: 0.28,
        mfu_half_dim: 128.0,
        comp_alpha: 400e-6,
        speed: 1.0,
    };

    /// Effective throughput (FLOP/s) for a matmul-dominated task whose
    /// characteristic inner dimension is `dim`.
    pub fn effective_flops(&self, dim: f64) -> f64 {
        let mfu = self.mfu_max * dim / (dim + self.mfu_half_dim);
        self.peak_flops * mfu * self.speed
    }

    /// Time (s) for `flops` of work at characteristic dim `dim`.
    pub fn compute_time(&self, flops: f64, dim: f64) -> f64 {
        self.comp_alpha + flops / self.effective_flops(dim)
    }

    /// A slowed copy (heterogeneous clusters / simulated degradation).
    pub fn slowed(&self, factor: f64) -> GpuProfile {
        let mut g = self.clone();
        g.speed = factor;
        g
    }
}

/// Network-side profile of the cluster fabric.
#[derive(Clone, Debug)]
pub struct NetProfile {
    /// Inter-node link bandwidth per node, bytes/s.
    pub inter_bw: f64,
    /// Intra-node (PCIe) bandwidth per GPU pair, bytes/s.
    pub intra_bw: f64,
    /// GPUs per node (share the node's NIC).
    pub ranks_per_node: usize,
    /// Per-message startup latency (s) — NCCL launch + protocol.
    pub alpha: f64,
    /// Algorithm/protocol efficiency of collectives (<= 1).
    pub algo_eff: f64,
    /// Effective end-to-end all-reduce bandwidth (bytes/s): the ring's
    /// inter-node edges share the NIC, so this is well below `inter_bw`.
    /// Calibrated so centralized AR reproduces the paper's Table 1
    /// all-reduce column (BERT ~98 ms, DeepSeek ~1.25 s on Cluster 1).
    pub ar_bw: f64,
    /// Per-all-reduce-launch startup (s). Calibrated to the paper's Fig. 4
    /// (the +100 ms penalty of S_p = 0.5 MB vs 2.5 MB on BERT-Large-MoE
    /// implies ~0.5 ms per extra chunk launch).
    pub ar_alpha: f64,
}

impl NetProfile {
    /// Effective point-to-point bandwidth seen by one rank when all ranks
    /// of a node drive the NIC simultaneously (collectives do).
    pub fn rank_bw(&self) -> f64 {
        (self.inter_bw / self.ranks_per_node as f64) * self.algo_eff
    }
}

/// Per-GPU power states for the energy model (dynamic power above idle;
/// the paper's nvidia-smi numbers are per-iteration averages — see
/// metrics::energy for calibration notes).
#[derive(Clone, Debug)]
pub struct PowerProfile {
    pub idle_w: f64,
    pub compute_w: f64,
    pub comm_w: f64,
    /// Both streams busy (overlap) — less than compute+comm (shared rails).
    pub both_w: f64,
}

impl PowerProfile {
    pub const RTX3090: PowerProfile = PowerProfile {
        idle_w: 25.0,
        compute_w: 280.0,
        comm_w: 95.0,
        both_w: 320.0,
    };
    pub const RTX2080TI: PowerProfile = PowerProfile {
        idle_w: 18.0,
        compute_w: 180.0,
        comm_w: 70.0,
        both_w: 210.0,
    };
}

/// A full cluster: P workers, compute + network + power profiles.
#[derive(Clone, Debug)]
pub struct ClusterProfile {
    pub name: &'static str,
    pub p: usize,
    pub gpu: GpuProfile,
    /// Per-worker overrides for heterogeneous clusters (empty = uniform).
    pub gpu_overrides: Vec<(usize, GpuProfile)>,
    pub net: NetProfile,
    pub power: PowerProfile,
    /// GPU memory per worker (bytes) — OOM detection for the sweeps.
    pub mem_bytes: f64,
}

impl ClusterProfile {
    /// Paper Cluster 1: 2 nodes x 8 RTX3090, 100 Gb/s inter-node, PCIe3.
    pub fn cluster1(p: usize) -> ClusterProfile {
        ClusterProfile {
            name: "Cluster1",
            p,
            gpu: GpuProfile::RTX3090,
            gpu_overrides: vec![],
            net: NetProfile {
                inter_bw: 12.5e9,
                intra_bw: 12.0e9,
                ranks_per_node: 8.min(p),
                alpha: 35e-6,
                algo_eff: 0.70,
                ar_bw: 1.2e9,
                ar_alpha: 0.5e-3,
            },
            power: PowerProfile::RTX3090,
            // 24 GB card; ~21.5 GB usable after CUDA context, cudnn
            // workspaces and allocator fragmentation.
            mem_bytes: 21.5e9,
        }
    }

    /// Paper Cluster 2: 4 nodes x 2 RTX2080Ti, 10 Gb/s inter-node.
    pub fn cluster2(p: usize) -> ClusterProfile {
        ClusterProfile {
            name: "Cluster2",
            p,
            gpu: GpuProfile::RTX2080TI,
            gpu_overrides: vec![],
            net: NetProfile {
                inter_bw: 1.25e9,
                intra_bw: 8.0e9,
                ranks_per_node: 2.min(p),
                alpha: 40e-6,
                algo_eff: 0.65,
                ar_bw: 0.3e9,
                ar_alpha: 0.6e-3,
            },
            power: PowerProfile::RTX2080TI,
            // 12 GB card (the 2080 Ti in the paper's Cluster 2); ~10.5 GB
            // usable.
            mem_bytes: 10.5e9,
        }
    }

    /// Appendix K heterogeneous variant: half the workers at half speed.
    pub fn cluster1_heterogeneous(p: usize) -> ClusterProfile {
        let mut c = Self::cluster1(p);
        c.name = "Cluster1-hetero";
        c.gpu_overrides = (0..p / 2).map(|w| (w, GpuProfile::RTX3090.slowed(0.5))).collect();
        c
    }

    /// The slowest GPU dictates the collective-task timeline (Appendix K.1):
    /// collectives can only start once the slowest worker's compute is done.
    pub fn slowest_gpu(&self) -> GpuProfile {
        let mut slow = self.gpu.clone();
        for (_, g) in &self.gpu_overrides {
            if g.speed < slow.speed {
                slow = g.clone();
            }
        }
        slow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_flops_monotone_in_dim() {
        let g = GpuProfile::RTX3090;
        assert!(g.effective_flops(256.0) < g.effective_flops(1024.0));
        assert!(g.effective_flops(1024.0) < g.effective_flops(8192.0));
    }

    #[test]
    fn effective_flops_below_peak() {
        let g = GpuProfile::RTX3090;
        assert!(g.effective_flops(1e9) < g.peak_flops);
    }

    #[test]
    fn compute_time_has_floor() {
        let g = GpuProfile::RTX3090;
        assert!(g.compute_time(0.0, 512.0) >= g.comp_alpha);
    }

    #[test]
    fn slowed_profile_is_slower() {
        let g = GpuProfile::RTX3090;
        let s = g.slowed(0.5);
        assert!(s.compute_time(1e9, 512.0) > g.compute_time(1e9, 512.0));
    }

    #[test]
    fn cluster_profiles() {
        let c1 = ClusterProfile::cluster1(16);
        let c2 = ClusterProfile::cluster2(8);
        assert!(c1.net.rank_bw() > c2.net.rank_bw());
        assert_eq!(c1.slowest_gpu().speed, 1.0);
        let h = ClusterProfile::cluster1_heterogeneous(16);
        assert_eq!(h.slowest_gpu().speed, 0.5);
    }

    #[test]
    fn rank_bw_shares_nic() {
        let c1 = ClusterProfile::cluster1(16);
        assert!(c1.net.rank_bw() < c1.net.inter_bw);
    }
}
