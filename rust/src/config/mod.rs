//! Model and cluster configuration (paper Table 2 + testbed profiles).

pub mod cluster;
pub mod model;
pub mod parse;

pub use cluster::{ClusterProfile, GpuProfile, NetProfile, PowerProfile};
pub use model::ModelCfg;

/// Paper Table 2 presets plus the AOT configs (`tiny`, `e2e`).
pub fn preset(name: &str) -> Option<ModelCfg> {
    model::PRESETS.iter().find(|c| c.name == name).cloned()
}

/// All Table 2 benchmark models used across the paper's tables.
pub fn table2_models() -> Vec<ModelCfg> {
    ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE", "DeepSeek-V2-S"]
        .iter()
        .filter_map(|&n| preset(n))
        .collect()
}
