//! MoE model configuration — mirrors `python/compile/configs.py` and the
//! paper's Table 2 notation (L, B, N, M, H, E, k, f).

/// A transformer-with-MoE-layers configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub name: &'static str,
    /// Number of transformer blocks.
    pub l: usize,
    /// Mini-batch size per worker.
    pub b: usize,
    /// Tokens per sample.
    pub n: usize,
    /// Embedding size.
    pub m: usize,
    /// Expert hidden size.
    pub h: usize,
    /// Total experts per MoE layer (cluster-wide).
    pub e: usize,
    /// Top-k experts per token.
    pub k: usize,
    /// Capacity factor.
    pub f: f64,
    /// Attention heads.
    pub n_heads: usize,
    /// Vocabulary (0 = no LM head).
    pub vocab: usize,
}

impl ModelCfg {
    /// Tokens per worker per iteration.
    pub fn tokens(&self) -> usize {
        self.b * self.n
    }

    /// Per-expert capacity C = f * k * B * N / E (>= 1).
    pub fn capacity(&self) -> usize {
        ((self.f * (self.k * self.b * self.n) as f64 / self.e as f64) as usize).max(1)
    }

    /// Replicated (data-parallel) parameter count per block: 4M^2 + M*E
    /// (+ 2M norm gains).
    pub fn mha_gating_params(&self) -> usize {
        4 * self.m * self.m + self.m * self.e + 2 * self.m
    }

    /// Expert parameters per block across the cluster: E * 2 * M * H.
    pub fn expert_params(&self) -> usize {
        self.e * 2 * self.m * self.h
    }

    /// Bytes of the per-block all-reduce tensor (f32 grads of the
    /// replicated part) — what Algorithm 2 partitions into S_p chunks.
    pub fn ar_bytes_per_block(&self) -> f64 {
        self.mha_gating_params() as f64 * 4.0
    }

    /// Total parameters (replicated + experts + embedding).
    pub fn total_params(&self) -> usize {
        self.l * (self.mha_gating_params() + self.expert_params())
            + self.vocab * self.m
            + self.m
    }

    /// FLOPs of the AT task (MHA + gating) forward, per worker:
    /// 4 projections (2*T*M^2 each) + attention scores/apply (2*2*B*N^2*M)
    /// + gate (2*T*M*E). (Appendix E's complexity expression, made exact.)
    pub fn at_fwd_flops(&self) -> f64 {
        let t = self.tokens() as f64;
        let (m, e) = (self.m as f64, self.e as f64);
        let attn = 4.0 * (self.b * self.n * self.n) as f64 * m;
        8.0 * t * m * m + attn + 2.0 * t * m * e
    }

    /// FLOPs of expert computing forward per worker: tokens are padded to
    /// E_local * C * P routed tokens; each routed token costs 2*2*M*H.
    /// With E = P experts spread over P workers, per-worker expert compute
    /// covers k*T tokens on average (capacity-padded by f).
    pub fn expert_fwd_flops(&self) -> f64 {
        let routed = (self.e * self.capacity()) as f64; // per worker's share after A2A, E_local*C*P = E*C
        4.0 * routed * (self.m * self.h) as f64 / 1.0
    }

    /// Bytes each worker sends in one dispatch (or combine) A2A, assuming
    /// uniform routing: E*C*M*4 of dispatched activations, of which
    /// (P-1)/P crosses worker boundaries.
    pub fn a2a_bytes(&self) -> f64 {
        (self.e * self.capacity() * self.m) as f64 * 4.0
    }
}

macro_rules! cfg {
    ($name:literal, $l:expr, $b:expr, $n:expr, $m:expr, $h:expr, $e:expr, $k:expr, $f:expr, $nh:expr, $v:expr) => {
        ModelCfg {
            name: $name,
            l: $l,
            b: $b,
            n: $n,
            m: $m,
            h: $h,
            e: $e,
            k: $k,
            f: $f,
            n_heads: $nh,
            vocab: $v,
        }
    };
}

/// Table 2 of the paper + AOT configs. E is the cluster-wide expert count
/// at the 16-GPU setting (E/P column of Table 2 × 16) for the four main
/// models; benches that sweep cluster sizes override `e` via
/// [`ModelCfg::with_experts`].
pub const PRESETS: &[ModelCfg] = &[
    cfg!("GPT2-Tiny-MoE", 12, 4, 256, 256, 512, 16, 2, 1.0, 4, 50257),
    cfg!("BERT-Large-MoE", 24, 4, 512, 512, 1024, 32, 1, 1.0, 8, 30522),
    cfg!("LLaMA2-MoE", 32, 4, 512, 1024, 4096, 16, 1, 1.0, 16, 32000),
    cfg!("LLaMA2-MoE-L", 64, 4, 512, 1024, 4096, 16, 1, 1.0, 16, 32000),
    cfg!("DeepSeek-V2-S", 4, 4, 256, 5120, 1536, 32, 8, 1.0, 16, 32000),
    cfg!("DeepSeek-V2-M", 7, 4, 256, 5120, 1536, 32, 1, 1.0, 16, 32000),
    cfg!("tiny", 2, 2, 16, 32, 64, 4, 2, 4.0, 4, 128),
    cfg!("e2e", 6, 4, 128, 512, 2048, 8, 1, 1.0, 8, 4096),
];

impl ModelCfg {
    /// Same model with the cluster-wide expert count scaled to `p` workers
    /// (the paper sets experts-per-GPU constant as the cluster grows).
    pub fn with_experts_for_workers(&self, experts_per_worker: usize, p: usize) -> ModelCfg {
        let mut c = self.clone();
        c.e = experts_per_worker * p;
        c
    }

    /// Experts per worker at the paper's 16-GPU main setting.
    pub fn experts_per_worker_16(&self) -> usize {
        (self.e / 16).max(1)
    }

    /// A customized MoE layer (single transformer block), as used by the
    /// paper's 675-config sweep (Sec. 5.1: E = P, k = 2).
    pub fn custom_layer(b: usize, f: f64, n: usize, m: usize, h: usize, p: usize) -> ModelCfg {
        ModelCfg {
            name: "custom",
            l: 1,
            b,
            n,
            m,
            h,
            e: p,
            k: 2,
            f,
            n_heads: 8,
            vocab: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn capacity_formula() {
        let c = preset("GPT2-Tiny-MoE").unwrap();
        // f*k*B*N/E = 1.0*2*4*256/16 = 128
        assert_eq!(c.capacity(), 128);
    }

    #[test]
    fn param_counts_match_paper_order_of_magnitude() {
        // Paper Table 2: BERT-Large-MoE ~25.2M MHA+gating, ~806.5M experts
        // (we include small norm-gain terms the paper omits).
        let c = preset("BERT-Large-MoE").unwrap();
        let mha = (c.l * c.mha_gating_params()) as f64;
        let exp = (c.l * c.expert_params()) as f64;
        assert!((mha / 25.2e6 - 1.0).abs() < 0.1, "mha={mha}");
        assert!((exp / 806.5e6 - 1.0).abs() < 0.1, "exp={exp}");
    }

    #[test]
    fn e2e_config_is_about_100m_params() {
        let c = preset("e2e").unwrap();
        let p = c.total_params() as f64;
        assert!(p > 80e6 && p < 130e6, "params={p}");
    }

    #[test]
    fn ar_bytes_positive_and_scales_with_m() {
        let a = preset("GPT2-Tiny-MoE").unwrap();
        let b = preset("BERT-Large-MoE").unwrap();
        assert!(b.ar_bytes_per_block() > a.ar_bytes_per_block());
    }

    #[test]
    fn custom_layer_sets_e_to_p() {
        let c = ModelCfg::custom_layer(4, 1.2, 512, 1024, 1024, 16);
        assert_eq!(c.e, 16);
        assert_eq!(c.k, 2);
    }

    #[test]
    fn flops_monotone_in_model_size() {
        let a = preset("GPT2-Tiny-MoE").unwrap();
        let b = preset("LLaMA2-MoE").unwrap();
        assert!(b.at_fwd_flops() > a.at_fwd_flops());
        assert!(b.expert_fwd_flops() > a.expert_fwd_flops());
    }
}
