//! Calibrated cost models: per-task durations from model + cluster config.
//!
//! Replaces the paper's measured GPU timings (DESIGN.md §1). Compute tasks
//! follow a FLOPs / effective-throughput model with per-task launch
//! overhead; A2A uses an α–β pairwise-exchange model over the shared NIC;
//! all-reduce uses the standard ring formula `2(P-1)/P · S/BW` with
//! per-chunk startup — the startup-vs-overlap trade-off that makes the
//! paper's S_p tuning non-trivial (Theorem 2 breaks exactly when α > 0).

use crate::config::{ClusterProfile, ModelCfg};

/// Ratio of backward to forward compute cost (two matmul passes).
pub const BWD_COMPUTE_FACTOR: f64 = 2.0;

/// All per-task costs of one iteration, in seconds. Same-type subtasks
/// share one duration (paper Sec. 3.2: "tasks with the same type have the
/// same execution time").
#[derive(Clone, Debug)]
pub struct TaskCosts {
    /// AT (MHA+gating) forward, full layer (divide by R for subtasks).
    pub at_fwd: f64,
    pub at_bwd: f64,
    /// Expert computing forward, full layer per worker.
    pub exp_fwd: f64,
    pub exp_bwd: f64,
    /// One dispatch (== combine) A2A for the full layer's tokens.
    pub a2a: f64,
    /// Per-message A2A startup (added per subtask when pipelined).
    pub a2a_alpha: f64,
    /// Ring all-reduce time for `s` bytes, excluding startup.
    pub ar_beta_per_byte: f64,
    /// Per-chunk all-reduce startup.
    pub ar_alpha: f64,
    /// Bytes of the per-block replicated-gradient all-reduce tensor.
    pub ar_bytes: f64,
    /// Bytes of one full-layer A2A.
    pub a2a_bytes: f64,
    /// Head/embedding/loss compute at the turnaround.
    pub head: f64,
}

impl TaskCosts {
    /// Build costs for `cfg` on `cluster`. Collective-task timing follows
    /// the slowest GPU (Appendix K.1).
    pub fn build(cfg: &ModelCfg, cluster: &ClusterProfile) -> TaskCosts {
        let gpu = cluster.slowest_gpu();
        let p = cluster.p as f64;

        let at_fwd = gpu.compute_time(cfg.at_fwd_flops(), cfg.m as f64);
        // Expert computing launches 2 GEMMs per local expert (the paper's
        // frameworks issue one kernel per expert) — per-expert launch
        // overhead matters at small scales.
        let e_local = (cfg.e as f64 / p).max(1.0);
        let exp_flops_time = gpu.compute_time(cfg.expert_fwd_flops(), cfg.m.min(cfg.h) as f64);
        let exp_fwd = exp_flops_time + gpu.comp_alpha * (e_local - 1.0).max(0.0);

        // A2A: each worker exchanges (P-1)/P of the dispatched tensor;
        // intra-node (PCIe P2P) and inter-node (shared NIC) portions move
        // on parallel channels, so the op takes the max of the two.
        let a2a_bytes = cfg.a2a_bytes();
        let rpn = cluster.net.ranks_per_node.min(cluster.p) as f64;
        let peers = (p - 1.0).max(1.0);
        let intra_frac = (rpn - 1.0) / peers;
        let inter_frac = (p - rpn).max(0.0) / peers;
        let cross = a2a_bytes * (p - 1.0) / p;
        let t_intra = cross * intra_frac / cluster.net.intra_bw;
        let t_inter = cross * inter_frac / (cluster.net.inter_bw / rpn * cluster.net.algo_eff);
        let a2a = cluster.net.alpha + t_intra.max(t_inter);

        // All-reduce: effective end-to-end ring bandwidth (the 2(P-1)/P
        // factor and shared-NIC edges are folded into the calibrated
        // `ar_bw`) + a per-launch startup.
        let _ = p;
        let ar_beta_per_byte = 1.0 / cluster.net.ar_bw;
        let ar_alpha = cluster.net.ar_alpha;

        // Head: embedding + LM head + loss — small vs the blocks; model as
        // one AT-sized compute task when a vocab exists.
        let head = if cfg.vocab > 0 { at_fwd * 0.5 } else { 0.0 };

        TaskCosts {
            at_fwd,
            at_bwd: at_fwd * BWD_COMPUTE_FACTOR,
            exp_fwd,
            exp_bwd: exp_fwd * BWD_COMPUTE_FACTOR,
            a2a,
            a2a_alpha: cluster.net.alpha,
            ar_beta_per_byte,
            ar_alpha,
            ar_bytes: cfg.ar_bytes_per_block(),
            a2a_bytes,
            head,
        }
    }

    /// Duration of one A2A subtask at pipelining degree R: the payload
    /// splits across subtasks, the startup does not.
    pub fn a2a_sub(&self, r_degree: usize) -> f64 {
        let payload = self.a2a - self.a2a_alpha;
        self.a2a_alpha + payload / r_degree as f64
    }

    /// Duration of one all-reduce chunk of `bytes`.
    pub fn ar_chunk(&self, bytes: f64) -> f64 {
        self.ar_alpha + bytes * self.ar_beta_per_byte
    }

    /// Total all-reduce time for one block when split into chunks of
    /// `sp_bytes` (the centralized baseline uses one chunk = the tensor).
    pub fn ar_total(&self, sp_bytes: f64) -> f64 {
        let chunks = (self.ar_bytes / sp_bytes).ceil().max(1.0);
        chunks * self.ar_alpha + self.ar_bytes * self.ar_beta_per_byte
    }

    /// Number of chunks a block's AR tensor splits into at size `sp_bytes`.
    pub fn ar_chunks(&self, sp_bytes: f64) -> usize {
        ((self.ar_bytes / sp_bytes).ceil() as usize).max(1)
    }
}

/// Peak-memory estimate per worker (bytes) under a given scheduler's
/// gradient-caching behaviour — used for OOM filtering (Fig. 6 sweep,
/// Table A.7) and the Table 6 memory comparison.
pub fn peak_memory_bytes(
    cfg: &ModelCfg,
    p: usize,
    grad_cache_blocks: f64,
    expert_replication: f64,
) -> f64 {
    let e_local = (cfg.e as f64 / p as f64).max(1.0) * expert_replication;
    let expert_params = e_local * 2.0 * (cfg.m * cfg.h) as f64;
    let repl_params = cfg.mha_gating_params() as f64;
    let params = cfg.l as f64 * (expert_params + repl_params) + (cfg.vocab * cfg.m) as f64;
    // fp32 params + momentum + gradients-in-flight
    let states = params * 2.0 * 4.0;
    let grads = (cfg.l as f64 * (expert_params + repl_params) * grad_cache_blocks / cfg.l as f64
        + (cfg.vocab * cfg.m) as f64)
        * 4.0;
    // activations saved for backward per block: ~6 residual-width tensors
    // (x, normed, q/k/v, attn out), the N x N attention probabilities
    // (the dominant term for long sequences without flash attention),
    // the dispatched (E, C, M) tensor and the local experts' hidden
    // activations; 2x framework workspace factor.
    let tokens = cfg.tokens() as f64;
    let attn_probs = (cfg.b * cfg.n_heads * cfg.n * cfg.n) as f64;
    let act_block = tokens * cfg.m as f64 * 6.0
        + attn_probs
        + (cfg.e * cfg.capacity() * cfg.m) as f64
        + e_local * (p * cfg.capacity()) as f64 * cfg.h as f64;
    let acts = cfg.l as f64 * act_block * 4.0 * 2.0;
    // NCCL-style per-rank communicator workspace grows with cluster size.
    let comm_ws = p as f64 * 64.0e6;
    states + grads + acts + comm_ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn costs16(name: &str) -> TaskCosts {
        let cfg = preset(name).unwrap();
        TaskCosts::build(&cfg, &ClusterProfile::cluster1(16))
    }

    #[test]
    fn durations_positive() {
        let c = costs16("BERT-Large-MoE");
        assert!(c.at_fwd > 0.0 && c.exp_fwd > 0.0 && c.a2a > 0.0);
        assert!(c.at_bwd > c.at_fwd);
    }

    #[test]
    fn a2a_subtask_splits_payload_not_alpha() {
        let c = costs16("BERT-Large-MoE");
        let full = c.a2a_sub(1);
        let half = c.a2a_sub(2);
        assert!((full - c.a2a).abs() < 1e-12);
        assert!(half > c.a2a / 2.0);
        assert!(half < full);
    }

    #[test]
    fn ar_total_monotone_decreasing_overhead_with_bigger_chunks() {
        let c = costs16("BERT-Large-MoE");
        // more chunks => more startup => larger total wire time
        assert!(c.ar_total(0.1e6) > c.ar_total(1.0e6));
        assert!(c.ar_total(1.0e6) >= c.ar_total(8.0e6));
    }

    #[test]
    fn ar_chunks_counts() {
        let c = costs16("BERT-Large-MoE");
        assert_eq!(c.ar_chunks(c.ar_bytes), 1);
        assert_eq!(c.ar_chunks(c.ar_bytes / 4.0), 4);
    }

    #[test]
    fn table1_ratio_band() {
        // Paper Table 1: (MHA+gating + all-reduce) / iteration = 30-40 %
        // under vanilla EP on Cluster 1 with 16 GPUs. Sanity-check the raw
        // cost components imply a ratio in a plausible 20-50 % band before
        // scheduling (the exact ratio is asserted on the simulated
        // timeline in the table1 bench/integration test).
        for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE", "DeepSeek-V2-S"] {
            let cfg = preset(name).unwrap();
            let c = TaskCosts::build(&cfg, &ClusterProfile::cluster1(16));
            let l = cfg.l as f64;
            let mha_ar = l * (c.at_fwd + c.at_bwd) + l * c.ar_total(c.ar_bytes);
            let iter = l * (c.at_fwd + c.at_bwd + c.exp_fwd + c.exp_bwd + 4.0 * c.a2a)
                + l * c.ar_total(c.ar_bytes);
            let ratio = mha_ar / iter;
            assert!(
                (0.15..=0.55).contains(&ratio),
                "{name}: ratio {ratio:.3} out of band"
            );
        }
    }

    #[test]
    fn heterogeneous_cluster_slower() {
        let cfg = preset("BERT-Large-MoE").unwrap();
        let uni = TaskCosts::build(&cfg, &ClusterProfile::cluster1(16));
        let het = TaskCosts::build(&cfg, &ClusterProfile::cluster1_heterogeneous(16));
        assert!(het.at_fwd > uni.at_fwd);
    }

    #[test]
    fn peak_memory_fastermoe_replication_costs_more() {
        let cfg = preset("LLaMA2-MoE").unwrap();
        let base = peak_memory_bytes(&cfg, 16, cfg.l as f64, 1.0);
        let repl = peak_memory_bytes(&cfg, 16, cfg.l as f64, 2.0);
        assert!(repl > base * 1.1);
    }

    #[test]
    fn peak_memory_early_ar_reduces_grad_cache() {
        let cfg = preset("LLaMA2-MoE").unwrap();
        let central = peak_memory_bytes(&cfg, 16, cfg.l as f64, 1.0);
        let early = peak_memory_bytes(&cfg, 16, 2.0, 1.0);
        assert!(early < central);
    }
}
