//! The task-graph executor — one scheduling engine for both the modeled
//! and the measured pipeline.
//!
//! A [`crate::sched::Policy`] builds a multi-type task [`Dag`] (Eqs. 2–5:
//! MHA+gating, dispatch A2A, expert compute, combine A2A, priority-ranked
//! AR chunks). Historically that DAG was only ever *simulated*; the real
//! trainer hand-coded its own overlap structure behind an `overlap: bool`
//! flag, so the schedule the analyzer certified and the schedule the
//! runtime executed could silently diverge. This module closes that gap:
//! the same statically verified [`Plan`] drives
//!
//! * [`run_modeled`] — the discrete-event engine over the cost model's
//!   durations (what [`crate::sim::simulate`] now delegates to), and
//! * [`Plan::run_native`] — real execution: DAG nodes dispatched in the
//!   same ready-set/priority order to a [`TaskRunner`] that binds compute
//!   nodes to native kernels and hands AR-chunk nodes to the
//!   [`crate::commpool`] FIFO thread (Algorithm 2's asynchronous lane).
//!
//! [`Plan::new`] is the mandatory pre-flight: it runs the **full**
//! [`crate::analyze::check_dag`] rule set (S001–S007) on every DAG the
//! runtime will execute — not just the simulated ones — and refuses to
//! construct a plan from an invalid schedule. (`run_modeled` itself keeps
//! only the policy-free structural half in debug builds, because the
//! simulator's unit fixtures deliberately violate the policy rules.)
//!
//! The chunked all-reduce submission helpers ([`enqueue_tensor_ar`] /
//! [`enqueue_block_ar`]) live here too: they are the runtime realization
//! of the DAG's `Ar{l, c}` nodes, shared by every [`TaskRunner`].

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::commpool::{partition_ranges, Collective, CommError, CommPool};
use crate::obs;
use crate::sched::Policy;
use crate::sim::{Span, Timeline};
use crate::tasks::{Dag, Stream, Task, TaskId};
use crate::util::lock_recover;

/// A statically verified, executable schedule: the policy-built DAG plus
/// the policy it was built under. Construction *is* the pre-flight — a
/// `Plan` cannot exist for a DAG that fails `analyze::check_dag`.
#[derive(Clone, Debug)]
pub struct Plan {
    pub dag: Dag,
    pub policy: Policy,
}

impl Plan {
    /// Verify `(dag, policy)` under the full S001–S007 rule set and wrap
    /// it. Unlike the simulator's debug-only structural assert, this runs
    /// unconditionally (release builds included): a schedule the runtime
    /// is about to execute must be provably well-formed.
    pub fn new(dag: Dag, policy: Policy) -> Result<Plan> {
        let vs = crate::analyze::check_dag(&dag, &policy);
        if let Some(v) = vs.first() {
            bail!(
                "schedule pre-flight failed for policy {} ({} violation(s), first: {v})",
                policy.name,
                vs.len()
            );
        }
        Ok(Plan { dag, policy })
    }

    /// Execute the plan against the cost model (modeled durations).
    pub fn modeled(&self) -> Timeline {
        run_modeled(&self.dag)
    }

    /// Execute the plan for real: walk the DAG in ready-set priority
    /// order, dispatching each node to `runner`.
    pub fn run_native<R: TaskRunner + ?Sized>(&self, runner: &mut R) -> Result<()> {
        run_native(&self.dag, runner)
    }
}

/// Binds DAG nodes to real work. [`run_native`] calls `run` for compute
/// and A2A nodes (executed inline, to completion) and `submit_ar` for AR
/// chunk nodes (handed to an asynchronous communication lane — typically
/// [`CommPool`] — and considered complete on submission, matching
/// Algorithm 2's no-preemption FIFO comm thread).
pub trait TaskRunner {
    /// Execute one inline (compute / A2A) task to completion.
    fn run(&mut self, task: &Task) -> Result<()>;
    /// Hand one AR-chunk task to the asynchronous comm lane.
    fn submit_ar(&mut self, task: &Task) -> Result<()>;
}

fn complete(
    dag: &Dag,
    dependents: &[Vec<TaskId>],
    indeg: &mut [u32],
    heap: &mut BinaryHeap<Reverse<(u64, TaskId)>>,
    ar_fifo: &mut VecDeque<TaskId>,
    id: TaskId,
) {
    for &dep in &dependents[id] {
        indeg[dep] -= 1;
        if indeg[dep] == 0 {
            let t = &dag.tasks[dep];
            if t.kind.is_ar() {
                ar_fifo.push_back(t.id);
            } else {
                heap.push(Reverse((t.seq, t.id)));
            }
        }
    }
}

/// Drive the DAG through a [`TaskRunner`] on the calling thread.
///
/// Ready non-AR tasks run inline in ascending `(seq, id)` order — the
/// Eqs. 2–5 FIFO rank, which for `sched::build_dag` output equals
/// emission order. Ready AR chunks are drained to `submit_ar` *before*
/// every inline task (and submission completes the node, so a chained
/// chunk unlocked by it is picked up by the same drain): the runner's
/// comm lane owns in-flight chunks from then on, which is exactly the
/// paper's compute-proceeds-while-AR-runs overlap. The caller decides
/// when to block on the lane (e.g. `CommPool::drain` at step end).
pub fn run_native<R: TaskRunner + ?Sized>(dag: &Dag, runner: &mut R) -> Result<()> {
    #[cfg(debug_assertions)]
    {
        let vs = crate::analyze::check_dag_structure(dag);
        assert!(vs.is_empty(), "run_native() given an invalid DAG: {}", vs[0]);
    }
    let n = dag.tasks.len();
    let mut indeg: Vec<u32> = vec![0; n];
    let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for t in &dag.tasks {
        indeg[t.id] = t.deps.len() as u32;
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }
    let mut heap: BinaryHeap<Reverse<(u64, TaskId)>> = BinaryHeap::new();
    let mut ar_fifo: VecDeque<TaskId> = VecDeque::new();
    for t in &dag.tasks {
        if t.deps.is_empty() {
            if t.kind.is_ar() {
                ar_fifo.push_back(t.id);
            } else {
                heap.push(Reverse((t.seq, t.id)));
            }
        }
    }
    let mut done = 0usize;
    while done < n {
        while let Some(id) = ar_fifo.pop_front() {
            runner.submit_ar(&dag.tasks[id])?;
            done += 1;
            complete(dag, &dependents, &mut indeg, &mut heap, &mut ar_fifo, id);
        }
        if done >= n {
            break;
        }
        let Some(Reverse((_, id))) = heap.pop() else {
            bail!("executor deadlock: {done}/{n} tasks complete but none ready");
        };
        runner.run(&dag.tasks[id])?;
        done += 1;
        complete(dag, &dependents, &mut indeg, &mut heap, &mut ar_fifo, id);
    }
    Ok(())
}

/// Execute the DAG against the cost model: the discrete-event two-stream
/// engine on exactly the resource model the paper's theorems assume
/// (Sec. 3.3) — one compute stream and one communication stream, one task
/// at a time per stream, no preemption, compute and comm may overlap.
/// When a stream frees up, it picks among *ready* tasks of its stream:
/// the lowest-`seq` A2A-or-compute task; AR chunks run only when no A2A
/// task is ready (Algorithm 2's priority rule).
///
/// Panics on invalid DAGs (structurally validated in debug builds only —
/// the policy-aware rules belong to [`Plan::new`] / `flowmoe analyze`,
/// and the simulator's unit fixtures violate them on purpose).
pub fn run_modeled(dag: &Dag) -> Timeline {
    #[cfg(debug_assertions)]
    {
        // Static pre-flight (policy-free half of the analyzer): cycles,
        // duplicate/out-of-range edges, AR FIFO discipline. Policy-aware
        // rules (streams, shape, AR partition) run via `flowmoe analyze`.
        let vs = crate::analyze::check_dag_structure(dag);
        assert!(vs.is_empty(), "simulate() given an invalid DAG: {}", vs[0]);
    }
    let n = dag.tasks.len();
    let mut indeg: Vec<u32> = vec![0; n];
    let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for t in &dag.tasks {
        indeg[t.id] = t.deps.len() as u32;
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }

    // Ready structures per stream (§Perf: a flat ready-vector scan was
    // O(ready^2) and pushed the scheduler past the paper's <1 % overhead
    // bound once thousands of AR chunks were in flight):
    //  * a min-heap on (seq, id) for non-AR tasks — Eqs. 2-5 FIFO order,
    //  * a FIFO queue for AR chunks (they are created, become ready and
    //    must run in seq order), consulted only when the heap is empty —
    //    exactly Algorithm 2's A2A-before-AR rule.
    let mut heap: [BinaryHeap<Reverse<(u64, TaskId)>>; 3] = Default::default();
    let mut ar_fifo: [VecDeque<TaskId>; 3] = Default::default();
    let idx = |s: Stream| match s {
        Stream::Compute => 0usize,
        Stream::Comm => 1usize,
        Stream::ArComm => 2usize,
    };
    let push_ready = |heap: &mut [BinaryHeap<Reverse<(u64, TaskId)>>; 3],
                      ar_fifo: &mut [VecDeque<TaskId>; 3],
                      t: &Task| {
        let s = idx(t.stream);
        if t.kind.is_ar() {
            ar_fifo[s].push_back(t.id);
        } else {
            heap[s].push(Reverse((t.seq, t.id)));
        }
    };
    for t in &dag.tasks {
        if t.deps.is_empty() {
            push_ready(&mut heap, &mut ar_fifo, t);
        }
    }

    let mut free_at = [0.0f64; 3]; // per-stream next-free time
    let mut running: [Option<(TaskId, f64)>; 3] = [None, None, None]; // (task, end)
    let mut spans: Vec<Span> = Vec::with_capacity(n);
    let mut done = 0usize;
    let mut now = 0.0f64;

    while done < n {
        // start tasks on any idle stream with ready work
        for s in 0..3 {
            if running[s].is_none() {
                let id = if let Some(Reverse((_, id))) = heap[s].pop() {
                    Some(id)
                } else {
                    ar_fifo[s].pop_front()
                };
                if let Some(id) = id {
                    let start = now.max(free_at[s]);
                    let end = start + dag.tasks[id].dur;
                    running[s] = Some((id, end));
                    spans.push(Span {
                        task: id,
                        start,
                        end,
                        stream: dag.tasks[id].stream,
                    });
                }
            }
        }
        // advance to the earliest completion
        let next_end = running
            .iter()
            .flatten()
            .map(|&(_, e)| e)
            .fold(f64::INFINITY, f64::min);
        if !next_end.is_finite() {
            // no task running but not all done => DAG has a cycle or
            // unreachable tasks (validate() prevents this).
            panic!("simulator deadlock: {done}/{n} tasks done");
        }
        now = next_end;
        for s in 0..3 {
            if let Some((id, end)) = running[s] {
                if end <= now {
                    running[s] = None;
                    free_at[s] = end;
                    done += 1;
                    for &dep in &dependents[id] {
                        indeg[dep] -= 1;
                        if indeg[dep] == 0 {
                            push_ready(&mut heap, &mut ar_fifo, &dag.tasks[dep]);
                        }
                    }
                }
            }
        }
    }

    let makespan = spans.iter().map(|s| s.end).fold(0.0, f64::max);
    Timeline { spans, makespan }
}

// ---------------------------------------------------------------------------
// AR-chunk submission: the runtime realization of the DAG's Ar{l, c} nodes
// ---------------------------------------------------------------------------

/// Enqueue chunked all-reduce jobs for one tensor of the grad store.
/// Returns the number of chunks enqueued. An AR failure is parked in
/// `ar_fail` (first one wins) and later chunks of the step short-circuit.
#[allow(clippy::too_many_arguments)]
pub fn enqueue_tensor_ar(
    pool: &CommPool,
    coll: &Arc<Collective>,
    gstore: &Arc<Mutex<Vec<Vec<f32>>>>,
    rank: usize,
    ar_fail: &Arc<Mutex<Option<CommError>>>,
    tensor_idx: usize,
    layer_id: usize,
    chunk_elems: usize,
    tag: &mut impl FnMut(usize, usize, usize) -> u64,
) -> usize {
    let len = lock_recover(gstore)[tensor_idx].len();
    let ranges = partition_ranges(len, chunk_elems);
    let n = ranges.len();
    for (c, (start, l)) in ranges.into_iter().enumerate() {
        let coll = Arc::clone(coll);
        let gstore = Arc::clone(gstore);
        let ar_fail = Arc::clone(ar_fail);
        let t = tag(layer_id, tensor_idx, c);
        pool.submit_ar(Box::new(move || {
            // runs on the comm-pool thread: this span is the measured
            // communication time of one AR chunk
            let _sp = obs::span("ar_chunk");
            if lock_recover(&ar_fail).is_some() {
                return; // a chunk already failed this step; don't pay the deadline again
            }
            let mut chunk = {
                let g = lock_recover(&gstore);
                g[tensor_idx][start..start + l].to_vec()
            };
            match coll.all_reduce_sum(rank, t, &mut chunk) {
                Ok(()) => {
                    let mut g = lock_recover(&gstore);
                    g[tensor_idx][start..start + l].copy_from_slice(&chunk);
                }
                Err(e) => {
                    let mut f = lock_recover(&ar_fail);
                    if f.is_none() {
                        *f = Some(e);
                    }
                }
            }
        }));
    }
    n
}

/// Enqueue chunked AR for all tensors of one block. Returns the number
/// of chunks enqueued.
#[allow(clippy::too_many_arguments)]
pub fn enqueue_block_ar(
    pool: &CommPool,
    coll: &Arc<Collective>,
    gstore: &Arc<Mutex<Vec<Vec<f32>>>>,
    rank: usize,
    ar_fail: &Arc<Mutex<Option<CommError>>>,
    layer_id: usize,
    first_tensor: usize,
    n_tensors: usize,
    chunk_elems: usize,
    tag: &mut impl FnMut(usize, usize, usize) -> u64,
) -> usize {
    let mut n = 0;
    for t in 0..n_tensors {
        n += enqueue_tensor_ar(pool, coll, gstore, rank, ar_fail, first_tensor + t, layer_id, chunk_elems, tag);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, ClusterProfile};
    use crate::cost::TaskCosts;
    use crate::sched::build_dag;
    use crate::tasks::{Phase, TaskKind};

    fn fixture(policy: &Policy) -> Dag {
        let cfg = preset("GPT2-Tiny-MoE").expect("preset");
        let costs = TaskCosts::build(&cfg, &ClusterProfile::cluster1(16));
        build_dag(&cfg, &costs, policy)
    }

    /// Records the exact dispatch order run_native produces.
    struct Recorder {
        order: Vec<(TaskId, bool)>, // (task, submitted-as-AR)
    }

    impl TaskRunner for Recorder {
        fn run(&mut self, task: &Task) -> Result<()> {
            self.order.push((task.id, false));
            Ok(())
        }
        fn submit_ar(&mut self, task: &Task) -> Result<()> {
            self.order.push((task.id, true));
            Ok(())
        }
    }

    #[test]
    fn plan_preflight_accepts_matching_policy() {
        let pol = Policy::flow_moe(2, 0.5e6);
        let dag = fixture(&pol);
        assert!(Plan::new(dag, pol).is_ok());
    }

    #[test]
    fn plan_preflight_rejects_policy_mismatch() {
        // a FlowMoE-CC DAG places AR chunks on the concurrent channel,
        // which is illegal under strict FlowMoE — the pre-flight must
        // refuse to build a plan for it (S003)
        let cc = Policy::flow_moe_cc(2, 2.5e6);
        let dag = fixture(&cc);
        let err = Plan::new(dag, Policy::flow_moe(2, 2.5e6)).unwrap_err();
        assert!(err.to_string().contains("pre-flight"), "{err}");
    }

    #[test]
    fn run_native_respects_deps_and_fifo_order() {
        let pol = Policy::flow_moe(2, 0.5e6);
        let plan = Plan::new(fixture(&pol), pol).expect("plan");
        let dag = &plan.dag;
        let mut rec = Recorder { order: Vec::new() };
        plan.run_native(&mut rec).expect("run");
        assert_eq!(rec.order.len(), dag.tasks.len(), "every task exactly once");
        let mut pos = vec![usize::MAX; dag.tasks.len()];
        for (i, &(id, is_ar)) in rec.order.iter().enumerate() {
            assert_eq!(pos[id], usize::MAX, "task {id} dispatched twice");
            pos[id] = i;
            assert_eq!(is_ar, dag.tasks[id].kind.is_ar(), "lane routing for task {id}");
        }
        // deps always dispatched first
        for t in &dag.tasks {
            for &d in &t.deps {
                assert!(pos[d] < pos[t.id], "task {} ran before dep {}", t.id, d);
            }
        }
        // inline tasks in strictly ascending FIFO rank (Eqs. 2–5)
        let inline_seqs: Vec<u64> = rec
            .order
            .iter()
            .filter(|&&(_, ar)| !ar)
            .map(|&(id, _)| dag.tasks[id].seq)
            .collect();
        assert!(inline_seqs.windows(2).all(|w| w[0] < w[1]), "inline FIFO order");
        // AR chunks submitted in FIFO (seq) order — Algorithm 2
        let ar_seqs: Vec<u64> = rec
            .order
            .iter()
            .filter(|&&(_, ar)| ar)
            .map(|&(id, _)| dag.tasks[id].seq)
            .collect();
        assert!(ar_seqs.len() >= 2, "fixture must have chunked AR");
        assert!(ar_seqs.windows(2).all(|w| w[0] < w[1]), "AR FIFO order");
        // Pipe-AR: layer l's chunks are all submitted before layer l-1's
        // first backward-AT completes its chunks (emission is l DESC)
        let ar_layers: Vec<usize> = rec
            .order
            .iter()
            .filter(|&&(_, ar)| ar)
            .map(|&(id, _)| match dag.tasks[id].kind {
                TaskKind::Ar { l, .. } => l,
                _ => unreachable!(),
            })
            .collect();
        assert!(ar_layers.windows(2).all(|w| w[0] >= w[1]), "AR layers descend");
    }

    #[test]
    fn centralized_plan_submits_ar_after_all_compute() {
        let pol = Policy::flow_moe_at(2);
        let plan = Plan::new(fixture(&pol), pol).expect("plan");
        let mut rec = Recorder { order: Vec::new() };
        plan.run_native(&mut rec).expect("run");
        let first_ar = rec.order.iter().position(|&(_, ar)| ar).expect("has AR");
        let last_inline = rec
            .order
            .iter()
            .rposition(|&(_, ar)| !ar)
            .expect("has inline work");
        assert!(
            last_inline < first_ar,
            "centralized AR must start only after the full backward pass"
        );
    }

    #[test]
    fn pipelined_plan_interleaves_ar_with_compute() {
        let pol = Policy::flow_moe(2, 0.5e6);
        let plan = Plan::new(fixture(&pol), pol).expect("plan");
        let mut rec = Recorder { order: Vec::new() };
        plan.run_native(&mut rec).expect("run");
        let first_ar = rec.order.iter().position(|&(_, ar)| ar).expect("has AR");
        let last_inline = rec.order.iter().rposition(|&(_, ar)| !ar).expect("inline");
        assert!(
            first_ar < last_inline,
            "Pipe-AR must submit block chunks while earlier blocks still run backward"
        );
    }

    #[test]
    fn run_native_head_runs_between_phases() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let plan = Plan::new(fixture(&pol), pol).expect("plan");
        let dag = &plan.dag;
        let mut rec = Recorder { order: Vec::new() };
        plan.run_native(&mut rec).expect("run");
        let mut pos = vec![0usize; dag.tasks.len()];
        for (i, &(id, _)) in rec.order.iter().enumerate() {
            pos[id] = i;
        }
        let head = dag
            .tasks
            .iter()
            .position(|t| matches!(t.kind, TaskKind::Head))
            .expect("head");
        for t in &dag.tasks {
            match t.kind {
                TaskKind::At { phase: Phase::Fwd, .. }
                | TaskKind::Disp { phase: Phase::Fwd, .. }
                | TaskKind::Exp { phase: Phase::Fwd, .. }
                | TaskKind::Comb { phase: Phase::Fwd, .. } => {
                    assert!(pos[t.id] < pos[head], "fwd task after head");
                }
                TaskKind::At { phase: Phase::Bwd, .. }
                | TaskKind::Disp { phase: Phase::Bwd, .. }
                | TaskKind::Exp { phase: Phase::Bwd, .. }
                | TaskKind::Comb { phase: Phase::Bwd, .. } => {
                    assert!(pos[t.id] > pos[head], "bwd task before head");
                }
                TaskKind::Ar { .. } | TaskKind::Head => {}
            }
        }
    }

    #[test]
    fn modeled_matches_simulator_delegate() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let plan = Plan::new(fixture(&pol), pol).expect("plan");
        let a = plan.modeled();
        let b = crate::sim::simulate(&plan.dag);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.spans.len(), b.spans.len());
    }
}
