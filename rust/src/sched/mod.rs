//! Scheduling policies: FlowMoE and the five baselines of the paper's
//! evaluation, expressed as builders of the iteration task DAG.
//!
//! The baselines differ **only** in which task types they pipeline
//! (paper Table A.2) plus small framework-specific A2A efficiency factors
//! (documented below); all share identical per-task costs, which is the
//! variable the paper's comparison isolates.
//!
//! Stream ordering follows Eqs. 2–5 *strictly*: consecutive same-stream
//! tasks are chained with explicit dependencies (the paper's FIFO
//! timeline), while AR chunks are attached only to their gradient
//! availability (end of the block's `AT` backward, Appendix H) and yield
//! to any ready A2A task (Algorithm 2) — the simulator's priority rule.

pub mod autor;

use crate::config::ModelCfg;
use crate::cost::TaskCosts;
use crate::tasks::{Dag, Phase, Stream, TaskId, TaskKind};

/// A scheduling policy (one per framework in the paper's comparison).
#[derive(Clone, Debug)]
pub struct Policy {
    pub name: &'static str,
    /// Pipeline D/E/C of the MoE layer into R subtasks (Tutel & up).
    pub pipe_moe: bool,
    /// Pipeline MHA+gating into R subtasks (FlowMoE's Pipe-AT).
    pub pipe_at: bool,
    /// Chunk all-reduce tensors and interleave (FlowMoE's Pipe-AR).
    pub pipe_ar: bool,
    /// Pipelining degree R.
    pub r: usize,
    /// All-reduce chunk size in bytes (only used when `pipe_ar`).
    pub sp_bytes: f64,
    /// Multiplier on the A2A payload time (framework-specific transport
    /// efficiency; <1.0 = faster than the Tutel baseline path).
    pub a2a_eff: f64,
    /// Multiplier on the per-subtask A2A startup (FasterMoE's
    /// point-to-point sends pay more launches).
    pub a2a_alpha_factor: f64,
    /// Expert-parameter replication factor (FasterMoE's shadow experts) —
    /// memory model only.
    pub expert_replication: f64,
    /// Place AR chunks on a concurrent communication channel (separate
    /// NCCL communicator) instead of the shared comm stream. `false` is
    /// the paper's *theoretical* single-comm-stream model (Theorems 1–2);
    /// `true` reproduces the measured behaviour of the paper's testbed,
    /// whose comm-dominated speedups exceed the strict model's comm-busy
    /// lower bound (see EXPERIMENTS.md §Findings). The contention with
    /// A2A traffic is baked into the calibrated `NetProfile::ar_bw`.
    pub ar_channel: bool,
}

impl Policy {
    /// Vanilla expert parallelism (FastMoE-style): no pipelining at all.
    pub fn vanilla_ep() -> Policy {
        Policy {
            name: "vanillaEP",
            pipe_moe: false,
            pipe_at: false,
            pipe_ar: false,
            r: 1,
            sp_bytes: f64::INFINITY,
            a2a_eff: 1.0,
            a2a_alpha_factor: 1.0,
            expert_replication: 1.0,
            ar_channel: false,
        }
    }

    /// FasterMoE-like: MoE-layer pipelining via per-worker point-to-point
    /// chunks (more startup per chunk), expert replication for load
    /// balance (memory cost).
    pub fn faster_moe(r: usize) -> Policy {
        Policy {
            name: "FasterMoE",
            pipe_moe: true,
            pipe_at: false,
            pipe_ar: false,
            r,
            sp_bytes: f64::INFINITY,
            a2a_eff: 1.02,
            a2a_alpha_factor: 3.0,
            expert_replication: 1.6,
            ar_channel: false,
        }
    }

    /// Tutel / PipeMoE-like: adaptive MoE-layer pipelining.
    pub fn tutel(r: usize) -> Policy {
        Policy {
            name: "Tutel",
            pipe_moe: true,
            pipe_at: false,
            pipe_ar: false,
            r,
            sp_bytes: f64::INFINITY,
            a2a_eff: 1.0,
            a2a_alpha_factor: 1.0,
            expert_replication: 1.0,
            ar_channel: false,
        }
    }

    /// ScheMoE-like: Tutel + optimized A2A ops (virtual streams / fused
    /// data layout => ~15 % faster A2A payload path, calibrated to the
    /// paper's Tutel-vs-ScheMoE gap).
    pub fn sche_moe(r: usize) -> Policy {
        Policy {
            name: "ScheMoE",
            a2a_eff: 0.85,
            ..Policy::tutel(r)
        }
    }

    /// FSMoE-like: ScheMoE-class scheduling + intra-/inter-node A2A split
    /// overlap (~22 % faster A2A payload, calibrated to the paper's gap).
    pub fn fs_moe(r: usize) -> Policy {
        Policy {
            name: "FSMoE",
            a2a_eff: 0.78,
            ..Policy::tutel(r)
        }
    }

    /// FlowMoE: unified AT+MoE pipeline + chunked-AR priority scheduling.
    pub fn flow_moe(r: usize, sp_bytes: f64) -> Policy {
        Policy {
            name: "FlowMoE",
            pipe_moe: true,
            pipe_at: true,
            pipe_ar: true,
            r,
            sp_bytes,
            a2a_eff: 1.0,
            a2a_alpha_factor: 1.0,
            expert_replication: 1.0,
            ar_channel: false,
        }
    }

    /// FlowMoE with AR on a concurrent comm channel — models the paper's
    /// measured testbed behaviour (concurrent NCCL communicators); see
    /// `ar_channel` docs and EXPERIMENTS.md §Findings.
    pub fn flow_moe_cc(r: usize, sp_bytes: f64) -> Policy {
        Policy {
            name: "FlowMoE-CC",
            ar_channel: true,
            ..Policy::flow_moe(r, sp_bytes)
        }
    }

    /// FlowMoE with ScheMoE's optimized A2A ops integrated — the paper's
    /// stated combination opportunity ("this strategy can also be
    /// integrated into FlowMoE", Sec. 5.2): FlowMoE scheduling over the
    /// ~15 % faster A2A payload path.
    pub fn flow_moe_sche(r: usize, sp_bytes: f64) -> Policy {
        Policy {
            name: "FlowMoE+Sche",
            a2a_eff: 0.85,
            ar_channel: true,
            ..Policy::flow_moe(r, sp_bytes)
        }
    }

    /// Ablation: Pipe-MoE + Pipe-AT only (Table 5 "FlowMoE-AT").
    pub fn flow_moe_at(r: usize) -> Policy {
        Policy {
            name: "FlowMoE-AT",
            pipe_ar: false,
            sp_bytes: f64::INFINITY,
            ..Policy::flow_moe(r, f64::INFINITY)
        }
    }

    /// Ablation: Pipe-MoE + Pipe-AR only (Table 5 "FlowMoE-AR").
    pub fn flow_moe_ar(r: usize, sp_bytes: f64) -> Policy {
        Policy {
            name: "FlowMoE-AR",
            pipe_at: false,
            ..Policy::flow_moe(r, sp_bytes)
        }
    }
}

/// Build the full fwd+bwd iteration DAG for `cfg` under `policy`.
pub fn build_dag(cfg: &ModelCfg, costs: &TaskCosts, policy: &Policy) -> Dag {
    let mut dag = Dag::new();
    let l_blocks = cfg.l;
    let r_moe = if policy.pipe_moe { policy.r.max(1) } else { 1 };
    let r_at = if policy.pipe_at { r_moe } else { 1 };

    // per-subtask durations
    let at_f = costs.at_fwd / r_at as f64;
    let at_b = costs.at_bwd / r_at as f64;
    let ex_f = costs.exp_fwd / r_moe as f64;
    let ex_b = costs.exp_bwd / r_moe as f64;
    let a2a_payload = (costs.a2a - costs.a2a_alpha) * policy.a2a_eff;
    let a2a_sub = costs.a2a_alpha * policy.a2a_alpha_factor + a2a_payload / r_moe as f64;
    let a2a_bytes_sub = costs.a2a_bytes / r_moe as f64;

    let mut seq: u64 = 0;
    let mut next_seq = || {
        seq += 1;
        seq
    };

    // stream chain heads (strict FIFO per Eqs. 2-5)
    let mut prev_comp: Option<TaskId> = None;
    let mut prev_a2a: Option<TaskId> = None;

    let chain = |prev: &mut Option<TaskId>, extra: &mut Vec<TaskId>| {
        if let Some(p) = *prev {
            extra.push(p);
        }
    };

    // map MoE subtask r -> AT subtask index feeding it
    let at_of = |r: usize| -> usize {
        if r_at == r_moe {
            r
        } else {
            0 // monolithic AT feeds every MoE subtask
        }
    };

    // ---------------- forward ----------------
    // fwd_comb[l][r] = id of combine subtask
    let mut fwd_comb: Vec<Vec<TaskId>> = vec![vec![0; r_moe]; l_blocks];
    let mut fwd_at: Vec<Vec<TaskId>> = vec![vec![0; r_at]; l_blocks];
    for l in 0..l_blocks {
        for r in 0..r_at {
            let mut deps = Vec::new();
            chain(&mut prev_comp, &mut deps);
            if l > 0 {
                if r_at == r_moe {
                    deps.push(fwd_comb[l - 1][r]);
                } else {
                    deps.extend(fwd_comb[l - 1].iter().copied());
                }
            }
            let id = dag.add(
                TaskKind::At { l, r, phase: Phase::Fwd },
                Stream::Compute,
                at_f,
                deps,
                next_seq(),
            );
            fwd_at[l][r] = id;
            prev_comp = Some(id);
        }
        let mut disp = vec![0; r_moe];
        for r in 0..r_moe {
            let mut deps = vec![fwd_at[l][at_of(r)]];
            chain(&mut prev_a2a, &mut deps);
            let id = dag.add_with_bytes(
                TaskKind::Disp { l, r, phase: Phase::Fwd },
                Stream::Comm,
                a2a_sub,
                deps,
                next_seq(),
                a2a_bytes_sub,
            );
            disp[r] = id;
            prev_a2a = Some(id);
        }
        let mut exp = vec![0; r_moe];
        for r in 0..r_moe {
            let mut deps = vec![disp[r]];
            chain(&mut prev_comp, &mut deps);
            let id = dag.add(
                TaskKind::Exp { l, r, phase: Phase::Fwd },
                Stream::Compute,
                ex_f,
                deps,
                next_seq(),
            );
            exp[r] = id;
            prev_comp = Some(id);
        }
        for r in 0..r_moe {
            let mut deps = vec![exp[r]];
            chain(&mut prev_a2a, &mut deps);
            let id = dag.add_with_bytes(
                TaskKind::Comb { l, r, phase: Phase::Fwd },
                Stream::Comm,
                a2a_sub,
                deps,
                next_seq(),
                a2a_bytes_sub,
            );
            fwd_comb[l][r] = id;
            prev_a2a = Some(id);
        }
    }

    // ---------------- head / loss turnaround ----------------
    let mut deps: Vec<TaskId> = fwd_comb[l_blocks - 1].clone();
    chain(&mut prev_comp, &mut deps);
    let head = dag.add(TaskKind::Head, Stream::Compute, costs.head, deps, next_seq());
    prev_comp = Some(head);

    // ---------------- backward (Eqs. 4/5, deps 6a-6e) ----------------
    let mut ar_seq_base: u64 = 1_000_000; // AR chunk FIFO among themselves
    let mut ar_tasks: Vec<TaskId> = Vec::new();
    let mut bwd_at: Vec<Vec<TaskId>> = vec![vec![0; r_at]; l_blocks];
    for l in (0..l_blocks).rev() {
        // combine-bwd (scatter dy to experts), order C_R..C_1 (Eq. 5)
        let mut comb_b = vec![0; r_moe];
        for r in (0..r_moe).rev() {
            let mut deps = Vec::new();
            chain(&mut prev_a2a, &mut deps);
            if l == l_blocks - 1 {
                deps.push(head);
            } else if r_at == r_moe {
                deps.push(bwd_at[l + 1][r]); // 6a
            } else {
                deps.extend(bwd_at[l + 1].iter().copied());
            }
            let id = dag.add_with_bytes(
                TaskKind::Comb { l, r, phase: Phase::Bwd },
                Stream::Comm,
                a2a_sub,
                deps,
                next_seq(),
                a2a_bytes_sub,
            );
            comb_b[r] = id;
            prev_a2a = Some(id);
        }
        // expert-bwd, order E_R..E_1 (Eq. 4)
        let mut exp_b = vec![0; r_moe];
        for r in (0..r_moe).rev() {
            let mut deps = vec![comb_b[r]]; // 6b
            chain(&mut prev_comp, &mut deps);
            let id = dag.add(
                TaskKind::Exp { l, r, phase: Phase::Bwd },
                Stream::Compute,
                ex_b,
                deps,
                next_seq(),
            );
            exp_b[r] = id;
            prev_comp = Some(id);
        }
        // dispatch-bwd, order D_R..D_1 (Eq. 5)
        let mut disp_b = vec![0; r_moe];
        for r in (0..r_moe).rev() {
            let mut deps = vec![exp_b[r]]; // 6c
            chain(&mut prev_a2a, &mut deps);
            let id = dag.add_with_bytes(
                TaskKind::Disp { l, r, phase: Phase::Bwd },
                Stream::Comm,
                a2a_sub,
                deps,
                next_seq(),
                a2a_bytes_sub,
            );
            disp_b[r] = id;
            prev_a2a = Some(id);
        }
        // AT-bwd, order AT_R..AT_1 (Eq. 4)
        for r in (0..r_at).rev() {
            let mut deps: Vec<TaskId> = if r_at == r_moe {
                vec![disp_b[r]] // 6d
            } else {
                disp_b.clone()
            };
            chain(&mut prev_comp, &mut deps);
            let id = dag.add(
                TaskKind::At { l, r, phase: Phase::Bwd },
                Stream::Compute,
                at_b,
                deps,
                next_seq(),
            );
            bwd_at[l][r] = id;
            prev_comp = Some(id);
        }

        if policy.pipe_ar {
            // AR chunks of block l: ready once the block's gradients are
            // fully accumulated (all AT-bwd subtasks done, Appendix H);
            // scheduled by the comm pool at lower priority than any A2A.
            let n_chunks = costs.ar_chunks(policy.sp_bytes);
            let chunk_bytes = costs.ar_bytes / n_chunks as f64;
            let ar_stream = if policy.ar_channel {
                Stream::ArComm
            } else {
                Stream::Comm
            };
            for c in 0..n_chunks {
                ar_seq_base += 1;
                // On the concurrent channel, chunks of one tensor are
                // FIFO: chain them so they serialize like one NCCL
                // communicator's stream does.
                let mut deps = bwd_at[l].clone();
                if policy.ar_channel {
                    if let Some(&prev) = ar_tasks.last() {
                        deps.push(prev);
                    }
                }
                let id = dag.add_with_bytes(
                    TaskKind::Ar { l, c },
                    ar_stream,
                    costs.ar_chunk(chunk_bytes),
                    deps,
                    ar_seq_base,
                    chunk_bytes,
                );
                ar_tasks.push(id);
            }
        }
    }

    if !policy.pipe_ar {
        // Centralized all-reduce: one AR per block, executed after the
        // entire backward propagation (the baselines' behaviour).
        // prev_comp always holds the last backward compute task here; fall
        // back to the head (always present) rather than unwrap.
        let last_compute = prev_comp.unwrap_or(head);
        let mut prev_ar: Option<TaskId> = None;
        for l in (0..l_blocks).rev() {
            let mut deps = vec![last_compute];
            if let Some(p) = prev_ar {
                deps.push(p);
            }
            ar_seq_base += 1;
            let id = dag.add_with_bytes(
                TaskKind::Ar { l, c: 0 },
                Stream::Comm,
                costs.ar_chunk(costs.ar_bytes),
                deps,
                ar_seq_base,
                costs.ar_bytes,
            );
            prev_ar = Some(id);
            ar_tasks.push(id);
        }
    }

    dag
}

/// Convenience: simulate one iteration and return (seconds, timeline).
pub fn iteration_time(
    cfg: &ModelCfg,
    cluster: &crate::config::ClusterProfile,
    policy: &Policy,
) -> (f64, crate::sim::Timeline) {
    let costs = TaskCosts::build(cfg, cluster);
    let dag = build_dag(cfg, &costs, policy);
    let tl = crate::sim::simulate(&dag);
    (tl.makespan, tl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, ClusterProfile};
    use crate::sim::{simulate, verify_timeline};

    fn setup(name: &str) -> (ModelCfg, TaskCosts) {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &ClusterProfile::cluster1(16));
        (cfg, costs)
    }

    #[test]
    fn dag_task_counts_vanilla() {
        let (cfg, costs) = setup("GPT2-Tiny-MoE");
        let d = build_dag(&cfg, &costs, &Policy::vanilla_ep());
        // per layer fwd: AT + D + E + C = 4; bwd same = 4; + L AR + head
        assert_eq!(d.len(), cfg.l * 8 + cfg.l + 1);
        d.validate().unwrap();
    }

    #[test]
    fn dag_task_counts_flowmoe() {
        let (cfg, costs) = setup("GPT2-Tiny-MoE");
        let pol = Policy::flow_moe(2, 1e6);
        let d = build_dag(&cfg, &costs, &pol);
        let n_chunks = costs.ar_chunks(1e6);
        assert_eq!(d.len(), cfg.l * 2 * 8 + cfg.l * n_chunks + 1);
        d.validate().unwrap();
    }

    #[test]
    fn all_policies_simulate_clean() {
        let (cfg, costs) = setup("BERT-Large-MoE");
        for pol in [
            Policy::vanilla_ep(),
            Policy::faster_moe(2),
            Policy::tutel(2),
            Policy::sche_moe(2),
            Policy::fs_moe(2),
            Policy::flow_moe_at(2),
            Policy::flow_moe_ar(2, 2.5e6),
            Policy::flow_moe(2, 2.5e6),
        ] {
            let d = build_dag(&cfg, &costs, &pol);
            d.validate().unwrap();
            let tl = simulate(&d);
            verify_timeline(&d, &tl).unwrap();
            assert!(tl.makespan > 0.0, "{}", pol.name);
        }
    }

    #[test]
    fn paper_ordering_holds() {
        // FlowMoE < ScheMoE/FSMoE < Tutel <= vanilla, per the paper's
        // Table 3 ordering (FasterMoE sits between Tutel and vanilla).
        let cfg = preset("BERT-Large-MoE").unwrap();
        let cl = ClusterProfile::cluster1(16);
        let t = |p: &Policy| iteration_time(&cfg, &cl, p).0;
        let flow = t(&Policy::flow_moe(2, 2.5e6));
        let sche = t(&Policy::sche_moe(2));
        let fsm = t(&Policy::fs_moe(2));
        let tut = t(&Policy::tutel(2));
        let fast = t(&Policy::faster_moe(2));
        let van = t(&Policy::vanilla_ep());
        assert!(flow < sche, "flow={flow} sche={sche}");
        assert!(flow < fsm, "flow={flow} fsm={fsm}");
        assert!(sche < tut, "sche={sche} tut={tut}");
        assert!(tut < van, "tut={tut} van={van}");
        assert!(fast < van, "fast={fast} van={van}");
    }

    #[test]
    fn tutel_beats_vanilla_by_pipelining() {
        let cfg = preset("DeepSeek-V2-S").unwrap();
        let cl = ClusterProfile::cluster1(16);
        let tut = iteration_time(&cfg, &cl, &Policy::tutel(2)).0;
        let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0;
        assert!(tut < van * 0.95);
    }

    #[test]
    fn flow_moe_speedup_band_16gpu() {
        // Paper Table 3 @16 GPUs: FlowMoE/vanilla speedup 1.43-1.82x.
        // Strict single-comm-stream mode is bounded by the comm-busy floor
        // (Appendix I case 1) on comm-dominated models, so we assert a
        // conservative strict band; the concurrent-channel mode (which is
        // what the testbed actually measured — EXPERIMENTS.md §Findings)
        // must land in the paper-compatible band.
        let cl = ClusterProfile::cluster1(16);
        // DeepSeek-V2-S is AR-wire-bound in the Table-1-consistent
        // calibration (1.68 GB replicated grads), which caps its speedup
        // well below the paper's 1.82x — see EXPERIMENTS.md §Findings.
        let cc_floor = [
            ("GPT2-Tiny-MoE", 1.30),
            ("BERT-Large-MoE", 1.30),
            ("LLaMA2-MoE", 1.30),
            ("DeepSeek-V2-S", 1.15),
        ];
        for (name, floor) in cc_floor {
            let cfg = preset(name).unwrap();
            let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0;
            let strict = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 2.5e6)).0;
            let cc = iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, 2.5e6)).0;
            let s_strict = van / strict;
            let s_cc = van / cc;
            assert!((1.02..=2.3).contains(&s_strict), "{name}: strict speedup {s_strict:.2}");
            assert!((floor..=2.3).contains(&s_cc), "{name}: cc speedup {s_cc:.2}");
            assert!(s_cc >= s_strict - 1e-9, "{name}: cc {s_cc:.2} < strict {s_strict:.2}");
        }
    }

    /// Best simulated time over a small S_p grid — what BO converges to.
    fn tuned_flow(cfg: &ModelCfg, cl: &ClusterProfile, make: impl Fn(f64) -> Policy) -> f64 {
        [0.5e6, 1e6, 2.5e6, 8e6, 32e6, 128e6]
            .iter()
            .map(|&sp| iteration_time(cfg, cl, &make(sp)).0)
            .fold(f64::INFINITY, f64::min)
    }

    #[test]
    fn ablation_ordering_table5() {
        // Paper Table 5 ordering (time): vanilla > Tutel > FlowMoE-AT >
        // FlowMoE-AR(BO) > FlowMoE. FlowMoE rows use the BO-tuned S_p
        // (the fixed-S_p row of the paper is covered by tableA4 bench).
        // Stacked x4: AR of block l overlaps tasks of block l-1, so a
        // single isolated layer (L=1) cannot show the Pipe-AR gain under
        // the strict model — its AR is only ready at the very end of its
        // own backward (see EXPERIMENTS.md §Findings).
        let mut cfg = ModelCfg::custom_layer(4, 1.2, 512, 8192, 8192, 16);
        cfg.l = 4;
        let cl = ClusterProfile::cluster1(16);
        let t = |p: &Policy| iteration_time(&cfg, &cl, p).0;
        let van = t(&Policy::vanilla_ep());
        let tut = t(&Policy::tutel(2));
        let at = t(&Policy::flow_moe_at(2));
        let ar = tuned_flow(&cfg, &cl, |sp| Policy::flow_moe_ar(2, sp));
        let full = tuned_flow(&cfg, &cl, |sp| Policy::flow_moe(2, sp));
        assert!(van > tut, "van={van} tut={tut}");
        assert!(tut > at, "tut={tut} at={at}");
        assert!(at > full, "at={at} full={full}");
        assert!(ar >= full - 1e-9, "ar={ar} full={full}");
        assert!(ar < tut, "ar={ar} tut={tut}");
    }

    #[test]
    fn theorem1_inserted_ar_not_worse_than_centralized() {
        // FlowMoE-AR (chunked, priority) <= FlowMoE-AT w/ centralized AR,
        // all else equal — the paper's Theorem 1 on the simulated model.
        let cl = ClusterProfile::cluster1(16);
        for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE"] {
            let cfg = preset(name).unwrap();
            let central = iteration_time(&cfg, &cl, &Policy::flow_moe_at(2)).0;
            let chunked = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 2.5e6)).0;
            assert!(
                chunked <= central + 1e-9,
                "{name}: chunked {chunked} > centralized {central}"
            );
        }
    }

    #[test]
    fn ar_chunks_present_only_with_pipe_ar() {
        let (cfg, costs) = setup("GPT2-Tiny-MoE");
        let d1 = build_dag(&cfg, &costs, &Policy::tutel(2));
        let d2 = build_dag(&cfg, &costs, &Policy::flow_moe(2, 0.5e6));
        let ar1 = d1.count(|k| k.is_ar());
        let ar2 = d2.count(|k| k.is_ar());
        assert_eq!(ar1, cfg.l);
        assert!(ar2 > cfg.l);
    }
}
