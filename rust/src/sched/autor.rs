//! Automatic pipelining-degree selection (the paper defers to PipeMoE
//! [21] for choosing R; this implements that selection over our cost
//! model: balance overlap gains against per-subtask startup overhead).
//!
//! PipeMoE's insight: the optimal R roughly equalizes the pipelined
//! stage times while keeping R·α (aggregate startup) small relative to
//! the payload. Rather than carry PipeMoE's closed form (tied to their
//! linear performance models), we evaluate the candidate Rs on the
//! simulator — which *is* our performance model — and pick the argmin.
//! This is exactly "profile a few candidates once, then train", the same
//! budget class as the paper's BO for S_p.

use crate::config::{ClusterProfile, ModelCfg};
use crate::sched::{iteration_time, Policy};

/// Candidate pipelining degrees (powers of two; R=1 means no pipelining
/// and is included so degenerate workloads can opt out).
pub const R_CANDIDATES: [usize; 5] = [1, 2, 4, 8, 16];

/// Pick the R minimizing simulated iteration time for `make(r)`.
/// Returns (best_r, best_seconds, all evaluated (r, seconds) pairs).
pub fn select_r<F: Fn(usize) -> Policy>(
    cfg: &ModelCfg,
    cluster: &ClusterProfile,
    make: F,
) -> (usize, f64, Vec<(usize, f64)>) {
    let mut evals = Vec::new();
    let mut best = (1usize, f64::INFINITY);
    for &r in &R_CANDIDATES {
        // R splits the MoE input on the token dimension (paper Sec. 2.3),
        // so it is bounded by the per-worker token count, not the sample
        // count — skip degenerate degrees only.
        if r > cfg.tokens().max(1) && r > 1 {
            continue;
        }
        let t = iteration_time(cfg, cluster, &make(r)).0;
        evals.push((r, t));
        if t < best.1 {
            best = (r, t);
        }
    }
    (best.0, best.1, evals)
}

/// Joint (R, S_p) selection: R by simulation sweep, then S_p by BO at
/// the chosen R — the full auto-tuning pipeline of an adaptive
/// deployment (paper Secs. 4.1–4.2 + [21]).
pub fn select_r_and_sp(
    cfg: &ModelCfg,
    cluster: &ClusterProfile,
    bo_samples: usize,
    seed: u64,
) -> (usize, f64, f64) {
    let (r, _, _) = select_r(cfg, cluster, |r| Policy::flow_moe(r, 4e6));
    let mut bo = crate::bo::BoTuner::new(cfg.ar_bytes_per_block().max(1e6), seed);
    let sp = bo.tune(bo_samples, |sp| {
        iteration_time(cfg, cluster, &Policy::flow_moe(r, sp)).0
    });
    let t = iteration_time(cfg, cluster, &Policy::flow_moe(r, sp)).0;
    (r, sp, t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn select_r_returns_a_candidate_and_best_time() {
        let cfg = preset("BERT-Large-MoE").unwrap();
        let cl = ClusterProfile::cluster1(16);
        let (r, t, evals) = select_r(&cfg, &cl, |r| Policy::flow_moe(r, 2.5e6));
        assert!(R_CANDIDATES.contains(&r));
        assert!(evals.iter().all(|&(_, tt)| tt >= t));
        assert!(t > 0.0);
    }

    #[test]
    fn auto_r_never_worse_than_fixed_r2() {
        for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE", "DeepSeek-V2-S"] {
            let cfg = preset(name).unwrap();
            let cl = ClusterProfile::cluster1(16);
            let fixed = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 2.5e6)).0;
            let (_, t, _) = select_r(&cfg, &cl, |r| Policy::flow_moe(r, 2.5e6));
            assert!(t <= fixed + 1e-12, "{name}: auto {t} > fixed {fixed}");
        }
    }

    #[test]
    fn infeasible_r_skipped_for_tiny_token_counts() {
        let mut cfg = preset("GPT2-Tiny-MoE").unwrap();
        cfg.b = 1;
        cfg.n = 8;
        let cl = ClusterProfile::cluster1(16);
        let (_, _, evals) = select_r(&cfg, &cl, |r| Policy::flow_moe(r, 2.5e6));
        assert!(evals.iter().all(|&(r, _)| r <= 8));
    }

    #[test]
    fn joint_selection_beats_default_deployment() {
        let cfg = preset("LLaMA2-MoE").unwrap();
        let cl = ClusterProfile::cluster1(16);
        let default = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 1e6)).0;
        let (_r, _sp, t) = select_r_and_sp(&cfg, &cl, 8, 3);
        assert!(t <= default * 1.001, "joint {t} vs default {default}");
    }
}
