//! Synthetic open-loop traffic: Poisson arrivals, Zipf lengths.
//!
//! Arrivals are open-loop (requests show up regardless of server
//! backlog) with exponential inter-arrival gaps measured in **virtual
//! decode steps**, so the full arrival/admission/token schedule is a
//! pure function of the seed — wall-clock speed only moves the timing
//! numbers, never the token stream. Prompt and output lengths follow
//! Zipf laws (most requests short, a heavy tail of long ones), and
//! prompt tokens follow the same Zipf-over-vocab shape as the training
//! corpus so routing is realistically skewed. Each random axis draws
//! from its own split [`Pcg32`] stream, so e.g. changing the length
//! distribution cannot perturb arrival times.

use crate::util::{rng::zipf_cdf, Pcg32};

use super::sched::Request;

/// Traffic shape knobs (all lengths in tokens, gaps in decode steps).
#[derive(Clone, Copy, Debug)]
pub struct TrafficCfg {
    pub requests: usize,
    /// Mean exponential inter-arrival gap, in decode steps.
    pub mean_gap_steps: f64,
    pub max_prompt: usize,
    pub max_new: usize,
    /// Zipf exponent of the prompt/output length laws.
    pub len_zipf_s: f64,
    pub vocab: usize,
}

/// Generate the full request trace for one serving run.
pub fn generate(seed: u64, cfg: &TrafficCfg) -> Vec<Request> {
    let mut root = Pcg32::new(seed);
    let mut arrivals = root.split();
    let mut lens = root.split();
    let mut toks = root.split();
    let prompt_cdf = zipf_cdf(cfg.max_prompt, cfg.len_zipf_s);
    let out_cdf = zipf_cdf(cfg.max_new, cfg.len_zipf_s);
    let tok_cdf = zipf_cdf(cfg.vocab, 1.1);
    let mut t = 0.0f64;
    let mut reqs = Vec::with_capacity(cfg.requests);
    for id in 0..cfg.requests as u64 {
        t += arrivals.exp(cfg.mean_gap_steps);
        let p_len = lens.zipf(&prompt_cdf) + 1;
        let max_new = lens.zipf(&out_cdf) + 1;
        let prompt: Vec<i32> = (0..p_len).map(|_| toks.zipf(&tok_cdf) as i32).collect();
        reqs.push(Request {
            id,
            arrival_step: t as u64,
            prompt,
            max_new,
        });
    }
    reqs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrafficCfg {
        TrafficCfg {
            requests: 64,
            mean_gap_steps: 2.0,
            max_prompt: 24,
            max_new: 16,
            len_zipf_s: 1.2,
            vocab: 128,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(7, &cfg());
        let b = generate(7, &cfg());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.arrival_step, &x.prompt, x.max_new), (y.arrival_step, &y.prompt, y.max_new));
        }
        let c = generate(8, &cfg());
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt || x.arrival_step != y.arrival_step),
            "different seed must change the trace"
        );
    }

    #[test]
    fn lengths_and_tokens_within_bounds() {
        let reqs = generate(3, &cfg());
        assert_eq!(reqs.len(), 64);
        for r in &reqs {
            assert!((1..=24).contains(&r.prompt.len()));
            assert!((1..=16).contains(&r.max_new));
            assert!(r.prompt.iter().all(|&t| (0..128).contains(&t)));
        }
        // Zipf: short requests dominate
        let short = reqs.iter().filter(|r| r.prompt.len() <= 4).count();
        assert!(short * 2 > reqs.len(), "short prompts should dominate ({short}/64)");
    }

    #[test]
    fn arrivals_are_nondecreasing_and_spread() {
        let reqs = generate(11, &cfg());
        for w in reqs.windows(2) {
            assert!(w[0].arrival_step <= w[1].arrival_step);
            assert!(w[0].id < w[1].id);
        }
        let last = reqs[reqs.len() - 1].arrival_step;
        assert!(last > 32, "64 requests at mean gap 2 should span many steps (got {last})");
    }
}
