//! Expert-parallel serving cluster: attention stays on the driver,
//! expert FFNs run on dedicated expert workers.
//!
//! Serving splits the model the way Expert Kit does (SNIPPETS.md §3):
//! the attention/gating half of every layer runs where the KV caches
//! live (the driver), while expert FFNs scale out at **individual
//! expert granularity** — each worker owns at most one expert's
//! weights. Spare workers replicate the hottest experts (ranked by the
//! routing counts the decoder observed during local warmup), and a
//! replicated expert's capacity rows are split near-evenly across its
//! replicas.
//!
//! Every worker receives exactly one message per (layer, step) round —
//! its fixed row range of its expert's `(c, M)` dispatch slab — so the
//! protocol never blocks on an unselected replica, message sizes are
//! step-invariant (capacity is fixed per run, see
//! [`super::decode::serve_capacity`]), and because the row split is
//! fixed and row outputs are independent of band composition (the same
//! contract the kernel conformance suite pins across thread budgets),
//! EP output is **bitwise identical** to local decode.
//!
//! Messages carry a one-element control tag ([`MSG_DATA`] /
//! [`MSG_SHUTDOWN`]) in front of the payload: a replica's fixed row
//! range can legitimately be empty (more replicas than this round's
//! capacity rows), so "no rows" and "shut down" must be distinguishable
//! by more than payload length.
//!
//! # Fault tolerance
//!
//! The driver's round-trip recv is deadline-bounded: a worker that dies
//! (or whose messages a seeded [`crate::ft::FaultPlan`] drops) surfaces
//! as a typed [`crate::commpool::CommError`] instead of a hang. The
//! driver then *heals in place* — it retires the dead thread, respawns
//! a replacement owning the same expert shard at the current round, and
//! replays the request; if even the replacement fails, it serves the
//! rows locally with the same kernel. Every path computes the identical
//! row range with identical weights, so decode output stays **bitwise
//! identical** across kills. Healing phases are traced as `ft_detect` /
//! `ft_reshard` spans.
//!
//! A2A exchanges are traced as `a2a_dispatch` / `a2a_combine` spans and
//! worker FFNs as `expert_fwd`, so `flowmoe serve --trace` renders in
//! the same Comm/Compute lanes as the trainer.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::backend::kernels as kn;
use crate::backend::model::Geo;
use crate::backend::Workspace;
use crate::cluster::{combine, dispatch};
use crate::commpool::Collective;
use crate::ft::FaultPlan;

/// Worker-side idle window: an expert worker that hears nothing from
/// the driver for this long assumes the driver is gone and exits (the
/// normal exit paths are the shutdown sentinel and `poison`).
const WORKER_IDLE_MS: u64 = 120_000;

/// Control/data tag prepended as element 0 of every driver→worker
/// message. The payload alone cannot carry this bit: a replica whose
/// fixed row split is empty this round (more replicas of an expert
/// than this call's capacity rows) legitimately receives zero data
/// elements, which used to be indistinguishable from the empty-message
/// shutdown sentinel — the replica would silently exit mid-serve and
/// the driver would burn a detection timeout + respawn on a healthy
/// round.
const MSG_DATA: f32 = 1.0;
/// Shutdown sentinel tag (the message carries no payload).
const MSG_SHUTDOWN: f32 = 0.0;

/// Wrap `chunk` as a tagged data message (`[MSG_DATA, rows...]`).
fn data_msg(chunk: &[f32]) -> Vec<f32> {
    let mut msg = Vec::with_capacity(1 + chunk.len());
    msg.push(MSG_DATA);
    msg.extend_from_slice(chunk);
    msg
}

/// Assign experts to worker ranks: every expert gets one worker, then
/// spare workers replicate the hottest experts (by observed routing
/// `counts`, ties to the smaller expert id), round-robin, capped at
/// `cap` replicas per expert (more replicas than capacity rows would
/// idle). Returns `assignment[e] = worker ranks serving expert e`;
/// ranks are contiguous from 0 in expert-major order.
pub fn plan_replicas(e: usize, workers: usize, counts: &[u64], cap: usize) -> Vec<Vec<usize>> {
    debug_assert_eq!(counts.len(), e);
    let workers = workers.max(e);
    let mut replicas = vec![1usize; e];
    let mut spare = workers - e;
    let mut order: Vec<usize> = (0..e).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(counts[i]), i));
    'outer: while spare > 0 {
        let mut grew = false;
        for &i in &order {
            if spare == 0 {
                break 'outer;
            }
            if replicas[i] < cap {
                replicas[i] += 1;
                spare -= 1;
                grew = true;
            }
        }
        if !grew {
            break; // every expert already at cap; leave the rest unspawned
        }
    }
    let mut assignment = Vec::with_capacity(e);
    let mut rank = 0usize;
    for r in replicas {
        assignment.push((rank..rank + r).collect());
        rank += r;
    }
    assignment
}

/// Row range `[lo, hi)` of replica `i` of `r` when `c` capacity rows
/// are split near-evenly (first `c % r` replicas get one extra row).
fn chunk_range(c: usize, r: usize, i: usize) -> (usize, usize) {
    let (base, rem) = (c / r, c % r);
    let lo = i * base + i.min(rem);
    (lo, lo + base + usize::from(i < rem))
}

/// Expert worker loop: one (layer, step) round per message. Element 0
/// of every message is the control tag — [`MSG_SHUTDOWN`] exits,
/// [`MSG_DATA`] carries this round's rows (possibly zero of them, which
/// still gets a reply so the driver never mistakes an idle replica for
/// a dead one). Replies use `send_replace` so a retired predecessor
/// racing a respawned replacement on the same round can never trip the
/// duplicate-send check — the newest reply wins.
#[allow(clippy::too_many_arguments)]
fn expert_worker(
    coll: Arc<Collective>,
    rank: usize,
    driver: usize,
    l_blocks: usize,
    geo_mh: (usize, usize),
    w1: Vec<Vec<f32>>,
    w2: Vec<Vec<f32>>,
    start_round: u64,
) {
    let (m, h) = geo_mh;
    let mut round: u64 = start_round;
    loop {
        let msg = match coll.recv_timeout(driver, rank, round, Duration::from_millis(WORKER_IDLE_MS)) {
            Ok(v) => v,
            Err(_) => return, // driver gone (shutdown poison) or idle too long
        };
        let Some((&tag, chunk)) = msg.split_first() else {
            return; // malformed (untagged empty) message: treat as shutdown
        };
        if tag == MSG_SHUTDOWN {
            return;
        }
        if coll.should_die(rank, round as usize) {
            // planned fault: vanish mid-request; the driver heals
            coll.mark_dead(rank);
            return;
        }
        // the driver issues layers 0..L in order every step, so the
        // layer is implied by the round counter
        let l = (round as usize) % l_blocks;
        let rows = chunk.len() / m;
        let mut out = vec![0.0f32; rows * m];
        if rows > 0 {
            let _sp = crate::obs::span("expert_fwd");
            kn::expert_ffn_into(chunk, &w1[l], &w2[l], &mut out, 1, rows, m, h);
        }
        // zero-row rounds still reply: the empty result is what tells
        // the driver this replica is alive
        coll.send_replace(rank, driver, round, out);
        round += 1;
    }
}

/// Handle to a running expert-parallel serving cluster.
pub struct EpExperts {
    coll: Arc<Collective>,
    /// `handles[rank]` = the live thread serving that rank (taken on
    /// respawn/shutdown).
    handles: Vec<Option<thread::JoinHandle<()>>>,
    /// Threads displaced by a respawn; possibly still blocked in recv,
    /// released by `poison` at shutdown.
    retired: Vec<thread::JoinHandle<()>>,
    /// `assignment[e]` = worker ranks serving expert `e`.
    assignment: Vec<Vec<usize>>,
    /// `expert_of[rank]` = the expert that rank serves.
    expert_of: Vec<usize>,
    /// Per-expert per-layer FFN weights, kept on the driver for
    /// respawns and the local fallback: `w1[e][l]`, `w2[e][l]`.
    w1: Vec<Vec<Vec<f32>>>,
    w2: Vec<Vec<Vec<f32>>>,
    l_blocks: usize,
    geo_mh: (usize, usize),
    n_workers: usize,
    round: u64,
    shut: bool,
}

impl EpExperts {
    /// Spawn expert workers per [`plan_replicas`] over the observed
    /// routing `counts`. Each worker clones only its own expert's
    /// per-layer FFN weights out of the canonical flat `params`.
    pub fn new(g: &Geo, params: &[Vec<f32>], counts: &[u64], workers: usize, c: usize) -> EpExperts {
        EpExperts::with_fault(g, params, counts, workers, c, None, crate::ft::DETECT_TIMEOUT_MS)
    }

    /// [`EpExperts::new`] with seeded fault injection and an explicit
    /// failure-detection window for the driver's round-trip waits.
    pub fn with_fault(
        g: &Geo,
        params: &[Vec<f32>],
        counts: &[u64],
        workers: usize,
        c: usize,
        fault: Option<FaultPlan>,
        detect_ms: u64,
    ) -> EpExperts {
        let l_blocks = (params.len() - 2) / 9;
        let assignment = plan_replicas(g.e, workers, counts, c);
        let n_workers: usize = assignment.iter().map(Vec::len).sum();
        let coll = Collective::with_opts(n_workers + 1, detect_ms, fault, 0);
        let (m, h) = (g.m, g.h);
        // canonical per-expert weight shards (driver-side master copy)
        let w1: Vec<Vec<Vec<f32>>> = (0..g.e)
            .map(|ex| {
                (0..l_blocks)
                    .map(|l| params[1 + l * 9 + 7][ex * m * h..(ex + 1) * m * h].to_vec())
                    .collect()
            })
            .collect();
        let w2: Vec<Vec<Vec<f32>>> = (0..g.e)
            .map(|ex| {
                (0..l_blocks)
                    .map(|l| params[1 + l * 9 + 8][ex * h * m..(ex + 1) * h * m].to_vec())
                    .collect()
            })
            .collect();
        let mut expert_of = vec![0usize; n_workers];
        for (ex, ranks) in assignment.iter().enumerate() {
            for &rank in ranks {
                expert_of[rank] = ex;
            }
        }
        let mut cluster = EpExperts {
            coll,
            handles: (0..n_workers).map(|_| None).collect(),
            retired: Vec::new(),
            assignment,
            expert_of,
            w1,
            w2,
            l_blocks,
            geo_mh: (m, h),
            n_workers,
            round: 0,
            shut: false,
        };
        for rank in 0..n_workers {
            cluster.spawn_worker(rank, 0);
        }
        cluster
    }

    /// Spawn (or respawn) the thread serving `rank`, starting its round
    /// counter at `start_round`.
    fn spawn_worker(&mut self, rank: usize, start_round: u64) {
        let coll = Arc::clone(&self.coll);
        let ex = self.expert_of[rank];
        let (w1, w2) = (self.w1[ex].clone(), self.w2[ex].clone());
        let (l_blocks, geo_mh, driver) = (self.l_blocks, self.geo_mh, self.n_workers);
        let disp = kn::active_dispatch();
        // flowmoe-lint: allow(thread_spawn) — long-lived expert worker, not a task
        self.handles[rank] = Some(thread::spawn(move || {
            kn::with_dispatch(disp, || {
                crate::sweep::scope::with_budget(1, || {
                    expert_worker(coll, rank, driver, l_blocks, geo_mh, w1, w2, start_round)
                })
            })
        }));
    }

    /// Replica count per expert (for the bench report header).
    pub fn replica_counts(&self) -> Vec<usize> {
        self.assignment.iter().map(Vec::len).collect()
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Respawns performed so far (0 on a faultless run).
    pub fn respawns(&self) -> usize {
        self.retired.len()
    }

    /// One MoE sublayer over the cluster: route on the driver, ship
    /// each expert's capacity rows to its replicas (A2A dispatch), run
    /// the FFNs remotely, gather (A2A combine), then combine + residual
    /// exactly like the local path. A worker failure mid-round is
    /// healed in place (see the module docs) — the returned output is
    /// bitwise identical either way.
    pub fn moe_step(
        &mut self,
        g: &Geo,
        h: &[f32],
        u: &[f32],
        gating: &kn::Gating,
        c: usize,
        ws: &mut Workspace,
    ) -> Vec<f32> {
        let driver = self.n_workers;
        let routing = dispatch(u, &gating.idx, gating.gate.len(), g.e, c, g.m);
        let round = self.round;
        self.round += 1;
        // (expert, rank, lo, hi) per in-flight request, fixed row split
        let mut fetches: Vec<(usize, usize, usize, usize)> = Vec::with_capacity(self.n_workers);
        {
            let _sp = crate::obs::span("a2a_dispatch");
            for (ex, ranks) in self.assignment.iter().enumerate() {
                for (ri, &rank) in ranks.iter().enumerate() {
                    let (lo, hi) = chunk_range(c, ranks.len(), ri);
                    let chunk = data_msg(&routing.disp[(ex * c + lo) * g.m..(ex * c + hi) * g.m]);
                    self.coll.send(driver, rank, round, chunk);
                    fetches.push((ex, rank, lo, hi));
                }
            }
        }
        let mut expert_out = ws.take(g.e * c * g.m);
        {
            let _sp = crate::obs::span("a2a_combine");
            for &(ex, rank, lo, hi) in &fetches {
                let out = match self.coll.recv(rank, driver, round) {
                    Ok(v) => v,
                    Err(_) => self.heal(g, ex, rank, lo, hi, round, &routing.disp, c),
                };
                expert_out[(ex * c + lo) * g.m..(ex * c + lo) * g.m + out.len()].copy_from_slice(&out);
            }
        }
        let yc = combine(&expert_out, &routing, &gating.gate);
        let mut y = ws.take(h.len());
        for ((yv, &hv), &cv) in y.iter_mut().zip(h).zip(&yc) {
            *yv = hv + cv;
        }
        ws.put_all([routing.disp, expert_out, yc]);
        y
    }

    /// Recover rows `[lo, hi)` of expert `ex` after rank `rank` failed
    /// round `round`: respawn a replacement at the current round, replay
    /// the request past the fault injector, and if the replacement also
    /// fails, run the rows on the driver with the same kernel + weights
    /// (bitwise identical by the row-independence contract).
    #[allow(clippy::too_many_arguments)]
    fn heal(
        &mut self,
        g: &Geo,
        ex: usize,
        rank: usize,
        lo: usize,
        hi: usize,
        round: u64,
        disp_slab: &[f32],
        c: usize,
    ) -> Vec<f32> {
        let now = std::time::Instant::now();
        if let Some(t0) = self.coll.death_time() {
            crate::obs::record_between("ft_detect", t0, now);
        }
        let driver = self.n_workers;
        {
            let _sp = crate::obs::span("ft_reshard");
            if let Some(old) = self.handles[rank].take() {
                // the old thread may still be blocked in recv; it exits
                // on its idle window or the shutdown poison — parking it
                // keeps healing latency off the decode path
                self.retired.push(old);
            }
            self.coll.revive(rank);
            self.spawn_worker(rank, round);
        }
        let chunk = disp_slab[(ex * c + lo) * g.m..(ex * c + hi) * g.m].to_vec();
        // replace-send: must reach the replacement even under a drop
        // plan, and must overwrite a delayed copy of the original
        self.coll.send_replace(driver, rank, round, data_msg(&chunk));
        match self.coll.recv(rank, driver, round) {
            Ok(v) => v,
            Err(_) => {
                // replacement failed too: serve the rows on the driver
                let l = (round as usize) % self.l_blocks;
                let (m, hdim) = self.geo_mh;
                let rows = hi - lo;
                let mut out = vec![0.0f32; rows * m];
                let _sp = crate::obs::span("expert_fwd");
                kn::expert_ffn_into(&chunk, &self.w1[ex][l], &self.w2[ex][l], &mut out, 1, rows, m, hdim);
                out
            }
        }
    }

    /// Stop all workers ([`MSG_SHUTDOWN`]-tagged sentinel at the next
    /// round) and join them. Idempotent.
    pub fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        let driver = self.n_workers;
        for rank in 0..self.n_workers {
            // replace-send: the sentinel must get through the injector
            self.coll.send_replace(driver, rank, self.round, vec![MSG_SHUTDOWN]);
        }
        for hd in self.handles.iter_mut().filter_map(Option::take) {
            let _ = hd.join();
        }
        // release retired threads still blocked on the collective
        self.coll.poison();
        for hd in self.retired.drain(..) {
            let _ = hd.join();
        }
    }
}

impl Drop for EpExperts {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_gives_every_expert_one_worker() {
        let plan = plan_replicas(4, 4, &[10, 0, 5, 1], 16);
        assert_eq!(plan, vec![vec![0], vec![1], vec![2], vec![3]]);
    }

    #[test]
    fn spares_replicate_hottest_first() {
        let plan = plan_replicas(4, 6, &[5, 90, 20, 20], 16);
        // hotness order: 1 (90), 2 (20, smaller id wins tie), 3, 0
        assert_eq!(plan[1].len(), 2, "hottest expert gets the first spare");
        assert_eq!(plan[2].len(), 2, "next hottest gets the second");
        assert_eq!(plan[0].len(), 1);
        assert_eq!(plan[3].len(), 1);
        let total: usize = plan.iter().map(Vec::len).sum();
        assert_eq!(total, 6);
        // ranks are contiguous and unique
        let mut all: Vec<usize> = plan.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn replicas_capped_at_capacity_rows() {
        // cap 2: with 4 experts and 100 workers only 8 are ever useful
        let plan = plan_replicas(4, 100, &[1, 1, 1, 1], 2);
        let total: usize = plan.iter().map(Vec::len).sum();
        assert_eq!(total, 8);
        assert!(plan.iter().all(|r| r.len() == 2));
    }

    /// Regression: a replica whose fixed row split is empty this round
    /// (more replicas of an expert than this call's capacity rows) must
    /// stay alive and keep serving later rounds. Before the control tag
    /// was added, the empty data payload looked exactly like the
    /// shutdown sentinel: the replica exited mid-serve, the driver's
    /// recv timed out, and a heal/respawn fired on a perfectly healthy
    /// round.
    #[test]
    fn empty_row_split_is_not_a_shutdown() {
        let g = Geo { m: 4, e: 1, h: 8, top_k: 1, n_heads: 1, n_seq: 2, f: 1.0, vocab: 16 };
        let l_blocks = 1usize;
        let params = crate::serve::init_params(&g, l_blocks, 7);
        // plan capacity 4 admits up to 4 replicas; 3 workers => expert 0
        // gets 3 replicas. Serving with per-call c = 2 then makes
        // replica 2's chunk_range(2, 3, 2) empty — the bug trigger.
        let plan_c = 4usize;
        let c = 2usize;
        let mut cluster =
            EpExperts::with_fault(&g, &params, &[10], 3, plan_c, None, 1_500);
        assert_eq!(cluster.replica_counts(), vec![3]);
        let t = 2usize; // tokens this round, both routed to expert 0
        let u: Vec<f32> = (0..t * g.m).map(|i| (i as f32) * 0.25 - 1.0).collect();
        let hres: Vec<f32> = (0..t * g.m).map(|i| (i as f32) * 0.1).collect();
        let gating = kn::Gating {
            probs: vec![1.0; t],
            idx: vec![0, 0],
            gate: vec![1.0, 1.0],
        };
        // local reference: same routing, same kernel, same weights
        let reference = {
            let routing = dispatch(&u, &gating.idx, gating.gate.len(), g.e, c, g.m);
            let mut expert_out = vec![0.0f32; g.e * c * g.m];
            kn::expert_ffn_into(&routing.disp, &params[8], &params[9], &mut expert_out, 1, c, g.m, g.h);
            let yc = combine(&expert_out, &routing, &gating.gate);
            hres.iter().zip(&yc).map(|(a, b)| a + b).collect::<Vec<f32>>()
        };
        let mut ws = Workspace::new();
        // two rounds: the empty-split replica must survive round 0 for
        // round 1 to complete without a detection timeout
        for round in 0..2 {
            let y = cluster.moe_step(&g, &hres, &u, &gating, c, &mut ws);
            assert_eq!(y, reference, "round {round}: EP output must match local decode bitwise");
            ws.put(y);
        }
        assert_eq!(cluster.respawns(), 0, "no healthy replica may be mistaken for dead");
        cluster.shutdown(); // must join all three replicas cleanly
    }

    #[test]
    fn chunk_ranges_tile_the_capacity() {
        for c in [1usize, 5, 16] {
            for r in 1..=c {
                let mut next = 0;
                for i in 0..r {
                    let (lo, hi) = chunk_range(c, r, i);
                    assert_eq!(lo, next);
                    assert!(hi > lo, "every replica gets at least one row");
                    next = hi;
                }
                assert_eq!(next, c);
            }
        }
    }
}
