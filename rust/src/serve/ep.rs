//! Expert-parallel serving cluster: attention stays on the driver,
//! expert FFNs run on dedicated expert workers.
//!
//! Serving splits the model the way Expert Kit does (SNIPPETS.md §3):
//! the attention/gating half of every layer runs where the KV caches
//! live (the driver), while expert FFNs scale out at **individual
//! expert granularity** — each worker owns at most one expert's
//! weights. Spare workers replicate the hottest experts (ranked by the
//! routing counts the decoder observed during local warmup), and a
//! replicated expert's capacity rows are split near-evenly across its
//! replicas.
//!
//! Every worker receives exactly one message per (layer, step) round —
//! its fixed row range of its expert's `(c, M)` dispatch slab — so the
//! protocol never blocks on an unselected replica, message sizes are
//! step-invariant (capacity is fixed per run, see
//! [`super::decode::serve_capacity`]), and because the row split is
//! fixed and row outputs are independent of band composition (the same
//! contract the kernel conformance suite pins across thread budgets),
//! EP output is **bitwise identical** to local decode.
//!
//! A2A exchanges are traced as `a2a_dispatch` / `a2a_combine` spans and
//! worker FFNs as `expert_fwd`, so `flowmoe serve --trace` renders in
//! the same Comm/Compute lanes as the trainer.

use std::sync::Arc;
use std::thread;

use crate::backend::kernels as kn;
use crate::backend::model::Geo;
use crate::backend::Workspace;
use crate::cluster::{combine, dispatch};
use crate::commpool::Collective;

/// Assign experts to worker ranks: every expert gets one worker, then
/// spare workers replicate the hottest experts (by observed routing
/// `counts`, ties to the smaller expert id), round-robin, capped at
/// `cap` replicas per expert (more replicas than capacity rows would
/// idle). Returns `assignment[e] = worker ranks serving expert e`;
/// ranks are contiguous from 0 in expert-major order.
pub fn plan_replicas(e: usize, workers: usize, counts: &[u64], cap: usize) -> Vec<Vec<usize>> {
    debug_assert_eq!(counts.len(), e);
    let workers = workers.max(e);
    let mut replicas = vec![1usize; e];
    let mut spare = workers - e;
    let mut order: Vec<usize> = (0..e).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(counts[i]), i));
    'outer: while spare > 0 {
        let mut grew = false;
        for &i in &order {
            if spare == 0 {
                break 'outer;
            }
            if replicas[i] < cap {
                replicas[i] += 1;
                spare -= 1;
                grew = true;
            }
        }
        if !grew {
            break; // every expert already at cap; leave the rest unspawned
        }
    }
    let mut assignment = Vec::with_capacity(e);
    let mut rank = 0usize;
    for r in replicas {
        assignment.push((rank..rank + r).collect());
        rank += r;
    }
    assignment
}

/// Row range `[lo, hi)` of replica `i` of `r` when `c` capacity rows
/// are split near-evenly (first `c % r` replicas get one extra row).
fn chunk_range(c: usize, r: usize, i: usize) -> (usize, usize) {
    let (base, rem) = (c / r, c % r);
    let lo = i * base + i.min(rem);
    (lo, lo + base + usize::from(i < rem))
}

/// Expert worker loop: one (layer, step) round per message. An empty
/// message is the shutdown sentinel.
fn expert_worker(
    coll: Arc<Collective>,
    rank: usize,
    driver: usize,
    l_blocks: usize,
    geo_mh: (usize, usize),
    w1: Vec<Vec<f32>>,
    w2: Vec<Vec<f32>>,
) {
    let (m, h) = geo_mh;
    let mut round: u64 = 0;
    loop {
        let chunk = coll.recv(driver, rank, round);
        if chunk.is_empty() {
            return;
        }
        // the driver issues layers 0..L in order every step, so the
        // layer is implied by the round counter
        let l = (round as usize) % l_blocks;
        let rows = chunk.len() / m;
        let mut out = vec![0.0f32; rows * m];
        {
            let _sp = crate::obs::span("expert_fwd");
            kn::expert_ffn_into(&chunk, &w1[l], &w2[l], &mut out, 1, rows, m, h);
        }
        coll.send(rank, driver, round, out);
        round += 1;
    }
}

/// Handle to a running expert-parallel serving cluster.
pub struct EpExperts {
    coll: Arc<Collective>,
    handles: Vec<thread::JoinHandle<()>>,
    /// `assignment[e]` = worker ranks serving expert `e`.
    assignment: Vec<Vec<usize>>,
    n_workers: usize,
    round: u64,
    shut: bool,
}

impl EpExperts {
    /// Spawn expert workers per [`plan_replicas`] over the observed
    /// routing `counts`. Each worker clones only its own expert's
    /// per-layer FFN weights out of the canonical flat `params`.
    pub fn new(g: &Geo, params: &[Vec<f32>], counts: &[u64], workers: usize, c: usize) -> EpExperts {
        let l_blocks = (params.len() - 2) / 9;
        let assignment = plan_replicas(g.e, workers, counts, c);
        let n_workers: usize = assignment.iter().map(Vec::len).sum();
        let coll = Collective::new(n_workers + 1);
        let driver = n_workers;
        let (m, h) = (g.m, g.h);
        let disp = kn::active_dispatch();
        let mut handles = Vec::with_capacity(n_workers);
        for (ex, ranks) in assignment.iter().enumerate() {
            for &rank in ranks {
                let coll = Arc::clone(&coll);
                let w1: Vec<Vec<f32>> = (0..l_blocks)
                    .map(|l| params[1 + l * 9 + 7][ex * m * h..(ex + 1) * m * h].to_vec())
                    .collect();
                let w2: Vec<Vec<f32>> = (0..l_blocks)
                    .map(|l| params[1 + l * 9 + 8][ex * h * m..(ex + 1) * h * m].to_vec())
                    .collect();
                // flowmoe-lint: allow(thread_spawn) — long-lived expert worker, not a task
                handles.push(thread::spawn(move || {
                    kn::with_dispatch(disp, || {
                        crate::sweep::scope::with_budget(1, || {
                            expert_worker(coll, rank, driver, l_blocks, (m, h), w1, w2)
                        })
                    })
                }));
            }
        }
        EpExperts {
            coll,
            handles,
            assignment,
            n_workers,
            round: 0,
            shut: false,
        }
    }

    /// Replica count per expert (for the bench report header).
    pub fn replica_counts(&self) -> Vec<usize> {
        self.assignment.iter().map(Vec::len).collect()
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// One MoE sublayer over the cluster: route on the driver, ship
    /// each expert's capacity rows to its replicas (A2A dispatch), run
    /// the FFNs remotely, gather (A2A combine), then combine + residual
    /// exactly like the local path.
    pub fn moe_step(
        &mut self,
        g: &Geo,
        h: &[f32],
        u: &[f32],
        gating: &kn::Gating,
        c: usize,
        ws: &mut Workspace,
    ) -> Vec<f32> {
        let driver = self.n_workers;
        let routing = dispatch(u, &gating.idx, gating.gate.len(), g.e, c, g.m);
        let round = self.round;
        self.round += 1;
        {
            let _sp = crate::obs::span("a2a_dispatch");
            for (ex, ranks) in self.assignment.iter().enumerate() {
                for (ri, &rank) in ranks.iter().enumerate() {
                    let (lo, hi) = chunk_range(c, ranks.len(), ri);
                    let chunk = routing.disp[(ex * c + lo) * g.m..(ex * c + hi) * g.m].to_vec();
                    self.coll.send(driver, rank, round, chunk);
                }
            }
        }
        let mut expert_out = ws.take(g.e * c * g.m);
        {
            let _sp = crate::obs::span("a2a_combine");
            for (ex, ranks) in self.assignment.iter().enumerate() {
                for (ri, &rank) in ranks.iter().enumerate() {
                    let (lo, _hi) = chunk_range(c, ranks.len(), ri);
                    let out = self.coll.recv(rank, driver, round);
                    expert_out[(ex * c + lo) * g.m..(ex * c + lo) * g.m + out.len()].copy_from_slice(&out);
                }
            }
        }
        let yc = combine(&expert_out, &routing, &gating.gate);
        let mut y = ws.take(h.len());
        for ((yv, &hv), &cv) in y.iter_mut().zip(h).zip(&yc) {
            *yv = hv + cv;
        }
        ws.put_all([routing.disp, expert_out, yc]);
        y
    }

    /// Stop all workers (empty-message sentinel at the next round) and
    /// join them. Idempotent.
    pub fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        let driver = self.n_workers;
        for rank in 0..self.n_workers {
            self.coll.send(driver, rank, self.round, Vec::new());
        }
        for hd in self.handles.drain(..) {
            let _ = hd.join();
        }
    }
}

impl Drop for EpExperts {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_gives_every_expert_one_worker() {
        let plan = plan_replicas(4, 4, &[10, 0, 5, 1], 16);
        assert_eq!(plan, vec![vec![0], vec![1], vec![2], vec![3]]);
    }

    #[test]
    fn spares_replicate_hottest_first() {
        let plan = plan_replicas(4, 6, &[5, 90, 20, 20], 16);
        // hotness order: 1 (90), 2 (20, smaller id wins tie), 3, 0
        assert_eq!(plan[1].len(), 2, "hottest expert gets the first spare");
        assert_eq!(plan[2].len(), 2, "next hottest gets the second");
        assert_eq!(plan[0].len(), 1);
        assert_eq!(plan[3].len(), 1);
        let total: usize = plan.iter().map(Vec::len).sum();
        assert_eq!(total, 6);
        // ranks are contiguous and unique
        let mut all: Vec<usize> = plan.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn replicas_capped_at_capacity_rows() {
        // cap 2: with 4 experts and 100 workers only 8 are ever useful
        let plan = plan_replicas(4, 100, &[1, 1, 1, 1], 2);
        let total: usize = plan.iter().map(Vec::len).sum();
        assert_eq!(total, 8);
        assert!(plan.iter().all(|r| r.len() == 2));
    }

    #[test]
    fn chunk_ranges_tile_the_capacity() {
        for c in [1usize, 5, 16] {
            for r in 1..=c {
                let mut next = 0;
                for i in 0..r {
                    let (lo, hi) = chunk_range(c, r, i);
                    assert_eq!(lo, next);
                    assert!(hi > lo, "every replica gets at least one row");
                    next = hi;
                }
                assert_eq!(next, c);
            }
        }
    }
}
