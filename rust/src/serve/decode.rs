//! Incremental (KV-cached) decode on the native backend.
//!
//! One [`Decoder::decode_step`] call feeds **one token per in-flight
//! sequence** through the whole model: cached multi-head attention over
//! each sequence's prefix, then the same gating head, routing and
//! expert FFN the trainer runs — shared with `model.rs` via
//! [`model::gate_forward_ws`] / [`model::moe_forward_ws`] rather than
//! duplicated — and finally the tied LM head
//! ([`model::lm_head_logits_ws`]).
//!
//! # Why cached decode matches full-prefix recompute
//!
//! Every non-attention op is row-independent, and the single-row
//! attention here follows exactly the last-row recipe of
//! [`kn::attention_causal`] (scores over the prefix, scale, softmax,
//! weighted V gather) — the causal mask never touches the last row. So
//! with drop-free capacity, decoding token `t` against the cache equals
//! row `t` of a full `block_forward` over the whole prefix to fp
//! tolerance; `tests/serve_decode.rs` pins this at every step.
//!
//! Batching is ragged: token-level ops run as a flat `(D, M)` batch
//! over the D active sequences while attention fans out per
//! `(sequence, head)` unit over each sequence's own prefix length, on
//! the same [`scope`] thread budget (and with the same capture-the-
//! dispatch-tier idiom) as the trainer's per-head loops.

use crate::backend::kernels as kn;
use crate::backend::model::{self, BlockParams, Geo};
use crate::backend::Workspace;
use crate::sweep::scope;
use crate::util::Rng;

use super::ep::EpExperts;
use super::kv::KvCache;

/// How each decode step's expert FFNs execute.
pub enum ExpertBackend {
    /// In-process, over the local expert weights.
    Local,
    /// On the expert-parallel serving cluster (see [`super::ep`]).
    Ep(EpExperts),
}

/// Per-expert slot capacity of a decode step over `d` single-token rows:
/// GShard `ceil(f * k * d / E)`, at least 1. (The trainer's
/// [`Geo::capacity`] counts `b * N` tokens; a decode step has exactly
/// `d`.) Sized once for the maximum batch so slab shapes — and the EP
/// message sizes — are step-invariant.
pub fn serve_capacity(g: &Geo, d: usize) -> usize {
    ((g.f * (g.top_k * d) as f64 / g.e as f64).ceil() as usize).max(1)
}

/// Deterministic model init in the canonical flat parameter order
/// (embed, L x 9 block tensors, normf): unit norm gains, fan-in-scaled
/// normals elsewhere — the trainer's init recipe, reproduced from a
/// seed so `serve --synthetic` needs no checkpoint.
pub fn init_params(g: &Geo, l_blocks: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    // (elems, fan_in) per tensor; fan_in 0 marks a norm gain (init 1.0)
    let mut shapes: Vec<(usize, usize)> = vec![(g.vocab * g.m, g.vocab)];
    for _ in 0..l_blocks {
        shapes.extend([
            (g.m, 0),
            (g.m * g.m, g.m),
            (g.m * g.m, g.m),
            (g.m * g.m, g.m),
            (g.m * g.m, g.m),
            (g.m, 0),
            (g.m * g.e, g.m),
            (g.e * g.m * g.h, g.m),
            (g.e * g.h * g.m, g.h),
        ]);
    }
    shapes.push((g.m, 0));
    shapes
        .iter()
        .map(|&(n, fan_in)| {
            if fan_in == 0 {
                vec![1.0f32; n]
            } else {
                let s = (fan_in as f64).powf(-0.5);
                (0..n).map(|_| (rng.normal() * s) as f32).collect()
            }
        })
        .collect()
}

/// Greedy next-token choice per `(D, vocab)` logits row, ties to the
/// smaller index (the same tie rule as `gating_topk`) — deterministic
/// sampling for the synthetic server.
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<i32> {
    logits
        .chunks_exact(vocab)
        .map(|row| {
            let mut best = 0usize;
            for (j, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = j;
                }
            }
            best as i32
        })
        .collect()
}

/// Copy head `hh` out of flat `(T, M)` rows into a contiguous `(T, hd)`
/// tile (the cached-prefix analogue of `model.rs`'s `gather_head`).
fn gather_head_rows(xf: &[f32], t: usize, m: usize, hh: usize, hd: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * hd];
    for i in 0..t {
        let src = i * m + hh * hd;
        out[i * hd..(i + 1) * hd].copy_from_slice(&xf[src..src + hd]);
    }
    out
}

/// Incremental decoder: model parameters + workspace + expert backend.
pub struct Decoder {
    pub geo: Geo,
    params: Vec<Vec<f32>>,
    l_blocks: usize,
    /// Fixed per-expert slot capacity of every decode step.
    c: usize,
    ws: Workspace,
    backend: ExpertBackend,
    /// Observed routing assignments per expert (drives the EP cluster's
    /// hot-expert replication plan).
    pub expert_counts: Vec<u64>,
}

impl Decoder {
    /// A local-expert decoder sized for decode batches up to `max_batch`.
    pub fn new(geo: Geo, params: Vec<Vec<f32>>, max_batch: usize) -> Decoder {
        let l_blocks = (params.len() - 2) / 9;
        debug_assert_eq!(params.len(), 2 + l_blocks * 9);
        let c = serve_capacity(&geo, max_batch.max(1));
        let expert_counts = vec![0u64; geo.e];
        Decoder {
            geo,
            params,
            l_blocks,
            c,
            ws: Workspace::new(),
            backend: ExpertBackend::Local,
            expert_counts,
        }
    }

    pub fn l_blocks(&self) -> usize {
        self.l_blocks
    }

    /// The step-invariant per-expert slot capacity.
    pub fn capacity(&self) -> usize {
        self.c
    }

    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// The workspace pool (KV slabs are taken from / retired to it so
    /// caches and decode temporaries share one arena).
    pub fn workspace(&mut self) -> &mut Workspace {
        &mut self.ws
    }

    /// Swap the expert backend (e.g. local -> EP cluster after warmup).
    /// Returns the previous backend so a cluster can be shut down.
    pub fn set_backend(&mut self, backend: ExpertBackend) -> ExpertBackend {
        std::mem::replace(&mut self.backend, backend)
    }

    /// Decode one token per sequence: `tokens[i]` extends `caches[i]`.
    /// Returns the next-token logits, flat `(D, vocab)`, taken from the
    /// workspace pool (retire with `workspace().put(..)` when done).
    pub fn decode_logits(&mut self, tokens: &[i32], caches: &mut [&mut KvCache]) -> Vec<f32> {
        let _sp = crate::obs::span("decode_step");
        let Decoder {
            geo: g,
            params,
            l_blocks,
            c,
            ws,
            backend,
            expert_counts,
        } = self;
        let d = tokens.len();
        debug_assert_eq!(d, caches.len());
        let (m, hd, n_heads) = (g.m, g.head_dim(), g.n_heads);
        let mut x = ws.take(d * m);
        kn::embed_lookup_into(&params[0], tokens, m, &mut x);
        for l in 0..*l_blocks {
            let refs: Vec<&[f32]> = params[1 + l * 9..1 + (l + 1) * 9].iter().map(|v| v.as_slice()).collect();
            let bp = BlockParams::new(&refs);
            // --- cached MHA: project the new rows, append K/V, attend
            // over each sequence's prefix ---
            let h = {
                let _sp = crate::obs::span("decode_mha");
                let mut xn = ws.take(d * m);
                kn::rmsnorm_into(&x, bp.at.n1, &mut xn);
                let mut qf = ws.take(d * m);
                kn::par_matmul_into(&xn, bp.at.wq, &mut qf, d, m, m);
                let mut kf = ws.take(d * m);
                kn::par_matmul_into(&xn, bp.at.wk, &mut kf, d, m, m);
                let mut vf = ws.take(d * m);
                kn::par_matmul_into(&xn, bp.at.wv, &mut vf, d, m, m);
                for (i, cache) in caches.iter_mut().enumerate() {
                    cache.append(l, &kf[i * m..(i + 1) * m], &vf[i * m..(i + 1) * m]);
                }
                // ragged per-(sequence, head) attention over the cached
                // prefixes; immutable views gathered up front so the
                // fan-out closure borrows them Sync-ly
                let views: Vec<(usize, &[f32], &[f32])> = caches
                    .iter()
                    .map(|cc| (cc.len() + 1, cc.k_with_pending(l), cc.v_with_pending(l)))
                    .collect();
                let units = d * n_heads;
                let disp = kn::active_dispatch();
                let qf_ref: &[f32] = &qf;
                let head = |u: usize| {
                    kn::with_dispatch(disp, || {
                        let (di, hh) = (u / n_heads, u % n_heads);
                        let (t_i, kc, vc) = views[di];
                        let q = &qf_ref[di * m + hh * hd..di * m + (hh + 1) * hd];
                        let kh = gather_head_rows(kc, t_i, m, hh, hd);
                        let vh = gather_head_rows(vc, t_i, m, hh, hd);
                        // last-row recipe of `kn::attention_causal`: the
                        // newest query attends to every cached position,
                        // so no mask is needed
                        let scale = 1.0 / (hd as f64).sqrt() as f32;
                        let mut s = kn::matmul_nt(q, &kh, 1, hd, t_i);
                        for sv in s.iter_mut() {
                            *sv *= scale;
                        }
                        let w = kn::softmax_rows(&s, t_i);
                        kn::matmul(&w, &vh, 1, t_i, hd)
                    })
                };
                let heads: Vec<Vec<f32>> = scope::par_map_vec(units, head);
                let mut of = ws.take(d * m);
                for (u, o) in heads.into_iter().enumerate() {
                    let (di, hh) = (u / n_heads, u % n_heads);
                    of[di * m + hh * hd..di * m + (hh + 1) * hd].copy_from_slice(&o);
                }
                let mut proj = ws.take(d * m);
                kn::par_matmul_into(&of, bp.at.wo, &mut proj, d, m, m);
                let mut h = ws.take(d * m);
                for ((hv, &xv), &pv) in h.iter_mut().zip(x.iter()).zip(&proj) {
                    *hv = xv + pv;
                }
                ws.put_all([xn, qf, kf, vf, of, proj]);
                h
            };
            // --- gating + expert FFN + combine: the trainer's own code ---
            let (u, gating) = model::gate_forward_ws(g, &bp.at, &h, ws);
            for &ex in &gating.idx {
                expert_counts[ex as usize] += 1;
            }
            let y = match backend {
                ExpertBackend::Local => {
                    let (y, routing, expert_out) = model::moe_forward_ws(g, bp.w1, bp.w2, &h, &u, &gating, *c, ws);
                    ws.put_all([routing.disp, expert_out]);
                    y
                }
                ExpertBackend::Ep(cluster) => cluster.moe_step(g, &h, &u, &gating, *c, ws),
            };
            ws.put_all([h, u, gating.probs, gating.gate]);
            ws.put(std::mem::replace(&mut x, y));
        }
        for cache in caches.iter_mut() {
            cache.advance();
        }
        let logits = model::lm_head_logits_ws(g, &params[0], &params[params.len() - 1], &x, ws);
        ws.put(x);
        logits
    }

    /// [`Decoder::decode_logits`] + greedy sampling: the next token per
    /// sequence.
    pub fn decode_step(&mut self, tokens: &[i32], caches: &mut [&mut KvCache]) -> Vec<i32> {
        let logits = self.decode_logits(tokens, caches);
        let next = argmax_rows(&logits, self.geo.vocab);
        self.ws.put(logits);
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn tiny_geo() -> Geo {
        match preset("tiny") {
            Some(cfg) => Geo::from_cfg(&cfg),
            None => unreachable!("tiny preset always exists"),
        }
    }

    #[test]
    fn serve_capacity_scales_with_batch() {
        let g = tiny_geo(); // f=4, k=2, E=4
        assert_eq!(serve_capacity(&g, 1), 2);
        assert_eq!(serve_capacity(&g, 8), 16);
        // drop-free for any routing: d tokens can all pick one expert
        for d in 1..=16 {
            assert!(serve_capacity(&g, d) >= d);
        }
    }

    #[test]
    fn init_params_shapes_and_gains() {
        let g = tiny_geo();
        let p = init_params(&g, 2, 7);
        assert_eq!(p.len(), 2 + 2 * 9);
        assert_eq!(p[0].len(), g.vocab * g.m);
        assert!(p[1].iter().all(|&x| x == 1.0), "n1 is a unit gain");
        assert!(p[2].iter().any(|&x| x != 0.0), "wq is random");
        assert_eq!(init_params(&g, 2, 7)[2], p[2], "seeded init is deterministic");
    }

    #[test]
    fn argmax_rows_ties_to_smaller_index() {
        let logits = [0.1, 0.9, 0.9, 0.2, /* row 2 */ 0.5, 0.5, 0.4, 0.3];
        assert_eq!(argmax_rows(&logits, 4), vec![1, 0]);
    }

    #[test]
    fn decode_step_is_deterministic() {
        let g = tiny_geo();
        let params = init_params(&g, 2, 3);
        let run = |params: Vec<Vec<f32>>| {
            let mut dec = Decoder::new(g, params, 2);
            let mut ca = KvCache::new(2, 8, g.m, dec.workspace());
            let mut cb = KvCache::new(2, 8, g.m, dec.workspace());
            let mut out = Vec::new();
            let mut toks = vec![5i32, 9i32];
            for _ in 0..6 {
                let mut refs = [&mut ca, &mut cb];
                let next = dec.decode_step(&toks, &mut refs);
                out.extend(next.iter().copied());
                toks = next;
            }
            out
        };
        assert_eq!(run(params.clone()), run(params));
    }
}
