//! `flowmoe serve` — continuous-batching MoE inference on the native
//! backend.
//!
//! Four layers (bottom up):
//!
//! 1. **Incremental decode** ([`kv`], [`decode`]): per-sequence
//!    append-only KV caches backed by the shared workspace pool,
//!    and a [`Decoder`] that runs cached attention + gating + expert
//!    FFN for one new token per sequence, sharing the trainer's
//!    `model.rs` forward code.
//! 2. **Continuous batching** ([`sched`]): FIFO admission against a
//!    max-batch and a KV-token budget; finished sequences retire
//!    mid-flight and their slot + budget refill immediately.
//! 3. **Expert-parallel serving** ([`ep`]): attention on the driver,
//!    ≤ 1 expert per worker, hottest experts replicated from routing
//!    counts observed during a local warmup; A2A traced like the
//!    trainer. EP decode is bitwise identical to local decode.
//! 4. **Synthetic traffic + bench** ([`traffic`], [`run_synthetic`]):
//!    seeded open-loop Poisson/Zipf load in virtual step time, p50/p99
//!    per-token and per-request latency + tokens/sec through the
//!    [`Registry`] histogram machinery, exported as `BENCH_serve.json`
//!    whose non-timing fields are deterministic per seed.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::backend::kernels as kn;
use crate::backend::model::Geo;
use crate::config::preset;
use crate::obs::{Registry, RegistrySnapshot};
use crate::sweep::scope;
use crate::util::{json_escape, percentile};

pub mod decode;
pub mod ep;
pub mod kv;
pub mod sched;
pub mod traffic;

pub use decode::{argmax_rows, init_params, serve_capacity, Decoder, ExpertBackend};
pub use ep::EpExperts;
pub use kv::KvCache;
pub use sched::{Request, Scheduler};
pub use traffic::TrafficCfg;

/// Default decode batch width (sequences decoded per step).
pub const DEFAULT_MAX_BATCH: usize = 8;
/// Default KV budget: total cached tokens across all in-flight
/// sequences (each admission reserves its worst case up front).
pub const DEFAULT_KV_BUDGET: usize = 4096;

/// Knobs of one `flowmoe serve --synthetic` run.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub config: String,
    pub seed: u64,
    pub requests: usize,
    pub max_batch: usize,
    pub kv_budget: usize,
    /// Expert workers for the EP phase; `None` = auto (`E + 2`),
    /// `Some(0)` = stay on the local backend for the whole run.
    pub workers: Option<usize>,
    /// Decode steps served locally before switching to EP (the routing
    /// counts observed here drive hot-expert replication).
    pub warmup_steps: u64,
    pub mean_gap_steps: f64,
    pub max_prompt: usize,
    pub max_new: usize,
}

impl ServeOpts {
    pub fn new(config: &str) -> ServeOpts {
        ServeOpts {
            config: config.to_string(),
            seed: 7,
            requests: 200,
            max_batch: DEFAULT_MAX_BATCH,
            kv_budget: DEFAULT_KV_BUDGET,
            workers: None,
            warmup_steps: 16,
            mean_gap_steps: 2.0,
            max_prompt: 24,
            max_new: 16,
        }
    }
}

/// Outcome of a serving run. Everything except `wall_s`,
/// `tokens_per_s`, the `*_ms_*` latencies and `stats` is a pure
/// function of the options (virtual-step-time scheduling), which is
/// what the determinism test pins.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub steps: u64,
    pub admitted: u64,
    pub finished: u64,
    pub prefill_tokens: u64,
    pub generated_tokens: u64,
    /// FNV-style rolling hash over emitted tokens in step order.
    pub token_checksum: u64,
    pub capacity: usize,
    pub workers_used: usize,
    /// Replicas per expert in the EP phase (empty when local-only).
    pub replicas: Vec<usize>,
    pub req_latency_steps_p50: f64,
    pub req_latency_steps_p99: f64,
    pub queue_wait_steps_p50: f64,
    pub queue_wait_steps_p99: f64,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    pub token_ms_p50: f64,
    pub token_ms_p99: f64,
    pub req_ms_p50: f64,
    pub req_ms_p99: f64,
    pub stats: RegistrySnapshot,
}

fn pct(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs, p)
    }
}

/// Drive the decoder with synthetic open-loop traffic to completion.
pub fn run_synthetic(opts: &ServeOpts) -> Result<ServeReport> {
    let Some(cfg) = preset(&opts.config) else {
        bail!("unknown config '{}'", opts.config);
    };
    if opts.max_batch == 0 {
        bail!("--max-batch must be >= 1");
    }
    if opts.max_prompt + opts.max_new > opts.kv_budget {
        bail!(
            "kv budget {} cannot hold one worst-case request ({} prompt + {} new)",
            opts.kv_budget,
            opts.max_prompt,
            opts.max_new
        );
    }
    let g = Geo::from_cfg(&cfg);
    let l_blocks = cfg.l;
    let reqs = traffic::generate(
        opts.seed,
        &TrafficCfg {
            requests: opts.requests,
            mean_gap_steps: opts.mean_gap_steps,
            max_prompt: opts.max_prompt,
            max_new: opts.max_new,
            len_zipf_s: 1.2,
            vocab: g.vocab,
        },
    );
    let params = init_params(&g, l_blocks, opts.seed ^ 0x5eed);
    let mut dec = Decoder::new(g, params, opts.max_batch);
    let mut sched = Scheduler::new(opts.max_batch, opts.kv_budget);
    let mut caches: Vec<Option<KvCache>> = (0..opts.max_batch).map(|_| None).collect();
    let mut admit_wall: Vec<Option<Instant>> = vec![None; opts.max_batch];

    let reg = Registry::new();
    let step_hist = reg.histogram("serve/step_s");
    let token_hist = reg.histogram("serve/token_s");
    let req_hist = reg.histogram("serve/req_s");

    let workers_requested = opts.workers.unwrap_or(g.e + 2);
    let mut ep_started = false;
    let mut workers_used = 0usize;
    let mut replicas: Vec<usize> = Vec::new();

    let mut next_req = 0usize;
    let mut step: u64 = 0;
    let (mut prefill_tokens, mut generated_tokens) = (0u64, 0u64);
    let mut token_checksum: u64 = 0xcbf2_9ce4_8422_2325;
    let mut req_latency_steps: Vec<f64> = Vec::new();
    let mut queue_wait_steps: Vec<f64> = Vec::new();
    let t0 = Instant::now();

    loop {
        while next_req < reqs.len() && reqs[next_req].arrival_step <= step {
            sched.push(reqs[next_req].clone());
            next_req += 1;
        }
        for slot in sched.admit(step) {
            let need = sched.slot_kv_need(slot);
            caches[slot] = Some(KvCache::new(l_blocks, need, g.m, dec.workspace()));
            admit_wall[slot] = Some(Instant::now());
        }
        if !ep_started && step >= opts.warmup_steps && workers_requested > 0 {
            let ep = EpExperts::new(&g, dec.params(), &dec.expert_counts, workers_requested, dec.capacity());
            replicas = ep.replica_counts();
            workers_used = ep.n_workers();
            dec.set_backend(ExpertBackend::Ep(ep));
            ep_started = true;
        }
        let batch = sched.batch();
        if batch.is_empty() {
            if next_req >= reqs.len() && sched.pending_len() == 0 {
                break;
            }
            // nothing in flight: fast-forward virtual time to the next
            // arrival instead of spinning empty steps
            let upcoming = sched.next_arrival().or_else(|| reqs.get(next_req).map(|r| r.arrival_step));
            match upcoming {
                Some(a) => step = a.max(step + 1),
                None => break,
            }
            continue;
        }
        let tokens: Vec<i32> = batch.iter().map(|&(_, t)| t).collect();
        let step_t = Instant::now();
        let next = {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().filter_map(Option::as_mut).collect();
            debug_assert_eq!(refs.len(), tokens.len());
            dec.decode_step(&tokens, &mut refs)
        };
        let step_el = step_t.elapsed().as_secs_f64();
        step_hist.observe(step_el);
        for (i, &(slot, _)) in batch.iter().enumerate() {
            let (emitted, fin) = sched.record(slot, next[i]);
            if emitted {
                generated_tokens += 1;
                token_checksum = token_checksum.wrapping_mul(0x0100_0000_01b3).wrapping_add(next[i] as u64);
                token_hist.observe(step_el);
            } else {
                prefill_tokens += 1;
            }
            if let Some(fin) = fin {
                if let Some(cache) = caches[slot].take() {
                    cache.free(dec.workspace());
                }
                if let Some(t) = admit_wall[slot].take() {
                    req_hist.observe(t.elapsed().as_secs_f64());
                }
                req_latency_steps.push((step + 1 - fin.arrival_step) as f64);
                queue_wait_steps.push((fin.admit_step - fin.arrival_step) as f64);
            }
        }
        step += 1;
    }

    if let ExpertBackend::Ep(mut ep) = dec.set_backend(ExpertBackend::Local) {
        ep.shutdown();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let total_tokens = prefill_tokens + generated_tokens;
    Ok(ServeReport {
        steps: step,
        admitted: sched.admitted,
        finished: sched.finished,
        prefill_tokens,
        generated_tokens,
        token_checksum,
        capacity: dec.capacity(),
        workers_used,
        replicas,
        req_latency_steps_p50: pct(&req_latency_steps, 50.0),
        req_latency_steps_p99: pct(&req_latency_steps, 99.0),
        queue_wait_steps_p50: pct(&queue_wait_steps, 50.0),
        queue_wait_steps_p99: pct(&queue_wait_steps, 99.0),
        wall_s,
        tokens_per_s: if wall_s > 0.0 { total_tokens as f64 / wall_s } else { 0.0 },
        token_ms_p50: token_hist.quantile(0.50) * 1e3,
        token_ms_p99: token_hist.quantile(0.99) * 1e3,
        req_ms_p50: req_hist.quantile(0.50) * 1e3,
        req_ms_p99: req_hist.quantile(0.99) * 1e3,
        stats: reg.snapshot(),
    })
}

/// Render the bench artifact. The `"deterministic"` object is a pure
/// function of the options; `"timing"` carries wall-clock numbers and
/// is exempt from the determinism check.
pub fn bench_json(opts: &ServeOpts, r: &ServeReport) -> String {
    let replicas = r.replicas.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
    format!(
        concat!(
            "{{\n",
            "  \"bench\": \"serve_synthetic\",\n",
            "  \"config\": \"{config}\",\n",
            "  \"seed\": {seed},\n",
            "  \"requests\": {requests},\n",
            "  \"max_batch\": {max_batch},\n",
            "  \"kv_budget\": {kv_budget},\n",
            "  \"capacity\": {capacity},\n",
            "  \"warmup_steps\": {warmup},\n",
            "  \"workers\": {workers},\n",
            "  \"replicas\": [{replicas}],\n",
            "  \"kernels\": \"{kernels}\",\n",
            "  \"threads\": {threads},\n",
            "  \"avx2\": {avx2},\n",
            "  \"deterministic\": {{\n",
            "    \"steps\": {steps},\n",
            "    \"admitted\": {admitted},\n",
            "    \"finished\": {finished},\n",
            "    \"prefill_tokens\": {prefill},\n",
            "    \"generated_tokens\": {generated},\n",
            "    \"token_checksum\": {checksum},\n",
            "    \"req_latency_steps_p50\": {rl50:.3},\n",
            "    \"req_latency_steps_p99\": {rl99:.3},\n",
            "    \"queue_wait_steps_p50\": {qw50:.3},\n",
            "    \"queue_wait_steps_p99\": {qw99:.3}\n",
            "  }},\n",
            "  \"timing\": {{\n",
            "    \"wall_s\": {wall:.6},\n",
            "    \"tokens_per_s\": {tps:.3},\n",
            "    \"token_ms_p50\": {t50:.6},\n",
            "    \"token_ms_p99\": {t99:.6},\n",
            "    \"req_ms_p50\": {r50:.6},\n",
            "    \"req_ms_p99\": {r99:.6}\n",
            "  }}\n",
            "}}\n"
        ),
        config = json_escape(&opts.config),
        seed = opts.seed,
        requests = opts.requests,
        max_batch = opts.max_batch,
        kv_budget = opts.kv_budget,
        capacity = r.capacity,
        warmup = opts.warmup_steps,
        workers = r.workers_used,
        replicas = replicas,
        kernels = json_escape(kn::default_dispatch().name()),
        threads = scope::current_budget(),
        avx2 = kn::avx2_available(),
        steps = r.steps,
        admitted = r.admitted,
        finished = r.finished,
        prefill = r.prefill_tokens,
        generated = r.generated_tokens,
        checksum = r.token_checksum,
        rl50 = r.req_latency_steps_p50,
        rl99 = r.req_latency_steps_p99,
        qw50 = r.queue_wait_steps_p50,
        qw99 = r.queue_wait_steps_p99,
        wall = r.wall_s,
        tps = r.tokens_per_s,
        t50 = r.token_ms_p50,
        t99 = r.token_ms_p99,
        r50 = r.req_ms_p50,
        r99 = r.req_ms_p99,
    )
}
