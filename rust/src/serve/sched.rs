//! Continuous-batching scheduler: admit, batch, record, evict.
//!
//! Requests queue FIFO; each decode step the scheduler admits as many
//! queued requests as fit (a free batch slot **and** enough KV-token
//! budget for the request's worst case, `prompt + max_new`), assembles
//! the ragged batch — one token per active sequence, either the next
//! prompt token (prefill) or the last generated token (decode) — and
//! retires finished sequences so their slot and KV budget refill
//! mid-flight. Admission is strictly FIFO: if the front request does
//! not fit, nothing behind it is considered, so a large request can
//! never starve behind a stream of small ones.
//!
//! Slots are a plain `Vec<Option<ActiveSeq>>` and the queue a
//! `VecDeque` — no hash maps on this hot path (lint FL003), and batch
//! order (ascending slot id) is deterministic.

use std::collections::VecDeque;

/// One inference request, timed in virtual step time.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Decode step at which the request becomes visible to admission.
    pub arrival_step: u64,
    pub prompt: Vec<i32>,
    /// Tokens to generate after the prompt.
    pub max_new: usize,
}

impl Request {
    /// Worst-case KV rows this request can occupy: every prompt token
    /// plus every generated token is cached. Reserved in full at
    /// admission so an admitted sequence can never stall mid-flight.
    pub fn kv_need(&self) -> usize {
        self.prompt.len() + self.max_new
    }
}

/// A request occupying a batch slot.
#[derive(Clone, Debug)]
pub struct ActiveSeq {
    pub id: u64,
    pub arrival_step: u64,
    pub admit_step: u64,
    pub prompt: Vec<i32>,
    /// Prompt tokens already fed (the cached prefix length during prefill).
    pub pos: usize,
    pub generated: Vec<i32>,
    pub max_new: usize,
}

impl ActiveSeq {
    /// The token this sequence contributes to the current step's batch.
    pub fn next_input(&self) -> i32 {
        if self.pos < self.prompt.len() {
            self.prompt[self.pos]
        } else {
            self.generated[self.generated.len() - 1]
        }
    }

    /// Still feeding prompt tokens (model output is discarded).
    pub fn in_prefill(&self) -> bool {
        self.pos < self.prompt.len()
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.max_new
    }

    fn kv_need(&self) -> usize {
        self.prompt.len() + self.max_new
    }
}

/// Continuous-batching state: fixed slots + FIFO queue + KV budget.
pub struct Scheduler {
    slots: Vec<Option<ActiveSeq>>,
    pending: VecDeque<Request>,
    kv_budget: usize,
    kv_used: usize,
    pub admitted: u64,
    pub finished: u64,
}

impl Scheduler {
    pub fn new(max_batch: usize, kv_budget: usize) -> Scheduler {
        Scheduler {
            slots: (0..max_batch).map(|_| None).collect(),
            pending: VecDeque::new(),
            kv_budget,
            kv_used: 0,
            admitted: 0,
            finished: 0,
        }
    }

    /// Enqueue an arrived request.
    pub fn push(&mut self, req: Request) {
        self.pending.push_back(req);
    }

    /// Admit queued requests into free slots, strictly FIFO, while the
    /// front request fits the KV budget and a slot is free. Returns the
    /// slot index of each admission (callers allocate a KV cache per
    /// returned slot).
    pub fn admit(&mut self, step: u64) -> Vec<usize> {
        let mut admitted = Vec::new();
        loop {
            let Some(req) = self.pending.front() else { break };
            if self.kv_used + req.kv_need() > self.kv_budget {
                break;
            }
            let Some(slot) = self.slots.iter().position(Option::is_none) else {
                break;
            };
            let Some(req) = self.pending.pop_front() else { break };
            self.kv_used += req.kv_need();
            self.admitted += 1;
            self.slots[slot] = Some(ActiveSeq {
                id: req.id,
                arrival_step: req.arrival_step,
                admit_step: step,
                prompt: req.prompt,
                pos: 0,
                generated: Vec::new(),
                max_new: req.max_new,
            });
            admitted.push(slot);
        }
        admitted
    }

    /// The ragged batch for this step: `(slot, input token)` in
    /// ascending slot order, one entry per active sequence.
    pub fn batch(&self) -> Vec<(usize, i32)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|seq| (i, seq.next_input())))
            .collect()
    }

    /// Record the model's output for slot `slot` this step. Returns
    /// `(emitted, finished)`: whether `next` counts as a generated
    /// token (prefill steps discard it), and the retired sequence if
    /// this token completed it (its slot and KV budget are freed).
    pub fn record(&mut self, slot: usize, next: i32) -> (bool, Option<ActiveSeq>) {
        let Some(seq) = self.slots[slot].as_mut() else {
            debug_assert!(false, "record on empty slot {slot}");
            return (false, None);
        };
        seq.pos += 1;
        let emitted = seq.pos >= seq.prompt.len();
        if emitted {
            seq.generated.push(next);
        }
        if seq.done() {
            let Some(seq) = self.slots[slot].take() else {
                return (emitted, None);
            };
            self.kv_used -= seq.kv_need();
            self.finished += 1;
            return (emitted, Some(seq));
        }
        (emitted, None)
    }

    /// Active sequence count (occupied slots).
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Arrival step of the queue's front request, if any — used to
    /// fast-forward virtual time when the system drains empty.
    pub fn next_arrival(&self) -> Option<u64> {
        self.pending.front().map(|r| r.arrival_step)
    }

    /// Reserved KV rows of the sequence occupying `slot` (0 if empty) —
    /// the cache size the engine allocates at admission.
    pub fn slot_kv_need(&self, slot: usize) -> usize {
        self.slots[slot].as_ref().map(ActiveSeq::kv_need).unwrap_or(0)
    }

    pub fn kv_used(&self) -> usize {
        self.kv_used
    }

    pub fn kv_budget(&self) -> usize {
        self.kv_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: u64, plen: usize, max_new: usize) -> Request {
        Request {
            id,
            arrival_step: arrival,
            prompt: (0..plen as i32).collect(),
            max_new,
        }
    }

    /// Run a sequence to completion by feeding a dummy token each step.
    fn drain(s: &mut Scheduler) -> Vec<u64> {
        let mut order = Vec::new();
        for _ in 0..10_000 {
            let batch = s.batch();
            if batch.is_empty() && s.pending_len() == 0 {
                break;
            }
            for (slot, _tok) in batch {
                if let (_, Some(fin)) = s.record(slot, 1) {
                    order.push(fin.id);
                }
            }
            s.admit(0);
        }
        order
    }

    #[test]
    fn admit_is_fifo_and_respects_budget() {
        let mut s = Scheduler::new(4, 20);
        s.push(req(0, 0, 8, 8)); // needs 16
        s.push(req(1, 0, 2, 2)); // needs 4 — fits alongside
        s.push(req(2, 0, 2, 2)); // needs 4 — would fit, but is behind
        let slots = s.admit(0);
        assert_eq!(slots, vec![0, 1]);
        assert_eq!(s.kv_used(), 20);
        // front (id 2) does not fit => nothing admitted, no skipping
        assert_eq!(s.admit(0), Vec::<usize>::new());
        assert_eq!(s.pending_len(), 1);
    }

    #[test]
    fn finish_frees_slot_and_budget_no_leak() {
        let mut s = Scheduler::new(2, 100);
        for i in 0..5 {
            s.push(req(i, 0, 3, 2));
        }
        s.admit(0);
        assert_eq!(s.active(), 2);
        let order = drain(&mut s);
        assert_eq!(order, vec![0, 1, 2, 3, 4], "completion follows FIFO admission");
        assert_eq!(s.active(), 0, "no slot leak");
        assert_eq!(s.kv_used(), 0, "no KV budget leak");
        assert_eq!(s.admitted, 5);
        assert_eq!(s.finished, 5);
    }

    #[test]
    fn evict_on_finish_lets_waiting_request_in() {
        // budget fits exactly one request at a time
        let mut s = Scheduler::new(4, 6);
        s.push(req(0, 0, 3, 3));
        s.push(req(1, 0, 3, 3));
        assert_eq!(s.admit(0), vec![0]);
        assert_eq!(s.admit(0), Vec::<usize>::new(), "second blocked on KV budget");
        // run request 0 to completion: 3 prefill + 3 decode steps
        for _ in 0..6 {
            let batch = s.batch();
            for (slot, _) in batch {
                s.record(slot, 7);
            }
        }
        assert_eq!(s.kv_used(), 0);
        assert_eq!(s.admit(6), vec![0], "freed budget admits the waiter");
    }

    #[test]
    fn prefill_then_decode_token_stream() {
        let mut s = Scheduler::new(1, 10);
        s.push(Request {
            id: 9,
            arrival_step: 0,
            prompt: vec![11, 12, 13],
            max_new: 2,
        });
        s.admit(0);
        // prefill: inputs are prompt tokens; outputs discarded until the
        // last prompt token's output, which is the first generated token
        assert_eq!(s.batch(), vec![(0, 11)]);
        assert!(!s.record(0, 99).0);
        assert_eq!(s.batch(), vec![(0, 12)]);
        assert!(!s.record(0, 99).0);
        assert_eq!(s.batch(), vec![(0, 13)]);
        assert!(s.record(0, 21).0, "last prefill step emits");
        // decode: input is the last generated token
        assert_eq!(s.batch(), vec![(0, 21)]);
        let (emitted, fin) = s.record(0, 22);
        assert!(emitted);
        let Some(fin) = fin else {
            panic!("sequence should finish at max_new=2")
        };
        assert_eq!(fin.generated, vec![21, 22]);
    }
}
