//! Append-only per-sequence KV cache backed by the workspace pool.
//!
//! One [`KvCache`] holds the cached attention keys/values of a single
//! in-flight sequence: per layer, a flat `(cap, M)` slab for K and one
//! for V, of which only the first `len` rows are live. Slabs are taken
//! from [`Workspace`] on admission and retired back into the pool on
//! eviction, so caches recycle across requests exactly like the
//! trainer's activation buffers recycle across steps — and because
//! [`Workspace::take`] hands out zeroed buffers, a recycled cache is
//! bit-identical to a fresh one.

use crate::backend::Workspace;

/// KV cache of one sequence (all layers). See the module docs.
pub struct KvCache {
    /// Per layer: flat `(cap, M)` K rows; rows `[0, len)` are live.
    k: Vec<Vec<f32>>,
    /// Per layer: flat `(cap, M)` V rows, same layout.
    v: Vec<Vec<f32>>,
    len: usize,
    cap: usize,
    m: usize,
}

impl KvCache {
    /// A cache with room for `cap` tokens across `l_blocks` layers;
    /// slabs come zeroed from the workspace pool.
    pub fn new(l_blocks: usize, cap: usize, m: usize, ws: &mut Workspace) -> KvCache {
        let k = (0..l_blocks).map(|_| ws.take(cap * m)).collect();
        let v = (0..l_blocks).map(|_| ws.take(cap * m)).collect();
        KvCache { k, v, len: 0, cap, m }
    }

    /// Tokens fully cached (every layer appended and advanced).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Token capacity reserved for this sequence.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Write the in-flight token's K/V rows (length M each) for layer
    /// `l` at row `len`. Call once per layer, then [`KvCache::advance`]
    /// once after all layers.
    pub fn append(&mut self, l: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(self.len < self.cap, "KV cache overflow ({}/{})", self.len, self.cap);
        let at = self.len * self.m;
        self.k[l][at..at + self.m].copy_from_slice(krow);
        self.v[l][at..at + self.m].copy_from_slice(vrow);
    }

    /// Commit the in-flight token: subsequent appends land on the next row.
    pub fn advance(&mut self) {
        debug_assert!(self.len < self.cap);
        self.len += 1;
    }

    /// Layer `l`'s K rows *including* the just-appended in-flight row:
    /// flat `(len + 1, M)` — the attention prefix of the current step.
    pub fn k_with_pending(&self, l: usize) -> &[f32] {
        &self.k[l][..(self.len + 1) * self.m]
    }

    /// Layer `l`'s V rows including the in-flight row, flat `(len + 1, M)`.
    pub fn v_with_pending(&self, l: usize) -> &[f32] {
        &self.v[l][..(self.len + 1) * self.m]
    }

    /// Evict: retire every slab back into the workspace pool.
    pub fn free(self, ws: &mut Workspace) {
        ws.put_all(self.k);
        ws.put_all(self.v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_advance_and_views() {
        let mut ws = Workspace::new();
        let m = 4;
        let mut c = KvCache::new(2, 3, m, &mut ws);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        let k0 = [1.0, 2.0, 3.0, 4.0];
        let v0 = [5.0, 6.0, 7.0, 8.0];
        c.append(0, &k0, &v0);
        c.append(1, &k0, &v0);
        assert_eq!(c.k_with_pending(0), &k0);
        assert_eq!(c.v_with_pending(1), &v0);
        c.advance();
        assert_eq!(c.len(), 1);
        let k1 = [9.0; 4];
        c.append(0, &k1, &v0);
        c.append(1, &k1, &v0);
        assert_eq!(&c.k_with_pending(0)[..m], &k0);
        assert_eq!(&c.k_with_pending(0)[m..], &k1);
        c.advance();
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn free_recycles_slabs_into_pool() {
        let mut ws = Workspace::new();
        let c = KvCache::new(3, 8, 16, &mut ws);
        assert_eq!(ws.pooled(), 0);
        c.free(&mut ws);
        assert_eq!(ws.pooled(), 6, "2 slabs per layer x 3 layers retired");
        // the next cache reuses the retired slabs and starts zeroed
        let c2 = KvCache::new(3, 8, 16, &mut ws);
        assert_eq!(ws.pooled(), 0);
        assert!(c2.k_with_pending(0).iter().all(|&x| x == 0.0));
        c2.free(&mut ws);
    }
}
