//! Discrete-event two-stream cluster simulator.
//!
//! Executes a task [`Dag`] on exactly the resource model the paper's
//! theorems assume (Sec. 3.3): one compute stream and one communication
//! stream, one task at a time per stream, no preemption, compute and comm
//! may overlap. When a stream frees up, it picks among *ready* tasks of
//! its stream: the lowest-`seq` A2A-or-compute task; AR chunks run only
//! when no A2A task is ready (Algorithm 2's priority rule).

use crate::tasks::{Dag, Stream, TaskId};

/// Execution record of one task.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub task: TaskId,
    pub start: f64,
    pub end: f64,
    pub stream: Stream,
}

/// Full execution timeline.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub spans: Vec<Span>,
    pub makespan: f64,
}

impl Timeline {
    /// Busy time of a stream.
    pub fn busy(&self, s: Stream) -> f64 {
        self.spans.iter().filter(|x| x.stream == s).map(|x| x.end - x.start).sum()
    }

    /// Stream occupancy (busy / makespan) — the SM-utilization analogue.
    pub fn occupancy(&self, s: Stream) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.busy(s) / self.makespan
    }

    /// Total communication busy time (both comm channels, unioned).
    pub fn busy_comm(&self) -> f64 {
        self.union_busy(|s| s != Stream::Compute)
    }

    /// Time compute and (any) communication are simultaneously busy.
    pub fn overlap(&self) -> f64 {
        // sweep over span boundaries
        let mut events: Vec<(f64, i32, bool)> = Vec::with_capacity(self.spans.len() * 2);
        for s in &self.spans {
            let is_comm = s.stream != Stream::Compute;
            events.push((s.start, 1, is_comm));
            events.push((s.end, -1, is_comm));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let (mut nc, mut nm) = (0i32, 0i32);
        let mut last = 0.0;
        let mut overlap = 0.0;
        for (t, d, is_comm) in events {
            if nc > 0 && nm > 0 {
                overlap += t - last;
            }
            last = t;
            if is_comm {
                nm += d;
            } else {
                nc += d;
            }
        }
        overlap
    }

    /// Union busy time of streams selected by `pred`.
    fn union_busy<F: Fn(Stream) -> bool>(&self, pred: F) -> f64 {
        let mut events: Vec<(f64, i32)> = Vec::new();
        for s in &self.spans {
            if pred(s.stream) {
                events.push((s.start, 1));
                events.push((s.end, -1));
            }
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut n = 0i32;
        let mut last = 0.0;
        let mut busy = 0.0;
        for (t, d) in events {
            if n > 0 {
                busy += t - last;
            }
            last = t;
            n += d;
        }
        busy
    }

    /// Span of a given task id.
    pub fn span_of(&self, id: TaskId) -> Option<&Span> {
        self.spans.iter().find(|s| s.task == id)
    }

    /// Export as a Chrome-trace (chrome://tracing / Perfetto) JSON string
    /// — one row per stream, one complete event per task. Hand-rolled
    /// JSON (no serde offline); task labels come from the DAG and are
    /// escaped with [`json_escape`].
    pub fn to_chrome_trace(&self, dag: &Dag) -> String {
        let mut out = String::from("[\n");
        for (i, s) in self.spans.iter().enumerate() {
            let tid = match s.stream {
                Stream::Compute => 0,
                Stream::Comm => 1,
                Stream::ArComm => 2,
            };
            let name = format!("{}", dag.tasks[s.task].kind);
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "  {{\"name\": \"{}\", \"ph\": \"X\", \"pid\": 0, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}",
                json_escape(&name),
                tid,
                s.start * 1e6,
                (s.end - s.start) * 1e6
            ));
        }
        out.push_str("\n]\n");
        out
    }
}

// JSON string escaping lives in `util` (shared with the runtime tracer
// in `obs`); re-exported here for compatibility with existing callers.
pub use crate::util::json_escape;

/// Simulate the DAG; panics on invalid DAGs (validated in debug).
///
/// Since the executor unification this is a thin delegate: the event loop
/// lives in [`crate::exec::run_modeled`], the cost-model driver of the
/// same task-graph executor whose native driver
/// ([`crate::exec::Plan::run_native`]) runs the real trainer. One engine,
/// two clocks — modeled and measured overlap describe the same schedule.
pub fn simulate(dag: &Dag) -> Timeline {
    crate::exec::run_modeled(dag)
}

/// Verify a timeline respects the model: no same-stream overlap, all deps
/// finished before starts, every task executed exactly once. Used by the
/// property tests.
pub fn verify_timeline(dag: &Dag, tl: &Timeline) -> Result<(), String> {
    if tl.spans.len() != dag.tasks.len() {
        return Err(format!("{} spans for {} tasks", tl.spans.len(), dag.tasks.len()));
    }
    let mut start = vec![f64::NAN; dag.tasks.len()];
    let mut end = vec![f64::NAN; dag.tasks.len()];
    for s in &tl.spans {
        if !start[s.task].is_nan() {
            return Err(format!("task {} executed twice", s.task));
        }
        start[s.task] = s.start;
        end[s.task] = s.end;
        let want = dag.tasks[s.task].dur;
        if ((s.end - s.start) - want).abs() > 1e-9 {
            return Err(format!("task {} duration {} != {}", s.task, s.end - s.start, want));
        }
    }
    for t in &dag.tasks {
        for &d in &t.deps {
            if end[d] > start[t.id] + 1e-9 {
                return Err(format!("task {} starts before dep {} ends", t.id, d));
            }
        }
    }
    // same-stream non-overlap
    for stream in [Stream::Compute, Stream::Comm, Stream::ArComm] {
        let mut xs: Vec<&Span> = tl.spans.iter().filter(|s| s.stream == stream).collect();
        xs.sort_by(|a, b| a.start.total_cmp(&b.start));
        for w in xs.windows(2) {
            if w[0].end > w[1].start + 1e-9 {
                return Err(format!(
                    "stream {:?}: tasks {} and {} overlap",
                    stream, w[0].task, w[1].task
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::{Phase, TaskKind};

    fn head() -> TaskKind {
        TaskKind::Head
    }

    #[test]
    fn sequential_chain() {
        let mut d = Dag::new();
        let a = d.add(head(), Stream::Compute, 1.0, vec![], 0);
        let b = d.add(head(), Stream::Compute, 2.0, vec![a], 1);
        let _ = d.add(head(), Stream::Compute, 3.0, vec![b], 2);
        let tl = simulate(&d);
        assert_eq!(tl.makespan, 6.0);
        verify_timeline(&d, &tl).unwrap();
    }

    #[test]
    fn streams_overlap() {
        let mut d = Dag::new();
        d.add(head(), Stream::Compute, 5.0, vec![], 0);
        d.add(head(), Stream::Comm, 4.0, vec![], 1);
        let tl = simulate(&d);
        assert_eq!(tl.makespan, 5.0);
        assert!((tl.overlap() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn same_stream_serializes() {
        let mut d = Dag::new();
        d.add(head(), Stream::Comm, 2.0, vec![], 0);
        d.add(head(), Stream::Comm, 2.0, vec![], 1);
        let tl = simulate(&d);
        assert_eq!(tl.makespan, 4.0);
        verify_timeline(&d, &tl).unwrap();
    }

    #[test]
    fn seq_order_respected_among_ready() {
        let mut d = Dag::new();
        let a = d.add(head(), Stream::Compute, 1.0, vec![], 5);
        let b = d.add(head(), Stream::Compute, 1.0, vec![], 1);
        let tl = simulate(&d);
        // b (seq 1) should run before a (seq 5)
        assert!(tl.span_of(b).unwrap().start < tl.span_of(a).unwrap().start);
    }

    #[test]
    fn ar_yields_to_a2a() {
        let mut d = Dag::new();
        // AR ready first by seq, but an A2A is also ready: A2A must win.
        let ar = d.add(TaskKind::Ar { l: 0, c: 0 }, Stream::Comm, 2.0, vec![], 0);
        let a2a = d.add(
            TaskKind::Disp { l: 0, r: 0, phase: Phase::Bwd },
            Stream::Comm,
            1.0,
            vec![],
            10,
        );
        let tl = simulate(&d);
        assert!(tl.span_of(a2a).unwrap().start < tl.span_of(ar).unwrap().start);
    }

    #[test]
    fn ar_fills_gaps_no_preemption() {
        // A2A arrives (via dep) while AR is running: AR is NOT preempted.
        let mut d = Dag::new();
        let gate = d.add(head(), Stream::Compute, 1.0, vec![], 0);
        let ar = d.add(TaskKind::Ar { l: 0, c: 0 }, Stream::Comm, 5.0, vec![], 1);
        let a2a = d.add(
            TaskKind::Comb { l: 0, r: 0, phase: Phase::Bwd },
            Stream::Comm,
            1.0,
            vec![gate],
            2,
        );
        let tl = simulate(&d);
        let ar_span = tl.span_of(ar).unwrap();
        let a2a_span = tl.span_of(a2a).unwrap();
        assert_eq!(ar_span.start, 0.0);
        // a2a waits for the running AR chunk to finish (no preemption)
        assert!(a2a_span.start >= ar_span.end - 1e-12);
        verify_timeline(&d, &tl).unwrap();
    }

    #[test]
    fn diamond_dependencies() {
        let mut d = Dag::new();
        let a = d.add(head(), Stream::Compute, 1.0, vec![], 0);
        let b = d.add(head(), Stream::Comm, 2.0, vec![a], 1);
        let c = d.add(head(), Stream::Compute, 3.0, vec![a], 2);
        let e = d.add(head(), Stream::Compute, 1.0, vec![b, c], 3);
        let tl = simulate(&d);
        assert_eq!(tl.makespan, 5.0);
        assert!(tl.span_of(e).unwrap().start >= 4.0 - 1e-12);
    }

    #[test]
    fn makespan_at_least_critical_path_and_stream_busy() {
        let mut d = Dag::new();
        let a = d.add(head(), Stream::Compute, 2.0, vec![], 0);
        let b = d.add(head(), Stream::Comm, 3.0, vec![a], 1);
        d.add(head(), Stream::Compute, 2.5, vec![b], 2);
        let tl = simulate(&d);
        assert!(tl.makespan >= d.critical_path() - 1e-12);
        assert!(tl.makespan >= d.stream_busy(Stream::Compute) - 1e-12);
    }

    #[test]
    fn json_escape_quoted_label() {
        // a task label with quotes and backslashes must survive, escaped
        let label = r#"AT "fused\gate" [0,1]"#;
        let esc = json_escape(label);
        assert_eq!(esc, r#"AT \"fused\\gate\" [0,1]"#);
        // embedding it in a JSON string literal keeps the quote count
        // balanced (the old exporter silently deleted quotes instead)
        let json = format!("{{\"name\": \"{esc}\"}}");
        assert_eq!(json.matches('"').count() - json.matches("\\\"").count(), 4);
        assert!(json.contains(r#"\"fused\\gate\""#));
    }

    #[test]
    fn json_escape_controls_and_passthrough() {
        assert_eq!(json_escape("plain AR[0.1]"), "plain AR[0.1]");
        assert_eq!(json_escape("a\tb\nc"), "a\\tb\\nc");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn chrome_trace_escapes_names() {
        let mut d = Dag::new();
        d.add(head(), Stream::Compute, 1.0, vec![], 0);
        let tl = simulate(&d);
        let json = tl.to_chrome_trace(&d);
        assert!(json.contains("\"name\": \"HEAD\""));
        assert!(json.trim_end().ends_with(']'));
    }

    #[test]
    fn occupancy_bounds() {
        let mut d = Dag::new();
        d.add(head(), Stream::Compute, 1.0, vec![], 0);
        d.add(head(), Stream::Comm, 1.0, vec![], 1);
        let tl = simulate(&d);
        for s in [Stream::Compute, Stream::Comm] {
            let o = tl.occupancy(s);
            assert!((0.0..=1.0 + 1e-12).contains(&o));
        }
    }
}
