//! End-to-end distributed trainer (real compute, real collectives). The
//! compute runs through [`crate::runtime::Engine`], i.e. on the native
//! in-tree backend from a clean checkout or on AOT artifacts when built.
//!
//! Two execution paths over the same entry points:
//!
//! * [`train_fused`] — single-process fused `train_step` HLO (oracle /
//!   baseline path).
//! * [`train_dp`] — P in-process workers, each owning a full replica and
//!   a private PJRT engine; every step runs microbatched per-block
//!   forward/backward pieces and all-reduces gradients through the
//!   [`crate::commpool`] machinery.
//!
//! Since the executor unification the step structure is not hand-coded:
//! each worker builds the same [`crate::sched::build_dag`] task graph the
//! simulator consumes — `overlap = true` selects the FlowMoE policy
//! (Pipe-AR: the AR chunks of block *l* are enqueued the moment its
//! gradients are accumulated, while the compute thread proceeds to block
//! *l−1*), `overlap = false` the FlowMoE-AT policy (centralized: one
//! whole-block AR after the full backward pass) — pre-flights it through
//! [`crate::analyze::check_dag`] and executes it with
//! [`crate::exec::Plan::run_native`]. [`ExecMode::Legacy`] keeps the
//! pre-executor hand-rolled loop selectable (`--exec legacy`) as the
//! bitwise reference for the parity suite and the CI smoke.
//!
//! Gradient scaling follows Appendix H: each microbatch loss is scaled by
//! 1/R so pipelined gradients equal full-batch gradients exactly (the
//! tiny config is drop-free; see python/compile/configs.py).
//!
//! # Fault tolerance (paper Appendix K, real)
//!
//! `train_dp` is structured as a driver over *attempts*. Each attempt
//! spawns the current world and runs until the target step or until a
//! failure surfaces as a typed [`CommError`] (the collective's ops are
//! deadline-bounded — see [`crate::commpool`]). On failure the driver
//! retires the casualty, re-forms the collective at P−1, re-shards the
//! expert service plan ([`crate::ft::reshard_survivors`]), reloads the
//! newest valid checkpoint and retries; each phase is traced as
//! `ft_detect` / `ft_reshard` / `ft_restore` spans and recorded in
//! [`crate::ft::RecoveryEvent`]s. Checkpoints written with
//! `ckpt_dir`/`ckpt_every` carry the full training state, and resume is
//! **bitwise**: train 2N steps == train N + checkpoint + resume N.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::backend::kernels::{active_dispatch, axpy, scale, with_dispatch};
use crate::commpool::{Collective, CommError, CommPool};
use crate::config::ClusterProfile;
use crate::cost::TaskCosts;
use crate::data::Corpus;
use crate::exec::{self, TaskRunner};
use crate::ft::{self, Checkpoint, FaultPlan, RecoveryEvent};
use crate::obs;
use crate::runtime::{ArtifactSpec, BufSpec, Engine, HostTensor, PjRtBuffer};
use crate::sched::Policy;
use crate::sweep::scope;
use crate::tasks::{Phase, Task, TaskKind};
use crate::util::{lock_recover, Rng};

/// Per-run report.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// Mean loss per step (averaged across workers). Index 0 is step
    /// `start_step`.
    pub losses: Vec<f32>,
    /// Wall seconds per step.
    pub step_secs: Vec<f64>,
    /// First global step of this run (> 0 after `--resume`).
    pub start_step: usize,
    /// Elastic recoveries performed during the run (empty = clean run).
    pub recoveries: Vec<RecoveryEvent>,
    /// Final parameters of worker 0 (for parity tests).
    pub final_params: Vec<Vec<f32>>,
    /// Per-run metrics: step/phase wall-time histograms (p50/p95/p99),
    /// step and AR-chunk counters. On the DP path the histograms pool
    /// observations from **all** workers (each worker-step observes
    /// once), taken after every worker has joined.
    pub stats: obs::RegistrySnapshot,
}

/// How the per-step work is driven.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Execute a policy-built, `analyze::check_dag`-verified task graph
    /// through [`crate::exec::Plan::run_native`] (the default).
    Graph,
    /// The pre-executor hand-rolled step loop, kept as the bitwise
    /// reference the parity tests and the CI smoke compare against.
    Legacy,
}

/// Training options.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub cfg_name: String,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// Pipe-AR overlap (FlowMoE) vs centralized AR (baselines).
    pub overlap: bool,
    /// All-reduce chunk size in bytes (elements = bytes/4).
    pub sp_bytes: usize,
    pub log_every: usize,
    /// Checkpoint directory (None = checkpointing off).
    pub ckpt_dir: Option<PathBuf>,
    /// Checkpoint cadence in steps (0 = off even with a dir).
    pub ckpt_every: usize,
    /// Resume from the newest valid checkpoint in `ckpt_dir`.
    pub resume: bool,
    /// Seeded fault injection (None = faultless).
    pub fault: Option<FaultPlan>,
    /// Failure-detection window for the collective's blocking ops.
    pub detect_ms: u64,
    /// Worker 0 exits the whole process (code 3) after completing this
    /// many steps — the CI kill-and-resume smoke's crash hook.
    pub die_at: Option<usize>,
    /// Step engine: graph-driven (default) or the legacy reference loop.
    pub exec: ExecMode,
}

impl TrainOpts {
    pub fn new(cfg_name: &str, steps: usize) -> TrainOpts {
        TrainOpts {
            cfg_name: cfg_name.to_string(),
            steps,
            lr: 0.05,
            momentum: 0.9,
            seed: 1234,
            overlap: true,
            sp_bytes: 1 << 20,
            log_every: 0,
            ckpt_dir: None,
            ckpt_every: 0,
            resume: false,
            fault: None,
            detect_ms: ft::DETECT_TIMEOUT_MS,
            die_at: None,
            exec: ExecMode::Graph,
        }
    }
}

/// Canonical parameter initialization (shared by both paths so they can
/// be compared bit-for-bit): norm gains = 1, everything else
/// normal * fan_in^-1/2, deterministic in `seed`.
pub fn init_params(engine: &Engine, cfg_name: &str, seed: u64) -> Result<Vec<Vec<f32>>> {
    let spec = engine.manifest().get(&format!("train_step_{cfg_name}"))?;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for b in &spec.inputs {
        let Some(name) = b.name.strip_prefix("param.") else {
            break; // params come first in the manifest order
        };
        let n = b.elems();
        let v = if name.ends_with(".n1") || name.ends_with(".n2") || name == "normf" {
            vec![1.0f32; n]
        } else {
            let fan_in = if b.shape.len() >= 2 {
                b.shape[b.shape.len() - 2]
            } else {
                *b.shape.last().unwrap_or(&1)
            } as f64;
            let s = fan_in.powf(-0.5);
            (0..n).map(|_| (rng.normal() * s) as f32).collect()
        };
        out.push(v);
    }
    Ok(out)
}

/// Geometry of a config read back from the manifest (no duplicated shape
/// knowledge in rust).
struct Geometry {
    n_params: usize,
    l_blocks: usize,
    bm: usize,
    n_tokens: usize,
    r: usize,
}

fn geometry(engine: &Engine, cfg: &str, full_b: usize) -> Result<Geometry> {
    let ts = engine.manifest().get(&format!("train_step_{cfg}"))?;
    let n_params = ts.inputs.iter().filter(|b| b.name.starts_with("param.")).count();
    let l_blocks = (n_params - 2) / 9;
    let ef = engine.manifest().get(&format!("embed_fwd_{cfg}"))?;
    let tok = &ef.inputs[1];
    let (bm, n_tokens) = (tok.shape[0], tok.shape[1]);
    Ok(Geometry {
        n_params,
        l_blocks,
        bm,
        n_tokens,
        r: full_b / bm,
    })
}

fn full_batch(engine: &Engine, cfg: &str) -> Result<usize> {
    let ts = engine.manifest().get(&format!("train_step_{cfg}"))?;
    let tok = ts
        .inputs
        .iter()
        .find(|b| b.name == "tokens")
        .ok_or_else(|| anyhow!("no tokens input"))?;
    Ok(tok.shape[0])
}

/// The scheduling policy `TrainOpts` implies: Pipe-AR overlap is full
/// FlowMoE; centralized is FlowMoE-AT (identical MHA+MoE pipelining with
/// `r_at == r_moe`, one whole-block AR per layer after backward).
fn step_policy(r_deg: usize, overlap: bool, sp_bytes: usize) -> Policy {
    if overlap {
        Policy::flow_moe(r_deg, sp_bytes as f64)
    } else {
        Policy::flow_moe_at(r_deg)
    }
}

/// Build and statically verify the per-step schedule plan for a config.
/// Durations come from the cost model — they matter for the modeled
/// timeline, not for native correctness; what `run_native` executes is
/// the *structure*: layer count, microbatch degree, AR placement and the
/// Eqs. 2–5 priority ranks.
fn build_plan(cfg_name: &str, l_blocks: usize, policy: Policy, p: usize) -> Result<exec::Plan> {
    let mut cfg = crate::config::preset(cfg_name)
        .ok_or_else(|| anyhow!("no model preset named '{cfg_name}' to build a schedule from"))?;
    cfg.l = l_blocks;
    let costs = TaskCosts::build(&cfg, &ClusterProfile::cluster1(p.max(2)));
    let dag = crate::sched::build_dag(&cfg, &costs, &policy);
    exec::Plan::new(dag, policy)
}

/// The schedule plan [`train_dp`] executes, geometry read back from the
/// manifest. Public so `flowmoe train`'s overlap report can compute the
/// modeled stats from the *same* verified DAG the runtime ran.
pub fn native_step_plan(artifacts: &Path, opts: &TrainOpts, p: usize) -> Result<exec::Plan> {
    let engine = Engine::new(artifacts)?;
    let b_full = full_batch(&engine, &opts.cfg_name)?;
    let geo = geometry(&engine, &opts.cfg_name, b_full)?;
    build_plan(
        &opts.cfg_name,
        geo.l_blocks,
        step_policy(geo.r, opts.overlap, opts.sp_bytes),
        p,
    )
}

/// The fused path's plan: the manifest's `train_step` HLO is one
/// monolithic kernel, so the plan is the Vanilla-EP policy (R = 1,
/// centralized AR) over the same block count.
pub fn fused_step_plan(artifacts: &Path, opts: &TrainOpts) -> Result<exec::Plan> {
    let engine = Engine::new(artifacts)?;
    let spec = engine.manifest().get(&format!("train_step_{}", opts.cfg_name))?;
    let n_params = spec.inputs.iter().filter(|b| b.name.starts_with("param.")).count();
    build_plan(&opts.cfg_name, (n_params - 2) / 9, Policy::vanilla_ep(), 2)
}

/// SGD + momentum update (matches the HLO train_step formula exactly).
/// The per-tensor updates are independent, so they fan out across the
/// worker's thread budget (identical results for any budget).
fn sgd_update(params: &mut [Vec<f32>], moms: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32, mu: f32) {
    let _sp = obs::span("update");
    let items: Vec<(&mut Vec<f32>, &mut Vec<f32>, &Vec<f32>)> = params
        .iter_mut()
        .zip(moms.iter_mut())
        .zip(grads.iter())
        .map(|((p, m), g)| (p, m, g))
        .collect();
    scope::par_items(items, |_, (p, m, g)| {
        for i in 0..p.len() {
            m[i] = mu * m[i] + g[i];
            p[i] -= lr * m[i];
        }
    });
}

/// Single-process fused-train_step path.
pub fn train_fused(artifacts: &Path, opts: &TrainOpts) -> Result<TrainReport> {
    let mut engine = Engine::new(artifacts)?;
    let cfg = &opts.cfg_name;
    let name = format!("train_step_{cfg}");
    let spec = engine.manifest().get(&name)?.clone();
    let n_params = spec.inputs.iter().filter(|b| b.name.starts_with("param.")).count();
    let b_full = full_batch(&engine, cfg)?;
    let n_tok = spec
        .inputs
        .iter()
        .find(|b| b.name == "tokens")
        .ok_or_else(|| anyhow!("{name} has no tokens input"))?
        .shape[1];

    let mut params = init_params(&engine, cfg, opts.seed)?;
    let mut moms: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut corpus = Corpus::new(
        spec.inputs[0].shape[0], // vocab from embed shape
        opts.seed ^ 0x0,
    );

    // graph mode: the whole fused step binds to the HEAD node of a
    // statically verified Vanilla-EP plan (R = 1 — the fused HLO is one
    // monolithic kernel); legacy calls the engine directly
    let plan = match opts.exec {
        ExecMode::Graph => Some(build_plan(cfg, (n_params - 2) / 9, Policy::vanilla_ep(), 2)?),
        ExecMode::Legacy => None,
    };

    let reg = obs::Registry::new();
    let step_hist = reg.histogram("step_s");
    let mut report = TrainReport::default();
    for step in 0..opts.steps {
        let t0 = std::time::Instant::now();
        let _sp_step = obs::span("step");
        let tokens = HostTensor::I32(corpus.batch(b_full, n_tok));
        let lr = HostTensor::F32(vec![opts.lr]);
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(2 * n_params + 2);
        for p in &params {
            inputs.push(HostTensor::F32(p.clone()));
        }
        for m in &moms {
            inputs.push(HostTensor::F32(m.clone()));
        }
        inputs.push(tokens);
        inputs.push(lr);
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        let outs = match &plan {
            Some(plan) => {
                let mut fs = FusedStep {
                    engine: &mut engine,
                    name: &name,
                    inputs: &refs,
                    outs: None,
                };
                plan.run_native(&mut fs)?;
                fs.outs
                    .ok_or_else(|| anyhow!("{name}: plan executed without reaching HEAD"))?
            }
            None => engine.run(&name, &refs)?,
        };
        for i in 0..n_params {
            params[i] = outs[i].f32().to_vec();
            moms[i] = outs[n_params + i].f32().to_vec();
        }
        let loss = outs[2 * n_params].scalar_f32();
        report.losses.push(loss);
        let secs = t0.elapsed().as_secs_f64();
        report.step_secs.push(secs);
        step_hist.observe(secs);
        reg.counter("steps").inc();
        reg.gauge("loss_last").set(loss as f64);
        if opts.log_every > 0 && step % opts.log_every == 0 {
            eprintln!("[fused {cfg}] step {step}: loss {loss:.4}");
        }
    }
    report.final_params = params;
    report.stats = reg.snapshot();
    Ok(report)
}

/// [`TaskRunner`] for the fused path: the manifest's `train_step` HLO is
/// one monolithic kernel, so the whole step binds to the HEAD node and
/// every other node is an ordering marker realized inside the fused
/// kernel (its AR happens in the update formula itself — P = 1).
struct FusedStep<'a, 'b> {
    engine: &'a mut Engine,
    name: &'a str,
    inputs: &'a [&'b HostTensor],
    outs: Option<Vec<HostTensor>>,
}

impl TaskRunner for FusedStep<'_, '_> {
    fn run(&mut self, task: &Task) -> Result<()> {
        if matches!(task.kind, TaskKind::Head) {
            self.outs = Some(self.engine.run(self.name, self.inputs)?);
        }
        Ok(())
    }

    fn submit_ar(&mut self, _task: &Task) -> Result<()> {
        Ok(())
    }
}

/// One worker's view of one attempt: per-step results up to either the
/// target or the step a failure surfaced at.
struct AttemptRun {
    losses: Vec<f32>,
    step_secs: Vec<f64>,
    /// `Some(step)` = this worker aborted during `step` (planned kill or
    /// detected peer failure). `None` = ran to the target.
    stopped_at: Option<usize>,
    /// This worker was the planned casualty.
    killed: bool,
    /// Kill -> error-surfaced latency observed by this worker (ms).
    detect_ms: Option<f64>,
    final_params: Vec<Vec<f32>>,
}

impl AttemptRun {
    fn new() -> AttemptRun {
        AttemptRun {
            losses: Vec::new(),
            step_secs: Vec::new(),
            stopped_at: None,
            killed: false,
            detect_ms: None,
            final_params: Vec::new(),
        }
    }
}

/// Record the failure a worker is aborting on: an `ft_detect` span from
/// the death mark to now (when the casualty is known), plus the
/// detection latency for the recovery report.
fn abort_attempt(mut run: AttemptRun, step: usize, coll: &Collective, err: &CommError) -> AttemptRun {
    let now = Instant::now();
    if let Some(t0) = coll.death_time() {
        obs::record_between("ft_detect", t0, now);
        run.detect_ms = Some(now.saturating_duration_since(t0).as_secs_f64() * 1e3);
    } else if let CommError::Timeout { waited_ms, .. } = err {
        run.detect_ms = Some(*waited_ms as f64);
    }
    run.stopped_at = Some(step);
    run
}

/// Distributed data-parallel path: P workers, per-block pipelined
/// backward, chunked-AR overlap through the comm pool.
///
/// The caller's thread budget ([`scope::current_budget`]) is divided
/// across the workers: each worker runs its kernels with `budget / P`
/// threads (min 1), so worker-level and kernel-level parallelism compose
/// without oversubscribing the host.
///
/// With `opts.resume` / `opts.ckpt_dir` / `opts.fault` this is the
/// fault-tolerance driver described in the module docs: it keeps
/// retrying at a shrinking world size until the target step is reached
/// or no survivors remain.
pub fn train_dp(artifacts: &Path, p: usize, opts: &TrainOpts) -> Result<TrainReport> {
    assert!(p >= 1);
    let dir: PathBuf = artifacts.to_path_buf();
    // one run-wide registry shared by all workers: every worker-step
    // observes into the same phase histograms
    let reg = Arc::new(obs::Registry::new());

    // resume bootstrap: newest valid checkpoint wins
    let mut boot: Arc<Option<Checkpoint>> = Arc::new(None);
    let mut start = 0usize;
    if opts.resume {
        let Some(ckdir) = &opts.ckpt_dir else {
            bail!("resume requires a checkpoint directory");
        };
        if let Some((path, ck)) = ft::latest_valid(ckdir).map_err(|e| anyhow!("checkpoint scan: {e}"))? {
            if ck.cfg != opts.cfg_name {
                bail!(
                    "checkpoint {} is for config '{}', not '{}'",
                    path.display(),
                    ck.cfg,
                    opts.cfg_name
                );
            }
            if p > ck.corpus_rng.len() {
                bail!(
                    "checkpoint {} has {} worker cursors, cannot resume with {p} workers",
                    path.display(),
                    ck.corpus_rng.len()
                );
            }
            start = ck.step as usize;
            eprintln!("[ft] resuming from {} (step {start})", path.display());
            boot = Arc::new(Some(ck));
        }
    }
    let boot0 = Arc::clone(&boot);
    let first_start = start;
    let target = first_start + opts.steps;

    let mut active = p;
    let mut plan = opts.fault.clone();
    let mut epoch = 0u64;
    let mut losses: Vec<f32> = Vec::new();
    let mut step_secs: Vec<f64> = Vec::new();
    let mut recoveries: Vec<RecoveryEvent> = Vec::new();

    let final_params = loop {
        // `start` can exceed the target: a stale checkpoint from a longer
        // earlier run wins `latest_valid`, or `--resume --steps 0`. Both
        // must be a clean no-op run of 0 steps, not an underflow.
        let remaining = target.saturating_sub(start);
        if start > target {
            eprintln!("[ft] checkpoint step {start} is already past the target {target}: nothing to do");
        }
        let (runs, first_err) = run_attempt(&dir, active, opts, start, remaining, &boot, &plan, epoch, &reg);
        let detected = runs.iter().flatten().filter_map(|r| r.stopped_at).min();
        let Some(detected_step) = detected else {
            // no failure surfaced: clean finish, or a hard error that
            // hit the whole group (e.g. a bad config)
            if let Some(e) = first_err {
                return Err(e);
            }
            let Some(run0) = runs.into_iter().next().flatten() else {
                return Err(anyhow!("dp worker 0 produced no report"));
            };
            losses.extend_from_slice(&run0.losses);
            step_secs.extend_from_slice(&run0.step_secs);
            break run0.final_params;
        };

        // ---------------- elastic recovery ----------------
        if active <= 1 {
            return Err(first_err.unwrap_or_else(|| anyhow!("worker failed with no survivors left")));
        }
        // casualty: a worker that returned Err/panicked, else the
        // planned kill, else (pure timeout, nobody identified) the
        // highest rank — conservative unresponsive-peer semantics.
        let failed_rank = runs
            .iter()
            .position(|r| r.is_none())
            .or_else(|| runs.iter().position(|r| r.as_ref().is_some_and(|a| a.killed)))
            .unwrap_or(active - 1);
        let detect_ms = runs
            .iter()
            .flatten()
            .filter_map(|r| r.detect_ms)
            .fold(0.0f64, f64::max);

        let t_restore = Instant::now();
        let (ck_step, new_boot) = {
            let _sp = obs::span("ft_restore");
            let newest = match &opts.ckpt_dir {
                Some(d) => ft::latest_valid(d).map_err(|e| anyhow!("checkpoint scan during recovery: {e}"))?,
                None => None,
            };
            match newest {
                Some((_, ck)) if ck.cfg == opts.cfg_name && ck.corpus_rng.len() >= active - 1 => {
                    let s = ck.step as usize;
                    (s, Arc::new(Some(ck)))
                }
                // no usable checkpoint: the attempt restarts from the
                // original boot state (step first_start)
                _ => (first_start, Arc::clone(&boot0)),
            }
        };
        let restore_ms = t_restore.elapsed().as_secs_f64() * 1e3;

        let t_reshard = Instant::now();
        let reshard = {
            let _sp = obs::span("ft_reshard");
            // DP replicates every expert on every worker, so the plan
            // records expert *service* assignment for the shrunken
            // group; counts are uniform (no routing skew signal here —
            // the serving path reshards from real counts).
            match crate::config::preset(&opts.cfg_name) {
                Some(cfg) => ft::reshard_survivors(cfg.e, active - 1, &vec![1u64; cfg.e]),
                None => Vec::new(),
            }
        };
        let reshard_ms = t_reshard.elapsed().as_secs_f64() * 1e3;

        // keep only losses up to the checkpoint we restart from: the
        // steps past it are discarded work and will be re-run at P−1
        losses.truncate(ck_step.saturating_sub(first_start));
        step_secs.truncate(ck_step.saturating_sub(first_start));
        if ck_step > start {
            if let Some(sv) = runs.iter().flatten().find(|r| !r.killed) {
                let take = (ck_step - start).min(sv.losses.len());
                losses.extend_from_slice(&sv.losses[..take]);
                step_secs.extend_from_slice(&sv.step_secs[..take.min(sv.step_secs.len())]);
            }
        }

        eprintln!(
            "[ft] worker {failed_rank} failed at step {detected_step}; resuming from checkpoint step {ck_step} with {} workers",
            active - 1
        );
        recoveries.push(RecoveryEvent {
            failed_rank,
            detected_step,
            ckpt_step: ck_step,
            steps_lost: (detected_step + 1).saturating_sub(ck_step),
            p_after: active - 1,
            reshard,
            detect_ms,
            reshard_ms,
            restore_ms,
        });
        active -= 1;
        start = ck_step;
        boot = new_boot;
        plan = plan.map(|pl| pl.without_kill());
        epoch += 1;
    };

    let mut report = TrainReport {
        losses,
        step_secs,
        start_step: first_start,
        recoveries,
        final_params,
        ..TrainReport::default()
    };
    // snapshot only after every worker has joined, so the counts are
    // complete and the snapshot is race-free
    report.stats = reg.snapshot();
    Ok(report)
}

/// Spawn `active` workers for steps `[start, start + n_steps)` and join
/// them all. Returns each rank's run (`None` = the worker returned a
/// hard error or panicked) plus the first hard error.
#[allow(clippy::too_many_arguments)]
fn run_attempt(
    artifacts: &Path,
    active: usize,
    opts: &TrainOpts,
    start: usize,
    n_steps: usize,
    boot: &Arc<Option<Checkpoint>>,
    plan: &Option<FaultPlan>,
    epoch: u64,
    reg: &Arc<obs::Registry>,
) -> (Vec<Option<AttemptRun>>, Option<anyhow::Error>) {
    let coll = Collective::with_opts(active, opts.detect_ms, plan.clone(), epoch);
    let worker_budget = (scope::current_budget() / active).max(1);
    // re-apply the caller's kernel-dispatch tier inside the workers:
    // spawned threads start with an empty thread-local override
    let disp = active_dispatch();
    // checkpoint rendezvous: each worker publishes its data cursor here
    // right before the pre-snapshot barrier
    let rng_slots: Arc<Mutex<Vec<[u64; 4]>>> = Arc::new(Mutex::new(vec![[0u64; 4]; active]));
    let mut handles = Vec::new();
    for w in 0..active {
        let coll = Arc::clone(&coll);
        let opts = opts.clone();
        let dir = artifacts.to_path_buf();
        let reg = Arc::clone(reg);
        let boot = Arc::clone(boot);
        let slots = Arc::clone(&rng_slots);
        // flowmoe-lint: allow(thread_spawn) — DP workers outlive any one scope
        handles.push(std::thread::spawn(move || {
            let out = with_dispatch(disp, || {
                scope::with_budget(worker_budget, || {
                    worker_dp(w, active, &coll, &dir, &opts, &reg, start, n_steps, &boot, &slots)
                })
            });
            if out.is_err() {
                // a hard failure = this worker is gone; unblock the
                // survivors' collective ops immediately
                coll.mark_dead(w);
            }
            out
        }));
    }
    let mut runs = Vec::with_capacity(active);
    let mut first_err = None;
    for h in handles {
        match h.join() {
            Ok(Ok(run)) => runs.push(Some(run)),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
                runs.push(None);
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some(anyhow!("dp worker panicked"));
                }
                runs.push(None);
            }
        }
    }
    (runs, first_err)
}

/// [`TaskRunner`] for the DP worker: binds each DAG node of the verified
/// step plan to the native per-block entry points.
///
/// * `At(l, r, Fwd)` — microbatch `r`'s embedding (at the first layer)
///   then the fused block-forward kernel. The block kernel realizes the
///   whole At→Disp→Exp→Comb stage, so the layer's MoE nodes are ordering
///   markers here — their measured footprint is the `dispatch` /
///   `expert_fwd` / `combine` spans the kernel emits.
/// * `Head` — closes the forward phase, runs the planned-fault hook,
///   then the per-microbatch head/loss accumulation (Appendix H's 1/R
///   scaling) and opens the backward phase.
/// * `At(l, r, Bwd)` — `block_bwd` + gradient accumulation. Eq. 5 ranks
///   backward microbatches in reverse FIFO order, so node `r` maps to
///   accumulation microbatch `R−1−r`: execution order equals the legacy
///   ascending-microbatch loop and the f32 gradient sums stay bitwise
///   identical.
/// * `Ar(l, c)` — chunk 0 enqueues the whole block's chunked all-reduce
///   on the comm pool ([`exec::enqueue_block_ar`]). The DAG's chunk
///   count follows the cost model's S_p partition of the block's AR
///   bytes, while the pool re-partitions per tensor at the same chunk
///   size (`prop_theorems` pins the boundary agreement), so chunks
///   `c > 0` mark work already enqueued.
struct GraphStep<'a> {
    engine: &'a mut Engine,
    corpus: &'a mut Corpus,
    coll: &'a Arc<Collective>,
    pool: &'a CommPool,
    reg: &'a obs::Registry,
    gstore: &'a Arc<Mutex<Vec<Vec<f32>>>>,
    ar_fail: &'a Arc<Mutex<Option<CommError>>>,
    params: &'a [Vec<f32>],
    block_lits: &'a [Vec<PjRtBuffer>],
    embed_lit: &'a PjRtBuffer,
    normf_lit: &'a PjRtBuffer,
    hl_spec: &'a ArtifactSpec,
    x_spec: &'a BufSpec,
    embed_fwd: &'a str,
    block_fwd: &'a str,
    block_bwd: &'a str,
    head_loss: &'a str,
    toks: Vec<HostTensor>,
    acts: Vec<Vec<HostTensor>>, // acts[r][l]
    dxs: Vec<HostTensor>,
    loss: f32,
    ar_chunks: usize,
    killed: bool,
    w: usize,
    step: usize,
    r_deg: usize,
    l_blocks: usize,
    n_params: usize,
    bm: usize,
    n_tok: usize,
    chunk_elems: usize,
    inv_r: f32,
    sp_fwd: Option<obs::SpanGuard>,
    t_fwd: Instant,
    sp_bwd: Option<obs::SpanGuard>,
    t_bwd: Instant,
}

impl GraphStep<'_> {
    fn at_fwd(&mut self, l: usize, r: usize) -> Result<()> {
        if l == 0 {
            // forward At nodes run in ascending (layer, microbatch)
            // order, so layer 0 draws the microbatches in the exact
            // corpus-RNG order the legacy loop used
            let t = HostTensor::I32(self.corpus.batch(self.bm, self.n_tok));
            let x0 = self
                .engine
                .run(self.embed_fwd, &[&HostTensor::F32(self.params[0].clone()), &t])?;
            self.toks.push(t);
            self.acts.push(vec![x0
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("{}: no output", self.embed_fwd))?]);
        }
        let x_lit = self.engine.buffer_f32(self.acts[r][l].f32(), self.x_spec)?;
        let mut inp: Vec<&PjRtBuffer> = self.block_lits[l].iter().collect();
        inp.push(&x_lit);
        let y = self.engine.run_buffers(self.block_fwd, &inp)?;
        self.acts[r].push(
            y.into_iter()
                .next()
                .ok_or_else(|| anyhow!("{}: no output", self.block_fwd))?,
        );
        Ok(())
    }

    fn head(&mut self) -> Result<()> {
        // forward phase ends exactly where the legacy loop ended it
        drop(self.sp_fwd.take());
        self.reg.histogram("fwd_s").observe(self.t_fwd.elapsed().as_secs_f64());

        // planned kill: this worker crashes mid-step; survivors detect
        // it through their deadline-bounded collective ops
        if self.coll.should_die(self.w, self.step) {
            eprintln!("[ft] worker {} dying at step {} (planned fault)", self.w, self.step);
            self.coll.mark_dead(self.w);
            self.killed = true;
            bail!("planned fault at step {}", self.step);
        }

        let t_head = std::time::Instant::now();
        for r in 0..self.r_deg {
            let xf_lit = self
                .engine
                .buffer_f32(self.acts[r][self.l_blocks].f32(), &self.hl_spec.inputs[2])?;
            let tok_lit = self.engine.buffer(&self.toks[r], &self.hl_spec.inputs[3])?;
            let outs = self
                .engine
                .run_buffers(self.head_loss, &[self.embed_lit, self.normf_lit, &xf_lit, &tok_lit])?;
            self.loss += outs[0].scalar_f32() * self.inv_r;
            let mut dxf = outs[1].f32().to_vec();
            scale(&mut dxf, self.inv_r);
            self.dxs.push(HostTensor::F32(dxf));
            let mut g = lock_recover(self.gstore);
            axpy(&mut g[0], outs[2].f32(), self.inv_r);
            axpy(&mut g[self.n_params - 1], outs[3].f32(), self.inv_r);
        }
        self.reg.histogram("head_s").observe(t_head.elapsed().as_secs_f64());
        self.sp_bwd = Some(obs::span("bwd"));
        self.t_bwd = std::time::Instant::now();
        Ok(())
    }

    fn at_bwd(&mut self, l: usize, r_node: usize) -> Result<()> {
        let r = self.r_deg - 1 - r_node; // Eq. 5 reverse-FIFO rank -> microbatch
        let x_lit = self.engine.buffer_f32(self.acts[r][l].f32(), self.x_spec)?;
        let dy_lit = self.engine.buffer_f32(self.dxs[r].f32(), self.x_spec)?;
        let mut inp: Vec<&PjRtBuffer> = self.block_lits[l].iter().collect();
        inp.push(&x_lit);
        inp.push(&dy_lit);
        let outs = self.engine.run_buffers(self.block_bwd, &inp)?;
        {
            let mut g = lock_recover(self.gstore);
            for t in 0..9 {
                axpy(&mut g[1 + l * 9 + t], outs[t].f32(), 1.0);
            }
        }
        self.dxs[r] = outs
            .into_iter()
            .nth(9)
            .ok_or_else(|| anyhow!("{}: missing dx output", self.block_bwd))?;
        Ok(())
    }
}

impl TaskRunner for GraphStep<'_> {
    fn run(&mut self, task: &Task) -> Result<()> {
        match task.kind {
            TaskKind::At { l, r, phase: Phase::Fwd } => self.at_fwd(l, r),
            TaskKind::At { l, r, phase: Phase::Bwd } => self.at_bwd(l, r),
            TaskKind::Head => self.head(),
            // realized inside the fused block kernels the At nodes run;
            // the nodes order the schedule, the kernels' dispatch /
            // expert / combine spans measure them
            TaskKind::Disp { .. } | TaskKind::Exp { .. } | TaskKind::Comb { .. } => Ok(()),
            TaskKind::Ar { .. } => bail!("AR node routed to the inline lane"),
        }
    }

    fn submit_ar(&mut self, task: &Task) -> Result<()> {
        let TaskKind::Ar { l, c } = task.kind else {
            bail!("non-AR node routed to the AR lane");
        };
        if c == 0 {
            let (step, l_blocks) = (self.step, self.l_blocks);
            let mut ar_tag = |layer: usize, tensor: usize, chunk: usize| -> u64 {
                (((step * (l_blocks + 2) + layer) as u64) << 24)
                    | ((tensor as u64) << 16)
                    | chunk as u64
            };
            self.ar_chunks += exec::enqueue_block_ar(
                self.pool,
                self.coll,
                self.gstore,
                self.w,
                self.ar_fail,
                l,
                1 + l * 9,
                9,
                self.chunk_elems,
                &mut ar_tag,
            );
        }
        Ok(())
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_dp(
    w: usize,
    p: usize,
    coll: &Arc<Collective>,
    artifacts: &Path,
    opts: &TrainOpts,
    reg: &obs::Registry,
    start_step: usize,
    n_steps: usize,
    boot: &Arc<Option<Checkpoint>>,
    rng_slots: &Arc<Mutex<Vec<[u64; 4]>>>,
) -> Result<AttemptRun> {
    let cfg = opts.cfg_name.clone();
    let mut engine = Engine::new(artifacts)?;
    let b_full = full_batch(&engine, &cfg)?;
    let geo = geometry(&engine, &cfg, b_full)?;
    let (l_blocks, r_deg, bm, n_tok) = (geo.l_blocks, geo.r, geo.bm, geo.n_tokens);
    let embed_fwd = format!("embed_fwd_{cfg}");
    let block_fwd = format!("block_fwd_{cfg}");
    let block_bwd = format!("block_bwd_{cfg}");
    let head_loss = format!("head_loss_{cfg}");
    let embed_bwd = format!("embed_bwd_{cfg}");
    for n in [&embed_fwd, &block_fwd, &block_bwd, &head_loss, &embed_bwd] {
        engine.prepare(n)?;
    }

    let mut params = init_params(&engine, &cfg, opts.seed)?;
    let n_params = geo.n_params;
    let mut moms: Vec<Vec<f32>> = params.iter().map(|q| vec![0.0; q.len()]).collect();
    // distinct data shard per worker
    let vocab = engine.manifest().get(&format!("train_step_{cfg}"))?.inputs[0].shape[0];
    let mut corpus = Corpus::new(vocab, opts.seed ^ (w as u64));
    if let Some(ck) = boot.as_ref() {
        if ck.params.len() != n_params {
            bail!("checkpoint has {} tensors, expected {n_params}", ck.params.len());
        }
        for (i, (have, want)) in ck.params.iter().zip(&params).enumerate() {
            if have.len() != want.len() {
                bail!("checkpoint tensor {i} has {} elems, expected {}", have.len(), want.len());
            }
        }
        params = ck.params.clone();
        moms = ck.moms.clone();
        corpus.set_rng_state(ck.corpus_rng[w]);
    }

    let pool = CommPool::new();
    let chunk_elems = (opts.sp_bytes / 4).max(1);
    let inv_r = 1.0f32 / r_deg as f32;
    // first AR-chunk failure of the current step (set on the comm-pool
    // thread, consumed after drain)
    let ar_fail: Arc<Mutex<Option<CommError>>> = Arc::new(Mutex::new(None));

    // buffer specs for the hot-path marshalling (§Perf: parameters are
    // read by 4R block calls per step; marshal each param once per step)
    let bf_spec = engine.manifest().get(&block_fwd)?.clone();
    let hl_spec = engine.manifest().get(&head_loss)?.clone();
    let x_spec = bf_spec.inputs[9].clone();

    // graph mode: build + statically verify the step schedule once per
    // attempt; every step executes this plan. Legacy skips it and runs
    // the pre-executor hand-rolled loop below.
    let plan = match opts.exec {
        ExecMode::Graph => Some(build_plan(
            &cfg,
            l_blocks,
            step_policy(r_deg, opts.overlap, opts.sp_bytes),
            p,
        )?),
        ExecMode::Legacy => None,
    };

    let mut run = AttemptRun::new();
    for i in 0..n_steps {
        let step = start_step + i;
        if let Err(e) = coll.barrier() {
            return Ok(abort_attempt(run, step, coll, &e));
        }
        let t0 = std::time::Instant::now();
        let _sp_step = obs::span("step");
        // marshal current params once (device buffers — leak-free
        // execute_b path, see runtime::Engine::buffer docs)
        let mut block_lits: Vec<Vec<PjRtBuffer>> = Vec::with_capacity(l_blocks);
        for l in 0..l_blocks {
            let mut v = Vec::with_capacity(9);
            for t in 0..9 {
                v.push(engine.buffer_f32(&params[1 + l * 9 + t], &bf_spec.inputs[t])?);
            }
            block_lits.push(v);
        }
        let embed_lit = engine.buffer_f32(&params[0], &hl_spec.inputs[0])?;
        let normf_lit = engine.buffer_f32(&params[n_params - 1], &hl_spec.inputs[1])?;

        // gradient store shared with the comm pool: [n_params] tensors
        let gstore: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(
            params.iter().map(|q| vec![0.0f32; q.len()]).collect(),
        ));

        let (loss, ar_chunks) = if let Some(plan) = &plan {
            // ---------------- graph-driven step ----------------
            // run_native walks the verified DAG: forward At nodes bind to
            // embed + block kernels, Head to the loss turnaround (which
            // also hosts the planned-fault hook), backward At nodes to
            // block_bwd + gradient accumulation, Ar nodes to comm-pool
            // submission; MoE nodes are realized inside the fused block
            // kernels (see [`GraphStep`]).
            let mut gs = GraphStep {
                engine: &mut engine,
                corpus: &mut corpus,
                coll,
                pool: &pool,
                reg,
                gstore: &gstore,
                ar_fail: &ar_fail,
                params: &params,
                block_lits: &block_lits,
                embed_lit: &embed_lit,
                normf_lit: &normf_lit,
                hl_spec: &hl_spec,
                x_spec: &x_spec,
                embed_fwd: &embed_fwd,
                block_fwd: &block_fwd,
                block_bwd: &block_bwd,
                head_loss: &head_loss,
                toks: Vec::with_capacity(r_deg),
                acts: Vec::with_capacity(r_deg),
                dxs: Vec::with_capacity(r_deg),
                loss: 0.0,
                ar_chunks: 0,
                killed: false,
                w,
                step,
                r_deg,
                l_blocks,
                n_params,
                bm,
                n_tok,
                chunk_elems,
                inv_r,
                sp_fwd: Some(obs::span("fwd")),
                t_fwd: std::time::Instant::now(),
                sp_bwd: None,
                t_bwd: std::time::Instant::now(),
            };
            match plan.run_native(&mut gs) {
                Ok(()) => {}
                Err(_) if gs.killed => {
                    // the planned fault surfaced inside the Head node
                    run.stopped_at = Some(step);
                    run.killed = true;
                    return Ok(run);
                }
                Err(e) => return Err(e),
            }
            let GraphStep {
                toks,
                dxs,
                loss,
                mut ar_chunks,
                sp_bwd,
                t_bwd,
                ..
            } = gs;
            // epilogue outside the DAG (embedding/head tensors are not
            // per-block nodes): embedding gradient via the input-lookup
            // path, then the embed + normf ARs under the same tag scheme
            // (layer ids l_blocks, l_blocks+1)
            for r in 0..r_deg {
                let outs = engine.run(&embed_bwd, &[&toks[r], &dxs[r]])?;
                let mut g = lock_recover(&gstore);
                axpy(&mut g[0], outs[0].f32(), 1.0);
            }
            let mut ar_tag = |layer: usize, tensor: usize, chunk: usize| -> u64 {
                (((step * (l_blocks + 2) + layer) as u64) << 24)
                    | ((tensor as u64) << 16)
                    | chunk as u64
            };
            ar_chunks += exec::enqueue_tensor_ar(&pool, coll, &gstore, w, &ar_fail, 0, l_blocks, chunk_elems, &mut ar_tag);
            ar_chunks += exec::enqueue_tensor_ar(&pool, coll, &gstore, w, &ar_fail, n_params - 1, l_blocks + 1, chunk_elems, &mut ar_tag);
            drop(sp_bwd);
            reg.histogram("bwd_s").observe(t_bwd.elapsed().as_secs_f64());
            (loss, ar_chunks)
        } else {
            // ---------------- legacy hand-rolled step ----------------
            // forward (all microbatches)
            let sp_fwd = obs::span("fwd");
            let t_fwd = std::time::Instant::now();
            let mut toks: Vec<HostTensor> = Vec::with_capacity(r_deg);
            let mut acts: Vec<Vec<HostTensor>> = Vec::with_capacity(r_deg); // acts[r][l]
            for _ in 0..r_deg {
                let t = HostTensor::I32(corpus.batch(bm, n_tok));
                let mut xs = Vec::with_capacity(l_blocks + 1);
                let x0 = engine.run(&embed_fwd, &[&HostTensor::F32(params[0].clone()), &t])?;
                xs.push(x0.into_iter().next().ok_or_else(|| anyhow!("{embed_fwd}: no output"))?);
                for l in 0..l_blocks {
                    let x_lit = engine.buffer_f32(xs[l].f32(), &x_spec)?;
                    let mut inp: Vec<&PjRtBuffer> = block_lits[l].iter().collect();
                    inp.push(&x_lit);
                    let y = engine.run_buffers(&block_fwd, &inp)?;
                    xs.push(y.into_iter().next().ok_or_else(|| anyhow!("{block_fwd}: no output"))?);
                }
                toks.push(t);
                acts.push(xs);
            }
            drop(sp_fwd);
            reg.histogram("fwd_s").observe(t_fwd.elapsed().as_secs_f64());

            // planned kill: this worker crashes mid-step; survivors
            // detect it through their deadline-bounded collective ops
            if coll.should_die(w, step) {
                eprintln!("[ft] worker {w} dying at step {step} (planned fault)");
                coll.mark_dead(w);
                run.stopped_at = Some(step);
                run.killed = true;
                return Ok(run);
            }

            // head / loss
            let t_head = std::time::Instant::now();
            let mut loss = 0.0f32;
            let mut dxs: Vec<HostTensor> = Vec::with_capacity(r_deg);
            for r in 0..r_deg {
                let xf_lit = engine.buffer_f32(acts[r][l_blocks].f32(), &hl_spec.inputs[2])?;
                let tok_lit = engine.buffer(&toks[r], &hl_spec.inputs[3])?;
                let outs =
                    engine.run_buffers(&head_loss, &[&embed_lit, &normf_lit, &xf_lit, &tok_lit])?;
                loss += outs[0].scalar_f32() * inv_r;
                let mut dxf = outs[1].f32().to_vec();
                scale(&mut dxf, inv_r);
                dxs.push(HostTensor::F32(dxf));
                let mut g = lock_recover(&gstore);
                axpy(&mut g[0], outs[2].f32(), inv_r);
                axpy(&mut g[n_params - 1], outs[3].f32(), inv_r);
            }
            reg.histogram("head_s").observe(t_head.elapsed().as_secs_f64());

            // backward per block, AR overlap
            let sp_bwd = obs::span("bwd");
            let t_bwd = std::time::Instant::now();
            let mut ar_chunks = 0usize;
            let mut ar_tag = |layer: usize, tensor: usize, chunk: usize| -> u64 {
                (((step * (l_blocks + 2) + layer) as u64) << 24)
                    | ((tensor as u64) << 16)
                    | chunk as u64
            };
            for l in (0..l_blocks).rev() {
                for r in 0..r_deg {
                    let x_lit = engine.buffer_f32(acts[r][l].f32(), &x_spec)?;
                    let dy_lit = engine.buffer_f32(dxs[r].f32(), &x_spec)?;
                    let mut inp: Vec<&PjRtBuffer> = block_lits[l].iter().collect();
                    inp.push(&x_lit);
                    inp.push(&dy_lit);
                    let outs = engine.run_buffers(&block_bwd, &inp)?;
                    {
                        let mut g = lock_recover(&gstore);
                        for t in 0..9 {
                            axpy(&mut g[1 + l * 9 + t], outs[t].f32(), 1.0);
                        }
                    }
                    dxs[r] = outs.into_iter().nth(9).ok_or_else(|| anyhow!("{block_bwd}: missing dx output"))?;
                }
                if opts.overlap {
                    ar_chunks += exec::enqueue_block_ar(&pool, coll, &gstore, w, &ar_fail, l, 1 + l * 9, 9, chunk_elems, &mut ar_tag);
                }
            }
            // embedding gradient via the input-lookup path
            for r in 0..r_deg {
                let outs = engine.run(&embed_bwd, &[&toks[r], &dxs[r]])?;
                let mut g = lock_recover(&gstore);
                axpy(&mut g[0], outs[0].f32(), 1.0);
            }
            // embed + normf AR (layer ids l_blocks, l_blocks+1)
            if opts.overlap {
                ar_chunks += exec::enqueue_tensor_ar(&pool, coll, &gstore, w, &ar_fail, 0, l_blocks, chunk_elems, &mut ar_tag);
                ar_chunks += exec::enqueue_tensor_ar(&pool, coll, &gstore, w, &ar_fail, n_params - 1, l_blocks + 1, chunk_elems, &mut ar_tag);
            } else {
                // centralized: everything after backward completes
                for l in (0..l_blocks).rev() {
                    ar_chunks += exec::enqueue_block_ar(&pool, coll, &gstore, w, &ar_fail, l, 1 + l * 9, 9, chunk_elems, &mut ar_tag);
                }
                ar_chunks += exec::enqueue_tensor_ar(&pool, coll, &gstore, w, &ar_fail, 0, l_blocks, chunk_elems, &mut ar_tag);
                ar_chunks += exec::enqueue_tensor_ar(&pool, coll, &gstore, w, &ar_fail, n_params - 1, l_blocks + 1, chunk_elems, &mut ar_tag);
            }
            drop(sp_bwd);
            reg.histogram("bwd_s").observe(t_bwd.elapsed().as_secs_f64());
            (loss, ar_chunks)
        };
        reg.counter("ar_chunks").add(ar_chunks as u64);
        {
            let _sp = obs::span("ar_drain");
            let t_drain = std::time::Instant::now();
            pool.drain();
            reg.histogram("drain_s").observe(t_drain.elapsed().as_secs_f64());
        }
        if let Some(e) = lock_recover(&ar_fail).take() {
            return Ok(abort_attempt(run, step, coll, &e));
        }

        // ---------------- update ----------------
        {
            let t_upd = std::time::Instant::now();
            let mut g = lock_recover(&gstore);
            let scale_w = 1.0 / p as f32;
            for gv in g.iter_mut() {
                scale(gv, scale_w);
            }
            sgd_update(&mut params, &mut moms, &g, opts.lr, opts.momentum);
            reg.histogram("update_s").observe(t_upd.elapsed().as_secs_f64());
        }
        let mut lbuf = [loss];
        // scalar loss mean, not a gradient chunk: not part of the scheduled DAG
        // flowmoe-lint: allow(trainer_direct_ar) — see above
        if let Err(e) = coll.all_reduce_sum(w, u64::MAX - step as u64, &mut lbuf) {
            return Ok(abort_attempt(run, step, coll, &e));
        }
        let mean_loss = lbuf[0] / p as f32;
        run.losses.push(mean_loss);
        let secs = t0.elapsed().as_secs_f64();
        run.step_secs.push(secs);
        reg.histogram("step_s").observe(secs);
        reg.counter("worker_steps").inc();
        if w == 0 {
            reg.gauge("loss_last").set(mean_loss as f64);
        }
        if w == 0 && opts.log_every > 0 && step % opts.log_every == 0 {
            eprintln!(
                "[dp{p} {cfg} overlap={}] step {step}: loss {mean_loss:.4} ({:.2}s)",
                opts.overlap,
                t0.elapsed().as_secs_f64()
            );
        }

        // ---------------- checkpoint ----------------
        if opts.ckpt_every > 0 && (step + 1) % opts.ckpt_every == 0 {
            if let Some(dir) = &opts.ckpt_dir {
                // publish my data cursor, then rendezvous so rank 0
                // snapshots a consistent cross-worker state
                lock_recover(rng_slots)[w] = corpus.rng_state();
                if let Err(e) = coll.barrier() {
                    return Ok(abort_attempt(run, step, coll, &e));
                }
                if w == 0 {
                    let _sp = obs::span("ckpt_save");
                    let ck = Checkpoint {
                        cfg: cfg.clone(),
                        step: (step + 1) as u64,
                        corpus_rng: lock_recover(rng_slots).clone(),
                        params: params.clone(),
                        moms: moms.clone(),
                    };
                    ft::save_atomic(dir, &ck).map_err(|e| anyhow!("checkpoint save: {e}"))?;
                }
            }
        }
        // CI crash hook: exit the whole process after the checkpoint
        if w == 0 && opts.die_at == Some(step + 1) {
            eprintln!("[ft] simulated process crash after step {} (--die-at)", step + 1);
            std::process::exit(3);
        }
    }
    run.final_params = params;
    Ok(run)
}

// `scale`/`axpy` for the gradient hot loops come from
// `backend::kernels` (dispatch-routed: f32x8 under the simd tier).
// The chunked-AR submission helpers moved to `exec::enqueue_tensor_ar` /
// `exec::enqueue_block_ar`: they are the runtime realization of the
// DAG's Ar nodes, owned by the executor that schedules them.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_matches_formula() {
        let mut p = vec![vec![1.0f32, 2.0]];
        let mut m = vec![vec![0.5f32, 0.0]];
        let g = vec![vec![0.1f32, -0.2]];
        sgd_update(&mut p, &mut m, &g, 0.1, 0.9);
        // m = 0.9*0.5 + 0.1 = 0.55 ; p = 1 - 0.1*0.55 = 0.945
        assert!((m[0][0] - 0.55).abs() < 1e-6);
        assert!((p[0][0] - 0.945).abs() < 1e-6);
        assert!((m[0][1] + 0.2).abs() < 1e-6);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = vec![1.0f32, 2.0];
        axpy(&mut a, &[10.0, 20.0], 0.5);
        assert_eq!(a, vec![6.0, 12.0]);
        scale(&mut a, 2.0);
        assert_eq!(a, vec![12.0, 24.0]);
    }
}
