//! `flowmoe-lint`: dependency-free source lint enforcing repo invariants
//! the compiler can't (see rust/README.md §Static analysis for the rule
//! catalog). A small hand-rolled Rust lexer (strings, raw strings, char
//! vs. lifetime, nested block comments, numbers) feeds token-level rules:
//!
//! * **FL001** `unsafe` without a nearby `SAFETY` comment.
//! * **FL002** unscoped thread creation (`std::thread::spawn` /
//!   `thread::Builder`) or `rayon` outside `sweep/scope.rs`. Scoped
//!   threads (`thread::scope` + `s.spawn`) are allowed everywhere: they
//!   cannot leak past their caller.
//! * **FL003** `HashMap` in the deterministic hot modules (`sched`,
//!   `sim`, `cost`, `cluster`): iteration order there must be stable
//!   run-to-run or simulated timelines stop being reproducible.
//! * **FL004** `.unwrap()` / `.expect()` in library code (tests exempt).
//! * **FL005** every `pub fn par_*`/`*simd*` kernel in
//!   `backend/kernels.rs` must be exercised by name in
//!   `tests/kernel_conformance.rs` or `tests/kernel_parity.rs`.
//! * **FL006** unbounded zero-arg `.recv()` in the distributed-runtime
//!   modules (`commpool`, `cluster`, `serve`): a dead peer must surface
//!   as a typed error within a deadline, never as a hang — use
//!   `recv_timeout` (or the deadline-bounded `Collective` ops).
//! * **FL007** direct `Collective`/`CommPool` all-reduce submission
//!   (`.all_reduce_sum(` / `.submit_ar(`) inside `trainer/`: gradient AR
//!   chunks must be enqueued by executing the policy-built DAG (the
//!   `exec` module), never ad hoc — otherwise the executed schedule can
//!   silently diverge from the one `analyze::check_dag` certified.
//!
//! An audited site is silenced with a magic comment on the same line or
//! the line above: `// flowmoe-lint: allow(<rule-name>) — <why>` where
//! `<rule-name>` is `safety`, `thread_spawn`, `hashmap`, `unwrap`,
//! `kernel_coverage`, `recv_unbounded` or `trainer_direct_ar`. Code under
//! `#[cfg(test)]` is exempt from every
//! rule. The lexer is intentionally approximate (it does not parse
//! Rust), but it is token-exact for the constructs the rules inspect —
//! in particular, nothing inside string literals or comments can ever
//! match a rule pattern.

use std::collections::{HashMap, HashSet};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// One lint hit: file, 1-based line, stable rule id, and what to do.
#[derive(Clone, Debug)]
pub struct LintFinding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for LintFinding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Lint every `.rs` file under `<root>/src` (library + binaries; the
/// crate's `tests/`, `benches/` and `examples/` are exempt by design).
/// `root` is the crate directory containing `src/` and `tests/`.
pub fn lint_repo(root: &Path) -> Result<Vec<LintFinding>> {
    let mut files = Vec::new();
    collect_rs(&root.join("src"), &mut files)?;
    files.sort();

    // identifiers exercised by the kernel test suites (FL005)
    let mut test_idents: HashSet<String> = HashSet::new();
    for tf in ["tests/kernel_conformance.rs", "tests/kernel_parity.rs"] {
        if let Ok(src) = fs::read_to_string(root.join(tf)) {
            for t in lex(&src) {
                if let Tok::Ident(name) = t.tok {
                    test_idents.insert(name);
                }
            }
        }
    }

    let mut out = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(f).with_context(|| format!("reading {}", f.display()))?;
        out.extend(lint_file(&rel, &src, &test_idents));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))?;
    for e in entries {
        let path = e?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// lexer
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
    /// Any string/char/byte literal — contents never inspected.
    Str,
    Comment(String),
    Num,
    Lifetime,
}

#[derive(Clone, Debug)]
struct Token {
    tok: Tok,
    line: usize,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Consume a `"..."` body starting *after* the opening quote; returns the
/// index just past the closing quote.
fn scan_string(b: &[char], mut i: usize, line: &mut usize) -> usize {
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Consume a raw string body `"..."###` starting at the opening quote,
/// with `hashes` trailing `#`s required to close.
fn scan_raw_string(b: &[char], mut i: usize, hashes: usize, line: &mut usize) -> usize {
    i += 1; // opening quote
    while i < b.len() {
        if b[i] == '\n' {
            *line += 1;
        }
        if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&c| c == '#').count() == hashes {
            return i + 1 + hashes;
        }
        i += 1;
    }
    i
}

/// Consume a char/byte-char body starting after the opening `'`.
fn scan_char(b: &[char], mut i: usize) -> usize {
    if i < b.len() && b[i] == '\\' {
        i += 1;
        if i < b.len() && b[i] == 'u' {
            while i < b.len() && b[i] != '}' {
                i += 1;
            }
        }
        i += 1;
    } else if i < b.len() {
        i += 1;
    }
    if i < b.len() && b[i] == '\'' {
        i += 1;
    }
    i
}

fn lex(src: &str) -> Vec<Token> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        let start_line = line;
        // comments
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let s = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            toks.push(Token { tok: Tok::Comment(b[s..i].iter().collect()), line: start_line });
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let s = i;
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Token { tok: Tok::Comment(b[s..i].iter().collect()), line: start_line });
            continue;
        }
        // string / char literals
        if c == '"' {
            i = scan_string(&b, i + 1, &mut line);
            toks.push(Token { tok: Tok::Str, line: start_line });
            continue;
        }
        if c == '\'' {
            let is_lifetime = i + 1 < n
                && is_ident_start(b[i + 1])
                && !(i + 2 < n && b[i + 2] == '\'');
            if is_lifetime {
                let mut j = i + 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                toks.push(Token { tok: Tok::Lifetime, line: start_line });
                i = j;
            } else {
                i = scan_char(&b, i + 1);
                toks.push(Token { tok: Tok::Str, line: start_line });
            }
            continue;
        }
        // prefixed literals and identifiers
        if is_ident_start(c) {
            // r"…", r#"…"#, r#ident
            if c == 'r' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '#') {
                let mut k = i + 1;
                while k < n && b[k] == '#' {
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    i = scan_raw_string(&b, k, k - (i + 1), &mut line);
                    toks.push(Token { tok: Tok::Str, line: start_line });
                    continue;
                }
                if k == i + 2 && k < n && is_ident_start(b[k]) {
                    // raw identifier r#name
                    let mut j = k;
                    while j < n && is_ident_cont(b[j]) {
                        j += 1;
                    }
                    toks.push(Token {
                        tok: Tok::Ident(b[k..j].iter().collect()),
                        line: start_line,
                    });
                    i = j;
                    continue;
                }
            }
            // b"…", b'…'
            if c == 'b' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '\'') {
                if b[i + 1] == '"' {
                    i = scan_string(&b, i + 2, &mut line);
                } else {
                    i = scan_char(&b, i + 2);
                }
                toks.push(Token { tok: Tok::Str, line: start_line });
                continue;
            }
            // br"…", br#"…"#
            if c == 'b' && i + 2 < n && b[i + 1] == 'r' && (b[i + 2] == '"' || b[i + 2] == '#') {
                let mut k = i + 2;
                while k < n && b[k] == '#' {
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    i = scan_raw_string(&b, k, k - (i + 2), &mut line);
                    toks.push(Token { tok: Tok::Str, line: start_line });
                    continue;
                }
            }
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Token { tok: Tok::Ident(b[i..j].iter().collect()), line: start_line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n {
                if is_ident_cont(b[j]) {
                    j += 1;
                } else if b[j] == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1; // decimal point, but stop before `..` ranges
                } else {
                    break;
                }
            }
            toks.push(Token { tok: Tok::Num, line: start_line });
            i = j;
            continue;
        }
        toks.push(Token { tok: Tok::Punct(c), line: start_line });
        i += 1;
    }
    toks
}

// ---------------------------------------------------------------------------
// per-file analysis
// ---------------------------------------------------------------------------

struct FileLint {
    toks: Vec<Token>,
    /// Indices into `toks` of non-comment tokens, in order.
    code: Vec<usize>,
    /// Per-token: inside a `#[cfg(test)]` item (rules exempt).
    masked: Vec<bool>,
    /// Per-token: part of an attribute `#[...]` / `#![...]`.
    attr: Vec<bool>,
    /// Line -> upper-cased concatenated comment text on that line.
    comment_upper: HashMap<usize, String>,
    /// Lines carrying at least one non-attribute code token.
    plain_code_lines: HashSet<usize>,
}

impl FileLint {
    fn new(src: &str) -> FileLint {
        let toks = lex(src);
        let code: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, t)| !matches!(t.tok, Tok::Comment(_)))
            .map(|(i, _)| i)
            .collect();
        let mut masked = vec![false; toks.len()];
        let mut attr = vec![false; toks.len()];

        let is_punct =
            |p: usize, c: char| -> bool { matches!(toks[code[p]].tok, Tok::Punct(x) if x == c) };
        let is_ident = |p: usize, name: &str| -> bool {
            matches!(&toks[code[p]].tok, Tok::Ident(x) if x == name)
        };
        // `]` position closing the attribute whose `[` is at code pos `open`
        let bracket_end = |open: usize| -> usize {
            let mut depth = 0i32;
            let mut p = open;
            while p < code.len() {
                if is_punct(p, '[') {
                    depth += 1;
                }
                if is_punct(p, ']') {
                    depth -= 1;
                    if depth == 0 {
                        return p;
                    }
                }
                p += 1;
            }
            code.len().saturating_sub(1)
        };

        let mut k = 0usize;
        while k < code.len() {
            if !is_punct(k, '#') {
                k += 1;
                continue;
            }
            let mut open = k + 1;
            if open < code.len() && is_punct(open, '!') {
                open += 1; // inner attribute #![...]
            }
            if open >= code.len() || !is_punct(open, '[') {
                k += 1;
                continue;
            }
            let end = bracket_end(open);
            for p in k..=end {
                attr[code[p]] = true;
            }
            let is_cfg_test = end == open + 4
                && is_ident(open + 1, "cfg")
                && is_punct(open + 2, '(')
                && is_ident(open + 3, "test")
                && is_punct(open + 4, ')');
            if !is_cfg_test {
                k = end + 1;
                continue;
            }
            // skip any further attributes on the same item
            let mut m = end + 1;
            while m < code.len() && is_punct(m, '#') {
                let mut o = m + 1;
                if o < code.len() && is_punct(o, '!') {
                    o += 1;
                }
                if o >= code.len() || !is_punct(o, '[') {
                    break;
                }
                let e = bracket_end(o);
                for p in m..=e {
                    attr[code[p]] = true;
                }
                m = e + 1;
            }
            // mask the item: through its matching `}` or a top-level `;`
            let item_start = m;
            let mut depth = 0i32;
            while m < code.len() {
                if is_punct(m, '{') {
                    depth += 1;
                } else if is_punct(m, '}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if is_punct(m, ';') && depth == 0 {
                    break;
                }
                m += 1;
            }
            for p in item_start..=m.min(code.len().saturating_sub(1)) {
                masked[code[p]] = true;
            }
            k = m + 1;
        }

        let mut comment_upper: HashMap<usize, String> = HashMap::new();
        for t in &toks {
            if let Tok::Comment(text) = &t.tok {
                for (off, seg) in text.split('\n').enumerate() {
                    comment_upper
                        .entry(t.line + off)
                        .or_default()
                        .push_str(&seg.to_ascii_uppercase());
                }
            }
        }
        let mut plain_code_lines = HashSet::new();
        for &i in &code {
            if !attr[i] {
                plain_code_lines.insert(toks[i].line);
            }
        }
        FileLint { toks, code, masked, attr, comment_upper, plain_code_lines }
    }

    fn ctok(&self, p: usize) -> &Tok {
        &self.toks[self.code[p]].tok
    }

    fn cline(&self, p: usize) -> usize {
        self.toks[self.code[p]].line
    }

    fn cmasked(&self, p: usize) -> bool {
        self.masked[self.code[p]]
    }

    fn is_punct(&self, p: usize, c: char) -> bool {
        matches!(*self.ctok(p), Tok::Punct(x) if x == c)
    }

    fn ident(&self, p: usize) -> Option<&str> {
        match self.ctok(p) {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    /// `// flowmoe-lint: allow(<name>)` on the same line or the line above.
    fn allowed(&self, line: usize, name: &str) -> bool {
        let needle = format!("FLOWMOE-LINT: ALLOW({})", name.to_ascii_uppercase());
        [line, line.saturating_sub(1)]
            .iter()
            .any(|l| self.comment_upper.get(l).is_some_and(|t| t.contains(&needle)))
    }

    /// A `SAFETY` comment on this line, or on a run of comment/attribute/
    /// blank lines immediately above it (a plain-code line breaks the run).
    fn has_safety_near(&self, line: usize) -> bool {
        let hit = |l: usize| self.comment_upper.get(&l).is_some_and(|t| t.contains("SAFETY"));
        if hit(line) {
            return true;
        }
        let mut l = line;
        for _ in 0..10 {
            if l <= 1 {
                break;
            }
            l -= 1;
            if hit(l) {
                return true;
            }
            if self.plain_code_lines.contains(&l) {
                break;
            }
        }
        false
    }
}

fn lint_file(rel: &str, src: &str, kernel_test_idents: &HashSet<String>) -> Vec<LintFinding> {
    let fl = FileLint::new(src);
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, message: String| {
        out.push(LintFinding { file: rel.to_string(), line, rule, message });
    };

    // FL001: unsafe requires a SAFETY comment
    for p in 0..fl.code.len() {
        if fl.cmasked(p) || fl.attr[fl.code[p]] {
            continue;
        }
        if fl.ident(p) == Some("unsafe") {
            let line = fl.cline(p);
            if !fl.has_safety_near(line) && !fl.allowed(line, "safety") {
                push(line, "FL001", "`unsafe` without a covering `// SAFETY:` comment".into());
            }
        }
    }

    // FL002: unscoped thread creation / rayon outside sweep/scope.rs
    if !rel.ends_with("sweep/scope.rs") {
        for p in 0..fl.code.len() {
            if fl.cmasked(p) {
                continue;
            }
            let line = fl.cline(p);
            if fl.ident(p) == Some("rayon") && !fl.allowed(line, "thread_spawn") {
                push(line, "FL002", "rayon is off-limits; use sweep::scope".into());
            }
            if fl.ident(p) == Some("thread")
                && p + 3 < fl.code.len()
                && fl.is_punct(p + 1, ':')
                && fl.is_punct(p + 2, ':')
                && matches!(fl.ident(p + 3), Some("spawn") | Some("Builder"))
                && !fl.allowed(line, "thread_spawn")
            {
                push(
                    line,
                    "FL002",
                    "unscoped thread creation outside sweep/scope.rs (use thread::scope)".into(),
                );
            }
        }
    }

    // FL003: HashMap in deterministic hot modules
    let hot = ["/sched/", "/sim/", "/cost/", "/cluster/", "/serve/"];
    if hot.iter().any(|d| rel.contains(d)) {
        for p in 0..fl.code.len() {
            if fl.cmasked(p) {
                continue;
            }
            if fl.ident(p) == Some("HashMap") {
                let line = fl.cline(p);
                if !fl.allowed(line, "hashmap") {
                    push(
                        line,
                        "FL003",
                        "HashMap in a deterministic hot module (iteration order is unstable); use a Vec or BTreeMap".into(),
                    );
                }
            }
        }
    }

    // FL004: unwrap/expect in library code
    for p in 0..fl.code.len() {
        if fl.cmasked(p) {
            continue;
        }
        if matches!(fl.ident(p), Some("unwrap") | Some("expect"))
            && p > 0
            && fl.is_punct(p - 1, '.')
            && p + 1 < fl.code.len()
            && fl.is_punct(p + 1, '(')
        {
            let line = fl.cline(p);
            if !fl.allowed(line, "unwrap") {
                push(
                    line,
                    "FL004",
                    "unwrap()/expect() in library code; propagate anyhow::Result or add an audited allow".into(),
                );
            }
        }
    }

    // FL006: unbounded zero-arg .recv() in the distributed runtime —
    // the hang class: a dead peer blocks the caller forever
    let bounded = ["/commpool/", "/cluster/", "/serve/"];
    if bounded.iter().any(|d| rel.contains(d)) {
        for p in 0..fl.code.len() {
            if fl.cmasked(p) {
                continue;
            }
            if fl.ident(p) == Some("recv")
                && p > 0
                && fl.is_punct(p - 1, '.')
                && p + 2 < fl.code.len()
                && fl.is_punct(p + 1, '(')
                && fl.is_punct(p + 2, ')')
            {
                let line = fl.cline(p);
                if !fl.allowed(line, "recv_unbounded") {
                    push(
                        line,
                        "FL006",
                        "unbounded .recv() in a distributed-runtime module; use recv_timeout so a dead peer errors within a deadline".into(),
                    );
                }
            }
        }
    }

    // FL007: direct all-reduce submission in the trainer — gradient AR
    // chunks must come from executing the policy-built DAG (the `exec`
    // module owns the enqueue helpers and the Plan driver), or the
    // executed schedule can diverge from the certified one
    if rel.contains("/trainer/") {
        for p in 0..fl.code.len() {
            if fl.cmasked(p) {
                continue;
            }
            if matches!(fl.ident(p), Some("all_reduce_sum") | Some("submit_ar"))
                && p > 0
                && fl.is_punct(p - 1, '.')
                && p + 1 < fl.code.len()
                && fl.is_punct(p + 1, '(')
            {
                let line = fl.cline(p);
                if !fl.allowed(line, "trainer_direct_ar") {
                    push(
                        line,
                        "FL007",
                        "direct Collective AR call in the trainer; route it through exec (enqueue_* / Plan::run_native) or add an audited allow".into(),
                    );
                }
            }
        }
    }

    // FL005: kernel coverage
    if rel.ends_with("backend/kernels.rs") {
        for p in 0..fl.code.len() {
            if fl.cmasked(p) || fl.ident(p) != Some("fn") || p + 1 >= fl.code.len() {
                continue;
            }
            let Some(name) = fl.ident(p + 1) else { continue };
            if !(name.starts_with("par_") || name.contains("simd")) {
                continue;
            }
            // only pub kernels: walk back over qualifiers to find `pub`
            let mut is_pub = false;
            let mut q = p;
            for _ in 0..8 {
                if q == 0 {
                    break;
                }
                q -= 1;
                match fl.ctok(q) {
                    Tok::Ident(s)
                        if matches!(
                            s.as_str(),
                            "unsafe" | "const" | "extern" | "crate" | "super" | "self" | "in"
                        ) => {}
                    Tok::Ident(s) if s == "pub" => {
                        is_pub = true;
                        break;
                    }
                    Tok::Str | Tok::Punct('(') | Tok::Punct(')') => {}
                    _ => break,
                }
            }
            if !is_pub {
                continue;
            }
            if !kernel_test_idents.contains(name) {
                let line = fl.cline(p + 1);
                if !fl.allowed(line, "kernel_coverage") {
                    push(
                        line,
                        "FL005",
                        format!(
                            "kernel `{name}` is not exercised by tests/kernel_conformance.rs or tests/kernel_parity.rs"
                        ),
                    );
                }
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_str(rel: &str, src: &str) -> Vec<LintFinding> {
        lint_file(rel, src, &HashSet::new())
    }

    #[test]
    fn lexer_strings_comments_and_chars() {
        let src = r##"
// a comment with unsafe unwrap thread::spawn
/* block /* nested */ still comment */
fn f<'a>(x: &'a str) -> char {
    let _s = "unsafe .unwrap() thread::spawn";
    let _r = r#"raw "quoted" unsafe"#;
    let _b = b"bytes";
    let _n = 1.5e-3 + 0x1F;
    'x'
}
"##;
        let toks = lex(src);
        // no Ident token from inside strings/comments
        assert!(!toks
            .iter()
            .any(|t| matches!(&t.tok, Tok::Ident(s) if s == "unsafe" || s == "unwrap")));
        assert!(toks.iter().any(|t| matches!(t.tok, Tok::Lifetime)));
        assert_eq!(lint_str("src/x.rs", src).len(), 0);
    }

    #[test]
    fn unsafe_without_safety_flagged() {
        let bad = "pub fn f() { unsafe { g(); } }\n";
        let vs = lint_str("src/x.rs", bad);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "FL001");

        let good = "pub fn f() {\n    // SAFETY: g has no requirements\n    unsafe { g(); }\n}\n";
        assert_eq!(lint_str("src/x.rs", good).len(), 0);

        // SAFETY above an attribute line still covers the fn
        let attr = "// SAFETY: callers must check for AVX2\n#[target_feature(enable = \"avx2\")]\npub unsafe fn g() {}\n";
        assert_eq!(lint_str("src/x.rs", attr).len(), 0);

        // a plain-code line between comment and unsafe breaks coverage
        let far = "// SAFETY: stale\nlet x = 1;\nunsafe { g(); }\n";
        assert_eq!(lint_str("src/x.rs", far).len(), 1);
    }

    #[test]
    fn unwrap_flagged_and_allow_honored() {
        let bad = "fn f() { x.unwrap(); y.expect(\"m\"); }\n";
        let vs = lint_str("src/x.rs", bad);
        assert_eq!(vs.len(), 2, "{vs:?}");
        assert!(vs.iter().all(|v| v.rule == "FL004"));

        let allowed =
            "fn f() {\n    // flowmoe-lint: allow(unwrap) — invariant: non-empty\n    x.unwrap();\n}\n";
        assert_eq!(lint_str("src/x.rs", allowed).len(), 0);

        // unwrap_or and friends are different identifiers
        assert_eq!(lint_str("src/x.rs", "fn f() { x.unwrap_or(0); }\n").len(), 0);
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); unsafe { g(); } }\n}\n";
        assert_eq!(lint_str("src/x.rs", src).len(), 0);
        // ...but code after the masked item is linted again
        let after = "#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }\nfn f() { y.unwrap(); }\n";
        assert_eq!(lint_str("src/x.rs", after).len(), 1);
    }

    #[test]
    fn thread_rules() {
        let vs = lint_str("src/x.rs", "fn f() { std::thread::spawn(|| {}); }\n");
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, "FL002");
        assert_eq!(
            lint_str("src/x.rs", "fn f() { let b = thread::Builder::new(); }\n").len(),
            1
        );
        // scoped threads are fine anywhere
        let scoped = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert_eq!(lint_str("src/x.rs", scoped).len(), 0);
        // the scope shim itself is exempt
        assert_eq!(
            lint_str("src/sweep/scope.rs", "fn f() { std::thread::spawn(|| {}); }\n").len(),
            0
        );
    }

    #[test]
    fn hashmap_only_flagged_in_hot_modules() {
        let src = "use std::collections::HashMap;\n";
        let vs = lint_str("src/sched/mod.rs", src);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, "FL003");
        assert_eq!(lint_str("src/sim/mod.rs", src).len(), 1);
        assert_eq!(lint_str("src/serve/sched.rs", src).len(), 1, "serving hot path is covered");
        assert_eq!(lint_str("src/analyze/mod.rs", src).len(), 0);
        assert_eq!(lint_str("src/commpool/mod.rs", src).len(), 0);
    }

    #[test]
    fn unbounded_recv_flagged_in_distributed_modules() {
        let src = "fn f(rx: Receiver<u8>) { let _ = rx.recv(); }\n";
        let vs = lint_str("src/commpool/mod.rs", src);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "FL006");
        assert_eq!(lint_str("src/cluster/mod.rs", src).len(), 1);
        assert_eq!(lint_str("src/serve/ep.rs", src).len(), 1);
        // other modules may poll however they like
        assert_eq!(lint_str("src/sweep/mod.rs", src).len(), 0);
        // recv with arguments (e.g. the Collective's tagged recv) and
        // recv_timeout are bounded by construction
        assert_eq!(
            lint_str("src/commpool/mod.rs", "fn f() { coll.recv(0, 1, 7); }\n").len(),
            0
        );
        assert_eq!(
            lint_str("src/serve/ep.rs", "fn f() { rx.recv_timeout(d); }\n").len(),
            0
        );
        // audited allow is honored
        let allowed = "fn f(rx: Receiver<u8>) {\n    // flowmoe-lint: allow(recv_unbounded) — sender outlives rx\n    let _ = rx.recv();\n}\n";
        assert_eq!(lint_str("src/commpool/mod.rs", allowed).len(), 0);
    }

    #[test]
    fn trainer_direct_ar_confined_to_executor() {
        let src = "fn f() { coll.all_reduce_sum(w, tag, &mut buf); pool.submit_ar(job); }\n";
        let vs = lint_str("src/trainer/mod.rs", src);
        assert_eq!(vs.len(), 2, "{vs:?}");
        assert!(vs.iter().all(|v| v.rule == "FL007"));
        // the executor module owns these calls; other modules are out of scope
        assert_eq!(lint_str("src/exec/mod.rs", src).len(), 0);
        assert_eq!(lint_str("src/commpool/mod.rs", src).len(), 0);
        // a TaskRunner impl *defines* submit_ar — a definition is not a call
        let def = "impl TaskRunner for S { fn submit_ar(&mut self, t: &Task) -> Result<()> { Ok(()) } }\n";
        assert_eq!(lint_str("src/trainer/mod.rs", def).len(), 0);
        // audited allow is honored (the trainer's scalar loss mean)
        let allowed = "fn f() {\n    // flowmoe-lint: allow(trainer_direct_ar) — scalar loss mean\n    coll.all_reduce_sum(w, tag, &mut b);\n}\n";
        assert_eq!(lint_str("src/trainer/mod.rs", allowed).len(), 0);
    }

    #[test]
    fn kernel_coverage_rule() {
        let kernels = "pub fn par_matmul() {}\nfn simd_shim() {}\npub fn plain() {}\n";
        let mut idents = HashSet::new();
        let vs = lint_file("src/backend/kernels.rs", kernels, &idents);
        // only the pub par_* fn is required; the private simd shim and the
        // unprefixed pub fn are not
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "FL005");
        idents.insert("par_matmul".to_string());
        assert_eq!(lint_file("src/backend/kernels.rs", kernels, &idents).len(), 0);
        // the rule only applies to kernels.rs
        assert_eq!(lint_file("src/other.rs", kernels, &HashSet::new()).len(), 0);
    }

    #[test]
    fn pub_unsafe_kernels_detected_through_qualifiers() {
        let kernels = "// SAFETY: caller checks avx2\npub unsafe fn par_axpy_simd() {}\n";
        let vs = lint_file("src/backend/kernels.rs", kernels, &HashSet::new());
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].rule, "FL005");
    }

    /// The repo itself must be lint-clean: this is the same gate CI runs
    /// via the `flowmoe-lint` binary, enforced from `cargo test` too.
    #[test]
    fn repo_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = lint_repo(root).expect("lint walk");
        let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(findings.is_empty(), "lint findings:\n{}", report.join("\n"));
    }
}
