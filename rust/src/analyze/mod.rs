//! Static schedule verification — the `flowmoe analyze` pass.
//!
//! The paper's claims rest on the *structure* of the multi-type task
//! pipeline (Sec. 3.2, Eqs. 2–5, Algorithm 2): MHA+gating, dispatch A2A,
//! expert compute, combine A2A and priority-scheduled AR chunks must obey
//! a strict dependency/priority discipline. The dynamic checker
//! ([`crate::sim::verify_timeline`]) only validates whatever happened to
//! be simulated; this module proves well-formedness *without* simulating:
//!
//! * [`check_dag_structure`] — policy-free invariants every DAG must hold
//!   (id/duration sanity, duplicate edges, cycle-freeness via a real DFS,
//!   AR-chunk FIFO discipline). This is what [`crate::sim::simulate`]
//!   asserts in debug builds.
//! * [`check_dag`] — the full rule set for a `(Dag, Policy)` pair: stream
//!   legality, connectivity, per-layer pipeline shape, fwd/bwd phase
//!   ordering, and the policy-dependent AR-chunk partition checks (which
//!   reuse [`crate::commpool::partition_ranges`], the runtime's own
//!   PARTITION procedure).
//! * [`check_schedule`] — builds the DAG for `(cfg, costs, policy)` and
//!   additionally reconciles AR chunk counts/bytes against the cost model.
//!
//! Rule families **cascade**: each family assumes every earlier family
//! holds, and `check_dag` stops at the first failing family. That keeps
//! later checks free of defensive re-validation and makes every broken
//! fixture trigger exactly one rule family (see the unit tests).
//!
//! The second prong of the static layer — the dependency-free source lint
//! behind the `flowmoe-lint` binary — lives in [`lint`].

pub mod lint;

use std::fmt;

use crate::commpool::partition_ranges;
use crate::config::ModelCfg;
use crate::cost::TaskCosts;
use crate::sched::{build_dag, Policy};
use crate::tasks::{Dag, Phase, Stream, Task, TaskId, TaskKind};

/// Analyzer rule families. One stable id per family (the catalog is
/// documented in rust/README.md §Static analysis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rule {
    /// Basic structure: ids consecutive, finite non-negative durations,
    /// dep ids in range, no self- or duplicate edges.
    Structure,
    /// Cycle-freeness of the dependency relation (DFS, not just id-range).
    Cycle,
    /// Stream legality: compute kinds on the compute stream, A2A on the
    /// comm stream, AR on comm (or the concurrent AR channel when the
    /// policy enables one).
    StreamLegality,
    /// Pipeline shape: per layer, fwd AT -> D -> E -> C and the mirrored
    /// backward chain, with the subtask counts the policy implies.
    PipelineShape,
    /// Phase ordering: every forward task FIFO-ranks before the head,
    /// every backward task after it.
    PhaseOrder,
    /// AR-chunk discipline: chunks partition the block's gradient tensor
    /// exactly, priorities are FIFO-monotone (the paper's tensor-chunk
    /// priority mechanism cannot invert), and no chunk depends on a
    /// later-seq task.
    ArChunks,
    /// Connectivity: no task is disconnected from the iteration's
    /// dependency structure (orphan tasks would silently skew makespans).
    Connectivity,
}

impl Rule {
    /// Stable rule id, e.g. `S006-ar-chunk`.
    pub fn id(self) -> &'static str {
        match self {
            Rule::Structure => "S001-structure",
            Rule::Cycle => "S002-cycle",
            Rule::StreamLegality => "S003-stream",
            Rule::PipelineShape => "S004-shape",
            Rule::PhaseOrder => "S005-phase",
            Rule::ArChunks => "S006-ar-chunk",
            Rule::Connectivity => "S007-connectivity",
        }
    }
}

/// One analyzer finding: which rule, which tasks, and why.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: Rule,
    pub tasks: Vec<TaskId>,
    pub message: String,
}

impl Violation {
    fn new(rule: Rule, tasks: Vec<TaskId>, message: String) -> Violation {
        Violation { rule, tasks, message }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] tasks {:?}: {}", self.rule.id(), self.tasks, self.message)
    }
}

/// Policy-free structural invariants every DAG must satisfy before it is
/// simulated: `Dag::validate`'s checks (split into the Structure and
/// Cycle families) plus the AR FIFO discipline — AR chunk priorities
/// strictly increase in creation order, and no AR chunk waits on a task
/// the FIFO ranks *after* it (which could deadlock a priority executor).
///
/// Deliberately excludes stream legality and the AR-below-A2A seq band:
/// those depend on the policy (and the simulator's own unit fixtures
/// violate them on purpose).
pub fn check_dag_structure(dag: &Dag) -> Vec<Violation> {
    let mut vs = Vec::new();
    let n = dag.tasks.len();
    for (i, t) in dag.tasks.iter().enumerate() {
        if t.id != i {
            vs.push(Violation::new(
                Rule::Structure,
                vec![i],
                format!("task at index {i} has id {}", t.id),
            ));
        }
        if !(t.dur.is_finite() && t.dur >= 0.0) {
            vs.push(Violation::new(
                Rule::Structure,
                vec![i],
                format!("task {} ({}) has bad duration {}", t.id, t.kind, t.dur),
            ));
        }
        for (j, &d) in t.deps.iter().enumerate() {
            if d >= n {
                vs.push(Violation::new(
                    Rule::Structure,
                    vec![i],
                    format!("task {i} depends on out-of-range task {d} (n={n})"),
                ));
            } else if d == i {
                vs.push(Violation::new(
                    Rule::Structure,
                    vec![i],
                    format!("task {i} depends on itself"),
                ));
            } else if t.deps[..j].contains(&d) {
                vs.push(Violation::new(
                    Rule::Structure,
                    vec![i, d],
                    format!("task {i} has a duplicate dep edge to task {d}"),
                ));
            }
        }
    }
    if !vs.is_empty() {
        return vs;
    }
    if let Some(cycle) = dag.find_cycle() {
        let path: Vec<String> = cycle.iter().map(|id| dag.tasks[*id].kind.to_string()).collect();
        vs.push(Violation::new(
            Rule::Cycle,
            cycle,
            format!("dependency cycle: {}", path.join(" -> ")),
        ));
        return vs;
    }
    // AR FIFO discipline (Algorithm 2 runs AR chunks in creation order)
    let mut prev_ar: Option<&Task> = None;
    for t in dag.tasks.iter().filter(|t| t.kind.is_ar()) {
        if let Some(p) = prev_ar {
            if t.seq <= p.seq {
                vs.push(Violation::new(
                    Rule::ArChunks,
                    vec![p.id, t.id],
                    format!(
                        "AR priority inversion: {} (seq {}) not above earlier {} (seq {})",
                        t.kind, t.seq, p.kind, p.seq
                    ),
                ));
            }
        }
        prev_ar = Some(t);
        for &d in &t.deps {
            let dep = &dag.tasks[d];
            if !dep.kind.is_ar() && dep.seq >= t.seq {
                vs.push(Violation::new(
                    Rule::ArChunks,
                    vec![t.id, d],
                    format!(
                        "AR chunk {} (seq {}) depends on later-seq task {} (seq {})",
                        t.kind, t.seq, dep.kind, dep.seq
                    ),
                ));
            }
        }
    }
    vs
}

/// Full static verification of a `(Dag, Policy)` pair. Returns the first
/// failing rule family's violations (empty = provably well-formed under
/// every rule). See the module docs for the cascade rationale.
pub fn check_dag(dag: &Dag, policy: &Policy) -> Vec<Violation> {
    let vs = check_dag_structure(dag);
    if !vs.is_empty() {
        return vs;
    }
    let vs = check_streams(dag, policy);
    if !vs.is_empty() {
        return vs;
    }
    let vs = check_connectivity(dag);
    if !vs.is_empty() {
        return vs;
    }
    let vs = check_shape(dag, policy);
    if !vs.is_empty() {
        return vs;
    }
    let vs = check_phase_order(dag);
    if !vs.is_empty() {
        return vs;
    }
    check_ar_policy(dag, policy)
}

/// Build the iteration DAG for `(cfg, costs, policy)`, statically verify
/// it, and reconcile the AR chunking against the cost model (chunk count
/// per block, total bytes == the block's replicated-gradient tensor).
/// Returns the DAG so callers can reuse it.
pub fn check_schedule(cfg: &ModelCfg, costs: &TaskCosts, policy: &Policy) -> (Dag, Vec<Violation>) {
    let dag = build_dag(cfg, costs, policy);
    let mut vs = check_dag(&dag, policy);
    if vs.is_empty() {
        let want_n = if policy.pipe_ar { costs.ar_chunks(policy.sp_bytes) } else { 1 };
        for l in 0..cfg.l {
            let chunks: Vec<&Task> = dag
                .tasks
                .iter()
                .filter(|t| t.kind.is_ar() && t.kind.layer() == Some(l))
                .collect();
            let total: f64 = chunks.iter().map(|t| t.bytes).sum();
            if chunks.len() != want_n {
                vs.push(Violation::new(
                    Rule::ArChunks,
                    chunks.iter().map(|t| t.id).collect(),
                    format!(
                        "layer {l}: {} AR chunks, cost model implies {want_n}",
                        chunks.len()
                    ),
                ));
            }
            if (total - costs.ar_bytes).abs() > costs.ar_bytes * 1e-9 + 1e-6 {
                vs.push(Violation::new(
                    Rule::ArChunks,
                    chunks.iter().map(|t| t.id).collect(),
                    format!(
                        "layer {l}: AR chunks sum to {total} bytes, tensor is {} bytes",
                        costs.ar_bytes
                    ),
                ));
            }
        }
    }
    (dag, vs)
}

/// The policy matrix the `flowmoe analyze` sweep exercises: the paper's
/// five baselines, the FlowMoE ablations (AT-only, AR-only), full FlowMoE
/// at the requested R plus the degenerate R=1 edge case, the concurrent-
/// channel variant and the +ScheMoE combination — 11 policies covering
/// every `(pipe_moe, pipe_at, pipe_ar, ar_channel)` combination the
/// builders can produce.
pub fn policy_matrix(r: usize, sp_bytes: f64) -> Vec<Policy> {
    vec![
        Policy::vanilla_ep(),
        Policy::faster_moe(r),
        Policy::tutel(r),
        Policy::sche_moe(r),
        Policy::fs_moe(r),
        Policy::flow_moe_at(r),
        Policy::flow_moe_ar(r, sp_bytes),
        Policy::flow_moe(r, sp_bytes),
        Policy::flow_moe(1, sp_bytes),
        Policy::flow_moe_cc(r, sp_bytes),
        Policy::flow_moe_sche(r, sp_bytes),
    ]
}

// ---------------------------------------------------------------------------
// rule families (internal; see check_dag for the cascade order)
// ---------------------------------------------------------------------------

fn pidx(p: Phase) -> usize {
    match p {
        Phase::Fwd => 0,
        Phase::Bwd => 1,
    }
}

/// S003: every task kind on its legal stream.
fn check_streams(dag: &Dag, policy: &Policy) -> Vec<Violation> {
    let mut vs = Vec::new();
    for t in &dag.tasks {
        let ok = match t.kind {
            TaskKind::At { .. } | TaskKind::Exp { .. } | TaskKind::Head => {
                t.stream == Stream::Compute
            }
            TaskKind::Disp { .. } | TaskKind::Comb { .. } => t.stream == Stream::Comm,
            TaskKind::Ar { .. } => {
                t.stream == Stream::Comm || (policy.ar_channel && t.stream == Stream::ArComm)
            }
        };
        if !ok {
            vs.push(Violation::new(
                Rule::StreamLegality,
                vec![t.id],
                format!("{} illegally placed on stream {:?}", t.kind, t.stream),
            ));
        }
    }
    vs
}

/// S007: single weakly-connected component (union-find over dep edges).
fn check_connectivity(dag: &Dag) -> Vec<Violation> {
    let n = dag.tasks.len();
    if n <= 1 {
        return Vec::new();
    }
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut [usize], mut x: usize) -> usize {
        while parent[x] != x {
            parent[x] = parent[parent[x]]; // path halving
            x = parent[x];
        }
        x
    }
    for t in &dag.tasks {
        for &d in &t.deps {
            let (a, b) = (find(&mut parent, t.id), find(&mut parent, d));
            if a != b {
                parent[a] = b;
            }
        }
    }
    let roots: Vec<usize> = (0..n).map(|i| find(&mut parent, i)).collect();
    let mut size = vec![0usize; n];
    for &r in &roots {
        size[r] += 1;
    }
    let main_root = (0..n).fold(0, |best, i| if size[i] > size[best] { i } else { best });
    let orphans: Vec<TaskId> = (0..n).filter(|&i| roots[i] != main_root).collect();
    if orphans.is_empty() {
        Vec::new()
    } else {
        let msg = format!(
            "{} task(s) disconnected from the iteration DAG (e.g. {})",
            orphans.len(),
            dag.tasks[orphans[0]].kind
        );
        vec![Violation::new(Rule::Connectivity, orphans, msg)]
    }
}

/// S004: per-layer pipeline shape — subtask counts match the policy's
/// (R, pipe_moe, pipe_at), and every task carries its Eq. 2–5 / 6a–6e
/// pipeline-predecessor dependency.
fn check_shape(dag: &Dag, policy: &Policy) -> Vec<Violation> {
    let mut vs = Vec::new();
    let r_moe = if policy.pipe_moe { policy.r.max(1) } else { 1 };
    let r_at = if policy.pipe_at { r_moe } else { 1 };
    let l_blocks = dag
        .tasks
        .iter()
        .filter_map(|t| t.kind.layer())
        .max()
        .map_or(0, |l| l + 1);

    let heads: Vec<&Task> = dag
        .tasks
        .iter()
        .filter(|t| matches!(t.kind, TaskKind::Head))
        .collect();
    if heads.len() != 1 || l_blocks == 0 {
        return vec![Violation::new(
            Rule::PipelineShape,
            heads.iter().map(|t| t.id).collect(),
            format!(
                "expected 1 HEAD task and >=1 layer, found {} head(s), {} layer(s)",
                heads.len(),
                l_blocks
            ),
        )];
    }
    let head = heads[0];

    // subtask counts per (layer, phase, kind class)
    const KIND_NAMES: [&str; 4] = ["AT", "D", "E", "C"];
    let mut counts = vec![[[0usize; 4]; 2]; l_blocks];
    for t in &dag.tasks {
        let (l, k, ph) = match t.kind {
            TaskKind::At { l, phase, .. } => (l, 0, phase),
            TaskKind::Disp { l, phase, .. } => (l, 1, phase),
            TaskKind::Exp { l, phase, .. } => (l, 2, phase),
            TaskKind::Comb { l, phase, .. } => (l, 3, phase),
            TaskKind::Ar { .. } | TaskKind::Head => continue,
        };
        counts[l][pidx(ph)][k] += 1;
    }
    let want = [r_at, r_moe, r_moe, r_moe];
    for (l, per_phase) in counts.iter().enumerate() {
        for (pi, pname) in [(0, "fwd"), (1, "bwd")] {
            for k in 0..4 {
                if per_phase[pi][k] != want[k] {
                    vs.push(Violation::new(
                        Rule::PipelineShape,
                        Vec::new(),
                        format!(
                            "layer {l} {pname}: {} {} subtasks, policy {} implies {}",
                            per_phase[pi][k], KIND_NAMES[k], policy.name, want[k]
                        ),
                    ));
                }
            }
        }
    }
    if !vs.is_empty() {
        return vs; // dep-presence checks below assume the counts are right
    }

    // Eq. 2–5 (fwd) / 6a–6e (bwd) pipeline-predecessor dependencies. When
    // r_at < r_moe the monolithic AT feeds/collects every MoE subtask, so
    // the r index is not constrained across the AT<->MoE boundary.
    let dep_any = |t: &Task, f: &dyn Fn(TaskKind) -> bool| -> bool {
        t.deps.iter().any(|&d| f(dag.tasks[d].kind))
    };
    let same_r = r_at == r_moe;
    for t in &dag.tasks {
        let ok = match t.kind {
            TaskKind::At { l, phase: Phase::Fwd, .. } => {
                l == 0
                    || dep_any(t, &|k| {
                        matches!(k, TaskKind::Comb { l: dl, phase: Phase::Fwd, .. } if dl == l - 1)
                    })
            }
            TaskKind::Disp { l, r, phase: Phase::Fwd } => dep_any(t, &|k| {
                matches!(k, TaskKind::At { l: dl, r: dr, phase: Phase::Fwd }
                    if dl == l && (!same_r || dr == r))
            }),
            TaskKind::Exp { l, r, phase: Phase::Fwd } => dep_any(t, &|k| {
                matches!(k, TaskKind::Disp { l: dl, r: dr, phase: Phase::Fwd } if dl == l && dr == r)
            }),
            TaskKind::Comb { l, r, phase: Phase::Fwd } => dep_any(t, &|k| {
                matches!(k, TaskKind::Exp { l: dl, r: dr, phase: Phase::Fwd } if dl == l && dr == r)
            }),
            TaskKind::Comb { l, r, phase: Phase::Bwd } => {
                if l == l_blocks - 1 {
                    dep_any(t, &|k| matches!(k, TaskKind::Head))
                } else {
                    dep_any(t, &|k| {
                        matches!(k, TaskKind::At { l: dl, r: dr, phase: Phase::Bwd }
                            if dl == l + 1 && (!same_r || dr == r))
                    })
                }
            }
            TaskKind::Exp { l, r, phase: Phase::Bwd } => dep_any(t, &|k| {
                matches!(k, TaskKind::Comb { l: dl, r: dr, phase: Phase::Bwd } if dl == l && dr == r)
            }),
            TaskKind::Disp { l, r, phase: Phase::Bwd } => dep_any(t, &|k| {
                matches!(k, TaskKind::Exp { l: dl, r: dr, phase: Phase::Bwd } if dl == l && dr == r)
            }),
            TaskKind::At { l, r, phase: Phase::Bwd } => dep_any(t, &|k| {
                matches!(k, TaskKind::Disp { l: dl, r: dr, phase: Phase::Bwd }
                    if dl == l && (!same_r || dr == r))
            }),
            TaskKind::Ar { .. } | TaskKind::Head => true, // S006 / below
        };
        if !ok {
            vs.push(Violation::new(
                Rule::PipelineShape,
                vec![t.id],
                format!("{} is missing its pipeline-predecessor dependency", t.kind),
            ));
        }
    }
    // the head must collect every last-layer combine (fwd -> loss)
    for r in 0..r_moe {
        let has = head.deps.iter().any(|&d| {
            matches!(dag.tasks[d].kind, TaskKind::Comb { l, r: dr, phase: Phase::Fwd }
                if l == l_blocks - 1 && dr == r)
        });
        if !has {
            vs.push(Violation::new(
                Rule::PipelineShape,
                vec![head.id],
                format!("HEAD does not depend on Cf[{},{r}]", l_blocks - 1),
            ));
        }
    }
    vs
}

/// S005: FIFO ranks respect the fwd -> head -> bwd phase order (Eqs. 2–5
/// rank forward tasks before the turnaround and backward tasks after it;
/// AR chunks live in their own FIFO band and are checked by S006).
fn check_phase_order(dag: &Dag) -> Vec<Violation> {
    let mut vs = Vec::new();
    let head_seq = match dag.tasks.iter().find(|t| matches!(t.kind, TaskKind::Head)) {
        Some(h) => h.seq,
        None => return vs, // shape (S004) already requires a head
    };
    for t in &dag.tasks {
        let phase = match t.kind {
            TaskKind::At { phase, .. }
            | TaskKind::Disp { phase, .. }
            | TaskKind::Exp { phase, .. }
            | TaskKind::Comb { phase, .. } => phase,
            TaskKind::Ar { .. } | TaskKind::Head => continue,
        };
        let bad = match phase {
            Phase::Fwd => t.seq >= head_seq,
            Phase::Bwd => t.seq <= head_seq,
        };
        if bad {
            vs.push(Violation::new(
                Rule::PhaseOrder,
                vec![t.id],
                format!(
                    "{} (seq {}) FIFO-ranks on the wrong side of HEAD (seq {head_seq})",
                    t.kind, t.seq
                ),
            ));
        }
    }
    vs
}

/// S006 (policy half): AR chunks sit strictly below the A2A/compute FIFO
/// band, every block's chunks are an exact equal partition of its tensor
/// (cross-checked against the runtime's own PARTITION procedure,
/// [`partition_ranges`]), chunk indices are contiguous, pipelined chunks
/// wait on the whole block's AT-backward (Appendix H), and centralized
/// policies emit exactly one post-backward AR per block.
fn check_ar_policy(dag: &Dag, policy: &Policy) -> Vec<Violation> {
    let mut vs = Vec::new();
    let l_blocks = dag
        .tasks
        .iter()
        .filter_map(|t| t.kind.layer())
        .max()
        .map_or(0, |l| l + 1);

    let max_nonar_seq = dag
        .tasks
        .iter()
        .filter(|t| !t.kind.is_ar())
        .map(|t| t.seq)
        .max()
        .unwrap_or(0);

    let mut per_layer: Vec<Vec<&Task>> = vec![Vec::new(); l_blocks];
    let mut at_bwd: Vec<Vec<TaskId>> = vec![Vec::new(); l_blocks];
    for t in &dag.tasks {
        match t.kind {
            TaskKind::Ar { l, .. } => per_layer[l].push(t),
            TaskKind::At { l, phase: Phase::Bwd, .. } => at_bwd[l].push(t.id),
            _ => {}
        }
        if t.kind.is_ar() && t.seq <= max_nonar_seq {
            vs.push(Violation::new(
                Rule::ArChunks,
                vec![t.id],
                format!(
                    "{} (seq {}) not strictly below the A2A/compute FIFO band (max non-AR seq {max_nonar_seq})",
                    t.kind, t.seq
                ),
            ));
        }
    }

    let mut layer_totals: Vec<f64> = Vec::with_capacity(l_blocks);
    for (l, chunks) in per_layer.iter().enumerate() {
        if chunks.is_empty() {
            vs.push(Violation::new(
                Rule::ArChunks,
                Vec::new(),
                format!("layer {l} has no all-reduce task"),
            ));
            layer_totals.push(0.0);
            continue;
        }
        let ids: Vec<TaskId> = chunks.iter().map(|t| t.id).collect();
        let mut idxs: Vec<usize> = chunks
            .iter()
            .map(|t| match t.kind {
                TaskKind::Ar { c, .. } => c,
                _ => 0,
            })
            .collect();
        idxs.sort_unstable();
        if idxs != (0..chunks.len()).collect::<Vec<usize>>() {
            vs.push(Violation::new(
                Rule::ArChunks,
                ids.clone(),
                format!("layer {l}: AR chunk indices not contiguous 0..{}", chunks.len()),
            ));
            layer_totals.push(chunks.iter().map(|t| t.bytes).sum());
            continue;
        }
        let total: f64 = chunks.iter().map(|t| t.bytes).sum();
        layer_totals.push(total);

        if policy.pipe_ar {
            // exact equal partition of the block tensor (gaps/overlaps in
            // the chunk cover show up as a deviating chunk size)
            let want = total / chunks.len() as f64;
            for t in chunks {
                if (t.bytes - want).abs() > want * 1e-9 + 1e-6 {
                    vs.push(Violation::new(
                        Rule::ArChunks,
                        vec![t.id],
                        format!(
                            "layer {l}: {} carries {} bytes, breaking the equal {}-byte partition of {} bytes",
                            t.kind, t.bytes, want, total
                        ),
                    ));
                }
            }
            // cross-check against the runtime's PARTITION procedure: a
            // greedy partition at the largest chunk size must reproduce
            // the chunk count (skip degenerate tiny tensors where integer
            // rounding dominates; real tensors are MBs)
            let chunk_max = chunks.iter().map(|t| t.bytes).fold(0.0, f64::max);
            let n_sq = (chunks.len() * chunks.len()) as f64;
            if chunk_max >= 1024.0 && n_sq <= total {
                let n_greedy =
                    partition_ranges(total.round() as usize, chunk_max.ceil() as usize).len();
                if n_greedy != chunks.len() {
                    vs.push(Violation::new(
                        Rule::ArChunks,
                        ids.clone(),
                        format!(
                            "layer {l}: {} chunks, but PARTITION({:.0}, {:.0}) yields {n_greedy}",
                            chunks.len(),
                            total,
                            chunk_max
                        ),
                    ));
                }
            }
            // S_p ceiling and minimality: no chunk exceeds S_p, and one
            // fewer chunk of size S_p could not cover the tensor
            if policy.sp_bytes.is_finite() && policy.sp_bytes > 0.0 {
                let sp = policy.sp_bytes;
                for t in chunks {
                    if t.bytes > sp * (1.0 + 1e-9) + 1.0 {
                        vs.push(Violation::new(
                            Rule::ArChunks,
                            vec![t.id],
                            format!("layer {l}: {} carries {} bytes > S_p = {sp}", t.kind, t.bytes),
                        ));
                    }
                }
                if (chunks.len() as f64 - 1.0) * sp >= total * (1.0 + 1e-9) + 1.0 {
                    vs.push(Violation::new(
                        Rule::ArChunks,
                        ids.clone(),
                        format!(
                            "layer {l}: {} chunks is not minimal for {} bytes at S_p = {sp}",
                            chunks.len(),
                            total
                        ),
                    ));
                }
            }
            // gradient availability (Appendix H): each chunk waits on the
            // whole block's AT-backward
            for t in chunks {
                for &a in &at_bwd[l] {
                    if !t.deps.contains(&a) {
                        vs.push(Violation::new(
                            Rule::ArChunks,
                            vec![t.id, a],
                            format!(
                                "{} does not wait on {} (gradient availability)",
                                t.kind, dag.tasks[a].kind
                            ),
                        ));
                    }
                }
            }
        } else {
            // centralized baseline: one whole-tensor AR per block, after
            // the backward pass (i.e. it has at least one dependency)
            if chunks.len() != 1 {
                vs.push(Violation::new(
                    Rule::ArChunks,
                    ids.clone(),
                    format!("layer {l}: centralized policy emitted {} AR chunks", chunks.len()),
                ));
            }
            for t in chunks {
                if t.deps.is_empty() {
                    vs.push(Violation::new(
                        Rule::ArChunks,
                        vec![t.id],
                        format!("{} has no dependency anchoring it after backward", t.kind),
                    ));
                }
            }
        }
    }
    // every block all-reduces the same replicated tensor
    if let Some(&first) = layer_totals.first() {
        for (l, &total) in layer_totals.iter().enumerate().skip(1) {
            if (total - first).abs() > first.abs() * 1e-9 + 1e-6 {
                vs.push(Violation::new(
                    Rule::ArChunks,
                    per_layer[l].iter().map(|t| t.id).collect(),
                    format!(
                        "layer {l} all-reduces {total} bytes, layer 0 all-reduces {first}"
                    ),
                ));
            }
        }
    }
    vs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{preset, ClusterProfile};
    use crate::sim::{simulate, verify_timeline};

    fn fixture(policy: &Policy) -> (Dag, TaskCosts, ModelCfg) {
        let cfg = preset("GPT2-Tiny-MoE").expect("preset");
        let costs = TaskCosts::build(&cfg, &ClusterProfile::cluster1(16));
        let dag = build_dag(&cfg, &costs, policy);
        (dag, costs, cfg)
    }

    #[track_caller]
    fn only_rule(vs: &[Violation], rule: Rule) {
        assert!(!vs.is_empty(), "expected violations of {:?}", rule);
        for v in vs {
            assert_eq!(v.rule, rule, "unexpected family: {v}");
        }
    }

    fn first_task<F: Fn(&Task) -> bool>(dag: &Dag, f: F) -> TaskId {
        dag.tasks.iter().position(|t| f(t)).expect("fixture task")
    }

    #[test]
    fn matrix_has_eleven_policies() {
        let pols = policy_matrix(2, 2.5e6);
        assert_eq!(pols.len(), 11);
    }

    #[test]
    fn clean_for_every_matrix_policy() {
        for pol in policy_matrix(2, 2.5e6) {
            let (dag, costs, cfg) = fixture(&pol);
            let vs = check_dag(&dag, &pol);
            assert!(vs.is_empty(), "{} ({}): {}", pol.name, pol.r, vs[0]);
            let (_, vs) = check_schedule(&cfg, &costs, &pol);
            assert!(vs.is_empty(), "{} schedule: {}", pol.name, vs[0]);
        }
    }

    #[test]
    fn cycle_fixture_triggers_cycle_rule() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let last = dag.tasks.len() - 1;
        dag.tasks[0].deps.push(last); // last transitively depends on 0
        only_rule(&check_dag(&dag, &pol), Rule::Cycle);
    }

    #[test]
    fn duplicate_edge_fixture_triggers_structure_rule() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let i = first_task(&dag, |t| !t.deps.is_empty());
        let d = dag.tasks[i].deps[0];
        dag.tasks[i].deps.push(d);
        only_rule(&check_dag(&dag, &pol), Rule::Structure);
    }

    #[test]
    fn wrong_stream_fixture_triggers_stream_rule() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let i = first_task(&dag, |t| matches!(t.kind, TaskKind::At { .. }));
        dag.tasks[i].stream = Stream::Comm;
        let vs = check_dag(&dag, &pol);
        only_rule(&vs, Rule::StreamLegality);
        assert!(vs[0].tasks.contains(&i));
    }

    #[test]
    fn ar_channel_stream_is_policy_gated() {
        // the same ArComm placement is legal under FlowMoE-CC and illegal
        // under strict FlowMoE
        let cc = Policy::flow_moe_cc(2, 2.5e6);
        let (dag, _, _) = fixture(&cc);
        assert!(check_dag(&dag, &cc).is_empty());
        let strict = Policy::flow_moe(2, 2.5e6);
        only_rule(&check_dag(&dag, &strict), Rule::StreamLegality);
    }

    #[test]
    fn ar_partition_gap_fixture_triggers_ar_rule() {
        let pol = Policy::flow_moe(2, 0.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let i = first_task(&dag, |t| t.kind.is_ar());
        dag.tasks[i].bytes *= 0.5; // a gap in the chunk cover
        only_rule(&check_dag(&dag, &pol), Rule::ArChunks);
    }

    #[test]
    fn ar_priority_inversion_fixture_triggers_ar_rule() {
        let pol = Policy::flow_moe(2, 0.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let ars: Vec<TaskId> =
            dag.tasks.iter().filter(|t| t.kind.is_ar()).map(|t| t.id).collect();
        assert!(ars.len() >= 2, "fixture needs >=2 AR chunks");
        let (a, b) = (ars[0], ars[1]);
        let tmp = dag.tasks[a].seq;
        dag.tasks[a].seq = dag.tasks[b].seq;
        dag.tasks[b].seq = tmp;
        only_rule(&check_dag(&dag, &pol), Rule::ArChunks);
    }

    #[test]
    fn ar_below_a2a_band_is_enforced() {
        // an AR chunk ranked inside the A2A FIFO band is an inversion of
        // Algorithm 2's priority rule even if AR-internal order is intact
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let i = first_task(&dag, |t| t.kind.is_ar());
        dag.tasks[i].seq = 0;
        only_rule(&check_dag(&dag, &pol), Rule::ArChunks);
    }

    #[test]
    fn orphan_fixture_triggers_connectivity_rule() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let id = dag.tasks.len();
        dag.tasks.push(Task {
            id,
            kind: TaskKind::Exp { l: 0, r: 0, phase: Phase::Fwd },
            stream: Stream::Compute,
            dur: 1e-4,
            deps: vec![],
            seq: 3,
            bytes: 0.0,
        });
        let vs = check_dag(&dag, &pol);
        only_rule(&vs, Rule::Connectivity);
        assert!(vs[0].tasks.contains(&id));
    }

    #[test]
    fn phase_order_fixture_triggers_phase_rule() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let i = first_task(&dag, |t| {
            matches!(t.kind, TaskKind::Disp { phase: Phase::Fwd, .. })
        });
        let max_nonar = dag
            .tasks
            .iter()
            .filter(|t| !t.kind.is_ar())
            .map(|t| t.seq)
            .max()
            .unwrap_or(0);
        dag.tasks[i].seq = max_nonar + 10; // fwd task ranked after the head
        only_rule(&check_dag(&dag, &pol), Rule::PhaseOrder);
    }

    #[test]
    fn missing_pipeline_dep_fixture_triggers_shape_rule() {
        let pol = Policy::flow_moe(2, 2.5e6);
        let (mut dag, _, _) = fixture(&pol);
        let i = first_task(&dag, |t| {
            matches!(t.kind, TaskKind::Exp { phase: Phase::Fwd, .. })
        });
        let keep: Vec<TaskId> = dag.tasks[i]
            .deps
            .iter()
            .copied()
            .filter(|&d| !matches!(dag.tasks[d].kind, TaskKind::Disp { .. }))
            .collect();
        assert!(!keep.is_empty(), "chain dep keeps the task connected");
        dag.tasks[i].deps = keep;
        only_rule(&check_dag(&dag, &pol), Rule::PipelineShape);
    }

    #[test]
    fn structure_check_is_policy_free() {
        // the simulator's own unit fixtures put HEAD on the comm stream
        // and rank AR below A2A — the debug-build hook (structure only)
        // must accept that, while the full policy check rejects it
        let mut d = Dag::new();
        d.add(TaskKind::Head, Stream::Comm, 1.0, vec![], 0);
        assert!(check_dag_structure(&d).is_empty());
        assert!(!check_dag(&d, &Policy::vanilla_ep()).is_empty());
    }

    #[test]
    fn violations_display_rule_id() {
        let v = Violation::new(Rule::ArChunks, vec![3, 4], "msg".into());
        let s = format!("{v}");
        assert!(s.contains("S006-ar-chunk") && s.contains("[3, 4]"), "{s}");
    }

    #[test]
    fn static_and_dynamic_verifiers_agree_on_clean_dags() {
        for pol in policy_matrix(2, 2.5e6) {
            let (dag, _, _) = fixture(&pol);
            assert!(check_dag(&dag, &pol).is_empty(), "{}", pol.name);
            let tl = simulate(&dag);
            verify_timeline(&dag, &tl).expect("timeline");
        }
    }
}
