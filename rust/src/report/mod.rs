//! Reporting: markdown table rendering (paper-table shaped), ASCII
//! histograms (Fig. 6) and the bench harness (no criterion offline —
//! median-of-N with warmup, printing paper-vs-measured rows).

use std::time::Instant;

/// A simple column-aligned markdown table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        let seps: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&line(&seps, &widths));
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// ASCII histogram of samples (Fig. 6's speedup statistic).
pub fn histogram(title: &str, samples: &[f64], n_bins: usize, width: usize) -> String {
    if samples.is_empty() {
        return format!("{title}: (no samples)\n");
    }
    let lo = samples.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = samples.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut bins = vec![0usize; n_bins];
    for &s in samples {
        let i = (((s - lo) / span) * n_bins as f64) as usize;
        bins[i.min(n_bins - 1)] += 1;
    }
    let maxc = bins.iter().copied().max().unwrap_or(1).max(1);
    let mut out = format!("\n{title} (n={}, mean={:.3})\n", samples.len(), crate::util::mean(samples));
    for (i, &c) in bins.iter().enumerate() {
        let a = lo + span * i as f64 / n_bins as f64;
        let b = lo + span * (i + 1) as f64 / n_bins as f64;
        let bar = "#".repeat((c as f64 / maxc as f64 * width as f64).round() as usize);
        out.push_str(&format!("  [{a:5.2}, {b:5.2})  {c:4}  {bar}\n"));
    }
    out
}

/// Render a metrics snapshot ([`crate::obs::RegistrySnapshot`]) as
/// one line per metric — counters, gauges, then histograms with count /
/// total / p50 / p95 / p99. `flowmoe train` prints these as `#`-prefixed
/// comment lines after the per-step CSV.
pub fn stats_lines(snap: &crate::obs::RegistrySnapshot) -> Vec<String> {
    let mut out = Vec::new();
    for (name, v) in &snap.counters {
        out.push(format!("{name} = {v}"));
    }
    for (name, v) in &snap.gauges {
        out.push(format!("{name} = {v:.4}"));
    }
    for h in &snap.hists {
        out.push(format!(
            "{}: n={} total={:.3}s p50={:.4}s p95={:.4}s p99={:.4}s",
            h.name, h.count, h.total_s, h.p50_s, h.p95_s, h.p99_s
        ));
    }
    out
}

/// Time a closure: `reps` runs after `warmup`, returns per-run seconds.
pub fn time_runs<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Median wall-clock seconds of `reps` runs after warmup.
pub fn bench_median<F: FnMut()>(warmup: usize, reps: usize, f: F) -> f64 {
    crate::util::median(&time_runs(warmup, reps, f))
}

/// Paper-vs-measured comparison row helper: value, paper band, verdict.
pub fn band_check(measured: f64, lo: f64, hi: f64) -> &'static str {
    if measured >= lo && measured <= hi {
        "in-band"
    } else if measured < lo {
        "below"
    } else {
        "above"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("| longer | 22   |"));
        assert!(r.contains("## T"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_width() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn histogram_counts_all() {
        let h = histogram("h", &[1.0, 1.1, 1.2, 2.0, 2.0], 2, 10);
        assert!(h.contains("n=5"));
    }

    #[test]
    fn bench_median_positive() {
        let m = bench_median(1, 3, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            std::hint::black_box(s);
        });
        assert!(m >= 0.0);
    }

    #[test]
    fn stats_lines_cover_all_metric_kinds() {
        let reg = crate::obs::Registry::new();
        reg.counter("steps").add(3);
        reg.gauge("loss_last").set(1.25);
        reg.histogram("step_s").observe(0.5);
        let lines = stats_lines(&reg.snapshot());
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("steps = 3"));
        assert!(lines[1].contains("loss_last = 1.2500"));
        assert!(lines[2].contains("step_s: n=1"));
        assert!(lines[2].contains("p99="));
    }

    #[test]
    fn band_check_verdicts() {
        assert_eq!(band_check(1.5, 1.0, 2.0), "in-band");
        assert_eq!(band_check(0.5, 1.0, 2.0), "below");
        assert_eq!(band_check(2.5, 1.0, 2.0), "above");
    }
}
