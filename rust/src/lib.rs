//! # FlowMoE — scalable pipeline scheduling for distributed MoE training
//!
//! Rust + JAX + Pallas reproduction of *"FlowMoE: A Scalable Pipeline
//! Scheduling Framework for Distributed Mixture-of-Experts Training"*.
//!
//! The crate is the **L3 coordinator** of the three-layer stack (see
//! DESIGN.md): it owns the paper's contribution — the unified multi-type
//! task pipeline (Eqs. 2–5), the all-reduce tensor-chunk priority
//! scheduling (Algorithm 2, Theorems 1–2) and the Bayesian-optimization
//! autotuner for the chunk size `S_p` — plus every substrate the paper's
//! evaluation depends on:
//!
//! * [`tasks`] — the multi-type task DAG of one training iteration,
//! * [`cost`] — calibrated compute/A2A/all-reduce cost models,
//! * [`sim`] — a discrete-event two-stream cluster simulator (the exact
//!   resource model the paper's theorems assume),
//! * [`sched`] — FlowMoE and the five baseline scheduling policies,
//! * [`exec`] — the task-graph executor unifying both worlds: one
//!   statically verified [`exec::Plan`] per policy-built DAG, driven
//!   either by the cost model (what [`sim::simulate`] delegates to) or
//!   by real kernels + collectives (what [`trainer`] executes),
//! * [`commpool`] — the runtime communication pool (Algorithm 2),
//! * [`sweep`] — the multi-core work-stealing sweep engine driving the
//!   675-layer evaluation grid (Fig. 6) and the other table benches,
//! * [`bo`] — Gaussian-process Bayesian optimization from scratch,
//! * [`runtime`] — manifest-driven execution engine with pluggable
//!   backends (PJRT artifact loader + the native dispatch),
//! * [`backend`] — the native execution backend: dense f32 CPU kernels
//!   (matmul, attention, gating, expert FFN, ... and their backward
//!   passes) that run every AOT entry point in-tree, so end-to-end
//!   training works with no JAX and no artifacts,
//! * [`cluster`] — an in-process multi-worker distributed runtime with
//!   real chunked ring all-reduce and real A2A dispatch,
//! * [`analyze`] — the static verification layer: schedule/DAG analyzer
//!   behind `flowmoe analyze` plus the dependency-free source lint
//!   behind the `flowmoe-lint` binary,
//! * [`trainer`] — the end-to-end training loop,
//! * [`serve`] — continuous-batching MoE inference: KV-cached decode,
//!   FIFO admission against a KV budget, expert-parallel serving with
//!   hot-expert replication, and a seeded synthetic-traffic bench,
//! * [`ft`] — fault tolerance: CRC-checked atomic checkpoints with a
//!   bitwise resume contract, seeded fault injection, and elastic
//!   P−1 recovery for the native training path,
//! * [`data`] — deterministic synthetic corpus,
//! * [`metrics`] — time/energy/memory/occupancy models,
//! * [`obs`] — runtime span tracing + metrics registry: measured (not
//!   modeled) overlap for the native execution path,
//! * [`report`] — paper-table renderers and the bench harness.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`); the
//! binary is self-contained afterwards.

pub mod analyze;
pub mod backend;
pub mod bo;
pub mod cli;
pub mod cluster;
pub mod commpool;
pub mod config;
pub mod cost;
pub mod data;
pub mod exec;
pub mod ft;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod sweep;
pub mod tasks;
pub mod testutil;
pub mod trainer;
pub mod util;
