//! Dependency-free JSON well-formedness scanner (no serde offline).
//!
//! Not a full parser — a structural scanner strong enough to catch the
//! ways hand-rolled JSON writers actually go wrong: unbalanced braces or
//! brackets, unterminated strings, invalid escape sequences, and bare
//! `NaN`/`Infinity` tokens (what `format!("{}", f64::NAN)` emits, which
//! JSON forbids). Applied to both the simulator's chrome trace and the
//! runtime span trace ([`crate::obs::chrome_trace`]); `flowmoe train
//! --trace` runs it on the trace before writing the file.

/// Scan `s` for JSON structural well-formedness. Returns `Ok(())` or a
/// description of the first problem with its byte offset.
pub fn scan_json(s: &str) -> Result<(), String> {
    let mut depth_obj: i64 = 0;
    let mut depth_arr: i64 = 0;
    let mut in_string = false;
    let mut chars = s.char_indices().peekable();

    // the document must begin with an object or array
    match s.trim_start().chars().next() {
        Some('{') | Some('[') => {}
        Some(c) => return Err(format!("document starts with '{c}', expected '{{' or '['")),
        None => return Err("empty document".to_string()),
    }

    while let Some((i, c)) = chars.next() {
        if in_string {
            match c {
                '"' => in_string = false,
                '\\' => match chars.next() {
                    Some((_, e)) if matches!(e, '"' | '\\' | '/' | 'b' | 'f' | 'n' | 'r' | 't') => {}
                    Some((j, 'u')) => {
                        for _ in 0..4 {
                            match chars.next() {
                                Some((_, h)) if h.is_ascii_hexdigit() => {}
                                _ => return Err(format!("byte {j}: \\u escape needs 4 hex digits")),
                            }
                        }
                    }
                    Some((j, e)) => return Err(format!("byte {j}: invalid escape '\\{e}'")),
                    None => return Err(format!("byte {i}: trailing backslash in string")),
                },
                '\n' | '\r' => return Err(format!("byte {i}: raw newline inside string")),
                _ => {}
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' => depth_obj += 1,
            '}' => {
                depth_obj -= 1;
                if depth_obj < 0 {
                    return Err(format!("byte {i}: unmatched '}}'"));
                }
            }
            '[' => depth_arr += 1,
            ']' => {
                depth_arr -= 1;
                if depth_arr < 0 {
                    return Err(format!("byte {i}: unmatched ']'"));
                }
            }
            // bare non-finite float tokens (JSON has no NaN/Infinity);
            // only need the leading letter — 'N' and 'I' start no valid
            // JSON token outside a string ('n' starts "null")
            'N' if s[i..].starts_with("NaN") => {
                return Err(format!("byte {i}: bare NaN token"));
            }
            'I' if s[i..].starts_with("Infinity") => {
                return Err(format!("byte {i}: bare Infinity token"));
            }
            'i' if s[i..].starts_with("inf") => {
                return Err(format!("byte {i}: bare inf token"));
            }
            _ => {}
        }
    }
    if in_string {
        return Err("unterminated string at end of document".to_string());
    }
    if depth_obj != 0 {
        return Err(format!("unbalanced braces: depth {depth_obj} at end"));
    }
    if depth_arr != 0 {
        return Err(format!("unbalanced brackets: depth {depth_arr} at end"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_wellformed_documents() {
        scan_json(r#"{}"#).unwrap();
        scan_json("[]\n").unwrap();
        scan_json(r#"{"a": [1, 2.5, -3e-4], "b": {"c": "x"}}"#).unwrap();
        scan_json(r#"["esc \" \\ \/ \b \f \n \r \t é ok"]"#).unwrap();
        // braces/brackets inside strings don't count toward nesting
        scan_json(r#"{"a": "}{][ not structure"}"#).unwrap();
        // 'null' is fine (starts with lowercase n, not the NaN check)
        scan_json(r#"{"a": null}"#).unwrap();
    }

    #[test]
    fn rejects_structural_errors() {
        assert!(scan_json("").is_err());
        assert!(scan_json("42").is_err(), "document must be object/array");
        assert!(scan_json(r#"{"a": 1"#).is_err(), "unbalanced brace");
        assert!(scan_json(r#"[1, 2"#).is_err(), "unbalanced bracket");
        assert!(scan_json(r#"[1]]"#).is_err(), "extra bracket");
        assert!(scan_json(r#"{"a": "unterminated}"#).is_err());
        assert!(scan_json("{\"a\": \"line\nbreak\"}").is_err(), "raw newline in string");
    }

    #[test]
    fn rejects_invalid_escapes() {
        assert!(scan_json(r#"{"a": "bad \x escape"}"#).is_err());
        assert!(scan_json(r#"{"a": "short \u00g0"}"#).is_err());
        assert!(scan_json(r#"{"a": "truncated \u00"}"#).is_err());
    }

    #[test]
    fn rejects_nonfinite_tokens() {
        assert!(scan_json(r#"{"a": NaN}"#).is_err());
        assert!(scan_json(r#"{"a": Infinity}"#).is_err());
        assert!(scan_json(r#"{"a": -inf}"#).is_err());
        // ...but the same words inside strings are fine
        scan_json(r#"{"a": "NaN and Infinity and inf"}"#).unwrap();
    }
}
