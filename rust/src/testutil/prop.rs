//! Seed-reporting randomized property checks.
//!
//! `check(n, |rng| ...)` runs the property over `n` deterministic seeds;
//! on failure it panics with the seed so the case replays exactly:
//! `check_seed(SEED, prop)`. No shrinking (offline constraint, DESIGN.md
//! §1) — properties should generate smallish cases instead.

use crate::util::Rng;

/// Run `prop` over `n` seeded cases; panic with the first failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(n: usize, mut prop: F) {
    for seed in 0..n as u64 {
        let mut rng = Rng::new(0xF10E ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}\nreplay: check_seed({seed}, prop)");
        }
    }
}

/// Replay one seed.
pub fn check_seed<F: FnMut(&mut Rng) -> Result<(), String>>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(0xF10E ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed at seed {seed}: {msg}");
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(50, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn failing_property_reports_seed() {
        check(10, |rng| {
            let x = rng.below(10);
            if x < 9 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    fn seeds_are_deterministic() {
        let mut first = Vec::new();
        check(5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check(5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
