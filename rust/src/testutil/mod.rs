//! Minimal property-testing harness (no proptest offline): runs a check
//! over many seeded random cases and reports the failing seed for
//! reproduction.

pub mod prop;

pub use prop::check;
