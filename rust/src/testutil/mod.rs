//! Test support: a minimal property-testing harness (no proptest
//! offline) that runs a check over many seeded random cases and reports
//! the failing seed for reproduction, plus a dep-free JSON
//! well-formedness scanner for the hand-rolled trace/bench writers.

pub mod json;
pub mod prop;

pub use json::scan_json;
pub use prop::check;
