//! FlowMoE CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   simulate  — simulate one iteration of a model under every scheduler
//!   sweep     — the customized-MoE-layer sweep (Fig. 6)
//!   analyze   — static schedule verification over the Fig. 6 grid × the
//!               full policy matrix (see src/analyze)
//!   tune      — BO-tune S_p for a model (Fig. 4)
//!   train     — end-to-end distributed training on real PJRT compute
//!   serve     — continuous-batching MoE inference under synthetic load
//!   info      — print presets and artifact manifest summary

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{anyhow, bail, Result};
use flowmoe::analyze::{check_schedule, policy_matrix};
use flowmoe::bo::BoTuner;
use flowmoe::cli::Args;
use flowmoe::config::{preset, table2_models, ClusterProfile, ModelCfg};
use flowmoe::cost::TaskCosts;
use flowmoe::metrics::{energy_joules, peak_memory, sm_utilization};
use flowmoe::report::Table;
use flowmoe::sched::{build_dag, iteration_time, Policy};
use flowmoe::sim::simulate;
use flowmoe::trainer::{train_dp, train_fused, ExecMode, TrainOpts};
use flowmoe::util::fmt_ms;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn main() -> ExitCode {
    let args = Args::from_env();
    // Fail fast on a bad FLOWMOE_KERNELS request (unknown value, or simd
    // forced on a host without AVX2) instead of panicking mid-kernel.
    if let Err(e) = flowmoe::backend::kernels::configured_dispatch() {
        eprintln!("flowmoe: {e}");
        return ExitCode::from(2);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let res = match cmd {
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "analyze" => cmd_analyze(&args),
        "tune" => cmd_tune(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: flowmoe <simulate|sweep|analyze|tune|train|info> [options]\n\
                 \n\
                 simulate --model <name> --gpus N --r R --sp MB    per-framework iteration time\n\
                 sweep    --gpus N --limit K --threads T            customized-layer speedup sweep (parallel)\n\
                 analyze  --grid fig6 | --model <name>              static schedule verification, all policies\n\
                          --gpus N --r R --sp MB --limit K\n\
                 tune     --model <name> --gpus N --samples K       BO-tune S_p (--batch B: parallel rounds)\n\
                 train    --config tiny|e2e --workers P --steps N   real distributed training (native backend\n\
                          --trace out.json                           by default; AOT artifacts when built);\n\
                                                                    --trace (or FLOWMOE_TRACE) writes a\n\
                                                                    chrome-trace of the run + measured-vs-\n\
                                                                    modeled overlap report\n\
                          --exec graph|legacy                        graph (default) executes the policy-built\n\
                                                                    task DAG; legacy is the pre-executor\n\
                                                                    reference loop (bitwise identical)\n\
                          --ckpt-dir D --ckpt-every N --resume       CRC-checked atomic checkpoints; resume\n\
                                                                    is bitwise (same losses + params)\n\
                          --kill W@K --drop-prob P --delay-prob P    seeded fault injection (--fault-seed S);\n\
                          --detect-ms T --die-at K                   elastic P-1 recovery, BENCH_fault.json\n\
                 serve    --synthetic --config tiny --requests N    continuous-batching inference under\n\
                          --seed S --max-batch D --kv-budget T       seeded open-loop load; writes\n\
                          --workers W --warmup K --trace out.json    BENCH_serve.json (--out to rename)\n\
                 info                                               presets + artifacts + obs + serving status"
            );
            Ok(())
        }
    };
    match res {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("flowmoe {cmd}: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn policies(r: usize, sp: f64) -> Vec<Policy> {
    vec![
        Policy::vanilla_ep(),
        Policy::faster_moe(r),
        Policy::tutel(r),
        Policy::sche_moe(r),
        Policy::fs_moe(r),
        Policy::flow_moe(r, sp),
    ]
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "BERT-Large-MoE");
    let gpus = args.usize_or("gpus", 16);
    let r = args.usize_or("r", 2);
    let sp = args.f64_or("sp", 2.5) * 1e6;
    let cfg = preset(&model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let cluster = if args.get_or("cluster", "1") == "2" {
        ClusterProfile::cluster2(gpus)
    } else {
        ClusterProfile::cluster1(gpus)
    };
    let mut t = Table::new(
        &format!("{model} on {} x{gpus} (R={r}, S_p={:.1}MB)", cluster.name, sp / 1e6),
        &["framework", "iter (ms)", "speedup", "energy (J)", "mem (GB)", "SM util"],
    );
    let mut base = 0.0;
    for pol in policies(r, sp) {
        let costs = TaskCosts::build(&cfg, &cluster);
        let dag = build_dag(&cfg, &costs, &pol);
        let tl = simulate(&dag);
        if pol.name == "vanillaEP" {
            base = tl.makespan;
        }
        let mem = peak_memory(&cfg, &cluster, &pol, &dag, &tl);
        t.row(vec![
            pol.name.into(),
            fmt_ms(tl.makespan * 1e3),
            format!("{:.2}x", base / tl.makespan),
            format!("{:.1}", energy_joules(&tl, &cluster.power)),
            format!("{:.2}", mem / 1e9),
            format!("{:.1}%", sm_utilization(&tl) * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let gpus = args.usize_or("gpus", 16);
    let limit = args.usize_or("limit", usize::MAX);
    let cluster = ClusterProfile::cluster1(gpus);
    // The customized-layer grid runs on the multi-core sweep engine
    // (sweep::Sweeper): deterministic grid-ordered results, all cores.
    let mut sweeper = flowmoe::sweep::Sweeper::new().on_progress(|p| {
        if p.done % 128 == 0 {
            eprintln!("  [{}/{}] ~{:.1}s left", p.done, p.total, p.eta_s);
        }
    });
    if let Some(t) = args.get("threads").and_then(|t| t.parse().ok()) {
        sweeper = sweeper.with_threads(t);
    }
    let stats = flowmoe::sweep::fig6_sweep(&sweeper, &cluster, gpus, limit);
    println!(
        "{}",
        flowmoe::report::histogram(
            &format!(
                "FlowMoE-CC (tuned S_p) speedup over ScheMoE, {} valid layers ({} OOM), {gpus} GPUs, win rate {:.0}%",
                stats.speedups.len(),
                stats.oom,
                100.0 * stats.wins as f64 / stats.speedups.len().max(1) as f64
            ),
            &stats.speedups,
            12,
            40
        )
    );
    Ok(())
}

/// Static schedule verification (`flowmoe analyze`): build and check every
/// schedule in the Fig. 6 customized-layer grid (or one preset model)
/// under the full 11-policy matrix — no simulation involved. Exits
/// non-zero on any violation; CI runs `analyze --grid fig6`.
fn cmd_analyze(args: &Args) -> Result<()> {
    let gpus = args.usize_or("gpus", 16);
    let r = args.usize_or("r", 2);
    let sp = args.f64_or("sp", 2.5) * 1e6;
    let limit = args.usize_or("limit", usize::MAX);
    if let Some(grid) = args.get("grid") {
        if grid != "fig6" {
            bail!("unknown grid {grid} (only fig6)");
        }
    }
    let cfgs: Vec<ModelCfg> = if let Some(model) = args.get("model") {
        vec![preset(model).ok_or_else(|| anyhow!("unknown model {model}"))?]
    } else {
        let mut grid = flowmoe::sweep::custom_layer_grid(gpus);
        grid.truncate(limit);
        grid
    };
    let cluster = ClusterProfile::cluster1(gpus);
    let mut sweeper = flowmoe::sweep::Sweeper::new();
    if let Some(t) = args.get("threads").and_then(|t| t.parse().ok()) {
        sweeper = sweeper.with_threads(t);
    }
    let pols = policy_matrix(r, sp);
    let reports: Vec<(usize, Vec<String>)> = sweeper.run(&cfgs, |i, cfg| {
        let costs = TaskCosts::build(cfg, &cluster);
        let mut msgs = Vec::new();
        let mut tasks = 0usize;
        for pol in &pols {
            let (dag, vs) = check_schedule(cfg, &costs, pol);
            tasks += dag.len();
            for v in vs {
                msgs.push(format!(
                    "config {i} (B={} N={} M={} H={}) under {}: {v}",
                    cfg.b, cfg.n, cfg.m, cfg.h, pol.name
                ));
            }
        }
        (tasks, msgs)
    });
    let mut violations: Vec<String> = Vec::new();
    let mut tasks = 0usize;
    for (t, msgs) in reports {
        tasks += t;
        violations.extend(msgs);
    }
    for v in violations.iter().take(50) {
        println!("{v}");
    }
    if violations.len() > 50 {
        println!("... and {} more", violations.len() - 50);
    }
    println!(
        "flowmoe analyze: {} config(s) x {} policies = {} schedules ({tasks} tasks) checked, {} violation(s)",
        cfgs.len(),
        pols.len(),
        cfgs.len() * pols.len(),
        violations.len()
    );
    if !violations.is_empty() {
        bail!("{} violation(s)", violations.len());
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let model = args.get_or("model", "BERT-Large-MoE");
    let gpus = args.usize_or("gpus", 16);
    let samples = args.usize_or("samples", 8);
    let batch = args.usize_or("batch", 1);
    let cfg = preset(&model).ok_or_else(|| anyhow!("unknown model {model}"))?;
    let cluster = ClusterProfile::cluster1(gpus);
    let max = cfg.ar_bytes_per_block() * 1.0;
    let mut bo = BoTuner::new(max, args.usize_or("seed", 42) as u64);
    let obj = |sp: f64| iteration_time(&cfg, &cluster, &Policy::flow_moe(2, sp)).0;
    let best = if batch > 1 {
        // batched acquisition: rounds of up to `batch` candidates
        // evaluated in parallel on the sweep engine, `samples` total
        bo.tune_batch(samples, batch, obj)
    } else {
        bo.tune(samples, obj)
    };
    println!("samples:");
    for (sp, t) in &bo.observations {
        println!("  S_p = {:7.3} MB -> {} ms", sp / 1e6, fmt_ms(t * 1e3));
    }
    let (b_sp, b_t) = bo.best().ok_or_else(|| anyhow!("BO produced no samples"))?;
    println!(
        "BO best: S_p = {:.3} MB ({} ms) after {samples} samples",
        b_sp / 1e6,
        fmt_ms(b_t * 1e3)
    );
    let _ = best;
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = args.get_or("config", "tiny");
    let p = args.usize_or("workers", 2);
    let steps = args.usize_or("steps", 20);
    let dir = artifacts_dir(args);
    let mut opts = TrainOpts::new(&cfg, steps);
    opts.lr = args.f64_or("lr", 0.05) as f32;
    opts.sp_bytes = (args.f64_or("sp", 1.0) * 1e6) as usize;
    opts.overlap = !args.has_flag("centralized");
    opts.log_every = args.usize_or("log-every", 10);
    opts.seed = args.usize_or("seed", 1234) as u64;
    opts.exec = match args.get_or("exec", "graph").as_str() {
        "graph" => ExecMode::Graph,
        "legacy" => ExecMode::Legacy,
        other => bail!("--exec expects graph|legacy, got '{other}'"),
    };
    // fault tolerance: checkpointing, resume, and seeded fault injection
    opts.ckpt_dir = args.get("ckpt-dir").map(PathBuf::from);
    let default_every = if opts.ckpt_dir.is_some() { flowmoe::ft::DEFAULT_CKPT_EVERY } else { 0 };
    opts.ckpt_every = args.usize_or("ckpt-every", default_every);
    opts.resume = args.has_flag("resume");
    opts.die_at = args.get("die-at").and_then(|s| s.parse().ok());
    opts.detect_ms = args.usize_or("detect-ms", flowmoe::ft::DETECT_TIMEOUT_MS as usize) as u64;
    let kill = match args.get("kill") {
        Some(s) => {
            let (w, k) = s
                .split_once('@')
                .ok_or_else(|| anyhow!("--kill expects W@K (worker@step), got '{s}'"))?;
            let w: usize = w.parse().map_err(|_| anyhow!("--kill: bad worker '{w}'"))?;
            let k: usize = k.parse().map_err(|_| anyhow!("--kill: bad step '{k}'"))?;
            if w >= p {
                bail!("--kill worker {w} out of range (P = {p})");
            }
            Some((w, k))
        }
        None => None,
    };
    let drop_prob = args.f64_or("drop-prob", 0.0);
    let delay_prob = args.f64_or("delay-prob", 0.0);
    if kill.is_some() || drop_prob > 0.0 || delay_prob > 0.0 {
        opts.fault = Some(flowmoe::ft::FaultPlan {
            seed: args.usize_or("fault-seed", 1) as u64,
            kill,
            drop_prob,
            delay_prob,
            delay_ms: args.usize_or("delay-ms", 20) as u64,
        });
    }
    if args.has_flag("fused")
        && (opts.ckpt_dir.is_some() || opts.resume || opts.fault.is_some() || opts.die_at.is_some())
    {
        bail!("--fused is the single-process oracle path; checkpoint/resume/fault flags need the dp path");
    }
    // runtime span tracing: --trace out.json, or the FLOWMOE_TRACE env
    // var (used by CI so the smoke needs no extra plumbing)
    let trace_path: Option<String> = args
        .get("trace")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("FLOWMOE_TRACE").ok().filter(|s| !s.is_empty()));
    if trace_path.is_some() {
        flowmoe::obs::set_enabled(true);
    }
    let report = if args.has_flag("fused") {
        train_fused(&dir, &opts)?
    } else {
        train_dp(&dir, p, &opts)?
    };
    flowmoe::obs::set_enabled(false);
    println!("step,loss,seconds");
    for (i, (l, s)) in report.losses.iter().zip(&report.step_secs).enumerate() {
        println!("{},{l:.4},{s:.3}", report.start_step + i);
    }
    let n = report.losses.len();
    if let (Some(first), Some(last)) = (report.losses.first(), report.losses.last()) {
        println!("# first loss {first:.4} -> last loss {last:.4} over {n} steps");
    }
    for ev in &report.recoveries {
        println!(
            "# recovery: worker {} failed at step {} -> resumed from ckpt step {} at P={} \
             ({} step(s) lost; detect {:.1} ms, restore {:.1} ms)",
            ev.failed_rank, ev.detected_step, ev.ckpt_step, ev.p_after, ev.steps_lost, ev.detect_ms, ev.restore_ms
        );
    }
    if let Some(fp) = &opts.fault {
        let train_s: f64 = report.step_secs.iter().sum();
        let json = flowmoe::ft::bench_json(
            &cfg,
            fp.seed,
            p,
            steps,
            opts.ckpt_every,
            opts.detect_ms,
            &report.recoveries,
            train_s,
        );
        if let Err(e) = flowmoe::testutil::scan_json(&json) {
            bail!("BENCH_fault.json failed the JSON well-formedness scan: {e}");
        }
        let out = args.get_or("fault-out", "BENCH_fault.json");
        std::fs::write(&out, &json)?;
        println!("# bench: {out}");
    }
    // per-run metrics: step/phase wall-time p50/p95/p99 + counters
    for line in flowmoe::report::stats_lines(&report.stats) {
        println!("# {line}");
    }
    if let Some(path) = trace_path {
        let spans = flowmoe::obs::take_spans();
        let json = flowmoe::obs::chrome_trace(&spans);
        // self-check before writing: a malformed trace is a bug, not a file
        if let Err(e) = flowmoe::testutil::scan_json(&json) {
            bail!("runtime trace failed the JSON well-formedness scan: {e}");
        }
        std::fs::write(&path, &json)?;
        println!(
            "# trace: {} spans -> {path} (open in chrome://tracing or Perfetto)",
            spans.len()
        );
        // the payoff: measured overlap from real spans, side by side with
        // the cost model's prediction for the SAME policy-built plan the
        // trainer just executed (not a separately hand-built dag)
        let measured = flowmoe::obs::OverlapStats::from_spans(&spans);
        let plan = if args.has_flag("fused") {
            flowmoe::trainer::fused_step_plan(&dir, &opts)
        } else {
            flowmoe::trainer::native_step_plan(&dir, &opts, p)
        };
        match plan {
            Ok(plan) => {
                let modeled = flowmoe::obs::OverlapStats::from_timeline(&plan.modeled());
                print!("{}", flowmoe::obs::overlap_report(&measured, &modeled));
            }
            Err(e) => {
                println!("# (no schedule plan: {e:#}; measured overlap only)");
                print!(
                    "{}",
                    flowmoe::obs::overlap_report(&measured, &flowmoe::obs::OverlapStats::default())
                );
            }
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if !args.has_flag("synthetic") {
        bail!("only synthetic load is supported: flowmoe serve --synthetic [options]");
    }
    let mut opts = flowmoe::serve::ServeOpts::new(&args.get_or("config", "tiny"));
    opts.seed = args.usize_or("seed", 7) as u64;
    opts.requests = args.usize_or("requests", 200);
    opts.max_batch = args.usize_or("max-batch", flowmoe::serve::DEFAULT_MAX_BATCH);
    opts.kv_budget = args.usize_or("kv-budget", flowmoe::serve::DEFAULT_KV_BUDGET);
    opts.workers = args.get("workers").and_then(|w| w.parse().ok());
    opts.warmup_steps = args.usize_or("warmup", 16) as u64;
    opts.mean_gap_steps = args.f64_or("gap", 2.0);
    opts.max_prompt = args.usize_or("max-prompt", 24);
    opts.max_new = args.usize_or("max-new", 16);
    // same trace plumbing as cmd_train: --trace or FLOWMOE_TRACE
    let trace_path: Option<String> = args
        .get("trace")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("FLOWMOE_TRACE").ok().filter(|s| !s.is_empty()));
    if trace_path.is_some() {
        flowmoe::obs::set_enabled(true);
    }
    let report = flowmoe::serve::run_synthetic(&opts)?;
    flowmoe::obs::set_enabled(false);
    println!(
        "served {} request(s) in {} decode step(s) ({} prefill + {} generated tokens, {:.0} tok/s)",
        report.finished, report.steps, report.prefill_tokens, report.generated_tokens, report.tokens_per_s
    );
    println!(
        "latency: per-token p50 {:.3} ms / p99 {:.3} ms; per-request p50 {:.3} ms / p99 {:.3} ms",
        report.token_ms_p50, report.token_ms_p99, report.req_ms_p50, report.req_ms_p99
    );
    println!(
        "virtual-time: request latency p50 {:.1} / p99 {:.1} steps; queue wait p50 {:.1} / p99 {:.1} steps",
        report.req_latency_steps_p50,
        report.req_latency_steps_p99,
        report.queue_wait_steps_p50,
        report.queue_wait_steps_p99
    );
    println!(
        "expert parallelism: {} worker(s), capacity {} rows/expert/step, replicas {:?}",
        report.workers_used, report.capacity, report.replicas
    );
    for line in flowmoe::report::stats_lines(&report.stats) {
        println!("# {line}");
    }
    let json = flowmoe::serve::bench_json(&opts, &report);
    if let Err(e) = flowmoe::testutil::scan_json(&json) {
        bail!("BENCH_serve.json failed the JSON well-formedness scan: {e}");
    }
    let out = args.get_or("out", "BENCH_serve.json");
    std::fs::write(&out, &json)?;
    println!("# bench: {out}");
    if let Some(path) = trace_path {
        let spans = flowmoe::obs::take_spans();
        let json = flowmoe::obs::chrome_trace(&spans);
        if let Err(e) = flowmoe::testutil::scan_json(&json) {
            bail!("serve trace failed the JSON well-formedness scan: {e}");
        }
        std::fs::write(&path, &json)?;
        println!(
            "# trace: {} spans -> {path} (open in chrome://tracing or Perfetto)",
            spans.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Model presets (paper Table 2)",
        &["name", "L", "B", "N", "M", "H", "E", "k", "params (M)"],
    );
    let extra: Vec<ModelCfg> = ["LLaMA2-MoE-L", "DeepSeek-V2-M", "tiny", "e2e"]
        .iter()
        .filter_map(|&n| preset(n))
        .collect();
    for cfg in table2_models().iter().chain(extra.iter()) {
        t.row(vec![
            cfg.name.into(),
            cfg.l.to_string(),
            cfg.b.to_string(),
            cfg.n.to_string(),
            cfg.m.to_string(),
            cfg.h.to_string(),
            cfg.e.to_string(),
            cfg.k.to_string(),
            format!("{:.1}", cfg.total_params() as f64 / 1e6),
        ]);
    }
    t.print();
    let dir = artifacts_dir(args);
    let (m, source) = match flowmoe::runtime::Manifest::load(&dir) {
        Ok(m) => (m, format!("AOT artifacts at {}", dir.display())),
        Err(e) => {
            println!("\nartifacts: {e:#}");
            (
                flowmoe::backend::native_manifest(&dir),
                "native in-tree backend (no artifacts needed)".to_string(),
            )
        }
    };
    println!("\nexecutable entry points ({source}):");
    for a in &m.artifacts {
        println!(
            "  {} [{}] {} in / {} out",
            a.name,
            a.config,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    println!(
        "\nthread budget: {} (override with FLOWMOE_THREADS; kernels, experts, heads and sweeps share it)",
        flowmoe::sweep::scope::default_budget()
    );
    println!(
        "kernel dispatch: {} (FLOWMOE_KERNELS=auto|simd|blocked|naive; avx2+fma {})",
        flowmoe::backend::kernels::default_dispatch().name(),
        if flowmoe::backend::kernels::avx2_available() {
            "detected"
        } else {
            "not detected"
        }
    );
    let trace_env = std::env::var("FLOWMOE_TRACE").ok().filter(|s| !s.is_empty());
    println!(
        "observability: span tracing {} (trace path: {}; enable with `train --trace out.json` or FLOWMOE_TRACE)",
        if flowmoe::obs::enabled() { "enabled" } else { "disabled" },
        trace_env.as_deref().unwrap_or("unset")
    );
    println!(
        "  metrics histograms: {} exponential buckets from {:.0}us, x{:.0} per bucket (p50/p95/p99 in train output)",
        flowmoe::obs::HIST_BUCKETS,
        flowmoe::obs::HIST_START_S * 1e6,
        flowmoe::obs::HIST_FACTOR
    );
    // serving defaults, printed from the same constants the bench JSON
    // header uses so `info` and BENCH_serve.json always agree
    println!(
        "serving: max batch {} sequence(s)/step, KV budget {} cached tokens (flowmoe serve --synthetic; \
         --max-batch/--kv-budget to override)",
        flowmoe::serve::DEFAULT_MAX_BATCH,
        flowmoe::serve::DEFAULT_KV_BUDGET
    );
    // fault-tolerance defaults, from the same constants the
    // BENCH_fault.json header uses so `info` and the bench always agree
    println!(
        "fault tolerance: checkpoint every {} step(s) when --ckpt-dir is set, failure-detection \
         timeout {} ms (flowmoe train --ckpt-dir D --resume; --kill W@K / --drop-prob for seeded faults)",
        flowmoe::ft::DEFAULT_CKPT_EVERY,
        flowmoe::ft::DETECT_TIMEOUT_MS
    );
    Ok(())
}
