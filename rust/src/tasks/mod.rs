//! Multi-type task DAG of one distributed-MoE training iteration.
//!
//! Implements the paper's task model (Sec. 3.2): the iteration is broken
//! into MHA+gating computing (`At`), dispatch/combine A2A communication
//! (`Disp`/`Comb`), expert computing (`Exp`) and all-reduce chunks (`Ar`),
//! each with forward and backward instances, related by the dependencies
//! of Eqs. 2–5 / 6a–6e. Scheduling policies (see [`crate::sched`]) build
//! concrete DAGs; the simulator ([`crate::sim`]) executes them on the
//! two-stream resource model the paper's theorems assume.

use std::fmt;

/// The hardware stream a task occupies (paper §3.3: one compute and one
/// communication task may run concurrently; same-stream tasks serialize).
///
/// `ArComm` is an optional third stream modelling concurrent NCCL
/// communicators (A2A and all-reduce on separate channels): the paper's
/// *theory* assumes a single communication stream, but its measured
/// speedups on communication-dominated models exceed that model's
/// comm-busy lower bound — which is only possible if A2A and AR overlap
/// physically. Policies choose strict (paper-theory) or concurrent
/// placement of AR chunks (see sched::Policy::ar_channel and
/// EXPERIMENTS.md §Findings).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stream {
    Compute,
    Comm,
    ArComm,
}

/// Phase of the iteration a task belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Fwd,
    Bwd,
}

/// Task types of the paper's set 𝕋 (Eq. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// MHA + gating computing subtask `AT_r^(l)`.
    At { l: usize, r: usize, phase: Phase },
    /// Dispatch A2A `D_r^(l)`.
    Disp { l: usize, r: usize, phase: Phase },
    /// Expert computing `E_r^(l)`.
    Exp { l: usize, r: usize, phase: Phase },
    /// Combine A2A `C_r^(l)`.
    Comb { l: usize, r: usize, phase: Phase },
    /// All-reduce tensor chunk `AR^(l)` (backward only), chunk `c` of the
    /// block's replicated-gradient tensor.
    Ar { l: usize, c: usize },
    /// Embedding/head/loss compute at the fwd->bwd turnaround (not in the
    /// paper's notation; negligible duration but keeps the DAG honest).
    Head,
}

impl TaskKind {
    pub fn is_a2a(&self) -> bool {
        matches!(self, TaskKind::Disp { .. } | TaskKind::Comb { .. })
    }
    pub fn is_ar(&self) -> bool {
        matches!(self, TaskKind::Ar { .. })
    }
    pub fn layer(&self) -> Option<usize> {
        match self {
            TaskKind::At { l, .. }
            | TaskKind::Disp { l, .. }
            | TaskKind::Exp { l, .. }
            | TaskKind::Comb { l, .. }
            | TaskKind::Ar { l, .. } => Some(*l),
            TaskKind::Head => None,
        }
    }
}

impl fmt::Display for TaskKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ph = |p: &Phase| if *p == Phase::Fwd { "f" } else { "b" };
        match self {
            TaskKind::At { l, r, phase } => write!(f, "AT{}[{l},{r}]", ph(phase)),
            TaskKind::Disp { l, r, phase } => write!(f, "D{}[{l},{r}]", ph(phase)),
            TaskKind::Exp { l, r, phase } => write!(f, "E{}[{l},{r}]", ph(phase)),
            TaskKind::Comb { l, r, phase } => write!(f, "C{}[{l},{r}]", ph(phase)),
            TaskKind::Ar { l, c } => write!(f, "AR[{l}.{c}]"),
            TaskKind::Head => write!(f, "HEAD"),
        }
    }
}

pub type TaskId = usize;

/// One schedulable task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub kind: TaskKind,
    pub stream: Stream,
    /// Duration in seconds.
    pub dur: f64,
    /// Ids of tasks that must complete before this one starts.
    pub deps: Vec<TaskId>,
    /// Within-stream FIFO rank (Eqs. 2–5 ordering). The simulator picks,
    /// among ready same-stream tasks, the one with the smallest `seq`;
    /// AR chunks are *always* outranked by ready A2A tasks (Algorithm 2)
    /// regardless of `seq`.
    pub seq: u64,
    /// Bytes moved (comm tasks; 0 for compute) — metrics only.
    pub bytes: f64,
}

/// A complete iteration DAG.
#[derive(Clone, Debug, Default)]
pub struct Dag {
    pub tasks: Vec<Task>,
}

impl Dag {
    pub fn new() -> Self {
        Dag { tasks: Vec::new() }
    }

    pub fn add(&mut self, kind: TaskKind, stream: Stream, dur: f64, deps: Vec<TaskId>, seq: u64) -> TaskId {
        self.add_with_bytes(kind, stream, dur, deps, seq, 0.0)
    }

    pub fn add_with_bytes(
        &mut self,
        kind: TaskKind,
        stream: Stream,
        dur: f64,
        deps: Vec<TaskId>,
        seq: u64,
        bytes: f64,
    ) -> TaskId {
        let id = self.tasks.len();
        debug_assert!(deps.iter().all(|&d| d < id), "forward-only dep edges");
        self.tasks.push(Task {
            id,
            kind,
            stream,
            dur,
            deps,
            seq,
            bytes,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Sum of durations per stream (lower bound on makespan per stream).
    pub fn stream_busy(&self, s: Stream) -> f64 {
        self.tasks.iter().filter(|t| t.stream == s).map(|t| t.dur).sum()
    }

    /// Critical-path lower bound on the makespan (longest dep chain).
    pub fn critical_path(&self) -> f64 {
        let mut finish = vec![0.0f64; self.tasks.len()];
        for t in &self.tasks {
            let start = t.deps.iter().map(|&d| finish[d]).fold(0.0, f64::max);
            finish[t.id] = start + t.dur;
        }
        finish.iter().copied().fold(0.0, f64::max)
    }

    /// Structural validation: ids consecutive, deps acyclic (guaranteed by
    /// construction), durations non-negative and finite.
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id != i {
                return Err(format!("task {i} has id {}", t.id));
            }
            if !(t.dur.is_finite() && t.dur >= 0.0) {
                return Err(format!("task {} ({}) bad duration {}", t.id, t.kind, t.dur));
            }
            for &d in &t.deps {
                if d >= i {
                    return Err(format!("task {} depends on later task {}", i, d));
                }
            }
        }
        Ok(())
    }

    /// Count tasks of a coarse category (for tests/reports).
    pub fn count<F: Fn(&TaskKind) -> bool>(&self, pred: F) -> usize {
        self.tasks.iter().filter(|t| pred(&t.kind)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(kind: TaskKind) -> TaskKind {
        kind
    }

    #[test]
    fn add_assigns_sequential_ids() {
        let mut d = Dag::new();
        let a = d.add(t(TaskKind::Head), Stream::Compute, 1.0, vec![], 0);
        let b = d.add(t(TaskKind::Head), Stream::Compute, 1.0, vec![a], 1);
        assert_eq!((a, b), (0, 1));
        assert!(d.validate().is_ok());
    }

    #[test]
    fn critical_path_longest_chain() {
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 2.0, vec![], 0);
        let b = d.add(TaskKind::Head, Stream::Comm, 3.0, vec![a], 1);
        let _c = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![a], 2);
        let _e = d.add(TaskKind::Head, Stream::Compute, 4.0, vec![b], 3);
        assert_eq!(d.critical_path(), 9.0);
    }

    #[test]
    fn stream_busy_partitions() {
        let mut d = Dag::new();
        d.add(TaskKind::Head, Stream::Compute, 2.0, vec![], 0);
        d.add(TaskKind::Head, Stream::Comm, 3.0, vec![], 1);
        assert_eq!(d.stream_busy(Stream::Compute), 2.0);
        assert_eq!(d.stream_busy(Stream::Comm), 3.0);
    }

    #[test]
    fn validate_rejects_bad_duration() {
        let mut d = Dag::new();
        d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        d.tasks[0].dur = f64::NAN;
        assert!(d.validate().is_err());
    }

    #[test]
    fn kind_predicates() {
        assert!(TaskKind::Disp { l: 0, r: 0, phase: Phase::Fwd }.is_a2a());
        assert!(TaskKind::Ar { l: 0, c: 0 }.is_ar());
        assert!(!TaskKind::At { l: 0, r: 0, phase: Phase::Bwd }.is_a2a());
        assert_eq!(TaskKind::Ar { l: 3, c: 1 }.layer(), Some(3));
        assert_eq!(TaskKind::Head.layer(), None);
    }

    #[test]
    fn display_compact() {
        let k = TaskKind::At { l: 2, r: 1, phase: Phase::Bwd };
        assert_eq!(format!("{k}"), "ATb[2,1]");
    }
}
