//! Multi-type task DAG of one distributed-MoE training iteration.
//!
//! Implements the paper's task model (Sec. 3.2): the iteration is broken
//! into MHA+gating computing (`At`), dispatch/combine A2A communication
//! (`Disp`/`Comb`), expert computing (`Exp`) and all-reduce chunks (`Ar`),
//! each with forward and backward instances, related by the dependencies
//! of Eqs. 2–5 / 6a–6e. Scheduling policies (see [`crate::sched`]) build
//! concrete DAGs; the simulator ([`crate::sim`]) executes them on the
//! two-stream resource model the paper's theorems assume.

use std::fmt;

/// The hardware stream a task occupies (paper §3.3: one compute and one
/// communication task may run concurrently; same-stream tasks serialize).
///
/// `ArComm` is an optional third stream modelling concurrent NCCL
/// communicators (A2A and all-reduce on separate channels): the paper's
/// *theory* assumes a single communication stream, but its measured
/// speedups on communication-dominated models exceed that model's
/// comm-busy lower bound — which is only possible if A2A and AR overlap
/// physically. Policies choose strict (paper-theory) or concurrent
/// placement of AR chunks (see sched::Policy::ar_channel and
/// EXPERIMENTS.md §Findings).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stream {
    Compute,
    Comm,
    ArComm,
}

/// Phase of the iteration a task belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Fwd,
    Bwd,
}

/// Task types of the paper's set 𝕋 (Eq. 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// MHA + gating computing subtask `AT_r^(l)`.
    At { l: usize, r: usize, phase: Phase },
    /// Dispatch A2A `D_r^(l)`.
    Disp { l: usize, r: usize, phase: Phase },
    /// Expert computing `E_r^(l)`.
    Exp { l: usize, r: usize, phase: Phase },
    /// Combine A2A `C_r^(l)`.
    Comb { l: usize, r: usize, phase: Phase },
    /// All-reduce tensor chunk `AR^(l)` (backward only), chunk `c` of the
    /// block's replicated-gradient tensor.
    Ar { l: usize, c: usize },
    /// Embedding/head/loss compute at the fwd->bwd turnaround (not in the
    /// paper's notation; negligible duration but keeps the DAG honest).
    Head,
}

impl TaskKind {
    pub fn is_a2a(&self) -> bool {
        matches!(self, TaskKind::Disp { .. } | TaskKind::Comb { .. })
    }
    pub fn is_ar(&self) -> bool {
        matches!(self, TaskKind::Ar { .. })
    }
    pub fn layer(&self) -> Option<usize> {
        match self {
            TaskKind::At { l, .. }
            | TaskKind::Disp { l, .. }
            | TaskKind::Exp { l, .. }
            | TaskKind::Comb { l, .. }
            | TaskKind::Ar { l, .. } => Some(*l),
            TaskKind::Head => None,
        }
    }
}

impl fmt::Display for TaskKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ph = |p: &Phase| if *p == Phase::Fwd { "f" } else { "b" };
        match self {
            TaskKind::At { l, r, phase } => write!(f, "AT{}[{l},{r}]", ph(phase)),
            TaskKind::Disp { l, r, phase } => write!(f, "D{}[{l},{r}]", ph(phase)),
            TaskKind::Exp { l, r, phase } => write!(f, "E{}[{l},{r}]", ph(phase)),
            TaskKind::Comb { l, r, phase } => write!(f, "C{}[{l},{r}]", ph(phase)),
            TaskKind::Ar { l, c } => write!(f, "AR[{l}.{c}]"),
            TaskKind::Head => write!(f, "HEAD"),
        }
    }
}

pub type TaskId = usize;

/// Structural validation failure: the offending task ids plus a message.
/// Returned by [`Dag::validate`] so callers (tests, the static analyzer)
/// can point at the broken tasks instead of re-parsing an error string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DagError {
    pub tasks: Vec<TaskId>,
    pub message: String,
}

impl DagError {
    fn new(tasks: Vec<TaskId>, message: String) -> DagError {
        DagError { tasks, message }
    }
}

impl fmt::Display for DagError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for DagError {}

/// One schedulable task.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub kind: TaskKind,
    pub stream: Stream,
    /// Duration in seconds.
    pub dur: f64,
    /// Ids of tasks that must complete before this one starts.
    pub deps: Vec<TaskId>,
    /// Within-stream FIFO rank (Eqs. 2–5 ordering). The simulator picks,
    /// among ready same-stream tasks, the one with the smallest `seq`;
    /// AR chunks are *always* outranked by ready A2A tasks (Algorithm 2)
    /// regardless of `seq`.
    pub seq: u64,
    /// Bytes moved (comm tasks; 0 for compute) — metrics only.
    pub bytes: f64,
}

/// A complete iteration DAG.
#[derive(Clone, Debug, Default)]
pub struct Dag {
    pub tasks: Vec<Task>,
}

impl Dag {
    pub fn new() -> Self {
        Dag { tasks: Vec::new() }
    }

    pub fn add(&mut self, kind: TaskKind, stream: Stream, dur: f64, deps: Vec<TaskId>, seq: u64) -> TaskId {
        self.add_with_bytes(kind, stream, dur, deps, seq, 0.0)
    }

    pub fn add_with_bytes(
        &mut self,
        kind: TaskKind,
        stream: Stream,
        dur: f64,
        deps: Vec<TaskId>,
        seq: u64,
        bytes: f64,
    ) -> TaskId {
        let id = self.tasks.len();
        debug_assert!(deps.iter().all(|&d| d < id), "forward-only dep edges");
        self.tasks.push(Task {
            id,
            kind,
            stream,
            dur,
            deps,
            seq,
            bytes,
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Sum of durations per stream (lower bound on makespan per stream).
    pub fn stream_busy(&self, s: Stream) -> f64 {
        self.tasks.iter().filter(|t| t.stream == s).map(|t| t.dur).sum()
    }

    /// Critical-path lower bound on the makespan (longest dep chain).
    pub fn critical_path(&self) -> f64 {
        let mut finish = vec![0.0f64; self.tasks.len()];
        for t in &self.tasks {
            let start = t.deps.iter().map(|&d| finish[d]).fold(0.0, f64::max);
            finish[t.id] = start + t.dur;
        }
        finish.iter().copied().fold(0.0, f64::max)
    }

    /// Structural validation: ids consecutive, durations non-negative and
    /// finite, dep ids in range, no self-edges, no duplicate edges, and no
    /// dependency cycles (a real DFS — `build_dag` only emits forward
    /// edges, but hand-built or mutated DAGs can be arbitrary). Cheap:
    /// O(V + E) plus the short per-task duplicate scan.
    pub fn validate(&self) -> Result<(), DagError> {
        let n = self.tasks.len();
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id != i {
                return Err(DagError::new(vec![i], format!("task at index {i} has id {}", t.id)));
            }
            if !(t.dur.is_finite() && t.dur >= 0.0) {
                return Err(DagError::new(
                    vec![i],
                    format!("task {} ({}) bad duration {}", t.id, t.kind, t.dur),
                ));
            }
            for (j, &d) in t.deps.iter().enumerate() {
                if d >= n {
                    return Err(DagError::new(
                        vec![i],
                        format!("task {i} depends on out-of-range task {d} (n={n})"),
                    ));
                }
                if d == i {
                    return Err(DagError::new(vec![i], format!("task {i} depends on itself")));
                }
                if t.deps[..j].contains(&d) {
                    return Err(DagError::new(
                        vec![i, d],
                        format!("task {i} has a duplicate dep edge to task {d}"),
                    ));
                }
            }
        }
        if let Some(cycle) = self.find_cycle() {
            let path: Vec<String> = cycle.iter().map(|t| t.to_string()).collect();
            return Err(DagError::new(
                cycle,
                format!("dependency cycle: {}", path.join(" -> ")),
            ));
        }
        Ok(())
    }

    /// Find one dependency cycle, if any, returning the task ids along it
    /// in dependency order. Iterative three-color DFS over `deps` edges;
    /// out-of-range deps are skipped (reported by [`Dag::validate`]).
    pub fn find_cycle(&self) -> Option<Vec<TaskId>> {
        const WHITE: u8 = 0;
        const GRAY: u8 = 1;
        const BLACK: u8 = 2;
        let n = self.tasks.len();
        let mut color = vec![WHITE; n];
        let mut parent = vec![usize::MAX; n];
        for root in 0..n {
            if color[root] != WHITE {
                continue;
            }
            color[root] = GRAY;
            // explicit stack of (node, next-dep cursor) — DAGs here can be
            // hundreds of thousands of tasks deep, too deep for recursion
            let mut stack: Vec<(usize, usize)> = vec![(root, 0)];
            while let Some(frame) = stack.last_mut() {
                let u = frame.0;
                if frame.1 < self.tasks[u].deps.len() {
                    let v = self.tasks[u].deps[frame.1];
                    frame.1 += 1;
                    if v >= n {
                        continue;
                    }
                    match color[v] {
                        WHITE => {
                            color[v] = GRAY;
                            parent[v] = u;
                            stack.push((v, 0));
                        }
                        GRAY => {
                            // gray-on-gray back edge u -> v closes a cycle
                            // v -> ... -> u; walk the parent chain back.
                            let mut cyc = vec![u];
                            let mut w = u;
                            while w != v {
                                w = parent[w];
                                cyc.push(w);
                            }
                            cyc.reverse();
                            return Some(cyc);
                        }
                        _ => {}
                    }
                } else {
                    color[u] = BLACK;
                    stack.pop();
                }
            }
        }
        None
    }

    /// Count tasks of a coarse category (for tests/reports).
    pub fn count<F: Fn(&TaskKind) -> bool>(&self, pred: F) -> usize {
        self.tasks.iter().filter(|t| pred(&t.kind)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(kind: TaskKind) -> TaskKind {
        kind
    }

    #[test]
    fn add_assigns_sequential_ids() {
        let mut d = Dag::new();
        let a = d.add(t(TaskKind::Head), Stream::Compute, 1.0, vec![], 0);
        let b = d.add(t(TaskKind::Head), Stream::Compute, 1.0, vec![a], 1);
        assert_eq!((a, b), (0, 1));
        assert!(d.validate().is_ok());
    }

    #[test]
    fn critical_path_longest_chain() {
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 2.0, vec![], 0);
        let b = d.add(TaskKind::Head, Stream::Comm, 3.0, vec![a], 1);
        let _c = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![a], 2);
        let _e = d.add(TaskKind::Head, Stream::Compute, 4.0, vec![b], 3);
        assert_eq!(d.critical_path(), 9.0);
    }

    #[test]
    fn stream_busy_partitions() {
        let mut d = Dag::new();
        d.add(TaskKind::Head, Stream::Compute, 2.0, vec![], 0);
        d.add(TaskKind::Head, Stream::Comm, 3.0, vec![], 1);
        assert_eq!(d.stream_busy(Stream::Compute), 2.0);
        assert_eq!(d.stream_busy(Stream::Comm), 3.0);
    }

    #[test]
    fn validate_rejects_bad_duration() {
        let mut d = Dag::new();
        d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        d.tasks[0].dur = f64::NAN;
        assert!(d.validate().is_err());
    }

    #[test]
    fn validate_rejects_cycle() {
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        let b = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![a], 1);
        let c = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![b], 2);
        d.tasks[a].deps.push(c); // close the loop a -> b -> c -> a
        let err = d.validate().expect_err("cycle must be rejected");
        assert!(err.message.contains("cycle"), "{err}");
        let mut ids = err.tasks.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![a, b, c]);
        assert_eq!(d.find_cycle().map(|c| c.len()), Some(3));
    }

    #[test]
    fn validate_rejects_self_loop() {
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        d.tasks[a].deps.push(a);
        let err = d.validate().expect_err("self-loop must be rejected");
        assert_eq!(err.tasks, vec![a]);
    }

    #[test]
    fn validate_rejects_duplicate_edge() {
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        let b = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![a], 1);
        d.tasks[b].deps.push(a);
        let err = d.validate().expect_err("duplicate edge must be rejected");
        assert_eq!(err.tasks, vec![b, a]);
        assert!(err.message.contains("duplicate"), "{err}");
    }

    #[test]
    fn validate_rejects_out_of_range_dep() {
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        d.tasks[a].deps.push(99);
        assert!(d.validate().is_err());
    }

    #[test]
    fn validate_accepts_acyclic_backward_edge() {
        // edges are validated by cycle-freeness now, not id order: a DAG
        // whose textual order disagrees with topological order is legal
        let mut d = Dag::new();
        let a = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 0);
        let b = d.add(TaskKind::Head, Stream::Compute, 1.0, vec![], 1);
        d.tasks[a].deps.push(b);
        assert!(d.validate().is_ok());
        assert!(d.find_cycle().is_none());
    }

    #[test]
    fn kind_predicates() {
        assert!(TaskKind::Disp { l: 0, r: 0, phase: Phase::Fwd }.is_a2a());
        assert!(TaskKind::Ar { l: 0, c: 0 }.is_ar());
        assert!(!TaskKind::At { l: 0, r: 0, phase: Phase::Bwd }.is_a2a());
        assert_eq!(TaskKind::Ar { l: 3, c: 1 }.layer(), Some(3));
        assert_eq!(TaskKind::Head.layer(), None);
    }

    #[test]
    fn display_compact() {
        let k = TaskKind::At { l: 2, r: 1, phase: Phase::Bwd };
        assert_eq!(format!("{k}"), "ATb[2,1]");
    }
}
