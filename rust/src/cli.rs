//! Minimal CLI argument parsing (no clap offline): `--key value` /
//! `--flag` options plus positional arguments.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("simulate --model BERT --gpus 16 --verbose");
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.get("model"), Some("BERT"));
        assert_eq!(a.usize_or("gpus", 4), 16);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--sp=2.5 --r=4");
        assert_eq!(a.f64_or("sp", 0.0), 2.5);
        assert_eq!(a.usize_or("r", 2), 4);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.usize_or("steps", 10), 10);
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--dry-run --model GPT2");
        assert!(a.has_flag("dry-run") || a.get("dry-run").is_some());
        assert_eq!(a.get("model"), Some("GPT2"));
    }
}
