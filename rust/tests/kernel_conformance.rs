//! Kernel-conformance suite for the dispatch tiers (§Perf):
//!
//! * every dispatch path (`naive` / `blocked` / `simd`) agrees with the
//!   naive `*_ref` oracles within the documented 1e-4 rel-tol, across
//!   awkward shapes — odd, prime, tile-aligned, remainder-heavy, and
//!   non-multiple-of-8 K (the SIMD tail path),
//! * softmax / RMSNorm / expert-FFN under the `simd` tier agree with the
//!   scalar tiers within the same contract,
//! * within a **fixed** path, results are byte-identical across
//!   `FLOWMOE_THREADS`-style budgets {1, 2, 4, 7} — banding and the
//!   parallel cross-entropy row loop must never change a bit.
//!
//! The `simd` tier is forced via `kernels::with_dispatch`, which runs
//! the portable 8-lane fallback on hosts without AVX2 — so this suite
//! exercises all three tiers on every host.

use flowmoe::backend::kernels as kn;
use flowmoe::backend::kernels::Dispatch;
use flowmoe::backend::model as nm;
use flowmoe::sweep::scope;
use flowmoe::util::Rng;

const PATHS: [Dispatch; 3] = [Dispatch::Naive, Dispatch::Blocked, Dispatch::Simd];
const BUDGETS: [usize; 3] = [2, 4, 7];
/// Awkward dimension set from the issue: odd, prime, power-of-two, and
/// non-multiple-of-8 values (1, 3, 7, 9, 17, 31, 100 all exercise the
/// 8-lane remainder handling when used as K).
const DIMS: [usize; 9] = [1, 3, 7, 8, 9, 17, 31, 64, 100];

fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * s).collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[track_caller]
fn assert_rel_close(got: &[f32], want: &[f32], rel: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = rel * (g.abs() + w.abs()) + 1e-5;
        assert!((g - w).abs() <= tol, "{what}[{i}]: {g} vs {w}");
    }
}

/// Every dispatch path vs the naive oracles over a rotated cross of the
/// awkward dimension set (every value appears in every position) plus a
/// few large shapes that cross the packed-B and banding gates.
#[test]
fn all_paths_match_ref_oracles_across_awkward_shapes() {
    let mut rng = Rng::new(2026);
    let mut shapes: Vec<(usize, usize, usize)> = Vec::new();
    for i in 0..DIMS.len() {
        for j in 0..DIMS.len() {
            shapes.push((DIMS[i], DIMS[j], DIMS[(i + j) % DIMS.len()]));
        }
    }
    // packed-B (m >= 8, k*n >= 4096) and band-parallel (macs >= 2^18)
    shapes.extend([(16, 64, 80), (64, 100, 64), (100, 31, 100)]);
    for (m, k, n) in shapes {
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let bt = randv(&mut rng, n * k, 1.0);
        let at = randv(&mut rng, k * m, 1.0);
        let want_mm = kn::matmul_ref(&a, &b, m, k, n);
        let want_nt = kn::matmul_nt_ref(&a, &bt, m, k, n);
        let want_tn = kn::matmul_tn_ref(&at, &b, k, m, n);
        for d in PATHS {
            kn::with_dispatch(d, || {
                let tag = d.name();
                assert_rel_close(&kn::matmul(&a, &b, m, k, n), &want_mm, 1e-4, &format!("{tag} mm {m}x{k}x{n}"));
                assert_rel_close(
                    &kn::matmul_nt(&a, &bt, m, k, n),
                    &want_nt,
                    1e-4,
                    &format!("{tag} nt {m}x{k}x{n}"),
                );
                assert_rel_close(
                    &kn::matmul_tn(&at, &b, k, m, n),
                    &want_tn,
                    1e-4,
                    &format!("{tag} tn {m}x{k}x{n}"),
                );
            });
        }
    }
}

/// softmax / softmax-backward / RMSNorm fwd+bwd: the `simd` tier's
/// 8-lane reductions vs the scalar tiers, across row lengths that
/// exercise the lane remainder.
#[test]
fn softmax_and_rmsnorm_simd_conform_to_scalar() {
    let mut rng = Rng::new(7);
    for n in [1usize, 3, 5, 8, 9, 33, 100] {
        let t = 4usize;
        let x = randv(&mut rng, t * n, 1.5);
        let g = randv(&mut rng, n, 0.8);
        let dy = randv(&mut rng, t * n, 1.0);
        let p_ref = kn::with_dispatch(Dispatch::Blocked, || kn::softmax_rows(&x, n));
        let p_simd = kn::with_dispatch(Dispatch::Simd, || kn::softmax_rows(&x, n));
        assert_rel_close(&p_simd, &p_ref, 1e-4, &format!("softmax n={n}"));
        let dp_ref = kn::with_dispatch(Dispatch::Blocked, || kn::softmax_bwd_rows(&p_ref, &dy, n));
        let dp_simd = kn::with_dispatch(Dispatch::Simd, || kn::softmax_bwd_rows(&p_ref, &dy, n));
        assert_rel_close(&dp_simd, &dp_ref, 1e-4, &format!("softmax_bwd n={n}"));
        let y_ref = kn::with_dispatch(Dispatch::Blocked, || kn::rmsnorm(&x, &g));
        let y_simd = kn::with_dispatch(Dispatch::Simd, || kn::rmsnorm(&x, &g));
        assert_rel_close(&y_simd, &y_ref, 1e-4, &format!("rmsnorm n={n}"));
        let (dx_ref, dg_ref) = kn::with_dispatch(Dispatch::Blocked, || kn::rmsnorm_bwd(&x, &g, &dy));
        let (dx_simd, dg_simd) = kn::with_dispatch(Dispatch::Simd, || kn::rmsnorm_bwd(&x, &g, &dy));
        assert_rel_close(&dx_simd, &dx_ref, 1e-4, &format!("rmsnorm_bwd dx n={n}"));
        assert_rel_close(&dg_simd, &dg_ref, 1e-4, &format!("rmsnorm_bwd dg n={n}"));
    }
}

/// Expert FFN fwd+bwd across all three tiers (no `*_ref` oracle exists;
/// the `naive` tier — reference triple loops — is the baseline).
#[test]
fn expert_ffn_all_paths_conform() {
    let (e, c, m, h) = (3usize, 5usize, 12usize, 9usize); // odd, non-8-multiple
    let mut rng = Rng::new(11);
    let x = randv(&mut rng, e * c * m, 0.7);
    let w1 = randv(&mut rng, e * m * h, 0.4);
    let w2 = randv(&mut rng, e * h * m, 0.4);
    let dy = randv(&mut rng, e * c * m, 1.0);
    let want_f = kn::with_dispatch(Dispatch::Naive, || kn::expert_ffn(&x, &w1, &w2, e, c, m, h));
    let (want_dx, want_dw1, want_dw2) =
        kn::with_dispatch(Dispatch::Naive, || kn::expert_ffn_bwd(&x, &w1, &w2, &dy, e, c, m, h));
    for d in [Dispatch::Blocked, Dispatch::Simd] {
        kn::with_dispatch(d, || {
            let tag = d.name();
            assert_rel_close(&kn::expert_ffn(&x, &w1, &w2, e, c, m, h), &want_f, 1e-4, &format!("{tag} ffn"));
            let (dx, dw1, dw2) = kn::expert_ffn_bwd(&x, &w1, &w2, &dy, e, c, m, h);
            assert_rel_close(&dx, &want_dx, 1e-4, &format!("{tag} ffn dx"));
            assert_rel_close(&dw1, &want_dw1, 1e-4, &format!("{tag} ffn dw1"));
            assert_rel_close(&dw2, &want_dw2, 1e-4, &format!("{tag} ffn dw2"));
        });
    }
}

/// Within a fixed dispatch path, the banded matmuls must be
/// byte-identical across thread budgets. Shapes sit above the parallel
/// work gate so the fan-out really runs.
#[test]
fn matmuls_deterministic_across_budgets_within_each_path() {
    let mut rng = Rng::new(31);
    for &(m, k, n) in &[(64usize, 64usize, 64usize), (100, 53, 67)] {
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let bt = randv(&mut rng, n * k, 1.0);
        let at = randv(&mut rng, k * m, 1.0);
        for d in PATHS {
            kn::with_dispatch(d, || {
                let s_mm = scope::with_budget(1, || kn::par_matmul(&a, &b, m, k, n));
                let s_nt = scope::with_budget(1, || kn::par_matmul_nt(&a, &bt, m, k, n));
                let s_tn = scope::with_budget(1, || kn::par_matmul_tn(&at, &b, k, m, n));
                for budget in BUDGETS {
                    scope::with_budget(budget, || {
                        let tag = format!("{} b={budget} {m}x{k}x{n}", d.name());
                        assert!(bits_eq(&s_mm, &kn::par_matmul(&a, &b, m, k, n)), "mm {tag}");
                        assert!(bits_eq(&s_nt, &kn::par_matmul_nt(&a, &bt, m, k, n)), "nt {tag}");
                        assert!(bits_eq(&s_tn, &kn::par_matmul_tn(&at, &b, k, m, n)), "tn {tag}");
                    });
                }
            });
        }
    }
}

/// Expert fan-out determinism across budgets, per path.
#[test]
fn expert_ffn_deterministic_across_budgets_within_each_path() {
    let (e, c, m, h) = (4usize, 32usize, 32usize, 256usize); // above the gate
    let mut rng = Rng::new(33);
    let x = randv(&mut rng, e * c * m, 0.7);
    let w1 = randv(&mut rng, e * m * h, 0.4);
    let w2 = randv(&mut rng, e * h * m, 0.4);
    let dy = randv(&mut rng, e * c * m, 1.0);
    for d in PATHS {
        kn::with_dispatch(d, || {
            let fwd_s = scope::with_budget(1, || kn::expert_ffn(&x, &w1, &w2, e, c, m, h));
            let (dx_s, dw1_s, dw2_s) =
                scope::with_budget(1, || kn::expert_ffn_bwd(&x, &w1, &w2, &dy, e, c, m, h));
            for budget in BUDGETS {
                scope::with_budget(budget, || {
                    let tag = format!("{} b={budget}", d.name());
                    assert!(bits_eq(&fwd_s, &kn::expert_ffn(&x, &w1, &w2, e, c, m, h)), "fwd {tag}");
                    let (dx, dw1, dw2) = kn::expert_ffn_bwd(&x, &w1, &w2, &dy, e, c, m, h);
                    assert!(bits_eq(&dx_s, &dx), "dx {tag}");
                    assert!(bits_eq(&dw1_s, &dw1), "dw1 {tag}");
                    assert!(bits_eq(&dw2_s, &dw2), "dw2 {tag}");
                });
            }
        });
    }
}

fn head_geo() -> nm::Geo {
    // t * vocab = 64 * 257 crosses the CE parallel gate; vocab = 257 and
    // m = 16 exercise the 8-lane remainders; the LM-head matmul_nt
    // crosses both the packed-B and the band-parallel gates.
    nm::Geo {
        m: 16,
        e: 4,
        h: 8,
        top_k: 2,
        n_heads: 2,
        n_seq: 16,
        f: 4.0,
        vocab: 257,
    }
}

/// The parallelized cross-entropy row loop (plus the packed LM head)
/// must be byte-identical across budgets within each path — loss
/// included (per-row losses are summed in fixed order).
#[test]
fn head_loss_deterministic_across_budgets_within_each_path() {
    let g = head_geo();
    let b = 4usize;
    let t = b * g.n_seq;
    let mut rng = Rng::new(41);
    let xf = randv(&mut rng, t * g.m, 0.8);
    let normf: Vec<f32> = (0..g.m).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
    let embed = randv(&mut rng, g.vocab * g.m, 0.4);
    let tokens: Vec<i32> = (0..t).map(|_| rng.below(g.vocab) as i32).collect();
    for d in PATHS {
        kn::with_dispatch(d, || {
            let (loss_s, dxf_s, de_s, dn_s) =
                scope::with_budget(1, || nm::head_loss(&g, &embed, &normf, &xf, &tokens, b));
            for budget in BUDGETS {
                scope::with_budget(budget, || {
                    let tag = format!("{} b={budget}", d.name());
                    let (loss, dxf, de, dn) = nm::head_loss(&g, &embed, &normf, &xf, &tokens, b);
                    assert_eq!(loss_s.to_bits(), loss.to_bits(), "loss {tag}");
                    assert!(bits_eq(&dxf_s, &dxf), "dxf {tag}");
                    assert!(bits_eq(&de_s, &de), "dembed {tag}");
                    assert!(bits_eq(&dn_s, &dn), "dnormf {tag}");
                });
            }
        });
    }
}

/// The head-loss values themselves conform across tiers (the simd CE
/// reassociates its reductions — the 1e-4 contract must hold).
#[test]
fn head_loss_simd_conforms_to_scalar() {
    let g = head_geo();
    let b = 4usize;
    let t = b * g.n_seq;
    let mut rng = Rng::new(43);
    let xf = randv(&mut rng, t * g.m, 0.8);
    let normf: Vec<f32> = (0..g.m).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
    let embed = randv(&mut rng, g.vocab * g.m, 0.4);
    let tokens: Vec<i32> = (0..t).map(|_| rng.below(g.vocab) as i32).collect();
    let (loss_b, dxf_b, de_b, dn_b) =
        kn::with_dispatch(Dispatch::Blocked, || nm::head_loss(&g, &embed, &normf, &xf, &tokens, b));
    let (loss_n, ..) = kn::with_dispatch(Dispatch::Naive, || nm::head_loss(&g, &embed, &normf, &xf, &tokens, b));
    let (loss_s, dxf_s, de_s, dn_s) =
        kn::with_dispatch(Dispatch::Simd, || nm::head_loss(&g, &embed, &normf, &xf, &tokens, b));
    assert!((loss_s - loss_b).abs() <= 1e-4 * (loss_b.abs() + 1.0), "{loss_s} vs {loss_b}");
    assert!((loss_n - loss_b).abs() <= 1e-4 * (loss_b.abs() + 1.0), "{loss_n} vs {loss_b}");
    assert_rel_close(&dxf_s, &dxf_b, 2e-4, "head dxf simd-vs-blocked");
    assert_rel_close(&de_s, &de_b, 2e-4, "head dembed simd-vs-blocked");
    assert_rel_close(&dn_s, &dn_b, 2e-4, "head dnormf simd-vs-blocked");
}

/// A full MHA fwd+bwd under a forced tier stays deterministic across
/// budgets — the model-level fan-outs must propagate the thread-local
/// dispatch override into their scope workers.
#[test]
fn mha_dispatch_override_survives_head_fanout() {
    let g = nm::Geo {
        m: 32,
        e: 4,
        h: 16,
        top_k: 2,
        n_heads: 4,
        n_seq: 32,
        f: 4.0,
        vocab: 64,
    };
    let mut rng = Rng::new(47);
    let params: Vec<Vec<f32>> = vec![
        vec![1.0; g.m],
        randv(&mut rng, g.m * g.m, 0.3),
        randv(&mut rng, g.m * g.m, 0.3),
        randv(&mut rng, g.m * g.m, 0.3),
        randv(&mut rng, g.m * g.m, 0.3),
        vec![1.0; g.m],
        randv(&mut rng, g.m * g.e, 0.5),
    ];
    let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let atp = nm::AtParams::new(&refs);
    let b = 4usize; // units * N^2 * hd = 16 * 1024 * 8 clears the gate
    let x = randv(&mut rng, b * g.n_seq * g.m, 0.5);
    let dh = randv(&mut rng, x.len(), 1.0);
    for d in PATHS {
        kn::with_dispatch(d, || {
            let (h_s, dx_s) = scope::with_budget(1, || {
                let st = nm::mha_forward(&g, &atp, &x);
                let (_, dx) = nm::mha_backward(&g, &atp, &x, &st, &dh);
                (st.h, dx)
            });
            for budget in BUDGETS {
                scope::with_budget(budget, || {
                    let st = nm::mha_forward(&g, &atp, &x);
                    assert!(bits_eq(&h_s, &st.h), "{} b={budget} h", d.name());
                    let (_, dx) = nm::mha_backward(&g, &atp, &x, &st, &dh);
                    assert!(bits_eq(&dx_s, &dx), "{} b={budget} dx", d.name());
                });
            }
        });
    }
}

/// The `_into` drivers (the non-allocating entry points the model layer
/// actually calls, including the workspace-pooled NT head path) conform
/// to the same oracles and are byte-identical to their allocating twins
/// across budgets.
#[test]
fn into_variants_match_oracles_and_allocating_twins() {
    use flowmoe::backend::Workspace;
    let mut rng = Rng::new(77);
    // small/awkward plus one shape past the packed-B and banding gates
    let shapes = [(3usize, 7usize, 9usize), (17, 31, 8), (64, 100, 64)];
    for (m, k, n) in shapes {
        let a = randv(&mut rng, m * k, 1.0);
        let b = randv(&mut rng, k * n, 1.0);
        let bt = randv(&mut rng, n * k, 1.0);
        let at = randv(&mut rng, k * m, 1.0);
        let want_mm = kn::matmul_ref(&a, &b, m, k, n);
        let want_nt = kn::matmul_nt_ref(&a, &bt, m, k, n);
        let want_tn = kn::matmul_tn_ref(&at, &b, k, m, n);
        for d in PATHS {
            kn::with_dispatch(d, || {
                let tag = format!("{} {m}x{k}x{n}", d.name());
                let mut out = vec![0.0f32; m * n];
                for budget in [1usize, 2, 7] {
                    scope::with_budget(budget, || {
                        kn::par_matmul_into(&a, &b, &mut out, m, k, n);
                        assert_rel_close(&out, &want_mm, 1e-4, &format!("{tag} mm_into b={budget}"));
                        assert!(bits_eq(&out, &kn::par_matmul(&a, &b, m, k, n)), "{tag} mm twin");

                        kn::par_matmul_nt_into(&a, &bt, &mut out, m, k, n);
                        assert_rel_close(&out, &want_nt, 1e-4, &format!("{tag} nt_into b={budget}"));
                        assert!(bits_eq(&out, &kn::par_matmul_nt(&a, &bt, m, k, n)), "{tag} nt twin");

                        // the workspace-pooled NT path must agree bit-for-bit
                        // with the plain NT driver (same kernels, pooled panel)
                        let mut ws = Workspace::new();
                        let mut out_ws = vec![0.0f32; m * n];
                        kn::par_matmul_nt_into_ws(&a, &bt, &mut out_ws, m, k, n, &mut ws);
                        assert!(bits_eq(&out_ws, &out), "{tag} nt_ws b={budget}");

                        kn::par_matmul_tn_into(&at, &b, &mut out, k, m, n);
                        assert_rel_close(&out, &want_tn, 1e-4, &format!("{tag} tn_into b={budget}"));
                        assert!(bits_eq(&out, &kn::par_matmul_tn(&at, &b, k, m, n)), "{tag} tn twin");
                    });
                }
            });
        }
    }
}
