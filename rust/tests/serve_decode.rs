//! Integration tests for the serving subsystem (`flowmoe serve`):
//!
//! * KV-cached incremental decode must match a full-prefix
//!   `block_forward` recompute at **every** step (the ISSUE pins
//!   sequence lengths 1, 7 and 32 explicitly),
//! * expert-parallel decode must be identical to single-process decode,
//! * the continuous-batching scheduler must leak neither slots nor KV
//!   budget and must admit strictly FIFO,
//! * a full synthetic run must be deterministic per seed in everything
//!   but wall-clock timing.

use flowmoe::backend::kernels as kn;
use flowmoe::backend::model::{block_forward, lm_head_logits_ws, BlockParams, Geo};
use flowmoe::backend::Workspace;
use flowmoe::config::preset;
use flowmoe::ft::FaultPlan;
use flowmoe::serve::{
    argmax_rows, init_params, run_synthetic, traffic, Decoder, EpExperts, ExpertBackend, KvCache, Scheduler, ServeOpts,
    TrafficCfg,
};
use flowmoe::util::Pcg32;

fn tiny_geo() -> (Geo, usize) {
    let cfg = preset("tiny").expect("tiny preset exists");
    (Geo::from_cfg(&cfg), cfg.l)
}

/// Decode token t against the KV cache == row t of a fresh full-prefix
/// forward over tokens[..=t], at every prefix length 1..=32.
#[test]
fn cached_decode_matches_full_prefix_recompute() {
    let (g, l_blocks) = tiny_geo();
    let params = init_params(&g, l_blocks, 11);
    let mut dec = Decoder::new(g, params.clone(), 1);
    let mut cache = KvCache::new(l_blocks, 40, g.m, dec.workspace());
    let mut rng = Pcg32::new(5);
    let tokens: Vec<i32> = (0..32).map(|_| rng.below(g.vocab) as i32).collect();
    let mut checked = Vec::new();
    for t in 1..=tokens.len() {
        let dec_logits = {
            let mut refs = [&mut cache];
            dec.decode_logits(&tokens[t - 1..t], &mut refs)
        };
        // full-prefix recompute with drop-free capacity (c = k*t rows
        // per expert can absorb any routing)
        let mut gt = g;
        gt.n_seq = t;
        let mut x = vec![0.0f32; t * g.m];
        kn::embed_lookup_into(&params[0], &tokens[..t], g.m, &mut x);
        for l in 0..l_blocks {
            let refs: Vec<&[f32]> = params[1 + l * 9..1 + (l + 1) * 9].iter().map(|v| v.as_slice()).collect();
            let bp = BlockParams::new(&refs);
            let (y, _state) = block_forward(&gt, &bp, &x, g.top_k * t);
            x = y;
        }
        let full = lm_head_logits_ws(&gt, &params[0], &params[params.len() - 1], &x, &mut Workspace::new());
        let last_row = &full[(t - 1) * g.vocab..t * g.vocab];
        for (j, (a, b)) in dec_logits.iter().zip(last_row).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5,
                "prefix len {t}, logit {j}: cached {a} vs recomputed {b}"
            );
        }
        checked.push(t);
        dec.workspace().put(dec_logits);
    }
    for required in [1usize, 7, 32] {
        assert!(checked.contains(&required), "length {required} must be covered");
    }
}

/// EP serving output (tokens AND logits) is identical to single-process
/// local decode: replication only splits each expert's capacity rows,
/// and row results are independent of band composition.
#[test]
fn ep_decode_identical_to_local() {
    let (g, l_blocks) = tiny_geo();
    let params = init_params(&g, l_blocks, 3);
    let run = |ep: bool| -> (Vec<i32>, Vec<f32>) {
        let mut dec = Decoder::new(g, params.clone(), 2);
        if ep {
            // e + 2 workers => two experts get a second replica
            let counts: Vec<u64> = (0..g.e as u64).collect();
            let cluster = EpExperts::new(&g, dec.params(), &counts, g.e + 2, dec.capacity());
            assert_eq!(cluster.n_workers(), g.e + 2);
            dec.set_backend(ExpertBackend::Ep(cluster));
        }
        let mut ca = KvCache::new(l_blocks, 16, g.m, dec.workspace());
        let mut cb = KvCache::new(l_blocks, 16, g.m, dec.workspace());
        let mut toks = vec![3i32, 17i32];
        let mut all = Vec::new();
        let mut last_logits = Vec::new();
        for _ in 0..12 {
            let logits = {
                let mut refs = [&mut ca, &mut cb];
                dec.decode_logits(&toks, &mut refs)
            };
            let next = argmax_rows(&logits, g.vocab);
            all.extend(next.iter().copied());
            last_logits = logits.clone();
            dec.workspace().put(logits);
            toks = next;
        }
        if let ExpertBackend::Ep(mut cluster) = dec.set_backend(ExpertBackend::Local) {
            cluster.shutdown();
        }
        (all, last_logits)
    };
    let (local_toks, local_logits) = run(false);
    let (ep_toks, ep_logits) = run(true);
    assert_eq!(local_toks, ep_toks, "token streams must be identical");
    assert_eq!(local_logits, ep_logits, "final-step logits must be bitwise identical");
}

/// A worker killed mid-decode is healed in place (respawn + replay) and
/// the output stream stays **bitwise** identical to a faultless run —
/// the row-independence contract makes recovery invisible to clients.
#[test]
fn ep_decode_survives_worker_kill_bitwise() {
    let (g, l_blocks) = tiny_geo();
    let params = init_params(&g, l_blocks, 3);
    let run = |fault: Option<FaultPlan>| -> (Vec<i32>, Vec<f32>, usize) {
        let mut dec = Decoder::new(g, params.clone(), 2);
        let counts: Vec<u64> = (0..g.e as u64).collect();
        let cluster =
            EpExperts::with_fault(&g, dec.params(), &counts, g.e, dec.capacity(), fault, 2000);
        dec.set_backend(ExpertBackend::Ep(cluster));
        let mut ca = KvCache::new(l_blocks, 16, g.m, dec.workspace());
        let mut cb = KvCache::new(l_blocks, 16, g.m, dec.workspace());
        let mut toks = vec![3i32, 17i32];
        let mut all = Vec::new();
        let mut last_logits = Vec::new();
        for _ in 0..12 {
            let logits = {
                let mut refs = [&mut ca, &mut cb];
                dec.decode_logits(&toks, &mut refs)
            };
            let next = argmax_rows(&logits, g.vocab);
            all.extend(next.iter().copied());
            last_logits = logits.clone();
            dec.workspace().put(logits);
            toks = next;
        }
        let respawns = match dec.set_backend(ExpertBackend::Local) {
            ExpertBackend::Ep(mut cluster) => {
                let r = cluster.respawns();
                cluster.shutdown();
                r
            }
            _ => 0,
        };
        (all, last_logits, respawns)
    };
    let (clean_toks, clean_logits, clean_resp) = run(None);
    assert_eq!(clean_resp, 0, "faultless run must not respawn anyone");
    let (ft_toks, ft_logits, ft_resp) = run(Some(FaultPlan {
        seed: 11,
        kill: Some((0, 3)),
        ..FaultPlan::default()
    }));
    assert_eq!(ft_resp, 1, "the killed worker must be respawned exactly once");
    assert_eq!(clean_toks, ft_toks, "token streams must survive the kill bitwise");
    assert_eq!(clean_logits, ft_logits, "final-step logits must survive the kill bitwise");
}

/// Pushing a realistic traffic trace through the scheduler with a dummy
/// model: every request completes, no slot or KV-budget leak, and
/// completion of equal-shape requests follows FIFO admission.
#[test]
fn scheduler_no_leak_under_synthetic_load() {
    let reqs = traffic::generate(
        21,
        &TrafficCfg {
            requests: 120,
            mean_gap_steps: 0.7,
            max_prompt: 12,
            max_new: 8,
            len_zipf_s: 1.2,
            vocab: 64,
        },
    );
    let mut sched = Scheduler::new(4, 64);
    let mut next_req = 0usize;
    let mut step = 0u64;
    let mut max_kv = 0usize;
    for _ in 0..200_000 {
        while next_req < reqs.len() && reqs[next_req].arrival_step <= step {
            sched.push(reqs[next_req].clone());
            next_req += 1;
        }
        sched.admit(step);
        max_kv = max_kv.max(sched.kv_used());
        let batch = sched.batch();
        if batch.is_empty() {
            if next_req >= reqs.len() && sched.pending_len() == 0 {
                break;
            }
            step += 1;
            continue;
        }
        for (slot, tok) in batch {
            sched.record(slot, tok); // echo model: output = input
        }
        step += 1;
    }
    assert_eq!(sched.admitted, 120);
    assert_eq!(sched.finished, 120, "every request must complete");
    assert_eq!(sched.active(), 0, "no slot leak");
    assert_eq!(sched.kv_used(), 0, "no KV budget leak");
    assert!(max_kv <= 64, "KV budget respected at all times (peak {max_kv})");
}

/// Equal-shape requests finish in arrival order: FIFO admission can
/// never let a later request overtake an earlier one.
#[test]
fn fifo_admission_is_fair() {
    let mut sched = Scheduler::new(2, 1000);
    for id in 0..9u64 {
        sched.push(flowmoe::serve::Request {
            id,
            arrival_step: id, // staggered arrivals
            prompt: vec![1, 2, 3],
            max_new: 4,
        });
    }
    let mut finish_order = Vec::new();
    for step in 0..1000u64 {
        sched.admit(step);
        let batch = sched.batch();
        if batch.is_empty() && sched.pending_len() == 0 {
            break;
        }
        for (slot, tok) in batch {
            if let (_, Some(fin)) = sched.record(slot, tok) {
                finish_order.push(fin.id);
            }
        }
    }
    assert_eq!(finish_order, (0..9).collect::<Vec<u64>>());
}

/// Two identical synthetic runs agree on every deterministic field —
/// the property `flowmoe serve --synthetic --seed 7` is specified to
/// have (BENCH_serve.json identical modulo the timing block).
#[test]
fn synthetic_run_is_deterministic_per_seed() {
    let mut opts = ServeOpts::new("tiny");
    opts.seed = 7;
    opts.requests = 40;
    opts.warmup_steps = 6;
    opts.max_batch = 4;
    opts.kv_budget = 256;
    let a = run_synthetic(&opts).expect("run a");
    let b = run_synthetic(&opts).expect("run b");
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.admitted, b.admitted);
    assert_eq!(a.finished, b.finished);
    assert_eq!(a.finished, 40, "all requests served");
    assert_eq!(a.prefill_tokens, b.prefill_tokens);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.token_checksum, b.token_checksum);
    assert_eq!(a.capacity, b.capacity);
    assert_eq!(a.workers_used, b.workers_used);
    assert_eq!(a.replicas, b.replicas);
    assert_eq!(a.req_latency_steps_p50, b.req_latency_steps_p50);
    assert_eq!(a.req_latency_steps_p99, b.req_latency_steps_p99);
    assert_eq!(a.queue_wait_steps_p50, b.queue_wait_steps_p50);
    assert_eq!(a.queue_wait_steps_p99, b.queue_wait_steps_p99);
    // a different seed must change the stream
    let mut opts2 = opts.clone();
    opts2.seed = 8;
    let c = run_synthetic(&opts2).expect("run c");
    assert_ne!(a.token_checksum, c.token_checksum);
}
