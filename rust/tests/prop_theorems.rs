//! Property tests for the paper's theorems and the scheduling invariants,
//! over randomized model/cluster instances (see testutil::prop; seeds are
//! reported on failure for exact replay).

use flowmoe::config::{ClusterProfile, ModelCfg};
use flowmoe::cost::TaskCosts;
use flowmoe::prop_assert;
use flowmoe::sched::{build_dag, Policy};
use flowmoe::sim::{simulate, verify_timeline};
use flowmoe::tasks::Stream;
use flowmoe::testutil::check;
use flowmoe::util::Rng;

fn random_model(rng: &mut Rng) -> ModelCfg {
    let b = *rng.choose(&[2usize, 4, 8]);
    let f = *rng.choose(&[1.0, 1.1, 1.2]);
    let n = *rng.choose(&[128usize, 256, 512, 1024]);
    let m = *rng.choose(&[256usize, 512, 1024, 2048]);
    let h = *rng.choose(&[512usize, 1024, 2048, 4096]);
    let p = *rng.choose(&[4usize, 8, 16]);
    let mut cfg = ModelCfg::custom_layer(b, f, n, m, h, p);
    cfg.l = rng.range(2, 8);
    cfg
}

fn random_cluster(rng: &mut Rng, p: usize) -> ClusterProfile {
    let mut cl = if rng.below(2) == 0 {
        ClusterProfile::cluster1(p)
    } else {
        ClusterProfile::cluster2(p)
    };
    // jitter the calibration so properties don't depend on one point
    cl.net.ar_bw *= rng.range_f64(0.5, 2.0);
    cl.net.inter_bw *= rng.range_f64(0.5, 2.0);
    cl.gpu.peak_flops *= rng.range_f64(0.5, 2.0);
    cl
}

fn cluster_p(cfg: &ModelCfg) -> usize {
    cfg.e // custom layers use E = P
}

#[test]
fn prop_schedules_are_valid_under_all_policies() {
    check(60, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let costs = TaskCosts::build(&cfg, &cl);
        let r = *rng.choose(&[2usize, 4]);
        for pol in [
            Policy::vanilla_ep(),
            Policy::tutel(r),
            Policy::flow_moe(r, rng.range_f64(0.2, 20.0) * 1e6),
            Policy::flow_moe_cc(r, rng.range_f64(0.2, 20.0) * 1e6),
        ] {
            let dag = build_dag(&cfg, &costs, &pol);
            dag.validate().map_err(|e| format!("{}: {e}", pol.name))?;
            let tl = simulate(&dag);
            verify_timeline(&dag, &tl).map_err(|e| format!("{}: {e}", pol.name))?;
            prop_assert!(
                tl.makespan >= dag.critical_path() - 1e-9,
                "{}: makespan below critical path",
                pol.name
            );
            prop_assert!(
                tl.makespan >= dag.stream_busy(Stream::Comm) - 1e-9,
                "{}: makespan below comm busy",
                pol.name
            );
        }
        Ok(())
    });
}

#[test]
fn prop_theorem1_chunked_ar_not_worse_without_startup() {
    // Theorem 1 as stated: with zero chunk-startup overhead, inserting AR
    // chunks between A2A tasks (priority rule) never loses to centralized
    // AR at the end of backward.
    check(60, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let mut costs = TaskCosts::build(&cfg, &cl);
        costs.ar_alpha = 0.0; // the theorem's assumption
        let r = *rng.choose(&[2usize, 4]);
        let sp = rng.range_f64(0.05, 4.0) * 1e6;

        let central = {
            let dag = build_dag(&cfg, &costs, &Policy::flow_moe_at(r));
            simulate(&dag).makespan
        };
        let chunked = {
            let dag = build_dag(&cfg, &costs, &Policy::flow_moe(r, sp));
            simulate(&dag).makespan
        };
        // Non-preemptive blocking can cost at most one chunk duration per
        // A2A gap in pathological cases; Theorem 1's statement covers the
        // idealized insertion. Allow a 1% slack for the non-preemption
        // artefact and require the typical case to win.
        prop_assert!(
            chunked <= central * 1.01 + 1e-9,
            "chunked {chunked} > centralized {central} (sp={sp}, L={}, model {:?})",
            cfg.l,
            (cfg.b, cfg.n, cfg.m, cfg.h)
        );
        Ok(())
    });
}

#[test]
fn prop_theorem2_smaller_chunks_monotone_without_startup() {
    // Theorem 2: without startup overhead, iteration time is minimized as
    // S_p -> 0; check monotone non-increase over a decreasing S_p ladder.
    check(40, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let mut costs = TaskCosts::build(&cfg, &cl);
        costs.ar_alpha = 0.0;
        let r = 2;
        let ladder = [64e6, 16e6, 4e6, 1e6, 0.25e6];
        let mut prev = f64::INFINITY;
        for sp in ladder {
            let dag = build_dag(&cfg, &costs, &Policy::flow_moe(r, sp));
            let t = simulate(&dag).makespan;
            prop_assert!(
                t <= prev * 1.005 + 1e-9,
                "S_p {sp}: {t} > previous {prev}"
            );
            prev = prev.min(t);
        }
        Ok(())
    });
}

#[test]
fn prop_with_startup_tiny_chunks_eventually_lose() {
    // The real trade-off (paper Sec. 4.1): with startup overhead, S_p -> 0
    // must eventually be worse than a moderate S_p.
    check(30, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let costs = TaskCosts::build(&cfg, &cl);
        let moderate = {
            let dag = build_dag(&cfg, &costs, &Policy::flow_moe(2, 4e6));
            simulate(&dag).makespan
        };
        let tiny = {
            let dag = build_dag(&cfg, &costs, &Policy::flow_moe(2, 0.01e6));
            simulate(&dag).makespan
        };
        prop_assert!(
            tiny > moderate,
            "tiny chunks {tiny} not worse than moderate {moderate}"
        );
        Ok(())
    });
}

#[test]
fn prop_flowmoe_tuned_dominates_vanilla() {
    // At a *fixed* S_p the chunk-startup overhead can lose to vanilla on
    // adversarial instances — that is exactly why the paper tunes S_p by
    // BO. The invariant that must hold: FlowMoE with a tuned S_p (coarse
    // grid stand-in for BO, including the one-chunk-per-block extreme)
    // never loses to vanilla EP.
    check(60, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let costs = TaskCosts::build(&cfg, &cl);
        let van = simulate(&build_dag(&cfg, &costs, &Policy::vanilla_ep())).makespan;
        let flow = [1e6, 4e6, 16e6, costs.ar_bytes]
            .iter()
            .map(|&sp| simulate(&build_dag(&cfg, &costs, &Policy::flow_moe(2, sp))).makespan)
            .fold(f64::INFINITY, f64::min);
        prop_assert!(flow <= van + 1e-9, "tuned flow {flow} > vanilla {van}");
        Ok(())
    });
}

#[test]
fn prop_ar_priority_ar_never_delays_ready_a2a_at_pick_time() {
    // Scheduling invariant of Algorithm 2: whenever an AR chunk starts,
    // no A2A task was ready-and-waiting on the same stream at that time.
    check(40, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let costs = TaskCosts::build(&cfg, &cl);
        let dag = build_dag(&cfg, &costs, &Policy::flow_moe(2, rng.range_f64(0.5, 8.0) * 1e6));
        let tl = simulate(&dag);
        // finish times
        let mut end = vec![0.0f64; dag.tasks.len()];
        let mut start = vec![0.0f64; dag.tasks.len()];
        for s in &tl.spans {
            end[s.task] = s.end;
            start[s.task] = s.start;
        }
        for s in &tl.spans {
            if !dag.tasks[s.task].kind.is_ar() || dag.tasks[s.task].stream != Stream::Comm {
                continue;
            }
            for t in &dag.tasks {
                if t.stream == Stream::Comm && t.kind.is_a2a() {
                    let ready_at = t
                        .deps
                        .iter()
                        .map(|&d| end[d])
                        .fold(0.0f64, f64::max);
                    // A2A ready strictly before the AR chunk started yet
                    // scheduled after it finishes => priority violation.
                    if ready_at < s.start - 1e-9 && start[t.id] > s.start + 1e-9 {
                        prop_assert!(
                            false,
                            "AR {} started at {} while A2A {} ready at {}",
                            s.task,
                            s.start,
                            t.id,
                            ready_at
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partition_ranges_cover() {
    // The paper's PARTITION procedure: chunks tile [0, len) exactly — no
    // gap, no overlap, no empty chunk — the count is ceil(len/chunk),
    // and only the last chunk may carry the remainder.
    check(200, |rng| {
        let len = rng.below(10_000);
        let chunk = rng.range(1, 4096);
        let ranges = flowmoe::commpool::partition_ranges(len, chunk);
        let total: usize = ranges.iter().map(|(_, l)| l).sum();
        prop_assert!(total == len, "covered {total} of {len}");
        prop_assert!(ranges.len() == len.div_ceil(chunk), "count {} != ceil({len}/{chunk})", ranges.len());
        let mut pos = 0;
        for (i, &(s, l)) in ranges.iter().enumerate() {
            prop_assert!(s == pos, "gap at {s} (expected {pos})");
            prop_assert!(l <= chunk && l > 0, "bad chunk len {l}");
            if i + 1 < ranges.len() {
                prop_assert!(l == chunk, "non-final chunk {i} has len {l} != {chunk}");
            }
            pos = s + l;
        }
        if let Some(&(_, last)) = ranges.last() {
            let rem = len % chunk;
            let want = if rem == 0 { chunk } else { rem };
            prop_assert!(last == want, "last chunk {last} != remainder {want}");
        }
        Ok(())
    });
}

#[test]
fn prop_cost_ar_chunks_matches_partition_count() {
    // The cost model's chunk count (`TaskCosts::ar_chunks`, f64 ceil)
    // must agree with what the runtime partitioner actually produces for
    // the same tensor and chunk size — for every (len, chunk) pair,
    // including exact-multiple and remainder cases. (Both sides are
    // exact: the byte counts are 4*integer, well inside f64's 2^53.)
    check(150, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let costs = TaskCosts::build(&cfg, &cl);
        let chunk_elems = rng.range(1, 1 << 21);
        let sp_bytes = (chunk_elems * 4) as f64;
        let elems = (costs.ar_bytes / 4.0) as usize;
        let ranges = flowmoe::commpool::partition_ranges(elems, chunk_elems);
        let parts = ranges.len().max(1);
        let chunks = costs.ar_chunks(sp_bytes);
        prop_assert!(
            chunks == parts,
            "ar_chunks({sp_bytes}) = {chunks} but partition_ranges({elems}, {chunk_elems}) has {parts}"
        );
        // Boundary agreement (executor unification): the DAG's AR node
        // `Ar { l, c }` stands for the element range starting at
        // c*chunk_elems, so the collective's partitions must tile exactly
        // that grid — same starts, full chunks everywhere except a final
        // remainder, covering [0, elems) with no gap or overlap. With
        // f32 gradients the byte boundaries are then exactly 4x the
        // element boundaries, i.e. chunk c starts at byte c*sp_bytes.
        let mut pos = 0usize;
        for (c, &(start, len)) in ranges.iter().enumerate() {
            prop_assert!(
                start == c * chunk_elems,
                "chunk {c} starts at element {start}, executor node expects {}",
                c * chunk_elems
            );
            prop_assert!(start == pos, "gap/overlap at chunk {c}: start {start} != {pos}");
            let want = chunk_elems.min(elems - start);
            prop_assert!(len == want && len > 0, "chunk {c} len {len} != {want}");
            prop_assert!(
                (start * 4) as f64 == c as f64 * sp_bytes,
                "chunk {c} byte offset {} != c*sp_bytes {}",
                start * 4,
                c as f64 * sp_bytes
            );
            pos = start + len;
        }
        prop_assert!(pos == elems, "partitions cover {pos} of {elems} elements");
        Ok(())
    });
}

#[test]
fn prop_overlap_measured_equals_modeled_on_shared_fixture() {
    // Guard for the unified executor's report: one schedule rendered both
    // ways — as measured `obs::SpanRec`s (whole-second ns timestamps, as
    // the runtime tracer would record them) and as the equivalent
    // simulated `Timeline` — must yield the same OverlapStats from
    // `from_spans` and `from_timeline`. `from_timeline`'s compute busy is
    // a per-span *sum*, so the fixture keeps each stream's spans
    // non-overlapping (exactly what a one-task-at-a-time stream produces);
    // Comm and ArComm spans may still overlap *each other*, exercising
    // the union sweep identically on both sides.
    use flowmoe::obs::{OverlapStats, SpanRec};
    use flowmoe::sim::{Span, Timeline};
    check(120, |rng| {
        let compute_labels: &[&'static str] = &["mha_fwd", "expert_fwd", "head_loss"];
        let comm_labels: &[&'static str] = &["dispatch", "combine", "a2a_dispatch"];
        let ar_labels: &[&'static str] = &["ar_chunk"];
        let mut spans: Vec<Span> = Vec::new();
        let mut recs: Vec<SpanRec> = Vec::new();
        let mut makespan = 0u64;
        let mut task = 0usize;
        for (stream, labels, tid) in [
            (Stream::Compute, compute_labels, 0u32),
            (Stream::Comm, comm_labels, 1u32),
            (Stream::ArComm, ar_labels, 2u32),
        ] {
            // the compute lane always has work and is anchored at t=0 so
            // both walls measure from the same origin
            let anchored = stream == Stream::Compute;
            let n = if anchored { 1 + rng.below(4) } else { rng.below(4) };
            let mut cursor: u64 = if anchored { 0 } else { rng.below(3) as u64 };
            for i in 0..n {
                let start = cursor;
                let end = start + 1 + rng.below(5) as u64;
                spans.push(Span {
                    task,
                    start: start as f64,
                    end: end as f64,
                    stream,
                });
                recs.push(SpanRec {
                    label: *rng.choose(labels),
                    tid,
                    seq: i as u32,
                    start_ns: start * 1_000_000_000,
                    end_ns: end * 1_000_000_000,
                });
                task += 1;
                makespan = makespan.max(end);
                cursor = end + rng.below(3) as u64;
            }
        }
        let measured = OverlapStats::from_spans(&recs);
        let modeled = OverlapStats::from_timeline(&Timeline {
            spans,
            makespan: makespan as f64,
        });
        for (a, b, name) in [
            (measured.wall_s, modeled.wall_s, "wall"),
            (measured.compute_busy_s, modeled.compute_busy_s, "compute busy"),
            (measured.comm_busy_s, modeled.comm_busy_s, "comm busy"),
            (measured.overlap_s, modeled.overlap_s, "overlap"),
        ] {
            prop_assert!(
                (a - b).abs() < 1e-9,
                "{name}: measured {a} != modeled {b}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_bo_result_in_range_and_never_terrible() {
    check(25, |rng| {
        let cfg = random_model(rng);
        let cl = random_cluster(rng, cluster_p(&cfg));
        let max_sp = cfg.ar_bytes_per_block();
        let mut bo = flowmoe::bo::BoTuner::new(max_sp, rng.next_u64());
        let costs = TaskCosts::build(&cfg, &cl);
        let obj = |sp: f64| {
            let dag = build_dag(&cfg, &costs, &Policy::flow_moe(2, sp));
            simulate(&dag).makespan
        };
        let best = bo.tune(8, obj);
        prop_assert!(best > 0.0 && best <= max_sp, "best {best} out of range");
        // BO must beat the worst observed sample by definition of best
        let (_, best_t) = bo.best().unwrap();
        let worst = bo
            .observations
            .iter()
            .map(|(_, t)| *t)
            .fold(0.0f64, f64::max);
        prop_assert!(best_t <= worst, "best {best_t} > worst {worst}");
        Ok(())
    });
}
