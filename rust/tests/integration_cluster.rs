//! Distributed-runtime integration: real multi-worker training (native
//! backend compute + real collectives) and the expert-parallel A2A path,
//! checked against single-process oracles. Runs from a clean checkout
//! (no artifacts, no skips); with `make artifacts` built, the same
//! assertions run against the AOT manifest shapes.

use std::path::PathBuf;

use flowmoe::cluster::{ep_geometry, run_ep_cluster};
use flowmoe::runtime::{Engine, HostTensor};
use flowmoe::trainer::{init_params, train_dp, train_fused, TrainOpts};
use flowmoe::util::Rng;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn dp1_pipelined_matches_fused_train_step() {
    // P=1 pipelined (per-block pieces + microbatching + chunked "AR" of 1
    // worker) must track the fused train_step: same init, same data.
    let dir = artifacts();
    let mut opts = TrainOpts::new("tiny", 5);
    opts.seed = 99;
    let fused = train_fused(&dir, &opts).unwrap();
    let dp = train_dp(&dir, 1, &opts).unwrap();
    for (i, (a, b)) in fused.losses.iter().zip(&dp.losses).enumerate() {
        assert!(
            (a - b).abs() < 2e-3,
            "step {i}: fused {a} vs dp {b}"
        );
    }
    // parameters stay in lockstep too
    for (i, (a, b)) in fused
        .final_params
        .iter()
        .zip(&dp.final_params)
        .enumerate()
    {
        let max = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max < 5e-3, "param {i}: max diff {max}");
    }
}

#[test]
fn dp2_workers_stay_in_sync_and_learn() {
    let dir = artifacts();
    let mut opts = TrainOpts::new("tiny", 40);
    opts.seed = 5;
    opts.lr = 0.1;
    let rep = train_dp(&dir, 2, &opts).unwrap();
    assert_eq!(rep.losses.len(), 40);
    // per-step batches are noisy at this scale: compare means of the
    // first and last fifth of the run
    let head: f32 = rep.losses[..8].iter().sum::<f32>() / 8.0;
    let tail: f32 = rep.losses[32..].iter().sum::<f32>() / 8.0;
    assert!(tail < head - 0.05, "no learning: head {head:.4} tail {tail:.4}");
    for l in &rep.losses {
        assert!(l.is_finite());
    }
}

#[test]
fn dp_overlap_and_centralized_produce_same_losses() {
    // FlowMoE scheduling only reorders communication; convergence must be
    // identical (paper Appendix H).
    let dir = artifacts();
    let mut opts = TrainOpts::new("tiny", 5);
    opts.seed = 21;
    let a = train_dp(&dir, 2, &opts).unwrap();
    opts.overlap = false;
    let b = train_dp(&dir, 2, &opts).unwrap();
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn dp_chunk_size_does_not_change_numerics() {
    let dir = artifacts();
    let mut opts = TrainOpts::new("tiny", 3);
    opts.seed = 31;
    opts.sp_bytes = 1 << 20;
    let a = train_dp(&dir, 2, &opts).unwrap();
    opts.sp_bytes = 512; // absurdly small chunks
    let b = train_dp(&dir, 2, &opts).unwrap();
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn dp_overlap_and_centralized_bit_identical_params() {
    // Appendix H, strengthened: Pipe-AR only *reorders* communication
    // relative to compute — the values entering each all-reduce chunk are
    // identical, chunk partitioning is identical, and a 2-worker f32 sum
    // is commutative bitwise. Final parameters must therefore match bit
    // for bit, not just within tolerance.
    let dir = artifacts();
    let mut opts = TrainOpts::new("tiny", 4);
    opts.seed = 61;
    opts.sp_bytes = 2048; // several chunks per tensor
    let a = train_dp(&dir, 2, &opts).unwrap();
    opts.overlap = false;
    let b = train_dp(&dir, 2, &opts).unwrap();
    assert_eq!(a.losses.len(), b.losses.len());
    for (i, (x, y)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "step {i}: loss {x} vs {y}");
    }
    assert_eq!(a.final_params.len(), b.final_params.len());
    for (i, (pa, pb)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert_eq!(pa.len(), pb.len());
        for (j, (x, y)) in pa.iter().zip(pb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "param {i}[{j}]: {x} vs {y}");
        }
    }
}

#[test]
fn ep_cluster_forward_backward_matches_block_oracle() {
    // Two workers run the real-A2A expert-parallel block; each worker's
    // output and gradients must match the monolithic block pieces run
    // single-process on the same inputs (tiny config is drop-free).
    let dir = artifacts();
    let mut engine = Engine::new(&dir).unwrap();
    let p = 2;
    let geo = ep_geometry(&engine, "tiny", p).unwrap();
    let params = init_params(&engine, "tiny", 55).unwrap();
    let bp = &params[1..10]; // block 0 tensors: n1,wq,wk,wv,wo,n2,wg,w1,w2
    let atp: Vec<Vec<f32>> = bp[..7].to_vec();
    let w1_full = bp[7].clone();
    let w2_full = bp[8].clone();

    let mut rng = Rng::new(77);
    let t_m = geo.t * geo.m;
    let xs: Vec<Vec<f32>> = (0..p)
        .map(|_| (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect())
        .collect();
    let dys: Vec<Vec<f32>> = (0..p)
        .map(|_| (0..t_m).map(|_| rng.normal() as f32 * 0.5).collect())
        .collect();

    let results = run_ep_cluster(
        &dir,
        "tiny",
        p,
        atp.clone(),
        w1_full.clone(),
        w2_full.clone(),
        xs.clone(),
        dys.clone(),
    )
    .unwrap();

    // oracle per worker: block_fwd / block_bwd on its local tokens
    let owned: Vec<HostTensor> = bp.iter().map(|v| HostTensor::F32(v.clone())).collect();
    let mut dw1_total = vec![0.0f32; w1_full.len()];
    let mut dw2_total = vec![0.0f32; w2_full.len()];
    for w in 0..p {
        let x_t = HostTensor::F32(xs[w].clone());
        let dy_t = HostTensor::F32(dys[w].clone());
        let mut inp: Vec<&HostTensor> = owned.iter().collect();
        inp.push(&x_t);
        let y_want = engine.run("block_fwd_tiny", &inp).unwrap().remove(0);
        let max_y: f32 = results[w]
            .y
            .iter()
            .zip(y_want.f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_y < 2e-3, "worker {w}: fwd max diff {max_y}");

        let mut inp: Vec<&HostTensor> = owned.iter().collect();
        inp.push(&x_t);
        inp.push(&dy_t);
        let outs = engine.run("block_bwd_tiny", &inp).unwrap();
        // AT grads (first 7) and dx
        for t in 0..7 {
            let max: f32 = results[w].datp[t]
                .iter()
                .zip(outs[t].f32())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(max < 2e-3, "worker {w}: atp grad {t} max diff {max}");
        }
        let max_dx: f32 = results[w]
            .dx
            .iter()
            .zip(outs[9].f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max_dx < 2e-3, "worker {w}: dx max diff {max_dx}");
        // expert grads from this worker's tokens accumulate
        for (d, s) in dw1_total.iter_mut().zip(outs[7].f32()) {
            *d += s;
        }
        for (d, s) in dw2_total.iter_mut().zip(outs[8].f32()) {
            *d += s;
        }
    }
    // EP owners hold complete expert grads for their shard (summed over
    // all source workers) — the defining property of expert parallelism.
    let shard1 = w1_full.len() / p;
    let shard2 = w2_full.len() / p;
    for w in 0..p {
        let max1: f32 = results[w]
            .dw1
            .iter()
            .zip(&dw1_total[w * shard1..(w + 1) * shard1])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max1 < 2e-3, "worker {w}: dw1 max diff {max1}");
        let max2: f32 = results[w]
            .dw2
            .iter()
            .zip(&dw2_total[w * shard2..(w + 1) * shard2])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max2 < 2e-3, "worker {w}: dw2 max diff {max2}");
    }
}

#[test]
fn ep_geometry_consistent_with_manifest() {
    let dir = artifacts();
    let engine = Engine::new(&dir).unwrap();
    let geo = ep_geometry(&engine, "tiny", 2).unwrap();
    assert_eq!(geo.e, geo.e_local * geo.p);
    assert_eq!(geo.cw, geo.c * geo.p);
    assert!(geo.t > 0 && geo.m > 0 && geo.k > 0);
}
