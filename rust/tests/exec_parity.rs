//! Executor parity: the graph-driven step engine (a policy-built,
//! `analyze::check_dag`-verified DAG walked by `exec::Plan::run_native`)
//! must be bitwise identical to the legacy hand-rolled step loop it
//! replaced — same losses, same final parameters, for every worker
//! count, both AR placements (Pipe-AR overlap and centralized), and the
//! fused single-kernel path. Any divergence means the schedule the
//! analyzer certifies and the schedule the runtime executes have
//! drifted apart again — the exact bug the executor exists to close.

use std::path::PathBuf;

use flowmoe::trainer::{train_dp, train_fused, ExecMode, TrainOpts, TrainReport};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn assert_bitwise_losses(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: step {i}: {x} vs {y}");
    }
}

fn assert_bitwise_params(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: tensor count");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        assert_eq!(pa.len(), pb.len(), "{what}: tensor {i} length");
        for (j, (x, y)) in pa.iter().zip(pb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i}[{j}]: {x} vs {y}");
        }
    }
}

fn assert_reports_match(graph: &TrainReport, legacy: &TrainReport, what: &str) {
    assert_bitwise_losses(&graph.losses, &legacy.losses, what);
    assert_bitwise_params(&graph.final_params, &legacy.final_params, what);
}

/// Run the same config twice — graph-driven and legacy — and return both.
fn dp_pair(p: usize, mut opts: TrainOpts) -> (TrainReport, TrainReport) {
    let dir = artifacts();
    opts.exec = ExecMode::Graph;
    let graph = train_dp(&dir, p, &opts).expect("graph run");
    opts.exec = ExecMode::Legacy;
    let legacy = train_dp(&dir, p, &opts).expect("legacy run");
    (graph, legacy)
}

/// Pipe-AR overlap (the FlowMoE policy) across worker counts, including
/// the degenerate single-worker pipeline.
#[test]
fn dp_overlap_graph_matches_legacy_across_worker_counts() {
    for p in [1usize, 2, 3] {
        let mut opts = TrainOpts::new("tiny", 4);
        opts.seed = 100 + p as u64;
        let (graph, legacy) = dp_pair(p, opts);
        assert_reports_match(&graph, &legacy, &format!("overlap P={p}"));
    }
}

/// Centralized AR (the FlowMoE-AT policy): every chunk is submitted only
/// after the full backward pass, so the graph engine must reproduce the
/// legacy post-backward enqueue order exactly.
#[test]
fn dp_centralized_graph_matches_legacy() {
    let mut opts = TrainOpts::new("tiny", 4);
    opts.seed = 211;
    opts.overlap = false;
    let (graph, legacy) = dp_pair(2, opts);
    assert_reports_match(&graph, &legacy, "centralized P=2");
}

/// A small AR chunk size forces every gradient tensor through multiple
/// `Ar{l, c}` nodes, exercising the chunk-chain dependencies and the
/// executor's submit-before-inline drain order.
#[test]
fn dp_small_chunks_graph_matches_legacy() {
    let mut opts = TrainOpts::new("tiny", 3);
    opts.seed = 307;
    opts.sp_bytes = 2048;
    let (graph, legacy) = dp_pair(2, opts);
    assert_reports_match(&graph, &legacy, "sp_bytes=2048 P=2");
}

/// The fused single-kernel trainer: graph mode binds the whole step to
/// the Head node of a Vanilla-EP plan, and must match the legacy direct
/// kernel loop bit for bit.
#[test]
fn fused_graph_matches_legacy() {
    let dir = artifacts();
    let mut opts = TrainOpts::new("tiny", 4);
    opts.seed = 409;
    opts.exec = ExecMode::Graph;
    let graph = train_fused(&dir, &opts).expect("graph run");
    opts.exec = ExecMode::Legacy;
    let legacy = train_fused(&dir, &opts).expect("legacy run");
    assert_reports_match(&graph, &legacy, "fused");
}
