//! Finite-difference gradient checks for every native-backend kernel.
//!
//! Each backward pass is compared against a central-difference
//! directional derivative of its forward: for scalar objective
//! `L(x) = <f(x), W>` and random direction `v`,
//! `(L(x + eps v) - L(x - eps v)) / (2 eps) ~= <grad, v>`.
//!
//! The MoE path contains two non-smooth choices — the top-k expert
//! selection and the ReLU kink. Selection-dependent checks re-read the
//! routing at both perturbed points and redraw the direction if the
//! discrete choice flipped (the gradient is defined piecewise, exactly
//! like `lax.top_k`'s), so the checks are deterministic under the fixed
//! seeds.
//!
//! Every check also re-runs under the forced **SIMD** dispatch tier
//! (`gradcheck_all_under_simd_dispatch`) so the backward kernels are
//! gradient-checked on the code that actually ships on AVX2 hosts (the
//! portable 8-lane fallback elsewhere). The tolerances already absorb
//! the tier's documented 1e-4 reassociation contract, so no widening is
//! needed.

use flowmoe::backend::kernels as kn;
use flowmoe::backend::model as nm;
use flowmoe::util::Rng;

fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * s).collect()
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Central finite difference of `f` along `v` at `x`.
fn fd_dir<F: Fn(&[f32]) -> f32>(f: F, x: &[f32], v: &[f32], eps: f32) -> f32 {
    let xp: Vec<f32> = x.iter().zip(v).map(|(a, b)| a + eps * b).collect();
    let xm: Vec<f32> = x.iter().zip(v).map(|(a, b)| a - eps * b).collect();
    (f(&xp) - f(&xm)) / (2.0 * eps)
}

#[track_caller]
fn assert_close(fd: f32, an: f32, rel: f32, what: &str) {
    let tol = rel * (fd.abs() + an.abs()) + 3e-3;
    assert!((fd - an).abs() <= tol, "{what}: fd={fd} analytic={an}");
}

const EPS: f32 = 1e-3;

#[test]
fn gradcheck_rmsnorm() {
    let mut rng = Rng::new(101);
    let (t, m) = (4usize, 8usize);
    let x = randv(&mut rng, t * m, 1.0);
    let g = randv(&mut rng, m, 0.8);
    let w = randv(&mut rng, t * m, 1.0);
    let (dx, dg) = kn::rmsnorm_bwd(&x, &g, &w);

    let vx = randv(&mut rng, t * m, 1.0);
    let fd = fd_dir(|xx| dot(&kn::rmsnorm(xx, &g), &w), &x, &vx, EPS);
    assert_close(fd, dot(&dx, &vx), 0.02, "rmsnorm dx");

    let vg = randv(&mut rng, m, 1.0);
    let fd = fd_dir(|gg| dot(&kn::rmsnorm(&x, gg), &w), &g, &vg, EPS);
    assert_close(fd, dot(&dg, &vg), 0.02, "rmsnorm dg");
}

#[test]
fn gradcheck_matmul_adjoints() {
    // d<A@B, W>/dA = W @ B^T, d<A@B, W>/dB = A^T @ W
    let mut rng = Rng::new(102);
    let (m, k, n) = (3usize, 4usize, 5usize);
    let a = randv(&mut rng, m * k, 1.0);
    let b = randv(&mut rng, k * n, 1.0);
    let w = randv(&mut rng, m * n, 1.0);
    let da = kn::matmul_nt(&w, &b, m, n, k);
    let db = kn::matmul_tn(&a, &w, m, k, n);

    let va = randv(&mut rng, m * k, 1.0);
    let fd = fd_dir(|aa| dot(&kn::matmul(aa, &b, m, k, n), &w), &a, &va, EPS);
    assert_close(fd, dot(&da, &va), 0.02, "matmul dA");

    let vb = randv(&mut rng, k * n, 1.0);
    let fd = fd_dir(|bb| dot(&kn::matmul(&a, bb, m, k, n), &w), &b, &vb, EPS);
    assert_close(fd, dot(&db, &vb), 0.02, "matmul dB");
}

#[test]
fn gradcheck_attention_causal() {
    let mut rng = Rng::new(103);
    let (n, d) = (5usize, 4usize);
    let q = randv(&mut rng, n * d, 0.7);
    let k = randv(&mut rng, n * d, 0.7);
    let v = randv(&mut rng, n * d, 0.7);
    let w = randv(&mut rng, n * d, 1.0);
    let (att, _) = kn::attention_causal(&q, &k, &v, n, d);
    let (dq, dk, dv) = kn::attention_causal_bwd(&q, &k, &v, &att, &w, n, d);

    let obj_q = |qq: &[f32]| dot(&kn::attention_causal(qq, &k, &v, n, d).1, &w);
    let vq = randv(&mut rng, n * d, 1.0);
    assert_close(fd_dir(obj_q, &q, &vq, EPS), dot(&dq, &vq), 0.02, "attention dq");

    let obj_k = |kk: &[f32]| dot(&kn::attention_causal(&q, kk, &v, n, d).1, &w);
    let vk = randv(&mut rng, n * d, 1.0);
    assert_close(fd_dir(obj_k, &k, &vk, EPS), dot(&dk, &vk), 0.02, "attention dk");

    let obj_v = |vv: &[f32]| dot(&kn::attention_causal(&q, &k, vv, n, d).1, &w);
    let vv = randv(&mut rng, n * d, 1.0);
    assert_close(fd_dir(obj_v, &v, &vv, EPS), dot(&dv, &vv), 0.02, "attention dv");
}

#[test]
fn gradcheck_gating_topk() {
    // fixed logits with healthy margins: the top-k selection cannot flip
    // under the eps-sized perturbation, so the piecewise gradient is exact
    let (e, k) = (4usize, 2usize);
    let logits = vec![
        1.2, -0.8, 0.4, -1.5, //
        -0.3, 2.0, 0.9, -1.1, //
        0.1, -2.0, 1.4, 0.7,
    ];
    let mut rng = Rng::new(104);
    let w = randv(&mut rng, 3 * k, 1.0);
    let g = kn::gating_topk(&logits, e, k);
    let dlogits = kn::gating_topk_bwd(&g, e, k, &w);

    let v = randv(&mut rng, logits.len(), 1.0);
    let fd = fd_dir(
        |ll| dot(&kn::gating_topk(ll, e, k).gate, &w),
        &logits,
        &v,
        EPS,
    );
    assert_close(fd, dot(&dlogits, &v), 0.02, "gating dlogits");
}

#[test]
fn gradcheck_expert_ffn() {
    // inputs chosen positive so the fd interval stays off the ReLU kink
    // (the kink subgradient itself is pinned by a hand-computed unit test
    // in backend::kernels)
    let mut rng = Rng::new(105);
    let (e, c, m, h) = (2usize, 3usize, 4usize, 5usize);
    let x: Vec<f32> = (0..e * c * m).map(|_| 0.5 + rng.f32()).collect();
    let w1: Vec<f32> = (0..e * m * h).map(|_| 0.2 + rng.f32()).collect();
    let w2 = randv(&mut rng, e * h * m, 0.5);
    let w = randv(&mut rng, e * c * m, 1.0);
    let (dx, dw1, dw2) = kn::expert_ffn_bwd(&x, &w1, &w2, &w, e, c, m, h);

    let obj_x = |xx: &[f32]| dot(&kn::expert_ffn(xx, &w1, &w2, e, c, m, h), &w);
    let vx = randv(&mut rng, x.len(), 1.0);
    assert_close(fd_dir(obj_x, &x, &vx, EPS), dot(&dx, &vx), 0.03, "expert_ffn dx");

    let obj_w1 = |ww: &[f32]| dot(&kn::expert_ffn(&x, ww, &w2, e, c, m, h), &w);
    let v1 = randv(&mut rng, w1.len(), 1.0);
    assert_close(fd_dir(obj_w1, &w1, &v1, EPS), dot(&dw1, &v1), 0.03, "expert_ffn dw1");

    let obj_w2 = |ww: &[f32]| dot(&kn::expert_ffn(&x, &w1, ww, e, c, m, h), &w);
    let v2 = randv(&mut rng, w2.len(), 1.0);
    assert_close(fd_dir(obj_w2, &w2, &v2, EPS), dot(&dw2, &v2), 0.03, "expert_ffn dw2");
}

fn small_geo() -> nm::Geo {
    nm::Geo {
        m: 8,
        e: 4,
        h: 6,
        top_k: 2,
        n_heads: 2,
        n_seq: 4,
        f: 4.0,
        vocab: 10,
    }
}

#[test]
fn gradcheck_head_loss() {
    let g = small_geo();
    let b = 2usize;
    let t = b * g.n_seq;
    let mut rng = Rng::new(106);
    let xf = randv(&mut rng, t * g.m, 1.0);
    let normf: Vec<f32> = (0..g.m).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect();
    let embed = randv(&mut rng, g.vocab * g.m, 0.5);
    let tokens: Vec<i32> = (0..t).map(|_| rng.below(g.vocab) as i32).collect();
    let (_, dxf, dembed, dnormf) = nm::head_loss(&g, &embed, &normf, &xf, &tokens, b);

    let vx = randv(&mut rng, xf.len(), 1.0);
    let fd = fd_dir(|xx| nm::head_loss(&g, &embed, &normf, xx, &tokens, b).0, &xf, &vx, EPS);
    assert_close(fd, dot(&dxf, &vx), 0.02, "head_loss dxf");

    let vn = randv(&mut rng, normf.len(), 1.0);
    let fd = fd_dir(|nn| nm::head_loss(&g, &embed, nn, &xf, &tokens, b).0, &normf, &vn, EPS);
    assert_close(fd, dot(&dnormf, &vn), 0.02, "head_loss dnormf");

    let ve = randv(&mut rng, embed.len(), 1.0);
    let fd = fd_dir(|ee| nm::head_loss(&g, ee, &normf, &xf, &tokens, b).0, &embed, &ve, EPS);
    assert_close(fd, dot(&dembed, &ve), 0.02, "head_loss dembed");
}

/// Block parameter tensors for the small geometry, scaled so activations
/// stay O(1) and routing margins are healthy.
fn small_block_params(g: &nm::Geo, rng: &mut Rng) -> Vec<Vec<f32>> {
    let m = g.m;
    let gain = |rng: &mut Rng| (0..m).map(|_| 1.0 + rng.normal() as f32 * 0.1).collect::<Vec<f32>>();
    let mut out = vec![gain(rng)]; // n1
    for _ in 0..4 {
        out.push(randv(rng, m * m, 0.35)); // wq wk wv wo
    }
    out.push(gain(rng)); // n2
    out.push(randv(rng, m * g.e, 1.0)); // wg (spread logits for stable top-k)
    out.push(randv(rng, g.e * m * g.h, 0.35)); // w1
    out.push(randv(rng, g.e * g.h * m, 0.35)); // w2
    out
}

const BLOCK_TENSOR_NAMES: [&str; 9] = ["n1", "wq", "wk", "wv", "wo", "n2", "wg", "w1", "w2"];

#[test]
fn gradcheck_block_backward_all_tensors() {
    let g = small_geo();
    let c = g.capacity(1); // drop-free: 8 slots >= 4 tokens
    let mut rng = Rng::new(107);
    let params = small_block_params(&g, &mut rng);
    let x = randv(&mut rng, g.n_seq * g.m, 0.7);
    let w = randv(&mut rng, g.n_seq * g.m, 1.0);

    let eval = |ps: &[Vec<f32>], xx: &[f32]| -> (f32, Vec<i32>) {
        let refs: Vec<&[f32]> = ps.iter().map(|v| v.as_slice()).collect();
        let bp = nm::BlockParams::new(&refs);
        let (y, st) = nm::block_forward(&g, &bp, xx, c);
        (dot(&y, &w), st.at.gating.idx)
    };
    let refs: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    let bp = nm::BlockParams::new(&refs);
    let (grads, dx) = nm::block_backward(&g, &bp, &x, c, &w);
    let (_, base_idx) = eval(&params, &x);

    for (ti, name) in BLOCK_TENSOR_NAMES.iter().enumerate() {
        // redraw the direction if the top-k routing flips inside the fd
        // interval (piecewise-defined gradient, cf. module docs)
        let mut checked = false;
        for _attempt in 0..10 {
            let v = randv(&mut rng, params[ti].len(), 1.0);
            let mut pp = params.clone();
            for (a, b) in pp[ti].iter_mut().zip(&v) {
                *a += EPS * b;
            }
            let (fp, ip) = eval(&pp, &x);
            for (a, b) in pp[ti].iter_mut().zip(&v) {
                *a -= 2.0 * EPS * b;
            }
            let (fm, im) = eval(&pp, &x);
            if ip != base_idx || im != base_idx {
                continue;
            }
            let fd = (fp - fm) / (2.0 * EPS);
            assert_close(fd, dot(&grads[ti], &v), 0.05, &format!("block d{name}"));
            checked = true;
            break;
        }
        assert!(checked, "no routing-stable fd direction found for {name}");
    }

    // dx
    let mut checked = false;
    for _attempt in 0..10 {
        let v = randv(&mut rng, x.len(), 1.0);
        let xp: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a + EPS * b).collect();
        let xm: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a - EPS * b).collect();
        let (fp, ip) = eval(&params, &xp);
        let (fm, im) = eval(&params, &xm);
        if ip != base_idx || im != base_idx {
            continue;
        }
        let fd = (fp - fm) / (2.0 * EPS);
        assert_close(fd, dot(&dx, &v), 0.05, "block dx");
        checked = true;
        break;
    }
    assert!(checked, "no routing-stable fd direction found for dx");
}

#[test]
fn gradcheck_at_backward_all_tensors() {
    let g = small_geo();
    let mut rng = Rng::new(108);
    let params = small_block_params(&g, &mut rng);
    let x = randv(&mut rng, g.n_seq * g.m, 0.7);
    let t = g.n_seq;
    let ch = randv(&mut rng, t * g.m, 1.0);
    let cu = randv(&mut rng, t * g.m, 1.0);
    let cg = randv(&mut rng, t * g.top_k, 1.0);

    let eval = |ps: &[Vec<f32>], xx: &[f32]| -> (f32, Vec<i32>) {
        let refs: Vec<&[f32]> = ps[..7].iter().map(|v| v.as_slice()).collect();
        let atp = nm::AtParams::new(&refs);
        let st = nm::at_forward(&g, &atp, xx);
        let obj = dot(&st.mha.h, &ch) + dot(&st.u, &cu) + dot(&st.gating.gate, &cg);
        (obj, st.gating.idx)
    };
    let refs: Vec<&[f32]> = params[..7].iter().map(|v| v.as_slice()).collect();
    let atp = nm::AtParams::new(&refs);
    let st = nm::at_forward(&g, &atp, &x);
    let (grads, dx) = nm::at_backward(&g, &atp, &x, &st, &ch, &cu, &cg);
    let base_idx = st.gating.idx.clone();

    for (ti, name) in BLOCK_TENSOR_NAMES[..7].iter().enumerate() {
        let mut checked = false;
        for _attempt in 0..10 {
            let v = randv(&mut rng, params[ti].len(), 1.0);
            let mut pp = params.clone();
            for (a, b) in pp[ti].iter_mut().zip(&v) {
                *a += EPS * b;
            }
            let (fp, ip) = eval(&pp, &x);
            for (a, b) in pp[ti].iter_mut().zip(&v) {
                *a -= 2.0 * EPS * b;
            }
            let (fm, im) = eval(&pp, &x);
            if ip != base_idx || im != base_idx {
                continue;
            }
            let fd = (fp - fm) / (2.0 * EPS);
            assert_close(fd, dot(&grads[ti], &v), 0.05, &format!("at d{name}"));
            checked = true;
            break;
        }
        assert!(checked, "no routing-stable fd direction found for at {name}");
    }

    let mut checked = false;
    for _attempt in 0..10 {
        let v = randv(&mut rng, x.len(), 1.0);
        let xp: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a + EPS * b).collect();
        let xm: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a - EPS * b).collect();
        let (fp, ip) = eval(&params, &xp);
        let (fm, im) = eval(&params, &xm);
        if ip != base_idx || im != base_idx {
            continue;
        }
        let fd = (fp - fm) / (2.0 * EPS);
        assert_close(fd, dot(&dx, &v), 0.05, "at dx");
        checked = true;
        break;
    }
    assert!(checked, "no routing-stable fd direction found for at dx");
}

#[test]
fn gradcheck_embed_lookup_scatter_adjoint() {
    // <lookup(E), dX> == <E, scatter(dX)> on a larger random instance
    let mut rng = Rng::new(109);
    let (v, m, t) = (12usize, 6usize, 9usize);
    let embed = randv(&mut rng, v * m, 1.0);
    let tokens: Vec<i32> = (0..t).map(|_| rng.below(v) as i32).collect();
    let dx = randv(&mut rng, t * m, 1.0);
    let lhs = dot(&kn::embed_lookup(&embed, &tokens, m), &dx);
    let rhs = dot(&embed, &kn::embed_scatter(&tokens, &dx, v, m));
    assert_close(lhs, rhs, 0.001, "embed adjoint");

    // fd: embedding enters linearly, so the fd matches to fp noise
    let ve = randv(&mut rng, embed.len(), 1.0);
    let fd = fd_dir(|ee| dot(&kn::embed_lookup(ee, &tokens, m), &dx), &embed, &ve, EPS);
    let an = dot(&kn::embed_scatter(&tokens, &dx, v, m), &ve);
    assert_close(fd, an, 0.02, "embed fd");
}

/// The SIMD satellite: every finite-difference check above re-runs with
/// the `simd` tier forced (AVX2+FMA where detected, the portable 8-lane
/// fallback otherwise), same seeds, same tolerances — so the shipping
/// SIMD backward kernels are gradient-checked, not just the scalar ones.
#[test]
fn gradcheck_all_under_simd_dispatch() {
    kn::with_dispatch(kn::Dispatch::Simd, || {
        gradcheck_rmsnorm();
        gradcheck_matmul_adjoints();
        gradcheck_attention_causal();
        gradcheck_gating_topk();
        gradcheck_expert_ffn();
        gradcheck_head_loss();
        gradcheck_block_backward_all_tensors();
        gradcheck_at_backward_all_tensors();
        gradcheck_embed_lookup_scatter_adjoint();
    });
}
