//! Simulator + scheduler integration: paper-table-shaped assertions over
//! the simulated timelines (the per-table benches print the full rows;
//! these tests pin the structural claims).

use flowmoe::config::{preset, ClusterProfile, ModelCfg};
use flowmoe::cost::TaskCosts;
use flowmoe::metrics::{energy_joules, peak_memory, sm_utilization};
use flowmoe::sched::{build_dag, iteration_time, Policy};
use flowmoe::sim::{simulate, verify_timeline};
use flowmoe::tasks::Stream;

fn all_policies() -> Vec<Policy> {
    vec![
        Policy::vanilla_ep(),
        Policy::faster_moe(2),
        Policy::tutel(2),
        Policy::sche_moe(2),
        Policy::fs_moe(2),
        Policy::flow_moe_at(2),
        Policy::flow_moe_ar(2, 2.5e6),
        Policy::flow_moe(2, 2.5e6),
        Policy::flow_moe_cc(2, 2.5e6),
    ]
}

#[test]
fn every_policy_and_model_simulates_validly() {
    let cl = ClusterProfile::cluster1(16);
    for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE", "DeepSeek-V2-S"] {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &cl);
        for pol in all_policies() {
            let dag = build_dag(&cfg, &costs, &pol);
            dag.validate().unwrap();
            let tl = simulate(&dag);
            verify_timeline(&dag, &tl).unwrap();
        }
    }
}

#[test]
fn table1_mha_ar_ratio_band() {
    // Paper Table 1: MHA+gating + all-reduce = 30-40 % of the vanilla
    // iteration on Cluster 1 / 16 GPUs. Assert 20-50 % on the simulated
    // timeline for all four models.
    let cl = ClusterProfile::cluster1(16);
    for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE", "LLaMA2-MoE", "DeepSeek-V2-S"] {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &cl);
        let dag = build_dag(&cfg, &costs, &Policy::vanilla_ep());
        let tl = simulate(&dag);
        let mut mha = 0.0;
        let mut ar = 0.0;
        for t in &dag.tasks {
            let span = tl.span_of(t.id).unwrap();
            match t.kind {
                flowmoe::tasks::TaskKind::At { .. } => mha += span.end - span.start,
                flowmoe::tasks::TaskKind::Ar { .. } => ar += span.end - span.start,
                _ => {}
            }
        }
        let ratio = (mha + ar) / tl.makespan;
        assert!(
            (0.18..=0.55).contains(&ratio),
            "{name}: (MHA+AR)/iter = {ratio:.3}"
        );
    }
}

#[test]
fn table3_scaling_4_8_16_gpus() {
    // FlowMoE must beat every baseline at every cluster size, and
    // vanilla's iteration must grow with the cluster (comm-bound growth,
    // as in the paper's Table 3 rows).
    for gpus in [4usize, 8, 16] {
        let cl = ClusterProfile::cluster1(gpus);
        for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE"] {
            let base = preset(name).unwrap();
            let cfg = base.with_experts_for_workers(base.e / 16, gpus);
            let flow = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 2.5e6)).0;
            for pol in [
                Policy::vanilla_ep(),
                Policy::faster_moe(2),
                Policy::tutel(2),
                Policy::sche_moe(2),
                Policy::fs_moe(2),
            ] {
                let t = iteration_time(&cfg, &cl, &pol).0;
                assert!(
                    flow < t,
                    "{name}@{gpus}: FlowMoE {flow:.4} !< {} {t:.4}",
                    pol.name
                );
            }
        }
    }
    // vanilla grows with cluster size (per-GPU batch fixed, comm grows)
    let t4 = {
        let cfg = preset("BERT-Large-MoE").unwrap().with_experts_for_workers(2, 4);
        iteration_time(&cfg, &ClusterProfile::cluster1(4), &Policy::vanilla_ep()).0
    };
    let t16 = {
        let cfg = preset("BERT-Large-MoE").unwrap().with_experts_for_workers(2, 16);
        iteration_time(&cfg, &ClusterProfile::cluster1(16), &Policy::vanilla_ep()).0
    };
    assert!(t16 > t4, "t16={t16} t4={t4}");
}

#[test]
fn table4_r_degree_flowmoe_always_wins() {
    // FlowMoE as deployed (concurrent NCCL communicators, cc mode — see
    // EXPERIMENTS.md §Findings) beats Tutel and ScheMoE at every R.
    let cfg = preset("DeepSeek-V2-S").unwrap();
    let cl = ClusterProfile::cluster1(16);
    for r in [2usize, 4, 8] {
        let tut = iteration_time(&cfg, &cl, &Policy::tutel(r)).0;
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(r)).0;
        let flow = iteration_time(&cfg, &cl, &Policy::flow_moe_cc(r, 2.5e6)).0;
        assert!(flow < sche && flow < tut, "R={r}: {flow} vs {sche}/{tut}");
    }
}

#[test]
fn table6_energy_and_memory_ordering() {
    // Table 6: FlowMoE lowest energy and memory; FasterMoE highest memory.
    let cl = ClusterProfile::cluster1(16);
    for name in ["BERT-Large-MoE", "LLaMA2-MoE"] {
        let cfg = preset(name).unwrap();
        let costs = TaskCosts::build(&cfg, &cl);
        let run = |pol: &Policy| {
            let dag = build_dag(&cfg, &costs, pol);
            let tl = simulate(&dag);
            let e = energy_joules(&tl, &cl.power);
            let m = peak_memory(&cfg, &cl, pol, &dag, &tl);
            (e, m)
        };
        let (ev, mv) = run(&Policy::vanilla_ep());
        let (et, mt) = run(&Policy::tutel(2));
        let (ef, mf) = run(&Policy::flow_moe(2, 2.5e6));
        let (efm, mfm) = run(&Policy::faster_moe(2));
        assert!(ef < et && ef < ev && ef < efm, "{name} energy");
        assert!(mf < mt && mf <= mv * 1.001, "{name} memory flow");
        assert!(mfm > mv, "{name} memory fasterMoE");
    }
}

#[test]
fn tableA7_stress_scaled_models_and_oom() {
    // LLaMA2-MoE-L at 16 GPUs OOMs on Cluster 1 (24 GB); DeepSeek-V2-M
    // fits and FlowMoE wins.
    let cl = ClusterProfile::cluster1(16);
    let l_l = preset("LLaMA2-MoE-L").unwrap();
    let mem = flowmoe::cost::peak_memory_bytes(&l_l, 16, l_l.l as f64, 1.0);
    assert!(mem > cl.mem_bytes, "LLaMA2-MoE-L should OOM: {mem}");
    let dsm = preset("DeepSeek-V2-M").unwrap();
    let mem2 = flowmoe::cost::peak_memory_bytes(&dsm, 16, dsm.l as f64, 1.0);
    assert!(mem2 < cl.mem_bytes, "DeepSeek-V2-M should fit: {mem2}");
    let van = iteration_time(&dsm, &cl, &Policy::vanilla_ep()).0;
    // DeepSeek-V2-M's replicated-gradient AR is 2.9 GB — tuned chunk size
    // matters enormously (tiny S_p adds seconds of launch overhead).
    let flow = [4e6, 16e6, 64e6, 256e6]
        .iter()
        .map(|&sp| iteration_time(&dsm, &cl, &Policy::flow_moe_cc(2, sp)).0)
        .fold(f64::INFINITY, f64::min);
    assert!(flow < van, "flow {flow} !< vanilla {van}");
}

#[test]
fn tableA12_heterogeneous_cluster_flowmoe_still_wins() {
    let cl = ClusterProfile::cluster1_heterogeneous(16);
    for name in ["GPT2-Tiny-MoE", "BERT-Large-MoE"] {
        let cfg = preset(name).unwrap();
        let van = iteration_time(&cfg, &cl, &Policy::vanilla_ep()).0;
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0;
        let flow = iteration_time(&cfg, &cl, &Policy::flow_moe(2, 2.5e6)).0;
        assert!(flow < sche && sche < van, "{name}: {flow} {sche} {van}");
        // slower than the homogeneous cluster
        let uni = iteration_time(&cfg, &ClusterProfile::cluster1(16), &Policy::flow_moe(2, 2.5e6)).0;
        assert!(flow > uni);
    }
}

#[test]
fn fig6_custom_layer_sweep_sample() {
    // A slice of the 675-layer sweep. The paper claims FlowMoE beats
    // ScheMoE in *all* valid cases (mean 1.26x); under honest modelling
    // that cannot hold on extremely comm-dominated single layers, where
    // ScheMoE's optimized A2A ops (~15 % faster payload path, which
    // FlowMoE does not include — paper Sec. 5.2) outweigh AT-pipelining
    // (Appendix I case 1). We assert the reproducible shape: FlowMoE wins
    // the large majority of cases and on average (EXPERIMENTS.md §Fig6).
    let cl = ClusterProfile::cluster1(16);
    let mut speedups = Vec::new();
    let mut wins = 0usize;
    for b in [2usize, 8] {
        for f in [1.0, 1.2] {
            for n in [512usize, 2048] {
                for m in [512usize, 4096] {
                    for h in [1024usize, 8192] {
                        let cfg = ModelCfg::custom_layer(b, f, n, m, h, 16);
                        if flowmoe::cost::peak_memory_bytes(&cfg, 16, 1.0, 1.0) > cl.mem_bytes {
                            continue;
                        }
                        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0;
                        // deployed cc mode, BO-tuned S_p (coarse grid)
                        let flow = [1e6, 4e6, 16e6, 64e6]
                            .iter()
                            .map(|&sp| iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, sp)).0)
                            .fold(f64::INFINITY, f64::min);
                        if flow < sche {
                            wins += 1;
                        }
                        speedups.push(sche / flow);
                    }
                }
            }
        }
    }
    let mean = flowmoe::util::mean(&speedups);
    let win_rate = wins as f64 / speedups.len() as f64;
    assert!(win_rate >= 0.6, "win rate {win_rate:.2} over {} cases", speedups.len());
    assert!(mean > 1.0, "mean speedup {mean:.3}");
}

#[test]
fn appendix_i_performance_bounds() {
    // Case (2): compute >> comm => FlowMoE beats the MoE-pipeliners by
    // hiding AR; case (1): comm >> compute => FlowMoE >= ScheMoE-class
    // but still >= vanilla gain. Synthesize both regimes.
    let cl = ClusterProfile::cluster1(16);
    // compute-heavy: huge M/H, tiny N
    let mut heavy = ModelCfg::custom_layer(4, 1.0, 512, 8192, 8192, 16);
    heavy.l = 4;
    let tut = iteration_time(&heavy, &cl, &Policy::tutel(2)).0;
    let flow = iteration_time(&heavy, &cl, &Policy::flow_moe(2, 8e6)).0;
    assert!(flow < tut, "compute-heavy: {flow} !< {tut}");
    // comm-heavy: big tokens, small model dims
    let mut light = ModelCfg::custom_layer(8, 1.0, 2048, 512, 512, 16);
    light.l = 4;
    let van = iteration_time(&light, &cl, &Policy::vanilla_ep()).0;
    let flow2 = iteration_time(&light, &cl, &Policy::flow_moe(2, 2.5e6)).0;
    assert!(flow2 < van, "comm-heavy: {flow2} !< {van}");
}

#[test]
fn sm_utilization_decreases_with_r_small_model() {
    // Appendix J / Table A.8: finer microbatches lower the compute-stream
    // occupancy for the small model.
    let cfg = preset("GPT2-Tiny-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let costs = TaskCosts::build(&cfg, &cl);
    let util = |r: usize| {
        let dag = build_dag(&cfg, &costs, &Policy::flow_moe(r, 2.5e6));
        sm_utilization(&simulate(&dag))
    };
    let (u2, u8) = (util(2), util(8));
    assert!(u8 <= u2 + 1e-9, "u8={u8} u2={u2}");
}

#[test]
fn chrome_trace_export_is_valid_shape() {
    let cfg = preset("GPT2-Tiny-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let costs = TaskCosts::build(&cfg, &cl);
    let dag = build_dag(&cfg, &costs, &Policy::flow_moe(2, 2.5e6));
    let tl = simulate(&dag);
    let json = tl.to_chrome_trace(&dag);
    assert!(json.starts_with("[\n") && json.trim_end().ends_with(']'));
    assert_eq!(json.matches("\"ph\": \"X\"").count(), dag.len());
    assert!(json.contains("ATf[0,0]"));
    assert!(json.contains("AR["));
}

#[test]
fn flowmoe_with_schemoe_a2a_integration_is_fastest() {
    // The paper's stated combination ("ScheMoE's strategy can also be
    // integrated into FlowMoE"): FlowMoE scheduling + ScheMoE's faster
    // A2A path beats both parents.
    let cl = ClusterProfile::cluster1(16);
    for name in ["BERT-Large-MoE", "LLaMA2-MoE"] {
        let cfg = preset(name).unwrap();
        let sche = iteration_time(&cfg, &cl, &Policy::sche_moe(2)).0;
        let flow = iteration_time(&cfg, &cl, &Policy::flow_moe_cc(2, 2.5e6)).0;
        let combined = iteration_time(&cfg, &cl, &Policy::flow_moe_sche(2, 2.5e6)).0;
        assert!(combined < sche && combined < flow, "{name}: {combined} vs {sche}/{flow}");
    }
}

#[test]
fn auto_r_selection_table4() {
    // R auto-selection (PipeMoE-style, sched::autor) matches or beats the
    // best fixed R from the Table 4 sweep.
    let cfg = preset("DeepSeek-V2-S").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let best_fixed = [2usize, 4, 8]
        .iter()
        .map(|&r| iteration_time(&cfg, &cl, &Policy::flow_moe(r, 2.5e6)).0)
        .fold(f64::INFINITY, f64::min);
    let (r, t, _) = flowmoe::sched::autor::select_r(&cfg, &cl, |r| Policy::flow_moe(r, 2.5e6));
    assert!(t <= best_fixed + 1e-12, "auto R={r}: {t} vs best fixed {best_fixed}");
}

#[test]
fn comm_stream_occupancy_sane() {
    let cfg = preset("BERT-Large-MoE").unwrap();
    let cl = ClusterProfile::cluster1(16);
    let costs = TaskCosts::build(&cfg, &cl);
    let dag = build_dag(&cfg, &costs, &Policy::flow_moe(2, 2.5e6));
    let tl = simulate(&dag);
    for s in [Stream::Compute, Stream::Comm] {
        let o = tl.occupancy(s);
        assert!((0.05..=1.0).contains(&o), "{s:?} occupancy {o}");
    }
    assert!(tl.busy_comm() <= tl.makespan + 1e-9);
}
