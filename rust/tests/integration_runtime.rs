//! Runtime integration: execute the exported entry points end to end and
//! check numerics against structural invariants. Runs on the native
//! backend from a clean checkout (no skips); with `make artifacts` built,
//! the same assertions run against the AOT manifest shapes.

use std::path::PathBuf;

use flowmoe::runtime::{Engine, HostTensor};
use flowmoe::util::Rng;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn rand_f32(rng: &mut Rng, n: usize, scale: f32) -> HostTensor {
    HostTensor::F32((0..n).map(|_| rng.normal() as f32 * scale).collect())
}

#[test]
fn manifest_lists_tiny_and_e2e() {
    let dir = artifacts();
    let engine = Engine::new(&dir).unwrap();
    for name in [
        "train_step_tiny",
        "grad_step_tiny",
        "block_fwd_tiny",
        "block_bwd_tiny",
        "embed_fwd_tiny",
        "head_loss_tiny",
        "embed_bwd_tiny",
        "at_fwd_tiny",
        "at_bwd_tiny",
        "exp_fwd_tiny",
        "exp_bwd_tiny",
        "train_step_e2e",
    ] {
        assert!(engine.manifest().get(name).is_ok(), "missing {name}");
    }
}

#[test]
fn exp_fwd_matches_host_reference() {
    // exp_fwd computes relu(x@w1)@w2 per expert — recompute on the host.
    let dir = artifacts();
    let mut engine = Engine::new(&dir).unwrap();
    let spec = engine.manifest().get("exp_fwd_tiny").unwrap().clone();
    let (el, m, h) = (
        spec.inputs[0].shape[0],
        spec.inputs[0].shape[1],
        spec.inputs[0].shape[2],
    );
    let cw = spec.inputs[2].shape[1];
    let mut rng = Rng::new(42);
    let w1 = rand_f32(&mut rng, el * m * h, 0.2);
    let w2 = rand_f32(&mut rng, el * h * m, 0.2);
    let xd = rand_f32(&mut rng, el * cw * m, 1.0);
    let out = engine.run("exp_fwd_tiny", &[&w1, &w2, &xd]).unwrap();
    let yd = out[0].f32();

    // host reference
    let (w1v, w2v, xv) = (w1.f32(), w2.f32(), xd.f32());
    let mut max_err = 0.0f32;
    for e in 0..el {
        for c in 0..cw {
            for j in 0..m {
                let mut acc = 0.0f32;
                for k in 0..h {
                    let mut hidden = 0.0f32;
                    for i in 0..m {
                        hidden += xv[(e * cw + c) * m + i] * w1v[(e * m + i) * h + k];
                    }
                    acc += hidden.max(0.0) * w2v[(e * h + k) * m + j];
                }
                max_err = max_err.max((acc - yd[(e * cw + c) * m + j]).abs());
            }
        }
    }
    assert!(max_err < 1e-3, "max_err={max_err}");
}

#[test]
fn train_step_runs_and_loss_is_sane() {
    let dir = artifacts();
    let mut engine = Engine::new(&dir).unwrap();
    let spec = engine.manifest().get("train_step_tiny").unwrap().clone();
    let n_params = spec
        .inputs
        .iter()
        .filter(|b| b.name.starts_with("param."))
        .count();
    let params = flowmoe::trainer::init_params(&engine, "tiny", 7).unwrap();
    assert_eq!(params.len(), n_params);
    let vocab = spec.inputs[0].shape[0];
    let tok_spec = spec.inputs.iter().find(|b| b.name == "tokens").unwrap();
    let n_tok = tok_spec.elems();
    let mut rng = Rng::new(3);
    let tokens = HostTensor::I32((0..n_tok).map(|_| rng.below(vocab) as i32).collect());
    let lr = HostTensor::F32(vec![0.05]);
    let mut inputs: Vec<HostTensor> = params.iter().map(|p| HostTensor::F32(p.clone())).collect();
    inputs.extend(params.iter().map(|p| HostTensor::F32(vec![0.0; p.len()])));
    inputs.push(tokens);
    inputs.push(lr);
    let refs: Vec<&HostTensor> = inputs.iter().collect();
    let outs = engine.run("train_step_tiny", &refs).unwrap();
    let loss = outs[2 * n_params].scalar_f32();
    // random init on vocab=128 => loss near ln(128) = 4.85
    assert!(loss.is_finite() && loss > 2.0 && loss < 8.0, "loss={loss}");
    // params must have changed
    let new0 = outs[0].f32();
    assert!(new0.iter().zip(&params[0]).any(|(a, b)| (a - b).abs() > 0.0));
}

#[test]
fn grad_step_grads_match_fused_direction() {
    // One grad_step + host SGD must equal one train_step output.
    let dir = artifacts();
    let mut engine = Engine::new(&dir).unwrap();
    let params = flowmoe::trainer::init_params(&engine, "tiny", 11).unwrap();
    let n_params = params.len();
    let spec = engine.manifest().get("grad_step_tiny").unwrap().clone();
    let tok_spec = spec.inputs.iter().find(|b| b.name == "tokens").unwrap();
    let mut rng = Rng::new(5);
    let tokens = HostTensor::I32(
        (0..tok_spec.elems())
            .map(|_| rng.below(128) as i32)
            .collect(),
    );

    // grad_step
    let mut inputs: Vec<HostTensor> = params.iter().map(|p| HostTensor::F32(p.clone())).collect();
    inputs.push(tokens.clone());
    let refs: Vec<&HostTensor> = inputs.iter().collect();
    let outs = engine.run("grad_step_tiny", &refs).unwrap();
    let loss_g = outs[0].scalar_f32();
    let grads: Vec<&[f32]> = outs[1..].iter().map(|t| t.f32()).collect();

    // train_step with lr, zero momentum: new_p = p - lr * g
    let lr = 0.05f32;
    let mut inputs2: Vec<HostTensor> = params.iter().map(|p| HostTensor::F32(p.clone())).collect();
    inputs2.extend(params.iter().map(|p| HostTensor::F32(vec![0.0; p.len()])));
    inputs2.push(tokens);
    inputs2.push(HostTensor::F32(vec![lr]));
    let refs2: Vec<&HostTensor> = inputs2.iter().collect();
    let outs2 = engine.run("train_step_tiny", &refs2).unwrap();
    let loss_t = outs2[2 * n_params].scalar_f32();
    assert!((loss_g - loss_t).abs() < 1e-5, "{loss_g} vs {loss_t}");
    for i in 0..n_params {
        let want: Vec<f32> = params[i]
            .iter()
            .zip(grads[i])
            .map(|(p, g)| p - lr * g)
            .collect();
        let got = outs2[i].f32();
        let max: f32 = want
            .iter()
            .zip(got)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max < 1e-4, "param {i}: max diff {max}");
    }
}

#[test]
fn block_fwd_bwd_pieces_compose_to_grad_step() {
    // The exact orchestration the trainer performs, with the microbatch
    // repeated to fill the batch so the fused grad_step computes the same
    // mean loss. Tiny config is drop-free, so equality is exact to fp
    // tolerance.
    let dir = artifacts();
    let mut engine = Engine::new(&dir).unwrap();
    let params = flowmoe::trainer::init_params(&engine, "tiny", 13).unwrap();
    let n_params = params.len();
    let l_blocks = (n_params - 2) / 9;

    let ef = engine.manifest().get("embed_fwd_tiny").unwrap().clone();
    let (bm, n_tok) = (ef.inputs[1].shape[0], ef.inputs[1].shape[1]);
    let mut rng = Rng::new(17);
    let tokens = HostTensor::I32((0..bm * n_tok).map(|_| rng.below(128) as i32).collect());

    let embed = HostTensor::F32(params[0].clone());
    let normf = HostTensor::F32(params[n_params - 1].clone());

    // forward
    let mut xs = vec![engine
        .run("embed_fwd_tiny", &[&embed, &tokens])
        .unwrap()
        .remove(0)];
    for l in 0..l_blocks {
        let owned: Vec<HostTensor> = params[1 + l * 9..1 + (l + 1) * 9]
            .iter()
            .map(|v| HostTensor::F32(v.clone()))
            .collect();
        let mut inp: Vec<&HostTensor> = owned.iter().collect();
        inp.push(&xs[l]);
        xs.push(engine.run("block_fwd_tiny", &inp).unwrap().remove(0));
    }
    let outs = engine
        .run("head_loss_tiny", &[&embed, &normf, &xs[l_blocks], &tokens])
        .unwrap();
    let loss = outs[0].scalar_f32();
    let mut dx = outs[1].clone();
    let de_head = outs[2].f32().to_vec();
    let dnormf = outs[3].f32().to_vec();

    // backward
    let mut block_grads: Vec<Vec<Vec<f32>>> = vec![Vec::new(); l_blocks];
    for l in (0..l_blocks).rev() {
        let owned: Vec<HostTensor> = params[1 + l * 9..1 + (l + 1) * 9]
            .iter()
            .map(|v| HostTensor::F32(v.clone()))
            .collect();
        let mut inp: Vec<&HostTensor> = owned.iter().collect();
        inp.push(&xs[l]);
        inp.push(&dx);
        let outs = engine.run("block_bwd_tiny", &inp).unwrap();
        block_grads[l] = outs[..9].iter().map(|t| t.f32().to_vec()).collect();
        dx = outs.into_iter().nth(9).unwrap();
    }
    let de_in = engine
        .run("embed_bwd_tiny", &[&tokens, &dx])
        .unwrap()
        .remove(0);
    let de: Vec<f32> = de_in.f32().iter().zip(&de_head).map(|(a, b)| a + b).collect();

    // fused oracle: repeat the microbatch to fill B (mean over identical
    // halves == microbatch mean).
    let reps = {
        let ts = engine.manifest().get("train_step_tiny").unwrap();
        let full_b = ts.inputs.iter().find(|b| b.name == "tokens").unwrap().shape[0];
        full_b / bm
    };
    let mut toks_full = Vec::new();
    for _ in 0..reps {
        toks_full.extend_from_slice(tokens.i32());
    }
    let mut inputs: Vec<HostTensor> = params.iter().map(|p| HostTensor::F32(p.clone())).collect();
    inputs.push(HostTensor::I32(toks_full));
    let refs: Vec<&HostTensor> = inputs.iter().collect();
    let outs = engine.run("grad_step_tiny", &refs).unwrap();
    let loss_f = outs[0].scalar_f32();
    assert!((loss - loss_f).abs() < 1e-4, "{loss} vs {loss_f}");
    let check = |got: &[f32], want: &[f32], what: &str| {
        let max: f32 = got
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max < 5e-3, "{what}: max diff {max}");
    };
    check(&de, outs[1].f32(), "embed");
    check(&dnormf, outs[1 + n_params - 1].f32(), "normf");
    for l in 0..l_blocks {
        for t in 0..9 {
            check(
                &block_grads[l][t],
                outs[1 + 1 + l * 9 + t].f32(),
                &format!("block{l}.{t}"),
            );
        }
    }
}
