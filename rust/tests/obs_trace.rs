//! Observability integration: tracing must never perturb training
//! numerics, and every trace the repo can emit (runtime spans from the
//! native path, the simulator's modeled timeline) must pass the dep-free
//! JSON well-formedness scan and carry the task families the paper's
//! pipeline overlaps.

use std::path::PathBuf;
use std::sync::{Mutex, PoisonError};

use flowmoe::obs;
use flowmoe::testutil::scan_json;
use flowmoe::trainer::{train_dp, train_fused, TrainOpts};

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Tests here toggle the process-global tracing flag and drain the
/// process-global span buffers; serialize them so the parallel test
/// harness can't interleave another toggle or drain mid-test.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_locked() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

#[test]
fn traced_train_fused_is_bitwise_identical_to_untraced() {
    let _g = obs_locked();
    let dir = artifacts();
    let opts = TrainOpts::new("tiny", 2);

    obs::set_enabled(false);
    let _ = obs::take_spans();
    let plain = train_fused(&dir, &opts).unwrap();

    obs::set_enabled(true);
    let traced = train_fused(&dir, &opts).unwrap();
    obs::set_enabled(false);
    let spans = obs::take_spans();

    // tracing observed real work...
    assert!(!spans.is_empty(), "traced run recorded no spans");
    // ...without changing a single bit of it
    assert_eq!(plain.losses.len(), traced.losses.len());
    for (a, b) in plain.losses.iter().zip(&traced.losses) {
        assert_eq!(a.to_bits(), b.to_bits(), "loss diverged under tracing");
    }
    assert_eq!(plain.final_params.len(), traced.final_params.len());
    for (pa, pb) in plain.final_params.iter().zip(&traced.final_params) {
        assert_eq!(pa.len(), pb.len());
        for (a, b) in pa.iter().zip(pb) {
            assert_eq!(a.to_bits(), b.to_bits(), "param diverged under tracing");
        }
    }
}

#[test]
fn traced_train_dp_emits_wellformed_trace_with_all_task_families() {
    let _g = obs_locked();
    let dir = artifacts();
    let opts = TrainOpts::new("tiny", 2);

    obs::set_enabled(false);
    let _ = obs::take_spans();
    obs::set_enabled(true);
    let report = train_dp(&dir, 2, &opts).unwrap();
    obs::set_enabled(false);
    let spans = obs::take_spans();

    assert_eq!(report.losses.len(), 2);
    assert!(!spans.is_empty());

    // spans are well-formed intervals, ordered by (thread, seq)
    for w in spans.windows(2) {
        assert!(
            (w[0].tid, w[0].seq) < (w[1].tid, w[1].seq),
            "spans not sorted by (tid, seq)"
        );
    }
    for s in &spans {
        assert!(s.start_ns <= s.end_ns, "span {} ends before it starts", s.label);
    }

    // all five task families of the paper's pipeline show up: MHA,
    // gating, expert FFN, dispatch/combine (A2A), update + all-reduce
    let labels: Vec<&str> = spans.iter().map(|s| s.label).collect();
    for family in ["mha_fwd", "mha_bwd", "gating_fwd", "expert_fwd", "expert_bwd", "dispatch", "combine", "ar_chunk", "update"] {
        assert!(labels.contains(&family), "no `{family}` span in traced train_dp run");
    }

    // the chrome-trace export of those spans is scannable JSON and
    // carries the escaped labels
    let json = obs::chrome_trace(&spans);
    scan_json(&json).expect("runtime chrome trace failed the JSON scan");
    assert!(json.contains("\"mha_fwd\""));
    assert!(json.contains("\"ph\": \"X\""));

    // the training report carries a metrics snapshot with the per-phase
    // histograms the trainer feeds
    let hist_names: Vec<&str> = report.stats.hists.iter().map(|h| h.name.as_str()).collect();
    for h in ["fwd_s", "bwd_s", "step_s", "update_s"] {
        assert!(hist_names.contains(&h), "missing `{h}` histogram in report.stats");
    }

    // measured overlap stats are computable and sane
    let st = obs::OverlapStats::from_spans(&spans);
    assert!(st.wall_s > 0.0);
    assert!(st.compute_busy_s > 0.0);
    assert!(st.overlap_s <= st.compute_busy_s.min(st.comm_busy_s) + 1e-12);
}

#[test]
fn sim_chrome_trace_passes_json_scan() {
    // no obs state touched — the modeled timeline export shares the
    // escaping and event shape with the runtime tracer
    use flowmoe::config::{preset, ClusterProfile};
    use flowmoe::cost::TaskCosts;
    use flowmoe::sched::{build_dag, Policy};
    use flowmoe::sim::simulate;

    let cfg = preset("tiny").unwrap();
    let cl = ClusterProfile::cluster1(2);
    let costs = TaskCosts::build(&cfg, &cl);
    let pol = Policy::flow_moe(flowmoe::backend::NATIVE_MICRO_R, 0.25e6);
    let dag = build_dag(&cfg, &costs, &pol);
    let tl = simulate(&dag);
    let json = tl.to_chrome_trace(&dag);
    scan_json(&json).expect("sim chrome trace failed the JSON scan");
    assert!(json.starts_with("[\n") && json.ends_with("]\n"));
}
